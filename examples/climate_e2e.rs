//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! Pipeline proved here (nothing mocked):
//!   Pallas kernels (L1) -> JAX LKGP graph (L2) -> AOT HLO artifacts
//!   -> rust coordinator (L3) loads them on the PJRT CPU client,
//!   runs Adam/CG marginal-likelihood training with live loss logging,
//!   draws 64 pathwise-conditioning posterior samples, and reports
//!   RMSE/NLL on held-out missing cells of a ~37k-point spatiotemporal
//!   climate grid (the paper's Table-2 workload, scaled).
//!
//! Requires `make artifacts`. Results are appended to
//! results/e2e_climate.md and summarized in EXPERIMENTS.md.
//!
//! Run: cargo run --release --example climate_e2e [train_iters]
//!
//! Expected output: per-iteration loss logging, then held-out RMSE/NLL
//! on the missing cells and a results/e2e_climate.md append. Without
//! `make artifacts` the example exits early with an "artifacts
//! unavailable" message — that is the expected offline behavior.

use lkgp::data::climate::ClimateSim;
use lkgp::gp::backend::PjrtKronBackend;
use lkgp::gp::lkgp::{Lkgp, LkgpConfig};
use lkgp::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let train_iters: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(10);
    let t_all = std::time::Instant::now();

    // artifact config dictates static shapes: p=384 stations, q=96 days
    let rt = Runtime::load_default()?;
    let cfg = rt.manifest.config("climate")?.clone();
    println!(
        "artifacts: config 'climate' p={} q={} (grid {} cells), platform {}",
        cfg.p,
        cfg.q,
        cfg.p * cfg.q,
        rt.platform()
    );
    let data = ClimateSim::default_temperature(cfg.p, cfg.q, 0.3, 0);
    println!(
        "dataset: {} | observed {}/{} ({:.0}% missing)\n",
        data.name,
        data.n_observed(),
        data.grid_len(),
        100.0 * data.missing_ratio()
    );

    let mut backend = PjrtKronBackend::new(rt, "climate")?;
    let fit_cfg = LkgpConfig {
        train_iters,
        n_samples: 64,
        cg_max_iters: 150,
        seed: 0,
        ..LkgpConfig::default()
    };
    println!("training {train_iters} Adam steps on the marginal likelihood (PJRT path)...");
    let fit = Lkgp::fit_backend(&data, &fit_cfg, &mut backend)?;

    println!("\nloss curve (0.5 y^T alpha, standardized units):");
    for (i, l) in fit.loss_trace.iter().enumerate() {
        let bar = "#".repeat(((l / fit.loss_trace[0]).clamp(0.0, 2.0) * 30.0) as usize);
        println!("  step {i:>3}: {l:>10.2} {bar}");
    }

    let (train_rmse, train_nll) = fit.posterior.train_metrics(&data);
    let (test_rmse, test_nll) = fit.posterior.test_metrics(&data);
    let rtref = backend.runtime();
    let summary = format!(
        "\n== e2e climate run ==\n\
         grid: {}x{} = {} cells, 30% missing (test set {})\n\
         backend: PJRT CPU, artifacts climate/*.hlo.txt\n\
         training: {} Adam steps, {} CG iterations, {} MVM batches\n\
         pjrt: {} artifact executions, {:.1}s inside PJRT\n\
         time: {:.1}s train + {:.1}s predict = {:.1}s total\n\
         final hypers: log_sigma2 {:.3}\n\
         train: rmse {:.3} nll {:.3}\n\
         test : rmse {:.3} nll {:.3}\n",
        data.p(),
        data.q(),
        data.grid_len(),
        data.grid_len() - data.n_observed(),
        fit.loss_trace.len() - 1,
        fit.cg_iters_total,
        fit.mvm_total,
        rtref.exec_calls,
        rtref.exec_secs,
        fit.train_secs,
        fit.predict_secs,
        t_all.elapsed().as_secs_f64(),
        fit.log_sigma2,
        train_rmse,
        train_nll,
        test_rmse,
        test_nll,
    );
    println!("{summary}");
    println!("profile:\n{}", fit.profile.render());

    // persist for EXPERIMENTS.md
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join("e2e_climate.md"), &summary)?;
    println!("[saved results/e2e_climate.md]");
    Ok(())
}
