//! Proposition 3.1 in practice: analytic break-even points vs *measured*
//! MVM-time crossover on real operators.
//!
//! For a grid of (p, q) shapes, sweeps the missing ratio and reports the
//! ratio where the dense observed-matrix MVM becomes faster than the
//! latent-Kronecker MVM, next to the analytic gamma*_time.
//!
//! Run: cargo run --release --example breakeven
//!
//! Expected output: one line per (p, q) shape with the measured
//! crossover missing-ratio next to the analytic gamma*_time — the two
//! should agree to within a few percentage points (timing noise moves
//! the measured value run to run). Takes tens of seconds in release.

use lkgp::kernels::ProductGridKernel;
use lkgp::kron::{breakeven, KronOp, MaskedKronSystem};
use lkgp::linalg::Matrix;
use lkgp::util::bench::black_box;
use lkgp::util::rng::Rng;

fn measure_secs(mut f: impl FnMut()) -> f64 {
    // calibrated repeat-timing
    let t0 = std::time::Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-7);
    let reps = ((0.05 / once) as usize).clamp(1, 2000);
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    println!("Prop 3.1: predicted vs measured MVM break-even missing ratio\n");
    println!(
        "{:>6} {:>6} {:>12} {:>12} {:>10}",
        "p", "q", "gamma*_time", "measured", "|diff|"
    );
    let mut rng = Rng::new(7);
    for (p, q) in [(96usize, 8usize), (128, 16), (192, 12)] {
        let kernel = ProductGridKernel::new(3, "rbf", q);
        let s = Matrix::from_vec(p, 3, rng.normals(p * 3));
        let t: Vec<f64> = (0..q).map(|k| k as f64 / (q - 1) as f64).collect();
        let kss = kernel.gram_s(&s);
        let ktt = kernel.gram_t(&t);
        let gamma_star = breakeven::gamma_time(p, q);

        let mut crossover = f64::NAN;
        let mut prev: Option<(f64, f64)> = None;
        for step in 0..18 {
            let gamma = 0.05 + 0.05 * step as f64;
            let n = breakeven::observed_count(p, q, gamma);
            let mask: Vec<f64> = {
                let mut m = vec![1.0; p * q];
                let missing = rng.choose(p * q, p * q - n);
                for i in missing {
                    m[i] = 0.0;
                }
                m
            };
            let obs: Vec<usize> = (0..p * q).filter(|&i| mask[i] != 0.0).collect();
            // kron MVM
            let sys =
                MaskedKronSystem::new(KronOp::new(kss.clone(), ktt.clone()), mask, 0.1);
            let v = Matrix::from_vec(1, p * q, rng.normals(p * q));
            let t_kron = measure_secs(|| {
                black_box(sys.apply_batch(&v));
            });
            // dense MVM on the n x n observed matrix
            let dense = {
                let full = sys.op.dense();
                full.submatrix(&obs, &obs)
            };
            let vd = Matrix::from_vec(1, n, rng.normals(n));
            let t_dense = measure_secs(|| {
                black_box(dense.matvec(vd.row(0)));
            });
            let speed = t_dense / t_kron;
            if let Some((g0, s0)) = prev {
                if s0 >= 1.0 && speed < 1.0 && crossover.is_nan() {
                    crossover = g0 + (gamma - g0) * (s0 - 1.0) / (s0 - speed).max(1e-9);
                }
            }
            prev = Some((gamma, speed));
        }
        println!(
            "{:>6} {:>6} {:>12.3} {:>12.3} {:>10.3}",
            p,
            q,
            gamma_star,
            crossover,
            (crossover - gamma_star).abs()
        );
    }
    println!("\n(measured crossover uses wall-clock MVM on this machine; the paper's\n Fig. 3 observation is that it lands near the asymptotic prediction)");
}
