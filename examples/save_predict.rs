//! Train-once / serve-many end to end: fit an LKGP, checkpoint the
//! pathwise state to disk, reload it in a fresh engine, and serve
//! batched predictions — demonstrating that the served posterior is
//! bit-identical to the in-memory fit (paper Sec. 3.3: after pathwise
//! conditioning, prediction is only cheap MVMs).
//!
//! Run: cargo run --release --example save_predict
//!
//! Expected output: dataset + fit summary, the checkpoint size on disk,
//! a "bit-identical: true" integrity line after reload, per-batch serve
//! latencies for a ragged query mix, and a predictive-mean row for a
//! brand-new spatial point (off-grid query). Exits non-zero if any
//! round-trip check fails.

use lkgp::data::synthetic::well_specified;
use lkgp::gp::lkgp::{Lkgp, LkgpConfig};
use lkgp::kernels::ProductGridKernel;
use lkgp::linalg::Matrix;
use lkgp::model::TrainedModel;
use lkgp::serve::{BatchRequest, ServeEngine};

fn main() -> anyhow::Result<()> {
    println!("=== 1. Train once (the expensive phase) ===\n");
    let kernel = ProductGridKernel::new(2, "rbf", 12);
    let data = well_specified(48, 12, 2, &kernel, 0.02, 0.3, 1);
    println!(
        "dataset: p={} q={} observed {}/{} ({}% missing)",
        data.p(),
        data.q(),
        data.n_observed(),
        data.grid_len(),
        (100.0 * data.missing_ratio()).round()
    );
    let fit = Lkgp::fit(
        &data,
        LkgpConfig { train_iters: 15, capture_pathwise: true, ..LkgpConfig::default() },
    )?;
    let (test_rmse, test_nll) = fit.posterior.test_metrics(&data);
    println!("fit: test rmse {test_rmse:.4}, nll {test_nll:.4}, {:.2}s train", fit.train_secs);

    println!("\n=== 2. Checkpoint the pathwise state ===\n");
    let model = fit.model.as_ref().expect("capture_pathwise was set");
    let path = std::env::temp_dir().join("lkgp_save_predict_example.ckpt");
    let bytes = model.save(&path)?;
    println!(
        "wrote {} ({:.1} KiB: hypers + grid metadata + representer \
         weights + {} pathwise samples)",
        path.display(), bytes as f64 / 1024.0, model.n_samples
    );

    println!("\n=== 3. Serve from the checkpoint (the cheap phase) ===\n");
    // one decode: load, then hand the model to the engine
    let engine = ServeEngine::from_model(TrainedModel::load(&path)?)?;
    println!("posterior reconstructed in {:.3}s (MVMs only, no CG)", engine.reconstruct_secs());
    let rep = engine.verify();
    println!("bit-identical to stored posterior: {}", rep.bit_identical);
    let mut exact = rep.bit_identical;
    for (a, b) in fit.posterior.mean.iter().zip(&engine.posterior().mean) {
        exact &= a.to_bits() == b.to_bits();
    }
    for (a, b) in fit.posterior.mean.iter().zip(&engine.reconstructed().mean) {
        exact &= a.to_bits() == b.to_bits();
    }
    anyhow::ensure!(exact, "round-trip was not bit-identical");

    // ragged batch mix, coalesced into one steal-scheduled sweep
    let pq = data.grid_len();
    let batches = vec![
        BatchRequest { cells: (0..pq).collect() },
        BatchRequest { cells: (0..pq).step_by(7).collect() },
        BatchRequest { cells: vec![0, pq - 1] },
    ];
    let t0 = std::time::Instant::now();
    let res = engine.predict_batch(&batches)?;
    let dt = t0.elapsed().as_secs_f64();
    let served: usize = res.iter().map(|r| r.mean.len()).sum();
    println!(
        "served {} predictions across {} ragged batches in {:.2} us \
         ({:.0} predictions/s)",
        served, batches.len(), dt * 1e6, served as f64 / dt.max(1e-12)
    );

    println!("\n=== 4. New-user query (off-grid spatial point) ===\n");
    let s_star = Matrix::from_vec(1, 2, vec![0.1, -0.4]);
    let mu = engine.predict_new_points(&s_star)?;
    let row: Vec<f64> = mu.row(0).iter().map(|x| (x * 1000.0).round() / 1000.0).collect();
    println!("predictive mean across the {} time steps: {row:?}", data.q());

    std::fs::remove_file(&path).ok();
    println!("\nround trip OK — the fit/serve boundary is lossless.");
    Ok(())
}
