//! Quickstart: the latent-Kronecker idea end to end in under a minute.
//!
//! 1. Demonstrates Figure 1 numerically: the kernel matrix of observed
//!    values IS the projection of the latent Kronecker product — no
//!    approximation.
//! 2. Fits an exact LKGP on a small synthetic partial grid and prints
//!    train/test metrics.
//!
//! Run: cargo run --release --example quickstart
//!
//! Expected output: a max projection error around 1e-16 (the latent
//! Kronecker structure is exact, not approximate), test RMSE well below
//! the data std with a finite NLL, and the analytic break-even missing
//! ratios of Prop. 3.1 for three (p, q) shapes. Runs in seconds.

use lkgp::data::synthetic::well_specified;
use lkgp::gp::lkgp::{Lkgp, LkgpConfig};
use lkgp::kernels::ProductGridKernel;
use lkgp::kron::{breakeven, KronOp, MaskedKronSystem};
use lkgp::linalg::Matrix;
use lkgp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("=== 1. Latent Kronecker structure is exact (Figure 1) ===\n");
    let mut rng = Rng::new(0);
    let (p, q) = (4, 3);
    let kernel = ProductGridKernel::new(2, "rbf", q);
    let s = Matrix::from_vec(p, 2, rng.normals(p * 2));
    let t: Vec<f64> = vec![0.0, 0.5, 1.0];
    let kss = kernel.gram_s(&s);
    let ktt = kernel.gram_t(&t);
    let op = KronOp::new(kss, ktt);

    // drop observation (s_0, t_2) — the grid is no longer Cartesian
    let mut mask = vec![1.0; p * q];
    mask[2] = 0.0;
    println!("grid {p}x{q}, missing cell (s_0, t_2) -> n = {}", p * q - 1);

    // dense ground truth: submatrix of the full Kronecker product
    let dense = op.dense();
    let obs: Vec<usize> = (0..p * q).filter(|&i| mask[i] != 0.0).collect();
    let sub = dense.submatrix(&obs, &obs);

    // latent-Kronecker path: masked MVM, never materializing anything
    let sys = MaskedKronSystem::new(op, mask.clone(), 0.0);
    let mut max_err = 0.0f64;
    for (col_pos, &col_idx) in obs.iter().enumerate() {
        let mut e = Matrix::zeros(1, p * q);
        e[(0, col_idx)] = 1.0;
        let kcol = sys.apply_batch(&e);
        for (row_pos, &row_idx) in obs.iter().enumerate() {
            max_err = max_err.max((kcol[(0, row_idx)] - sub[(row_pos, col_pos)]).abs());
        }
    }
    println!("max |P(K_SS (x) K_TT)P^T  -  K_XX| = {max_err:.2e}  (exactly zero up to fp)\n");

    println!("=== 2. Exact GP regression on a partial grid ===\n");
    let kernel = ProductGridKernel::new(2, "rbf", 12);
    let data = well_specified(48, 12, 2, &kernel, 0.02, 0.3, 1);
    println!(
        "dataset: p={} q={} observed {}/{} ({}% missing)",
        data.p(),
        data.q(),
        data.n_observed(),
        data.grid_len(),
        (100.0 * data.missing_ratio()).round()
    );
    let fit = Lkgp::fit(&data, LkgpConfig { train_iters: 20, ..LkgpConfig::default() })?;
    let (train_rmse, train_nll) = fit.posterior.train_metrics(&data);
    let (test_rmse, test_nll) = fit.posterior.test_metrics(&data);
    println!("train: rmse {train_rmse:.4}, nll {train_nll:.4}");
    println!("test : rmse {test_rmse:.4}, nll {test_nll:.4}");
    println!(
        "fit took {:.2}s train + {:.2}s predict, {} CG iterations total",
        fit.train_secs, fit.predict_secs, fit.cg_iters_total
    );

    println!("\n=== 3. When is latent Kronecker worth it? (Prop 3.1) ===\n");
    for (p, q) in [(5000, 7), (2000, 52), (5000, 1000)] {
        println!(
            "p={p:<5} q={q:<5} -> break-even missing ratio: time {:.1}%, memory {:.1}%",
            100.0 * breakeven::gamma_time(p, q),
            100.0 * breakeven::gamma_mem(p, q)
        );
    }
    Ok(())
}
