//! Learning-curve prediction (the paper's AutoML experiment, Sec. 4):
//! fit an exact LKGP over (hyperparameter config) x (epoch) learning
//! curves where 90% of curves are right-censored, then extrapolate —
//! the early-stopping decision problem.
//!
//! Run: cargo run --release --example learning_curves
//!
//! Expected output: the censored-dataset summary, extrapolation
//! RMSE/NLL on the withheld curve tails, and an early-stopping check
//! reporting where the truly best censored curve lands in the
//! predicted final-value ranking (it should place near the top of the
//! ~115 censored curves). Runs in under a minute in release.

use lkgp::data::lcbench::LcBenchSim;
use lkgp::gp::lkgp::{Lkgp, LkgpConfig};

fn main() -> anyhow::Result<()> {
    let sim = LcBenchSim::new(128, 52, 17);
    let data = sim.generate();
    println!(
        "sim-LCBench: {} curves x {} epochs, {} observed cells ({}% missing, right-censored)",
        data.p(),
        data.q(),
        data.n_observed(),
        (100.0 * data.missing_ratio()).round()
    );

    let fit = Lkgp::fit(
        &data,
        LkgpConfig { train_iters: 20, n_samples: 32, ..LkgpConfig::default() },
    )?;
    let (test_rmse, test_nll) = fit.posterior.test_metrics(&data);
    println!("extrapolation quality: test rmse {test_rmse:.3}, test nll {test_nll:.3}\n");

    // early-stopping utility: rank curves by predicted final value and
    // compare against the true final ranking
    let q = data.q();
    let censored: Vec<usize> =
        (0..data.p()).filter(|&j| !data.mask[j * q + q - 1]).collect();
    let mut pred_final: Vec<(usize, f64)> = censored
        .iter()
        .map(|&j| (j, fit.posterior.mean[j * q + q - 1]))
        .collect();
    pred_final.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let true_best = censored
        .iter()
        .min_by(|&&a, &&b| {
            data.y_grid[a * q + q - 1].partial_cmp(&data.y_grid[b * q + q - 1]).unwrap()
        })
        .copied()
        .unwrap();
    let predicted_rank_of_true_best = pred_final
        .iter()
        .position(|&(j, _)| j == true_best)
        .unwrap();
    println!(
        "early stopping: true best curve {} ranked #{} of {} by predicted final error",
        true_best,
        predicted_rank_of_true_best + 1,
        censored.len()
    );

    // spot-check one censored curve
    let j = censored[censored.len() / 2];
    let prefix = (0..q).take_while(|&k| data.mask[j * q + k]).count();
    println!("\ncurve {j}: observed through epoch {prefix}, extrapolated to {q}:");
    println!("{:>6} {:>10} {:>10} {:>8}", "epoch", "truth", "pred", "2sigma");
    for k in (0..q).step_by(6) {
        let idx = j * q + k;
        println!(
            "{:>6} {:>10.2} {:>10.2} {:>8.2}{}",
            k,
            data.y_grid[idx],
            fit.posterior.mean[idx],
            2.0 * fit.posterior.var[idx].sqrt(),
            if data.mask[idx] { "" } else { "   <- missing" },
        );
    }
    Ok(())
}
