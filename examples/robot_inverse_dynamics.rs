//! Inverse dynamics of a simulated 7-DOF arm (the paper's SARCOS
//! experiment): multi-output regression with k_S = SE(R^21) and a
//! full-rank ICM task kernel over the 7 joint torques.
//!
//! Compares LKGP against the standard dense iterative method at one
//! missing ratio, verifying: identical predictions, different cost.
//!
//! Run: cargo run --release --example robot_inverse_dynamics
//!
//! Expected output: a side-by-side LKGP vs dense-iterative table with
//! near-identical test RMSE/NLL (prediction gap around 1e-2 RMSE or
//! less, limited by CG tolerance), while LKGP reports far fewer kernel
//! bytes — the Fig-3 "same predictions, different cost" claim. Runs in
//! a minute or two in release.

use lkgp::data::sarcos::SarcosSim;
use lkgp::gp::backend::MvmMode;
use lkgp::gp::lkgp::{Backend, Lkgp, LkgpConfig};
use lkgp::kron::breakeven;

fn main() -> anyhow::Result<()> {
    let (p, missing) = (256, 0.3);
    let data = SarcosSim::new(p, missing, 3).generate();
    println!(
        "sim-SARCOS: {} joint states x 7 torques, {}% of torque readings missing",
        p,
        (missing * 100.0) as u32
    );
    println!(
        "Prop 3.1: break-even at missing {:.0}% (time) / {:.0}% (memory) for p={p}, q=7\n",
        100.0 * breakeven::gamma_time(p, 7),
        100.0 * breakeven::gamma_mem(p, 7),
    );

    let cfg = LkgpConfig { train_iters: 15, n_samples: 32, seed: 1, ..LkgpConfig::default() };
    let lkgp = Lkgp::fit(&data, cfg.clone())?;
    let dense = Lkgp::fit(
        &data,
        LkgpConfig { backend: Backend::Rust(MvmMode::DenseMaterialized), ..cfg },
    )?;

    println!("{:<26} {:>12} {:>12}", "", "LKGP", "dense iterative");
    let (lr, ln) = lkgp.posterior.test_metrics(&data);
    let (dr, dn) = dense.posterior.test_metrics(&data);
    println!("{:<26} {:>12.4} {:>12.4}", "test RMSE", lr, dr);
    println!("{:<26} {:>12.4} {:>12.4}", "test NLL", ln, dn);
    println!(
        "{:<26} {:>12.2} {:>12.2}",
        "total seconds",
        lkgp.train_secs + lkgp.predict_secs,
        dense.train_secs + dense.predict_secs
    );
    println!(
        "{:<26} {:>12} {:>12}",
        "kernel bytes", lkgp.kernel_bytes, dense.kernel_bytes
    );
    println!(
        "\nsame model, same solver, same seed -> prediction gap {:.2e} RMSE \
         (the latent Kronecker structure is exact; only the cost changes)",
        (lr - dr).abs()
    );
    Ok(())
}
