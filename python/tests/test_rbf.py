"""L1 Pallas RBF Gram kernel vs broadcast oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.rbf import rbf_gram

rows = st.integers(min_value=1, max_value=80)
feats = st.integers(min_value=1, max_value=24)


def rand(rng, *shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), dtype)


@settings(max_examples=25, deadline=None)
@given(m=rows, n=rows, d=feats, seed=st.integers(0, 2**31 - 1))
def test_rbf_matches_ref_shapes(m, n, d, seed):
    rng = np.random.default_rng(seed)
    x, y = rand(rng, m, d), rand(rng, n, d)
    np.testing.assert_allclose(
        rbf_gram(x, y), ref.rbf_ref(x, y), rtol=1e-5, atol=1e-5
    )


def test_rbf_diagonal_is_one():
    rng = np.random.default_rng(0)
    x = rand(rng, 37, 5)
    k = rbf_gram(x, x)
    np.testing.assert_allclose(np.diag(k), np.ones(37), rtol=1e-5, atol=1e-5)


def test_rbf_symmetric_and_bounded():
    rng = np.random.default_rng(1)
    x = rand(rng, 50, 3)
    k = np.asarray(rbf_gram(x, x))
    np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-5)
    assert (k <= 1.0 + 1e-5).all() and (k >= 0.0).all()


def test_rbf_psd():
    """Gram matrix of the SE kernel must be PSD (+ tiny float slack)."""
    rng = np.random.default_rng(2)
    x = rand(rng, 40, 4)
    k = np.asarray(rbf_gram(x, x), np.float64)
    evals = np.linalg.eigvalsh(0.5 * (k + k.T))
    assert evals.min() > -1e-5


@pytest.mark.parametrize("block", [(8, 8), (32, 16)])
def test_rbf_block_shapes(block):
    rng = np.random.default_rng(3)
    x, y = rand(rng, 27, 6), rand(rng, 41, 6)
    np.testing.assert_allclose(
        rbf_gram(x, y, block=block), ref.rbf_ref(x, y), rtol=1e-5, atol=1e-5
    )


def test_rbf_vjp_matches_jnp():
    rng = np.random.default_rng(4)
    x, y = rand(rng, 13, 3), rand(rng, 17, 3)
    f_pallas = lambda x, y: jnp.sum(rbf_gram(x, y) ** 2)
    f_ref = lambda x, y: jnp.sum(ref.rbf_ref(x, y) ** 2)
    gx, gy = jax.grad(f_pallas, argnums=(0, 1))(x, y)
    gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gy, gy_r, rtol=1e-4, atol=1e-4)
