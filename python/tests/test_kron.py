"""Latent-Kronecker algebra: the paper's Section-3 identities.

Verifies the masked Kronecker MVM against the *materialized*
``M (K_SS (x) K_TT) M + sigma2 I`` — i.e. the exactness claim that latent
Kronecker structure is a lazy re-expression, not an approximation.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.kron_mvm import kron_apply, kron_mvm

small = st.integers(min_value=1, max_value=12)


def spd(rng, n):
    a = rng.normal(size=(n, n))
    return jnp.asarray(a @ a.T + n * np.eye(n), jnp.float32)


@settings(max_examples=20, deadline=None)
@given(p=small, q=small, b=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_kron_apply_matches_dense_kron(p, q, b, seed):
    rng = np.random.default_rng(seed)
    kss, ktt = spd(rng, p), spd(rng, q)
    v = jnp.asarray(rng.normal(size=(b, p * q)), jnp.float32)
    got = kron_apply(kss, ktt, v)
    want = (jnp.kron(kss, ktt) @ v.T).T
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@settings(max_examples=20, deadline=None)
@given(
    p=small,
    q=small,
    b=st.integers(1, 4),
    missing=st.floats(0.0, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_kron_mvm_matches_dense_projection(p, q, b, missing, seed):
    rng = np.random.default_rng(seed)
    kss, ktt = spd(rng, p), spd(rng, q)
    mask = jnp.asarray(rng.random(p * q) >= missing, jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, p * q)), jnp.float32)
    got = kron_mvm(kss, ktt, mask, 0.25, v)
    want = ref.kron_mvm_dense_ref(kss, ktt, mask, 0.25, v)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_kron_mvm_preserves_observed_subspace():
    """Masked RHS stays masked: CG iterates never leave the observed
    subspace, which is what makes padded-space CG exact (Section 3)."""
    rng = np.random.default_rng(0)
    p, q = 7, 5
    kss, ktt = spd(rng, p), spd(rng, q)
    mask = jnp.asarray(rng.random(p * q) >= 0.4, jnp.float32)
    v = jnp.asarray(rng.normal(size=(3, p * q)), jnp.float32) * mask[None, :]
    out = np.asarray(kron_mvm(kss, ktt, mask, 0.1, v))
    assert np.abs(out[:, np.asarray(mask) == 0]).max() < 1e-6


def test_kron_mvm_full_mask_equals_kron_plus_noise():
    rng = np.random.default_rng(1)
    p, q = 6, 4
    kss, ktt = spd(rng, p), spd(rng, q)
    mask = jnp.ones(p * q, jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, p * q)), jnp.float32)
    got = kron_mvm(kss, ktt, mask, 0.5, v)
    want = (jnp.kron(kss, ktt) @ v.T).T + 0.5 * v
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_layout_convention_row_major_pq():
    """v[j*q + k] is (s_j, t_k): kron_apply must equal K_SS V K_TT^T."""
    rng = np.random.default_rng(2)
    p, q = 5, 3
    kss, ktt = spd(rng, p), spd(rng, q)
    v = jnp.asarray(rng.normal(size=(1, p * q)), jnp.float32)
    got = np.asarray(kron_apply(kss, ktt, v)).reshape(p, q)
    want = np.asarray(kss) @ np.asarray(v).reshape(p, q) @ np.asarray(ktt).T
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
