"""L2 model builders: Gram-matrix properties, prior sampling, and the
Hutchinson MLL gradient against dense ground truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import CONFIGS, n_theta
from compile.model import (
    BUILDERS,
    build_kernels,
    build_mll_grads,
    build_prior_sample,
    unpack_theta,
)

TINY = CONFIGS["tiny"]


def make_inputs(cfg, seed=0):
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.normal(size=(cfg["p"], cfg["ds"])), jnp.float32)
    t = jnp.asarray(np.linspace(0, 1, cfg["q"])[:, None], jnp.float32)
    theta = jnp.asarray(0.1 * rng.normal(size=n_theta(cfg)), jnp.float32)
    return rng, s, t, theta


def dense_khat(cfg, s, t, theta, sigma2, mask):
    kss, ktt = build_kernels(cfg)(s, t, theta)
    kfull = jnp.kron(kss, ktt)
    m = jnp.diag(mask)
    return m @ kfull @ m + sigma2 * jnp.eye(kfull.shape[0])


@pytest.mark.parametrize("cname", ["tiny", "sarcos", "lcbench", "climate"])
def test_kernels_psd_and_shapes(cname):
    cfg = dict(CONFIGS[cname])
    cfg["p"], cfg["q"] = min(cfg["p"], 24), min(cfg["q"], 12)  # keep tests fast
    _, s, t, theta = make_inputs(cfg)
    kss, ktt = build_kernels(cfg)(s, t, theta)
    assert kss.shape == (cfg["p"], cfg["p"]) and ktt.shape == (cfg["q"], cfg["q"])
    for k in (kss, ktt):
        k64 = np.asarray(k, np.float64)
        np.testing.assert_allclose(k64, k64.T, rtol=1e-5, atol=1e-5)
        assert np.linalg.eigvalsh(0.5 * (k64 + k64.T)).min() > -1e-4


def test_kernels_outputscale_on_diagonal():
    cfg = TINY
    _, s, t, theta = make_inputs(cfg)
    th = unpack_theta(cfg, theta)
    kss, _ = build_kernels(cfg)(s, t, theta)
    np.testing.assert_allclose(
        np.diag(np.asarray(kss)),
        np.exp(float(th["log_os"][0])) * np.ones(cfg["p"]),
        rtol=1e-5,
    )


def test_prior_sample_matches_dense_cholesky_covariance():
    """Cov[(L_S (x) L_T) z] must equal K_SS (x) K_TT exactly, so the
    factored sample equals a dense-Cholesky sample in distribution.
    We verify L_S (x) L_T (L_S (x) L_T)^T == K (x) K on the same z.
    (Factorization happens host-side; the artifact applies the factors.)"""
    cfg = TINY
    rng, s, t, theta = make_inputs(cfg, seed=1)
    kss, ktt = build_kernels(cfg)(s, t, theta)
    ls = jnp.linalg.cholesky(kss + 1e-6 * jnp.eye(cfg["p"]))
    lt = jnp.linalg.cholesky(ktt + 1e-6 * jnp.eye(cfg["q"]))
    pq = cfg["p"] * cfg["q"]
    nsamp = 4000
    z = jnp.asarray(rng.normal(size=(nsamp, pq)), jnp.float32)
    f = np.asarray(build_prior_sample(cfg)(ls, lt, z)[0], np.float64)
    emp = f.T @ f / nsamp
    want = np.kron(np.asarray(kss, np.float64), np.asarray(ktt, np.float64))
    # statistical tolerance ~ 1/sqrt(nsamp)
    assert np.abs(emp - want).max() < 0.15 * np.abs(want).max() + 0.05


def test_mll_grads_match_dense_same_probe_gradient():
    """Deterministic check: the artifact's gradient must equal jax.grad
    of the *dense* surrogate with the same alpha/W/Z (no estimator
    noise involved)."""
    cfg = TINY
    rng, s, t, theta = make_inputs(cfg, seed=2)
    pq = cfg["p"] * cfg["q"]
    k = cfg["probes"]
    mask = jnp.asarray(rng.random(pq) >= 0.3, jnp.float32)
    alpha = jnp.asarray(rng.normal(size=pq), jnp.float32) * mask
    z = jnp.asarray(rng.choice([-1.0, 1.0], size=(k, pq)), jnp.float32) * mask
    w = jnp.asarray(rng.normal(size=(k, pq)), jnp.float32) * mask
    log_s2 = jnp.asarray(np.log(0.1), jnp.float32)

    got = np.asarray(build_mll_grads(cfg)(s, t, theta, log_s2, mask, alpha, w, z)[0])

    def dense_surrogate(theta, log_s2):
        khat = dense_khat(cfg, s, t, theta, jnp.exp(log_s2), mask)
        # dense khat adds sigma2 on missing coords too, but alpha/w/z are
        # masked so those coords contribute nothing (same as artifact).
        data = -0.5 * alpha @ (khat @ alpha)
        tr = 0.5 / k * jnp.sum(w * (khat @ z.T).T)
        return data + tr

    g_theta, g_s2 = jax.grad(dense_surrogate, argnums=(0, 1))(theta, log_s2)
    want = np.concatenate([np.asarray(g_theta), [float(g_s2)]])
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


def test_mll_grads_estimate_true_nll_gradient():
    """Statistical check: with exact solves alpha = Khat^-1 y,
    W = Khat^-1 Z and many probes, the surrogate gradient approximates
    the exact NLL gradient (validates the sign/scale conventions the
    rust trainer relies on)."""
    cfg = dict(TINY)
    cfg["probes"] = 128
    rng, s, t, theta = make_inputs(cfg, seed=3)
    pq = cfg["p"] * cfg["q"]
    mask_np = rng.random(pq) >= 0.4
    mask = jnp.asarray(mask_np, jnp.float32)
    sigma2 = 0.2
    log_s2 = jnp.asarray(np.log(sigma2), jnp.float32)
    y = jnp.asarray(rng.normal(size=pq), jnp.float32) * mask

    khat = dense_khat(cfg, s, t, theta, sigma2, mask)
    alpha = jnp.linalg.solve(khat, y) * mask
    z = jnp.asarray(rng.choice([-1.0, 1.0], size=(cfg["probes"], pq)), jnp.float32)
    z = z * mask[None, :]
    w = jnp.linalg.solve(khat, z.T).T * mask[None, :]

    got = np.asarray(
        build_mll_grads(cfg)(s, t, theta, log_s2, mask, alpha, w, z)[0]
    )

    obs = np.flatnonzero(mask_np)

    def exact_nll(theta, log_s2):
        khat = dense_khat(cfg, s, t, theta, jnp.exp(log_s2), mask)
        ko = khat[jnp.ix_(jnp.asarray(obs), jnp.asarray(obs))]
        yo = y[jnp.asarray(obs)]
        sol = jnp.linalg.solve(ko, yo)
        _, logdet = jnp.linalg.slogdet(ko)
        return 0.5 * yo @ sol + 0.5 * logdet

    g_theta, g_s2 = jax.grad(exact_nll, argnums=(0, 1))(theta, log_s2)
    want = np.concatenate([np.asarray(g_theta), [float(g_s2)]])
    scale = np.abs(want).max()
    np.testing.assert_allclose(got, want, rtol=0.25, atol=0.1 * scale)


def test_builders_registry_complete():
    assert set(BUILDERS) == {
        "kernels",
        "kron_mvm",
        "kron_apply",
        "prior_sample",
        "mll_grads",
    }
