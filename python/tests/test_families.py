"""Kernel-family coverage: the rbf_periodic (climate) and icm (SARCOS)
time kernels through the full L2 path — Gram properties, gradient
correctness vs dense autodiff, and block-shape invariance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import KT_ICM, KT_RBF_PERIODIC, n_theta
from compile.model import build_kernels, build_kron_mvm, build_mll_grads

FAMILIES = {
    "rbf_periodic": dict(p=10, q=8, ds=2, kernel_t=KT_RBF_PERIODIC, batch=3,
                         probes=3, block=None),
    "icm": dict(p=8, q=5, ds=3, kernel_t=KT_ICM, batch=3, probes=3, block=None),
}


def inputs_for(cfg, seed=0):
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.normal(size=(cfg["p"], cfg["ds"])), jnp.float32)
    t = jnp.asarray(np.linspace(0, 1, cfg["q"])[:, None], jnp.float32)
    theta = jnp.asarray(0.15 * rng.normal(size=n_theta(cfg)), jnp.float32)
    return rng, s, t, theta


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_gram_is_psd_and_symmetric(fam):
    cfg = FAMILIES[fam]
    _, s, t, theta = inputs_for(cfg)
    kss, ktt = build_kernels(cfg)(s, t, theta)
    for k in (kss, ktt):
        k64 = np.asarray(k, np.float64)
        np.testing.assert_allclose(k64, k64.T, atol=1e-5)
        assert np.linalg.eigvalsh(0.5 * (k64 + k64.T)).min() > -1e-4


def test_periodic_kernel_periodicity():
    cfg = dict(FAMILIES["rbf_periodic"], q=9)
    _, s, _, theta = inputs_for(cfg)
    # set long SE lengthscale and period 0.25 so lag-period pairs match
    th = np.array(theta, copy=True)
    layout_off = cfg["ds"] + 1  # [ls_s.., os, ls_t, ls_per, log_period]
    th[layout_off] = np.log(5.0)  # ls_t long
    th[layout_off + 2] = np.log(0.25)
    t = jnp.asarray(np.array([0.0, 0.25, 0.5, 0.75, 1.0, 0.1, 0.2, 0.3, 0.4])[:, None],
                    jnp.float32)
    _, ktt = build_kernels(cfg)(s, t, jnp.asarray(th, jnp.float32))
    # t=0 vs t=0.25/0.5/0.75: one/two/three full periods -> near max corr
    assert float(ktt[0, 1]) > 0.9
    assert float(ktt[0, 2]) > 0.85
    # mid-period lag is least similar
    assert float(ktt[0, 5]) < float(ktt[0, 1])


def test_icm_gram_uses_cholesky_parameterization():
    cfg = FAMILIES["icm"]
    _, s, t, theta = inputs_for(cfg, seed=2)
    _, ktt = build_kernels(cfg)(s, t, theta)
    # full-rank ICM: must be PD (not just PSD) thanks to exp-diagonal
    evals = np.linalg.eigvalsh(np.asarray(ktt, np.float64))
    assert evals.min() > 0


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_mll_grads_match_dense_autodiff(fam):
    """Same-probe deterministic check per family (the rust integration
    test covers rbf; these cover the periodic and ICM branches of the
    jax.grad path through the Pallas custom VJPs)."""
    cfg = FAMILIES[fam]
    rng, s, t, theta = inputs_for(cfg, seed=3)
    p, q, k = cfg["p"], cfg["q"], cfg["probes"]
    pq = p * q
    mask = jnp.asarray(rng.random(pq) >= 0.3, jnp.float32)
    alpha = jnp.asarray(rng.normal(size=pq), jnp.float32) * mask
    z = jnp.asarray(rng.choice([-1.0, 1.0], size=(k, pq)), jnp.float32) * mask
    w = jnp.asarray(rng.normal(size=(k, pq)), jnp.float32) * mask
    log_s2 = jnp.asarray(np.log(0.2), jnp.float32)

    got = np.asarray(build_mll_grads(cfg)(s, t, theta, log_s2, mask, alpha, w, z)[0])

    def dense_surrogate(theta, log_s2):
        kss, ktt = build_kernels(cfg)(s, t, theta)
        kfull = jnp.kron(kss, ktt)
        m = jnp.diag(mask)
        khat = m @ kfull @ m + jnp.exp(log_s2) * jnp.eye(pq)
        data = -0.5 * alpha @ (khat @ alpha)
        tr = 0.5 / k * jnp.sum(w * (khat @ z.T).T)
        return data + tr

    g_theta, g_s2 = jax.grad(dense_surrogate, argnums=(0, 1))(theta, log_s2)
    want = np.concatenate([np.asarray(g_theta), [float(g_s2)]])
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("block", [None, (8, 8, 8), (64, 32, 16)])
def test_kron_mvm_block_invariance(block):
    """Tile shape is a pure schedule knob: results must not change."""
    cfg = dict(FAMILIES["rbf_periodic"], block=block)
    rng, s, t, theta = inputs_for(cfg, seed=4)
    kss, ktt = build_kernels(cfg)(s, t, theta)
    pq = cfg["p"] * cfg["q"]
    mask = jnp.asarray(rng.random(pq) >= 0.4, jnp.float32)
    v = jnp.asarray(rng.normal(size=(cfg["batch"], pq)), jnp.float32)
    got = np.asarray(build_kron_mvm(cfg)(kss, ktt, mask, 0.3, v)[0])
    ref_cfg = dict(cfg, block=None)
    want = np.asarray(build_kron_mvm(ref_cfg)(kss, ktt, mask, 0.3, v)[0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
