"""L1 Pallas matmul vs pure-jnp oracle, across shapes/dtypes/blocks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import matmul

dims = st.integers(min_value=1, max_value=97)


def rand(rng, *shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), dtype)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = rand(rng, m, k), rand(rng, k, n)
    got = matmul(x, y)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    rng = np.random.default_rng(0)
    x, y = rand(rng, 40, 24, dtype=dtype), rand(rng, 24, 56, dtype=dtype)
    got = np.asarray(matmul(x, y), np.float32)
    want = np.asarray(ref.matmul_ref(x, y), np.float32)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("block", [(8, 8, 8), (16, 32, 8), (64, 64, 64)])
def test_matmul_block_shapes(block):
    """Result must be block-shape independent (pure schedule change)."""
    rng = np.random.default_rng(1)
    x, y = rand(rng, 50, 37), rand(rng, 37, 29)
    got = matmul(x, y, block=block)
    np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_identity():
    rng = np.random.default_rng(2)
    x = rand(rng, 33, 33)
    np.testing.assert_allclose(matmul(x, jnp.eye(33)), x, rtol=1e-6, atol=1e-6)


def test_matmul_vjp_matches_jnp():
    """The custom VJP (itself Pallas matmuls) must match jnp autodiff."""
    rng = np.random.default_rng(3)
    x, y = rand(rng, 19, 23), rand(rng, 23, 11)

    f_pallas = lambda x, y: jnp.sum(jnp.sin(matmul(x, y)))
    f_ref = lambda x, y: jnp.sum(jnp.sin(x @ y))
    gx, gy = jax.grad(f_pallas, argnums=(0, 1))(x, y)
    gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gy, gy_r, rtol=1e-4, atol=1e-4)
