"""AOT pipeline: lowering produces loadable HLO text + coherent manifest."""

import json
import os

import pytest

from compile import aot
from compile.configs import CONFIGS, n_theta


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build_all(out, config_names=["tiny"])
    return out


def test_manifest_structure(built):
    with open(os.path.join(built, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1 and man["dtype"] == "f32"
    tiny = man["configs"]["tiny"]
    cfg = CONFIGS["tiny"]
    assert tiny["p"] == cfg["p"] and tiny["q"] == cfg["q"]
    assert tiny["n_theta"] == n_theta(cfg)
    assert set(tiny["artifacts"]) == set(aot.BUILDERS)


def test_hlo_text_is_parseable_entry(built):
    """HLO text must contain an ENTRY computation with the declared
    parameter count (the rust loader's contract)."""
    with open(os.path.join(built, "manifest.json")) as f:
        man = json.load(f)
    for aname, meta in man["configs"]["tiny"]["artifacts"].items():
        path = os.path.join(built, meta["file"])
        text = open(path).read()
        assert "ENTRY" in text, aname
        assert "HloModule" in text, aname
        for i in range(len(meta["inputs"])):
            assert f"parameter({i})" in text, (aname, i)


def test_input_specs_match_configs(built):
    with open(os.path.join(built, "manifest.json")) as f:
        man = json.load(f)
    cfg = CONFIGS["tiny"]
    p, q, pq = cfg["p"], cfg["q"], cfg["p"] * cfg["q"]
    arts = man["configs"]["tiny"]["artifacts"]
    kron = {i["name"]: i["shape"] for i in arts["kron_mvm"]["inputs"]}
    assert kron["kss"] == [p, p] and kron["ktt"] == [q, q]
    assert kron["mask"] == [pq] and kron["v"] == [cfg["batch"], pq]
    assert kron["sigma2"] == []


def test_hlo_is_deterministic(built):
    """Re-lowering must produce identical HLO (sha recorded in manifest)."""
    text1, _ = aot.lower_artifact("kron_mvm", CONFIGS["tiny"])
    text2, _ = aot.lower_artifact("kron_mvm", CONFIGS["tiny"])
    assert text1 == text2
