"""L2: the LKGP compute graph in JAX, calling the L1 Pallas kernels.

Five jit-able builders, one per AOT artifact (see aot.py):

  kernels      (S, T, theta)                         -> (K_SS, K_TT)
  kron_mvm     (K_SS, K_TT, mask, sigma2, V)         -> (A V,)
  kron_apply   (K_SS, K_TT, V)                       -> ((K (x) K) V,)
  prior_sample (K_SS, K_TT, Z)                       -> ((L_S (x) L_T) Z,)
  mll_grads    (S, T, theta, log_s2, mask, a, W, Z)  -> (grads,)

All positive hyperparameters are log-parameterized. The spatial Gram
matrix K_SS (the large one, p x p) is computed by the Pallas RBF kernel;
K_TT (q x q, q <= ~100) uses direct jnp broadcasting — it is tiny and its
functional form varies per config (SE / SE*periodic / full-rank ICM).
"""

import jax
import jax.numpy as jnp

from .configs import KT_ICM, KT_RBF, KT_RBF_PERIODIC, theta_layout
from .kernels.kron_mvm import kron_apply, kron_mvm
from .kernels.rbf import rbf_gram

# Relative jitter added before Cholesky in prior sampling.
CHOL_JITTER = 1e-4


def unpack_theta(cfg, theta):
    """Split the flat theta vector per configs.theta_layout."""
    out, off = {}, 0
    for name, size in theta_layout(cfg):
        out[name] = theta[off : off + size]
        off += size
    return out


def spatial_gram(s1, s2, log_ls_s, log_os, *, interpret=True):
    """ARD squared-exponential Gram via the Pallas RBF kernel."""
    ls = jnp.exp(log_ls_s)[None, :]
    k = rbf_gram(s1 / ls, s2 / ls, interpret=interpret)
    return jnp.exp(log_os[0]) * k


def time_gram(cfg, t1, t2, th):
    """K_TT for the config's time-kernel family (small q, direct jnp)."""
    kt = cfg["kernel_t"]
    if kt == KT_RBF:
        ls = jnp.exp(th["log_ls_t"])
        d2 = jnp.sum((t1[:, None, :] - t2[None, :, :]) ** 2, axis=-1)
        return jnp.exp(-0.5 * d2 / ls[0] ** 2)
    if kt == KT_RBF_PERIODIC:
        ls = jnp.exp(th["log_ls_t"])[0]
        lsp = jnp.exp(th["log_ls_per"])[0]
        period = jnp.exp(th["log_period"])[0]
        diff = t1[:, None, 0] - t2[None, :, 0]
        se = jnp.exp(-0.5 * diff**2 / ls**2)
        per = jnp.exp(-2.0 * jnp.sin(jnp.pi * diff / period) ** 2 / lsp**2)
        return se * per
    if kt == KT_ICM:
        # Full-rank ICM: K_TT = L L^T with L lower-triangular, exp on the
        # diagonal for positivity (the paper's SARCOS task kernel).
        q = cfg["q"]
        tril = th["icm_chol"]
        il = jnp.tril_indices(q)
        l = jnp.zeros((q, q), tril.dtype).at[il].set(tril)
        diag = jnp.exp(jnp.diagonal(l))
        l = l - jnp.diag(jnp.diagonal(l)) + jnp.diag(diag)
        return l @ l.T + 1e-6 * jnp.eye(q, dtype=tril.dtype)
    raise ValueError(f"unknown kernel_t {kt!r}")


def build_kernels(cfg, *, interpret=True):
    """(S[p,ds], T[q,dt], theta) -> (K_SS[p,p], K_TT[q,q])."""

    def fn(s, t, theta):
        th = unpack_theta(cfg, theta)
        kss = spatial_gram(s, s, th["log_ls_s"], th["log_os"], interpret=interpret)
        ktt = time_gram(cfg, t, t, th)
        return kss, ktt.astype(kss.dtype)

    return fn


def build_kron_mvm(cfg, *, interpret=True):
    """System operator A = M (K_SS (x) K_TT) M + sigma2 I, batched RHS."""

    blk = cfg.get("block")

    def fn(kss, ktt, mask, sigma2, v):
        return (kron_mvm(kss, ktt, mask, sigma2, v, block=blk, interpret=interpret),)

    return fn


def build_kron_apply(cfg, *, interpret=True):
    """Unmasked (K_SS (x) K_TT) V for pathwise-conditioning prediction."""

    blk = cfg.get("block")

    def fn(kss, ktt, v):
        return (kron_apply(kss, ktt, v, block=blk, interpret=interpret),)

    return fn


def build_prior_sample(cfg, *, interpret=True):
    """Kronecker-factored prior draws: (L_S (x) L_T) Z, Z ~ N(0, I).

    Takes the *Cholesky factors* L_S (p x p) and L_T (q x q) as inputs:
    factorizing the small Gram matrices is a setup-time host operation
    (the rust coordinator does it in f64) — `jnp.linalg.cholesky` lowers
    to a typed-FFI LAPACK custom call that xla_extension 0.5.1 cannot
    load, and O(p^3 + q^3) is negligible next to the O(b pq(p+q)) factor
    application, which is what runs here on the Pallas kron_apply path.
    """

    blk = cfg.get("block")

    def fn(ls, lt, z):
        return (kron_apply(ls, lt, z, block=blk, interpret=interpret),)

    return fn


def build_mll_grads(cfg, *, interpret=True):
    """Hutchinson-estimated marginal-likelihood gradients.

    With Khat(theta) = P K(theta) P^T + sigma2 I, alpha = Khat^-1 y and
    probe solves W = Khat^-1 Z (computed by the rust CG driver), the NLL
    gradient is

      dNLL/dtheta ~= d/dtheta [ -1/2 a^T Khat(theta) a
                                + 1/(2k) sum_i w_i^T Khat(theta) z_i ]

    holding a, W, Z fixed (standard iterative-GP identity; Lin et al.
    2024b). jax.grad differentiates the surrogate through the Pallas
    kron MVM, so the gradient costs the same O(p^2 q + p q^2) as a
    forward MVM. Returns a single vector [d/dtheta..., d/dlog_sigma2].
    """

    def surrogate(theta, log_sigma2, s, t, mask, alpha, w, z):
        th = unpack_theta(cfg, theta)
        kss = spatial_gram(s, s, th["log_ls_s"], th["log_os"], interpret=interpret)
        ktt = time_gram(cfg, t, t, th).astype(kss.dtype)
        s2 = jnp.exp(log_sigma2)
        blk = cfg.get("block")
        ka = kron_mvm(kss, ktt, mask, s2, alpha[None, :], block=blk, interpret=interpret)[0]
        data_term = -0.5 * jnp.dot(alpha, ka)
        kz = kron_mvm(kss, ktt, mask, s2, z, block=blk, interpret=interpret)
        kprobes = z.shape[0]
        trace_term = 0.5 / kprobes * jnp.sum(w * kz)
        return data_term + trace_term

    grad_fn = jax.grad(surrogate, argnums=(0, 1))

    def fn(s, t, theta, log_sigma2, mask, alpha, w, z):
        g_theta, g_s2 = grad_fn(theta, log_sigma2, s, t, mask, alpha, w, z)
        return (jnp.concatenate([g_theta, g_s2[None]]),)

    return fn


BUILDERS = {
    "kernels": build_kernels,
    "kron_mvm": build_kron_mvm,
    "kron_apply": build_kron_apply,
    "prior_sample": build_prior_sample,
    "mll_grads": build_mll_grads,
}
