"""AOT compile path: lower every L2 builder for every shape config to
HLO *text* + a manifest.json the rust runtime consumes.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` 0.1.6 crate binds) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. Lowered with return_tuple=True; the rust side unwraps the tuple.

Run via `make artifacts` (from python/: `python -m compile.aot --out
../artifacts`). Python never runs after this point.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import CONFIGS, n_theta
from .model import BUILDERS

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def artifact_specs(name, cfg):
    """Input ShapeDtypeStructs for each artifact, in call order.

    This list is mirrored in manifest.json and is the ABI between the
    compile path and the rust runtime — keep ordering stable.
    """
    p, q, ds = cfg["p"], cfg["q"], cfg["ds"]
    b, k, nt = cfg["batch"], cfg["probes"], n_theta(cfg)
    pq = p * q
    if name == "kernels":
        return [("s", spec(p, ds)), ("t", spec(q, 1)), ("theta", spec(nt))]
    if name == "kron_mvm":
        return [
            ("kss", spec(p, p)),
            ("ktt", spec(q, q)),
            ("mask", spec(pq)),
            ("sigma2", spec()),
            ("v", spec(b, pq)),
        ]
    if name == "kron_apply":
        return [("kss", spec(p, p)), ("ktt", spec(q, q)), ("v", spec(b, pq))]
    if name == "prior_sample":
        # Cholesky factors, not Gram matrices — see model.build_prior_sample
        return [("ls", spec(p, p)), ("lt", spec(q, q)), ("z", spec(b, pq))]
    if name == "mll_grads":
        return [
            ("s", spec(p, ds)),
            ("t", spec(q, 1)),
            ("theta", spec(nt)),
            ("log_sigma2", spec()),
            ("mask", spec(pq)),
            ("alpha", spec(pq)),
            ("w", spec(k, pq)),
            ("z", spec(k, pq)),
        ]
    raise KeyError(name)


def lower_artifact(name, cfg):
    fn = BUILDERS[name](cfg)
    specs = [s for _, s in artifact_specs(name, cfg)]
    # keep_unused: ICM ignores `t`; the parameter must stay in the HLO
    # signature so the rust ABI is uniform across kernel families.
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    return to_hlo_text(lowered), specs


def build_all(out_dir, config_names=None):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": 1, "dtype": "f32", "configs": {}}
    for cname, cfg in CONFIGS.items():
        if config_names and cname not in config_names:
            continue
        entry = {
            "p": cfg["p"],
            "q": cfg["q"],
            "ds": cfg["ds"],
            "kernel_t": cfg["kernel_t"],
            "batch": cfg["batch"],
            "probes": cfg["probes"],
            "n_theta": n_theta(cfg),
            "artifacts": {},
        }
        for aname in BUILDERS:
            fname = f"{aname}_{cname}.hlo.txt"
            text, specs = lower_artifact(aname, cfg)
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            entry["artifacts"][aname] = {
                "file": fname,
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                "inputs": [
                    {"name": n, "shape": list(s.shape)}
                    for n, s in artifact_specs(aname, cfg)
                ],
            }
            print(f"  {fname}: {len(text) / 1024:.0f} KiB")
        manifest["configs"][cname] = entry
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {out_dir}/manifest.json")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", nargs="*", default=None, help="subset of config names")
    args = ap.parse_args()
    build_all(args.out, args.configs)


if __name__ == "__main__":
    main()
