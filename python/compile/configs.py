"""Shape configurations for AOT artifact generation.

Each config fixes the static shapes the PJRT runtime will execute:
p spatial points, q time steps / tasks, d_s input dims, the time-kernel
family, the CG right-hand-side batch, and the number of Hutchinson probes.

The rust coordinator picks a config by name from artifacts/manifest.json;
everything else (missing masks, hyperparameter values, data) is a runtime
input, so one artifact set serves every missing ratio / seed of an
experiment.
"""

# Time-kernel families. Determines both K_TT's functional form and the
# hyperparameter packing (see theta_layout).
KT_RBF = "rbf"                  # squared exponential on t
KT_RBF_PERIODIC = "rbf_periodic"  # SE * periodic (climate seasonal trend)
KT_ICM = "icm"                  # full-rank ICM task kernel (SARCOS torques)


def theta_layout(cfg):
    """Return (names, sizes) of the hyperparameter vector theta.

    theta is a flat f32 vector; log-scale for positive quantities.
    Layout: [log_ls_S (ARD, d_s) | log_outputscale | time-kernel params].
    The observation noise log_sigma2 is a separate scalar input.
    """
    names = [("log_ls_s", cfg["ds"]), ("log_os", 1)]
    kt = cfg["kernel_t"]
    if kt == KT_RBF:
        names.append(("log_ls_t", 1))
    elif kt == KT_RBF_PERIODIC:
        names.append(("log_ls_t", 1))
        names.append(("log_ls_per", 1))
        names.append(("log_period", 1))
    elif kt == KT_ICM:
        q = cfg["q"]
        names.append(("icm_chol", q * (q + 1) // 2))
    else:
        raise ValueError(f"unknown kernel_t {kt!r}")
    return names


def n_theta(cfg):
    return sum(size for _, size in theta_layout(cfg))


# NOTE: sizes are scaled for a 1-core CPU testbed (see DESIGN.md §3/§6);
# the paper's A100 sizes (p=5000, q=1000) use the same artifacts with
# larger statics.
#
# `block` is the Pallas matmul tile, tuned per shape by the perf pass
# (EXPERIMENTS.md §Perf). interpret=True executes the grid as an XLA
# while-loop, so on CPU fewer/larger tiles win (3-10x over the 128^3
# default). On a real TPU the same knob would be capped by VMEM
# (3 * bm*bk * 4B <= ~12 MiB); 128^3 is the MXU-native choice there —
# see DESIGN.md §Hardware-Adaptation.
CONFIGS = {
    # Tiny config: python tests + rust integration tests.
    "tiny": dict(p=16, q=8, ds=2, kernel_t=KT_RBF, batch=4, probes=4, block=None),
    # Fig 3: simulated SARCOS inverse dynamics, 7 torque tasks (ICM).
    "sarcos": dict(
        p=512, q=7, ds=21, kernel_t=KT_ICM, batch=8, probes=8,
        block=(2048, 512, 512),
    ),
    # Table 1 / Fig 4: learning-curve prediction (configs x epochs).
    "lcbench": dict(
        p=256, q=52, ds=7, kernel_t=KT_RBF, batch=16, probes=8,
        block=(1024, 256, 256),
    ),
    # Table 2 / Fig 5: spatiotemporal climate (lat/lon x days).
    "climate": dict(
        p=384, q=96, ds=2, kernel_t=KT_RBF_PERIODIC, batch=16, probes=8,
        block=(1536, 384, 384),
    ),
}
