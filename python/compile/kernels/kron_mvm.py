"""L1: masked latent-Kronecker matrix-vector products, built on the
Pallas matmul kernel.

Layout convention (shared with the rust coordinator): a grid vector v of
length p*q is ``reshape(v, (p, q))`` row-major, i.e. ``v[j*q + k]`` is the
value at (s_j, t_k). Under this layout

    (K_SS (x) K_TT) v  ==  vec( K_SS @ unvec(v) @ K_TT^T ).

The projection P / P^T of the paper is implemented as a dense {0,1} mask
multiply (zero padding), which keeps layouts static — exactly the "lazy
projection" the paper prescribes, and the TPU-friendly alternative to a
gather.
"""

import functools

import jax
import jax.numpy as jnp

from .matmul import matmul


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def kron_apply(kss, ktt, v, *, block=None, interpret=True):
    """(K_SS (x) K_TT) applied to a batch of grid vectors.

    kss: (p, p), ktt: (q, q), v: (b, p*q) -> (b, p*q).
    Two GEMMs: (b*p, q) @ K_TT^T then per-batch K_SS @ (.), expressed as
    one (b*q, p) @ K_SS^T after a transpose so both halves use the same
    2-D Pallas matmul kernel.
    """
    b, pq = v.shape
    p, q = kss.shape[0], ktt.shape[0]
    if pq != p * q:
        raise ValueError(f"v has {pq} cols, expected {p}*{q}")
    # right half: V @ K_TT^T, batched by stacking rows
    t1 = matmul(v.reshape(b * p, q), ktt.T, block=block, interpret=interpret)
    # left half: K_SS @ T1[b]  ==  (T1[b]^T @ K_SS^T)^T
    t1 = t1.reshape(b, p, q).transpose(0, 2, 1).reshape(b * q, p)
    t2 = matmul(t1, kss.T, block=block, interpret=interpret)
    return t2.reshape(b, q, p).transpose(0, 2, 1).reshape(b, pq)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def kron_mvm(kss, ktt, mask, sigma2, v, *, block=None, interpret=True):
    """System operator of LKGP: ``A = M (K_SS (x) K_TT) M + sigma2 I``.

    mask: (p*q,) in {0,1}; sigma2: scalar; v: (b, p*q) -> (b, p*q).

    On the observed subspace (mask == 1) this equals the paper's
    ``P (K_SS (x) K_TT) P^T + sigma2 I``; on the missing coordinates it
    acts as ``sigma2 I``, so CG iterates started at 0 with masked RHS
    never leave the observed subspace — the projection is exact, not an
    approximation.
    """
    kv = kron_apply(kss, ktt, v * mask[None, :], block=block, interpret=interpret)
    return kv * mask[None, :] + sigma2 * v
