"""L1: tiled matrix-multiplication Pallas kernel.

This is the compute primitive behind the latent-Kronecker MVM
``v -> vec(K_SS . unvec(v) . K_TT^T)`` (two GEMMs) and the Cholesky-factor
application in pathwise prior sampling.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the (bm, bk, bn) tiles
stream HBM->VMEM via BlockSpec index maps; the inner ``jnp.dot`` hits the
MXU with f32 accumulation. The k-axis is the innermost, sequential grid
dimension so the output block acts as the VMEM accumulator (standard
revisiting pattern). interpret=True lowers the same schedule to plain HLO
for the CPU PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: MXU-shaped 128x128 tiles, f32 accumulation.
# VMEM footprint per grid step: (bm*bk + bk*bn + bm*bn) * 4B = 192 KiB.
DEFAULT_BLOCK = (128, 128, 128)


def _matmul_kernel(x_ref, y_ref, o_ref, *, k_steps):
    """One (i, j, s) grid step: o[i,j] (+)= x[i,s] @ y[s,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad_to(a, m, n):
    pm, pn = m - a.shape[0], n - a.shape[1]
    if pm == 0 and pn == 0:
        return a
    return jnp.pad(a, ((0, pm), (0, pn)))


def _ceil_to(x, b):
    return (x + b - 1) // b * b


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _matmul(x, y, block, interpret):
    (m, k), (k2, n) = x.shape, y.shape
    if k != k2:
        raise ValueError(f"shape mismatch {x.shape} @ {y.shape}")
    bm, bk, bn = block or DEFAULT_BLOCK
    bm, bk, bn = min(bm, _ceil_to(m, 8)), min(bk, _ceil_to(k, 8)), min(bn, _ceil_to(n, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp, yp = _pad_to(x, mp, kp), _pad_to(y, kp, np_)
    k_steps = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n]


def _matmul_fwd(x, y, block, interpret):
    return _matmul(x, y, block, interpret), (x, y)


def _matmul_bwd(block, interpret, res, g):
    # The cotangents are themselves tiled Pallas matmuls, so jax.grad of
    # anything built on `matmul` (the MLL-gradient artifact in
    # particular) stays on the L1 hot path.
    x, y = res
    dx = _matmul(g, y.T, block, interpret)
    dy = _matmul(x.T, g, block, interpret)
    return dx, dy


_matmul.defvjp(_matmul_fwd, _matmul_bwd)


def matmul(x, y, *, block=None, interpret=True):
    """Tiled ``x @ y`` via Pallas. Arbitrary (m, k) x (k, n) shapes.

    Inputs are zero-padded up to tile multiples and the result is sliced
    back, so the kernel itself only ever sees full tiles (static layout,
    which is what Mosaic wants on real hardware). Differentiable via a
    custom VJP whose backward matmuls reuse this same kernel.
    """
    return _matmul(x, y, block, interpret)
