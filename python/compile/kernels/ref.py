"""Pure-jnp oracles for every Pallas kernel — the correctness signal.

Each function here is the mathematically obvious implementation; pytest
(+ hypothesis shape/dtype sweeps) asserts the Pallas kernels match to
float tolerance. ``kron_mvm_dense_ref`` additionally materializes the
full Kronecker product, verifying the latent-Kronecker algebra itself
against the paper's Section 3 definition.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.matmul(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def rbf_ref(x, y):
    """exp(-0.5 ||x_i - y_j||^2), computed by explicit broadcasting."""
    d2 = jnp.sum((x[:, None, :] - y[None, :, :]) ** 2, axis=-1)
    return jnp.exp(-0.5 * d2).astype(x.dtype)


def kron_apply_ref(kss, ktt, v):
    """(K_SS (x) K_TT) V^T via the unvec identity, plain jnp."""
    b, pq = v.shape
    p, q = kss.shape[0], ktt.shape[0]
    vm = v.reshape(b, p, q)
    return jnp.einsum("ij,bjk,lk->bil", kss, vm, ktt).reshape(b, pq)


def kron_mvm_ref(kss, ktt, mask, sigma2, v):
    kv = kron_apply_ref(kss, ktt, v * mask[None, :])
    return kv * mask[None, :] + sigma2 * v


def kron_mvm_dense_ref(kss, ktt, mask, sigma2, v):
    """Materialize M (K_SS (x) K_TT) M + sigma2 I. Small shapes only.

    This is the ground-truth definition: the projection P of the paper
    selects mask==1 rows; padding with the mask is algebraically
    identical on the observed subspace.
    """
    kfull = jnp.kron(kss, ktt)
    m = jnp.diag(mask)
    a = m @ kfull @ m + sigma2 * jnp.eye(kfull.shape[0], dtype=kfull.dtype)
    return (a @ v.T).T
