"""L1: tiled RBF (squared-exponential) Gram-matrix Pallas kernel.

Computes ``K[i, j] = exp(-0.5 * ||x_i - y_j||^2)`` over row tiles of x and
y. Lengthscales are applied by the caller (inputs are pre-scaled), the
outputscale is applied outside; this keeps the kernel a pure geometry op.

TPU mapping: the pairwise squared distance is evaluated in the
MXU-friendly form ``x.x + y.y - 2 x y^T`` so the inner loop is a matmul
rather than a broadcasted subtract-square (which would be VPU-bound).
The feature dimension d is small (<= 32) and rides along whole inside the
tile; padding feature columns with zeros is exact for this form.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (128, 128)


def _rbf_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (bm, d)
    y = y_ref[...].astype(jnp.float32)  # (bn, d)
    xx = jnp.sum(x * x, axis=1, keepdims=True)          # (bm, 1)
    yy = jnp.sum(y * y, axis=1)[None, :]                # (1, bn)
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)  # MXU
    sqd = jnp.maximum(xx + yy - 2.0 * xy, 0.0)
    o_ref[...] = jnp.exp(-0.5 * sqd).astype(o_ref.dtype)


def _ceil_to(x, b):
    return (x + b - 1) // b * b


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rbf_gram(x, y, block, interpret):
    (m, d), (n, d2) = x.shape, y.shape
    if d != d2:
        raise ValueError(f"feature mismatch {x.shape} vs {y.shape}")
    bm, bn = block or DEFAULT_BLOCK
    bm, bn = min(bm, _ceil_to(m, 8)), min(bn, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    yp = jnp.pad(y, ((0, np_ - n), (0, 0)))
    out = pl.pallas_call(
        _rbf_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n]


def _rbf_fwd(x, y, block, interpret):
    k = _rbf_gram(x, y, block, interpret)
    return k, (x, y, k)


def _rbf_bwd(block, interpret, res, g):
    # d/dx_i exp(-0.5||x_i - y_j||^2) = K_ij (y_j - x_i); the reductions
    # over j (resp. i) are Pallas matmuls, keeping the VJP on the MXU.
    from .matmul import matmul

    x, y, k = res
    gk = g * k
    dx = matmul(gk, y, interpret=interpret) - x * jnp.sum(gk, axis=1, keepdims=True)
    dy = matmul(gk.T, x, interpret=interpret) - y * jnp.sum(gk, axis=0)[:, None]
    return dx, dy


_rbf_gram.defvjp(_rbf_fwd, _rbf_bwd)


def rbf_gram(x, y, *, block=None, interpret=True):
    """Unit-lengthscale RBF Gram matrix ``exp(-0.5 ||x_i - y_j||^2)``.

    x: (m, d), y: (n, d) -> (m, n). Row-padded to tile multiples; padded
    rows produce garbage values that are sliced away (they see distance 0
    to other padded rows, never leaking into the valid region).
    Differentiable via a custom VJP built on the Pallas matmul.
    """
    return _rbf_gram(x, y, block, interpret)
