//! Bench: batched preconditioned CG on the LKGP system operator —
//! iterations and wall time per preconditioner (identity / Jacobi /
//! pivoted Cholesky, the paper's Appendix-C solver configuration).

use lkgp::kernels::ProductGridKernel;
use lkgp::kron::{KronOp, MaskedKronSystem};
use lkgp::linalg::Matrix;
use lkgp::solvers::cg::{solve_cg, BatchedOp, CgOptions};
use lkgp::solvers::precond::Preconditioner;
use lkgp::util::bench::{black_box, Bencher};
use lkgp::util::rng::Rng;

struct Op<'a>(&'a MaskedKronSystem<f64>);

impl<'a> BatchedOp<f64> for Op<'a> {
    fn dim(&self) -> usize {
        self.0.dim()
    }
    fn apply_batch(&mut self, v: &Matrix<f64>) -> Matrix<f64> {
        self.0.apply_batch(v)
    }
}

fn main() {
    let mut b = Bencher::quick();
    let mut rng = Rng::new(3);
    println!("# bench_solver — PCG on the latent-Kronecker system\n");
    for (p, q, s2) in [(128usize, 16usize, 0.1f64), (256, 32, 0.01)] {
        let n = p * q;
        let kernel = ProductGridKernel::new(3, "rbf", q);
        let s = Matrix::from_vec(p, 3, rng.normals(p * 3));
        let t: Vec<f64> = (0..q).map(|k| k as f64 / (q - 1) as f64).collect();
        let mask: Vec<f64> =
            (0..n).map(|_| if rng.uniform() < 0.3 { 0.0 } else { 1.0 }).collect();
        let sys = MaskedKronSystem::new(
            KronOp::new(kernel.gram_s(&s), kernel.gram_t(&t)),
            mask.clone(),
            s2,
        );
        let rhs = {
            let mut r = Matrix::from_vec(4, n, rng.normals(4 * n));
            for row in 0..4 {
                for (x, m) in r.row_mut(row).iter_mut().zip(&mask) {
                    *x *= *m;
                }
            }
            r
        };
        let opts = CgOptions { max_iters: 400, tol: 1e-2, ..CgOptions::default() };
        for (pname, pre) in [
            ("identity", Preconditioner::Identity),
            ("jacobi", Preconditioner::jacobi(&sys.diag())),
            (
                "pivchol-50",
                Preconditioner::pivoted_from_columns(
                    sys.diag().iter().map(|d| d - s2).collect(),
                    |j| sys.kernel_col(j),
                    50,
                    s2,
                ),
            ),
        ] {
            let (_, stats) = solve_cg(&mut Op(&sys), &rhs, &pre, &opts);
            b.bench(
                &format!(
                    "cg p={p} q={q} s2={s2} pre={pname} [{} iters, conv={}]",
                    stats.iters, stats.converged
                ),
                || {
                    black_box(solve_cg(&mut Op(&sys), &rhs, &pre, &opts));
                },
            );
        }
    }
    b.save_csv("bench_solver");
}
