//! Bench: batched preconditioned CG on the LKGP system operator —
//! iterations and wall time per preconditioner (identity / Jacobi /
//! pivoted Cholesky, the paper's Appendix-C solver configuration) —
//! plus the eigendecomposition solver paths added on top of it:
//!
//! * `KronEig` preconditioner under light (5%) masking, gated in
//!   `BENCH_solver.json` to cut CG iterations at least 2x versus
//!   pivoted Cholesky (`eig.iters_reduction_ge_2x`);
//! * the direct spectral solve on a fully-observed grid
//!   (factorization + solve) versus CG wall time
//!   (`eig.full_grid_speedup_vs_cg`, informational).
//!
//! `LKGP_BENCH_SMOKE=1` shrinks sizes for the CI `bench-smoke` job,
//! which gates on the emitted `BENCH_solver.json` via
//! `scripts/check_bench.py`.

use lkgp::kernels::ProductGridKernel;
use lkgp::kron::{KronOp, MaskedKronSystem};
use lkgp::linalg::Matrix;
use lkgp::solvers::cg::{solve_cg, BatchedOp, CgOptions};
use lkgp::solvers::eig::EigSolver;
use lkgp::solvers::precond::Preconditioner;
use lkgp::util::bench::{black_box, Bencher};
use lkgp::util::json::Json;
use lkgp::util::rng::Rng;

struct Op<'a>(&'a MaskedKronSystem<f64>);

impl<'a> BatchedOp<f64> for Op<'a> {
    fn dim(&self) -> usize {
        self.0.dim()
    }
    fn apply_batch(&mut self, v: &Matrix<f64>) -> Matrix<f64> {
        self.0.apply_batch(v)
    }
}

fn masked_rhs(rng: &mut Rng, rows: usize, n: usize, mask: &[f64]) -> Matrix<f64> {
    let mut r = Matrix::from_vec(rows, n, rng.normals(rows * n));
    for row in 0..rows {
        for (x, m) in r.row_mut(row).iter_mut().zip(mask) {
            *x *= *m;
        }
    }
    r
}

fn main() {
    let smoke = std::env::var("LKGP_BENCH_SMOKE").ok().as_deref() == Some("1");
    let mut b = if smoke { Bencher::quick() } else { Bencher::default() };
    let mut rng = Rng::new(3);
    println!("# bench_solver — PCG + eig solver on the latent-Kronecker system (smoke: {smoke})\n");

    // ---- section 1: the Appendix-C preconditioner ladder at 30% masking
    let shapes: &[(usize, usize, f64)] =
        if smoke { &[(128, 16, 0.1)] } else { &[(128, 16, 0.1), (256, 32, 0.01)] };
    for &(p, q, s2) in shapes {
        let n = p * q;
        let kernel = ProductGridKernel::new(3, "rbf", q);
        let s = Matrix::from_vec(p, 3, rng.normals(p * 3));
        let t: Vec<f64> = (0..q).map(|k| k as f64 / (q - 1) as f64).collect();
        let mask: Vec<f64> =
            (0..n).map(|_| if rng.uniform() < 0.3 { 0.0 } else { 1.0 }).collect();
        let sys = MaskedKronSystem::new(
            KronOp::new(kernel.gram_s(&s), kernel.gram_t(&t)),
            mask.clone(),
            s2,
        );
        let rhs = masked_rhs(&mut rng, 4, n, &mask);
        let opts = CgOptions { max_iters: 400, tol: 1e-2, ..CgOptions::default() };
        for (pname, pre) in [
            ("identity", Preconditioner::Identity),
            ("jacobi", Preconditioner::jacobi(&sys.diag())),
            (
                "pivchol-50",
                Preconditioner::pivoted_from_columns(
                    sys.diag().iter().map(|d| d - s2).collect(),
                    |j| sys.kernel_col(j),
                    50,
                    s2,
                ),
            ),
        ] {
            let (_, stats) = solve_cg(&mut Op(&sys), &rhs, &pre, &opts);
            b.bench(
                &format!(
                    "cg p={p} q={q} s2={s2} pre={pname} [{} iters, conv={}]",
                    stats.iters, stats.converged
                ),
                || {
                    black_box(solve_cg(&mut Op(&sys), &rhs, &pre, &opts));
                },
            );
        }
    }

    // ---- section 2: KronEig preconditioner at 5% masking, tight tol
    // The latent-grid inverse is exact up to a rank <= 2 * #missing
    // perturbation, so preconditioned CG converges in O(#missing) steps
    // where pivoted Cholesky still grinds through the tail spectrum.
    let (p, q) = if smoke { (64usize, 12usize) } else { (128usize, 16usize) };
    let s2 = 1e-3;
    let tol = 1e-6;
    let n = p * q;
    let kernel = ProductGridKernel::new(3, "rbf", q);
    let s = Matrix::from_vec(p, 3, rng.normals(p * 3));
    let t: Vec<f64> = (0..q).map(|k| k as f64 / (q - 1) as f64).collect();
    let kss = kernel.gram_s(&s);
    let ktt = kernel.gram_t(&t);
    let mask: Vec<f64> =
        (0..n).map(|_| if rng.uniform() < 0.05 { 0.0 } else { 1.0 }).collect();
    let sys = MaskedKronSystem::new(KronOp::new(kss.clone(), ktt.clone()), mask.clone(), s2);
    let rhs = masked_rhs(&mut rng, 4, n, &mask);
    let opts = CgOptions { max_iters: 2000, tol, ..CgOptions::default() };

    let pivchol = Preconditioner::pivoted_from_columns(
        sys.diag().iter().map(|d| d - s2).collect(),
        |j| sys.kernel_col(j),
        50,
        s2,
    );
    let (_, plain_stats) = solve_cg(&mut Op(&sys), &rhs, &pivchol, &opts);
    let kron_eig =
        Preconditioner::try_kron_eig(&kss, &ktt, s2).expect("kron-eig preconditioner");
    let (_, eig_stats) = solve_cg(&mut Op(&sys), &rhs, &kron_eig, &opts);
    b.bench(
        &format!(
            "cg 5% p={p} q={q} pre=pivchol-50 [{} iters, conv={}]",
            plain_stats.iters, plain_stats.converged
        ),
        || {
            black_box(solve_cg(&mut Op(&sys), &rhs, &pivchol, &opts));
        },
    );
    b.bench(
        &format!(
            "cg 5% p={p} q={q} pre=kron-eig [{} iters, conv={}]",
            eig_stats.iters, eig_stats.converged
        ),
        || {
            black_box(solve_cg(&mut Op(&sys), &rhs, &kron_eig, &opts));
        },
    );
    let cg_iters_plain = plain_stats.iters;
    let cg_iters_eig_precond = eig_stats.iters;
    let reduction_ok =
        eig_stats.converged && cg_iters_plain >= 2 * cg_iters_eig_precond.max(1);

    // ---- section 3: full grid — direct spectral solve vs CG wall time
    let full_sys =
        MaskedKronSystem::new(KronOp::new(kss.clone(), ktt.clone()), vec![1.0; n], s2);
    let rhs_full = Matrix::from_vec(4, n, rng.normals(4 * n));
    let jacobi_full = Preconditioner::jacobi(&full_sys.diag());
    let (_, full_cg_stats) = solve_cg(&mut Op(&full_sys), &rhs_full, &jacobi_full, &opts);
    let cg_secs = b
        .bench(
            &format!(
                "cg full-grid p={p} q={q} pre=jacobi [{} iters, conv={}]",
                full_cg_stats.iters, full_cg_stats.converged
            ),
            || {
                black_box(solve_cg(&mut Op(&full_sys), &rhs_full, &jacobi_full, &opts));
            },
        )
        .secs();
    let eig_secs = b
        .bench(&format!("eig full-grid p={p} q={q} [factor + 4-rhs solve]"), || {
            let es = EigSolver::try_new(&kss, &ktt, s2).expect("eig solver");
            black_box(es.solve_batch(&rhs_full));
        })
        .secs();
    let full_grid_speedup_vs_cg = cg_secs / eig_secs.max(1e-12);
    println!(
        "\nfull-grid: eig {:.3}ms vs cg {:.3}ms ({full_grid_speedup_vs_cg:.1}x); \
         5% masking: kron-eig {cg_iters_eig_precond} iters vs pivchol {cg_iters_plain}",
        eig_secs * 1e3,
        cg_secs * 1e3
    );

    let doc = Json::obj(vec![
        ("bench", Json::Str("bench_solver".to_string())),
        ("smoke", Json::Bool(smoke)),
        (
            "eig",
            Json::obj(vec![
                ("shape", Json::Str(format!("{p}x{q}"))),
                ("mask_missing", Json::Num(0.05)),
                ("sigma2", Json::Num(s2)),
                ("tol", Json::Num(tol)),
                ("cg_iters_plain", Json::Num(cg_iters_plain as f64)),
                ("cg_iters_eig_precond", Json::Num(cg_iters_eig_precond as f64)),
                ("iters_reduction_ge_2x", Json::Bool(reduction_ok)),
                ("full_grid_secs_cg", Json::Num(cg_secs)),
                ("full_grid_secs_eig", Json::Num(eig_secs)),
                ("full_grid_speedup_vs_cg", Json::Num(full_grid_speedup_vs_cg)),
            ]),
        ),
    ]);
    let _ = std::fs::write("BENCH_solver.json", format!("{doc}\n"));
    b.save_csv("bench_solver");
    b.save_json("bench_solver");
    println!("\nwrote BENCH_solver.json + results/bench/bench_solver.{{csv,json}}");
}
