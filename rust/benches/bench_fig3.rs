//! Bench: the Figure-3 end-to-end comparison at one size — full
//! LKGP fit vs dense-iterative fit on sim-SARCOS, at a low and a high
//! missing ratio (below/above the Prop-3.1 break-even).

use lkgp::data::sarcos::SarcosSim;
use lkgp::gp::backend::MvmMode;
use lkgp::gp::lkgp::{Backend, Lkgp, LkgpConfig};
use lkgp::kron::breakeven;
use lkgp::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::quick();
    let p = 96;
    println!(
        "# bench_fig3 — end-to-end fit, sim-SARCOS p={p} q=7 \
         (gamma*_time = {:.2})\n",
        breakeven::gamma_time(p, 7)
    );
    for ratio in [0.2, 0.8] {
        let data = SarcosSim::new(p, ratio, 0).generate();
        let cfg = LkgpConfig {
            train_iters: 5,
            n_samples: 8,
            probes: 4,
            seed: 0,
            ..LkgpConfig::default()
        };
        b.bench(&format!("lkgp_fit missing={ratio}"), || {
            black_box(Lkgp::fit(&data, cfg.clone()).unwrap());
        });
        let cfg_d = LkgpConfig {
            backend: Backend::Rust(MvmMode::DenseMaterialized),
            ..cfg.clone()
        };
        b.bench(&format!("dense_fit missing={ratio}"), || {
            black_box(Lkgp::fit(&data, cfg_d.clone()).unwrap());
        });
    }
    b.save_csv("bench_fig3");
}
