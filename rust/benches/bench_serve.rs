//! Bench: the `lkgp serve` daemon under concurrent client load —
//! cross-request batching (admission window > 0) versus serial
//! per-request dispatch (window = 0) on the same checkpointed model.
//!
//! Concurrent clients pipeline small predict requests over their own
//! TCP connections; the batched daemon coalesces requests from all of
//! them into shared steal-scheduled `predict_batch` sweeps with one
//! coalesced socket write per connection per sweep, while the serial
//! daemon answers each request on its own. Every response is checked
//! bit-for-bit against the engine's offline answer — grouping must
//! never change output bits (`serve.wire_bit_identical`).
//!
//! Emits `BENCH_serve.json`, gated in CI by `scripts/check_bench.py`
//! (`serve.batched_ge_1x`: batched throughput at least matches serial;
//! p50/p99 latency fields present and numeric). `LKGP_BENCH_SMOKE=1`
//! shrinks sizes for the CI `bench-smoke` job.

use std::sync::Arc;

use lkgp::data::synthetic::well_specified;
use lkgp::gp::lkgp::{Lkgp, LkgpConfig};
use lkgp::kernels::ProductGridKernel;
use lkgp::model::TrainedModel;
use lkgp::serve::daemon::{DaemonOptions, ServeClient, ServeDaemon};
use lkgp::serve::ServeEngine;
use lkgp::util::json::Json;
use lkgp::util::rng::Rng;
use lkgp::util::wire::{Request, Response};

/// Pipelining depth: requests in flight per client before draining
/// responses (bounds socket buffering on both sides).
const PIPELINE: usize = 64;

struct Load {
    clients: usize,
    requests_per_client: usize,
    cells_per_request: usize,
}

/// Drive `load` against a daemon at `addr`; every client checks each
/// response bit-for-bit against the expected posterior. Returns the
/// wall seconds for the whole round.
fn drive(addr: &str, load: &Load, expect_mean: &Arc<Vec<f64>>, expect_var: &Arc<Vec<f64>>) -> f64 {
    let pq = expect_mean.len();
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for client_id in 0..load.clients {
        let addr = addr.to_string();
        let (expect_mean, expect_var) = (Arc::clone(expect_mean), Arc::clone(expect_var));
        let (reqs, per_req) = (load.requests_per_client, load.cells_per_request);
        handles.push(std::thread::spawn(move || {
            let mut client = ServeClient::connect(&addr).expect("connect");
            let mut rng = Rng::new(0xBE7C_u64 + client_id as u64);
            let mut sent = 0usize;
            while sent < reqs {
                let burst = PIPELINE.min(reqs - sent);
                let mut expected: Vec<(u64, Vec<usize>)> = Vec::with_capacity(burst);
                for _ in 0..burst {
                    let cells: Vec<usize> = (0..per_req).map(|_| rng.below(pq)).collect();
                    let id = client.fresh_id();
                    client
                        .send(&Request::Predict { id, model: String::new(), cells: cells.clone() })
                        .expect("send");
                    expected.push((id, cells));
                }
                for (id, cells) in expected {
                    let resp = client.recv().expect("recv");
                    match resp {
                        Response::Predict { id: rid, mean, var } => {
                            assert_eq!(rid, id, "responses must arrive in request order");
                            for (i, &c) in cells.iter().enumerate() {
                                assert_eq!(
                                    mean[i].to_bits(),
                                    expect_mean[c].to_bits(),
                                    "client {client_id}: served mean bits differ at cell {c}"
                                );
                                assert_eq!(
                                    var[i].to_bits(),
                                    expect_var[c].to_bits(),
                                    "client {client_id}: served var bits differ at cell {c}"
                                );
                            }
                        }
                        other => panic!("client {client_id}: unexpected response {other:?}"),
                    }
                }
                sent += burst;
            }
        }));
    }
    let mut ok = true;
    for h in handles {
        ok &= h.join().is_ok();
    }
    assert!(ok, "a bench client panicked (bit mismatch or transport error)");
    t0.elapsed().as_secs_f64()
}

/// Best-of-`rounds` throughput (requests/sec) against one daemon.
fn measure(
    addr: &str,
    load: &Load,
    rounds: usize,
    expect_mean: &Arc<Vec<f64>>,
    expect_var: &Arc<Vec<f64>>,
) -> f64 {
    let total = (load.clients * load.requests_per_client) as f64;
    let mut best = 0.0f64;
    for _ in 0..rounds {
        let secs = drive(addr, load, expect_mean, expect_var);
        best = best.max(total / secs.max(1e-9));
    }
    best
}

fn fit_model(p: usize, q: usize) -> TrainedModel {
    let kernel = ProductGridKernel::new(2, "rbf", q);
    let data = well_specified(p, q, 2, &kernel, 0.05, 0.3, 7);
    let cfg = LkgpConfig {
        train_iters: 3,
        n_samples: 8,
        probes: 4,
        cg_tol: 1e-2,
        cg_max_iters: 200,
        seed: 7,
        capture_pathwise: true,
        ..LkgpConfig::default()
    };
    let fit = Lkgp::fit(&data, cfg).expect("bench fit");
    fit.model.expect("capture_pathwise was on")
}

fn main() {
    let smoke = std::env::var("LKGP_BENCH_SMOKE").ok().as_deref() == Some("1");
    let (p, q) = if smoke { (32usize, 8usize) } else { (64usize, 16usize) };
    let load = Load {
        clients: 8,
        requests_per_client: if smoke { 128 } else { 512 },
        cells_per_request: 8,
    };
    let rounds = 3;
    let window_ms = 1u64;
    println!("# bench_serve — daemon throughput under concurrency (smoke: {smoke})\n");

    let model = fit_model(p, q);
    let engine = ServeEngine::from_model(model.clone()).expect("engine");
    let pq = engine.model().grid_len();
    let full = engine.predict_cells(&(0..pq).collect::<Vec<_>>()).expect("offline posterior");
    let expect_mean = Arc::new(full.mean);
    let expect_var = Arc::new(full.var);

    // ---- serial baseline: window 0, one sweep per request
    let serial_engine = ServeEngine::from_model(model.clone()).expect("engine");
    let mut serial_daemon = ServeDaemon::start(
        "127.0.0.1:0",
        vec![("bench".to_string(), serial_engine)],
        DaemonOptions { window_ms: 0, ..DaemonOptions::default() },
    )
    .expect("serial daemon");
    let addr = serial_daemon.local_addr().to_string();
    let throughput_serial_rps = measure(&addr, &load, rounds, &expect_mean, &expect_var);
    let serial_report = serial_daemon.shutdown();
    println!(
        "serial  (window 0 ms): {throughput_serial_rps:>10.0} req/s  [{}]",
        serial_report.render()
    );

    // ---- cross-request batching: admission window + early close
    let batched_engine = ServeEngine::from_model(model).expect("engine");
    let mut batched_daemon = ServeDaemon::start(
        "127.0.0.1:0",
        vec![("bench".to_string(), batched_engine)],
        DaemonOptions { window_ms, max_batch: 256, ..DaemonOptions::default() },
    )
    .expect("batched daemon");
    let addr = batched_daemon.local_addr().to_string();
    let throughput_batched_rps = measure(&addr, &load, rounds, &expect_mean, &expect_var);
    let batched_report = batched_daemon.shutdown();
    println!(
        "batched (window {window_ms} ms): {throughput_batched_rps:>10.0} req/s  [{}]",
        batched_report.render()
    );

    let batched_speedup = throughput_batched_rps / throughput_serial_rps.max(1e-9);
    let batched_ge_1x = throughput_batched_rps >= throughput_serial_rps;
    println!(
        "\ncross-request batching: {batched_speedup:.2}x serial dispatch \
         (occupancy {:.1}, p50 {:.3} ms, p99 {:.3} ms)",
        batched_report.mean_batch_occupancy, batched_report.p50_ms, batched_report.p99_ms
    );

    let doc = Json::obj(vec![
        ("bench", Json::Str("bench_serve".to_string())),
        ("smoke", Json::Bool(smoke)),
        (
            "serve",
            Json::obj(vec![
                ("grid", Json::Str(format!("{p}x{q}"))),
                ("clients", Json::Num(load.clients as f64)),
                ("requests_per_client", Json::Num(load.requests_per_client as f64)),
                ("cells_per_request", Json::Num(load.cells_per_request as f64)),
                ("window_ms", Json::Num(window_ms as f64)),
                ("throughput_serial_rps", Json::Num(throughput_serial_rps)),
                ("throughput_batched_rps", Json::Num(throughput_batched_rps)),
                ("batched_speedup", Json::Num(batched_speedup)),
                ("batched_ge_1x", Json::Bool(batched_ge_1x)),
                // every response of every round was asserted bit-equal
                // to the offline posterior, or a client panic would
                // have aborted the bench before this line
                ("wire_bit_identical", Json::Bool(true)),
                ("mean_batch_occupancy", Json::Num(batched_report.mean_batch_occupancy)),
                ("p50_ms", Json::Num(batched_report.p50_ms)),
                ("p99_ms", Json::Num(batched_report.p99_ms)),
                ("serial_p50_ms", Json::Num(serial_report.p50_ms)),
                ("serial_p99_ms", Json::Num(serial_report.p99_ms)),
            ]),
        ),
    ]);
    let _ = std::fs::write("BENCH_serve.json", format!("{doc}\n"));
    let _ = std::fs::create_dir_all("results/bench");
    let _ = std::fs::copy("BENCH_serve.json", "results/bench/bench_serve.json");
    println!("\nwrote BENCH_serve.json + results/bench/bench_serve.json");
}
