//! Bench: parallel scaling of the compute subsystem (`lkgp::par`) and
//! the register-tiled GEMM microkernel.
//!
//! Measures the batched Kronecker MVM, the tiled GEMM at 1/2/4/8 worker
//! threads, the microkernel against the retained scalar baseline
//! (`matmul_nt_ref`, single-threaded so the comparison isolates the
//! register tile from parallel scaling), the persistent-pool region
//! dispatch against the scoped-spawn baseline it replaced (plus the
//! steal-mode chunk counters), and an end-to-end `Lkgp::fit`; asserts
//! the MVM outputs and the fit posterior are bit-identical across
//! thread counts, and writes `BENCH_par.json` with the
//! `gemm_microkernel` and `pool` acceptance fields the `bench-smoke`
//! CI job gates on (`tiled_ge_1p5x`, `tiled_f32_ge_2x`,
//! `gemm_gflops_ok`, `region_speedup_ge_1x`).
//!
//! `LKGP_BENCH_SMOKE=1` shrinks problem sizes and sample counts for CI;
//! the acceptance ratios are size-stable, so the gate fields stay
//! meaningful. `LKGP_GEMM_GFLOPS_MIN` (default 1.0) sets the absolute
//! GFLOP/s floor — deliberately conservative, since shared CI runners
//! vary; the ratio fields are the real regression signal.

use lkgp::data::synthetic::well_specified;
use lkgp::gp::lkgp::{Lkgp, LkgpConfig};
use lkgp::kernels::{ProductGridKernel, RbfArd};
use lkgp::kron::{breakeven, KronOp, MaskedKronSystem};
use lkgp::linalg::gemm::{gemm_flops, matmul_nt, matmul_nt_ref};
use lkgp::linalg::Matrix;
use lkgp::par;
use lkgp::util::bench::{black_box, Bencher};
use lkgp::util::json::Json;
use lkgp::util::rng::Rng;

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn main() {
    let smoke = std::env::var("LKGP_BENCH_SMOKE").ok().as_deref() == Some("1");
    let mut b = if smoke { Bencher::quick() } else { Bencher::default() };
    let mut rng = Rng::new(0);
    println!(
        "# bench_par — thread scaling + GEMM microkernel (cores: {}, smoke: {})\n",
        cores(),
        smoke
    );

    // ---- batched Kron MVM (p=256, q=32 — the Fig-3 shape) ----
    let (p, q) = if smoke { (128usize, 16usize) } else { (256usize, 32usize) };
    let n = p * q;
    let kss = {
        let a = Matrix::from_vec(p, 3, rng.normals(p * 3));
        RbfArd::new(3).gram(&a, &a)
    };
    let ktt = {
        let a = Matrix::from_vec(q, 1, rng.normals(q));
        RbfArd::new(1).gram(&a, &a)
    };
    let sys = MaskedKronSystem::new(KronOp::new(kss, ktt), vec![1.0; n], 0.1);
    let batch = 8usize;
    let v = Matrix::from_vec(batch, n, rng.normals(batch * n));
    let mut mvm_ref: Option<Vec<u64>> = None;
    for &t in &THREADS {
        let out = par::with_threads(t, || {
            b.bench_with_flops(
                &format!("kron_mvm p={p} q={q} batch={batch} threads={t}"),
                Some(batch as f64 * breakeven::kron_mvm_flops(p, q)),
                || {
                    black_box(sys.apply_batch(&v));
                },
            );
            sys.apply_batch(&v)
        });
        let bits: Vec<u64> = out.data.iter().map(|x| x.to_bits()).collect();
        match &mvm_ref {
            None => mvm_ref = Some(bits),
            Some(want) => assert_eq!(want, &bits, "kron MVM not bit-identical at t={t}"),
        }
    }
    println!();

    // ---- tiled GEMM thread scaling ----
    let gdim = if smoke { 256usize } else { 384usize };
    let (gm, gk, gn) = (gdim, gdim, gdim);
    let ga = Matrix::from_vec(gm, gk, rng.normals(gm * gk));
    let gb = Matrix::from_vec(gk, gn, rng.normals(gk * gn));
    for &t in &THREADS {
        par::with_threads(t, || {
            b.bench_with_flops(
                &format!("gemm {gm}x{gk}x{gn} threads={t}"),
                Some(gemm_flops(gm, gk, gn)),
                || {
                    black_box(ga.matmul(&gb));
                },
            );
        });
    }
    println!();

    // ---- GEMM microkernel vs scalar baseline (single-threaded) ----
    // Largest dense shape in this bench, A @ B^T form in both paths so
    // the only difference is the register tile + packing. These four
    // measurements feed hard CI gates, so they get more samples than
    // the surrounding sections even in smoke mode, and the acceptance
    // ratios are computed from p10 (near-best) times — far less
    // sensitive to noisy-neighbor bursts on shared runners than the
    // median of a handful of samples.
    let fl = gemm_flops(gdim, gdim, gdim);
    let gbt = gb.transpose(); // gdim x gdim, row-major "B" for the nt form
    let (ga32, gbt32): (Matrix<f32>, Matrix<f32>) = (ga.cast(), gbt.cast());
    let saved = (b.sample_target, b.samples);
    b.sample_target = saved.0.max(std::time::Duration::from_millis(120));
    b.samples = saved.1.max(7);
    let (t_ref64, t_tile64, t_ref32, t_tile32) = par::with_threads(1, || {
        let t_ref64 = b
            .bench_with_flops(&format!("gemm_nt {gdim}^3 f64 scalar-ref t=1"), Some(fl), || {
                black_box(matmul_nt_ref(&ga, &gbt));
            })
            .p10_ns;
        let t_tile64 = b
            .bench_with_flops(&format!("gemm_nt {gdim}^3 f64 tiled t=1"), Some(fl), || {
                black_box(matmul_nt(&ga, &gbt));
            })
            .p10_ns;
        let t_ref32 = b
            .bench_with_flops(&format!("gemm_nt {gdim}^3 f32 scalar-ref t=1"), Some(fl), || {
                black_box(matmul_nt_ref(&ga32, &gbt32));
            })
            .p10_ns;
        let t_tile32 = b
            .bench_with_flops(&format!("gemm_nt {gdim}^3 f32 tiled t=1"), Some(fl), || {
                black_box(matmul_nt(&ga32, &gbt32));
            })
            .p10_ns;
        (t_ref64, t_tile64, t_ref32, t_tile32)
    });
    b.sample_target = saved.0;
    b.samples = saved.1;
    let gfl = |ns: f64| fl / ns; // flops per ns == GFLOP/s
    let speedup64 = t_ref64 / t_tile64;
    let speedup32 = t_ref32 / t_tile32;
    let gflops_min: f64 = std::env::var("LKGP_GEMM_GFLOPS_MIN")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let gflops_ok = gfl(t_tile64) >= gflops_min && gfl(t_tile32) >= gflops_min;
    println!(
        "-> microkernel f64: {:.2} GFLOP/s tiled vs {:.2} scalar ({speedup64:.2}x, \
         acceptance >= 1.5x)",
        gfl(t_tile64),
        gfl(t_ref64)
    );
    println!(
        "-> microkernel f32: {:.2} GFLOP/s tiled vs {:.2} scalar ({speedup32:.2}x, \
         acceptance >= 2x)\n",
        gfl(t_tile32),
        gfl(t_ref32)
    );

    // ---- region dispatch: persistent pool vs scoped spawn ----
    // The cost an iterative solver pays per small parallel region. The
    // pool path measures a full empty region (publish + claims + wait);
    // the baseline is what the PR-1 design paid per region: spawning
    // and joining the same number of scoped helper threads.
    let dt = cores().clamp(2, 4);
    let (pool_ns, spawn_ns, steal_ratio) = par::with_threads(dt, || {
        par::par_rows("bench.warmup", dt, |_r| {}); // start + park workers
        let pool_ns = b
            .bench(&format!("region_dispatch pool w={dt} (empty)"), || {
                par::par_rows("bench.dispatch", dt, |_r| {});
            })
            .median_ns;
        let spawn_ns = b
            .bench(&format!("region_dispatch scoped-spawn w={dt} (empty)"), || {
                std::thread::scope(|s| {
                    for _ in 1..dt {
                        s.spawn(|| {});
                    }
                });
            })
            .median_ns;
        // ragged steal-mode workload (chunk cost grows with index) to
        // exercise the shared-cursor assignment and read its counters
        let s0 = par::pool_stats();
        let mut buf = vec![0.0f64; 64 * 256];
        for _ in 0..10 {
            par::par_chunks_mut_steal("bench.steal", &mut buf, 256, |ci, chunk| {
                for (off, x) in chunk.iter_mut().enumerate() {
                    let mut acc = 0.0;
                    for k in 0..=ci {
                        acc += ((off + k) as f64).sqrt();
                    }
                    *x = acc;
                }
            });
        }
        black_box(&buf);
        let s1 = par::pool_stats();
        let d_chunks = (s1.steal_chunks - s0.steal_chunks).max(1);
        let ratio = (s1.stolen_chunks - s0.stolen_chunks) as f64 / d_chunks as f64;
        (pool_ns, spawn_ns, ratio)
    });
    let dispatch_speedup = spawn_ns / pool_ns;
    println!(
        "-> pool dispatch: {:.2} µs/region vs {:.2} µs scoped spawn \
         ({dispatch_speedup:.1}x, acceptance >= 1x, target >= 10x; \
         steal_ratio {steal_ratio:.2})\n",
        pool_ns / 1e3,
        spawn_ns / 1e3
    );

    // ---- end-to-end fit (synthetic workload) ----
    let (fp, fq) = if smoke { (96usize, 16usize) } else { (256usize, 32usize) };
    let kernel = ProductGridKernel::new(2, "rbf", fq);
    let data = well_specified(fp, fq, 2, &kernel, 0.05, 0.25, 7);
    let cfg = LkgpConfig {
        train_iters: if smoke { 2 } else { 3 },
        n_samples: 16,
        probes: 4,
        cg_max_iters: 100,
        seed: 11,
        ..LkgpConfig::default()
    };
    let mut fit_rows = Vec::new();
    let mut fit_base = f64::NAN;
    let mut post_ref: Option<(Vec<u64>, Vec<u64>)> = None;
    for &t in &THREADS {
        let (secs, fit) = par::with_threads(t, || {
            // one warm-up fit, then keep the faster of two timed runs
            let _ = Lkgp::fit(&data, cfg.clone()).unwrap();
            let mut best = f64::INFINITY;
            let mut last = None;
            for _ in 0..2 {
                let t0 = std::time::Instant::now();
                let fit = Lkgp::fit(&data, cfg.clone()).unwrap();
                best = best.min(t0.elapsed().as_secs_f64());
                last = Some(fit);
            }
            (best, last.unwrap())
        });
        if t == 1 {
            fit_base = secs;
        }
        let bits = (
            fit.posterior.mean.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            fit.posterior.var.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        );
        let identical = match &post_ref {
            None => {
                post_ref = Some(bits);
                true
            }
            Some(want) => *want == bits,
        };
        assert!(identical, "fit posterior not bit-identical at t={t}");
        let speedup = fit_base / secs;
        println!(
            "fit/e2e p={fp} q={fq} threads={t}: {secs:.3}s  speedup {speedup:.2}x  \
             bit-identical: {identical}"
        );
        fit_rows.push(Json::obj(vec![
            ("name", Json::Str(format!("fit/e2e p={fp} q={fq}"))),
            ("threads", Json::Num(t as f64)),
            ("secs", Json::Num(secs)),
            ("speedup_vs_1", Json::Num(speedup)),
            ("bit_identical", Json::Bool(identical)),
        ]));
    }

    // machine-readable perf trajectory seed + CI acceptance fields
    let doc = Json::obj(vec![
        ("bench", Json::Str("bench_par".to_string())),
        ("cores", Json::Num(cores() as f64)),
        ("smoke", Json::Bool(smoke)),
        ("micro", b.to_json()),
        (
            "gemm_microkernel",
            Json::obj(vec![
                ("shape", Json::Str(format!("{gdim}x{gdim}x{gdim}"))),
                ("f64_scalar_gflops", Json::Num(gfl(t_ref64))),
                ("f64_tiled_gflops", Json::Num(gfl(t_tile64))),
                ("f64_speedup", Json::Num(speedup64)),
                ("tiled_ge_1p5x", Json::Bool(speedup64 >= 1.5)),
                ("f32_scalar_gflops", Json::Num(gfl(t_ref32))),
                ("f32_tiled_gflops", Json::Num(gfl(t_tile32))),
                ("f32_speedup", Json::Num(speedup32)),
                ("tiled_f32_ge_2x", Json::Bool(speedup32 >= 2.0)),
                ("gemm_gflops_min", Json::Num(gflops_min)),
                ("gemm_gflops_ok", Json::Bool(gflops_ok)),
            ]),
        ),
        (
            "pool",
            Json::obj(vec![
                ("threads", Json::Num(dt as f64)),
                ("dispatch_ns", Json::Num(pool_ns)),
                ("spawn_ns", Json::Num(spawn_ns)),
                ("dispatch_speedup", Json::Num(dispatch_speedup)),
                ("region_speedup_ge_1x", Json::Bool(dispatch_speedup >= 1.0)),
                ("dispatch_ge_10x", Json::Bool(dispatch_speedup >= 10.0)),
                ("steal_ratio", Json::Num(steal_ratio)),
                ("cheap_sweep_min", Json::Num(par::cheap_sweep_min() as f64)),
                ("workers_live", Json::Num(par::pool_stats().workers_live as f64)),
            ]),
        ),
        ("fit", Json::Arr(fit_rows)),
    ]);
    let _ = std::fs::write("BENCH_par.json", format!("{doc}\n"));
    b.save_csv("bench_par");
    b.save_json("bench_par");
    println!("\nwrote BENCH_par.json + results/bench/bench_par.{{csv,json}}");
}
