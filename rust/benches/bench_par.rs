//! Bench: parallel scaling of the compute subsystem (`lkgp::par`).
//!
//! Measures the batched Kronecker MVM, the blocked GEMM, and an
//! end-to-end `Lkgp::fit` on a p=256, q=32 synthetic workload at
//! 1/2/4/8 worker threads, asserts the MVM outputs and the fit
//! posterior are bit-identical across thread counts, and writes
//! `BENCH_par.json` (the machine-readable perf-trajectory seed) plus
//! the usual results/bench CSV/JSON.

use lkgp::data::synthetic::well_specified;
use lkgp::gp::lkgp::{Lkgp, LkgpConfig};
use lkgp::kernels::{ProductGridKernel, RbfArd};
use lkgp::kron::{breakeven, KronOp, MaskedKronSystem};
use lkgp::linalg::gemm::gemm_flops;
use lkgp::linalg::Matrix;
use lkgp::par;
use lkgp::util::bench::{black_box, Bencher};
use lkgp::util::json::Json;
use lkgp::util::rng::Rng;

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn main() {
    let mut b = Bencher::default();
    let mut rng = Rng::new(0);
    println!("# bench_par — thread scaling (cores available: {})\n", cores());

    // ---- batched Kron MVM (p=256, q=32 — the Fig-3 shape) ----
    let (p, q) = (256usize, 32usize);
    let n = p * q;
    let kss = {
        let a = Matrix::from_vec(p, 3, rng.normals(p * 3));
        RbfArd::new(3).gram(&a, &a)
    };
    let ktt = {
        let a = Matrix::from_vec(q, 1, rng.normals(q));
        RbfArd::new(1).gram(&a, &a)
    };
    let sys = MaskedKronSystem::new(KronOp::new(kss, ktt), vec![1.0; n], 0.1);
    let batch = 8usize;
    let v = Matrix::from_vec(batch, n, rng.normals(batch * n));
    let mut mvm_ref: Option<Vec<u64>> = None;
    for &t in &THREADS {
        let out = par::with_threads(t, || {
            b.bench_with_flops(
                &format!("kron_mvm p={p} q={q} batch={batch} threads={t}"),
                Some(batch as f64 * breakeven::kron_mvm_flops(p, q)),
                || {
                    black_box(sys.apply_batch(&v));
                },
            );
            sys.apply_batch(&v)
        });
        let bits: Vec<u64> = out.data.iter().map(|x| x.to_bits()).collect();
        match &mvm_ref {
            None => mvm_ref = Some(bits),
            Some(want) => assert_eq!(want, &bits, "kron MVM not bit-identical at t={t}"),
        }
    }
    println!();

    // ---- blocked GEMM ----
    let (gm, gk, gn) = (384usize, 384, 384);
    let ga = Matrix::from_vec(gm, gk, rng.normals(gm * gk));
    let gb = Matrix::from_vec(gk, gn, rng.normals(gk * gn));
    for &t in &THREADS {
        par::with_threads(t, || {
            b.bench_with_flops(
                &format!("gemm {gm}x{gk}x{gn} threads={t}"),
                Some(gemm_flops(gm, gk, gn)),
                || {
                    black_box(ga.matmul(&gb));
                },
            );
        });
    }
    println!();

    // ---- end-to-end fit (p=256, q=32 synthetic workload) ----
    let kernel = ProductGridKernel::new(2, "rbf", q);
    let data = well_specified(p, q, 2, &kernel, 0.05, 0.25, 7);
    let cfg = LkgpConfig {
        train_iters: 3,
        n_samples: 16,
        probes: 4,
        cg_max_iters: 100,
        seed: 11,
        ..LkgpConfig::default()
    };
    let mut fit_rows = Vec::new();
    let mut fit_base = f64::NAN;
    let mut post_ref: Option<(Vec<u64>, Vec<u64>)> = None;
    for &t in &THREADS {
        let (secs, fit) = par::with_threads(t, || {
            // one warm-up fit, then keep the faster of two timed runs
            let _ = Lkgp::fit(&data, cfg.clone()).unwrap();
            let mut best = f64::INFINITY;
            let mut last = None;
            for _ in 0..2 {
                let t0 = std::time::Instant::now();
                let fit = Lkgp::fit(&data, cfg.clone()).unwrap();
                best = best.min(t0.elapsed().as_secs_f64());
                last = Some(fit);
            }
            (best, last.unwrap())
        });
        if t == 1 {
            fit_base = secs;
        }
        let bits = (
            fit.posterior.mean.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            fit.posterior.var.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        );
        let identical = match &post_ref {
            None => {
                post_ref = Some(bits);
                true
            }
            Some(want) => *want == bits,
        };
        assert!(identical, "fit posterior not bit-identical at t={t}");
        let speedup = fit_base / secs;
        println!(
            "fit/e2e p={p} q={q} threads={t}: {secs:.3}s  speedup {speedup:.2}x  \
             bit-identical: {identical}"
        );
        fit_rows.push(Json::obj(vec![
            ("name", Json::Str(format!("fit/e2e p={p} q={q}"))),
            ("threads", Json::Num(t as f64)),
            ("secs", Json::Num(secs)),
            ("speedup_vs_1", Json::Num(speedup)),
            ("bit_identical", Json::Bool(identical)),
        ]));
    }

    // machine-readable perf trajectory seed
    let doc = Json::obj(vec![
        ("bench", Json::Str("bench_par".to_string())),
        ("cores", Json::Num(cores() as f64)),
        ("micro", b.to_json()),
        ("fit", Json::Arr(fit_rows)),
    ]);
    let _ = std::fs::write("BENCH_par.json", format!("{doc}\n"));
    b.save_csv("bench_par");
    b.save_json("bench_par");
    println!("\nwrote BENCH_par.json + results/bench/bench_par.{{csv,json}}");
}
