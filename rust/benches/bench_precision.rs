//! Bench: mixed-precision (f32) vs f64 across the inference hot path.
//!
//! Measures the batched masked Kronecker MVM, the blocked GEMM, a
//! fixed-iteration preconditioned CG solve, and an end-to-end
//! `Lkgp::fit` in both precisions, plus a Fig-3-style accuracy check
//! (sim-SARCOS test RMSE: f32 must land within 1% of f64). Writes
//! `BENCH_precision.json` (machine-readable: per-measurement table +
//! speedup/accuracy summary) and the usual results/bench CSV/JSON.

use lkgp::data::sarcos::SarcosSim;
use lkgp::gp::backend::Precision;
use lkgp::gp::lkgp::{Lkgp, LkgpConfig};
use lkgp::kernels::RbfArd;
use lkgp::kron::{breakeven, KronOp, MaskedKronSystem};
use lkgp::linalg::gemm::gemm_flops;
use lkgp::linalg::{Matrix, Scalar};
use lkgp::solvers::cg::{solve_cg, BatchedOp, CgOptions};
use lkgp::solvers::precond::Preconditioner;
use lkgp::util::bench::{black_box, Bencher};
use lkgp::util::json::Json;
use lkgp::util::rng::Rng;

struct SysOp<'a, T: Scalar>(&'a MaskedKronSystem<T>);

impl<'a, T: Scalar> BatchedOp<T> for SysOp<'a, T> {
    fn dim(&self) -> usize {
        self.0.dim()
    }
    fn apply_batch(&mut self, v: &Matrix<T>) -> Matrix<T> {
        self.0.apply_batch(v)
    }
}

fn cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn main() {
    // LKGP_BENCH_SMOKE=1 (the CI bench-smoke job): fewer/shorter samples.
    // Problem sizes are kept as-is — the `mvm_ge_1p5x` / `within_1pct`
    // acceptance fields are calibrated at these shapes and the fit
    // section is what pins the accuracy contract.
    let smoke = std::env::var("LKGP_BENCH_SMOKE").ok().as_deref() == Some("1");
    let mut b = if smoke { Bencher::quick() } else { Bencher::default() };
    let mut rng = Rng::new(0);
    println!(
        "# bench_precision — f32 vs f64 hot path (cores: {}, threads: {}, smoke: {})\n",
        cores(),
        lkgp::par::num_threads(),
        smoke
    );

    // ---- batched masked Kron MVM (p=256, q=32 — the Fig-3 shape) ----
    let (p, q) = (256usize, 32usize);
    let n = p * q;
    let kss64 = {
        let a = Matrix::from_vec(p, 3, rng.normals(p * 3));
        RbfArd::new(3).gram(&a, &a)
    };
    let ktt64 = {
        let a = Matrix::from_vec(q, 1, rng.normals(q));
        RbfArd::new(1).gram(&a, &a)
    };
    let mask: Vec<f64> = (0..n).map(|i| if i % 5 == 0 { 0.0 } else { 1.0 }).collect();
    let sys64 = MaskedKronSystem::new(
        KronOp::new(kss64.clone(), ktt64.clone()),
        mask.clone(),
        0.1,
    );
    let sys32: MaskedKronSystem<f32> = MaskedKronSystem::new(
        KronOp::new(kss64.cast(), ktt64.cast()),
        mask.iter().map(|&m| m as f32).collect(),
        0.1f32,
    );
    let batch = 8usize;
    let v64 = Matrix::from_vec(batch, n, rng.normals(batch * n));
    let v32: Matrix<f32> = v64.cast();
    let mvm_flops = batch as f64 * breakeven::kron_mvm_flops(p, q);
    let t_mvm64 = b
        .bench_with_flops(
            &format!("kron_mvm p={p} q={q} batch={batch} f64"),
            Some(mvm_flops),
            || {
                black_box(sys64.apply_batch(&v64));
            },
        )
        .median_ns;
    let t_mvm32 = b
        .bench_with_flops(
            &format!("kron_mvm p={p} q={q} batch={batch} f32"),
            Some(mvm_flops),
            || {
                black_box(sys32.apply_batch(&v32));
            },
        )
        .median_ns;
    let mvm_speedup = t_mvm64 / t_mvm32;
    println!("-> MVM f32 speedup: {mvm_speedup:.2}x (acceptance: >= 1.5x)\n");

    // ---- blocked GEMM ----
    let (gm, gk, gn) = (384usize, 384, 384);
    let ga64 = Matrix::from_vec(gm, gk, rng.normals(gm * gk));
    let gb64 = Matrix::from_vec(gk, gn, rng.normals(gk * gn));
    let (ga32, gb32): (Matrix<f32>, Matrix<f32>) = (ga64.cast(), gb64.cast());
    let t_gemm64 = b
        .bench_with_flops(
            &format!("gemm {gm}x{gk}x{gn} f64"),
            Some(gemm_flops(gm, gk, gn)),
            || {
                black_box(ga64.matmul(&gb64));
            },
        )
        .median_ns;
    let t_gemm32 = b
        .bench_with_flops(
            &format!("gemm {gm}x{gk}x{gn} f32"),
            Some(gemm_flops(gm, gk, gn)),
            || {
                black_box(ga32.matmul(&gb32));
            },
        )
        .median_ns;
    let gemm_speedup = t_gemm64 / t_gemm32;
    println!("-> GEMM f32 speedup: {gemm_speedup:.2}x\n");

    // ---- fixed-iteration preconditioned CG on the masked system ----
    // tol=0 never triggers the early exit, so both precisions do exactly
    // `cg_iters` MVMs — a like-for-like throughput comparison.
    let cg_iters = 20usize;
    let rhs_rows = 4usize;
    let rhs64 = Matrix::from_vec(rhs_rows, n, rng.normals(rhs_rows * n));
    let rhs32: Matrix<f32> = rhs64.cast();
    let diag = sys64.diag();
    let pre64: Preconditioner<f64> = Preconditioner::jacobi(&diag);
    let pre32: Preconditioner<f32> = Preconditioner::jacobi(&diag);
    let cg_opts = CgOptions { max_iters: cg_iters, tol: 0.0, ..CgOptions::default() };
    let t_cg64 = b
        .bench(&format!("cg {cg_iters}it rhs={rhs_rows} f64"), || {
            black_box(solve_cg(&mut SysOp(&sys64), &rhs64, &pre64, &cg_opts))
        })
        .median_ns;
    let t_cg32 = b
        .bench(&format!("cg {cg_iters}it rhs={rhs_rows} f32"), || {
            black_box(solve_cg(&mut SysOp(&sys32), &rhs32, &pre32, &cg_opts))
        })
        .median_ns;
    let cg_speedup = t_cg64 / t_cg32;
    println!("-> CG f32 speedup: {cg_speedup:.2}x\n");

    // ---- end-to-end fit + Fig-3-style accuracy (sim-SARCOS) ----
    let data = SarcosSim::new(96, 0.3, 0).generate();
    let mk_cfg = |precision| LkgpConfig {
        train_iters: 6,
        // gentle Adam steps keep the f32/f64 hyperparameter trajectories
        // glued, so the RMSE comparison isolates precision effects
        lr: 0.02,
        n_samples: 16,
        probes: 4,
        cg_tol: 1e-3,
        cg_max_iters: 200,
        seed: 11,
        precision,
        ..LkgpConfig::default()
    };
    let time_fit = |cfg: &LkgpConfig| {
        let _ = Lkgp::fit(&data, cfg.clone()).unwrap(); // warm-up
        let mut best = f64::INFINITY;
        let mut last = None;
        for _ in 0..2 {
            let t0 = std::time::Instant::now();
            let fit = Lkgp::fit(&data, cfg.clone()).unwrap();
            best = best.min(t0.elapsed().as_secs_f64());
            last = Some(fit);
        }
        (best, last.unwrap())
    };
    let (secs64, fit64) = time_fit(&mk_cfg(Precision::F64));
    let (secs32, fit32) = time_fit(&mk_cfg(Precision::F32));
    let fit_speedup = secs64 / secs32;
    let (rmse64, nll64) = fit64.posterior.test_metrics(&data);
    let (rmse32, nll32) = fit32.posterior.test_metrics(&data);
    let rmse_rel_diff = (rmse32 - rmse64).abs() / rmse64.max(1e-12);
    println!(
        "fit/e2e sim-SARCOS p=96: f64 {secs64:.3}s  f32 {secs32:.3}s  \
         speedup {fit_speedup:.2}x"
    );
    println!(
        "accuracy: test RMSE f64 {rmse64:.4} vs f32 {rmse32:.4} \
         (rel diff {:.3}%, acceptance <= 1%); NLL {nll64:.3} vs {nll32:.3}",
        100.0 * rmse_rel_diff
    );
    println!(
        "kernel bytes: f64 {} vs f32 {} (factored Kron form)",
        fit64.kernel_bytes, fit32.kernel_bytes
    );

    // machine-readable summary (the acceptance artifacts)
    let doc = Json::obj(vec![
        ("bench", Json::Str("bench_precision".to_string())),
        ("cores", Json::Num(cores() as f64)),
        ("smoke", Json::Bool(smoke)),
        ("threads", Json::Num(lkgp::par::num_threads() as f64)),
        ("micro", b.to_json()),
        (
            "speedups_f32_over_f64",
            Json::obj(vec![
                ("mvm", Json::Num(mvm_speedup)),
                ("mvm_ge_1p5x", Json::Bool(mvm_speedup >= 1.5)),
                ("gemm", Json::Num(gemm_speedup)),
                ("cg", Json::Num(cg_speedup)),
                ("fit", Json::Num(fit_speedup)),
            ]),
        ),
        (
            "fig3_accuracy",
            Json::obj(vec![
                ("dataset", Json::Str("sim-SARCOS p=96 q=7 missing=0.3".to_string())),
                ("test_rmse_f64", Json::Num(rmse64)),
                ("test_rmse_f32", Json::Num(rmse32)),
                ("rmse_rel_diff", Json::Num(rmse_rel_diff)),
                ("within_1pct", Json::Bool(rmse_rel_diff <= 0.01)),
                ("test_nll_f64", Json::Num(nll64)),
                ("test_nll_f32", Json::Num(nll32)),
                ("fit_secs_f64", Json::Num(secs64)),
                ("fit_secs_f32", Json::Num(secs32)),
                ("kernel_bytes_f64", Json::Num(fit64.kernel_bytes as f64)),
                ("kernel_bytes_f32", Json::Num(fit32.kernel_bytes as f64)),
            ]),
        ),
    ]);
    let _ = std::fs::write("BENCH_precision.json", format!("{doc}\n"));
    b.save_csv("bench_precision");
    b.save_json("bench_precision");
    println!("\nwrote BENCH_precision.json + results/bench/bench_precision.{{csv,json}}");
}
