//! Bench: the FFT/Toeplitz time-factor fast path vs the dense
//! `K_TT` half-GEMM it replaces.
//!
//! Gates emitted to `BENCH_toeplitz.json` (checked by
//! `scripts/check_bench.py` in the CI `bench-smoke` job):
//!
//! * `toeplitz.mvm_speedup_ge_2x` — at q = 4096 the O(q log q)
//!   circulant-embedding MVM must beat the dense O(q^2) half-GEMM by at
//!   least 2x (the asymptotic claim holds even at smoke sizes, so the
//!   q stays 4096 in smoke mode);
//! * `toeplitz.bit_identical_threads` — a Toeplitz-path
//!   `KronOp::apply_batch` produces identical bits at 1 and 4 worker
//!   threads (fixed butterfly order, one column per steal task).
//!
//! `LKGP_BENCH_SMOKE=1` shrinks repetition counts, not the gate shape.

use lkgp::kernels::RbfArd;
use lkgp::kron::toeplitz::ToeplitzOp;
use lkgp::kron::KronOp;
use lkgp::linalg::gemm::matmul_nt;
use lkgp::linalg::Matrix;
use lkgp::par::with_threads;
use lkgp::util::bench::{black_box, Bencher};
use lkgp::util::json::Json;
use lkgp::util::rng::Rng;

fn toeplitz_col(q: usize, ell: f64) -> Vec<f64> {
    (0..q).map(|lag| (-0.5 * (lag as f64 / ell).powi(2)).exp()).collect()
}

fn main() {
    let smoke = std::env::var("LKGP_BENCH_SMOKE").ok().as_deref() == Some("1");
    let mut b = if smoke { Bencher::quick() } else { Bencher::default() };
    let mut rng = Rng::new(11);
    println!("# bench_toeplitz — FFT time factor vs dense half-GEMM (smoke: {smoke})\n");

    // ---- section 1: the headline MVM crossover at q = 4096 ----
    // The dense comparator is exactly the production dense path's K_TT
    // half: one `V @ K_TT^T` GEMM over the batch rows.
    let q = 4096usize;
    let rows = 4usize;
    let col = toeplitz_col(q, 64.0);
    let top = ToeplitzOp::new(&col);
    let ktt = Matrix::from_fn(q, q, |i, j| col[i.abs_diff(j)]);
    let v = Matrix::from_vec(rows, q, rng.normals(rows * q));

    let dense_secs = b
        .bench(&format!("dense half-GEMM q={q} rows={rows}"), || {
            black_box(matmul_nt(&v, &ktt));
        })
        .secs();
    let toep_secs = b
        .bench(&format!("toeplitz fft q={q} rows={rows} (m={})", top.embed_len()), || {
            let mut out = vec![0.0f64; q];
            for r in 0..rows {
                top.matvec_into(v.row(r), &mut out);
                black_box(&out);
            }
        })
        .secs();
    let mvm_speedup = dense_secs / toep_secs.max(1e-12);

    // agreement sanity: FFT rounding differs from GEMM rounding, so the
    // two paths match to tolerance, never bit-for-bit
    let want = matmul_nt(&v, &ktt);
    let mut max_abs_diff = 0.0f64;
    let mut out = vec![0.0f64; q];
    for r in 0..rows {
        top.matvec_into(v.row(r), &mut out);
        for (a, w) in out.iter().zip(want.row(r)) {
            max_abs_diff = max_abs_diff.max((a - w).abs());
        }
    }

    // ---- section 2: thread-count bit-invariance of the full Kron op ----
    // Ragged sizes on purpose: 7 spatial points x 257 time steps leaves
    // uneven steal chunks at every thread count.
    let (bp, bq) = (7usize, 257usize);
    let bcol = toeplitz_col(bq, 16.0);
    let bktt = Matrix::from_fn(bq, bq, |i, j| bcol[i.abs_diff(j)]);
    let s = Matrix::from_vec(bp, 2, rng.normals(bp * 2));
    let kss = RbfArd::new(2).gram(&s, &s);
    let fast = KronOp::new(kss, bktt).with_toeplitz(ToeplitzOp::new(&bcol));
    let bv = Matrix::from_vec(3, bp * bq, rng.normals(3 * bp * bq));
    let a1 = with_threads(1, || fast.apply_batch(&bv));
    let a4 = with_threads(4, || fast.apply_batch(&bv));
    let bit_identical_threads = a1
        .data
        .iter()
        .zip(&a4.data)
        .all(|(x, y)| x.to_bits() == y.to_bits());

    println!(
        "\nq={q}: dense {:.3}ms vs toeplitz {:.3}ms ({mvm_speedup:.1}x, max |diff| {max_abs_diff:.2e}); \
         threads 1 vs 4 bit-identical: {bit_identical_threads}",
        dense_secs * 1e3,
        toep_secs * 1e3
    );

    let doc = Json::obj(vec![
        ("bench", Json::Str("bench_toeplitz".to_string())),
        ("smoke", Json::Bool(smoke)),
        (
            "toeplitz",
            Json::obj(vec![
                ("q", Json::Num(q as f64)),
                ("embed_len", Json::Num(top.embed_len() as f64)),
                ("batch_rows", Json::Num(rows as f64)),
                ("secs_dense", Json::Num(dense_secs)),
                ("secs_toeplitz", Json::Num(toep_secs)),
                ("mvm_speedup", Json::Num(mvm_speedup)),
                ("mvm_speedup_ge_2x", Json::Bool(mvm_speedup >= 2.0)),
                ("max_abs_diff_vs_dense", Json::Num(max_abs_diff)),
                ("bit_identical_threads", Json::Bool(bit_identical_threads)),
            ]),
        ),
    ]);
    let _ = std::fs::write("BENCH_toeplitz.json", format!("{doc}\n"));
    b.save_csv("bench_toeplitz");
    b.save_json("bench_toeplitz");
    println!("\nwrote BENCH_toeplitz.json + results/bench/bench_toeplitz.{{csv,json}}");
}
