//! Bench: the Table-2 climate workload — LKGP cost across missing
//! ratios (the paper's observation that LKGP gets *cheaper* with more
//! missing data while approximate baselines do not benefit as much),
//! plus the PJRT-backend variant when artifacts are available.

use lkgp::data::climate::ClimateSim;
use lkgp::gp::lkgp::{Backend, Lkgp, LkgpConfig};
use lkgp::runtime::Manifest;
use lkgp::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::quick();
    println!("# bench_table2 — LKGP on sim-climate across missing ratios\n");
    let cfg = LkgpConfig {
        train_iters: 4,
        n_samples: 8,
        probes: 4,
        ..LkgpConfig::default()
    };
    for ratio in [0.1, 0.3, 0.5] {
        let data = ClimateSim::default_temperature(64, 48, ratio, 0);
        b.bench(&format!("lkgp/rust climate missing={ratio}"), || {
            black_box(Lkgp::fit(&data, cfg.clone()).unwrap());
        });
    }
    // PJRT path on the tiny artifact config (kernel family must match
    // the artifact: tiny is plain rbf, so use a well-specified grid)
    if Manifest::default_dir().join("manifest.json").exists() {
        let man = Manifest::load(&Manifest::default_dir()).unwrap();
        if let Ok(c) = man.config("tiny") {
            let kernel = lkgp::kernels::ProductGridKernel::new(c.ds, &c.kernel_t, c.q);
            let data = lkgp::data::synthetic::well_specified(
                c.p, c.q, c.ds, &kernel, 0.05, 0.3, 0,
            );
            let mut cfg_p = cfg.clone();
            cfg_p.backend = Backend::Pjrt { config: "tiny".into() };
            cfg_p.probes = c.probes;
            b.bench("lkgp/pjrt tiny-config grid", || {
                black_box(Lkgp::fit(&data, cfg_p.clone()).unwrap());
            });
        }
    }
    b.save_csv("bench_table2");
}
