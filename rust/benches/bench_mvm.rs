//! Bench: MVM primitives — the Figure-2 companion.
//!
//! Dense n x n MVM vs latent-Kronecker MVM (rust backend) vs the
//! AOT Pallas kron_mvm artifact on the PJRT client, with GFLOP/s and
//! the worker-thread count in every row. Machine-readable JSON lands
//! next to the CSV under results/bench/.

use lkgp::kron::{breakeven, KronOp, MaskedKronSystem};
use lkgp::linalg::gemm::gemm_flops;
use lkgp::linalg::Matrix;
use lkgp::par;
use lkgp::runtime::{Manifest, Runtime, TensorF32};
use lkgp::util::bench::{black_box, Bencher};
use lkgp::util::rng::Rng;

fn main() {
    let mut b = Bencher::default();
    let mut rng = Rng::new(0);
    println!(
        "# bench_mvm — dense vs latent-Kronecker MVM (Fig. 2) [threads={}]\n",
        par::num_threads()
    );

    for (p, q) in [(64usize, 16usize), (128, 32), (256, 64), (512, 96)] {
        let n = p * q;
        let kss = {
            let a = Matrix::from_vec(p, 3, rng.normals(p * 3));
            lkgp::kernels::RbfArd::new(3).gram(&a, &a)
        };
        let ktt = {
            let a = Matrix::from_vec(q, 1, rng.normals(q));
            lkgp::kernels::RbfArd::new(1).gram(&a, &a)
        };
        let sys = MaskedKronSystem::new(KronOp::new(kss, ktt), vec![1.0; n], 0.1);
        let v = Matrix::from_vec(1, n, rng.normals(n));
        b.bench_with_flops(
            &format!("kron_mvm/rust p={p} q={q} (n={n})"),
            Some(breakeven::kron_mvm_flops(p, q)),
            || {
                black_box(sys.apply_batch(&v));
            },
        );
        // the K_SS-side GEMM underneath the Kron MVM, via gemm_flops
        let t1 = Matrix::from_vec(p, q, rng.normals(p * q));
        b.bench_with_flops(
            &format!("gemm/rust {p}x{p}x{q}"),
            Some(gemm_flops(p, p, q)),
            || {
                black_box(sys.op.kss.matmul(&t1));
            },
        );
        if n <= 16384 {
            let dense = sys.op.dense();
            b.bench_with_flops(
                &format!("dense_mvm/rust n={n}"),
                Some(breakeven::dense_mvm_flops(n)),
                || {
                    black_box(dense.matvec(v.row(0)));
                },
            );
        }
    }

    // PJRT artifact path (batched), if artifacts are present
    if Manifest::default_dir().join("manifest.json").exists() {
        let mut rt = Runtime::load_default().unwrap();
        for cfg_name in ["tiny", "lcbench", "climate"] {
            let cfg = rt.manifest.config(cfg_name).unwrap().clone();
            let (p, q, bsz) = (cfg.p, cfg.q, cfg.batch);
            let pq = p * q;
            let inputs = [
                TensorF32::new(vec![p, p], vec![0.1; p * p]),
                TensorF32::new(vec![q, q], vec![0.1; q * q]),
                TensorF32::vec1(vec![1.0; pq]),
                TensorF32::scalar(0.1),
                TensorF32::new(vec![bsz, pq], vec![0.5; bsz * pq]),
            ];
            rt.exec_f32(cfg_name, "kron_mvm", &inputs).unwrap(); // compile
            b.bench_with_flops(
                &format!("kron_mvm/pjrt {cfg_name} (batch {bsz})"),
                Some(bsz as f64 * breakeven::kron_mvm_flops(p, q)),
                || {
                    black_box(rt.exec_f32(cfg_name, "kron_mvm", &inputs).unwrap());
                },
            );
        }
    } else {
        println!("(artifacts not built; skipping PJRT series)");
    }
    b.save_csv("bench_mvm");
    b.save_json("bench_mvm");
}
