//! Bench: the Table-1 model set on one sim-LCBench dataset — per-model
//! fit+predict wall time (the paper's "Time in min" rows, scaled).

use lkgp::baselines::{BaselineModel, CaGp, Svgp, Vnngp};
use lkgp::data::lcbench::LcBenchSim;
use lkgp::gp::lkgp::{Lkgp, LkgpConfig};
use lkgp::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::quick();
    println!("# bench_table1 — per-model cost on sim-LCBench (p=48, q=52)\n");
    let data = LcBenchSim::new(48, 52, 0).generate();
    let cfg = LkgpConfig {
        train_iters: 5,
        n_samples: 8,
        probes: 4,
        ..LkgpConfig::default()
    };
    b.bench("LKGP fit+predict", || {
        black_box(Lkgp::fit(&data, cfg.clone()).unwrap());
    });
    b.bench("SVGP fit+predict", || {
        black_box(Svgp::new(64, 3, 0).fit_predict(&data).unwrap());
    });
    b.bench("VNNGP fit+predict", || {
        black_box(Vnngp::new(16, 3, 0).fit_predict(&data).unwrap());
    });
    b.bench("CaGP fit+predict", || {
        black_box(CaGp::new(32, 3, 0).fit_predict(&data).unwrap());
    });
    b.save_csv("bench_table1");
}
