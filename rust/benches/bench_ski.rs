//! Bench: SKI (sparse-kernel-interpolation) training vs a dense exact
//! GP on the same off-grid regression sample.
//!
//! Gates emitted to `BENCH_ski.json` (checked by
//! `scripts/check_bench.py` in the CI `bench-smoke` job):
//!
//! * `ski.rmse_within_5pct_of_dense` — held-out RMSE of the SKI fit is
//!   within 5% of the dense exact-GP baseline (`rmse_ski <= 1.05 *
//!   rmse_dense`), so the structured approximation costs essentially no
//!   accuracy on a smooth surface;
//! * `ski.fit_speedup_ge_2x` — the SKI fit (CG in data space, Kronecker
//!   MVMs through the sparse projection) beats the O(n^3) dense
//!   Cholesky fit by at least 2x end to end;
//! * `ski.bit_identical_threads` — the full SKI fit posterior is
//!   bit-identical at 1 and 4 worker threads.
//!
//! `LKGP_BENCH_SMOKE=1` shrinks n (and training iterations), not the
//! gate shape: the asymptotic O(n^3) vs O(n + pq(p+q)) gap holds at
//! smoke sizes too.

use lkgp::data::synthetic::off_grid;
use lkgp::gp::diagnostics::ProjectionChoice;
use lkgp::gp::lkgp::{Lkgp, LkgpConfig, LkgpFit};
use lkgp::kernels::RbfArd;
use lkgp::kron::interp::{InterpDegree, SparseProjection};
use lkgp::linalg::{cholesky, Matrix};
use lkgp::par::with_threads;
use lkgp::util::json::Json;

fn rmse(pred: &[f64], want: &[f64]) -> f64 {
    let mut sq = 0.0;
    for (p, w) in pred.iter().zip(want) {
        sq += (p - w) * (p - w);
    }
    (sq / want.len().max(1) as f64).sqrt()
}

/// Dense exact GP on the scattered points: assemble the full n x n
/// Gram, Cholesky-factor `K + sigma2 I`, solve for the representer
/// weights, and predict at the test points through the cross-Gram.
/// Returns (test predictions, wall seconds for the whole fit+predict).
fn dense_exact_gp(
    xs: &[f64],
    xt: &[f64],
    y: &[f64],
    test_xs: &[f64],
    test_xt: &[f64],
    sigma2: f64,
) -> (Vec<f64>, f64) {
    let n = y.len();
    let pack = |a: &[f64], b: &[f64]| {
        let mut data = Vec::with_capacity(2 * a.len());
        for i in 0..a.len() {
            data.push(a[i]);
            data.push(b[i]);
        }
        Matrix::from_vec(a.len(), 2, data)
    };
    let xtrain = pack(xs, xt);
    let xtest = pack(test_xs, test_xt);
    // well-specified-ish hypers for the unit square: lengthscale 0.25
    // per dimension, unit outputscale
    let mut kernel = RbfArd::new(2);
    kernel.log_ls = vec![0.25f64.ln(); 2];
    let ym = y.iter().sum::<f64>() / n as f64;
    let yc: Vec<f64> = y.iter().map(|v| v - ym).collect();
    let t0 = std::time::Instant::now();
    let mut k = kernel.gram(&xtrain, &xtrain);
    k.add_diag(sigma2);
    let ch = cholesky(&k).expect("dense Gram not PD");
    let alpha = ch.solve(&yc);
    let kx = kernel.gram(&xtest, &xtrain);
    let pred: Vec<f64> = kx.matvec(&alpha).iter().map(|v| v + ym).collect();
    (pred, t0.elapsed().as_secs_f64())
}

fn ski_cfg(train_iters: usize) -> LkgpConfig {
    LkgpConfig {
        train_iters,
        n_samples: 8,
        probes: 4,
        cg_tol: 1e-3,
        cg_max_iters: 400,
        seed: 17,
        projection: ProjectionChoice::Interp(InterpDegree::Cubic),
        ..LkgpConfig::default()
    }
}

fn posterior_bits(fit: &LkgpFit) -> Vec<u64> {
    let mut out: Vec<u64> = fit.posterior.mean.iter().map(|x| x.to_bits()).collect();
    out.extend(fit.posterior.var.iter().map(|x| x.to_bits()));
    out
}

fn main() {
    let smoke = std::env::var("LKGP_BENCH_SMOKE").ok().as_deref() == Some("1");
    // full scale: n ~ 4k scattered points on a 64 x 64 inducing grid;
    // smoke shrinks n so the O(n^3) dense baseline stays CI-friendly
    let (n, n_test, p, q, iters) =
        if smoke { (1536usize, 384usize, 40usize, 40usize, 4usize) } else { (4096, 1024, 64, 64, 8) };
    let sigma2 = 0.02;
    println!("# bench_ski — SKI projection vs dense exact GP (smoke: {smoke})\n");
    let data = off_grid(n, n_test, p, q, sigma2, 17);

    // ---- dense exact-GP baseline ----
    let (dense_pred, dense_secs) = dense_exact_gp(
        &data.xs,
        &data.xt,
        &data.y,
        &data.test_xs,
        &data.test_xt,
        sigma2,
    );
    let rmse_dense = rmse(&dense_pred, &data.test_y);
    println!("dense exact GP: n={n} fit+predict {:.3}s, test rmse {rmse_dense:.4}", dense_secs);

    // ---- SKI fit + test-point prediction ----
    let t0 = std::time::Instant::now();
    let fit = Lkgp::fit_offgrid(&data, ski_cfg(iters)).expect("SKI fit");
    let wq = SparseProjection::build(
        &data.test_xs,
        &data.test_xt,
        &data.grid_s,
        &data.grid_t,
        InterpDegree::Cubic,
    )
    .expect("test-point projection");
    let mean_grid = Matrix::from_vec(1, fit.posterior.mean.len(), fit.posterior.mean.clone());
    let ski_pred = wq.interp_apply(&mean_grid);
    let ski_secs = t0.elapsed().as_secs_f64();
    let rmse_ski = rmse(ski_pred.row(0), &data.test_y);
    let fit_speedup = dense_secs / ski_secs.max(1e-12);
    println!(
        "SKI (cubic, {p}x{q} grid): fit+predict {:.3}s ({fit_speedup:.1}x), test rmse {rmse_ski:.4}",
        ski_secs
    );

    // ---- thread-count bit-invariance of the full SKI fit ----
    let f1 = with_threads(1, || Lkgp::fit_offgrid(&data, ski_cfg(iters)).expect("t=1 fit"));
    let f4 = with_threads(4, || Lkgp::fit_offgrid(&data, ski_cfg(iters)).expect("t=4 fit"));
    let bit_identical_threads = posterior_bits(&f1) == posterior_bits(&f4);
    println!("threads 1 vs 4 bit-identical: {bit_identical_threads}");

    let rmse_ratio = rmse_ski / rmse_dense.max(1e-12);
    let doc = Json::obj(vec![
        ("bench", Json::Str("bench_ski".to_string())),
        ("smoke", Json::Bool(smoke)),
        (
            "ski",
            Json::obj(vec![
                ("n", Json::Num(n as f64)),
                ("n_test", Json::Num(n_test as f64)),
                ("p", Json::Num(p as f64)),
                ("q", Json::Num(q as f64)),
                ("degree", Json::Str("cubic".to_string())),
                ("rmse_dense", Json::Num(rmse_dense)),
                ("rmse_ski", Json::Num(rmse_ski)),
                ("rmse_ratio", Json::Num(rmse_ratio)),
                ("rmse_within_5pct_of_dense", Json::Bool(rmse_ratio <= 1.05)),
                ("secs_dense_fit", Json::Num(dense_secs)),
                ("secs_ski_fit", Json::Num(ski_secs)),
                ("fit_speedup", Json::Num(fit_speedup)),
                ("fit_speedup_ge_2x", Json::Bool(fit_speedup >= 2.0)),
                ("bit_identical_threads", Json::Bool(bit_identical_threads)),
            ]),
        ),
    ]);
    let _ = std::fs::write("BENCH_ski.json", format!("{doc}\n"));
    println!("\nwrote BENCH_ski.json");
}
