//! Precision-aware numerics harness: every property runs in **both**
//! compute precisions with per-precision tolerances stated at the call
//! site (`util::testing::assert_close_prec`). The f64 bounds pin the
//! solver-tolerance-limited regime; the f32 bounds document the
//! accuracy contract of the `Precision::F32` hot path (compute in f32,
//! accumulate in f64 — see `gp::backend::Precision`).
//!
//! Also hosts the golden posterior regression: a fixed-seed
//! quickstart-sized fit whose f64 posterior must match checked-in bits
//! exactly (thread-count invariance makes this deterministic on a given
//! toolchain/libm) and whose f32 posterior must stay within the
//! documented tolerance of the same golden values.

use std::path::{Path, PathBuf};

use lkgp::data::synthetic::{kron_gp_draw, well_specified};
use lkgp::data::GridDataset;
use lkgp::util::rng::Rng;
use lkgp::gp::backend::Precision;
use lkgp::gp::lkgp::{Lkgp, LkgpConfig};
use lkgp::kernels::ProductGridKernel;
use lkgp::kron::{KronOp, MaskedKronSystem};
use lkgp::linalg::{cholesky, Matrix, Scalar};
use lkgp::solvers::cg::{solve_cg, CgOptions, DenseOp};
use lkgp::solvers::precond::Preconditioner;
use lkgp::util::json::Json;
use lkgp::util::testing::{assert_close_prec, prec_tol, prop_check};

// ---------------------------------------------------------------------
// Kron MVM vs dense reference
// ---------------------------------------------------------------------

fn kron_mvm_matches_dense<T: Scalar>() {
    prop_check(&format!("kron-mvm-dense-{}", T::NAME), 3101, 12, |g| {
        let (p, q, b) = (g.size(1, 9), g.size(1, 9), g.size(1, 3));
        let kss64 = Matrix::from_vec(p, p, g.spd(p));
        let ktt64 = Matrix::from_vec(q, q, g.spd(q));
        let v64 = Matrix::from_vec(b, p * q, g.vec_normal(b * p * q));
        let op: KronOp<T> = KronOp::new(kss64.cast(), ktt64.cast());
        let v: Matrix<T> = v64.cast();
        let got = op.apply_batch(&v);
        // reference: unrounded f64 dense Kronecker product
        let dense = KronOp::new(kss64, ktt64).dense();
        let mut want = Vec::with_capacity(b * p * q);
        for bi in 0..b {
            want.extend(dense.matvec(v64.row(bi)));
        }
        assert_close_prec(&got.data, &want, 1e-8, 1e-3)
    });
}

#[test]
fn prop_kron_mvm_matches_dense_f64() {
    kron_mvm_matches_dense::<f64>();
}

#[test]
fn prop_kron_mvm_matches_dense_f32() {
    kron_mvm_matches_dense::<f32>();
}

// ---------------------------------------------------------------------
// Masked projection identity: P (K_SS (x) K_TT) P^T == gathered Gram
// ---------------------------------------------------------------------

fn masked_projection_identity<T: Scalar>() {
    prop_check(&format!("masked-projection-{}", T::NAME), 3307, 8, |g| {
        let (p, q) = (g.size(1, 7), g.size(1, 7));
        let n = p * q;
        let kss64 = Matrix::from_vec(p, p, g.spd(p));
        let ktt64 = Matrix::from_vec(q, q, g.spd(q));
        let mask = g.mask(n, 0.4);
        let mask_t: Vec<T> = mask.iter().map(|&m| T::from_f64(m)).collect();
        // sigma2 = 0 so the operator is exactly M (K (x) K) M
        let sys: MaskedKronSystem<T> =
            MaskedKronSystem::new(KronOp::new(kss64.cast(), ktt64.cast()), mask_t, T::ZERO);
        let dense = KronOp::new(kss64, ktt64).dense();
        let obs: Vec<usize> = (0..n).filter(|&i| mask[i] != 0.0).collect();
        for &cidx in &obs {
            let mut e = Matrix::<T>::zeros(1, n);
            e[(0, cidx)] = T::ONE;
            let col = sys.apply_batch(&e);
            // observed rows reproduce the gathered dense Gram column
            let got: Vec<T> = obs.iter().map(|&r| col[(0, r)]).collect();
            let want: Vec<f64> = obs.iter().map(|&r| dense[(r, cidx)]).collect();
            assert_close_prec(&got, &want, 1e-8, 1e-3)?;
            // missing rows stay exactly zero (projection, not damping)
            for i in 0..n {
                if mask[i] == 0.0 && col[(0, i)].to_f64() != 0.0 {
                    return Err(format!("leaked into missing coord {i}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_masked_projection_identity_f64() {
    masked_projection_identity::<f64>();
}

#[test]
fn prop_masked_projection_identity_f32() {
    masked_projection_identity::<f32>();
}

// ---------------------------------------------------------------------
// CG residual bound (verified independently in f64)
// ---------------------------------------------------------------------

fn cg_solution_meets_residual_bound<T: Scalar>() {
    prop_check(&format!("cg-residual-{}", T::NAME), 3511, 10, |g| {
        let n = g.size(2, 24);
        let a64 = Matrix::from_vec(n, n, g.spd(n));
        let a: Matrix<T> = a64.cast();
        let b64 = Matrix::from_vec(2, n, g.vec_normal(2 * n));
        let b: Matrix<T> = b64.cast();
        let tol = prec_tol::<T>(1e-8, 1e-4);
        let (x, stats) = solve_cg(
            &mut DenseOp(&a),
            &b,
            &Preconditioner::Identity,
            &CgOptions { max_iters: 30 * n, tol, ..CgOptions::default() },
        );
        if !stats.converged {
            return Err(format!("not converged: {:?}", stats.rel_residuals));
        }
        // verify the claimed residual with an independent f64 recompute
        // on the same (rounded) operator — CG's recursive residual must
        // not have drifted past a small multiple of the tolerance
        let a_check: Matrix<f64> = a.cast();
        for sys in 0..2 {
            let xr: Vec<f64> = x.row(sys).iter().map(|v| v.to_f64()).collect();
            let ax = a_check.matvec(&xr);
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for (got, want) in ax.iter().zip(b64.row(sys)) {
                num += (got - want) * (got - want);
                den += want * want;
            }
            let rel = num.sqrt() / den.sqrt().max(1e-300);
            if rel > 10.0 * tol {
                return Err(format!("system {sys}: true residual {rel} > 10*{tol}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cg_residual_bound_f64() {
    cg_solution_meets_residual_bound::<f64>();
}

#[test]
fn prop_cg_residual_bound_f32() {
    cg_solution_meets_residual_bound::<f32>();
}

// ---------------------------------------------------------------------
// Pivoted-Cholesky preconditioner: SPD + Woodbury-apply consistency
// ---------------------------------------------------------------------

fn precond_spd_and_woodbury_consistent<T: Scalar>() {
    prop_check(&format!("precond-woodbury-{}", T::NAME), 3709, 8, |g| {
        let n = g.size(2, 16);
        let a64 = Matrix::from_vec(n, n, g.spd(n));
        let a: Matrix<T> = a64.cast();
        let sigma2 = g.f64_in(0.2, 1.5);
        let diag: Vec<f64> = (0..n).map(|i| a64[(i, i)]).collect();
        // full-rank lazy pivoted Cholesky => M = A + sigma2 I (+ rounding)
        let pre =
            Preconditioner::<T>::pivoted_from_columns(diag, |j| a.col(j), n, sigma2);
        let rhs64 = Matrix::from_vec(2, n, g.vec_normal(2 * n));
        let rhs: Matrix<T> = rhs64.cast();
        let got = pre.apply_batch(&rhs);
        // f64 reference inverse of the unrounded M
        let mut m = a64.clone();
        m.add_diag(sigma2);
        let ch = cholesky(&m).ok_or("M not PD")?;
        for sys in 0..2 {
            let want = ch.solve(rhs64.row(sys));
            assert_close_prec(got.row(sys), &want, 1e-5, 2e-2)?;
        }
        // SPD of the Woodbury apply, accumulated in f64:
        // z^T M^{-1} z > 0 and u^T M^{-1} v == v^T M^{-1} u
        let quad = |u: &[T], mv: &[T]| -> f64 {
            u.iter().zip(mv).map(|(a, b)| a.to_f64() * b.to_f64()).sum()
        };
        let z_quad = quad(rhs.row(0), got.row(0));
        if z_quad <= 0.0 {
            return Err(format!("z^T M^-1 z = {z_quad} not positive"));
        }
        let asym = quad(rhs.row(0), got.row(1)) - quad(rhs.row(1), got.row(0));
        let scale = z_quad.abs().max(1.0);
        if asym.abs() > prec_tol::<T>(1e-8, 1e-3) * scale {
            return Err(format!("Woodbury apply not symmetric: {asym}"));
        }
        Ok(())
    });
}

#[test]
fn prop_precond_spd_woodbury_f64() {
    precond_spd_and_woodbury_consistent::<f64>();
}

#[test]
fn prop_precond_spd_woodbury_f32() {
    precond_spd_and_woodbury_consistent::<f32>();
}

// ---------------------------------------------------------------------
// SKI differential test: mask == W in the degenerate case
// ---------------------------------------------------------------------

/// A fully-observed ds=1 dataset whose spatial inputs sit exactly on
/// the strictly-increasing linspace nodes the SKI projection induces —
/// the degenerate case where a linear stencil collapses to a 0/1 mask.
fn coincident_data(p: usize, q: usize, seed: u64) -> GridDataset {
    let kernel = ProductGridKernel::new(1, "rbf", q);
    let s_nodes: Vec<f64> = (0..p).map(|j| j as f64 / (p - 1) as f64).collect();
    let s = Matrix::from_vec(p, 1, s_nodes);
    let t: Vec<f64> = (0..q).map(|k| k as f64 / (q - 1) as f64).collect();
    let kss = kernel.gram_s(&s);
    let ktt = kernel.gram_t(&t);
    let mut rng = Rng::new(seed);
    let y = kron_gp_draw(&kss, &ktt, 0.01, &mut rng);
    let data = GridDataset {
        s,
        t,
        y_grid: y,
        mask: vec![true; p * q],
        time_family: "rbf".to_string(),
        name: "coincident".to_string(),
    };
    data.validate();
    data
}

/// Differential test for the SKI projection layer: on grid-coincident,
/// fully-observed data the linear interpolation matrix `W` degenerates
/// to the identity permutation (every row a single 1.0), so an interp
/// fit must reproduce the mask fit **bit for bit** — posterior mean and
/// variance, loss trace, CG iteration counts, and the captured pathwise
/// tensors. `Solver::Cg` is forced in BOTH configs because the fully
/// observed mask path would otherwise take the eigendecomposition
/// direct solve, which the interp system (data space, no Gram factors)
/// never does.
#[test]
fn interp_on_grid_coincident_data_matches_mask_bitwise() {
    use lkgp::gp::diagnostics::{ProjectionChoice, ProjectionPath, Solver};
    use lkgp::kron::interp::InterpDegree;

    let data = coincident_data(10, 7, 77);
    let base = LkgpConfig {
        train_iters: 5,
        n_samples: 8,
        probes: 4,
        cg_tol: 1e-3,
        cg_max_iters: 200,
        seed: 7,
        solver: Solver::Cg,
        capture_pathwise: true,
        ..LkgpConfig::default()
    };
    let mask_fit = Lkgp::fit(&data, base.clone()).unwrap();
    let interp_fit = Lkgp::fit(
        &data,
        LkgpConfig { projection: ProjectionChoice::Interp(InterpDegree::Linear), ..base },
    )
    .unwrap();

    assert_eq!(mask_fit.diagnostics.projection, ProjectionPath::Mask);
    assert_eq!(
        interp_fit.diagnostics.projection,
        ProjectionPath::Interp(InterpDegree::Linear)
    );

    // The W record really is a 0/1 mask: one unit entry per row.
    let im = interp_fit.model.as_ref().unwrap();
    let w = im.w.as_ref().expect("interp fit must carry its W record");
    assert_eq!(w.n(), data.grid_len());
    for r in 0..w.n() {
        let (cols, weights) = w.row(r);
        assert_eq!(cols.len(), 1, "row {r} not degenerate: {cols:?} {weights:?}");
        assert_eq!(weights[0].to_bits(), 1.0f64.to_bits(), "row {r} weight");
    }

    // Training trajectory: identical loss trace and CG work.
    assert_eq!(mask_fit.loss_trace.len(), interp_fit.loss_trace.len());
    for (i, (a, b)) in mask_fit.loss_trace.iter().zip(&interp_fit.loss_trace).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "loss_trace[{i}]: {a} vs {b}");
    }
    assert_eq!(mask_fit.cg_iters_total, interp_fit.cg_iters_total, "CG iteration counters");

    // Posterior: bit-identical mean and variance on every grid cell.
    for i in 0..data.grid_len() {
        assert_eq!(
            mask_fit.posterior.mean[i].to_bits(),
            interp_fit.posterior.mean[i].to_bits(),
            "posterior mean[{i}]: {} vs {}",
            mask_fit.posterior.mean[i],
            interp_fit.posterior.mean[i]
        );
        assert_eq!(
            mask_fit.posterior.var[i].to_bits(),
            interp_fit.posterior.var[i].to_bits(),
            "posterior var[{i}]: {} vs {}",
            mask_fit.posterior.var[i],
            interp_fit.posterior.var[i]
        );
    }

    // Captured pathwise state: the interp fit's grid-space tensors
    // (W^T folded in) equal the mask fit's masked tensors bitwise.
    let mm = mask_fit.model.as_ref().unwrap();
    assert_eq!(mm.theta.len(), im.theta.len());
    for (i, (a, b)) in mm.theta.iter().zip(&im.theta).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "theta[{i}]");
    }
    assert_eq!(mm.log_sigma2.to_bits(), im.log_sigma2.to_bits(), "log_sigma2");
    for (i, (a, b)) in mm.masked_alpha.iter().zip(&im.masked_alpha).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "masked_alpha[{i}]");
    }
    assert_eq!((mm.vm.rows, mm.vm.cols), (im.vm.rows, im.vm.cols));
    for (i, (a, b)) in mm.vm.data.iter().zip(&im.vm.data).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "vm[{i}]");
    }
    for (i, (a, b)) in mm.f_prior.data.iter().zip(&im.f_prior.data).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "f_prior[{i}]");
    }
}

// ---------------------------------------------------------------------
// Golden posterior regression
// ---------------------------------------------------------------------

fn golden_data() -> GridDataset {
    let kernel = ProductGridKernel::new(2, "rbf", 8);
    well_specified(24, 8, 2, &kernel, 0.01, 0.3, 42)
}

fn golden_cfg(precision: Precision) -> LkgpConfig {
    LkgpConfig {
        train_iters: 8,
        // gentle steps keep the f32/f64 Adam trajectories glued, so the
        // cross-precision comparison measures numerics, not optimizer
        // bifurcation on near-zero gradient components
        lr: 0.02,
        n_samples: 16,
        probes: 4,
        cg_tol: 1e-3,
        cg_max_iters: 200,
        precond_rank: 16, // exercise the pivoted-Cholesky path
        seed: 42,
        precision,
        ..LkgpConfig::default()
    }
}

fn golden_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/posterior_f64.json")
}

fn bits_hex(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Str(format!("{:016x}", x.to_bits()))).collect())
}

fn read_bits(doc: &Json, key: &str) -> Vec<f64> {
    doc.get(key)
        .unwrap_or_else(|| panic!("golden file missing key {key:?}"))
        .as_arr()
        .expect("golden key not an array")
        .iter()
        .map(|j| {
            let s = j.as_str().expect("golden entry not a hex string");
            f64::from_bits(u64::from_str_radix(s, 16).expect("bad hex"))
        })
        .collect()
}

/// Fixed-seed quickstart-sized fit vs checked-in golden posterior.
///
/// * f64: **exact bit match**. Everything on the path is deterministic
///   and thread-count invariant, so any drift means a numerics change —
///   rebless deliberately with `LKGP_BLESS=1 cargo test golden` after
///   auditing it. (The golden bits are tied to the build's libm; a
///   toolchain/platform change may also require reblessing.)
/// * f32: every posterior-mean cell within 5% of the f64 golden
///   posterior's max-|mean| scale (+0.02 absolute slack), and every
///   variance within 25% relative — the documented accuracy contract
///   of `Precision::F32` at CG tolerance 1e-3.
///
/// On the very first run (no golden file yet) the test writes the file
/// and validates against it, so a fresh checkout self-bootstraps; the
/// blessed file is meant to be committed. CI enforces that: the
/// `build-test` job's "Golden posterior guard" step fails if the file
/// is absent, uncommitted, or was silently re-blessed during the test
/// run (see rust/tests/golden/README.md).
#[test]
fn golden_posterior_regression() {
    let data = golden_data();
    let fit = Lkgp::fit(&data, golden_cfg(Precision::F64)).unwrap();
    let path = golden_path();
    let bless_requested =
        std::env::var("LKGP_BLESS").map(|v| v == "1").unwrap_or(false);
    if bless_requested || !path.exists() {
        let doc = Json::obj(vec![
            (
                "config",
                Json::Str(
                    "well_specified(p=24,q=8,ds=2,rbf,s2=0.01,miss=0.3,seed=42); \
                     train_iters=8 n_samples=16 probes=4 cg_tol=1e-3 precond_rank=16 seed=42"
                        .to_string(),
                ),
            ),
            ("mean_bits", bits_hex(&fit.posterior.mean)),
            ("var_bits", bits_hex(&fit.posterior.var)),
        ]);
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, format!("{doc}\n")).unwrap();
        eprintln!("blessed golden posterior at {path:?}");
    }
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let want_mean = read_bits(&doc, "mean_bits");
    let want_var = read_bits(&doc, "var_bits");
    assert_eq!(fit.posterior.mean.len(), want_mean.len(), "golden shape drift");
    for i in 0..want_mean.len() {
        assert_eq!(
            fit.posterior.mean[i].to_bits(),
            want_mean[i].to_bits(),
            "f64 posterior mean[{i}] drifted: {} vs golden {}",
            fit.posterior.mean[i],
            want_mean[i]
        );
        assert_eq!(
            fit.posterior.var[i].to_bits(),
            want_var[i].to_bits(),
            "f64 posterior var[{i}] drifted: {} vs golden {}",
            fit.posterior.var[i],
            want_var[i]
        );
    }

    // f32 within documented tolerance of the same golden values
    let fit32 = Lkgp::fit(&data, golden_cfg(Precision::F32)).unwrap();
    let scale = want_mean.iter().map(|x| x.abs()).fold(0.0, f64::max).max(1e-6);
    for i in 0..want_mean.len() {
        let dm = (fit32.posterior.mean[i] - want_mean[i]).abs();
        assert!(
            dm < 0.05 * scale + 0.02,
            "f32 mean[{i}] off golden by {dm} (scale {scale})"
        );
        let dv = (fit32.posterior.var[i] - want_var[i]).abs();
        assert!(
            dv < 0.25 * want_var[i].abs() + 1e-8,
            "f32 var[{i}] {} vs golden {}",
            fit32.posterior.var[i],
            want_var[i]
        );
    }
}
