//! End-to-end tests of the `lkgp serve` daemon: the wire path must
//! preserve the engine's determinism contract (grouping and windowing
//! never change output bits), route multiple models, turn every
//! malformed frame into a typed per-connection error while the daemon
//! keeps serving, and shut down cleanly on request.
//!
//! Tests that arm failpoints use `with_failpoints`; every other test
//! wraps its daemon lifetime in `without_failpoints` so the serialized
//! scopes can never leak faults into a concurrently running test (the
//! faults.rs idiom).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use lkgp::data::synthetic::well_specified;
use lkgp::data::GridDataset;
use lkgp::gp::lkgp::{Lkgp, LkgpConfig};
use lkgp::kernels::ProductGridKernel;
use lkgp::model::TrainedModel;
use lkgp::serve::daemon::{DaemonOptions, ServeClient, ServeDaemon};
use lkgp::serve::ServeEngine;
use lkgp::util::failpoint::{with_failpoints, without_failpoints};
use lkgp::util::rng::Rng;
use lkgp::util::wire::{decode_response, encode_request, Request, Response};

fn dataset(seed: u64) -> GridDataset {
    let kernel = ProductGridKernel::new(2, "rbf", 8);
    well_specified(20, 8, 2, &kernel, 0.01, 0.25, seed)
}

fn fitted_model(seed: u64) -> TrainedModel {
    let data = dataset(seed);
    let cfg = LkgpConfig {
        train_iters: 3,
        n_samples: 8,
        probes: 4,
        cg_tol: 1e-3,
        cg_max_iters: 200,
        seed,
        capture_pathwise: true,
        ..LkgpConfig::default()
    };
    Lkgp::fit(&data, cfg).expect("fit").model.expect("capture_pathwise was set")
}

fn start(engines: Vec<(String, ServeEngine)>, window_ms: u64) -> ServeDaemon {
    ServeDaemon::start(
        "127.0.0.1:0",
        engines,
        DaemonOptions { window_ms, ..DaemonOptions::default() },
    )
    .expect("daemon start")
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Frame a payload onto a raw socket (length prefix + bytes), without
/// going through the library's writer.
fn raw_send(s: &mut TcpStream, payload: &[u8]) {
    let mut buf = (payload.len() as u32).to_le_bytes().to_vec();
    buf.extend_from_slice(payload);
    s.write_all(&buf).expect("raw send");
}

/// Read one frame off a raw socket without consulting any failpoint
/// (the library's `read_frame` checks `serve_frame`, which fault tests
/// arm for the *daemon* side only).
fn raw_recv(s: &mut TcpStream) -> Option<Vec<u8>> {
    let mut prefix = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match s.read(&mut prefix[got..]) {
            Ok(0) => return None,
            Ok(n) => got += n,
            Err(_) => return None,
        }
    }
    let len = u32::from_le_bytes(prefix) as usize;
    let mut payload = vec![0u8; len];
    let mut filled = 0;
    while filled < len {
        match s.read(&mut payload[filled..]) {
            Ok(0) => return None,
            Ok(n) => filled += n,
            Err(_) => return None,
        }
    }
    Some(payload)
}

fn recv_error_message(s: &mut TcpStream) -> String {
    let payload = raw_recv(s).expect("expected an error frame before close");
    match decode_response(&payload).expect("daemon frames always decode") {
        Response::Error { message, .. } => message,
        other => panic!("expected an error response, got {other:?}"),
    }
}

// ---------------------------------------------------------------------
// determinism across the wire
// ---------------------------------------------------------------------

#[test]
fn wire_grouping_and_windowing_never_change_bits() {
    without_failpoints(|| {
        let model = fitted_model(21);
        let offline = ServeEngine::from_model(model.clone()).expect("engine");
        let pq = offline.model().grid_len();
        let all: Vec<usize> = (0..pq).collect();
        let expect = offline.predict_cells(&all).expect("offline predict");

        // serial dispatch (window 0) and cross-request batching (window
        // 2 ms) must serve the same bits, for any request grouping
        for window_ms in [0u64, 2] {
            let engine = ServeEngine::from_model(model.clone()).expect("engine");
            let daemon = start(vec![("m".to_string(), engine)], window_ms);
            let addr = daemon.local_addr().to_string();

            // one request covering the grid
            let mut c = ServeClient::connect(&addr).expect("connect");
            let one = c.predict("m", &all).expect("predict");
            assert_eq!(bits(&one.mean), bits(&expect.mean), "window {window_ms}: one-shot mean");
            assert_eq!(bits(&one.var), bits(&expect.var), "window {window_ms}: one-shot var");

            // the same cells split into ragged pipelined requests on one
            // connection; responses must come back in request order
            let splits = [&all[..5], &all[5..6], &all[6..]];
            let mut ids = Vec::new();
            for part in splits {
                let id = c.fresh_id();
                c.send(&Request::Predict {
                    id,
                    model: "m".to_string(),
                    cells: part.to_vec(),
                })
                .expect("send");
                ids.push(id);
            }
            let mut glued_mean = Vec::new();
            let mut glued_var = Vec::new();
            for want_id in ids {
                match c.recv().expect("recv") {
                    Response::Predict { id, mean, var } => {
                        assert_eq!(id, want_id, "per-connection responses are FIFO");
                        glued_mean.extend(mean);
                        glued_var.extend(var);
                    }
                    other => panic!("unexpected response {other:?}"),
                }
            }
            assert_eq!(bits(&glued_mean), bits(&expect.mean), "window {window_ms}: ragged mean");
            assert_eq!(bits(&glued_var), bits(&expect.var), "window {window_ms}: ragged var");

            // concurrent clients hammering random subsets: whatever the
            // batcher coalesced, every response matches the offline bits
            let expect_mean = Arc::new(expect.mean.clone());
            let expect_var = Arc::new(expect.var.clone());
            let handles: Vec<_> = (0..4)
                .map(|tid| {
                    let addr = addr.clone();
                    let (em, ev) = (Arc::clone(&expect_mean), Arc::clone(&expect_var));
                    std::thread::spawn(move || {
                        let mut c = ServeClient::connect(&addr).expect("connect");
                        let mut rng = Rng::new(100 + tid as u64);
                        for _ in 0..10 {
                            let cells: Vec<usize> =
                                (0..7).map(|_| rng.below(em.len())).collect();
                            let got = c.predict("m", &cells).expect("predict");
                            for (i, &cell) in cells.iter().enumerate() {
                                assert_eq!(got.mean[i].to_bits(), em[cell].to_bits());
                                assert_eq!(got.var[i].to_bits(), ev[cell].to_bits());
                            }
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("concurrent client");
            }

            // clean shutdown over the wire
            c.shutdown_server().expect("shutdown ack");
            let report = daemon.wait();
            assert!(report.predict_requests >= 44, "{report:?}");
            if window_ms == 0 {
                // serial mode: one sweep per request, occupancy exactly 1
                assert!((report.mean_batch_occupancy - 1.0).abs() < 1e-12, "{report:?}");
            }
        }
    });
}

// ---------------------------------------------------------------------
// multi-model routing
// ---------------------------------------------------------------------

#[test]
fn multiple_checkpoints_route_by_model_id() {
    without_failpoints(|| {
        let (ma, mb) = (fitted_model(22), fitted_model(23));
        let ea = ServeEngine::from_model(ma.clone()).expect("engine a");
        let eb = ServeEngine::from_model(mb.clone()).expect("engine b");
        let cells: Vec<usize> = (0..ea.model().grid_len()).step_by(3).collect();
        let want_a = ea.predict_cells(&cells).expect("offline a");
        let want_b = eb.predict_cells(&cells).expect("offline b");
        assert_ne!(bits(&want_a.mean), bits(&want_b.mean), "distinct fits expected");

        let daemon = start(
            vec![
                ("a".to_string(), ServeEngine::from_model(ma).expect("engine")),
                ("b".to_string(), ServeEngine::from_model(mb).expect("engine")),
            ],
            2,
        );
        let addr = daemon.local_addr().to_string();
        let mut c = ServeClient::connect(&addr).expect("connect");

        let got_a = c.predict("a", &cells).expect("predict a");
        let got_b = c.predict("b", &cells).expect("predict b");
        assert_eq!(bits(&got_a.mean), bits(&want_a.mean));
        assert_eq!(bits(&got_b.mean), bits(&want_b.mean));

        // with two models loaded, an empty model id is ambiguous
        let err = c.predict("", &cells).expect_err("ambiguous model id");
        assert!(format!("{err:#}").contains("available"), "{err:#}");
        // an unknown id is a typed error naming the candidates
        let err = c.predict("zebra", &cells).expect_err("unknown model");
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown model") && msg.contains("a, b"), "{msg}");
        // an out-of-range cell is rejected per request...
        let pq = ea.model().grid_len();
        let err = c.predict("a", &[0, pq]).expect_err("out-of-range cell");
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
        // ...and the connection stays perfectly usable afterwards
        let again = c.predict("a", &cells).expect("connection survived the errors");
        assert_eq!(bits(&again.mean), bits(&want_a.mean));

        let info = c.ping().expect("ping");
        assert!(info.contains('a') && info.contains('b'), "{info}");
        c.shutdown_server().expect("shutdown");
        daemon.wait();
    });
}

#[test]
fn single_model_daemon_accepts_empty_model_id() {
    without_failpoints(|| {
        let model = fitted_model(24);
        let engine = ServeEngine::from_model(model.clone()).expect("engine");
        let offline = ServeEngine::from_model(model).expect("engine");
        let cells = vec![0usize, 3, 3, 17];
        let want = offline.predict_cells(&cells).expect("offline");
        let mut daemon = start(vec![("only".to_string(), engine)], 2);
        let mut c = ServeClient::connect(&daemon.local_addr().to_string()).expect("connect");
        let got = c.predict("", &cells).expect("empty id resolves the only model");
        assert_eq!(bits(&got.mean), bits(&want.mean));
        daemon.shutdown();
    });
}

// ---------------------------------------------------------------------
// malformed input never kills the daemon
// ---------------------------------------------------------------------

#[test]
fn malformed_frames_yield_typed_errors_and_daemon_survives() {
    without_failpoints(|| {
        let engine = ServeEngine::from_model(fitted_model(25)).expect("engine");
        let mut daemon = start(vec![("m".to_string(), engine)], 2);
        let addr = daemon.local_addr().to_string();

        // 1. garbage payload behind an intact frame boundary: typed
        //    decode error, connection STAYS OPEN (long enough to pass
        //    the minimum-length check and fail on the magic)
        let mut s = TcpStream::connect(&addr).expect("connect");
        raw_send(&mut s, &[0xDE; 16]);
        let msg = recv_error_message(&mut s);
        assert!(msg.contains("magic"), "{msg}");
        // same connection still serves a valid request
        raw_send(&mut s, &encode_request(&Request::Ping { id: 9 }));
        let payload = raw_recv(&mut s).expect("ping response");
        match decode_response(&payload).expect("decode") {
            Response::Info { id, .. } => assert_eq!(id, 9),
            other => panic!("expected Info, got {other:?}"),
        }

        // 2. corrupted bytes inside a well-formed request: the checksum
        //    trailer catches it
        let mut corrupted = encode_request(&Request::Predict {
            id: 1,
            model: "m".to_string(),
            cells: vec![0, 1],
        });
        let mid = corrupted.len() / 2;
        corrupted[mid] ^= 0x10;
        raw_send(&mut s, &corrupted);
        let msg = recv_error_message(&mut s);
        assert!(msg.contains("checksum"), "{msg}");

        // 3. oversized length prefix: typed error, then the daemon
        //    closes this connection (the stream can't be re-synced)
        let mut s2 = TcpStream::connect(&addr).expect("connect");
        s2.write_all(&[0xFF, 0xFF, 0xFF, 0xFF]).expect("evil prefix");
        let msg = recv_error_message(&mut s2);
        assert!(msg.contains("oversized"), "{msg}");
        assert!(raw_recv(&mut s2).is_none(), "daemon must close after a framing error");

        // 4. mid-frame disconnect: claim 100 bytes, send 10, vanish
        let mut s3 = TcpStream::connect(&addr).expect("connect");
        s3.write_all(&100u32.to_le_bytes()).expect("prefix");
        s3.write_all(&[0u8; 10]).expect("partial payload");
        drop(s3);

        // after all of that, the daemon still serves new clients
        let mut c = ServeClient::connect(&addr).expect("daemon is still alive");
        c.ping().expect("daemon still answers");
        let report = daemon.shutdown();
        assert!(report.errors >= 3, "typed errors must be counted: {report:?}");
    });
}

// ---------------------------------------------------------------------
// failpoints on the accept/read path
// ---------------------------------------------------------------------

#[test]
fn injected_accept_fault_rejects_one_connection_only() {
    let engine = without_failpoints(|| ServeEngine::from_model(fitted_model(26))).expect("engine");
    with_failpoints("serve_accept@0:error", || {
        let mut daemon = start(vec![("m".to_string(), engine)], 2);
        let addr = daemon.local_addr().to_string();
        // first connection: rejected with a typed error frame
        let mut s = TcpStream::connect(&addr).expect("connect");
        let msg = recv_error_message(&mut s);
        assert!(msg.contains("serve_accept"), "{msg}");
        // second connection: served normally — the daemon never died
        let mut c = ServeClient::connect(&addr).expect("connect");
        c.ping().expect("daemon kept serving");
        daemon.shutdown();
    });
}

#[test]
fn injected_frame_fault_is_a_typed_error_not_a_crash() {
    let engine = without_failpoints(|| ServeEngine::from_model(fitted_model(27))).expect("engine");
    with_failpoints("serve_frame@0:error", || {
        let mut daemon = start(vec![("m".to_string(), engine)], 2);
        let addr = daemon.local_addr().to_string();
        // the daemon's first read_frame consumes hit 0 and fails: this
        // connection gets a typed error and closes
        let mut s = TcpStream::connect(&addr).expect("connect");
        let msg = recv_error_message(&mut s);
        assert!(msg.contains("serve_frame"), "{msg}");
        assert!(raw_recv(&mut s).is_none(), "connection closes after a framing fault");
        // subsequent connections read clean (hit 0 already consumed)
        let mut c = ServeClient::connect(&addr).expect("connect");
        c.ping().expect("daemon kept serving");
        daemon.shutdown();
    });
}
