//! Checkpoint + serving integration tests: the train-once / serve-many
//! contract.
//!
//! * save -> load -> serve reproduces the in-memory posterior **bit for
//!   bit** in f64 (and within the documented tolerance in f32, where it
//!   is in fact also bit-exact because the f32 state round-trips
//!   losslessly through the f64-widened in-memory form).
//! * Corrupted, truncated, and wrong-version checkpoints are rejected
//!   with typed `CheckpointError`s, never panics.
//! * Serving is bit-invariant across thread counts (1/2/4/8) and across
//!   arbitrary regroupings of query batches.

use lkgp::data::synthetic::well_specified;
use lkgp::gp::backend::Precision;
use lkgp::gp::lkgp::{Lkgp, LkgpConfig, LkgpFit};
use lkgp::kernels::ProductGridKernel;
use lkgp::model::io::{fnv64, CheckpointError, VERSION};
use lkgp::model::TrainedModel;
use lkgp::par;
use lkgp::serve::{BatchRequest, ServeEngine};
use lkgp::util::testing::assert_close;

fn fit_small(precision: Precision, seed: u64) -> LkgpFit {
    let kernel = ProductGridKernel::new(2, "rbf", 6);
    let data = well_specified(16, 6, 2, &kernel, 0.02, 0.3, seed);
    let cfg = LkgpConfig {
        train_iters: 6,
        n_samples: 8,
        probes: 4,
        cg_tol: 1e-3,
        cg_max_iters: 200,
        seed,
        precision,
        capture_pathwise: true,
        ..LkgpConfig::default()
    };
    Lkgp::fit(&data, cfg).unwrap()
}

fn tmp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("lkgp_ckpt_test_{}_{tag}.ckpt", std::process::id()))
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn save_load_serve_is_bit_identical_in_f64() {
    let fit = fit_small(Precision::F64, 3);
    let model = fit.model.as_ref().unwrap();
    let path = tmp_path("f64");
    let n_bytes = model.save(&path).unwrap();
    assert!(n_bytes > 0);

    let loaded = TrainedModel::load(&path).unwrap();
    // the stored posterior survives the disk round trip exactly
    assert_eq!(bits(&fit.posterior.mean), bits(&loaded.posterior.mean));
    assert_eq!(bits(&fit.posterior.var), bits(&loaded.posterior.var));

    // and serving reconstructs it bit for bit from the pathwise state
    let engine = ServeEngine::open(&path).unwrap();
    let rep = engine.verify();
    assert!(
        rep.bit_identical,
        "reconstruction deviated: mean {} var {}",
        rep.max_mean_diff,
        rep.max_var_diff
    );
    let pq = engine.model().grid_len();
    let res = engine.predict_cells(&(0..pq).collect::<Vec<_>>()).unwrap();
    assert_eq!(bits(&fit.posterior.mean), bits(&res.mean));
    assert_eq!(bits(&fit.posterior.var), bits(&res.var));
    std::fs::remove_file(&path).ok();
}

#[test]
fn save_load_serve_f32_within_precision_tolerance() {
    let fit = fit_small(Precision::F32, 5);
    let model = fit.model.as_ref().unwrap();
    let path = tmp_path("f32");
    model.save(&path).unwrap();
    let loaded = TrainedModel::load(&path).unwrap();
    assert_eq!(loaded.precision, Precision::F32);
    // stored posterior is f64 and survives exactly
    assert_eq!(bits(&fit.posterior.mean), bits(&loaded.posterior.mean));
    // the f32 state tensors round-trip exactly (they originated as f32)
    assert_eq!(bits(&model.vm.data), bits(&loaded.vm.data));

    let engine = ServeEngine::from_model(loaded).unwrap();
    // reconstruction replays the same f32 MVMs, so it lands well within
    // the documented f32 accuracy contract (and is bit-exact in
    // practice — the tolerance guards the contract, not the mechanism)
    assert_close(&engine.reconstructed().mean, &fit.posterior.mean, 1e-4).unwrap();
    assert_close(&engine.reconstructed().var, &fit.posterior.var, 1e-4).unwrap();
    // serving itself always answers from the stored (exact) posterior
    assert_eq!(bits(&engine.posterior().mean), bits(&fit.posterior.mean));
    std::fs::remove_file(&path).ok();
}

#[test]
fn f32_checkpoint_is_smaller_than_f64() {
    let b64 = fit_small(Precision::F64, 7).model.unwrap().to_bytes();
    let b32 = fit_small(Precision::F32, 7).model.unwrap().to_bytes();
    // the three state tensors halve; metadata and posterior stay f64
    assert!(
        (b32.len() as f64) < 0.8 * b64.len() as f64,
        "f32 checkpoint {} bytes vs f64 {} bytes",
        b32.len(),
        b64.len()
    );
}

#[test]
fn corrupted_checkpoints_are_rejected_with_typed_errors() {
    let model = fit_small(Precision::F64, 9).model.unwrap();
    let bytes = model.to_bytes();

    // bit rot in the middle -> checksum mismatch
    let mut flipped = bytes.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x10;
    match TrainedModel::from_bytes(&flipped) {
        Err(CheckpointError::ChecksumMismatch { .. }) => {}
        other => panic!("expected ChecksumMismatch, got {other:?}"),
    }

    // too short to even hold the header
    match TrainedModel::from_bytes(&bytes[..12]) {
        Err(CheckpointError::Truncated { .. }) => {}
        other => panic!("expected Truncated, got {other:?}"),
    }

    // mid-body truncation with a re-stamped (valid) trailer
    let cut = bytes.len() / 2;
    let mut short = bytes[..cut].to_vec();
    short.extend_from_slice(&fnv64(&short).to_le_bytes());
    match TrainedModel::from_bytes(&short) {
        Err(CheckpointError::Truncated { .. }) => {}
        other => panic!("expected Truncated, got {other:?}"),
    }

    // future format version, well-formed otherwise
    let mut vnext = bytes.clone();
    vnext[8..12].copy_from_slice(&(VERSION + 1).to_le_bytes());
    let n = vnext.len();
    let sum = fnv64(&vnext[..n - 8]);
    vnext[n - 8..].copy_from_slice(&sum.to_le_bytes());
    match TrainedModel::from_bytes(&vnext) {
        Err(CheckpointError::UnsupportedVersion { supported, .. }) => {
            assert_eq!(supported, VERSION)
        }
        other => panic!("expected UnsupportedVersion, got {other:?}"),
    }

    // not a checkpoint at all
    let mut junk = bytes;
    junk[..8].copy_from_slice(b"NOTLKGP!");
    let n = junk.len();
    let sum = fnv64(&junk[..n - 8]);
    junk[n - 8..].copy_from_slice(&sum.to_le_bytes());
    match TrainedModel::from_bytes(&junk) {
        Err(CheckpointError::BadMagic { .. }) => {}
        other => panic!("expected BadMagic, got {other:?}"),
    }
}

#[test]
fn typed_error_survives_the_anyhow_chain_of_load() {
    let model = fit_small(Precision::F64, 11).model.unwrap();
    let mut bytes = model.to_bytes();
    let mid = bytes.len() / 3;
    bytes[mid] ^= 0x01;
    let path = tmp_path("corrupt");
    std::fs::write(&path, &bytes).unwrap();
    let err = TrainedModel::load(&path).unwrap_err();
    let typed = err
        .downcast_ref::<CheckpointError>()
        .unwrap_or_else(|| panic!("no CheckpointError in chain: {err:#}"));
    assert!(matches!(typed, CheckpointError::ChecksumMismatch { .. }), "{typed}");
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------
// Checkpoint format v3: the SKI projection record
// ---------------------------------------------------------------------

fn fit_ski(seed: u64) -> LkgpFit {
    use lkgp::data::synthetic::off_grid;
    use lkgp::gp::diagnostics::ProjectionChoice;
    use lkgp::kron::interp::InterpDegree;
    let data = off_grid(80, 0, 8, 6, 0.02, seed);
    let cfg = LkgpConfig {
        train_iters: 4,
        n_samples: 8,
        probes: 4,
        cg_tol: 1e-3,
        cg_max_iters: 200,
        seed,
        capture_pathwise: true,
        projection: ProjectionChoice::Interp(InterpDegree::Cubic),
        ..LkgpConfig::default()
    };
    Lkgp::fit_offgrid(&data, cfg).unwrap()
}

/// Re-stamp the trailing FNV-1a checksum after deliberately editing a
/// checkpoint body, so the corruption reaches the decoder instead of
/// tripping the integrity check.
fn restamp(bytes: &mut [u8]) {
    let n = bytes.len();
    let sum = fnv64(&bytes[..n - 8]);
    bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
}

#[test]
fn ski_save_load_serve_is_bit_identical() {
    use lkgp::gp::diagnostics::ProjectionPath;
    use lkgp::kron::interp::InterpDegree;
    let fit = fit_ski(23);
    let model = fit.model.as_ref().unwrap();
    let path = tmp_path("ski_v3");
    model.save(&path).unwrap();

    let loaded = TrainedModel::load(&path).unwrap();
    assert_eq!(loaded.projection, ProjectionPath::Interp(InterpDegree::Cubic));
    let (ww, lw) = (model.w.as_ref().unwrap(), loaded.w.as_ref().unwrap());
    assert_eq!(ww.nnz(), lw.nnz(), "W sparsity drifted through the disk round trip");
    assert_eq!(ww.indptr(), lw.indptr());
    assert_eq!(ww.cols(), lw.cols());
    assert_eq!(bits(ww.row_weights()), bits(lw.row_weights()));
    assert_eq!(bits(&fit.posterior.mean), bits(&loaded.posterior.mean));
    assert_eq!(bits(&fit.posterior.var), bits(&loaded.posterior.var));

    let engine = ServeEngine::open(&path).unwrap();
    let rep = engine.verify();
    assert!(
        rep.bit_identical,
        "SKI reconstruction deviated: mean {} var {}",
        rep.max_mean_diff,
        rep.max_var_diff
    );
    let pq = engine.model().grid_len();
    let res = engine.predict_cells(&(0..pq).collect::<Vec<_>>()).unwrap();
    assert_eq!(bits(&fit.posterior.mean), bits(&res.mean));
    assert_eq!(bits(&fit.posterior.var), bits(&res.var));
    std::fs::remove_file(&path).ok();
}

#[test]
fn version_2_files_still_load_as_mask_models() {
    use lkgp::gp::diagnostics::ProjectionPath;
    let model = fit_small(Precision::F64, 19).model.unwrap();
    let mut bytes = model.to_bytes();
    // a v2 writer's output is byte-identical to a v3 mask file except
    // for the version stamp, so back-stamping produces a faithful
    // legacy checkpoint
    bytes[8..12].copy_from_slice(&2u32.to_le_bytes());
    restamp(&mut bytes);
    let path = tmp_path("v2_compat");
    std::fs::write(&path, &bytes).unwrap();
    let loaded = TrainedModel::load(&path).unwrap();
    assert_eq!(loaded.projection, ProjectionPath::Mask);
    assert!(loaded.w.is_none());
    assert_eq!(bits(&model.posterior.mean), bits(&loaded.posterior.mean));
    assert!(ServeEngine::open(&path).unwrap().verify().bit_identical);
    std::fs::remove_file(&path).ok();
}

#[test]
fn unknown_projection_tags_are_rejected_with_typed_errors() {
    // header byte 14 is the projection tag; a value outside the known
    // set (or a W tag on a pre-v3 file) must fail as BadField, not
    // panic or mis-decode
    let mask_bytes = fit_small(Precision::F64, 19).model.unwrap().to_bytes();
    let mut unknown = mask_bytes.clone();
    unknown[14] = 9;
    restamp(&mut unknown);
    match TrainedModel::from_bytes(&unknown) {
        Err(CheckpointError::BadField { what: "projection", .. }) => {}
        other => panic!("expected BadField(projection), got {other:?}"),
    }
    let mut v2_interp = mask_bytes;
    v2_interp[8..12].copy_from_slice(&2u32.to_le_bytes());
    v2_interp[14] = 1;
    restamp(&mut v2_interp);
    match TrainedModel::from_bytes(&v2_interp) {
        Err(CheckpointError::BadField { what: "projection", .. }) => {}
        other => panic!("expected BadField(projection), got {other:?}"),
    }
}

#[test]
fn ski_byte_flip_fuzz_yields_typed_errors_never_panics() {
    // Seeded single-byte-flip fuzz over a real v3 checkpoint, with the
    // checksum re-stamped so every corruption reaches the decoder: each
    // attempt must either decode to a model that passes validate() or
    // fail with a typed CheckpointError — never panic, never OOM on a
    // lying length field.
    let bytes = fit_ski(29).model.unwrap().to_bytes();
    let mut state = 0x9E37_79B9_7F4A_7C15u64;
    let mut next = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    for round in 0..200u32 {
        let pos = (next() as usize) % (bytes.len() - 8);
        let bit = 1u8 << (next() % 8);
        let mut m = bytes.clone();
        m[pos] ^= bit;
        restamp(&mut m);
        match TrainedModel::from_bytes(&m) {
            Ok(model) => {
                // benign flip (e.g. inside a float payload): the decoded
                // model must still be internally consistent
                if let Err(e) = model.validate() {
                    panic!("round {round}: decoded model fails validate: {e}");
                }
            }
            Err(e) => {
                // typed and displayable, by construction
                let _ = format!("{e}");
            }
        }
    }
}

#[test]
fn serving_is_bit_invariant_across_thread_counts() {
    let fit = fit_small(Precision::F64, 13);
    let model = fit.model.unwrap();
    let pq = model.grid_len();
    // ragged batch mix exercising the steal-scheduled coalesced sweep
    let batches: Vec<BatchRequest> = vec![
        BatchRequest { cells: (0..pq).collect() },
        BatchRequest { cells: vec![0] },
        BatchRequest { cells: (0..pq).rev().take(7).collect() },
        BatchRequest { cells: vec![] },
        BatchRequest { cells: (0..pq).step_by(3).collect() },
    ];
    let run = |t: usize| {
        par::with_threads(t, || {
            let engine = ServeEngine::from_model(model.clone()).unwrap();
            assert!(engine.verify().bit_identical, "replay broke at {t} threads");
            let res = engine.predict_batch(&batches).unwrap();
            let mut out: Vec<u64> = bits(&engine.reconstructed().mean);
            out.extend(bits(&engine.reconstructed().var));
            for r in &res {
                out.extend(bits(&r.mean));
                out.extend(bits(&r.var));
            }
            out
        })
    };
    let want = run(1);
    for t in [2usize, 4, 8] {
        assert_eq!(want, run(t), "thread count {t} changed served bits");
    }
}

#[test]
fn f32_serving_is_bit_invariant_across_thread_counts() {
    let fit = fit_small(Precision::F32, 17);
    let model = fit.model.unwrap();
    let run = |t: usize| {
        par::with_threads(t, || {
            let engine = ServeEngine::from_model(model.clone()).unwrap();
            let mut out = bits(&engine.reconstructed().mean);
            out.extend(bits(&engine.reconstructed().var));
            out
        })
    };
    let want = run(1);
    for t in [2usize, 4, 8] {
        assert_eq!(want, run(t), "thread count {t} changed f32 served bits");
    }
}
