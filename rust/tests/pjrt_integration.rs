//! Integration tests across the three layers: the PJRT artifacts
//! (Pallas L1 + JAX L2, AOT-compiled) must agree with the rust-native
//! backend on every LKGP operation, and a full fit must produce the
//! same posterior through either path.
//!
//! Requires `make artifacts` (tests self-skip when artifacts are absent).

use lkgp::data::synthetic::well_specified;
use lkgp::gp::backend::{KronBackend, MvmMode, PjrtKronBackend, RustKronBackend};
use lkgp::gp::lkgp::{Backend, Lkgp, LkgpConfig};
use lkgp::kernels::ProductGridKernel;
use lkgp::linalg::Matrix;
use lkgp::runtime::{Manifest, Runtime};
use lkgp::util::rng::Rng;

fn artifacts_available() -> bool {
    Manifest::default_dir().join("manifest.json").exists()
}

/// Build matched (rust, pjrt) backends on the tiny config with the same
/// data + hypers installed.
fn matched_backends(seed: u64) -> Option<(RustKronBackend, PjrtKronBackend, usize, usize)> {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    let rt = Runtime::load_default().unwrap();
    let cfg = rt.manifest.config("tiny").unwrap().clone();
    let (p, q, ds) = (cfg.p, cfg.q, cfg.ds);
    let mut rng = Rng::new(seed);
    let s = Matrix::from_vec(p, ds, rng.normals(p * ds));
    let t: Vec<f64> = (0..q).map(|k| k as f64 / (q - 1) as f64).collect();
    let mask: Vec<f64> =
        (0..p * q).map(|_| if rng.uniform() < 0.3 { 0.0 } else { 1.0 }).collect();
    let theta: Vec<f64> = (0..cfg.n_theta).map(|_| 0.2 * rng.normal()).collect();
    let log_s2 = -1.5;

    let mut rust = RustKronBackend::new(ds, &cfg.kernel_t, q, cfg.probes);
    rust.set_data(&s, &t, &mask).unwrap();
    rust.set_hypers(&theta, log_s2).unwrap();

    let mut pjrt = PjrtKronBackend::new(rt, "tiny").unwrap();
    pjrt.set_data(&s, &t, &mask).unwrap();
    pjrt.set_hypers(&theta, log_s2).unwrap();
    Some((rust, pjrt, p, q))
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn system_mvm_agrees() {
    let Some((mut rust, mut pjrt, p, q)) = matched_backends(1) else { return };
    let mut rng = Rng::new(99);
    let v = Matrix::from_vec(3, p * q, rng.normals(3 * p * q));
    let a = rust.system_mvm(&v).unwrap();
    let b = pjrt.system_mvm(&v).unwrap();
    let d = max_abs_diff(&a.data, &b.data);
    assert!(d < 1e-3, "system_mvm diff {d}");
}

#[test]
fn kron_apply_agrees() {
    let Some((mut rust, mut pjrt, p, q)) = matched_backends(2) else { return };
    let mut rng = Rng::new(98);
    let v = Matrix::from_vec(2, p * q, rng.normals(2 * p * q));
    let a = rust.kron_apply(&v).unwrap();
    let b = pjrt.kron_apply(&v).unwrap();
    let d = max_abs_diff(&a.data, &b.data);
    assert!(d < 1e-3, "kron_apply diff {d}");
}

#[test]
fn prior_sample_agrees_on_same_z() {
    // both backends apply (L_S (x) L_T); same z must give (nearly) the
    // same sample — Cholesky is deterministic. Jitter conventions match
    // (1e-4 relative trace) by construction.
    let Some((mut rust, mut pjrt, p, q)) = matched_backends(3) else { return };
    let mut rng = Rng::new(97);
    let z = Matrix::from_vec(2, p * q, rng.normals(2 * p * q));
    let a = rust.prior_sample(&z).unwrap();
    let b = pjrt.prior_sample(&z).unwrap();
    let d = max_abs_diff(&a.data, &b.data);
    assert!(d < 5e-3, "prior_sample diff {d}");
}

#[test]
fn mll_grads_agree() {
    // The strongest cross-layer check: jax.grad through the Pallas
    // custom-VJP kernels vs the hand-derived rust gradients.
    let Some((mut rust, mut pjrt, p, q)) = matched_backends(4) else { return };
    let probes = rust.probes();
    let mut rng = Rng::new(96);
    let mask_mul = |m: &mut Matrix<f64>, rust: &RustKronBackend| {
        let _ = rust; // mask is in the backends; rebuild here
        let _ = m;
    };
    let _ = mask_mul;
    // masked vectors: reuse the system diag to find the mask (diag has
    // +s2 on all coords; kernel part zero at missing)
    let diag = rust.system_diag();
    let s2 = (-1.5f64).exp();
    let mask: Vec<f64> =
        diag.iter().map(|&d| if (d - s2).abs() < 1e-9 { 0.0 } else { 1.0 }).collect();
    let mk = |rng: &mut Rng| -> Vec<f64> {
        rng.normals(p * q).iter().zip(&mask).map(|(x, m)| x * m).collect()
    };
    let alpha = mk(&mut rng);
    let mut w = Matrix::zeros(probes, p * q);
    let mut z = Matrix::zeros(probes, p * q);
    for i in 0..probes {
        w.row_mut(i).copy_from_slice(&mk(&mut rng));
        z.row_mut(i).copy_from_slice(&mk(&mut rng));
    }
    let ga = rust.mll_grads(&alpha, &w, &z).unwrap();
    let gb = pjrt.mll_grads(&alpha, &w, &z).unwrap();
    assert_eq!(ga.len(), gb.len());
    for (i, (x, y)) in ga.iter().zip(&gb).enumerate() {
        assert!(
            (x - y).abs() < 1e-2 * (1.0 + x.abs()),
            "grad[{i}]: rust {x} vs pjrt {y}"
        );
    }
}

#[test]
fn full_fit_agrees_across_backends() {
    if !artifacts_available() {
        return;
    }
    let kernel = ProductGridKernel::new(2, "rbf", 8);
    let data = well_specified(16, 8, 2, &kernel, 0.05, 0.25, 21);
    let mk_cfg = |backend| LkgpConfig {
        train_iters: 8,
        n_samples: 8,
        probes: 4,
        seed: 9,
        backend,
        ..LkgpConfig::default()
    };
    let fit_rust = Lkgp::fit(&data, mk_cfg(Backend::Rust(MvmMode::Kron))).unwrap();
    let fit_pjrt =
        Lkgp::fit(&data, mk_cfg(Backend::Pjrt { config: "tiny".into() })).unwrap();
    // same seeds, same probes: hyperparameter trajectories should track
    // closely (f32 artifacts vs f64 rust), posterior means close.
    let scale = fit_rust.posterior.mean.iter().map(|x| x.abs()).fold(0.0, f64::max);
    let d = max_abs_diff(&fit_rust.posterior.mean, &fit_pjrt.posterior.mean);
    assert!(d < 0.05 * scale + 0.05, "posterior mean diff {d} (scale {scale})");
    let (rmse_r, _) = fit_rust.posterior.test_metrics(&data);
    let (rmse_p, _) = fit_pjrt.posterior.test_metrics(&data);
    assert!((rmse_r - rmse_p).abs() < 0.2 * rmse_r.max(rmse_p) + 0.02);
}

#[test]
fn pjrt_backend_rejects_mismatched_data() {
    if !artifacts_available() {
        return;
    }
    let rt = Runtime::load_default().unwrap();
    let mut be = PjrtKronBackend::new(rt, "tiny").unwrap();
    let s = Matrix::zeros(3, 2); // wrong p
    assert!(be.set_data(&s, &[0.0; 8], &[1.0; 24]).is_err());
}
