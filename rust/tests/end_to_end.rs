//! End-to-end tests over the full coordinator stack (rust backend —
//! fast; the PJRT path is covered by pjrt_integration.rs and the
//! climate_e2e example).

use lkgp::baselines::{BaselineModel, CaGp, Svgp, Vnngp};
use lkgp::coordinator::ExperimentScale;
use lkgp::data::climate::ClimateSim;
use lkgp::data::lcbench::LcBenchSim;
use lkgp::data::sarcos::SarcosSim;
use lkgp::gp::lkgp::{Lkgp, LkgpConfig};
use lkgp::kron::breakeven;

fn quick_cfg(seed: u64) -> LkgpConfig {
    LkgpConfig {
        train_iters: 8,
        n_samples: 8,
        probes: 4,
        seed,
        ..LkgpConfig::default()
    }
}

#[test]
fn lkgp_beats_mean_predictor_on_climate() {
    let data = ClimateSim::default_temperature(48, 32, 0.3, 0);
    let fit = Lkgp::fit(&data, quick_cfg(0)).unwrap();
    let (rmse, nll) = fit.posterior.test_metrics(&data);
    let (_, y_std) = data.target_stats();
    assert!(rmse < 0.8 * y_std, "rmse {rmse} vs std {y_std}");
    assert!(nll.is_finite());
}

#[test]
fn lkgp_handles_censored_lcbench_pattern() {
    let data = LcBenchSim::new(48, 30, 1).generate();
    let fit = Lkgp::fit(&data, quick_cfg(1)).unwrap();
    let (train_rmse, _) = fit.posterior.train_metrics(&data);
    let (test_rmse, _) = fit.posterior.test_metrics(&data);
    assert!(train_rmse.is_finite() && test_rmse.is_finite());
    assert!(train_rmse < test_rmse, "exact GP should fit train better");
}

#[test]
fn lkgp_multioutput_icm_on_sarcos() {
    let data = SarcosSim::new(48, 0.25, 2).generate();
    assert_eq!(data.time_family, "icm");
    let fit = Lkgp::fit(&data, quick_cfg(2)).unwrap();
    let (rmse, _) = fit.posterior.test_metrics(&data);
    let (_, y_std) = data.target_stats();
    assert!(rmse < 1.5 * y_std, "rmse {rmse} vs {y_std}");
}

#[test]
fn all_baselines_run_on_all_dataset_families() {
    for (name, data) in [
        ("climate", ClimateSim::default_temperature(24, 16, 0.3, 3)),
        ("lcbench", LcBenchSim::new(24, 16, 3).generate()),
        ("sarcos", SarcosSim::new(24, 0.3, 3).generate()),
    ] {
        for model in &mut [
            &mut Svgp::new(16, 2, 0) as &mut dyn BaselineModel,
            &mut Vnngp::new(8, 2, 0),
            &mut CaGp::new(8, 2, 0),
        ] {
            let fit = model
                .fit_predict(&data)
                .unwrap_or_else(|e| panic!("{} on {name}: {e:#}", model.name()));
            let (rmse, nll) = fit.posterior.test_metrics(&data);
            assert!(rmse.is_finite() && nll.is_finite(), "{} on {name}", model.name());
        }
    }
}

#[test]
fn experiment_scales_parse_and_are_consistent() {
    let s = ExperimentScale::quick();
    assert!(!s.fig3_ratios.is_empty());
    // Prop 3.1 consistency at the fig3 scale
    let g = breakeven::gamma_time(s.fig3_p, 7);
    assert!(g > 0.0 && g < 1.0);
}

#[test]
fn dense_and_kron_agree_on_every_dataset_family() {
    use lkgp::gp::backend::MvmMode;
    use lkgp::gp::lkgp::Backend;
    for data in [
        ClimateSim::default_temperature(20, 12, 0.3, 4),
        SarcosSim::new(20, 0.3, 4).generate(),
    ] {
        let base = quick_cfg(7);
        let fk = Lkgp::fit(&data, base.clone()).unwrap();
        let fd = Lkgp::fit(
            &data,
            LkgpConfig { backend: Backend::Rust(MvmMode::DenseMaterialized), ..base },
        )
        .unwrap();
        let (rk, _) = fk.posterior.test_metrics(&data);
        let (rd, _) = fd.posterior.test_metrics(&data);
        assert!(
            (rk - rd).abs() < 0.1 * rk.max(rd) + 1e-3,
            "{}: kron {rk} vs dense {rd}",
            data.name
        );
    }
}
