//! Thread-count invariance: every parallel kernel in the inference hot
//! path must produce *bit-identical* results for any `LKGP_THREADS`,
//! in **both compute precisions**. The `crate::par` helpers guarantee
//! this by construction (chunk boundaries depend only on the problem
//! shape; each output element is written by exactly one worker with a
//! fixed sequential reduction order) — these tests assert it
//! end-to-end, from the GEMM primitives up through a full `Lkgp::fit`
//! posterior, for f64 and for the `Precision::F32` path.

use lkgp::data::synthetic::well_specified;
use lkgp::gp::backend::Precision;
use lkgp::gp::lkgp::{Lkgp, LkgpConfig};
use lkgp::kernels::ProductGridKernel;
use lkgp::kron::{KronOp, MaskedKronSystem};
use lkgp::linalg::gemm::{matmul, matmul_acc, matmul_nt};
use lkgp::linalg::Matrix;
use lkgp::par::{self, with_threads, RegionPanic};
use lkgp::solvers::precond::Preconditioner;
use lkgp::util::rng::Rng;
use lkgp::util::testing::{prop_check, Gen};

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn gemm_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(1);
    // sizes straddle the MC=64 block boundary and leave ragged
    // remainder microtiles in every direction (mr=4, nr=4 for f64)
    for (m, k, n) in [(130usize, 70usize, 65usize), (67, 33, 21)] {
        let a = Matrix::from_vec(m, k, rng.normals(m * k));
        let b = Matrix::from_vec(k, n, rng.normals(k * n));
        let bt = b.transpose();
        let want = with_threads(1, || {
            let mut c = Matrix::zeros(m, n);
            matmul_acc(&a, &b, &mut c);
            (matmul(&a, &b), matmul_nt(&a, &bt), c)
        });
        for t in [2usize, 3, 8] {
            let got = with_threads(t, || {
                let mut c = Matrix::zeros(m, n);
                matmul_acc(&a, &b, &mut c);
                (matmul(&a, &b), matmul_nt(&a, &bt), c)
            });
            assert_eq!(bits(&want.0.data), bits(&got.0.data), "matmul {m}x{k}x{n} t={t}");
            assert_eq!(bits(&want.1.data), bits(&got.1.data), "matmul_nt {m}x{k}x{n} t={t}");
            assert_eq!(bits(&want.2.data), bits(&got.2.data), "matmul_acc {m}x{k}x{n} t={t}");
        }
    }
}

#[test]
fn prop_kron_apply_bit_identical_across_thread_counts() {
    prop_check("kron-thread-invariance", 7151, 10, |g: &mut Gen| {
        let (p, q, bsz) = (g.size(1, 24), g.size(1, 12), g.size(1, 6));
        let op = KronOp::new(
            Matrix::from_vec(p, p, g.spd(p)),
            Matrix::from_vec(q, q, g.spd(q)),
        );
        let mask = g.mask(p * q, 0.3);
        let sys = MaskedKronSystem::new(op.clone(), mask, 0.21);
        let v = Matrix::from_vec(bsz, p * q, g.vec_normal(bsz * p * q));
        let base = with_threads(1, || {
            (op.apply_batch(&v), sys.apply_batch(&v), sys.diag(), sys.kernel_col(0))
        });
        for t in [2usize, 5] {
            let got = with_threads(t, || {
                (op.apply_batch(&v), sys.apply_batch(&v), sys.diag(), sys.kernel_col(0))
            });
            if bits(&base.0.data) != bits(&got.0.data) {
                return Err(format!("KronOp::apply_batch differs at t={t}"));
            }
            if bits(&base.1.data) != bits(&got.1.data) {
                return Err(format!("MaskedKronSystem::apply_batch differs at t={t}"));
            }
            if bits(&base.2) != bits(&got.2) {
                return Err(format!("diag differs at t={t}"));
            }
            if bits(&base.3) != bits(&got.3) {
                return Err(format!("kernel_col differs at t={t}"));
            }
        }
        Ok(())
    });
}

#[test]
fn f32_gemm_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(21);
    // shapes straddle the MC=64 block boundary and the f32 microtile
    // (mr=4, nr=8): 65 = 8*8+1 and 21 = 2*8+5 leave ragged strips
    for (m, k, n) in [(130usize, 70usize, 65usize), (67, 33, 21)] {
        let a: Matrix<f32> = Matrix::from_vec(m, k, rng.normals(m * k)).cast();
        let b: Matrix<f32> = Matrix::from_vec(k, n, rng.normals(k * n)).cast();
        let bt = b.transpose();
        let want = with_threads(1, || {
            let mut c = Matrix::<f32>::zeros(m, n);
            matmul_acc(&a, &b, &mut c);
            (matmul(&a, &b), matmul_nt(&a, &bt), c)
        });
        for t in [2usize, 3, 8] {
            let got = with_threads(t, || {
                let mut c = Matrix::<f32>::zeros(m, n);
                matmul_acc(&a, &b, &mut c);
                (matmul(&a, &b), matmul_nt(&a, &bt), c)
            });
            assert_eq!(
                bits32(&want.0.data),
                bits32(&got.0.data),
                "f32 matmul {m}x{k}x{n} t={t}"
            );
            assert_eq!(
                bits32(&want.1.data),
                bits32(&got.1.data),
                "f32 matmul_nt {m}x{k}x{n} t={t}"
            );
            assert_eq!(
                bits32(&want.2.data),
                bits32(&got.2.data),
                "f32 matmul_acc {m}x{k}x{n} t={t}"
            );
        }
    }
}

#[test]
fn prop_f32_kron_apply_bit_identical_across_thread_counts() {
    prop_check("kron-thread-invariance-f32", 7253, 8, |g: &mut Gen| {
        let (p, q, bsz) = (g.size(1, 24), g.size(1, 12), g.size(1, 6));
        let op: KronOp<f32> = KronOp::new(
            Matrix::from_vec(p, p, g.spd(p)).cast(),
            Matrix::from_vec(q, q, g.spd(q)).cast(),
        );
        let mask: Vec<f32> = g.mask(p * q, 0.3).iter().map(|&m| m as f32).collect();
        let sys = MaskedKronSystem::new(op.clone(), mask, 0.21f32);
        let v: Matrix<f32> =
            Matrix::from_vec(bsz, p * q, g.vec_normal(bsz * p * q)).cast();
        let base = with_threads(1, || {
            (op.apply_batch(&v), sys.apply_batch(&v), sys.diag(), sys.kernel_col(0))
        });
        for t in [2usize, 5] {
            let got = with_threads(t, || {
                (op.apply_batch(&v), sys.apply_batch(&v), sys.diag(), sys.kernel_col(0))
            });
            if bits32(&base.0.data) != bits32(&got.0.data) {
                return Err(format!("f32 KronOp::apply_batch differs at t={t}"));
            }
            if bits32(&base.1.data) != bits32(&got.1.data) {
                return Err(format!("f32 MaskedKronSystem::apply_batch differs at t={t}"));
            }
            if bits32(&base.2) != bits32(&got.2) {
                return Err(format!("f32 diag differs at t={t}"));
            }
            if bits32(&base.3) != bits32(&got.3) {
                return Err(format!("f32 kernel_col differs at t={t}"));
            }
        }
        Ok(())
    });
}

#[test]
fn f32_fit_posterior_bit_identical_across_thread_counts() {
    // The acceptance bar for the mixed-precision path: a full f32 fit —
    // f32 GEMM, f32 apply_batch, parallel pivoted-Cholesky, pathwise
    // accumulation — is bit-identical at 1/2/4/8 worker threads.
    let kernel = ProductGridKernel::new(2, "rbf", 8);
    let data = well_specified(16, 8, 2, &kernel, 0.05, 0.3, 9);
    let cfg = LkgpConfig {
        train_iters: 4,
        n_samples: 8,
        probes: 4,
        precond_rank: 20,
        seed: 3,
        precision: Precision::F32,
        ..LkgpConfig::default()
    };
    let f1 = with_threads(1, || Lkgp::fit(&data, cfg.clone()).unwrap());
    for t in [2usize, 4, 8] {
        let ft = with_threads(t, || Lkgp::fit(&data, cfg.clone()).unwrap());
        assert_eq!(
            bits(&f1.posterior.mean),
            bits(&ft.posterior.mean),
            "f32 posterior mean differs at t={t}"
        );
        assert_eq!(
            bits(&f1.posterior.var),
            bits(&ft.posterior.var),
            "f32 posterior var differs at t={t}"
        );
        for (a, b) in f1.loss_trace.iter().zip(&ft.loss_trace) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 loss trace differs at t={t}");
        }
    }
}

#[test]
fn full_fit_posterior_bit_identical_across_thread_counts() {
    let kernel = ProductGridKernel::new(2, "rbf", 8);
    let data = well_specified(16, 8, 2, &kernel, 0.05, 0.3, 9);
    let cfg = LkgpConfig {
        train_iters: 4,
        n_samples: 8,
        probes: 4,
        precond_rank: 20, // exercise the parallel pivoted-Cholesky path
        seed: 3,
        ..LkgpConfig::default()
    };
    let f1 = with_threads(1, || Lkgp::fit(&data, cfg.clone()).unwrap());
    for t in [2usize, 4, 8] {
        let ft = with_threads(t, || Lkgp::fit(&data, cfg.clone()).unwrap());
        assert_eq!(
            bits(&f1.posterior.mean),
            bits(&ft.posterior.mean),
            "posterior mean differs at t={t}"
        );
        assert_eq!(
            bits(&f1.posterior.var),
            bits(&ft.posterior.var),
            "posterior var differs at t={t}"
        );
        assert_eq!(f1.loss_trace.len(), ft.loss_trace.len());
        for (a, b) in f1.loss_trace.iter().zip(&ft.loss_trace) {
            assert_eq!(a.to_bits(), b.to_bits(), "loss trace differs at t={t}");
        }
    }
}

#[test]
fn toeplitz_fit_posterior_bit_identical_across_thread_counts() {
    // The FFT/Toeplitz time factor: one column per steal-pool task with
    // a fixed butterfly order, so a full fit through the fast path must
    // be bit-identical at 1/2/4/8 worker threads like every other path.
    use lkgp::gp::diagnostics::{TimeOpChoice, TimeOpPath};
    let kernel = ProductGridKernel::new(2, "rbf", 8);
    let data = well_specified(16, 8, 2, &kernel, 0.05, 0.3, 9);
    let cfg = LkgpConfig {
        train_iters: 4,
        n_samples: 8,
        probes: 4,
        precond_rank: 20,
        seed: 3,
        time_op: TimeOpChoice::Toeplitz,
        ..LkgpConfig::default()
    };
    let f1 = with_threads(1, || Lkgp::fit(&data, cfg.clone()).unwrap());
    assert_eq!(f1.diagnostics.time_op, TimeOpPath::Toeplitz);
    for t in [2usize, 4, 8] {
        let ft = with_threads(t, || Lkgp::fit(&data, cfg.clone()).unwrap());
        assert_eq!(ft.diagnostics.time_op, TimeOpPath::Toeplitz);
        assert_eq!(
            bits(&f1.posterior.mean),
            bits(&ft.posterior.mean),
            "toeplitz posterior mean differs at t={t}"
        );
        assert_eq!(
            bits(&f1.posterior.var),
            bits(&ft.posterior.var),
            "toeplitz posterior var differs at t={t}"
        );
        for (a, b) in f1.loss_trace.iter().zip(&ft.loss_trace) {
            assert_eq!(a.to_bits(), b.to_bits(), "toeplitz loss trace differs at t={t}");
        }
    }
}

#[test]
fn eig_solver_fit_bit_identical_across_thread_counts() {
    // The direct spectral path on a fully-observed grid: the sequential
    // eigendecomposition plus KronOp-based applies must keep the whole
    // posterior bit-identical at 1/2/4/8 worker threads.
    use lkgp::gp::diagnostics::SolverPath;
    let kernel = ProductGridKernel::new(2, "rbf", 8);
    let data = well_specified(16, 8, 2, &kernel, 0.05, 0.0, 9);
    let cfg = LkgpConfig {
        train_iters: 4,
        n_samples: 8,
        probes: 4,
        seed: 3,
        ..LkgpConfig::default()
    };
    let f1 = with_threads(1, || Lkgp::fit(&data, cfg.clone()).unwrap());
    assert_eq!(f1.diagnostics.solver_path, SolverPath::Eig);
    assert_eq!(f1.cg_iters_total, 0);
    for t in [2usize, 4, 8] {
        let ft = with_threads(t, || Lkgp::fit(&data, cfg.clone()).unwrap());
        assert_eq!(ft.diagnostics.solver_path, SolverPath::Eig);
        assert_eq!(
            bits(&f1.posterior.mean),
            bits(&ft.posterior.mean),
            "eig posterior mean differs at t={t}"
        );
        assert_eq!(
            bits(&f1.posterior.var),
            bits(&ft.posterior.var),
            "eig posterior var differs at t={t}"
        );
        for (a, b) in f1.loss_trace.iter().zip(&ft.loss_trace) {
            assert_eq!(a.to_bits(), b.to_bits(), "eig loss trace differs at t={t}");
        }
    }
}

#[test]
fn kron_eig_precond_fit_bit_identical_across_thread_counts() {
    // Solver::Eig on a masked grid: CG preconditioned by the latent-grid
    // eigendecomposition. Same bit-invariance bar as every other path.
    use lkgp::gp::diagnostics::Solver;
    let kernel = ProductGridKernel::new(2, "rbf", 8);
    let data = well_specified(16, 8, 2, &kernel, 0.05, 0.3, 9);
    let cfg = LkgpConfig {
        train_iters: 4,
        n_samples: 8,
        probes: 4,
        seed: 3,
        solver: Solver::Eig,
        ..LkgpConfig::default()
    };
    let f1 = with_threads(1, || Lkgp::fit(&data, cfg.clone()).unwrap());
    assert!(f1.cg_iters_total > 0, "masked grid must still run CG");
    for t in [2usize, 4, 8] {
        let ft = with_threads(t, || Lkgp::fit(&data, cfg.clone()).unwrap());
        assert_eq!(f1.cg_iters_total, ft.cg_iters_total, "iteration count differs at t={t}");
        assert_eq!(
            bits(&f1.posterior.mean),
            bits(&ft.posterior.mean),
            "kron-eig posterior mean differs at t={t}"
        );
        assert_eq!(
            bits(&f1.posterior.var),
            bits(&ft.posterior.var),
            "kron-eig posterior var differs at t={t}"
        );
    }
}

#[test]
fn pivoted_cholesky_steal_bit_identical_across_thread_counts() {
    // The ragged work-stealing schedule on the production
    // lazy-pivoted-Cholesky path: later columns sweep n rows whose cost
    // thins out as pivots are consumed, and n*(k+1) crosses the
    // parallel threshold mid-factorization — factor and apply must be
    // bit-identical at 1/2/4/8 worker threads anyway.
    let mut g = Gen { rng: Rng::new(97) };
    let (p, q) = (64usize, 8usize);
    let n = p * q;
    let op = KronOp::new(
        Matrix::from_vec(p, p, g.spd(p)),
        Matrix::from_vec(q, q, g.spd(q)),
    );
    let sys = MaskedKronSystem::new(op, g.mask(n, 0.25), 0.1);
    let diag: Vec<f64> = (0..n).map(|i| sys.kernel_col(i)[i]).collect();
    let rhs = Matrix::from_vec(2, n, g.vec_normal(2 * n));
    let build = |t: usize| {
        with_threads(t, || {
            let pre = Preconditioner::<f64>::pivoted_from_columns(
                diag.clone(),
                |j| sys.kernel_col(j),
                48,
                0.1,
            );
            let out = pre.apply_batch(&rhs);
            let l = match &pre {
                Preconditioner::LowRankPlusNoise { l, .. } => l.data.clone(),
                _ => unreachable!("pivoted_from_columns builds the low-rank form"),
            };
            (bits(&l), bits(&out.data))
        })
    };
    let want = build(1);
    for t in [2usize, 4, 8] {
        let got = build(t);
        assert_eq!(want.0, got.0, "pivoted-Cholesky factor differs at t={t}");
        assert_eq!(want.1, got.1, "preconditioner apply differs at t={t}");
    }
}

#[test]
fn oversubscribed_threads_bit_identical() {
    // LKGP_THREADS far above the core count: the pool must complete
    // promptly and produce the same bits as a single worker
    let mut rng = Rng::new(33);
    let (m, k, n) = (130usize, 70usize, 65usize);
    let a = Matrix::from_vec(m, k, rng.normals(m * k));
    let b = Matrix::from_vec(k, n, rng.normals(k * n));
    let want = with_threads(1, || matmul(&a, &b));
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let over = 4 * cores + 3;
    let got = with_threads(over, || matmul(&a, &b));
    assert_eq!(bits(&want.data), bits(&got.data), "gemm differs at t={over}");
}

#[test]
fn pool_shutdown_reinit_roundtrip_full_fit() {
    // shutdown_pool joins every worker; the next region must lazily
    // restart the pool and reproduce the exact posterior
    let kernel = ProductGridKernel::new(2, "rbf", 8);
    let data = well_specified(16, 8, 2, &kernel, 0.05, 0.3, 9);
    let cfg = LkgpConfig {
        train_iters: 2,
        n_samples: 4,
        probes: 2,
        precond_rank: 20,
        seed: 3,
        ..LkgpConfig::default()
    };
    let f1 = with_threads(4, || Lkgp::fit(&data, cfg.clone()).unwrap());
    for round in 0..2 {
        par::shutdown_pool();
        let f2 = with_threads(4, || Lkgp::fit(&data, cfg.clone()).unwrap());
        assert_eq!(
            bits(&f1.posterior.mean),
            bits(&f2.posterior.mean),
            "posterior mean differs after shutdown round {round}"
        );
        assert_eq!(
            bits(&f1.posterior.var),
            bits(&f2.posterior.var),
            "posterior var differs after shutdown round {round}"
        );
    }
}

#[test]
fn region_panic_is_structured_and_pool_survives() {
    // a panicking task must surface as a RegionPanic (region name +
    // chunk index) on the caller — no deadlock — and leave the pool
    // fully usable for subsequent regions
    let err = with_threads(4, || {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut buf = vec![0.0f64; 64];
            par::par_chunks_mut("invariance.boom", &mut buf, 8, |ci, _chunk| {
                if ci == 5 {
                    panic!("deliberate test panic");
                }
            });
        }))
        .expect_err("the region panic must propagate to the caller")
    });
    let rp = err.downcast::<RegionPanic>().expect("payload must be a RegionPanic");
    assert_eq!(rp.region, "invariance.boom");
    assert_eq!(rp.chunk, 5);
    assert!(rp.payload.contains("deliberate test panic"));
    // the pool is not poisoned: a fanned-out GEMM still matches t=1
    let mut rng = Rng::new(5);
    let (m, k, n) = (67usize, 33, 21);
    let a = Matrix::from_vec(m, k, rng.normals(m * k));
    let b = Matrix::from_vec(k, n, rng.normals(k * n));
    let want = with_threads(1, || matmul(&a, &b));
    let got = with_threads(4, || matmul(&a, &b));
    assert_eq!(bits(&want.data), bits(&got.data), "gemm differs after a region panic");
}

#[test]
fn nested_regions_collapse_on_pool() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    with_threads(4, || {
        let hits: Vec<AtomicUsize> = (0..256).map(|_| AtomicUsize::new(0)).collect();
        par::par_rows("invariance.outer", 4, |range| {
            for w in range {
                // the inner region must run inline on this worker —
                // every index still covered exactly once, no deadlock
                par::par_rows("invariance.inner", 64, |inner| {
                    for i in inner {
                        hits[w * 64 + i].fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    });
}

#[test]
fn interp_spmm_bit_identical_across_thread_counts() {
    // The SKI SpMM kernels in isolation on ragged shapes: flattened
    // outputs b*n = 903 and b*p*q = 897 both straddle the fixed
    // SPMM chunk size (256) with remainder chunks, so the one-writer-
    // per-chunk steal schedule is exercised end to end — for both
    // stencil degrees, in both precisions.
    use lkgp::kron::interp::{InterpDegree, SparseProjection};
    let mut rng = Rng::new(71);
    let (p, q, n, b) = (23usize, 13usize, 301usize, 3usize);
    let grid_s: Vec<f64> = (0..p).map(|j| j as f64 / (p - 1) as f64).collect();
    let grid_t: Vec<f64> = (0..q).map(|k| k as f64 / (q - 1) as f64).collect();
    let xs: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
    let xt: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
    for degree in [InterpDegree::Linear, InterpDegree::Cubic] {
        let w = SparseProjection::build(&xs, &xt, &grid_s, &grid_t, degree).unwrap();
        let vg = Matrix::from_vec(b, p * q, rng.normals(b * p * q));
        let vd = Matrix::from_vec(b, n, rng.normals(b * n));
        let base = with_threads(1, || (w.interp_apply(&vg), w.interp_apply_t(&vd)));
        for t in [2usize, 3, 8] {
            let got = with_threads(t, || (w.interp_apply(&vg), w.interp_apply_t(&vd)));
            assert_eq!(
                bits(&base.0.data),
                bits(&got.0.data),
                "{degree} interp_apply differs at t={t}"
            );
            assert_eq!(
                bits(&base.1.data),
                bits(&got.1.data),
                "{degree} interp_apply_t differs at t={t}"
            );
        }
        let vg32: Matrix<f32> = vg.cast();
        let vd32: Matrix<f32> = vd.cast();
        let base32 = with_threads(1, || (w.interp_apply(&vg32), w.interp_apply_t(&vd32)));
        for t in [2usize, 3, 8] {
            let got32 = with_threads(t, || (w.interp_apply(&vg32), w.interp_apply_t(&vd32)));
            assert_eq!(
                bits32(&base32.0.data),
                bits32(&got32.0.data),
                "{degree} f32 interp_apply differs at t={t}"
            );
            assert_eq!(
                bits32(&base32.1.data),
                bits32(&got32.1.data),
                "{degree} f32 interp_apply_t differs at t={t}"
            );
        }
    }
}

#[test]
fn ski_fit_bit_identical_across_thread_counts() {
    // A full off-grid SKI fit — SpMM projection, data-space CG, grid-
    // space pathwise conditioning — is bit-identical at 1/2/4/8 worker
    // threads, for both stencil degrees and both compute precisions.
    use lkgp::data::synthetic::off_grid;
    use lkgp::gp::diagnostics::{ProjectionChoice, ProjectionPath};
    use lkgp::kron::interp::InterpDegree;
    let data = off_grid(150, 0, 10, 8, 0.02, 11);
    for degree in [InterpDegree::Linear, InterpDegree::Cubic] {
        for precision in [Precision::F64, Precision::F32] {
            let cfg = LkgpConfig {
                train_iters: 3,
                n_samples: 8,
                probes: 4,
                cg_tol: 1e-3,
                cg_max_iters: 200,
                seed: 3,
                precision,
                projection: ProjectionChoice::Interp(degree),
                ..LkgpConfig::default()
            };
            let f1 = with_threads(1, || Lkgp::fit_offgrid(&data, cfg.clone()).unwrap());
            assert_eq!(f1.diagnostics.projection, ProjectionPath::Interp(degree));
            for t in [2usize, 4, 8] {
                let ft = with_threads(t, || Lkgp::fit_offgrid(&data, cfg.clone()).unwrap());
                assert_eq!(
                    bits(&f1.posterior.mean),
                    bits(&ft.posterior.mean),
                    "ski {degree}/{precision:?} posterior mean differs at t={t}"
                );
                assert_eq!(
                    bits(&f1.posterior.var),
                    bits(&ft.posterior.var),
                    "ski {degree}/{precision:?} posterior var differs at t={t}"
                );
                assert_eq!(f1.loss_trace.len(), ft.loss_trace.len());
                for (a, b) in f1.loss_trace.iter().zip(&ft.loss_trace) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "ski {degree}/{precision:?} loss trace differs at t={t}"
                    );
                }
            }
        }
    }
}

#[test]
fn dense_baseline_modes_bit_identical_across_thread_counts() {
    use lkgp::gp::backend::MvmMode;
    use lkgp::gp::lkgp::Backend;
    let kernel = ProductGridKernel::new(2, "rbf", 6);
    let data = well_specified(12, 6, 2, &kernel, 0.05, 0.3, 5);
    for mode in [MvmMode::DenseMaterialized, MvmMode::DenseLazy { block_rows: 5 }] {
        let cfg = LkgpConfig {
            train_iters: 2,
            n_samples: 4,
            probes: 2,
            seed: 1,
            backend: Backend::Rust(mode.clone()),
            ..LkgpConfig::default()
        };
        let f1 = with_threads(1, || Lkgp::fit(&data, cfg.clone()).unwrap());
        let f4 = with_threads(4, || Lkgp::fit(&data, cfg.clone()).unwrap());
        assert_eq!(
            bits(&f1.posterior.mean),
            bits(&f4.posterior.mean),
            "{mode:?} posterior mean differs"
        );
        assert_eq!(
            bits(&f1.posterior.var),
            bits(&f4.posterior.var),
            "{mode:?} posterior var differs"
        );
    }
}
