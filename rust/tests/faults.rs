//! Fault-injection integration tests: every injected fault must surface
//! as a typed error or a deterministic recovery — never a panic, never
//! silently wrong bits.
//!
//! All tests that arm real failpoint sites do so through
//! `with_failpoints` (and baselines through `without_failpoints`);
//! those scopes are serialized process-wide, so the tests in this
//! binary cannot perturb each other even when the harness runs them on
//! parallel threads. Dataset generation happens *outside* the scopes so
//! faults only ever hit the operation under test.

use lkgp::data::synthetic::well_specified;
use lkgp::data::GridDataset;
use lkgp::gp::diagnostics::{OnNonConverged, PrecondLevel};
use lkgp::gp::lkgp::{Lkgp, LkgpConfig, LkgpFit};
use lkgp::kernels::ProductGridKernel;
use lkgp::model::io::CheckpointError;
use lkgp::model::TrainedModel;
use lkgp::par::RegionPanic;
use lkgp::serve::ServeEngine;
use lkgp::solvers::SolveError;
use lkgp::util::failpoint::{with_failpoints, without_failpoints, InjectedFault};
use lkgp::util::rng::Rng;

fn dataset(seed: u64) -> GridDataset {
    let kernel = ProductGridKernel::new(2, "rbf", 8);
    well_specified(20, 8, 2, &kernel, 0.01, 0.25, seed)
}

fn cfg(seed: u64) -> LkgpConfig {
    LkgpConfig {
        train_iters: 3,
        n_samples: 8,
        probes: 4,
        cg_tol: 1e-3,
        cg_max_iters: 200,
        seed,
        capture_pathwise: true,
        mvm_retry_backoff_ms: 0, // retries should not slow the tests
        ..LkgpConfig::default()
    }
}

fn posterior_bits(fit: &LkgpFit) -> (Vec<u64>, Vec<u64>) {
    (
        fit.posterior.mean.iter().map(|x| x.to_bits()).collect(),
        fit.posterior.var.iter().map(|x| x.to_bits()).collect(),
    )
}

fn tmp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("lkgp_faults_{}_{tag}.ckpt", std::process::id()))
}

// ---------------------------------------------------------------------
// backend MVM faults
// ---------------------------------------------------------------------

#[test]
fn persistent_backend_error_fails_fit_with_typed_error() {
    let data = dataset(1);
    let err = with_failpoints("backend_mvm:error", || Lkgp::fit(&data, cfg(1)))
        .err()
        .expect("a persistently failing backend cannot produce a fit");
    let injected = err
        .downcast_ref::<InjectedFault>()
        .unwrap_or_else(|| panic!("expected InjectedFault in chain, got: {err:#}"));
    assert_eq!(injected.site, "backend_mvm");
}

#[test]
fn transient_backend_error_recovers_bit_identically() {
    let data = dataset(2);
    let clean = without_failpoints(|| Lkgp::fit(&data, cfg(2)).expect("clean fit"));
    let faulted = with_failpoints("backend_mvm@2:error", || {
        Lkgp::fit(&data, cfg(2)).expect("one transient MVM failure is within the retry budget")
    });
    assert!(
        faulted.diagnostics.backend_retries >= 1,
        "the injected failure must show up as a recorded retry"
    );
    assert_eq!(clean.diagnostics.backend_retries, 0);
    assert_eq!(
        posterior_bits(&clean),
        posterior_bits(&faulted),
        "a retried deterministic MVM must not change a single output bit"
    );
}

#[test]
fn transient_recovery_is_thread_invariant() {
    let data = dataset(3);
    let run = |threads: usize| {
        lkgp::par::with_threads(threads, || {
            with_failpoints("backend_mvm@2:error", || {
                Lkgp::fit(&data, cfg(3)).expect("transient fault recovers at any thread count")
            })
        })
    };
    let f1 = run(1);
    let f4 = run(4);
    assert!(f1.diagnostics.backend_retries >= 1);
    assert_eq!(f1.diagnostics.backend_retries, f4.diagnostics.backend_retries);
    assert_eq!(posterior_bits(&f1), posterior_bits(&f4));
}

#[test]
fn ski_backend_mvm_faults_are_typed_and_transients_recover_bitwise() {
    // The SKI (interp-projection) solve runs its MVMs through the same
    // `backend_mvm` failpoint site as the mask path: a persistent fault
    // must fail the fit with a typed InjectedFault, and a transient one
    // must be retried to a bit-identical posterior.
    use lkgp::data::synthetic::off_grid;
    use lkgp::gp::diagnostics::ProjectionChoice;
    use lkgp::kron::interp::InterpDegree;
    let data = off_grid(80, 0, 8, 6, 0.02, 13);
    let c = LkgpConfig {
        projection: ProjectionChoice::Interp(InterpDegree::Linear),
        ..cfg(13)
    };

    let err = with_failpoints("backend_mvm:error", || Lkgp::fit_offgrid(&data, c.clone()))
        .err()
        .expect("a persistently failing backend cannot produce a SKI fit");
    let injected = err
        .downcast_ref::<InjectedFault>()
        .unwrap_or_else(|| panic!("expected InjectedFault in chain, got: {err:#}"));
    assert_eq!(injected.site, "backend_mvm");

    let clean =
        without_failpoints(|| Lkgp::fit_offgrid(&data, c.clone()).expect("clean SKI fit"));
    let faulted = with_failpoints("backend_mvm@2:error", || {
        Lkgp::fit_offgrid(&data, c.clone())
            .expect("one transient MVM failure is within the retry budget")
    });
    assert!(
        faulted.diagnostics.backend_retries >= 1,
        "the injected failure must show up as a recorded retry"
    );
    assert_eq!(clean.diagnostics.backend_retries, 0);
    assert_eq!(
        posterior_bits(&clean),
        posterior_bits(&faulted),
        "a retried deterministic SKI MVM must not change a single output bit"
    );
}

// ---------------------------------------------------------------------
// CG divergence detection
// ---------------------------------------------------------------------

#[test]
fn nan_in_cg_residual_is_a_typed_breakdown() {
    let data = dataset(4);
    let err = with_failpoints("cg_iter:nan", || Lkgp::fit(&data, cfg(4)))
        .err()
        .expect("a NaN-poisoned solve must fail");
    match err.downcast_ref::<SolveError>() {
        Some(SolveError::Breakdown { .. }) => {}
        other => panic!("expected SolveError::Breakdown, got {other:?} in: {err:#}"),
    }
}

#[test]
fn nonconverged_solve_policy_warn_vs_error() {
    let data = dataset(5);
    let strangled = |policy: OnNonConverged| LkgpConfig {
        cg_max_iters: 1,
        cg_tol: 1e-12,
        on_nonconverged: policy,
        ..cfg(5)
    };
    without_failpoints(|| {
        let err = Lkgp::fit(&data, strangled(OnNonConverged::Error))
            .err()
            .expect("Error policy must fail a non-converged fit");
        match err.downcast_ref::<SolveError>() {
            Some(SolveError::NotConverged { .. }) => {}
            other => panic!("expected SolveError::NotConverged, got {other:?} in: {err:#}"),
        }
        let fit = Lkgp::fit(&data, strangled(OnNonConverged::Warn))
            .expect("Warn policy records but does not fail");
        assert!(fit.diagnostics.nonconverged_solves > 0);
        assert!(!fit.diagnostics.healthy());
    });
}

// ---------------------------------------------------------------------
// preconditioner fallback
// ---------------------------------------------------------------------

#[test]
fn failed_pivoted_precond_falls_back_to_jacobi_bit_identically() {
    let data = dataset(6);
    // Baseline: rank 0 goes straight to the Jacobi preconditioner.
    let jacobi = without_failpoints(|| Lkgp::fit(&data, cfg(6)).expect("clean jacobi fit"));
    // Faulted: rank > 0 attempts pivoted Cholesky, whose build fails at
    // the failpoint; the policy chain must land on the same Jacobi.
    let fallback = with_failpoints("precond_build:error", || {
        let c = LkgpConfig { precond_rank: 30, ..cfg(6) };
        Lkgp::fit(&data, c).expect("precond build failure is recoverable")
    });
    assert!(
        !fallback.diagnostics.precond_fallbacks.is_empty(),
        "fallback must be recorded in the diagnostics"
    );
    for f in &fallback.diagnostics.precond_fallbacks {
        assert_eq!(f.from, PrecondLevel::PivotedCholesky);
        assert_eq!(f.to, PrecondLevel::Jacobi);
    }
    assert_eq!(
        posterior_bits(&jacobi),
        posterior_bits(&fallback),
        "fallback Jacobi must run the exact math of a rank-0 fit"
    );
}

#[test]
fn worst_residual_reflects_the_recovered_solve_not_the_aborted_one() {
    // A NaN-poisoned preconditioner apply aborts the first train solve
    // at iteration 0 with its relative residuals still at their initial
    // 1.0. The fit recovers by downgrading to Jacobi and re-solving —
    // and FitDiagnostics::worst_rel_residual must report the residual of
    // the solve that stands, not the 1.0 of the aborted attempt.
    let data = dataset(12);
    let fit = with_failpoints("precond_apply@0:nan", || {
        let c = LkgpConfig { precond_rank: 30, ..cfg(12) };
        Lkgp::fit(&data, c).expect("an indefinite preconditioner apply is recoverable")
    });
    assert!(
        fit.diagnostics
            .precond_fallbacks
            .iter()
            .any(|f| f.from == PrecondLevel::PivotedCholesky && f.to == PrecondLevel::Jacobi),
        "{:?}",
        fit.diagnostics.precond_fallbacks
    );
    assert!(
        fit.diagnostics.worst_rel_residual <= 1e-3,
        "worst_rel_residual {} still reflects the aborted attempt",
        fit.diagnostics.worst_rel_residual
    );
    assert!(fit.diagnostics.worst_rel_residual > 0.0);
}

// ---------------------------------------------------------------------
// parallel-region faults
// ---------------------------------------------------------------------

#[test]
fn region_panic_surfaces_as_typed_error_not_a_crash() {
    let data = dataset(7);
    let err = with_failpoints("par_region:panic", || Lkgp::fit(&data, cfg(7)))
        .err()
        .expect("a panicking region chunk must fail the fit");
    let rp = err
        .downcast_ref::<RegionPanic>()
        .unwrap_or_else(|| panic!("expected RegionPanic in chain, got: {err:#}"));
    assert!(rp.payload.contains("injected fault"), "{rp}");
}

// ---------------------------------------------------------------------
// checkpoint IO faults
// ---------------------------------------------------------------------

fn fitted_model(seed: u64) -> TrainedModel {
    let data = dataset(seed);
    without_failpoints(|| Lkgp::fit(&data, cfg(seed)).expect("clean fit"))
        .model
        .expect("capture_pathwise was set")
}

#[test]
fn torn_checkpoint_write_is_detected_on_load() {
    let model = fitted_model(8);
    let path = tmp_path("torn");
    with_failpoints("ckpt_write:torn", || {
        model.save(&path).expect("the torn write itself succeeds");
    });
    let err = without_failpoints(|| TrainedModel::load(&path))
        .err()
        .expect("a torn checkpoint must not load");
    assert!(
        err.downcast_ref::<CheckpointError>().is_some(),
        "expected a typed CheckpointError, got: {err:#}"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn short_and_bitflipped_reads_are_typed_errors() {
    let model = fitted_model(9);
    let path = tmp_path("read");
    without_failpoints(|| model.save(&path).expect("clean save"));

    let err = with_failpoints("ckpt_read:short", || TrainedModel::load(&path))
        .err()
        .expect("a short read must not load");
    assert!(err.downcast_ref::<CheckpointError>().is_some(), "{err:#}");

    let err = with_failpoints("ckpt_read:bitflip", || TrainedModel::load(&path))
        .err()
        .expect("a silently corrupted read must not load");
    match err.downcast_ref::<CheckpointError>() {
        Some(CheckpointError::ChecksumMismatch { .. }) => {}
        other => panic!("expected ChecksumMismatch, got {other:?} in: {err:#}"),
    }

    // and the file itself is still good once faults are disarmed
    without_failpoints(|| TrainedModel::load(&path).expect("uncorrupted load succeeds"));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn checkpoint_fuzz_byte_flips_and_truncations_never_panic() {
    let model = fitted_model(10);
    let bytes = model.to_bytes();
    let n = bytes.len();
    assert!(n > 64, "checkpoint unexpectedly tiny ({n} bytes)");

    // truncations at structural boundaries and arbitrary cut points:
    // every prefix must be rejected with a typed error, never a panic
    let cuts =
        [0usize, 1, 7, 8, 9, 15, 16, 31, n / 4, n / 2, 3 * n / 4, n - 9, n - 8, n - 1];
    for &cut in cuts.iter().filter(|&&c| c < n) {
        assert!(
            TrainedModel::from_bytes(&bytes[..cut]).is_err(),
            "truncation to {cut} bytes must be rejected"
        );
    }

    // seeded single-bit flips all over the file: the trailing checksum
    // (or an earlier structural check) must catch every one of them
    let mut rng = Rng::new(0xFAu64);
    for _ in 0..64 {
        let pos = (rng.next_u64() % n as u64) as usize;
        let bit = (rng.next_u64() % 8) as u8;
        let mut corrupted = bytes.clone();
        corrupted[pos] ^= 1 << bit;
        assert!(
            TrainedModel::from_bytes(&corrupted).is_err(),
            "flip of bit {bit} at byte {pos} must be rejected"
        );
    }

    // sanity: the pristine bytes still round-trip
    let back = TrainedModel::from_bytes(&bytes).expect("pristine bytes round-trip");
    assert_eq!(back.posterior.mean, model.posterior.mean);
}

// ---------------------------------------------------------------------
// serving faults
// ---------------------------------------------------------------------

#[test]
fn serve_reconstruction_retries_transient_mvm_failures() {
    let model = fitted_model(11);
    let engine = with_failpoints("serve_mvm@0:error", || {
        ServeEngine::from_model(model.clone()).expect("one transient MVM failure is retried")
    });
    assert!(engine.diagnostics().backend_retries >= 1);
    assert!(
        engine.verify().bit_identical,
        "a retried reconstruction must still match the stored posterior bit for bit"
    );

    let err = with_failpoints("serve_mvm:error", || ServeEngine::from_model(model))
        .err()
        .expect("a persistently failing backend cannot build an engine");
    assert!(err.downcast_ref::<InjectedFault>().is_some(), "{err:#}");
}
