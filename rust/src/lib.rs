//! # LKGP — Latent Kronecker Gaussian Processes
//!
//! Production reproduction of *"Scalable Gaussian Processes with Latent
//! Kronecker Structure"* (ICML 2025) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **Layer 1/2** (build time, `python/`): Pallas matmul/RBF kernels and
//!   the JAX LKGP compute graph, AOT-lowered to HLO text artifacts.
//! * **Layer 3** (this crate): the runtime coordinator — PJRT artifact
//!   execution, batched preconditioned CG, hyperparameter training,
//!   pathwise-conditioning prediction, datasets, baselines
//!   (dense iterative exact GP, SVGP, VNNGP, CaGP), and the experiment
//!   harness regenerating every table/figure of the paper.
//!
//! Python never runs on the request path: once `make artifacts` has
//! produced `artifacts/*.hlo.txt`, the `lkgp` binary is self-contained.
//!
//! The whole inference hot path (blocked GEMM, Kronecker MVMs, dense
//! baselines, preconditioner construction, pathwise sampling) is
//! multithreaded through the [`par`] worker-pool subsystem
//! (`LKGP_THREADS`, default = available cores) with bit-identical
//! results for any thread count.
//!
//! ## GEMM microkernel
//!
//! Every dense product in the hot path (`linalg::gemm::matmul_acc` /
//! `matmul_nt` — behind the Kron MVM halves, the RBF Gram trick, CG's
//! dense baseline, and the MLL gradient contractions) runs a
//! register-tiled microkernel over packed panels:
//!
//! * **Tiling** (`linalg::gemm::Tiling`, chosen per [`linalg::Scalar`]):
//!   MR x NR register tiles — 4x4 for f64, 4x8 for f32, so the NR axis
//!   is exactly one AVX2 vector (f64x4 / f32x8) — inside MC = 64 row
//!   blocks and KC = 256 deep k-panels.
//! * **Packing**: B is packed once per call into panel-major NR-wide
//!   strips (`bp[k * NR + j]`), reading either orientation (B or B^T)
//!   into the same layout; each row block packs its A rows into MR-lane
//!   panels (`ap[k * MR + i]`). The microkernel therefore streams two
//!   contiguous buffers regardless of the caller's memory layout, and
//!   ragged edges are zero-padded — padding adds discarded lanes, never
//!   terms, so edge cells match full-tile arithmetic bit for bit.
//! * **FMA lanes**: on x86-64 with AVX2+FMA (runtime-detected, stable
//!   `std::arch`) each tile cell is one `vfmadd` chain; elsewhere a
//!   portable mul+add tile with the identical loop structure runs.
//! * **Fixed reduction order**: ascending k within a panel, panels in
//!   ascending k0, block boundaries a function of shape alone — never
//!   of the thread count. That is what keeps parallel results
//!   bit-identical for any `LKGP_THREADS` (the `par_invariance`
//!   guarantee) while still permitting FMA contraction inside a chain.
//!
//! `cargo bench --bench bench_par` measures the tile against the
//! retained scalar baseline (`matmul_nt_ref`) and writes the
//! `gemm_microkernel` acceptance fields of BENCH_par.json that the
//! `bench-smoke` CI job gates on.
//!
//! ## Mixed precision
//!
//! The iterative hot path runs in either f64 (default) or f32, selected
//! by `LkgpConfig::precision` (see [`gp::backend::Precision`]); the CLI
//! flag is `lkgp train --f32`. The policy is *compute in f32,
//! accumulate in f64*: Gram factors, Kronecker/dense MVMs, CG iterates,
//! preconditioner columns, and pathwise samples are stored and
//! multiplied in f32 (~2x memory bandwidth and SIMD width), while CG
//! dot products and residual norms, the data-fit term, hyperparameter
//! gradients, pathwise moment accumulation, and the small-factor
//! Choleskys stay in f64. f64 -> f32 narrowing goes through the single
//! rounding point in [`util::convert`], the public posterior is always
//! f64, and thread-count bit-invariance holds in both precisions
//! (rust/tests/par_invariance.rs); the accuracy contract per precision
//! is pinned by rust/tests/numerics.rs and measured by
//! `cargo bench --bench bench_precision` (BENCH_precision.json).

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod gp;
pub mod kernels;
pub mod kron;
pub mod linalg;
pub mod optim;
pub mod par;
pub mod runtime;
pub mod solvers;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
