//! # LKGP — Latent Kronecker Gaussian Processes
//!
//! Production reproduction of *"Scalable Gaussian Processes with Latent
//! Kronecker Structure"* (ICML 2025) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **Layer 1/2** (build time, `python/`): Pallas matmul/RBF kernels and
//!   the JAX LKGP compute graph, AOT-lowered to HLO text artifacts.
//! * **Layer 3** (this crate): the runtime coordinator — PJRT artifact
//!   execution, batched preconditioned CG, hyperparameter training,
//!   pathwise-conditioning prediction, datasets, baselines
//!   (dense iterative exact GP, SVGP, VNNGP, CaGP), and the experiment
//!   harness regenerating every table/figure of the paper.
//!
//! Python never runs on the request path: once `make artifacts` has
//! produced `artifacts/*.hlo.txt`, the `lkgp` binary is self-contained.
//!
//! The whole inference hot path (blocked GEMM, Kronecker MVMs, dense
//! baselines, preconditioner construction, pathwise sampling) is
//! multithreaded through the [`par`] worker-pool subsystem
//! (`LKGP_THREADS`, default = available cores) with bit-identical
//! results for any thread count.

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod gp;
pub mod kernels;
pub mod kron;
pub mod linalg;
pub mod optim;
pub mod par;
pub mod runtime;
pub mod solvers;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
