//! # LKGP — Latent Kronecker Gaussian Processes
//!
//! Production reproduction of *"Scalable Gaussian Processes with Latent
//! Kronecker Structure"* (ICML 2025) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **Layer 1/2** (build time, `python/`): Pallas matmul/RBF kernels and
//!   the JAX LKGP compute graph, AOT-lowered to HLO text artifacts.
//! * **Layer 3** (this crate): the runtime coordinator — PJRT artifact
//!   execution, batched preconditioned CG, hyperparameter training,
//!   pathwise-conditioning prediction, datasets, baselines
//!   (dense iterative exact GP, SVGP, VNNGP, CaGP), and the experiment
//!   harness regenerating every table/figure of the paper.
//!
//! Python never runs on the request path: once `make artifacts` has
//! produced `artifacts/*.hlo.txt`, the `lkgp` binary is self-contained.
//!
//! The whole inference hot path (blocked GEMM, Kronecker MVMs, dense
//! baselines, preconditioner construction, pathwise sampling) is
//! multithreaded through the [`par`] worker-pool subsystem
//! (`LKGP_THREADS`, default = available cores) with bit-identical
//! results for any thread count.
//!
//! ## Worker pool & scheduling
//!
//! [`par`] is a **persistent pool + deterministic region scheduler**,
//! not a spawn-per-region design: long-lived workers start lazily on
//! the first parallel region, park on a condvar when idle (with a
//! short spin window so the back-to-back regions of a CG iteration
//! skip the futex wait), and serve every subsequent region — dispatch
//! costs ~a microsecond where scoped spawn/join cost tens. The
//! dispatch model: a region is published as a claim-slot job, the
//! submitting thread always participates as worker 0, pool workers
//! claim the remaining slots, and any slot left unclaimed is executed
//! inline by the submitter — so completion never depends on worker
//! availability and a pool shutdown (`par::shutdown_pool`) can never
//! deadlock an in-flight region. Nested regions collapse onto the
//! worker that issued them.
//!
//! **Determinism contract.** Work is split into chunks whose
//! boundaries depend only on the problem shape; each chunk's content
//! is a pure function of its index and each chunk is executed by
//! exactly one worker with a fixed internal reduction order, so every
//! parallel output is bit-identical for any `LKGP_THREADS` ∈ {1, 2,
//! 4, 8, ...}. Two schedules exist: *block* (contiguous chunk runs per
//! worker — uniform work, best locality) and *steal* (workers pull the
//! lowest unclaimed chunk index from a shared cursor). The stealing
//! mode is legal exactly when chunk content does not depend on which
//! worker runs it or in what order chunks complete — true for every
//! region in this crate — and is used where chunk cost is ragged:
//! pivoted-Cholesky row sweeps (rows thin out as pivots are consumed),
//! GEMM row blocks with a short tail, lazy kernel-row materialization.
//! Worker panics are caught per chunk and rethrown on the submitting
//! thread as a structured [`par::RegionPanic`] (region name + chunk
//! index); the pool is never poisoned. The cheap-sweep sequential
//! fallback threshold dropped 8x versus the spawn era
//! (`par::CHEAP_SWEEP_MIN`, override with `LKGP_CHEAP_SWEEP_MIN`);
//! `cargo bench --bench bench_par` measures dispatch-vs-spawn latency
//! and the steal ratio into the `pool` section of BENCH_par.json.
//!
//! ## GEMM microkernel
//!
//! Every dense product in the hot path (`linalg::gemm::matmul_acc` /
//! `matmul_nt` — behind the Kron MVM halves, the RBF Gram trick, CG's
//! dense baseline, and the MLL gradient contractions) runs a
//! register-tiled microkernel over packed panels:
//!
//! * **Tiling** (`linalg::gemm::Tiling`, chosen per [`linalg::Scalar`]):
//!   MR x NR register tiles — 4x4 for f64, 4x8 for f32, so the NR axis
//!   is exactly one AVX2 vector (f64x4 / f32x8) — inside MC = 64 row
//!   blocks and KC = 256 deep k-panels.
//! * **Packing**: B is packed once per call into panel-major NR-wide
//!   strips (`bp[k * NR + j]`), reading either orientation (B or B^T)
//!   into the same layout; each row block packs its A rows into MR-lane
//!   panels (`ap[k * MR + i]`). The microkernel therefore streams two
//!   contiguous buffers regardless of the caller's memory layout, and
//!   ragged edges are zero-padded — padding adds discarded lanes, never
//!   terms, so edge cells match full-tile arithmetic bit for bit.
//! * **FMA lanes**: on x86-64 with AVX2+FMA (runtime-detected, stable
//!   `std::arch`) each tile cell is one `vfmadd` chain; elsewhere a
//!   portable mul+add tile with the identical loop structure runs.
//! * **Fixed reduction order**: ascending k within a panel, panels in
//!   ascending k0, block boundaries a function of shape alone — never
//!   of the thread count. That is what keeps parallel results
//!   bit-identical for any `LKGP_THREADS` (the `par_invariance`
//!   guarantee) while still permitting FMA contraction inside a chain.
//!
//! `cargo bench --bench bench_par` measures the tile against the
//! retained scalar baseline (`matmul_nt_ref`) and writes the
//! `gemm_microkernel` acceptance fields of BENCH_par.json that the
//! `bench-smoke` CI job gates on.
//!
//! ## Mixed precision
//!
//! The iterative hot path runs in either f64 (default) or f32, selected
//! by `LkgpConfig::precision` (see [`gp::backend::Precision`]); the CLI
//! flag is `lkgp train --f32`. The policy is *compute in f32,
//! accumulate in f64*: Gram factors, Kronecker/dense MVMs, CG iterates,
//! preconditioner columns, and pathwise samples are stored and
//! multiplied in f32 (~2x memory bandwidth and SIMD width), while CG
//! dot products and residual norms, the data-fit term, hyperparameter
//! gradients, pathwise moment accumulation, and the small-factor
//! Choleskys stay in f64. f64 -> f32 narrowing goes through the single
//! rounding point in [`util::convert`], the public posterior is always
//! f64, and thread-count bit-invariance holds in both precisions
//! (rust/tests/par_invariance.rs); the accuracy contract per precision
//! is pinned by rust/tests/numerics.rs and measured by
//! `cargo bench --bench bench_precision` (BENCH_precision.json).
//!
//! ## Solvers
//!
//! The observed-grid system `M (K_SS ⊗ K_TT) M + σ²I` is solved by
//! batched preconditioned CG ([`solvers::cg`], the paper's solver) or,
//! when the grid is fully observed, **exactly** by the direct spectral
//! solver [`solvers::eig::EigSolver`]: one symmetric
//! eigendecomposition per Kronecker factor (in-crate
//! tridiagonalization + implicit-shift QL, [`linalg::eig::sym_eig`])
//! turns `(K_SS ⊗ K_TT + σ²I)⁻¹` into four Kronecker GEMMs and a
//! diagonal scale — zero CG iterations. Selection is
//! [`gp::diagnostics::Solver`] (`LkgpConfig::solver`, CLI `--solver`,
//! env `LKGP_SOLVER`; default `auto` = eig on full grids, CG under
//! masking). Under light masking the same spectral identity serves as
//! the `KronEig` preconditioner
//! ([`solvers::precond::Preconditioner::try_kron_eig`]): the latent
//! inverse differs from the true one by a rank `<= 2 * #missing`
//! perturbation, so preconditioned CG converges in `O(#missing)`
//! iterations (the `bench_solver` CI gate pins >= 2x fewer iterations
//! than pivoted Cholesky at 5% missing). Both eig paths fall back to
//! CG on any [`solvers::eig::EigSolveError`], replace only the solve
//! calls (RNG streams match the CG path, so serve replay stays
//! bit-identical), and are thread-count bit-invariant. See
//! docs/solvers.md for the selection matrix.
//!
//! ## Train once, serve many
//!
//! The expensive part of LKGP inference is the fit; after pathwise
//! conditioning every prediction is a cheap Kronecker MVM. The
//! [`model`] module captures that boundary as a versioned, endian-stable
//! binary checkpoint (magic + header + f64/f32 tensor blobs + FNV-1a
//! trailer, spec in docs/formats.md), and [`serve`] loads checkpoints
//! into a [`serve::ServeEngine`] that reconstructs the posterior with
//! MVMs only — **bit-identical** to the in-memory fit for rust-backend
//! models — and answers coalesced query batches over the worker pool.
//! [`serve::daemon::ServeDaemon`] keeps those engines resident behind
//! a dependency-free TCP endpoint ([`util::wire`], spec in
//! docs/formats.md): an admission window lifts `predict_batch`'s
//! within-call coalescing to *cross-request* batching — concurrent
//! clients' queries ride one steal-scheduled sweep — while served
//! bytes stay bit-identical to the offline path for any request
//! grouping, window, or `LKGP_THREADS` (docs/serve.md; gated end to
//! end by the `serve-smoke` CI job and `bench_serve`). CLI:
//! `lkgp save` / `lkgp predict --checkpoint <path>` /
//! `lkgp serve --checkpoint <path>` / `lkgp predict --addr host:port`.
//!
//! ## Resilience
//!
//! Iterative inference fails in structured ways — NaN residuals,
//! indefinite preconditioners, stagnating solves, transient backend
//! errors, corrupted checkpoints — and the crate detects and reports
//! all of them as **typed errors**, never panics (see
//! docs/robustness.md). [`solvers::cg`] detects breakdown, indefinite
//! preconditioning, and stagnation per system; a deterministic policy
//! chain recovers where recovery is sound (bounded MVM retries, CG
//! restart with a recomputed residual, preconditioner fallback pivoted
//! Cholesky -> Jacobi -> identity) and every recovery is **shape-only**,
//! so a recovered run is bit-identical to a clean one at any
//! `LKGP_THREADS`. Each fit returns a
//! [`gp::diagnostics::FitDiagnostics`] health report (non-converged
//! solves, restarts, retries, fallbacks, NaN gradients skipped), and
//! the [`util::failpoint`] harness (`LKGP_FAILPOINTS`, e.g.
//! `backend_mvm@3:error;ckpt_write:torn`) injects deterministic faults
//! at named sites — exercised by rust/tests/faults.rs and the `faults`
//! CI job, which assert that every injected fault yields a typed error
//! or a bit-identical recovery.

#![warn(missing_docs)]

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod gp;
pub mod kernels;
pub mod kron;
pub mod linalg;
pub mod model;
pub mod optim;
pub mod par;
pub mod runtime;
pub mod serve;
pub mod solvers;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
