//! # LKGP — Latent Kronecker Gaussian Processes
//!
//! Production reproduction of *"Scalable Gaussian Processes with Latent
//! Kronecker Structure"* (ICML 2025) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **Layer 1/2** (build time, `python/`): Pallas matmul/RBF kernels and
//!   the JAX LKGP compute graph, AOT-lowered to HLO text artifacts.
//! * **Layer 3** (this crate): the runtime coordinator — PJRT artifact
//!   execution, batched preconditioned CG, hyperparameter training,
//!   pathwise-conditioning prediction, datasets, baselines
//!   (dense iterative exact GP, SVGP, VNNGP, CaGP), and the experiment
//!   harness regenerating every table/figure of the paper.
//!
//! Python never runs on the request path: once `make artifacts` has
//! produced `artifacts/*.hlo.txt`, the `lkgp` binary is self-contained.
//!
//! The whole inference hot path (blocked GEMM, Kronecker MVMs, dense
//! baselines, preconditioner construction, pathwise sampling) is
//! multithreaded through the [`par`] worker-pool subsystem
//! (`LKGP_THREADS`, default = available cores) with bit-identical
//! results for any thread count.
//!
//! ## Mixed precision
//!
//! The iterative hot path runs in either f64 (default) or f32, selected
//! by `LkgpConfig::precision` (see [`gp::backend::Precision`]); the CLI
//! flag is `lkgp train --f32`. The policy is *compute in f32,
//! accumulate in f64*: Gram factors, Kronecker/dense MVMs, CG iterates,
//! preconditioner columns, and pathwise samples are stored and
//! multiplied in f32 (~2x memory bandwidth and SIMD width), while CG
//! dot products and residual norms, the data-fit term, hyperparameter
//! gradients, pathwise moment accumulation, and the small-factor
//! Choleskys stay in f64. f64 -> f32 narrowing goes through the single
//! rounding point in [`util::convert`], the public posterior is always
//! f64, and thread-count bit-invariance holds in both precisions
//! (rust/tests/par_invariance.rs); the accuracy contract per precision
//! is pinned by rust/tests/numerics.rs and measured by
//! `cargo bench --bench bench_precision` (BENCH_precision.json).

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod gp;
pub mod kernels;
pub mod kron;
pub mod linalg;
pub mod optim;
pub mod par;
pub mod runtime;
pub mod solvers;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
