//! Analytic hyperparameter gradients of the Hutchinson MLL surrogate —
//! the rust-native mirror of the `mll_grads` AOT artifact.
//!
//! Surrogate (same convention as python/compile/model.py):
//!
//!   g(theta, log_s2) = -1/2 a^T Khat a + 1/(2k) sum_i w_i^T Khat z_i
//!   Khat v = M (A (x) B) M v + s2 v,   A = K_SS(theta), B = K_TT(theta)
//!
//! For any masked pair (u, v), d(u^T (A (x) B) v)/dA = U B V^T and
//! d(.)/dB = U^T A V with U = unvec(u) (p x q, row-major). Pair
//! contributions are accumulated into GA (p x p) and GB (q x q) once,
//! then contracted against dA/dtheta, dB/dtheta per kernel family.
//! Integration tests assert this matches the jax.grad artifact.

use crate::kernels::{ProductGridKernel, TimeKernel};
use crate::kron::KronOp;
use crate::linalg::gemm::{matmul_acc, matmul_nt};
use crate::linalg::Matrix;

/// A (u, v, coefficient) quadratic-form pair of the surrogate.
pub struct Pair<'a> {
    /// Left masked grid vector u.
    pub u: &'a [f64],
    /// Right masked grid vector v.
    pub v: &'a [f64],
    /// Weight of this pair's contribution to the surrogate.
    pub coef: f64,
}

/// Gradient of the surrogate w.r.t. [theta.., log_sigma2].
/// All pair vectors must already be masked (zeros at missing cells).
pub fn mll_surrogate_grads(
    kernel: &ProductGridKernel,
    s: &Matrix<f64>,
    t: &[f64],
    kss: &Matrix<f64>,
    ktt: &Matrix<f64>,
    log_sigma2: f64,
    pairs: &[Pair<'_>],
) -> Vec<f64> {
    let (p, q) = (kss.rows, ktt.rows);
    // ---- accumulate GA, GB, and the noise quadratic form ----
    let mut ga = Matrix::<f64>::zeros(p, p);
    let mut gb = Matrix::<f64>::zeros(q, q);
    let mut uv_sum = 0.0;
    for pair in pairs {
        assert_eq!(pair.u.len(), p * q);
        assert_eq!(pair.v.len(), p * q);
        let u = Matrix { rows: p, cols: q, data: pair.u.to_vec() };
        let v = Matrix { rows: p, cols: q, data: pair.v.to_vec() };
        // GA += coef * U B V^T ; B symmetric so U B = (B U^T)^T computed
        // directly as matmul. ub: p x q
        let ub = {
            let mut m = u.matmul(ktt); // U (p x q) @ B (q x q) -> B symmetric
            m.scale(pair.coef);
            m
        };
        // ga += ub @ v^T
        let ubvt = matmul_nt(&ub, &v);
        ga.add_assign(&ubvt);
        // GB += coef * U^T A V : (q x p) @ (p x p) @ (p x q)
        let au = kss.matmul(&u); // A U (p x q); A symmetric => U^T A = (A U)^T
        let mut gb_c = Matrix::<f64>::zeros(q, q);
        // gb_c = (A U)^T @ V
        matmul_acc(&au.transpose(), &v, &mut gb_c);
        gb_c.scale(pair.coef);
        gb.add_assign(&gb_c);
        // noise: coef * u^T v
        let mut d = 0.0;
        for (a, b) in pair.u.iter().zip(pair.v) {
            d += a * b;
        }
        uv_sum += pair.coef * d;
    }

    // ---- contract GA with dA/dtheta (spatial ARD-SE) ----
    let ds = kernel.spatial.dim();
    let mut grads = Vec::with_capacity(kernel.n_theta() + 1);
    // d/dlog_ls_d : sum_ij GA_ij A_ij (ds_ijd / ls_d)^2
    let ls: Vec<f64> = kernel.spatial.log_ls.iter().map(|l| l.exp()).collect();
    let mut g_ls = vec![0.0; ds];
    let mut g_os = 0.0;
    for i in 0..p {
        for j in 0..p {
            let w = ga[(i, j)] * kss[(i, j)];
            g_os += w;
            let (si, sj) = (s.row(i), s.row(j));
            for d in 0..ds {
                let z = (si[d] - sj[d]) / ls[d];
                g_ls[d] += w * z * z;
            }
        }
    }
    grads.extend_from_slice(&g_ls);
    grads.push(g_os);

    // ---- contract GB with dB/dtheta (time family) ----
    match &kernel.time {
        TimeKernel::Rbf { log_ls } => {
            let lt = log_ls.exp();
            let mut g = 0.0;
            for k in 0..q {
                for l in 0..q {
                    let z = (t[k] - t[l]) / lt;
                    g += gb[(k, l)] * ktt[(k, l)] * z * z;
                }
            }
            grads.push(g);
        }
        TimeKernel::RbfPeriodic { log_ls, log_ls_per, log_period } => {
            let (lt, lsp, per) = (log_ls.exp(), log_ls_per.exp(), log_period.exp());
            let (mut g_lt, mut g_lsp, mut g_per) = (0.0, 0.0, 0.0);
            for k in 0..q {
                for l in 0..q {
                    let dt = t[k] - t[l];
                    let w = gb[(k, l)] * ktt[(k, l)];
                    let z = dt / lt;
                    g_lt += w * z * z;
                    let x = std::f64::consts::PI * dt / per;
                    let sx = x.sin();
                    g_lsp += w * 4.0 * sx * sx / (lsp * lsp);
                    g_per += w * 2.0 * std::f64::consts::PI * dt * (2.0 * x).sin()
                        / (lsp * lsp * per);
                }
            }
            grads.push(g_lt);
            grads.push(g_lsp);
            grads.push(g_per);
        }
        TimeKernel::Icm { q: qq, .. } => {
            // B = L L^T (+const jitter): dg/dL = (GB + GB^T) L, exp-chain
            // on the diagonal.
            let l = kernel.time.icm_l();
            let mut gsym = gb.clone();
            let gbt = gb.transpose();
            gsym.add_assign(&gbt);
            let gl = gsym.matmul(&l);
            for i in 0..*qq {
                for j in 0..=i {
                    let g = if i == j { gl[(i, j)] * l[(i, i)] } else { gl[(i, j)] };
                    grads.push(g);
                }
            }
        }
    }

    // ---- noise ----
    // d/dlog_s2 [ s2 * sum coef u^T v ] = s2 * uv_sum
    grads.push(log_sigma2.exp() * uv_sum);
    grads
}

/// Convenience: build the standard surrogate pair set from alpha and
/// probe solves (all masked): (a, a, -1/2) + (w_i, z_i, 1/(2k)).
pub fn standard_pairs<'a>(
    alpha: &'a [f64],
    w: &'a Matrix<f64>,
    z: &'a Matrix<f64>,
) -> Vec<Pair<'a>> {
    assert_eq!(w.rows, z.rows);
    let k = w.rows.max(1) as f64;
    let mut pairs = vec![Pair { u: alpha, v: alpha, coef: -0.5 }];
    for i in 0..w.rows {
        pairs.push(Pair { u: w.row(i), v: z.row(i), coef: 0.5 / k });
    }
    pairs
}

/// The surrogate value itself (used by finite-difference tests).
pub fn mll_surrogate_value(
    kss: &Matrix<f64>,
    ktt: &Matrix<f64>,
    mask: &[f64],
    log_sigma2: f64,
    pairs: &[Pair<'_>],
) -> f64 {
    let op = KronOp::new(kss.clone(), ktt.clone());
    let s2 = log_sigma2.exp();
    let mut total = 0.0;
    for pair in pairs {
        let mut vm = Matrix { rows: 1, cols: pair.v.len(), data: pair.v.to_vec() };
        for (x, m) in vm.row_mut(0).iter_mut().zip(mask) {
            *x *= m;
        }
        let kv = op.apply_batch(&vm);
        let mut quad = 0.0;
        for ((u, kvi), m) in pair.u.iter().zip(kv.row(0)).zip(mask) {
            quad += u * kvi * m;
        }
        let mut uv = 0.0;
        for (u, v) in pair.u.iter().zip(pair.v) {
            uv += u * v;
        }
        total += pair.coef * (quad + s2 * uv);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testing::Gen;

    /// finite-difference check of the analytic gradient for every family
    fn fd_check(family: &str, q: usize, seed: u64) {
        let mut g = Gen { rng: Rng::new(seed) };
        let (p, ds) = (6, 2);
        let mut kernel = ProductGridKernel::new(ds, family, q);
        let theta0: Vec<f64> = (0..kernel.n_theta()).map(|_| g.f64_in(-0.3, 0.3)).collect();
        kernel.set_theta(&theta0);
        let s = Matrix::from_vec(p, ds, g.vec_normal(p * ds));
        let t: Vec<f64> = (0..q).map(|k| k as f64 / (q - 1) as f64).collect();
        let mask = g.mask(p * q, 0.3);
        let log_s2 = -1.2;
        // masked pair vectors
        let mk = |g: &mut Gen| -> Vec<f64> {
            g.vec_normal(p * q).iter().zip(&mask).map(|(x, m)| x * m).collect()
        };
        let alpha = mk(&mut g);
        let w = Matrix::from_vec(2, p * q, [mk(&mut g), mk(&mut g)].concat());
        let z = Matrix::from_vec(2, p * q, [mk(&mut g), mk(&mut g)].concat());
        let pairs = standard_pairs(&alpha, &w, &z);

        let kss = kernel.gram_s(&s);
        let ktt = kernel.gram_t(&t);
        let got = mll_surrogate_grads(&kernel, &s, &t, &kss, &ktt, log_s2, &pairs);
        assert_eq!(got.len(), kernel.n_theta() + 1);

        let eval = |theta: &[f64], ls2: f64| -> f64 {
            let mut k2 = kernel.clone();
            k2.set_theta(theta);
            let kss = k2.gram_s(&s);
            let ktt = k2.gram_t(&t);
            let pairs = standard_pairs(&alpha, &w, &z);
            mll_surrogate_value(&kss, &ktt, &mask, ls2, &pairs)
        };
        let eps = 1e-5;
        for d in 0..kernel.n_theta() {
            let mut tp = theta0.clone();
            tp[d] += eps;
            let mut tm = theta0.clone();
            tm[d] -= eps;
            let fd = (eval(&tp, log_s2) - eval(&tm, log_s2)) / (2.0 * eps);
            assert!(
                (got[d] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "{family} theta[{d}]: analytic {} vs fd {fd}",
                got[d]
            );
        }
        let fd_s2 =
            (eval(&theta0, log_s2 + eps) - eval(&theta0, log_s2 - eps)) / (2.0 * eps);
        let gs2 = got[kernel.n_theta()];
        assert!(
            (gs2 - fd_s2).abs() < 1e-4 * (1.0 + fd_s2.abs()),
            "{family} log_s2: {gs2} vs {fd_s2}"
        );
    }

    #[test]
    fn fd_rbf() {
        fd_check("rbf", 5, 101);
    }

    #[test]
    fn fd_rbf_periodic() {
        fd_check("rbf_periodic", 6, 103);
    }

    #[test]
    fn fd_icm() {
        fd_check("icm", 4, 107);
    }
}
