//! Gaussian-process models.
//!
//! * `lkgp` — the paper's Latent Kronecker GP: exact GP inference on a
//!   partial grid via masked Kronecker MVMs + iterative solvers +
//!   pathwise conditioning. The dense iterative *baseline* is the same
//!   model with `MvmMode::DenseMaterialized` (identical prior,
//!   hyperparameters and solver; only the MVM changes — exactly the
//!   Fig-3 comparison).
//! * `backend` — compute backends (rust-native / PJRT artifacts).
//! * `grad` — analytic MLL surrogate gradients (mirror of the AOT
//!   `mll_grads` artifact).

pub mod backend;
pub mod diagnostics;
pub mod grad;
pub mod lkgp;

use crate::data::GridDataset;
use crate::util::stats;

/// Full-grid predictive posterior in raw target scale.
#[derive(Clone, Debug)]
pub struct Posterior {
    /// predictive mean per grid cell
    pub mean: Vec<f64>,
    /// predictive variance per grid cell (includes observation noise)
    pub var: Vec<f64>,
}

impl Posterior {
    /// RMSE over the given grid indices.
    pub fn rmse_at(&self, data: &GridDataset, idx: &[usize]) -> f64 {
        let pred: Vec<f64> = idx.iter().map(|&i| self.mean[i]).collect();
        let target: Vec<f64> = idx.iter().map(|&i| data.y_grid[i]).collect();
        stats::rmse(&pred, &target)
    }

    /// Mean Gaussian NLL over the given grid indices.
    pub fn nll_at(&self, data: &GridDataset, idx: &[usize]) -> f64 {
        let pred: Vec<f64> = idx.iter().map(|&i| self.mean[i]).collect();
        let var: Vec<f64> = idx.iter().map(|&i| self.var[i]).collect();
        let target: Vec<f64> = idx.iter().map(|&i| data.y_grid[i]).collect();
        stats::gaussian_nll(&pred, &var, &target)
    }

    /// Test metrics (missing cells).
    pub fn test_metrics(&self, data: &GridDataset) -> (f64, f64) {
        let idx = data.missing_indices();
        (self.rmse_at(data, &idx), self.nll_at(data, &idx))
    }

    /// Train metrics (observed cells).
    pub fn train_metrics(&self, data: &GridDataset) -> (f64, f64) {
        let idx = data.observed_indices();
        (self.rmse_at(data, &idx), self.nll_at(data, &idx))
    }
}
