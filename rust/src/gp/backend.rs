//! Compute backends for the LKGP model.
//!
//! `KronBackend` abstracts the five operations inference needs; two
//! implementations:
//!
//! * `RustKronBackend` — pure-rust kernels + Kronecker algebra. Also
//!   hosts the *dense baseline* MVM modes (materialized / lazy) so the
//!   Fig-2/Fig-3 comparisons change exactly one thing: the MVM.
//! * `PjrtKronBackend` — the production three-layer path: all five ops
//!   run as AOT-compiled Pallas/JAX artifacts on the PJRT CPU client.
//!
//! An integration test (rust/tests/) asserts the two backends agree.

use anyhow::{bail, Context, Result};

use crate::kernels::ProductGridKernel;
use crate::kron::lazy::LazyGramOp;
use crate::kron::{KronOp, MaskedKronSystem};
use crate::linalg::{cholesky, Matrix};
use crate::runtime::{Runtime, TensorF32};
use crate::solvers::cg::BatchedOp;

use super::grad::{mll_surrogate_grads, standard_pairs};

/// How the CG system operator is applied (the Fig-3 comparison axis).
#[derive(Clone, Debug, PartialEq)]
pub enum MvmMode {
    /// Latent Kronecker structure: O(p^2 q + p q^2) per MVM (the paper).
    Kron,
    /// Materialized dense n x n observed kernel matrix (f32):
    /// O(n^2) time and memory — the standard iterative baseline.
    DenseMaterialized,
    /// Lazy dense: kernel entries recomputed every MVM (O(n^2 d) time,
    /// O(n * block) memory) — the out-of-memory regime of Fig. 2.
    DenseLazy { block_rows: usize },
}

/// Operations LKGP inference needs from a backend. All vectors live in
/// the padded p*q grid space; masking conventions follow kron::.
pub trait KronBackend {
    fn dim(&self) -> usize;
    /// number of Hutchinson probes the gradient path expects
    fn probes(&self) -> usize;
    /// install data (spatial inputs, time grid, mask); called once
    fn set_data(&mut self, s: &Matrix<f64>, t: &[f64], mask: &[f64]) -> Result<()>;
    /// install hyperparameters; recomputes Gram state
    fn set_hypers(&mut self, theta: &[f64], log_sigma2: f64) -> Result<()>;
    /// v -> M (K (x) K) M v + sigma2 v, batched rows
    fn system_mvm(&mut self, v: &Matrix<f64>) -> Result<Matrix<f64>>;
    /// v -> (K (x) K) v (unmasked cross-covariance apply)
    fn kron_apply(&mut self, v: &Matrix<f64>) -> Result<Matrix<f64>>;
    /// z -> (L_S (x) L_T) z prior sample
    fn prior_sample(&mut self, z: &Matrix<f64>) -> Result<Matrix<f64>>;
    /// gradient of the Hutchinson MLL surrogate: [d theta.., d log_s2]
    fn mll_grads(&mut self, alpha: &[f64], w: &Matrix<f64>, z: &Matrix<f64>)
        -> Result<Vec<f64>>;
    /// diagonal of the system matrix (Jacobi preconditioner)
    fn system_diag(&self) -> Vec<f64>;
    /// one column of M (K (x) K) M (pivoted-Cholesky preconditioner)
    fn kernel_col(&self, idx: usize) -> Vec<f64>;
    /// bytes held by the kernel representation (Fig-2/3 memory axis)
    fn kernel_bytes(&self) -> u64;
    /// kernel evaluations performed since set_hypers (Fig-2 axis)
    fn kernel_evals(&self) -> u64;
}

/// Adapter: use a backend as a CG operator.
///
/// `BatchedOp::apply_batch` is infallible by contract, but backend MVMs
/// (notably PJRT execution) can fail mid-solve. Instead of panicking,
/// the first failure is parked in an error slot, `BatchedOp::failed`
/// reports it so `solve_cg` stops at its next check, and the caller
/// surfaces the error through [`SystemOp::take_err`] after the solve —
/// see `gp/lkgp.rs`.
pub struct SystemOp<'a, B: KronBackend> {
    be: &'a mut B,
    err: Option<anyhow::Error>,
}

impl<'a, B: KronBackend> SystemOp<'a, B> {
    pub fn new(be: &'a mut B) -> Self {
        SystemOp { be, err: None }
    }

    /// Return the first backend error observed during the solve, if any.
    /// Must be called after `solve_cg` for failures to propagate.
    pub fn take_err(&mut self) -> Result<()> {
        match self.err.take() {
            Some(e) => Err(e.context("backend MVM failed during CG solve")),
            None => Ok(()),
        }
    }
}

impl<'a, B: KronBackend> BatchedOp<f64> for SystemOp<'a, B> {
    fn dim(&self) -> usize {
        self.be.dim()
    }
    fn apply_batch(&mut self, v: &Matrix<f64>) -> Matrix<f64> {
        if self.err.is_some() {
            return Matrix::zeros(v.rows, v.cols);
        }
        match self.be.system_mvm(v) {
            Ok(out) => out,
            Err(e) => {
                self.err = Some(e);
                Matrix::zeros(v.rows, v.cols)
            }
        }
    }
    fn failed(&self) -> bool {
        self.err.is_some()
    }
}

// ---------------------------------------------------------------------
// Rust-native backend
// ---------------------------------------------------------------------

pub struct RustKronBackend {
    pub kernel: ProductGridKernel,
    pub mode: MvmMode,
    probes: usize,
    s: Matrix<f64>,
    t: Vec<f64>,
    mask: Vec<f64>,
    log_sigma2: f64,
    sys: Option<MaskedKronSystem<f64>>,
    /// dense baseline state
    dense: Option<Matrix<f32>>,
    obs_idx: Vec<usize>,
    kernel_evals: u64,
}

impl RustKronBackend {
    pub fn new(ds: usize, time_family: &str, q: usize, probes: usize) -> Self {
        RustKronBackend {
            kernel: ProductGridKernel::new(ds, time_family, q),
            mode: MvmMode::Kron,
            probes,
            s: Matrix::zeros(0, ds),
            t: Vec::new(),
            mask: Vec::new(),
            log_sigma2: 0.0,
            sys: None,
            dense: None,
            obs_idx: Vec::new(),
            kernel_evals: 0,
        }
    }

    pub fn with_mode(mut self, mode: MvmMode) -> Self {
        self.mode = mode;
        self
    }

    fn sys(&self) -> &MaskedKronSystem<f64> {
        self.sys.as_ref().expect("set_hypers not called")
    }

    /// gather padded grid vector -> observed coords
    fn gather(&self, v: &[f64]) -> Vec<f64> {
        self.obs_idx.iter().map(|&i| v[i]).collect()
    }

    /// scatter observed -> padded grid vector
    fn scatter(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        for (val, &i) in v.iter().zip(&self.obs_idx) {
            out[i] = *val;
        }
        out
    }
}

impl KronBackend for RustKronBackend {
    fn dim(&self) -> usize {
        self.s.rows * self.t.len()
    }

    fn probes(&self) -> usize {
        self.probes
    }

    fn set_data(&mut self, s: &Matrix<f64>, t: &[f64], mask: &[f64]) -> Result<()> {
        self.s = s.clone();
        self.t = t.to_vec();
        self.mask = mask.to_vec();
        self.obs_idx =
            (0..mask.len()).filter(|&i| mask[i] != 0.0).collect();
        self.sys = None;
        self.dense = None;
        Ok(())
    }

    fn set_hypers(&mut self, theta: &[f64], log_sigma2: f64) -> Result<()> {
        self.kernel.set_theta(theta);
        self.log_sigma2 = log_sigma2;
        let kss = self.kernel.gram_s(&self.s);
        let ktt = self.kernel.gram_t(&self.t);
        let (p, q) = (kss.rows, ktt.rows);
        self.kernel_evals = (p * p + q * q) as u64;
        self.sys = Some(MaskedKronSystem::new(
            KronOp::new(kss, ktt),
            self.mask.clone(),
            log_sigma2.exp(),
        ));
        self.dense = None;
        if self.mode == MvmMode::DenseMaterialized {
            // n x n observed Gram in f32 (what the standard iterative
            // baseline stores on the GPU); rows built in parallel
            let sys = self.sys.as_ref().unwrap();
            let n = self.obs_idx.len();
            let q = sys.op.q();
            let mut dense = Matrix::<f32>::zeros(n, n);
            let obs = &self.obs_idx;
            crate::par::par_chunks_mut(&mut dense.data, n.max(1), |a, row| {
                let ia = obs[a];
                let (sa, ta) = (ia / q, ia % q);
                for (x, &ib) in row.iter_mut().zip(obs.iter()) {
                    let (sb, tb) = (ib / q, ib % q);
                    *x = (sys.op.kss[(sa, sb)] * sys.op.ktt[(ta, tb)]) as f32;
                }
            });
            self.kernel_evals = (n * n) as u64;
            self.dense = Some(dense);
        }
        Ok(())
    }

    fn system_mvm(&mut self, v: &Matrix<f64>) -> Result<Matrix<f64>> {
        match &self.mode {
            MvmMode::Kron => Ok(self.sys().apply_batch(v)),
            MvmMode::DenseMaterialized => {
                let dense = self.dense.as_ref().context("dense gram")?;
                let s2 = self.log_sigma2.exp();
                let obs = &self.obs_idx;
                let mut out = Matrix::zeros(v.rows, v.cols);
                // batch rows are independent systems: one worker per row
                // (gather -> f32 dense MVM -> scatter -> +sigma2 v)
                crate::par::par_chunks_mut(&mut out.data, v.cols.max(1), |b, orow| {
                    let vrow = v.row(b);
                    let vo32: Vec<f32> = obs.iter().map(|&i| vrow[i] as f32).collect();
                    for (i, &io) in obs.iter().enumerate() {
                        let row = dense.row(i);
                        let mut sum = 0.0f32;
                        for (k, x) in row.iter().zip(&vo32) {
                            sum += k * x;
                        }
                        orow[io] = sum as f64;
                    }
                    // sigma2 acts on all padded coords (same convention
                    // as the kron system operator)
                    for (o, vi) in orow.iter_mut().zip(vrow) {
                        *o += s2 * vi;
                    }
                });
                Ok(out)
            }
            MvmMode::DenseLazy { block_rows } => {
                let sys = self.sys.as_ref().context("hypers")?;
                let n = self.obs_idx.len();
                let q = sys.op.q();
                let (kss, ktt) = (&sys.op.kss, &sys.op.ktt);
                let obs = &self.obs_idx;
                let entry = |i: usize, j: usize| -> f64 {
                    let (ia, ib) = (obs[i], obs[j]);
                    kss[(ia / q, ib / q)] * ktt[(ia % q, ib % q)]
                };
                let op = LazyGramOp::new(n, *block_rows, entry, 0.0);
                let s2 = self.log_sigma2.exp();
                let mut out = Matrix::zeros(v.rows, v.cols);
                let mut vo = Matrix::zeros(v.rows, n);
                for b in 0..v.rows {
                    vo.row_mut(b).copy_from_slice(&self.gather(v.row(b)));
                }
                let (r, evals) = op.apply_batch(&vo);
                // evals counts actual entry evaluations: each block is
                // materialized once and shared across all batch rows
                self.kernel_evals += evals;
                for b in 0..v.rows {
                    let mut padded = self.scatter(r.row(b));
                    for (o, vi) in padded.iter_mut().zip(v.row(b)) {
                        *o += s2 * vi;
                    }
                    out.row_mut(b).copy_from_slice(&padded);
                }
                Ok(out)
            }
        }
    }

    fn kron_apply(&mut self, v: &Matrix<f64>) -> Result<Matrix<f64>> {
        Ok(self.sys().op.apply_batch(v))
    }

    fn prior_sample(&mut self, z: &Matrix<f64>) -> Result<Matrix<f64>> {
        let sys = self.sys();
        let (p, q) = (sys.op.p(), sys.op.q());
        let mut kss_j = sys.op.kss.clone();
        kss_j.add_diag(1e-4 * kss_j.trace() / p as f64);
        let mut ktt_j = sys.op.ktt.clone();
        ktt_j.add_diag(1e-4 * ktt_j.trace() / q as f64);
        let ls = cholesky(&kss_j).context("K_SS cholesky")?.l;
        let lt = cholesky(&ktt_j).context("K_TT cholesky")?.l;
        Ok(KronOp::new(ls, lt).apply_batch(z))
    }

    fn mll_grads(
        &mut self,
        alpha: &[f64],
        w: &Matrix<f64>,
        z: &Matrix<f64>,
    ) -> Result<Vec<f64>> {
        let sys = self.sys();
        let pairs = standard_pairs(alpha, w, z);
        Ok(mll_surrogate_grads(
            &self.kernel,
            &self.s,
            &self.t,
            &sys.op.kss,
            &sys.op.ktt,
            self.log_sigma2,
            &pairs,
        ))
    }

    fn system_diag(&self) -> Vec<f64> {
        self.sys().diag()
    }

    fn kernel_col(&self, idx: usize) -> Vec<f64> {
        self.sys().kernel_col(idx)
    }

    fn kernel_bytes(&self) -> u64 {
        match &self.mode {
            MvmMode::Kron => {
                let (p, q) = (self.s.rows, self.t.len());
                ((p * p + q * q) * 8) as u64
            }
            MvmMode::DenseMaterialized => {
                let n = self.obs_idx.len();
                (n * n * 4) as u64
            }
            MvmMode::DenseLazy { block_rows } => {
                (self.obs_idx.len() * block_rows * 8) as u64
            }
        }
    }

    fn kernel_evals(&self) -> u64 {
        self.kernel_evals
    }
}

// ---------------------------------------------------------------------
// PJRT backend (the three-layer production path)
// ---------------------------------------------------------------------

pub struct PjrtKronBackend {
    rt: Runtime,
    pub config: String,
    p: usize,
    q: usize,
    ds: usize,
    batch: usize,
    n_probes: usize,
    n_theta: usize,
    // state tensors (f32, PJRT boundary)
    s32: Vec<f32>,
    t32: Vec<f32>,
    mask32: Vec<f32>,
    theta32: Vec<f32>,
    log_sigma2: f64,
    // Gram matrices fetched back to host after `kernels` runs (used by
    // preconditioner construction; p^2 + q^2 floats, cheap by design)
    kss: Vec<f32>,
    ktt: Vec<f32>,
    fresh: bool,
}

impl PjrtKronBackend {
    /// Build over the named artifact config; verifies shape compatibility.
    pub fn new(rt: Runtime, config: &str) -> Result<Self> {
        let meta = rt.manifest.config(config)?.clone();
        Ok(PjrtKronBackend {
            rt,
            config: config.to_string(),
            p: meta.p,
            q: meta.q,
            ds: meta.ds,
            batch: meta.batch,
            n_probes: meta.probes,
            n_theta: meta.n_theta,
            s32: vec![],
            t32: vec![],
            mask32: vec![],
            theta32: vec![],
            log_sigma2: 0.0,
            kss: vec![],
            ktt: vec![],
            fresh: false,
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Run an artifact over a batched matrix, chunking rows into the
    /// config's static batch size (zero-padding the tail chunk).
    fn exec_batched(
        &mut self,
        artifact: &str,
        fixed: &[TensorF32],
        v: &Matrix<f64>,
    ) -> Result<Matrix<f64>> {
        let pq = self.p * self.q;
        assert_eq!(v.cols, pq);
        let mut out = Matrix::zeros(v.rows, pq);
        let b = self.batch;
        let mut row = 0;
        while row < v.rows {
            let take = (v.rows - row).min(b);
            let mut chunk = vec![0.0f32; b * pq];
            for r in 0..take {
                for (c, x) in v.row(row + r).iter().enumerate() {
                    chunk[r * pq + c] = *x as f32;
                }
            }
            let mut inputs = fixed.to_vec();
            inputs.push(TensorF32::new(vec![b, pq], chunk));
            let res = self.rt.exec_f32(&self.config, artifact, &inputs)?;
            let y = &res[0];
            for r in 0..take {
                for c in 0..pq {
                    out[(row + r, c)] = y[r * pq + c] as f64;
                }
            }
            row += take;
        }
        Ok(out)
    }

    fn gram_inputs(&self) -> [TensorF32; 2] {
        [
            TensorF32::new(vec![self.p, self.p], self.kss.clone()),
            TensorF32::new(vec![self.q, self.q], self.ktt.clone()),
        ]
    }

    fn check_fresh(&self) -> Result<()> {
        if !self.fresh {
            bail!("set_hypers must be called before backend ops");
        }
        Ok(())
    }
}

impl KronBackend for PjrtKronBackend {
    fn dim(&self) -> usize {
        self.p * self.q
    }

    fn probes(&self) -> usize {
        self.n_probes
    }

    fn set_data(&mut self, s: &Matrix<f64>, t: &[f64], mask: &[f64]) -> Result<()> {
        if s.rows != self.p || s.cols != self.ds || t.len() != self.q {
            bail!(
                "data ({}x{}, q={}) does not match artifact config {:?} ({}x{}, q={})",
                s.rows,
                s.cols,
                t.len(),
                self.config,
                self.p,
                self.ds,
                self.q
            );
        }
        self.s32 = s.data.iter().map(|&x| x as f32).collect();
        self.t32 = t.iter().map(|&x| x as f32).collect();
        self.mask32 = mask.iter().map(|&x| x as f32).collect();
        self.fresh = false;
        Ok(())
    }

    fn set_hypers(&mut self, theta: &[f64], log_sigma2: f64) -> Result<()> {
        if theta.len() != self.n_theta {
            bail!("theta len {} != {}", theta.len(), self.n_theta);
        }
        self.theta32 = theta.iter().map(|&x| x as f32).collect();
        self.log_sigma2 = log_sigma2;
        let out = self.rt.exec_f32(
            &self.config,
            "kernels",
            &[
                TensorF32::new(vec![self.p, self.ds], self.s32.clone()),
                TensorF32::new(vec![self.q, 1], self.t32.clone()),
                TensorF32::vec1(self.theta32.clone()),
            ],
        )?;
        self.kss = out[0].clone();
        self.ktt = out[1].clone();
        self.fresh = true;
        Ok(())
    }

    fn system_mvm(&mut self, v: &Matrix<f64>) -> Result<Matrix<f64>> {
        self.check_fresh()?;
        let [kss, ktt] = self.gram_inputs();
        let fixed = [
            kss,
            ktt,
            TensorF32::vec1(self.mask32.clone()),
            TensorF32::scalar(self.log_sigma2.exp() as f32),
        ];
        self.exec_batched("kron_mvm", &fixed, v)
    }

    fn kron_apply(&mut self, v: &Matrix<f64>) -> Result<Matrix<f64>> {
        self.check_fresh()?;
        let fixed = self.gram_inputs();
        self.exec_batched("kron_apply", &fixed, v)
    }

    fn prior_sample(&mut self, z: &Matrix<f64>) -> Result<Matrix<f64>> {
        self.check_fresh()?;
        // Cholesky of the small factors happens host-side in f64 (setup
        // op; the artifact's job is the O(b pq (p+q)) factor application
        // — see python/compile/model.py::build_prior_sample).
        let to_f64 = |v: &[f32], n: usize| -> Matrix<f64> {
            Matrix::from_vec(n, n, v.iter().map(|&x| x as f64).collect())
        };
        let chol_jittered = |mut m: Matrix<f64>| -> Result<Matrix<f64>> {
            let n = m.rows;
            m.add_diag(1e-4 * m.trace() / n as f64);
            Ok(cholesky(&m).context("gram cholesky")?.l)
        };
        let ls = chol_jittered(to_f64(&self.kss, self.p))?;
        let lt = chol_jittered(to_f64(&self.ktt, self.q))?;
        let fixed = [
            TensorF32::from_f64(vec![self.p, self.p], &ls.data),
            TensorF32::from_f64(vec![self.q, self.q], &lt.data),
        ];
        self.exec_batched("prior_sample", &fixed, z)
    }

    fn mll_grads(
        &mut self,
        alpha: &[f64],
        w: &Matrix<f64>,
        z: &Matrix<f64>,
    ) -> Result<Vec<f64>> {
        self.check_fresh()?;
        let k = self.n_probes;
        if w.rows != k || z.rows != k {
            bail!("probe count {} != artifact's static {}", w.rows, k);
        }
        let pq = self.p * self.q;
        let out = self.rt.exec_f32(
            &self.config,
            "mll_grads",
            &[
                TensorF32::new(vec![self.p, self.ds], self.s32.clone()),
                TensorF32::new(vec![self.q, 1], self.t32.clone()),
                TensorF32::vec1(self.theta32.clone()),
                TensorF32::scalar(self.log_sigma2 as f32),
                TensorF32::vec1(self.mask32.clone()),
                TensorF32::from_f64(vec![pq], alpha),
                TensorF32::from_f64(vec![k, pq], &w.data),
                TensorF32::from_f64(vec![k, pq], &z.data),
            ],
        )?;
        Ok(out[0].iter().map(|&x| x as f64).collect())
    }

    fn system_diag(&self) -> Vec<f64> {
        let s2 = self.log_sigma2.exp();
        let mut d = Vec::with_capacity(self.p * self.q);
        for j in 0..self.p {
            let ks = self.kss[j * self.p + j] as f64;
            for kk in 0..self.q {
                let idx = j * self.q + kk;
                d.push(
                    self.mask32[idx] as f64 * ks * self.ktt[kk * self.q + kk] as f64 + s2,
                );
            }
        }
        d
    }

    fn kernel_col(&self, idx: usize) -> Vec<f64> {
        let (j0, k0) = (idx / self.q, idx % self.q);
        let mcol = self.mask32[idx] as f64;
        let mut col = Vec::with_capacity(self.p * self.q);
        for j in 0..self.p {
            let ks = self.kss[j * self.p + j0] as f64;
            for kk in 0..self.q {
                let v = ks * self.ktt[kk * self.q + k0] as f64;
                col.push(v * self.mask32[j * self.q + kk] as f64 * mcol);
            }
        }
        col
    }

    fn kernel_bytes(&self) -> u64 {
        ((self.p * self.p + self.q * self.q) * 4) as u64
    }

    fn kernel_evals(&self) -> u64 {
        ((self.p * self.p) + (self.q * self.q)) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy_backend(mode: MvmMode) -> RustKronBackend {
        let mut rng = Rng::new(7);
        let (p, q, ds) = (8, 5, 2);
        let s = Matrix::from_vec(p, ds, rng.normals(p * ds));
        let t: Vec<f64> = (0..q).map(|k| k as f64 / (q - 1) as f64).collect();
        let mut mask = vec![1.0; p * q];
        for i in (0..p * q).step_by(3) {
            mask[i] = 0.0;
        }
        let mut be = RustKronBackend::new(ds, "rbf", q, 4).with_mode(mode);
        be.set_data(&s, &t, &mask).unwrap();
        be.set_hypers(&vec![0.0; be.kernel.n_theta()], -1.5).unwrap();
        be
    }

    #[test]
    fn dense_modes_match_kron_mvm() {
        let mut rng = Rng::new(11);
        let mut kron = toy_backend(MvmMode::Kron);
        let mut dense = toy_backend(MvmMode::DenseMaterialized);
        let mut lazy = toy_backend(MvmMode::DenseLazy { block_rows: 3 });
        let v = Matrix::from_vec(2, kron.dim(), rng.normals(2 * kron.dim()));
        // dense modes only act on the observed subspace; compare there
        let mut vm = v.clone();
        for b in 0..2 {
            for (x, m) in vm.row_mut(b).iter_mut().zip(&kron.mask) {
                *x *= *m;
            }
        }
        let a = kron.system_mvm(&vm).unwrap();
        let b = dense.system_mvm(&vm).unwrap();
        let c = lazy.system_mvm(&vm).unwrap();
        for i in 0..a.data.len() {
            assert!((a.data[i] - b.data[i]).abs() < 1e-3, "dense idx {i}");
            assert!((a.data[i] - c.data[i]).abs() < 1e-6, "lazy idx {i}");
        }
    }

    #[test]
    fn kernel_bytes_ordering() {
        let kron = toy_backend(MvmMode::Kron);
        let dense = toy_backend(MvmMode::DenseMaterialized);
        // 8x5 grid with 1/3 missing: n ~ 26, n^2*4 ~ 2.7 KB vs (64+25)*8
        assert!(kron.kernel_bytes() < dense.kernel_bytes());
    }

    #[test]
    fn prior_sample_has_kernel_covariance() {
        let mut be = toy_backend(MvmMode::Kron);
        let mut rng = Rng::new(3);
        let nsamp = 2000;
        let z = Matrix::from_vec(nsamp, be.dim(), rng.normals(nsamp * be.dim()));
        let f = be.prior_sample(&z).unwrap();
        // marginal variance ~ diag(K (x) K) = 1 (unit outputscale/kernels)
        for c in 0..be.dim() {
            let var: f64 = (0..nsamp).map(|r| f[(r, c)] * f[(r, c)]).sum::<f64>() / nsamp as f64;
            assert!((var - 1.0).abs() < 0.2, "cell {c} var {var}");
        }
    }
}
