//! Compute backends for the LKGP model.
//!
//! `KronBackend<T>` abstracts the five operations inference needs,
//! generic over the compute precision `T` (f32 | f64); two
//! implementations:
//!
//! * `RustKronBackend<T>` — pure-rust kernels + Kronecker algebra in
//!   either precision. Also hosts the *dense baseline* MVM modes
//!   (materialized / lazy) so the Fig-2/Fig-3 comparisons change exactly
//!   one thing: the MVM.
//! * `PjrtKronBackend` — the production three-layer path: all five ops
//!   run as AOT-compiled Pallas/JAX artifacts on the PJRT CPU client
//!   (always f32 on-device; implements `KronBackend<f64>` at the host
//!   boundary).
//!
//! An integration test (rust/tests/) asserts the two backends agree;
//! rust/tests/numerics.rs pins the accuracy contract of each precision.

use anyhow::{bail, Context, Result};

use crate::kernels::time::{detect_uniform_spacing, GridSpacing};
use crate::kernels::ProductGridKernel;
use crate::kron::interp::{InterpKronSystem, SparseProjection};
use crate::kron::lazy::LazyGramOp;
use crate::kron::toeplitz::ToeplitzOp;
use crate::kron::{KronOp, MaskedKronSystem};
use crate::linalg::{cholesky, Matrix, Scalar};
use crate::runtime::{Runtime, TensorF32};
use crate::solvers::cg::BatchedOp;
use crate::util::convert;

use super::diagnostics::{TimeOpChoice, TimeOpPath};
use super::grad::{mll_surrogate_grads, standard_pairs};

/// Relative tolerance under which a time grid counts as uniformly
/// spaced for time-op auto-selection (loose enough for accumulated
/// float noise in `linspace`-style grids, tight enough to reject
/// real jitter).
const UNIFORM_GRID_REL_TOL: f64 = 1e-6;

/// How the CG system operator is applied (the Fig-3 comparison axis).
#[derive(Clone, Debug, PartialEq)]
pub enum MvmMode {
    /// Latent Kronecker structure: O(p^2 q + p q^2) per MVM (the paper).
    Kron,
    /// Materialized dense n x n observed kernel matrix (f32):
    /// O(n^2) time and memory — the standard iterative baseline.
    DenseMaterialized,
    /// Lazy dense: kernel entries recomputed every MVM (O(n^2 d) time,
    /// O(n * block) memory) — the out-of-memory regime of Fig. 2.
    DenseLazy {
        /// Kernel rows materialized at a time.
        block_rows: usize,
    },
}

/// Floating-point precision of the iterative inference hot path
/// (`LkgpConfig::precision`).
///
/// Policy: **compute in the selected precision, accumulate in f64**.
/// Under `F32`, the Gram factors, every Kronecker/dense MVM, the CG
/// iterates, the preconditioner, and the pathwise samples are stored and
/// multiplied in f32 — roughly 2x the memory bandwidth and SIMD width of
/// f64 — while the numerically sensitive reductions (CG dot products and
/// residual norms, the data-fit term, hyperparameter gradients, pathwise
/// moment accumulation, and the small-factor Choleskys) stay in f64.
/// Conversions to f32 go through the crate's single rounding point
/// (`util::convert`). The public [`super::Posterior`] is always f64.
///
/// Choose `F32` when fit/predict time or kernel memory is the
/// bottleneck and a relative posterior error around 1e-3 (versus ~1e-7
/// solver-tolerance-limited error for `F64`) is acceptable — e.g. the
/// paper's Fig-2/Fig-3 scaling regimes, where the dominant cost is the
/// MVM. Keep the default `F64` for small problems or when posteriors
/// feed downstream analyses that are sensitive at the 1e-3 level.
/// Thread-count bit-invariance holds in both precisions
/// (rust/tests/par_invariance.rs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Precision {
    /// Double precision everywhere (default).
    #[default]
    F64,
    /// f32 compute with f64 accumulation (see type-level docs).
    F32,
}

/// Operations LKGP inference needs from a backend, generic over the
/// compute precision `T`. All vectors live in the padded p*q grid
/// space; masking conventions follow kron::. Hyperparameters, data, and
/// gradients stay f64 at this boundary regardless of `T` — only the
/// iterative hot path (MVMs, CG iterates, preconditioner columns)
/// switches precision.
pub trait KronBackend<T: Scalar = f64> {
    /// Padded grid dimension p*q.
    fn dim(&self) -> usize;
    /// number of Hutchinson probes the gradient path expects
    fn probes(&self) -> usize;
    /// install data (spatial inputs, time grid, mask); called once
    fn set_data(&mut self, s: &Matrix<f64>, t: &[f64], mask: &[f64]) -> Result<()>;
    /// install hyperparameters; recomputes Gram state
    fn set_hypers(&mut self, theta: &[f64], log_sigma2: f64) -> Result<()>;
    /// v -> M (K (x) K) M v + sigma2 v, batched rows
    fn system_mvm(&mut self, v: &Matrix<T>) -> Result<Matrix<T>>;
    /// v -> (K (x) K) v (unmasked cross-covariance apply)
    fn kron_apply(&mut self, v: &Matrix<T>) -> Result<Matrix<T>>;
    /// z -> (L_S (x) L_T) z prior sample
    fn prior_sample(&mut self, z: &Matrix<T>) -> Result<Matrix<T>>;
    /// gradient of the Hutchinson MLL surrogate: [d theta.., d log_s2]
    /// (always accumulated and returned in f64)
    fn mll_grads(&mut self, alpha: &[T], w: &Matrix<T>, z: &Matrix<T>)
        -> Result<Vec<f64>>;
    /// diagonal of the system matrix (Jacobi preconditioner), widened
    /// to f64. The values are computed in `T`, so near-ties in greedy
    /// pivot selection can still order differently between precisions;
    /// within a precision the sequence is deterministic.
    fn system_diag(&self) -> Vec<f64>;
    /// one column of M (K (x) K) M (pivoted-Cholesky preconditioner)
    fn kernel_col(&self, idx: usize) -> Vec<T>;
    /// bytes held by the kernel representation (Fig-2/3 memory axis)
    fn kernel_bytes(&self) -> u64;
    /// kernel evaluations performed since set_hypers (Fig-2 axis)
    fn kernel_evals(&self) -> u64;
    /// The current Gram factors `(K_SS, K_TT)` widened to f64, if the
    /// backend exposes them (after `set_hypers`). Feeds the direct
    /// eigendecomposition solver and the `KronEig` preconditioner;
    /// `None` means those paths fall back to CG.
    fn gram_factors(&self) -> Option<(Matrix<f64>, Matrix<f64>)> {
        None
    }
    /// Which time-factor engine this backend's MVMs use (recorded in
    /// `FitDiagnostics::time_op`). Backends without a Toeplitz fast
    /// path are always dense.
    fn time_op_path(&self) -> TimeOpPath {
        TimeOpPath::Dense
    }
}

/// Adapter: use a backend as a CG operator.
///
/// `BatchedOp::apply_batch` is infallible by contract, but backend MVMs
/// (notably PJRT execution) can fail mid-solve. A failing apply is
/// retried up to a bounded number of times with doubling backoff (see
/// [`SystemOp::with_retries`]; retrying an identical deterministic MVM
/// cannot change bits — a retried success returns exactly the value a
/// first-try success would have). Once retries are exhausted the
/// failure is parked in an error slot, `BatchedOp::failed` reports it
/// so `solve_cg` stops at its next check, and the caller surfaces the
/// error through [`SystemOp::take_err`] after the solve — see
/// `gp/lkgp.rs`.
pub struct SystemOp<'a, B> {
    be: &'a mut B,
    err: Option<anyhow::Error>,
    max_retries: usize,
    backoff_ms: u64,
    retries: u64,
}

impl<'a, B> SystemOp<'a, B> {
    /// Wrap a backend for the duration of one CG solve (no retries).
    pub fn new(be: &'a mut B) -> Self {
        SystemOp::with_retries(be, 0, 0)
    }

    /// Wrap a backend, retrying each failing MVM up to `max_retries`
    /// times. The first retry waits `backoff_ms` milliseconds and each
    /// further retry doubles the wait (`backoff_ms = 0` retries
    /// immediately — keep it 0 in tests for determinism of *runtime*;
    /// numeric outputs are unaffected either way).
    pub fn with_retries(be: &'a mut B, max_retries: usize, backoff_ms: u64) -> Self {
        SystemOp { be, err: None, max_retries, backoff_ms, retries: 0 }
    }

    /// MVM retries performed so far (across all applies of this solve).
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Return the first backend error observed during the solve, if any.
    /// Must be called after `solve_cg` for failures to propagate.
    pub fn take_err(&mut self) -> Result<()> {
        match self.err.take() {
            Some(e) => Err(e.context("backend MVM failed during CG solve")),
            None => Ok(()),
        }
    }
}

impl<'a, T: Scalar, B: KronBackend<T>> BatchedOp<T> for SystemOp<'a, B> {
    fn dim(&self) -> usize {
        self.be.dim()
    }
    fn apply_batch(&mut self, v: &Matrix<T>) -> Matrix<T> {
        if self.err.is_some() {
            return Matrix::zeros(v.rows, v.cols);
        }
        let mut attempt = 0;
        let mut wait_ms = self.backoff_ms;
        loop {
            match self.be.system_mvm(v) {
                Ok(out) => return out,
                Err(e) if attempt < self.max_retries => {
                    attempt += 1;
                    self.retries += 1;
                    let _ = e; // transient: drop and retry
                    if wait_ms > 0 {
                        std::thread::sleep(std::time::Duration::from_millis(wait_ms));
                        wait_ms = wait_ms.saturating_mul(2);
                    }
                }
                Err(e) => {
                    self.err = Some(e);
                    return Matrix::zeros(v.rows, v.cols);
                }
            }
        }
    }
    fn failed(&self) -> bool {
        self.err.is_some()
    }
}

// ---------------------------------------------------------------------
// Rust-native backend (precision-generic)
// ---------------------------------------------------------------------

/// Pure-rust backend: kernels + Kronecker algebra in precision `T`,
/// plus the dense-baseline MVM modes (see [`MvmMode`]).
pub struct RustKronBackend<T: Scalar = f64> {
    /// The product kernel (hyperparameters installed by `set_hypers`).
    pub kernel: ProductGridKernel,
    /// Which MVM implementation `system_mvm` runs.
    pub mode: MvmMode,
    /// Requested time-factor engine (resolved against the grid and
    /// kernel family in `set_data`; see [`TimeOpChoice`]).
    time_choice: TimeOpChoice,
    /// Resolved time-factor path actually applied by `system_mvm`.
    time_path: TimeOpPath,
    probes: usize,
    s: Matrix<f64>,
    t: Vec<f64>,
    mask: Vec<f64>,
    log_sigma2: f64,
    sys: Option<MaskedKronSystem<T>>,
    /// dense baseline state (f32 regardless of `T`: that is what the
    /// standard iterative baseline stores on the GPU)
    dense: Option<Matrix<f32>>,
    obs_idx: Vec<usize>,
    kernel_evals: u64,
}

impl<T: Scalar> RustKronBackend<T> {
    /// Backend over `ds` spatial dims and a q-point time grid of the
    /// named family; `probes` Hutchinson probes for the gradient path.
    pub fn new(ds: usize, time_family: &str, q: usize, probes: usize) -> Self {
        RustKronBackend {
            kernel: ProductGridKernel::new(ds, time_family, q),
            mode: MvmMode::Kron,
            time_choice: TimeOpChoice::Dense,
            time_path: TimeOpPath::Dense,
            probes,
            s: Matrix::zeros(0, ds),
            t: Vec::new(),
            mask: Vec::new(),
            log_sigma2: 0.0,
            sys: None,
            dense: None,
            obs_idx: Vec::new(),
            kernel_evals: 0,
        }
    }

    /// Select the MVM mode (builder style).
    pub fn with_mode(mut self, mode: MvmMode) -> Self {
        self.mode = mode;
        self
    }

    /// Select the time-factor engine (builder style). The choice is
    /// resolved against the actual grid and kernel family when
    /// `set_data` runs; `Auto`/`Toeplitz` fall back to dense (with a
    /// warning) when K_TT is not Toeplitz. Call before `set_data`.
    pub fn with_time_op(mut self, choice: TimeOpChoice) -> Self {
        self.time_choice = choice;
        self
    }

    fn sys(&self) -> &MaskedKronSystem<T> {
        self.sys.as_ref().expect("set_hypers not called")
    }

    /// gather padded grid vector -> observed coords
    fn gather(&self, v: &[T]) -> Vec<T> {
        self.obs_idx.iter().map(|&i| v[i]).collect()
    }

    /// scatter observed -> padded grid vector
    fn scatter(&self, v: &[T]) -> Vec<T> {
        let mut out = vec![T::ZERO; self.dim()];
        for (val, &i) in v.iter().zip(&self.obs_idx) {
            out[i] = *val;
        }
        out
    }
}

impl<T: Scalar> KronBackend<T> for RustKronBackend<T> {
    fn dim(&self) -> usize {
        self.s.rows * self.t.len()
    }

    fn probes(&self) -> usize {
        self.probes
    }

    fn set_data(&mut self, s: &Matrix<f64>, t: &[f64], mask: &[f64]) -> Result<()> {
        self.s = s.clone();
        self.t = t.to_vec();
        self.mask = mask.to_vec();
        self.obs_idx =
            (0..mask.len()).filter(|&i| mask[i] != 0.0).collect();
        self.time_path = match self.time_choice {
            TimeOpChoice::Dense => TimeOpPath::Dense,
            req @ (TimeOpChoice::Auto | TimeOpChoice::Toeplitz) => {
                let stationary = self.kernel.time.is_stationary();
                let uniform = !t.is_empty()
                    && matches!(
                        detect_uniform_spacing(t, UNIFORM_GRID_REL_TOL),
                        GridSpacing::Uniform { .. }
                    );
                if stationary && uniform {
                    TimeOpPath::Toeplitz
                } else {
                    eprintln!(
                        "warning: time-op {req:?} requested but K_TT is not Toeplitz \
                         (stationary kernel: {stationary}, uniform grid: {uniform}); \
                         using the dense path"
                    );
                    TimeOpPath::Dense
                }
            }
        };
        self.sys = None;
        self.dense = None;
        Ok(())
    }

    fn set_hypers(&mut self, theta: &[f64], log_sigma2: f64) -> Result<()> {
        self.kernel.set_theta(theta);
        self.log_sigma2 = log_sigma2;
        // Gram factors in the compute precision: the O(p^2 d) spatial
        // Gram runs natively in T (kernels::gram_s_in)
        let kss: Matrix<T> = self.kernel.gram_s_in(&self.s);
        let ktt: Matrix<T> = self.kernel.gram_t_in(&self.t);
        let (p, q) = (kss.rows, ktt.rows);
        self.kernel_evals = (p * p + q * q) as u64;
        let mask_t: Vec<T> = self.mask.iter().map(|&m| T::from_f64(m)).collect();
        let mut op = KronOp::new(kss, ktt);
        if self.time_path == TimeOpPath::Toeplitz {
            // first row of the (exactly symmetric) Gram is the Toeplitz
            // column, widened through the same values the dense path
            // multiplies — no separate kernel evaluation
            let col: Vec<f64> = (0..q).map(|lag| op.ktt[(0, lag)].to_f64()).collect();
            op = op.with_toeplitz(ToeplitzOp::new(&col));
        }
        self.sys = Some(MaskedKronSystem::new(op, mask_t, T::from_f64(log_sigma2.exp())));
        self.dense = None;
        if self.mode == MvmMode::DenseMaterialized {
            // n x n observed Gram in f32 (what the standard iterative
            // baseline stores on the GPU); rows built in parallel
            let sys = self.sys.as_ref().expect("sys installed above");
            let n = self.obs_idx.len();
            let q = sys.op.q();
            let mut dense = Matrix::<f32>::zeros(n, n);
            let obs = &self.obs_idx;
            crate::par::par_chunks_mut("backend.dense_gram", &mut dense.data, n.max(1), |a, row| {
                let ia = obs[a];
                let (sa, ta) = (ia / q, ia % q);
                for (x, &ib) in row.iter_mut().zip(obs.iter()) {
                    let (sb, tb) = (ib / q, ib % q);
                    *x = convert::f32_of(
                        (sys.op.kss[(sa, sb)] * sys.op.ktt[(ta, tb)]).to_f64(),
                    );
                }
            });
            self.kernel_evals = (n * n) as u64;
            self.dense = Some(dense);
        }
        Ok(())
    }

    fn system_mvm(&mut self, v: &Matrix<T>) -> Result<Matrix<T>> {
        let fault = crate::util::failpoint::check("backend_mvm");
        if matches!(fault, Some(crate::util::failpoint::FaultAction::Error)) {
            return Err(anyhow::Error::new(crate::util::failpoint::InjectedFault {
                site: "backend_mvm".into(),
                action: crate::util::failpoint::FaultAction::Error,
            }));
        }
        let mut out = match &self.mode {
            MvmMode::Kron => self.sys().apply_batch(v),
            MvmMode::DenseMaterialized => {
                let dense = self.dense.as_ref().context("dense gram")?;
                let s2 = T::from_f64(self.log_sigma2.exp());
                let obs = &self.obs_idx;
                let mut out = Matrix::zeros(v.rows, v.cols);
                // batch rows are independent systems: one worker per row
                // (gather -> f32 dense MVM -> scatter -> +sigma2 v)
                let cols = v.cols.max(1);
                crate::par::par_chunks_mut("backend.dense_mvm", &mut out.data, cols, |b, orow| {
                    let vrow = v.row(b);
                    let vo32: Vec<f32> =
                        obs.iter().map(|&i| convert::f32_of(vrow[i].to_f64())).collect();
                    for (i, &io) in obs.iter().enumerate() {
                        let row = dense.row(i);
                        let mut sum = 0.0f32;
                        for (k, x) in row.iter().zip(&vo32) {
                            sum += k * x;
                        }
                        orow[io] = T::from_f64(sum as f64);
                    }
                    // sigma2 acts on all padded coords (same convention
                    // as the kron system operator)
                    for (o, vi) in orow.iter_mut().zip(vrow) {
                        *o += s2 * *vi;
                    }
                });
                out
            }
            MvmMode::DenseLazy { block_rows } => {
                let sys = self.sys.as_ref().context("hypers")?;
                let n = self.obs_idx.len();
                let q = sys.op.q();
                let (kss, ktt) = (&sys.op.kss, &sys.op.ktt);
                let obs = &self.obs_idx;
                let entry = |i: usize, j: usize| -> f64 {
                    let (ia, ib) = (obs[i], obs[j]);
                    (kss[(ia / q, ib / q)] * ktt[(ia % q, ib % q)]).to_f64()
                };
                let op = LazyGramOp::new(n, *block_rows, entry, 0.0);
                let s2 = T::from_f64(self.log_sigma2.exp());
                let mut out = Matrix::zeros(v.rows, v.cols);
                let mut vo = Matrix::zeros(v.rows, n);
                for b in 0..v.rows {
                    vo.row_mut(b).copy_from_slice(&self.gather(v.row(b)));
                }
                let (r, evals) = op.apply_batch(&vo);
                // evals counts actual entry evaluations: each block is
                // materialized once and shared across all batch rows
                self.kernel_evals += evals;
                for b in 0..v.rows {
                    let mut padded = self.scatter(r.row(b));
                    for (o, vi) in padded.iter_mut().zip(v.row(b)) {
                        *o += s2 * *vi;
                    }
                    out.row_mut(b).copy_from_slice(&padded);
                }
                out
            }
        };
        if matches!(fault, Some(crate::util::failpoint::FaultAction::Nan)) {
            out[(0, 0)] = T::from_f64(f64::NAN);
        }
        Ok(out)
    }

    fn kron_apply(&mut self, v: &Matrix<T>) -> Result<Matrix<T>> {
        Ok(self.sys().op.apply_batch(v))
    }

    fn prior_sample(&mut self, z: &Matrix<T>) -> Result<Matrix<T>> {
        let sys = self.sys();
        let (p, q) = (sys.op.p(), sys.op.q());
        // Cholesky of the small factors runs in f64 for stability (f64
        // accumulation policy); the O(b pq (p+q)) factor application
        // then runs in the compute precision.
        let mut kss_j: Matrix<f64> = sys.op.kss.cast();
        kss_j.add_diag(1e-4 * kss_j.trace() / p as f64);
        let mut ktt_j: Matrix<f64> = sys.op.ktt.cast();
        ktt_j.add_diag(1e-4 * ktt_j.trace() / q as f64);
        let ls: Matrix<T> = cholesky(&kss_j).context("K_SS cholesky")?.l.cast();
        let lt: Matrix<T> = cholesky(&ktt_j).context("K_TT cholesky")?.l.cast();
        Ok(KronOp::new(ls, lt).apply_batch(z))
    }

    fn mll_grads(
        &mut self,
        alpha: &[T],
        w: &Matrix<T>,
        z: &Matrix<T>,
    ) -> Result<Vec<f64>> {
        // Gradients always accumulate in f64: the contraction against
        // dA/dtheta spans O(p^2) terms of mixed sign, where f32
        // cancellation would feed noise straight into Adam. The casts
        // below copy O(p^2 + q^2 + k pq) values once per Adam iteration
        // (identity copies when T = f64) — a factor ~(p+q) x CG-iters
        // below the solve cost of the same iteration, so not worth a
        // borrow-when-f64 specialization.
        let sys = self.sys();
        let kss64: Matrix<f64> = sys.op.kss.cast();
        let ktt64: Matrix<f64> = sys.op.ktt.cast();
        let alpha64: Vec<f64> = alpha.iter().map(|a| a.to_f64()).collect();
        let w64: Matrix<f64> = w.cast();
        let z64: Matrix<f64> = z.cast();
        let pairs = standard_pairs(&alpha64, &w64, &z64);
        Ok(mll_surrogate_grads(
            &self.kernel,
            &self.s,
            &self.t,
            &kss64,
            &ktt64,
            self.log_sigma2,
            &pairs,
        ))
    }

    fn system_diag(&self) -> Vec<f64> {
        self.sys().diag().iter().map(|d| d.to_f64()).collect()
    }

    fn kernel_col(&self, idx: usize) -> Vec<T> {
        self.sys().kernel_col(idx)
    }

    fn kernel_bytes(&self) -> u64 {
        match &self.mode {
            MvmMode::Kron => {
                let (p, q) = (self.s.rows, self.t.len());
                ((p * p + q * q) * std::mem::size_of::<T>()) as u64
            }
            MvmMode::DenseMaterialized => {
                let n = self.obs_idx.len();
                (n * n * 4) as u64
            }
            MvmMode::DenseLazy { block_rows } => {
                // the lazy row block is materialized in f64 regardless
                // of the compute precision (see kron::lazy)
                (self.obs_idx.len() * block_rows * 8) as u64
            }
        }
    }

    fn kernel_evals(&self) -> u64 {
        self.kernel_evals
    }

    fn gram_factors(&self) -> Option<(Matrix<f64>, Matrix<f64>)> {
        self.sys
            .as_ref()
            .map(|s| (s.op.kss.cast::<f64>(), s.op.ktt.cast::<f64>()))
    }

    fn time_op_path(&self) -> TimeOpPath {
        self.time_path
    }
}

// ---------------------------------------------------------------------
// SKI (sparse kernel interpolation) backend
// ---------------------------------------------------------------------

/// Pure-rust SKI backend: the system operator is
/// `W (K_SS (x) K_TT) W^T + sigma2 I` over the n-point *data space*
/// (`dim() == n`), with `W` a [`SparseProjection`] onto the latent
/// spatial x time inducing grid (see `kron::interp`).
///
/// Grid-space ops (`kron_apply`, `prior_sample`) still act on p*q-wide
/// batches — the pathwise conditioning pipeline projects between the
/// two spaces with `W`/`W^T` (see `fit_interp_inner` in `gp/lkgp.rs`).
/// `gram_factors` returns `None` by design: the direct eigensolver and
/// the `KronEig` preconditioner address the p*q grid system, not the
/// n-point data system, so both fall back to CG exactly as the
/// preconditioner fallback chain prescribes.
pub struct InterpRustBackend<T: Scalar = f64> {
    /// The product kernel (hyperparameters installed by `set_hypers`).
    pub kernel: ProductGridKernel,
    /// Requested time-factor engine (resolved in `set_data`).
    time_choice: TimeOpChoice,
    /// Resolved time-factor path actually applied by `system_mvm`.
    time_path: TimeOpPath,
    probes: usize,
    /// Spatial inducing-grid nodes as a p x 1 matrix (SKI interpolation
    /// requires a 1-D sorted spatial axis).
    s: Matrix<f64>,
    t: Vec<f64>,
    proj: SparseProjection,
    log_sigma2: f64,
    sys: Option<InterpKronSystem<T>>,
    kernel_evals: u64,
}

impl<T: Scalar> InterpRustBackend<T> {
    /// Backend over a q-point time grid of the named family with the
    /// given interpolation projection; `probes` Hutchinson probes for
    /// the gradient path. The spatial axis is 1-D (`ds = 1`).
    pub fn new(time_family: &str, q: usize, probes: usize, proj: SparseProjection) -> Self {
        InterpRustBackend {
            kernel: ProductGridKernel::new(1, time_family, q),
            time_choice: TimeOpChoice::Dense,
            time_path: TimeOpPath::Dense,
            probes,
            s: Matrix::zeros(0, 1),
            t: Vec::new(),
            proj,
            log_sigma2: 0.0,
            sys: None,
            kernel_evals: 0,
        }
    }

    /// Select the time-factor engine (builder style); resolved against
    /// the actual grid and kernel family when `set_data` runs, exactly
    /// like [`RustKronBackend::with_time_op`].
    pub fn with_time_op(mut self, choice: TimeOpChoice) -> Self {
        self.time_choice = choice;
        self
    }

    /// The interpolation projection this backend applies.
    pub fn proj(&self) -> &SparseProjection {
        &self.proj
    }

    fn sys(&self) -> &InterpKronSystem<T> {
        self.sys.as_ref().expect("set_hypers not called")
    }
}

impl<T: Scalar> KronBackend<T> for InterpRustBackend<T> {
    /// Data-space dimension n (NOT the grid size p*q — the SKI system
    /// is n x n).
    fn dim(&self) -> usize {
        self.proj.n()
    }

    fn probes(&self) -> usize {
        self.probes
    }

    /// Install the inducing grids (`s` is the p x 1 spatial node list,
    /// `t` the time grid). The mask argument is ignored — the
    /// projection already encodes which grid cells each data point
    /// touches.
    fn set_data(&mut self, s: &Matrix<f64>, t: &[f64], _mask: &[f64]) -> Result<()> {
        if s.rows != self.proj.grid_p() || s.cols != 1 {
            bail!(
                "spatial grid {}x{} does not match projection ({} x 1 expected)",
                s.rows,
                s.cols,
                self.proj.grid_p()
            );
        }
        if t.len() != self.proj.grid_q() {
            bail!("time grid {} does not match projection ({})", t.len(), self.proj.grid_q());
        }
        self.s = s.clone();
        self.t = t.to_vec();
        self.time_path = match self.time_choice {
            TimeOpChoice::Dense => TimeOpPath::Dense,
            req @ (TimeOpChoice::Auto | TimeOpChoice::Toeplitz) => {
                let stationary = self.kernel.time.is_stationary();
                let uniform = !t.is_empty()
                    && matches!(
                        detect_uniform_spacing(t, UNIFORM_GRID_REL_TOL),
                        GridSpacing::Uniform { .. }
                    );
                if stationary && uniform {
                    TimeOpPath::Toeplitz
                } else {
                    eprintln!(
                        "warning: time-op {req:?} requested but K_TT is not Toeplitz \
                         (stationary kernel: {stationary}, uniform grid: {uniform}); \
                         using the dense path"
                    );
                    TimeOpPath::Dense
                }
            }
        };
        self.sys = None;
        Ok(())
    }

    fn set_hypers(&mut self, theta: &[f64], log_sigma2: f64) -> Result<()> {
        self.kernel.set_theta(theta);
        self.log_sigma2 = log_sigma2;
        let kss: Matrix<T> = self.kernel.gram_s_in(&self.s);
        let ktt: Matrix<T> = self.kernel.gram_t_in(&self.t);
        let (p, q) = (kss.rows, ktt.rows);
        self.kernel_evals = (p * p + q * q) as u64;
        let mut op = KronOp::new(kss, ktt);
        if self.time_path == TimeOpPath::Toeplitz {
            let col: Vec<f64> = (0..q).map(|lag| op.ktt[(0, lag)].to_f64()).collect();
            op = op.with_toeplitz(ToeplitzOp::new(&col));
        }
        self.sys = Some(InterpKronSystem::new(
            op,
            self.proj.clone(),
            T::from_f64(log_sigma2.exp()),
        ));
        Ok(())
    }

    fn system_mvm(&mut self, v: &Matrix<T>) -> Result<Matrix<T>> {
        let fault = crate::util::failpoint::check("backend_mvm");
        if matches!(fault, Some(crate::util::failpoint::FaultAction::Error)) {
            return Err(anyhow::Error::new(crate::util::failpoint::InjectedFault {
                site: "backend_mvm".into(),
                action: crate::util::failpoint::FaultAction::Error,
            }));
        }
        let mut out = self.sys().apply_batch(v);
        if matches!(fault, Some(crate::util::failpoint::FaultAction::Nan)) {
            out[(0, 0)] = T::from_f64(f64::NAN);
        }
        Ok(out)
    }

    /// Unmasked grid-space cross-covariance apply: `v` is p*q wide
    /// (not n) — the pathwise pipeline projects into grid space first.
    fn kron_apply(&mut self, v: &Matrix<T>) -> Result<Matrix<T>> {
        Ok(self.sys().op.apply_batch(v))
    }

    /// Grid-space prior sample: `z` is p*q wide (not n).
    fn prior_sample(&mut self, z: &Matrix<T>) -> Result<Matrix<T>> {
        let sys = self.sys();
        let (p, q) = (sys.op.p(), sys.op.q());
        let mut kss_j: Matrix<f64> = sys.op.kss.cast();
        kss_j.add_diag(1e-4 * kss_j.trace() / p as f64);
        let mut ktt_j: Matrix<f64> = sys.op.ktt.cast();
        ktt_j.add_diag(1e-4 * ktt_j.trace() / q as f64);
        let ls: Matrix<T> = cholesky(&kss_j).context("K_SS cholesky")?.l.cast();
        let lt: Matrix<T> = cholesky(&ktt_j).context("K_TT cholesky")?.l.cast();
        Ok(KronOp::new(ls, lt).apply_batch(z))
    }

    fn mll_grads(
        &mut self,
        alpha: &[T],
        w: &Matrix<T>,
        z: &Matrix<T>,
    ) -> Result<Vec<f64>> {
        // Kernel gradients: a^T W dK W^T a = (W^T a)^T dK (W^T a), so
        // projecting every pair vector onto the grid in f64 reduces the
        // SKI gradient to the existing grid-space contraction. The
        // noise gradient is the one term that lives in data space
        // (dA/dlog_s2 = s2 I_n), so it is recomputed below and
        // overwrites the grid-space value.
        let sys = self.sys();
        let kss64: Matrix<f64> = sys.op.kss.cast();
        let ktt64: Matrix<f64> = sys.op.ktt.cast();
        let alpha64: Vec<f64> = alpha.iter().map(|a| a.to_f64()).collect();
        let w64: Matrix<f64> = w.cast();
        let z64: Matrix<f64> = z.cast();
        let ga = self.proj.project_vec_f64(&alpha64);
        let gw = self.proj.interp_apply_t(&w64);
        let gz = self.proj.interp_apply_t(&z64);
        let grid_pairs = standard_pairs(&ga, &gw, &gz);
        let mut grads = mll_surrogate_grads(
            &self.kernel,
            &self.s,
            &self.t,
            &kss64,
            &ktt64,
            self.log_sigma2,
            &grid_pairs,
        );
        // d/dlog_s2 [ s2 * sum coef u^T v ] accumulated over the
        // data-space pairs, same fold order as mll_surrogate_grads
        let data_pairs = standard_pairs(&alpha64, &w64, &z64);
        let mut uv_sum = 0.0;
        for pair in &data_pairs {
            let mut d = 0.0;
            for (a, b) in pair.u.iter().zip(pair.v) {
                d += a * b;
            }
            uv_sum += pair.coef * d;
        }
        let last = grads.len() - 1;
        grads[last] = self.log_sigma2.exp() * uv_sum;
        Ok(grads)
    }

    fn system_diag(&self) -> Vec<f64> {
        self.sys().diag().iter().map(|d| d.to_f64()).collect()
    }

    fn kernel_col(&self, idx: usize) -> Vec<T> {
        self.sys().kernel_col(idx)
    }

    fn kernel_bytes(&self) -> u64 {
        let (p, q) = (self.proj.grid_p(), self.proj.grid_q());
        let factors = (p * p + q * q) * std::mem::size_of::<T>();
        let proj = self.proj.nnz() * (std::mem::size_of::<f64>() + std::mem::size_of::<usize>());
        (factors + proj) as u64
    }

    fn kernel_evals(&self) -> u64 {
        self.kernel_evals
    }

    fn time_op_path(&self) -> TimeOpPath {
        self.time_path
    }
}

// ---------------------------------------------------------------------
// PJRT backend (the three-layer production path)
// ---------------------------------------------------------------------

/// The production three-layer backend: all five LKGP operations run as
/// AOT-compiled Pallas/JAX artifacts on the PJRT CPU client.
pub struct PjrtKronBackend {
    rt: Runtime,
    /// Artifact configuration name this backend executes.
    pub config: String,
    p: usize,
    q: usize,
    ds: usize,
    batch: usize,
    n_probes: usize,
    n_theta: usize,
    // state tensors (f32, PJRT boundary)
    s32: Vec<f32>,
    t32: Vec<f32>,
    mask32: Vec<f32>,
    theta32: Vec<f32>,
    log_sigma2: f64,
    // Gram matrices fetched back to host after `kernels` runs (used by
    // preconditioner construction; p^2 + q^2 floats, cheap by design)
    kss: Vec<f32>,
    ktt: Vec<f32>,
    fresh: bool,
}

impl PjrtKronBackend {
    /// Build over the named artifact config; verifies shape compatibility.
    pub fn new(rt: Runtime, config: &str) -> Result<Self> {
        let meta = rt.manifest.config(config)?.clone();
        Ok(PjrtKronBackend {
            rt,
            config: config.to_string(),
            p: meta.p,
            q: meta.q,
            ds: meta.ds,
            batch: meta.batch,
            n_probes: meta.probes,
            n_theta: meta.n_theta,
            s32: vec![],
            t32: vec![],
            mask32: vec![],
            theta32: vec![],
            log_sigma2: 0.0,
            kss: vec![],
            ktt: vec![],
            fresh: false,
        })
    }

    /// The PJRT runtime (shared across fits by the experiment harness).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Run an artifact over a batched matrix, chunking rows into the
    /// config's static batch size (zero-padding the tail chunk).
    fn exec_batched(
        &mut self,
        artifact: &str,
        fixed: &[TensorF32],
        v: &Matrix<f64>,
    ) -> Result<Matrix<f64>> {
        let pq = self.p * self.q;
        assert_eq!(v.cols, pq);
        let mut out = Matrix::zeros(v.rows, pq);
        let b = self.batch;
        let mut row = 0;
        while row < v.rows {
            let take = (v.rows - row).min(b);
            let mut chunk = vec![0.0f32; b * pq];
            for r in 0..take {
                for (c, x) in v.row(row + r).iter().enumerate() {
                    chunk[r * pq + c] = convert::f32_of(*x);
                }
            }
            let mut inputs = fixed.to_vec();
            inputs.push(TensorF32::new(vec![b, pq], chunk));
            let res = self.rt.exec_f32(&self.config, artifact, &inputs)?;
            let y = &res[0];
            for r in 0..take {
                for c in 0..pq {
                    out[(row + r, c)] = y[r * pq + c] as f64;
                }
            }
            row += take;
        }
        Ok(out)
    }

    fn gram_inputs(&self) -> [TensorF32; 2] {
        [
            TensorF32::new(vec![self.p, self.p], self.kss.clone()),
            TensorF32::new(vec![self.q, self.q], self.ktt.clone()),
        ]
    }

    fn check_fresh(&self) -> Result<()> {
        if !self.fresh {
            bail!("set_hypers must be called before backend ops");
        }
        Ok(())
    }
}

impl KronBackend<f64> for PjrtKronBackend {
    fn dim(&self) -> usize {
        self.p * self.q
    }

    fn probes(&self) -> usize {
        self.n_probes
    }

    fn set_data(&mut self, s: &Matrix<f64>, t: &[f64], mask: &[f64]) -> Result<()> {
        if s.rows != self.p || s.cols != self.ds || t.len() != self.q {
            bail!(
                "data ({}x{}, q={}) does not match artifact config {:?} ({}x{}, q={})",
                s.rows,
                s.cols,
                t.len(),
                self.config,
                self.p,
                self.ds,
                self.q
            );
        }
        self.s32 = convert::f32_vec(&s.data);
        self.t32 = convert::f32_vec(t);
        self.mask32 = convert::f32_vec(mask);
        self.fresh = false;
        Ok(())
    }

    fn set_hypers(&mut self, theta: &[f64], log_sigma2: f64) -> Result<()> {
        if theta.len() != self.n_theta {
            bail!("theta len {} != {}", theta.len(), self.n_theta);
        }
        self.theta32 = convert::f32_vec(theta);
        self.log_sigma2 = log_sigma2;
        let out = self.rt.exec_f32(
            &self.config,
            "kernels",
            &[
                TensorF32::new(vec![self.p, self.ds], self.s32.clone()),
                TensorF32::new(vec![self.q, 1], self.t32.clone()),
                TensorF32::vec1(self.theta32.clone()),
            ],
        )?;
        self.kss = out[0].clone();
        self.ktt = out[1].clone();
        self.fresh = true;
        Ok(())
    }

    fn system_mvm(&mut self, v: &Matrix<f64>) -> Result<Matrix<f64>> {
        let fault = crate::util::failpoint::check("backend_mvm");
        if matches!(fault, Some(crate::util::failpoint::FaultAction::Error)) {
            return Err(anyhow::Error::new(crate::util::failpoint::InjectedFault {
                site: "backend_mvm".into(),
                action: crate::util::failpoint::FaultAction::Error,
            }));
        }
        self.check_fresh()?;
        let [kss, ktt] = self.gram_inputs();
        let fixed = [
            kss,
            ktt,
            TensorF32::vec1(self.mask32.clone()),
            TensorF32::scalar(convert::f32_of(self.log_sigma2.exp())),
        ];
        let mut out = self.exec_batched("kron_mvm", &fixed, v)?;
        if matches!(fault, Some(crate::util::failpoint::FaultAction::Nan)) {
            out[(0, 0)] = f64::NAN;
        }
        Ok(out)
    }

    fn kron_apply(&mut self, v: &Matrix<f64>) -> Result<Matrix<f64>> {
        self.check_fresh()?;
        let fixed = self.gram_inputs();
        self.exec_batched("kron_apply", &fixed, v)
    }

    fn prior_sample(&mut self, z: &Matrix<f64>) -> Result<Matrix<f64>> {
        self.check_fresh()?;
        // Cholesky of the small factors happens host-side in f64 (setup
        // op; the artifact's job is the O(b pq (p+q)) factor application
        // — see python/compile/model.py::build_prior_sample).
        let to_f64 = |v: &[f32], n: usize| -> Matrix<f64> {
            Matrix::from_vec(n, n, crate::util::convert::f64_vec(v))
        };
        let chol_jittered = |mut m: Matrix<f64>| -> Result<Matrix<f64>> {
            let n = m.rows;
            m.add_diag(1e-4 * m.trace() / n as f64);
            Ok(cholesky(&m).context("gram cholesky")?.l)
        };
        let ls = chol_jittered(to_f64(&self.kss, self.p))?;
        let lt = chol_jittered(to_f64(&self.ktt, self.q))?;
        let fixed = [
            TensorF32::from_f64(vec![self.p, self.p], &ls.data),
            TensorF32::from_f64(vec![self.q, self.q], &lt.data),
        ];
        self.exec_batched("prior_sample", &fixed, z)
    }

    fn mll_grads(
        &mut self,
        alpha: &[f64],
        w: &Matrix<f64>,
        z: &Matrix<f64>,
    ) -> Result<Vec<f64>> {
        self.check_fresh()?;
        let k = self.n_probes;
        if w.rows != k || z.rows != k {
            bail!("probe count {} != artifact's static {}", w.rows, k);
        }
        let pq = self.p * self.q;
        let out = self.rt.exec_f32(
            &self.config,
            "mll_grads",
            &[
                TensorF32::new(vec![self.p, self.ds], self.s32.clone()),
                TensorF32::new(vec![self.q, 1], self.t32.clone()),
                TensorF32::vec1(self.theta32.clone()),
                TensorF32::scalar(convert::f32_of(self.log_sigma2)),
                TensorF32::vec1(self.mask32.clone()),
                TensorF32::from_f64(vec![pq], alpha),
                TensorF32::from_f64(vec![k, pq], &w.data),
                TensorF32::from_f64(vec![k, pq], &z.data),
            ],
        )?;
        Ok(out[0].iter().map(|&x| x as f64).collect())
    }

    fn system_diag(&self) -> Vec<f64> {
        let s2 = self.log_sigma2.exp();
        let mut d = Vec::with_capacity(self.p * self.q);
        for j in 0..self.p {
            let ks = self.kss[j * self.p + j] as f64;
            for kk in 0..self.q {
                let idx = j * self.q + kk;
                d.push(
                    self.mask32[idx] as f64 * ks * self.ktt[kk * self.q + kk] as f64 + s2,
                );
            }
        }
        d
    }

    fn kernel_col(&self, idx: usize) -> Vec<f64> {
        let (j0, k0) = (idx / self.q, idx % self.q);
        let mcol = self.mask32[idx] as f64;
        let mut col = Vec::with_capacity(self.p * self.q);
        for j in 0..self.p {
            let ks = self.kss[j * self.p + j0] as f64;
            for kk in 0..self.q {
                let v = ks * self.ktt[kk * self.q + k0] as f64;
                col.push(v * self.mask32[j * self.q + kk] as f64 * mcol);
            }
        }
        col
    }

    fn kernel_bytes(&self) -> u64 {
        ((self.p * self.p + self.q * self.q) * 4) as u64
    }

    fn kernel_evals(&self) -> u64 {
        ((self.p * self.p) + (self.q * self.q)) as u64
    }

    fn gram_factors(&self) -> Option<(Matrix<f64>, Matrix<f64>)> {
        if !self.fresh {
            return None;
        }
        Some((
            Matrix::from_vec(self.p, self.p, convert::f64_vec(&self.kss)),
            Matrix::from_vec(self.q, self.q, convert::f64_vec(&self.ktt)),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy_backend_in<T: Scalar>(mode: MvmMode) -> RustKronBackend<T> {
        let mut rng = Rng::new(7);
        let (p, q, ds) = (8, 5, 2);
        let s = Matrix::from_vec(p, ds, rng.normals(p * ds));
        let t: Vec<f64> = (0..q).map(|k| k as f64 / (q - 1) as f64).collect();
        let mut mask = vec![1.0; p * q];
        for i in (0..p * q).step_by(3) {
            mask[i] = 0.0;
        }
        let mut be = RustKronBackend::<T>::new(ds, "rbf", q, 4).with_mode(mode);
        be.set_data(&s, &t, &mask).unwrap();
        be.set_hypers(&vec![0.0; be.kernel.n_theta()], -1.5).unwrap();
        be
    }

    fn toy_backend(mode: MvmMode) -> RustKronBackend {
        toy_backend_in::<f64>(mode)
    }

    /// Same data/hypers as `toy_backend`, routed through `choice`.
    fn toy_backend_time_op(choice: TimeOpChoice) -> RustKronBackend {
        let mut rng = Rng::new(7);
        let (p, q, ds) = (8, 5, 2);
        let s = Matrix::from_vec(p, ds, rng.normals(p * ds));
        let t: Vec<f64> = (0..q).map(|k| k as f64 / (q - 1) as f64).collect();
        let mut mask = vec![1.0; p * q];
        for i in (0..p * q).step_by(3) {
            mask[i] = 0.0;
        }
        let mut be = RustKronBackend::new(ds, "rbf", q, 4).with_time_op(choice);
        be.set_data(&s, &t, &mask).unwrap();
        be.set_hypers(&vec![0.0; be.kernel.n_theta()], -1.5).unwrap();
        be
    }

    #[test]
    fn time_op_resolves_against_grid_and_family() {
        let mut rng = Rng::new(21);
        let (p, q, ds) = (6, 8, 2);
        let s = Matrix::from_vec(p, ds, rng.normals(p * ds));
        let t: Vec<f64> = (0..q).map(|k| k as f64 * 0.25).collect();
        let mask = vec![1.0; p * q];
        let mut resolve = |choice, t: &[f64], family: &str| {
            let mut be = RustKronBackend::<f64>::new(ds, family, q, 2).with_time_op(choice);
            be.set_data(&s, t, &mask).unwrap();
            be.time_op_path()
        };
        assert_eq!(resolve(TimeOpChoice::Dense, &t, "rbf"), TimeOpPath::Dense);
        assert_eq!(resolve(TimeOpChoice::Auto, &t, "rbf"), TimeOpPath::Toeplitz);
        assert_eq!(resolve(TimeOpChoice::Toeplitz, &t, "rbf"), TimeOpPath::Toeplitz);
        // irregular grid falls back to dense
        let mut tj = t.clone();
        tj[3] += 0.1;
        assert_eq!(resolve(TimeOpChoice::Auto, &tj, "rbf"), TimeOpPath::Dense);
        assert_eq!(resolve(TimeOpChoice::Toeplitz, &tj, "rbf"), TimeOpPath::Dense);
        // non-stationary (task-indexed) family falls back to dense
        assert_eq!(resolve(TimeOpChoice::Auto, &t, "icm"), TimeOpPath::Dense);
    }

    #[test]
    fn toeplitz_time_op_matches_dense_backend_mvm() {
        let mut rng = Rng::new(23);
        let mut be_d = toy_backend_time_op(TimeOpChoice::Dense);
        let mut be_t = toy_backend_time_op(TimeOpChoice::Toeplitz);
        assert_eq!(be_d.time_op_path(), TimeOpPath::Dense);
        assert_eq!(be_t.time_op_path(), TimeOpPath::Toeplitz);
        let v = Matrix::from_vec(3, be_d.dim(), rng.normals(3 * be_d.dim()));
        let a = be_d.system_mvm(&v).unwrap();
        let b = be_t.system_mvm(&v).unwrap();
        let scale = a.max_abs().max(1.0);
        for i in 0..a.data.len() {
            assert!(
                (a.data[i] - b.data[i]).abs() < 1e-9 * scale,
                "idx {i}: {} vs {}",
                a.data[i],
                b.data[i]
            );
        }
        // the cross-covariance apply routes through the same TimeOp
        let ka = be_d.kron_apply(&v).unwrap();
        let kb = be_t.kron_apply(&v).unwrap();
        for i in 0..ka.data.len() {
            assert!((ka.data[i] - kb.data[i]).abs() < 1e-9 * scale, "kron idx {i}");
        }
    }

    #[test]
    fn dense_modes_match_kron_mvm() {
        let mut rng = Rng::new(11);
        let mut kron = toy_backend(MvmMode::Kron);
        let mut dense = toy_backend(MvmMode::DenseMaterialized);
        let mut lazy = toy_backend(MvmMode::DenseLazy { block_rows: 3 });
        let v = Matrix::from_vec(2, kron.dim(), rng.normals(2 * kron.dim()));
        // dense modes only act on the observed subspace; compare there
        let mut vm = v.clone();
        for b in 0..2 {
            for (x, m) in vm.row_mut(b).iter_mut().zip(&kron.mask) {
                *x *= *m;
            }
        }
        let a = kron.system_mvm(&vm).unwrap();
        let b = dense.system_mvm(&vm).unwrap();
        let c = lazy.system_mvm(&vm).unwrap();
        for i in 0..a.data.len() {
            assert!((a.data[i] - b.data[i]).abs() < 1e-3, "dense idx {i}");
            assert!((a.data[i] - c.data[i]).abs() < 1e-6, "lazy idx {i}");
        }
    }

    #[test]
    fn f32_backend_mvm_close_to_f64() {
        let mut rng = Rng::new(13);
        let mut be64 = toy_backend(MvmMode::Kron);
        let mut be32 = toy_backend_in::<f32>(MvmMode::Kron);
        let v64 = Matrix::from_vec(2, be64.dim(), rng.normals(2 * be64.dim()));
        let v32: Matrix<f32> = v64.cast();
        let a = be64.system_mvm(&v64).unwrap();
        let b = be32.system_mvm(&v32).unwrap();
        let scale = a.max_abs().max(1.0);
        for i in 0..a.data.len() {
            let diff = (a.data[i] - b.data[i] as f64).abs();
            assert!(diff < 1e-4 * scale, "idx {i}: {} vs {}", a.data[i], b.data[i]);
        }
        // precision switch halves the factored-kernel footprint
        assert_eq!(be32.kernel_bytes() * 2, be64.kernel_bytes());
    }

    #[test]
    fn f32_backend_dense_modes_agree_with_kron() {
        let mut rng = Rng::new(17);
        let mut kron = toy_backend_in::<f32>(MvmMode::Kron);
        let mut dense = toy_backend_in::<f32>(MvmMode::DenseMaterialized);
        let mut lazy = toy_backend_in::<f32>(MvmMode::DenseLazy { block_rows: 3 });
        let v64 = Matrix::from_vec(2, kron.dim(), rng.normals(2 * kron.dim()));
        let mut vm: Matrix<f32> = v64.cast();
        for b in 0..2 {
            for (x, m) in vm.row_mut(b).iter_mut().zip(&kron.mask) {
                *x *= *m as f32;
            }
        }
        let a = kron.system_mvm(&vm).unwrap();
        let b = dense.system_mvm(&vm).unwrap();
        let c = lazy.system_mvm(&vm).unwrap();
        for i in 0..a.data.len() {
            assert!((a.data[i] - b.data[i]).abs() < 1e-2, "dense idx {i}");
            assert!((a.data[i] - c.data[i]).abs() < 1e-2, "lazy idx {i}");
        }
    }

    #[test]
    fn kernel_bytes_ordering() {
        let kron = toy_backend(MvmMode::Kron);
        let dense = toy_backend(MvmMode::DenseMaterialized);
        // 8x5 grid with 1/3 missing: n ~ 26, n^2*4 ~ 2.7 KB vs (64+25)*8
        assert!(kron.kernel_bytes() < dense.kernel_bytes());
    }

    #[test]
    fn prior_sample_has_kernel_covariance() {
        let mut be = toy_backend(MvmMode::Kron);
        let mut rng = Rng::new(3);
        let nsamp = 2000;
        let z = Matrix::from_vec(nsamp, be.dim(), rng.normals(nsamp * be.dim()));
        let f = be.prior_sample(&z).unwrap();
        // marginal variance ~ diag(K (x) K) = 1 (unit outputscale/kernels)
        for c in 0..be.dim() {
            let var: f64 = (0..nsamp).map(|r| f[(r, c)] * f[(r, c)]).sum::<f64>() / nsamp as f64;
            assert!((var - 1.0).abs() < 0.2, "cell {c} var {var}");
        }
    }
}
