//! Fit/serve health diagnostics.
//!
//! Every `Lkgp::fit` (and `serve::ServeEngine` reconstruction) records
//! what its iterative solves actually did — iterations, residuals,
//! non-convergence, recovery actions taken — in a [`FitDiagnostics`]
//! attached to the result. A fit that silently recovered (preconditioner
//! fallback, MVM retry, CG restart) still succeeds, but the diagnostics
//! make the recovery visible to the CLI, the serving layer, and tests.

/// What to do when a CG solve finishes without reaching its tolerance.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OnNonConverged {
    /// Record it in [`FitDiagnostics`] and print one warning per fit
    /// (the default — matches the paper's loose 0.01 tolerance, where a
    /// near-miss is usually benign).
    #[default]
    Warn,
    /// Fail the fit with a typed `SolveError::NotConverged`.
    Error,
}

impl OnNonConverged {
    /// Parse `"warn"` / `"error"` (case-insensitive).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "warn" => Ok(OnNonConverged::Warn),
            "error" => Ok(OnNonConverged::Error),
            _ => Err(format!("invalid on_nonconverged value {s:?} (expected warn|error)")),
        }
    }

    /// Read `LKGP_ON_NONCONVERGED` from the environment (default Warn;
    /// an invalid value warns and falls back to Warn).
    pub fn from_env() -> Self {
        match std::env::var("LKGP_ON_NONCONVERGED") {
            Ok(v) if !v.trim().is_empty() => Self::parse(v.trim()).unwrap_or_else(|e| {
                eprintln!("warning: {e}; using warn");
                OnNonConverged::Warn
            }),
            _ => OnNonConverged::Warn,
        }
    }
}

/// Preconditioner strength levels, ordered by the fallback chain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrecondLevel {
    /// The paper's pivoted-Cholesky + Woodbury preconditioner.
    PivotedCholesky,
    /// Diagonal (Jacobi) scaling.
    Jacobi,
    /// No preconditioning.
    Identity,
}

impl std::fmt::Display for PrecondLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PrecondLevel::PivotedCholesky => write!(f, "pivoted-cholesky"),
            PrecondLevel::Jacobi => write!(f, "jacobi"),
            PrecondLevel::Identity => write!(f, "identity"),
        }
    }
}

/// One preconditioner downgrade taken during a fit.
#[derive(Clone, Debug)]
pub struct PrecondFallback {
    /// Level that failed.
    pub from: PrecondLevel,
    /// Level that replaced it.
    pub to: PrecondLevel,
    /// Human-readable cause (construction error, indefinite apply, ...).
    pub reason: String,
}

/// Health report of one fit (or serve reconstruction).
///
/// All counters are deterministic for a given input and configuration:
/// they reflect solver decisions made on f64 reductions with fixed
/// order, never on timing or thread count.
#[derive(Clone, Debug, Default)]
pub struct FitDiagnostics {
    /// CG solves performed (train + pathwise batches).
    pub cg_solves: usize,
    /// How many of those finished without reaching the tolerance.
    pub nonconverged_solves: usize,
    /// Largest final relative residual observed across all solves.
    pub worst_rel_residual: f64,
    /// Stagnation restarts taken inside CG.
    pub cg_restarts: usize,
    /// Total CG iterations across all solves.
    pub cg_iters_total: usize,
    /// Total batched MVMs across all solves.
    pub mvm_total: usize,
    /// Backend MVM retries that recovered a transient failure.
    pub backend_retries: u64,
    /// Preconditioner downgrades taken (empty on a healthy fit).
    pub precond_fallbacks: Vec<PrecondFallback>,
    /// Hyperparameter gradient entries skipped because they were
    /// NaN/Inf (see `optim::adam`): a nonzero count flags a diverging
    /// hyperparameter search that would otherwise be invisible.
    pub grads_skipped_nonfinite: u64,
}

impl FitDiagnostics {
    /// True when the fit needed no recovery and every solve converged.
    pub fn healthy(&self) -> bool {
        self.nonconverged_solves == 0
            && self.cg_restarts == 0
            && self.backend_retries == 0
            && self.precond_fallbacks.is_empty()
            && self.grads_skipped_nonfinite == 0
    }

    /// Multi-line human-readable report (CLI `train` output).
    pub fn render(&self) -> String {
        let mut s = format!(
            "  cg: {} solves, {} iters, {} mvms, worst rel residual {:.3e}\n",
            self.cg_solves, self.cg_iters_total, self.mvm_total, self.worst_rel_residual
        );
        s += &format!(
            "  recovery: {} non-converged, {} restarts, {} mvm retries, {} skipped grads\n",
            self.nonconverged_solves,
            self.cg_restarts,
            self.backend_retries,
            self.grads_skipped_nonfinite
        );
        if self.precond_fallbacks.is_empty() {
            s += "  preconditioner: no fallbacks";
        } else {
            for f in &self.precond_fallbacks {
                s += &format!("  preconditioner: {} -> {} ({})\n", f.from, f.to, f.reason);
            }
            s.pop();
        }
        s
    }
}

impl std::fmt::Display for FitDiagnostics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_policy() {
        assert_eq!(OnNonConverged::parse("warn"), Ok(OnNonConverged::Warn));
        assert_eq!(OnNonConverged::parse("ERROR"), Ok(OnNonConverged::Error));
        assert!(OnNonConverged::parse("panic").is_err());
        assert_eq!(OnNonConverged::default(), OnNonConverged::Warn);
    }

    #[test]
    fn healthy_and_render() {
        let mut d = FitDiagnostics::default();
        assert!(d.healthy());
        d.precond_fallbacks.push(PrecondFallback {
            from: PrecondLevel::PivotedCholesky,
            to: PrecondLevel::Jacobi,
            reason: "capacitance not PD".into(),
        });
        d.nonconverged_solves = 1;
        assert!(!d.healthy());
        let r = d.render();
        assert!(r.contains("pivoted-cholesky -> jacobi"), "{r}");
        assert!(r.contains("1 non-converged"), "{r}");
    }
}
