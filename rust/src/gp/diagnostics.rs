//! Fit/serve health diagnostics.
//!
//! Every `Lkgp::fit` (and `serve::ServeEngine` reconstruction) records
//! what its iterative solves actually did — iterations, residuals,
//! non-convergence, recovery actions taken — in a [`FitDiagnostics`]
//! attached to the result. A fit that silently recovered (preconditioner
//! fallback, MVM retry, CG restart) still succeeds, but the diagnostics
//! make the recovery visible to the CLI, the serving layer, and tests.

/// What to do when a CG solve finishes without reaching its tolerance.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OnNonConverged {
    /// Record it in [`FitDiagnostics`] and print one warning per fit
    /// (the default — matches the paper's loose 0.01 tolerance, where a
    /// near-miss is usually benign).
    #[default]
    Warn,
    /// Fail the fit with a typed `SolveError::NotConverged`.
    Error,
}

impl OnNonConverged {
    /// Parse `"warn"` / `"error"` (case-insensitive).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "warn" => Ok(OnNonConverged::Warn),
            "error" => Ok(OnNonConverged::Error),
            _ => Err(format!("invalid on_nonconverged value {s:?} (expected warn|error)")),
        }
    }

    /// Read `LKGP_ON_NONCONVERGED` from the environment (default Warn;
    /// an invalid value warns and falls back to Warn).
    pub fn from_env() -> Self {
        match std::env::var("LKGP_ON_NONCONVERGED") {
            Ok(v) if !v.trim().is_empty() => Self::parse(v.trim()).unwrap_or_else(|e| {
                eprintln!("warning: {e}; using warn");
                OnNonConverged::Warn
            }),
            _ => OnNonConverged::Warn,
        }
    }
}

/// Which linear-system engine `Lkgp::fit` should use
/// (config `LkgpConfig::solver`, env `LKGP_SOLVER`, CLI `--solver`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Solver {
    /// Pick automatically: the exact per-factor eigendecomposition
    /// solver on fully-observed grids (zero CG iterations), plain CG
    /// everywhere else — bit-identical to `Cg` on any masked grid.
    #[default]
    Auto,
    /// Always run (preconditioned) CG — the paper's default engine.
    Cg,
    /// Force the eigendecomposition path: direct spectral solves on
    /// fully-observed grids; under masking, CG with the latent-grid
    /// `KronEig` preconditioner ahead of pivoted Cholesky.
    Eig,
}

impl Solver {
    /// Parse `"auto"` / `"cg"` / `"eig"` (case-insensitive).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(Solver::Auto),
            "cg" => Ok(Solver::Cg),
            "eig" => Ok(Solver::Eig),
            _ => Err(format!("invalid solver value {s:?} (expected cg|eig|auto)")),
        }
    }

    /// Read `LKGP_SOLVER` from the environment (default Auto; an
    /// invalid value warns and falls back to Auto).
    pub fn from_env() -> Self {
        match std::env::var("LKGP_SOLVER") {
            Ok(v) if !v.trim().is_empty() => Self::parse(v.trim()).unwrap_or_else(|e| {
                eprintln!("warning: {e}; using auto");
                Solver::Auto
            }),
            _ => Solver::Auto,
        }
    }
}

/// Which solver path actually produced a result (recorded in
/// [`FitDiagnostics`]; the request lives in `LkgpConfig::solver`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SolverPath {
    /// Batched preconditioned conjugate gradients.
    #[default]
    Cg,
    /// Direct per-factor eigendecomposition solves (no CG iterations).
    Eig,
    /// Serve-side checkpoint reconstruction: captured pathwise state
    /// replayed through MVMs only, no linear solves at all.
    Replay,
}

impl std::fmt::Display for SolverPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolverPath::Cg => write!(f, "cg"),
            SolverPath::Eig => write!(f, "eig"),
            SolverPath::Replay => write!(f, "mvm-replay"),
        }
    }
}

/// Which engine should apply the `K_TT` half of Kronecker MVMs
/// (config `LkgpConfig::time_op`, env `LKGP_TIME_OP`, CLI `--time-op`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TimeOpChoice {
    /// Engage the O(q log q) Toeplitz/FFT path when the time grid is
    /// detected uniform and the time kernel is stationary; fall back to
    /// dense (with a warning) otherwise.
    Auto,
    /// Always use the dense q x q GEMM — the default, bit-compatible
    /// with the committed golden posterior.
    #[default]
    Dense,
    /// Require the Toeplitz/FFT path; falls back to dense with a
    /// warning when the grid is non-uniform or the kernel
    /// non-stationary (recorded in [`FitDiagnostics::time_op`]).
    Toeplitz,
}

impl TimeOpChoice {
    /// Parse `"auto"` / `"dense"` / `"toeplitz"` (case-insensitive).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(TimeOpChoice::Auto),
            "dense" => Ok(TimeOpChoice::Dense),
            "toeplitz" => Ok(TimeOpChoice::Toeplitz),
            _ => Err(format!("invalid time-op value {s:?} (expected auto|dense|toeplitz)")),
        }
    }

    /// Read `LKGP_TIME_OP` from the environment (default Dense; an
    /// invalid value warns and falls back to Dense).
    pub fn from_env() -> Self {
        match std::env::var("LKGP_TIME_OP") {
            Ok(v) if !v.trim().is_empty() => Self::parse(v.trim()).unwrap_or_else(|e| {
                eprintln!("warning: {e}; using dense");
                TimeOpChoice::Dense
            }),
            _ => TimeOpChoice::Dense,
        }
    }
}

/// Which time-factor engine actually ran (recorded in
/// [`FitDiagnostics`] and persisted in checkpoints so serve replays the
/// identical MVM path; the request lives in `LkgpConfig::time_op`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TimeOpPath {
    /// Dense q x q GEMM for the `K_TT` half of every Kron MVM.
    #[default]
    Dense,
    /// Planned-FFT circulant-embedding MVMs (O(q log q)).
    Toeplitz,
}

impl std::fmt::Display for TimeOpPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TimeOpPath::Dense => write!(f, "dense"),
            TimeOpPath::Toeplitz => write!(f, "toeplitz"),
        }
    }
}

/// Which observation projection `Lkgp::fit` should build (config
/// `LkgpConfig::projection`, env `LKGP_PROJECTION`, CLI `--projection`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ProjectionChoice {
    /// The paper's 0/1 grid mask — training data must lie on (partial)
    /// grid cells. The default, bit-compatible with the committed
    /// golden posterior.
    #[default]
    Mask,
    /// Sparse kernel interpolation (SKI) with the given stencil family:
    /// the system operator becomes `W (K_SS (x) K_TT) W^T + sigma2 I`,
    /// admitting off-grid training inputs
    /// (see [`crate::kron::interp::SparseProjection`]).
    Interp(crate::kron::interp::InterpDegree),
}

impl ProjectionChoice {
    /// Parse `"mask"` / `"interp"` (= linear) / `"interp-cubic"`
    /// (case-insensitive; `"interp-linear"` is accepted as an alias).
    pub fn parse(s: &str) -> Result<Self, String> {
        use crate::kron::interp::InterpDegree;
        match s.to_ascii_lowercase().as_str() {
            "mask" => Ok(ProjectionChoice::Mask),
            "interp" | "interp-linear" => Ok(ProjectionChoice::Interp(InterpDegree::Linear)),
            "interp-cubic" => Ok(ProjectionChoice::Interp(InterpDegree::Cubic)),
            _ => Err(format!(
                "invalid projection value {s:?} (expected mask|interp|interp-cubic)"
            )),
        }
    }

    /// Read `LKGP_PROJECTION` from the environment (default Mask; an
    /// invalid value warns and falls back to Mask).
    pub fn from_env() -> Self {
        match std::env::var("LKGP_PROJECTION") {
            Ok(v) if !v.trim().is_empty() => Self::parse(v.trim()).unwrap_or_else(|e| {
                eprintln!("warning: {e}; using mask");
                ProjectionChoice::Mask
            }),
            _ => ProjectionChoice::Mask,
        }
    }
}

impl std::fmt::Display for ProjectionChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProjectionChoice::Mask => write!(f, "mask"),
            ProjectionChoice::Interp(d) => write!(f, "interp-{d}"),
        }
    }
}

/// Which observation projection actually ran (recorded in
/// [`FitDiagnostics`] and persisted in checkpoints so serve knows how
/// the posterior was trained; the request lives in
/// `LkgpConfig::projection`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ProjectionPath {
    /// 0/1 grid-mask projection (the paper's `P`).
    #[default]
    Mask,
    /// Sparse kernel interpolation with the recorded stencil family.
    Interp(crate::kron::interp::InterpDegree),
}

impl std::fmt::Display for ProjectionPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProjectionPath::Mask => write!(f, "mask"),
            ProjectionPath::Interp(d) => write!(f, "interp-{d}"),
        }
    }
}

/// Preconditioner strength levels, ordered by the fallback chain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrecondLevel {
    /// Exact latent-grid (unmasked-system) inverse from per-factor
    /// eigendecompositions — the strongest level, used ahead of pivoted
    /// Cholesky when `LKGP_SOLVER=eig` meets a masked grid.
    KronEig,
    /// The paper's pivoted-Cholesky + Woodbury preconditioner.
    PivotedCholesky,
    /// Diagonal (Jacobi) scaling.
    Jacobi,
    /// No preconditioning.
    Identity,
}

impl std::fmt::Display for PrecondLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PrecondLevel::KronEig => write!(f, "kron-eig"),
            PrecondLevel::PivotedCholesky => write!(f, "pivoted-cholesky"),
            PrecondLevel::Jacobi => write!(f, "jacobi"),
            PrecondLevel::Identity => write!(f, "identity"),
        }
    }
}

/// One preconditioner downgrade taken during a fit.
#[derive(Clone, Debug)]
pub struct PrecondFallback {
    /// Level that failed.
    pub from: PrecondLevel,
    /// Level that replaced it.
    pub to: PrecondLevel,
    /// Human-readable cause (construction error, indefinite apply, ...).
    pub reason: String,
}

/// Health report of one fit (or serve reconstruction).
///
/// All counters are deterministic for a given input and configuration:
/// they reflect solver decisions made on f64 reductions with fixed
/// order, never on timing or thread count.
#[derive(Clone, Debug, Default)]
pub struct FitDiagnostics {
    /// Which solver path produced the result (CG, direct eig, or a
    /// serve-side MVM replay).
    pub solver_path: SolverPath,
    /// Which time-factor engine applied the `K_TT` half of Kron MVMs.
    pub time_op: TimeOpPath,
    /// Which observation projection tied the data to the latent grid.
    pub projection: ProjectionPath,
    /// Direct eigendecomposition solves performed (always zero on the
    /// CG path; these contribute zero CG iterations).
    pub eig_solves: usize,
    /// CG solves performed (train + pathwise batches).
    pub cg_solves: usize,
    /// How many of those finished without reaching the tolerance.
    pub nonconverged_solves: usize,
    /// Largest final relative residual observed across all solves.
    pub worst_rel_residual: f64,
    /// Stagnation restarts taken inside CG.
    pub cg_restarts: usize,
    /// Total CG iterations across all solves.
    pub cg_iters_total: usize,
    /// Total batched MVMs across all solves.
    pub mvm_total: usize,
    /// Backend MVM retries that recovered a transient failure.
    pub backend_retries: u64,
    /// Preconditioner downgrades taken (empty on a healthy fit).
    pub precond_fallbacks: Vec<PrecondFallback>,
    /// Hyperparameter gradient entries skipped because they were
    /// NaN/Inf (see `optim::adam`): a nonzero count flags a diverging
    /// hyperparameter search that would otherwise be invisible.
    pub grads_skipped_nonfinite: u64,
}

impl FitDiagnostics {
    /// True when the fit needed no recovery and every solve converged.
    pub fn healthy(&self) -> bool {
        self.nonconverged_solves == 0
            && self.cg_restarts == 0
            && self.backend_retries == 0
            && self.precond_fallbacks.is_empty()
            && self.grads_skipped_nonfinite == 0
    }

    /// Multi-line human-readable report (CLI `train` output).
    pub fn render(&self) -> String {
        let mut s = format!(
            "  solver: {} path, {} eig solves, {} time factor, {} projection\n",
            self.solver_path, self.eig_solves, self.time_op, self.projection
        );
        s += &format!(
            "  cg: {} solves, {} iters, {} mvms, worst rel residual {:.3e}\n",
            self.cg_solves, self.cg_iters_total, self.mvm_total, self.worst_rel_residual
        );
        s += &format!(
            "  recovery: {} non-converged, {} restarts, {} mvm retries, {} skipped grads\n",
            self.nonconverged_solves,
            self.cg_restarts,
            self.backend_retries,
            self.grads_skipped_nonfinite
        );
        if self.precond_fallbacks.is_empty() {
            s += "  preconditioner: no fallbacks";
        } else {
            for f in &self.precond_fallbacks {
                s += &format!("  preconditioner: {} -> {} ({})\n", f.from, f.to, f.reason);
            }
            s.pop();
        }
        s
    }
}

impl std::fmt::Display for FitDiagnostics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.render())
    }
}

/// Live health counters of a running `lkgp serve` daemon.
///
/// Shared (`Arc`) between the accept loop, every connection thread, and
/// the cross-request batcher; the hot-path counters are relaxed atomics
/// (exact totals, no ordering guarantees between them) and per-request
/// latencies go through a mutex only once per request, after the
/// response bytes are on the wire.
#[derive(Debug, Default)]
pub struct ServeCounters {
    /// Requests decoded successfully (all kinds).
    pub requests: std::sync::atomic::AtomicU64,
    /// Predict requests among them.
    pub predict_requests: std::sync::atomic::AtomicU64,
    /// Typed error responses written (framing, decode, or per-request).
    pub errors: std::sync::atomic::AtomicU64,
    /// Connections accepted.
    pub connections: std::sync::atomic::AtomicU64,
    /// Coalesced `predict_batch` sweeps dispatched.
    pub batches: std::sync::atomic::AtomicU64,
    /// Predict requests answered by those sweeps (occupancy numerator).
    pub batched_requests: std::sync::atomic::AtomicU64,
    /// Grid cells served by those sweeps.
    pub cells: std::sync::atomic::AtomicU64,
    /// Per-request wall latencies in microseconds, enqueue-to-respond.
    pub latencies_us: std::sync::Mutex<Vec<u64>>,
}

/// Point-in-time snapshot of [`ServeCounters`], with derived summary
/// statistics (what the daemon prints on shutdown and what
/// `bench_serve` reports into `BENCH_serve.json`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeReport {
    /// Requests decoded successfully.
    pub requests: u64,
    /// Predict requests among them.
    pub predict_requests: u64,
    /// Typed error responses written.
    pub errors: u64,
    /// Connections accepted.
    pub connections: u64,
    /// Coalesced sweeps dispatched.
    pub batches: u64,
    /// Grid cells served.
    pub cells: u64,
    /// Mean predict requests per sweep (window occupancy); 1.0 means
    /// cross-request batching never coalesced anything.
    pub mean_batch_occupancy: f64,
    /// Median request latency, milliseconds (0 when nothing measured).
    pub p50_ms: f64,
    /// 99th-percentile request latency, milliseconds.
    pub p99_ms: f64,
}

impl ServeCounters {
    /// Record one coalesced sweep over `requests` predict requests
    /// covering `cells` grid cells.
    pub fn record_batch(&self, requests: u64, cells: u64) {
        use std::sync::atomic::Ordering::Relaxed;
        self.batches.fetch_add(1, Relaxed);
        self.batched_requests.fetch_add(requests, Relaxed);
        self.cells.fetch_add(cells, Relaxed);
    }

    /// Record one finished request's latency in microseconds.
    pub fn record_latency_us(&self, us: u64) {
        self.latencies_us.lock().unwrap_or_else(|e| e.into_inner()).push(us);
    }

    /// Snapshot the counters into a report with derived statistics.
    pub fn report(&self) -> ServeReport {
        use std::sync::atomic::Ordering::Relaxed;
        let batches = self.batches.load(Relaxed);
        let batched = self.batched_requests.load(Relaxed);
        let mut lat = self.latencies_us.lock().unwrap_or_else(|e| e.into_inner()).clone();
        lat.sort_unstable();
        let pct = |p: f64| -> f64 {
            if lat.is_empty() {
                return 0.0;
            }
            // nearest-rank on the sorted sample; index arithmetic only,
            // so the same latencies always yield the same percentile
            let idx = ((p / 100.0) * (lat.len() - 1) as f64).round() as usize;
            lat[idx.min(lat.len() - 1)] as f64 / 1000.0
        };
        ServeReport {
            requests: self.requests.load(Relaxed),
            predict_requests: self.predict_requests.load(Relaxed),
            errors: self.errors.load(Relaxed),
            connections: self.connections.load(Relaxed),
            batches,
            cells: self.cells.load(Relaxed),
            mean_batch_occupancy: if batches == 0 {
                0.0
            } else {
                batched as f64 / batches as f64
            },
            p50_ms: pct(50.0),
            p99_ms: pct(99.0),
        }
    }
}

impl ServeReport {
    /// One-line human-readable summary (daemon shutdown log line).
    pub fn render(&self) -> String {
        format!(
            "served {} requests ({} predict, {} errors) on {} connections; \
             {} sweeps, occupancy {:.2}, {} cells; latency p50 {:.3} ms p99 {:.3} ms",
            self.requests,
            self.predict_requests,
            self.errors,
            self.connections,
            self.batches,
            self.mean_batch_occupancy,
            self.cells,
            self.p50_ms,
            self.p99_ms
        )
    }
}

impl std::fmt::Display for ServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_policy() {
        assert_eq!(OnNonConverged::parse("warn"), Ok(OnNonConverged::Warn));
        assert_eq!(OnNonConverged::parse("ERROR"), Ok(OnNonConverged::Error));
        assert!(OnNonConverged::parse("panic").is_err());
        assert_eq!(OnNonConverged::default(), OnNonConverged::Warn);
    }

    #[test]
    fn parse_solver() {
        assert_eq!(Solver::parse("cg"), Ok(Solver::Cg));
        assert_eq!(Solver::parse("EIG"), Ok(Solver::Eig));
        assert_eq!(Solver::parse("Auto"), Ok(Solver::Auto));
        assert!(Solver::parse("lu").is_err());
        assert_eq!(Solver::default(), Solver::Auto);
        assert_eq!(SolverPath::default(), SolverPath::Cg);
        assert_eq!(format!("{}", SolverPath::Replay), "mvm-replay");
        assert_eq!(format!("{}", PrecondLevel::KronEig), "kron-eig");
    }

    #[test]
    fn parse_time_op() {
        assert_eq!(TimeOpChoice::parse("auto"), Ok(TimeOpChoice::Auto));
        assert_eq!(TimeOpChoice::parse("DENSE"), Ok(TimeOpChoice::Dense));
        assert_eq!(TimeOpChoice::parse("Toeplitz"), Ok(TimeOpChoice::Toeplitz));
        assert!(TimeOpChoice::parse("fft").is_err());
        // default must stay Dense: the golden posterior pins dense bits
        assert_eq!(TimeOpChoice::default(), TimeOpChoice::Dense);
        assert_eq!(TimeOpPath::default(), TimeOpPath::Dense);
        assert_eq!(format!("{}", TimeOpPath::Toeplitz), "toeplitz");
        assert!(FitDiagnostics::default().render().contains("dense time factor"));
    }

    #[test]
    fn parse_projection() {
        use crate::kron::interp::InterpDegree;
        assert_eq!(ProjectionChoice::parse("mask"), Ok(ProjectionChoice::Mask));
        assert_eq!(
            ProjectionChoice::parse("INTERP"),
            Ok(ProjectionChoice::Interp(InterpDegree::Linear))
        );
        assert_eq!(
            ProjectionChoice::parse("interp-linear"),
            Ok(ProjectionChoice::Interp(InterpDegree::Linear))
        );
        assert_eq!(
            ProjectionChoice::parse("Interp-Cubic"),
            Ok(ProjectionChoice::Interp(InterpDegree::Cubic))
        );
        assert!(ProjectionChoice::parse("ski").is_err());
        // default must stay Mask: the golden posterior pins mask bits
        assert_eq!(ProjectionChoice::default(), ProjectionChoice::Mask);
        assert_eq!(ProjectionPath::default(), ProjectionPath::Mask);
        assert_eq!(
            format!("{}", ProjectionPath::Interp(InterpDegree::Cubic)),
            "interp-cubic"
        );
        assert_eq!(format!("{}", ProjectionChoice::Interp(InterpDegree::Linear)), "interp-linear");
        assert!(FitDiagnostics::default().render().contains("mask projection"));
    }

    #[test]
    fn serve_counters_report() {
        use std::sync::atomic::Ordering::Relaxed;
        let c = ServeCounters::default();
        c.requests.store(10, Relaxed);
        c.predict_requests.store(8, Relaxed);
        c.connections.store(3, Relaxed);
        c.record_batch(4, 100);
        c.record_batch(4, 60);
        for us in [1000, 2000, 3000, 4000] {
            c.record_latency_us(us);
        }
        let r = c.report();
        assert_eq!(r.requests, 10);
        assert_eq!(r.batches, 2);
        assert_eq!(r.cells, 160);
        assert!((r.mean_batch_occupancy - 4.0).abs() < 1e-12);
        // sorted latencies ms: [1, 2, 3, 4]; nearest-rank p50 = idx 2
        assert!((r.p50_ms - 3.0).abs() < 1e-12, "p50={}", r.p50_ms);
        assert!((r.p99_ms - 4.0).abs() < 1e-12, "p99={}", r.p99_ms);
        let line = r.render();
        assert!(line.contains("occupancy 4.00"), "{line}");
    }

    #[test]
    fn serve_report_empty_is_zeroes() {
        let r = ServeCounters::default().report();
        assert_eq!(r.p50_ms, 0.0);
        assert_eq!(r.mean_batch_occupancy, 0.0);
    }

    #[test]
    fn healthy_and_render() {
        let mut d = FitDiagnostics::default();
        assert!(d.healthy());
        d.precond_fallbacks.push(PrecondFallback {
            from: PrecondLevel::PivotedCholesky,
            to: PrecondLevel::Jacobi,
            reason: "capacitance not PD".into(),
        });
        d.nonconverged_solves = 1;
        assert!(!d.healthy());
        let r = d.render();
        assert!(r.contains("pivoted-cholesky -> jacobi"), "{r}");
        assert!(r.contains("1 non-converged"), "{r}");
    }
}
