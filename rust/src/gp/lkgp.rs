//! The Latent Kronecker GP model: training (iterative MLL maximization)
//! and prediction (pathwise conditioning), generic over compute backend
//! and compute precision.
//!
//! Training (paper Appendix C): Adam on [theta, log_sigma2], gradients
//! from the Hutchinson surrogate with CG solves batched across
//! [y | probes]; CG uses relative-residual tolerance 0.01 with a
//! pivoted-Cholesky (or Jacobi) preconditioner.
//!
//! Prediction (paper Sec. 3): pathwise conditioning —
//!   (f|y)(grid) = f(grid) + (K_SS (x) K_TT) P^T v,
//!   v = (P K P^T + s2 I)^{-1} (y - (P f + eps)),
//! with f ~ prior via Kronecker Cholesky factors. The predictive mean
//! uses the exact alpha solve; variances come from `n_samples` pathwise
//! samples plus observation noise.
//!
//! Mixed precision: `LkgpConfig::precision` selects the scalar type of
//! the whole iterative hot path (see [`Precision`]). The generic
//! `fit_with_backend` body computes in `T` but keeps every sensitive
//! reduction — data-fit term, gradients, pathwise moment accumulation —
//! in f64, and the returned [`Posterior`] is always f64.

use anyhow::{Context, Result};

use crate::data::{GridDataset, OffGridDataset};
use crate::kron::interp::SparseProjection;
use crate::linalg::{Matrix, Scalar};
use crate::runtime::Runtime;
use crate::solvers::cg::{
    solve_cg, CgOptions, CgStats, SolveDiag, SolveError, SolveOutcome,
};
use crate::solvers::eig::EigSolver;
use crate::solvers::precond::Preconditioner;
use crate::util::rng::Rng;
use crate::util::timer::Profile;

use super::backend::{
    InterpRustBackend, KronBackend, MvmMode, PjrtKronBackend, Precision, RustKronBackend,
    SystemOp,
};
use super::diagnostics::{
    FitDiagnostics, OnNonConverged, PrecondFallback, PrecondLevel, ProjectionChoice,
    ProjectionPath, Solver, SolverPath, TimeOpChoice,
};
use super::Posterior;

/// Which backend executes the five LKGP operations.
#[derive(Clone, Debug)]
pub enum Backend {
    /// Pure-rust kernels + Kron algebra, with a selectable MVM mode
    /// (Kron = LKGP, DenseMaterialized/DenseLazy = iterative baselines).
    Rust(MvmMode),
    /// AOT artifacts on the PJRT CPU client (named artifact config).
    Pjrt {
        /// Artifact configuration name from the manifest.
        config: String,
    },
}

/// Configuration of one LKGP fit (training + pathwise prediction).
#[derive(Clone, Debug)]
pub struct LkgpConfig {
    /// Adam iterations on the marginal likelihood
    pub train_iters: usize,
    /// Adam learning rate
    pub lr: f64,
    /// CG relative-residual tolerance
    pub cg_tol: f64,
    /// CG iteration cap per solve
    pub cg_max_iters: usize,
    /// Hutchinson probes (must equal the artifact's static count on PJRT)
    pub probes: usize,
    /// pathwise-conditioning samples for predictive variance
    pub n_samples: usize,
    /// pivoted-Cholesky preconditioner rank (0 = Jacobi)
    pub precond_rank: usize,
    /// RNG seed for probes, pathwise samples, and observation noise
    pub seed: u64,
    /// compute backend executing the five LKGP operations
    pub backend: Backend,
    /// compute precision of the iterative hot path (Rust backend only;
    /// PJRT artifacts always execute in f32 on-device) — see
    /// [`Precision`] for the f32-compute / f64-accumulate policy
    pub precision: Precision,
    /// initial log observation-noise variance
    pub init_log_sigma2: f64,
    /// Capture the pathwise-conditioning state (representer weights,
    /// masked sample coefficients, prior sample values) into
    /// [`LkgpFit::model`] so the fit can be checkpointed with
    /// [`crate::model::TrainedModel::save`] and served by
    /// [`crate::serve::ServeEngine`]. Costs two extra
    /// `n_samples x (p q)` matrices of resident memory; off by default
    /// so experiments and benches pay nothing.
    pub capture_pathwise: bool,
    /// What to do when a CG solve finishes without reaching `cg_tol`
    /// (default [`OnNonConverged::Warn`]: record + one warning; `Error`
    /// fails the fit with a typed `SolveError::NotConverged`).
    pub on_nonconverged: OnNonConverged,
    /// Bounded retries for a failing backend MVM inside a CG solve
    /// (retrying a deterministic MVM cannot change bits; a transient
    /// fault that recovers within this budget leaves only a
    /// [`FitDiagnostics::backend_retries`] trace).
    pub mvm_retries: usize,
    /// Backoff before the first MVM retry, in milliseconds (doubles per
    /// retry; 0 = retry immediately).
    pub mvm_retry_backoff_ms: u64,
    /// Which linear-system engine runs the solves (default
    /// [`Solver::Auto`]: the direct per-factor eigendecomposition path
    /// on fully-observed grids — zero CG iterations — and plain CG,
    /// bit-identical to [`Solver::Cg`], on any masked grid).
    /// [`Solver::Eig`] additionally enables the latent-grid `KronEig`
    /// preconditioner under masking. The CLI maps `--solver` /
    /// `LKGP_SOLVER` here; `Default::default()` does not read the
    /// environment.
    pub solver: Solver,
    /// Which engine applies the `K_TT` half of every Kronecker MVM
    /// (default [`TimeOpChoice::Dense`]: the seed GEMM path,
    /// bit-compatible with the committed golden posterior).
    /// [`TimeOpChoice::Auto`] and [`TimeOpChoice::Toeplitz`] engage the
    /// O(q log q) planned-FFT circulant-embedding path when the time
    /// grid is uniformly spaced and the time kernel stationary, and
    /// fall back to dense (with a warning) otherwise; the path actually
    /// taken is recorded in [`FitDiagnostics::time_op`] and persisted
    /// in checkpoints. The CLI maps `--time-op` / `LKGP_TIME_OP` here;
    /// `Default::default()` does not read the environment. Rust backend
    /// only — PJRT artifacts keep their compiled dense MVM.
    pub time_op: TimeOpChoice,
    /// Which projection relates the n training targets to the latent
    /// p*q grid (default [`ProjectionChoice::Mask`]: the paper's 0/1
    /// observation mask, bit-compatible with the committed golden
    /// posterior — training data must sit on grid cells).
    /// [`ProjectionChoice::Interp`] enables SKI training: a sparse
    /// interpolation matrix `W` onto the inducing grid, so off-grid
    /// inputs become first-class (`Lkgp::fit_offgrid`); on a
    /// `GridDataset` the observed cells are converted to
    /// grid-coincident points first. The path taken is recorded in
    /// [`FitDiagnostics::projection`] and persisted in checkpoints
    /// (format v3). The CLI maps `--projection` / `LKGP_PROJECTION`
    /// here; `Default::default()` does not read the environment. Rust
    /// Kron backend only.
    pub projection: ProjectionChoice,
    /// Admission window of the `lkgp serve` daemon's cross-request
    /// batcher, in milliseconds: how long the daemon collects predict
    /// requests from concurrent connections before coalescing them into
    /// one steal-scheduled `predict_batch` sweep. `0` disables
    /// cross-request batching (each request dispatches on its own — the
    /// serial baseline `bench_serve` compares against). Grouping never
    /// changes output bits; the window trades per-request latency for
    /// sweep throughput. The CLI maps `--window` / `LKGP_SERVE_WINDOW`
    /// here; `Default::default()` does not read the environment.
    pub serve_batch_window_ms: u64,
}

impl Default for LkgpConfig {
    fn default() -> Self {
        LkgpConfig {
            train_iters: 30,
            lr: 0.1,
            cg_tol: 1e-2,
            cg_max_iters: 300,
            probes: 8,
            n_samples: 64,
            precond_rank: 0,
            seed: 0,
            backend: Backend::Rust(MvmMode::Kron),
            precision: Precision::F64,
            init_log_sigma2: (0.1f64).ln(),
            capture_pathwise: false,
            on_nonconverged: OnNonConverged::Warn,
            mvm_retries: 2,
            mvm_retry_backoff_ms: 10,
            solver: Solver::Auto,
            time_op: TimeOpChoice::Dense,
            projection: ProjectionChoice::Mask,
            serve_batch_window_ms: 2,
        }
    }
}

/// Result of a fit: posterior + hyperparameters + cost accounting.
pub struct LkgpFit {
    /// Full-grid predictive posterior in raw target scale.
    pub posterior: Posterior,
    /// Fitted kernel hyperparameters (flat layout, see `kernels`).
    pub theta: Vec<f64>,
    /// Fitted log observation-noise variance.
    pub log_sigma2: f64,
    /// 0.5 y^T alpha per training iteration (data-fit part of the NLL)
    pub loss_trace: Vec<f64>,
    /// Wall-clock seconds spent in hyperparameter training.
    pub train_secs: f64,
    /// Wall-clock seconds spent in pathwise prediction.
    pub predict_secs: f64,
    /// Total CG iterations across all solves.
    pub cg_iters_total: usize,
    /// Total system MVMs across all solves.
    pub mvm_total: usize,
    /// Bytes held by the kernel representation (Fig-2/3 memory axis).
    pub kernel_bytes: u64,
    /// Per-phase wall-clock profile.
    pub profile: Profile,
    /// Serializable train-once/serve-many state, captured when
    /// [`LkgpConfig::capture_pathwise`] is set (`None` otherwise).
    /// Checkpoint it with [`crate::model::TrainedModel::save`].
    pub model: Option<crate::model::TrainedModel>,
    /// Solver health report: convergence, residuals, and any recovery
    /// actions (preconditioner fallbacks, MVM retries, CG restarts,
    /// skipped gradients) taken during the fit.
    pub diagnostics: FitDiagnostics,
}

/// Train + predict an LKGP (or iterative-baseline) model on a dataset.
pub struct Lkgp;

impl Lkgp {
    /// Fit on `data` with the backend/precision selected by `cfg`.
    ///
    /// With [`LkgpConfig::projection`] set to `Interp`, the observed
    /// cells are converted to grid-coincident off-grid points
    /// ([`OffGridDataset::from_grid`]) and the fit routes through
    /// [`Lkgp::fit_offgrid`] — on a fully observed grid the linear
    /// projection degenerates to the 0/1 mask and the posterior is
    /// bit-identical to the mask path under [`Solver::Cg`].
    pub fn fit(data: &GridDataset, cfg: LkgpConfig) -> Result<LkgpFit> {
        if let ProjectionChoice::Interp(_) = cfg.projection {
            let od = OffGridDataset::from_grid(data)?;
            return Self::fit_offgrid(&od, cfg);
        }
        match &cfg.backend {
            Backend::Rust(mode) => match cfg.precision {
                Precision::F64 => {
                    let mut be = RustKronBackend::<f64>::new(
                        data.s.cols,
                        &data.time_family,
                        data.q(),
                        cfg.probes,
                    )
                    .with_mode(mode.clone())
                    .with_time_op(cfg.time_op);
                    fit_with_backend(data, &cfg, &mut be)
                }
                Precision::F32 => {
                    let mut be = RustKronBackend::<f32>::new(
                        data.s.cols,
                        &data.time_family,
                        data.q(),
                        cfg.probes,
                    )
                    .with_mode(mode.clone())
                    .with_time_op(cfg.time_op);
                    fit_with_backend(data, &cfg, &mut be)
                }
            },
            Backend::Pjrt { config } => {
                // PJRT artifacts compute in f32 on-device regardless of
                // `cfg.precision`; the host boundary stays f64.
                let rt = Runtime::load_default().context("loading artifacts")?;
                let mut be = PjrtKronBackend::new(rt, config)?;
                fit_with_backend(data, &cfg, &mut be)
            }
        }
    }

    /// Fit with a caller-provided backend (used by experiments that
    /// share a PJRT runtime across fits). The compute precision is the
    /// backend's `T`, not `cfg.precision` — the caller chose it when
    /// instantiating the backend.
    pub fn fit_backend<T: Scalar, B: KronBackend<T>>(
        data: &GridDataset,
        cfg: &LkgpConfig,
        be: &mut B,
    ) -> Result<LkgpFit> {
        fit_with_backend(data, cfg, be)
    }

    /// SKI fit on off-grid data: build the sparse interpolation
    /// projection `W` from the point coordinates and train against the
    /// data-space system `W (K_SS (x) K_TT) W^T + sigma2 I`. The
    /// returned posterior lives on the latent p*q inducing grid;
    /// predictions at arbitrary points are `W_* mu` for a fresh
    /// projection `W_*` built at the query coordinates (see
    /// [`SparseProjection::build`]).
    ///
    /// Requires [`LkgpConfig::projection`] = `Interp` and the rust Kron
    /// backend ([`Backend::Rust`] with [`MvmMode::Kron`]); the solver is
    /// always CG — the direct spectral path addresses the grid system,
    /// not the n-point data system.
    pub fn fit_offgrid(data: &OffGridDataset, cfg: LkgpConfig) -> Result<LkgpFit> {
        data.validate()?;
        let degree = match cfg.projection {
            ProjectionChoice::Interp(d) => d,
            ProjectionChoice::Mask => anyhow::bail!(
                "off-grid data needs an interpolation projection (--projection interp)"
            ),
        };
        if !matches!(cfg.backend, Backend::Rust(MvmMode::Kron)) {
            anyhow::bail!(
                "projection interp supports only the rust Kron backend, got {:?}",
                cfg.backend
            );
        }
        let proj = SparseProjection::build(
            &data.xs,
            &data.xt,
            &data.grid_s,
            &data.grid_t,
            degree,
        )
        .map_err(|e| anyhow::anyhow!("building interpolation projection: {e}"))?;
        match cfg.precision {
            Precision::F64 => {
                let mut be =
                    InterpRustBackend::<f64>::new(&data.time_family, data.q(), cfg.probes, proj)
                        .with_time_op(cfg.time_op);
                fit_interp(data, &cfg, &mut be)
            }
            Precision::F32 => {
                let mut be =
                    InterpRustBackend::<f32>::new(&data.time_family, data.q(), cfg.probes, proj)
                        .with_time_op(cfg.time_op);
                fit_interp(data, &cfg, &mut be)
            }
        }
    }
}

/// Build the strongest preconditioner that constructs cleanly, walking
/// the fallback chain KronEig (when `kron_eig` requests it) -> pivoted
/// Cholesky -> Jacobi -> identity and recording every downgrade in
/// `diags`. On the happy path the built preconditioner is exactly what
/// the infallible constructors produce.
fn build_precond<T: Scalar, B: KronBackend<T>>(
    be: &B,
    rank: usize,
    sigma2: f64,
    kron_eig: bool,
    diags: &mut FitDiagnostics,
) -> (Preconditioner<T>, PrecondLevel) {
    if kron_eig {
        let next = if rank > 0 { PrecondLevel::PivotedCholesky } else { PrecondLevel::Jacobi };
        match be.gram_factors() {
            Some((kss, ktt)) => {
                match Preconditioner::try_kron_eig(&kss, &ktt, sigma2) {
                    Ok(p) => return (p, PrecondLevel::KronEig),
                    Err(e) => diags.precond_fallbacks.push(PrecondFallback {
                        from: PrecondLevel::KronEig,
                        to: next,
                        reason: e.to_string(),
                    }),
                }
            }
            None => diags.precond_fallbacks.push(PrecondFallback {
                from: PrecondLevel::KronEig,
                to: next,
                reason: "backend does not expose Gram factors".into(),
            }),
        }
    }
    if rank > 0 {
        // greedy pivot selection runs on an f64 diagonal (widened from
        // the T-precision Gram, so near-ties can still order differently
        // between precisions); within a precision it is deterministic
        // and thread-count invariant. The factor columns are in T.
        let diag: Vec<f64> = be.system_diag().iter().map(|d| d - sigma2).collect();
        match Preconditioner::try_pivoted_from_columns(diag, |j| be.kernel_col(j), rank, sigma2)
        {
            Ok(p) => return (p, PrecondLevel::PivotedCholesky),
            Err(e) => diags.precond_fallbacks.push(PrecondFallback {
                from: PrecondLevel::PivotedCholesky,
                to: PrecondLevel::Jacobi,
                reason: e.to_string(),
            }),
        }
    }
    match Preconditioner::try_jacobi(&be.system_diag()) {
        Ok(p) => (p, PrecondLevel::Jacobi),
        Err(e) => {
            diags.precond_fallbacks.push(PrecondFallback {
                from: PrecondLevel::Jacobi,
                to: PrecondLevel::Identity,
                reason: e.to_string(),
            });
            (Preconditioner::Identity, PrecondLevel::Identity)
        }
    }
}

/// Downgrade one level along the fallback chain after an in-solve
/// failure (indefinite apply). Returns the replacement and its level.
fn downgrade_precond<T: Scalar, B: KronBackend<T>>(
    be: &B,
    from: PrecondLevel,
) -> (Preconditioner<T>, PrecondLevel) {
    if from == PrecondLevel::KronEig || from == PrecondLevel::PivotedCholesky {
        if let Ok(p) = Preconditioner::try_jacobi(&be.system_diag()) {
            return (p, PrecondLevel::Jacobi);
        }
    }
    (Preconditioner::Identity, PrecondLevel::Identity)
}

/// One CG solve with the recovery policy chain applied:
/// * backend MVM failures are retried (bounded, inside [`SystemOp`])
///   and then surfaced as typed errors;
/// * an indefinite-preconditioner failure downgrades the preconditioner
///   one level and re-solves (deterministic: the decision depends only
///   on solver f64 reductions);
/// * non-convergence is recorded and handled per
///   [`LkgpConfig::on_nonconverged`];
/// * breakdowns (NaN residual) abort with a typed [`SolveError`].
///
/// On the happy path this is exactly `solve_cg` + counter bookkeeping —
/// no numeric behaviour changes.
#[allow(clippy::too_many_arguments)]
fn solve_resilient<T: Scalar, B: KronBackend<T>>(
    be: &mut B,
    rhs: &Matrix<T>,
    pre: &mut Preconditioner<T>,
    level: &mut PrecondLevel,
    opts: &CgOptions,
    cfg: &LkgpConfig,
    diags: &mut FitDiagnostics,
    label: &str,
) -> Result<(Matrix<T>, CgStats)> {
    loop {
        let (x, stats, retries, op_err) = {
            let mut op = SystemOp::with_retries(be, cfg.mvm_retries, cfg.mvm_retry_backoff_ms);
            let (x, stats) = solve_cg(&mut op, rhs, &*pre, opts);
            let retries = op.retries();
            (x, stats, retries, op.take_err())
        };
        diags.backend_retries += retries;
        if let Err(e) = op_err {
            return Err(e.context(format!("{label} solve failed")));
        }
        diags.cg_solves += 1;
        diags.cg_iters_total += stats.iters;
        diags.mvm_total += stats.mvm_count;
        diags.cg_restarts += stats.restarts;
        match stats.error.clone() {
            None => {
                // Residuals fold into worst_rel_residual only for the
                // solve that actually stands: an aborted attempt (e.g.
                // indefinite preconditioner, whose residuals are still
                // at their initial 1.0) is replaced by the re-solve
                // below, and its residuals must not poison the report.
                for &r in &stats.rel_residuals {
                    if r.is_finite() && r > diags.worst_rel_residual {
                        diags.worst_rel_residual = r;
                    }
                }
                if !stats.converged {
                    diags.nonconverged_solves += 1;
                    let (worst_system, rel_residual) = stats
                        .rel_residuals
                        .iter()
                        .enumerate()
                        .fold((0, 0.0), |acc, (i, &r)| if r > acc.1 { (i, r) } else { acc });
                    let err = SolveError::NotConverged {
                        worst_system,
                        rel_residual,
                        iters: stats.iters,
                    };
                    match cfg.on_nonconverged {
                        OnNonConverged::Error => {
                            return Err(anyhow::Error::new(err)
                                .context(format!("{label} solve did not converge")));
                        }
                        OnNonConverged::Warn => {
                            if diags.nonconverged_solves == 1 {
                                eprintln!("warning: {label} {err}");
                            }
                        }
                    }
                }
                return Ok((x, stats));
            }
            Some(e @ SolveError::IndefinitePreconditioner { .. })
                if *level != PrecondLevel::Identity =>
            {
                let (next, to) = downgrade_precond(be, *level);
                diags.precond_fallbacks.push(PrecondFallback {
                    from: *level,
                    to,
                    reason: e.to_string(),
                });
                *pre = next;
                *level = to;
            }
            Some(e) => {
                return Err(anyhow::Error::new(e).context(format!("{label} solve failed")));
            }
        }
    }
}

/// One direct spectral solve standing in for [`solve_resilient`] on the
/// fully-observed path: zero CG iterations and zero MVMs. The true
/// per-row residuals (measured against the original factors, typically
/// ~1e-14) fold into the same diagnostics and are checked against
/// `cg_tol` under the same [`LkgpConfig::on_nonconverged`] policy;
/// fabricated [`CgStats`] keep downstream accounting uniform across
/// solver paths.
fn solve_eig_direct<T: Scalar>(
    es: &EigSolver,
    rhs: &Matrix<T>,
    cfg: &LkgpConfig,
    diags: &mut FitDiagnostics,
    label: &str,
) -> Result<(Matrix<T>, CgStats)> {
    let (x, rels) = es.solve_batch(rhs);
    diags.solver_path = SolverPath::Eig;
    diags.eig_solves += 1;
    for &r in &rels {
        if r.is_finite() && r > diags.worst_rel_residual {
            diags.worst_rel_residual = r;
        }
    }
    let converged = rels.iter().all(|&r| r.is_finite() && r <= cfg.cg_tol);
    if !converged {
        diags.nonconverged_solves += 1;
        let (worst_system, rel_residual) = rels
            .iter()
            .enumerate()
            .fold((0, 0.0), |acc, (i, &r)| if r > acc.1 { (i, r) } else { acc });
        let err = SolveError::NotConverged { worst_system, rel_residual, iters: 0 };
        match cfg.on_nonconverged {
            OnNonConverged::Error => {
                return Err(anyhow::Error::new(err)
                    .context(format!("{label} eig solve missed tolerance")));
            }
            OnNonConverged::Warn => {
                if diags.nonconverged_solves == 1 {
                    eprintln!("warning: {label} {err}");
                }
            }
        }
    }
    let sys_diags: Vec<SolveDiag> = rels
        .iter()
        .map(|&r| SolveDiag {
            outcome: if r.is_finite() && r <= cfg.cg_tol {
                SolveOutcome::Converged
            } else {
                SolveOutcome::MaxIters
            },
            rel_residual: r,
        })
        .collect();
    let stats = CgStats {
        iters: 0,
        mvm_count: 0,
        rel_residuals: rels,
        converged,
        diags: sys_diags,
        restarts: 0,
        error: None,
    };
    Ok((x, stats))
}

/// Entry point shared by every `Lkgp::fit` path: runs the fit body with
/// parallel-region panic capture so a fault inside a `par::` region
/// surfaces as a typed error (`par::RegionPanic` in the anyhow chain)
/// instead of tearing down the process.
fn fit_with_backend<T: Scalar, B: KronBackend<T>>(
    data: &GridDataset,
    cfg: &LkgpConfig,
    be: &mut B,
) -> Result<LkgpFit> {
    crate::par::catch_region(|| fit_with_backend_inner(data, cfg, be))
        .map_err(|rp| anyhow::Error::new(rp).context("parallel region fault during fit"))?
}

fn fit_with_backend_inner<T: Scalar, B: KronBackend<T>>(
    data: &GridDataset,
    cfg: &LkgpConfig,
    be: &mut B,
) -> Result<LkgpFit> {
    let mut prof = Profile::new();
    let t_train = std::time::Instant::now();
    let (p, q) = (data.p(), data.q());
    let pq = p * q;
    let mask = data.mask_f64();
    let y = data.y_std_padded();
    let (y_mean, y_std) = data.target_stats();

    be.set_data(&data.s, &data.t, &mask)?;

    // Solver selection (see `LkgpConfig::solver`): on a fully-observed
    // grid Auto/Eig replace CG with exact per-factor spectral solves;
    // under masking Eig requests the KronEig preconditioner and Auto
    // stays bit-identical to plain CG.
    let full_grid = !mask.is_empty() && mask.iter().all(|&m| m != 0.0);
    let mut eig_direct = full_grid && cfg.solver != Solver::Cg;
    let kron_eig_pre = !full_grid && cfg.solver == Solver::Eig;
    let mut eig_cur: Option<EigSolver> = None;

    // hyperparameter vector: [theta.., log_sigma2]
    let mut kernel = crate::kernels::ProductGridKernel::new(data.s.cols, &data.time_family, q);
    let n_theta = kernel.n_theta();
    let mut params = vec![0.0; n_theta + 1];
    params[n_theta] = cfg.init_log_sigma2;
    // time-grid coordinates are standardized inside kernels via theta
    // init; lengthscale init 1.0 (log 0) matches standardized inputs.

    let mut adam = crate::optim::Adam::new(n_theta + 1, cfg.lr);
    let mut rng = Rng::new(cfg.seed ^ 0x16C9);

    // fixed masked Rademacher probes (fixed across iterations reduces
    // gradient noise; cf. Lin et al. 2024b)
    // the backend dictates the probe count (static on PJRT artifacts)
    let n_probes = be.probes();
    let z_probes = {
        let mut z = Matrix::<T>::zeros(n_probes, pq);
        for i in 0..n_probes {
            // drawn in f64, rounded once at the precision boundary
            let row: Vec<T> = rng
                .rademacher_f32(pq)
                .iter()
                .zip(&mask)
                .map(|(r, m)| T::from_f64(*r as f64 * m))
                .collect();
            z.row_mut(i).copy_from_slice(&row);
        }
        z
    };
    let y_t: Vec<T> = y.iter().map(|&v| T::from_f64(v)).collect();

    let cg_opts =
        CgOptions { max_iters: cfg.cg_max_iters, tol: cfg.cg_tol, ..CgOptions::default() };
    let mut loss_trace = Vec::with_capacity(cfg.train_iters);
    let mut cg_iters_total = 0;
    let mut mvm_total = 0;
    // the backend resolved the time-op request against the actual grid
    // and kernel family in set_data above
    let mut diagnostics =
        FitDiagnostics { time_op: be.time_op_path(), ..FitDiagnostics::default() };
    let mut alpha = vec![T::ZERO; pq];

    for it in 0..cfg.train_iters + 1 {
        let theta = &params[..n_theta];
        let log_s2 = params[n_theta];
        prof.time("set_hypers", || be.set_hypers(theta, log_s2))?;
        kernel.set_theta(theta);

        if eig_direct {
            // refactor once per hyperparameter setting; a construction
            // failure (no factors, or a non-invertible spectrum) drops
            // the whole fit back to CG with one warning
            eig_cur = match prof.time("eig_factor", || {
                be.gram_factors()
                    .map(|(kss, ktt)| EigSolver::try_new(&kss, &ktt, log_s2.exp()))
            }) {
                Some(Ok(es)) => Some(es),
                Some(Err(e)) => {
                    eprintln!("warning: eig solver unavailable ({e}); falling back to cg");
                    eig_direct = false;
                    None
                }
                None => {
                    eprintln!(
                        "warning: backend exposes no Gram factors; falling back to cg"
                    );
                    eig_direct = false;
                    None
                }
            };
        }

        // batched solve: [y | probes]
        let mut rhs = Matrix::<T>::zeros(1 + n_probes, pq);
        rhs.row_mut(0).copy_from_slice(&y_t);
        for i in 0..n_probes {
            rhs.row_mut(1 + i).copy_from_slice(z_probes.row(i));
        }
        let (sol, stats) = if let Some(es) = eig_cur.as_ref().filter(|_| eig_direct) {
            prof.time("eig_solve", || {
                solve_eig_direct(es, &rhs, cfg, &mut diagnostics, "train")
            })?
        } else {
            let (mut pre, mut level) = prof.time("precond", || {
                build_precond(be, cfg.precond_rank, log_s2.exp(), kron_eig_pre, &mut diagnostics)
            });
            prof.time("cg_solve", || -> Result<(Matrix<T>, CgStats)> {
                let d = &mut diagnostics;
                solve_resilient(be, &rhs, &mut pre, &mut level, &cg_opts, cfg, d, "train")
            })?
        };
        cg_iters_total += stats.iters;
        mvm_total += stats.mvm_count;
        alpha.copy_from_slice(sol.row(0));
        // data-fit term accumulates in f64 in both precisions
        let fit_term =
            0.5 * y.iter().zip(&alpha).map(|(a, b)| a * b.to_f64()).sum::<f64>();
        loss_trace.push(fit_term);

        if it == cfg.train_iters {
            break; // final solve only (alpha for prediction)
        }
        let w = {
            let mut w = Matrix::<T>::zeros(n_probes, pq);
            for i in 0..n_probes {
                w.row_mut(i).copy_from_slice(sol.row(1 + i));
            }
            w
        };
        let grads = prof.time("mll_grads", || be.mll_grads(&alpha, &w, &z_probes))?;
        adam.step(&mut params, &grads);
    }
    diagnostics.grads_skipped_nonfinite = adam.skipped_nonfinite();
    let train_secs = t_train.elapsed().as_secs_f64();

    // ---- prediction via pathwise conditioning ----
    let t_pred = std::time::Instant::now();
    let sigma2 = params[n_theta].exp();
    // exact predictive mean: mu = (K (x) K) M alpha
    let masked_alpha = {
        let mut a = Matrix::<T>::zeros(1, pq);
        for ((o, a0), m) in a.row_mut(0).iter_mut().zip(&alpha).zip(&mask) {
            *o = *a0 * T::from_f64(*m);
        }
        a
    };
    let mean_std = prof.time("predict_mean", || be.kron_apply(&masked_alpha))?;

    // pathwise samples for predictive variance
    let nsamp = cfg.n_samples.max(2);
    let mut var_acc = vec![0.0f64; pq];
    let mut mean_acc = vec![0.0f64; pq];
    let chunk = PATHWISE_CHUNK;
    // optional train-once/serve-many capture: the masked sample
    // coefficients and prior sample values, row-aligned with the chunk
    // loop below so serve-time reconstruction replays the exact same
    // accumulation (see crate::serve)
    let mut capture: Option<(Matrix<T>, Matrix<T>)> = if cfg.capture_pathwise {
        Some((Matrix::zeros(nsamp, pq), Matrix::zeros(nsamp, pq)))
    } else {
        None
    };
    // The eig solver factored at the final training iteration already
    // holds the final hyperparameters (the loop breaks after the solve,
    // before any Adam step), so the pathwise solves reuse it directly.
    let eig_pred = eig_cur.as_ref().filter(|_| eig_direct);
    let (mut pre, mut level) = if eig_pred.is_some() {
        (Preconditioner::Identity, PrecondLevel::Identity)
    } else {
        build_precond(be, cfg.precond_rank, sigma2, kron_eig_pre, &mut diagnostics)
    };
    let mut done = 0;
    while done < nsamp {
        let b = chunk.min(nsamp - done);
        let z = Matrix::<T>::from_vec(
            b,
            pq,
            rng.normals(b * pq).iter().map(|&x| T::from_f64(x)).collect(),
        );
        let f_prior = prof.time("prior_sample", || be.prior_sample(&z))?;
        // rhs = M (y - f - eps). Per-row noise streams are forked from
        // the master rng *sequentially*, then rows are assembled in
        // parallel from the independent streams — deterministic for any
        // thread count. Each element is formed in f64 and rounded once
        // at the precision boundary.
        let row_rngs: Vec<Rng> = (0..b).map(|r| rng.fork(r as u64)).collect();
        let sigma = sigma2.sqrt();
        let mut rhs = Matrix::<T>::zeros(b, pq);
        prof.time("rhs_assemble", || {
            crate::par::par_chunks_mut("lkgp.rhs_assemble", &mut rhs.data, pq, |r, row| {
                let mut noise = row_rngs[r].clone();
                for (c, x) in row.iter_mut().enumerate() {
                    let eps = sigma * noise.normal();
                    *x = T::from_f64(mask[c] * (y[c] - f_prior[(r, c)].to_f64() - eps));
                }
            });
        });
        let (v, stats) = if let Some(es) = eig_pred {
            prof.time("eig_sample", || {
                solve_eig_direct(es, &rhs, cfg, &mut diagnostics, "pathwise")
            })?
        } else {
            prof.time("cg_sample", || -> Result<(Matrix<T>, CgStats)> {
                solve_resilient(
                    be,
                    &rhs,
                    &mut pre,
                    &mut level,
                    &cg_opts,
                    cfg,
                    &mut diagnostics,
                    "pathwise",
                )
            })?
        };
        mvm_total += stats.mvm_count;
        // f_post = f_prior + (K (x) K) M v
        let mut vm = v;
        crate::par::par_chunks_mut_cheap("lkgp.mask_v", &mut vm.data, pq, |_, row| {
            for (x, m) in row.iter_mut().zip(&mask) {
                *x *= T::from_f64(*m);
            }
        });
        if let Some((vm_all, fp_all)) = capture.as_mut() {
            for r in 0..b {
                vm_all.row_mut(done + r).copy_from_slice(vm.row(r));
                fp_all.row_mut(done + r).copy_from_slice(f_prior.row(r));
            }
        }
        let kv = prof.time("predict_apply", || be.kron_apply(&vm))?;
        // accumulate pathwise moments per grid cell in parallel; the
        // per-cell reduction over sample rows runs in a fixed order and
        // in f64 (in both precisions), so the posterior is bit-identical
        // for any thread count
        prof.time("var_accum", || {
            accumulate_pathwise_moments(&f_prior, &kv, &mut mean_acc, &mut var_acc);
        });
        done += b;
    }
    // raw scale: mean from exact solve, variance from samples + noise
    let mean_std64: Vec<f64> = mean_std.row(0).iter().map(|x| x.to_f64()).collect();
    let posterior =
        finalize_posterior(&mean_std64, &mean_acc, &var_acc, nsamp, sigma2, y_mean, y_std);
    let predict_secs = t_pred.elapsed().as_secs_f64();

    let model = capture.map(|(vm_all, fp_all)| crate::model::TrainedModel {
        name: data.name.clone(),
        time_family: data.time_family.clone(),
        precision: match T::NAME {
            "f32" => Precision::F32,
            _ => Precision::F64,
        },
        time_op: be.time_op_path(),
        projection: ProjectionPath::Mask,
        w: None,
        ds: data.s.cols,
        s: data.s.clone(),
        t: data.t.clone(),
        mask: mask.clone(),
        theta: params[..n_theta].to_vec(),
        log_sigma2: params[n_theta],
        y_mean,
        y_std,
        n_samples: nsamp,
        masked_alpha: masked_alpha.row(0).iter().map(|x| x.to_f64()).collect(),
        vm: vm_all.cast(),
        f_prior: fp_all.cast(),
        posterior: posterior.clone(),
    });

    Ok(LkgpFit {
        posterior,
        theta: params[..n_theta].to_vec(),
        log_sigma2: params[n_theta],
        loss_trace,
        train_secs,
        predict_secs,
        cg_iters_total,
        mvm_total,
        kernel_bytes: be.kernel_bytes(),
        profile: prof,
        model,
        diagnostics,
    })
}

/// Entry point of the SKI fit: same parallel-region panic capture as
/// [`fit_with_backend`].
fn fit_interp<T: Scalar>(
    data: &OffGridDataset,
    cfg: &LkgpConfig,
    be: &mut InterpRustBackend<T>,
) -> Result<LkgpFit> {
    crate::par::catch_region(|| fit_interp_inner(data, cfg, be))
        .map_err(|rp| anyhow::Error::new(rp).context("parallel region fault during fit"))?
}

/// The SKI fit body: a statement-by-statement mirror of
/// [`fit_with_backend_inner`] with the 0/1 mask generalized to the
/// sparse interpolation projection `W`. The system vectors (targets,
/// probes, CG solutions) live in the n-point *data space*; the prior
/// samples, representer weights, and posterior live on the latent p*q
/// grid, with `W` / `W^T` projecting between the two. When every
/// training point coincides with a grid node the linear `W` is exactly
/// the mask and (multiplying by a weight of exactly 1.0 being an IEEE
/// identity) every stage below reproduces the mask path's bits.
fn fit_interp_inner<T: Scalar>(
    data: &OffGridDataset,
    cfg: &LkgpConfig,
    be: &mut InterpRustBackend<T>,
) -> Result<LkgpFit> {
    let mut prof = Profile::new();
    let t_train = std::time::Instant::now();
    let (p, q) = (data.p(), data.q());
    let pq = p * q;
    let n = data.n();
    let y = data.y_std();
    let (y_mean, y_std) = data.target_stats();
    let s_nodes = data.s_matrix();

    // the backend reads the grids; the mask argument is ignored (the
    // projection already encodes the data -> grid incidence)
    be.set_data(&s_nodes, &data.grid_t, &[])?;

    // The direct spectral solver addresses the p*q grid system and
    // cannot run here (dim() is n); Solver::Eig still requests the
    // latent-grid KronEig preconditioner, which walks the fallback
    // chain (the backend exposes no Gram factors by design).
    let kron_eig_pre = cfg.solver == Solver::Eig;

    // hyperparameter vector: [theta.., log_sigma2]
    let mut kernel = crate::kernels::ProductGridKernel::new(1, &data.time_family, q);
    let n_theta = kernel.n_theta();
    let mut params = vec![0.0; n_theta + 1];
    params[n_theta] = cfg.init_log_sigma2;

    let mut adam = crate::optim::Adam::new(n_theta + 1, cfg.lr);
    let mut rng = Rng::new(cfg.seed ^ 0x16C9);

    // fixed Rademacher probes in data space (no mask factor: every
    // point is observed; when W is the mask this draws the same stream
    // and the mask path's `* 1.0` is the identity)
    let n_probes = be.probes();
    let z_probes = {
        let mut z = Matrix::<T>::zeros(n_probes, n);
        for i in 0..n_probes {
            // drawn in f64, rounded once at the precision boundary
            let row: Vec<T> =
                rng.rademacher_f32(n).iter().map(|&r| T::from_f64(r as f64)).collect();
            z.row_mut(i).copy_from_slice(&row);
        }
        z
    };
    let y_t: Vec<T> = y.iter().map(|&v| T::from_f64(v)).collect();

    let cg_opts =
        CgOptions { max_iters: cfg.cg_max_iters, tol: cfg.cg_tol, ..CgOptions::default() };
    let mut loss_trace = Vec::with_capacity(cfg.train_iters);
    let mut cg_iters_total = 0;
    let mut mvm_total = 0;
    let mut diagnostics = FitDiagnostics {
        time_op: be.time_op_path(),
        projection: ProjectionPath::Interp(be.proj().degree()),
        ..FitDiagnostics::default()
    };
    let mut alpha = vec![T::ZERO; n];

    for it in 0..cfg.train_iters + 1 {
        let theta = &params[..n_theta];
        let log_s2 = params[n_theta];
        prof.time("set_hypers", || be.set_hypers(theta, log_s2))?;
        kernel.set_theta(theta);

        // batched solve: [y | probes]
        let mut rhs = Matrix::<T>::zeros(1 + n_probes, n);
        rhs.row_mut(0).copy_from_slice(&y_t);
        for i in 0..n_probes {
            rhs.row_mut(1 + i).copy_from_slice(z_probes.row(i));
        }
        let (sol, stats) = {
            let (mut pre, mut level) = prof.time("precond", || {
                build_precond(be, cfg.precond_rank, log_s2.exp(), kron_eig_pre, &mut diagnostics)
            });
            prof.time("cg_solve", || -> Result<(Matrix<T>, CgStats)> {
                let d = &mut diagnostics;
                solve_resilient(be, &rhs, &mut pre, &mut level, &cg_opts, cfg, d, "train")
            })?
        };
        cg_iters_total += stats.iters;
        mvm_total += stats.mvm_count;
        alpha.copy_from_slice(sol.row(0));
        // data-fit term accumulates in f64 in both precisions
        let fit_term =
            0.5 * y.iter().zip(&alpha).map(|(a, b)| a * b.to_f64()).sum::<f64>();
        loss_trace.push(fit_term);

        if it == cfg.train_iters {
            break; // final solve only (alpha for prediction)
        }
        let w = {
            let mut w = Matrix::<T>::zeros(n_probes, n);
            for i in 0..n_probes {
                w.row_mut(i).copy_from_slice(sol.row(1 + i));
            }
            w
        };
        let grads = prof.time("mll_grads", || be.mll_grads(&alpha, &w, &z_probes))?;
        adam.step(&mut params, &grads);
    }
    diagnostics.grads_skipped_nonfinite = adam.skipped_nonfinite();
    let train_secs = t_train.elapsed().as_secs_f64();

    // ---- prediction via pathwise conditioning ----
    let t_pred = std::time::Instant::now();
    let sigma2 = params[n_theta].exp();
    // exact predictive mean on the grid: mu = (K (x) K) W^T alpha
    let grid_alpha = {
        let a = Matrix::<T>::from_vec(1, n, alpha.clone());
        be.proj().interp_apply_t(&a)
    };
    let mean_std = prof.time("predict_mean", || be.kron_apply(&grid_alpha))?;

    // pathwise samples for predictive variance
    let nsamp = cfg.n_samples.max(2);
    let mut var_acc = vec![0.0f64; pq];
    let mut mean_acc = vec![0.0f64; pq];
    let chunk = PATHWISE_CHUNK;
    let mut capture: Option<(Matrix<T>, Matrix<T>)> = if cfg.capture_pathwise {
        Some((Matrix::zeros(nsamp, pq), Matrix::zeros(nsamp, pq)))
    } else {
        None
    };
    let (mut pre, mut level) =
        build_precond(be, cfg.precond_rank, sigma2, kron_eig_pre, &mut diagnostics);
    let mut done = 0;
    while done < nsamp {
        let b = chunk.min(nsamp - done);
        let z = Matrix::<T>::from_vec(
            b,
            pq,
            rng.normals(b * pq).iter().map(|&x| T::from_f64(x)).collect(),
        );
        let f_prior = prof.time("prior_sample", || be.prior_sample(&z))?;
        // prior sample values at the data points: W f
        let wf = be.proj().interp_apply(&f_prior);
        // rhs = y - W f - eps, per-row noise streams forked from the
        // master rng *sequentially* as in the mask path. Each element
        // is formed in f64 and rounded once at the precision boundary.
        let row_rngs: Vec<Rng> = (0..b).map(|r| rng.fork(r as u64)).collect();
        let sigma = sigma2.sqrt();
        let mut rhs = Matrix::<T>::zeros(b, n);
        prof.time("rhs_assemble", || {
            crate::par::par_chunks_mut("lkgp.rhs_assemble", &mut rhs.data, n, |r, row| {
                let mut noise = row_rngs[r].clone();
                for (c, x) in row.iter_mut().enumerate() {
                    let eps = sigma * noise.normal();
                    *x = T::from_f64(y[c] - wf[(r, c)].to_f64() - eps);
                }
            });
        });
        let (v, stats) = prof.time("cg_sample", || -> Result<(Matrix<T>, CgStats)> {
            solve_resilient(
                be,
                &rhs,
                &mut pre,
                &mut level,
                &cg_opts,
                cfg,
                &mut diagnostics,
                "pathwise",
            )
        })?;
        mvm_total += stats.mvm_count;
        // f_post = f_prior + (K (x) K) W^T v
        let u = be.proj().interp_apply_t(&v);
        if let Some((vm_all, fp_all)) = capture.as_mut() {
            for r in 0..b {
                vm_all.row_mut(done + r).copy_from_slice(u.row(r));
                fp_all.row_mut(done + r).copy_from_slice(f_prior.row(r));
            }
        }
        let kv = prof.time("predict_apply", || be.kron_apply(&u))?;
        prof.time("var_accum", || {
            accumulate_pathwise_moments(&f_prior, &kv, &mut mean_acc, &mut var_acc);
        });
        done += b;
    }
    // raw scale: mean from exact solve, variance from samples + noise
    let mean_std64: Vec<f64> = mean_std.row(0).iter().map(|x| x.to_f64()).collect();
    let posterior =
        finalize_posterior(&mean_std64, &mean_acc, &var_acc, nsamp, sigma2, y_mean, y_std);
    let predict_secs = t_pred.elapsed().as_secs_f64();

    let model = capture.map(|(vm_all, fp_all)| crate::model::TrainedModel {
        name: data.name.clone(),
        time_family: data.time_family.clone(),
        precision: match T::NAME {
            "f32" => Precision::F32,
            _ => Precision::F64,
        },
        time_op: be.time_op_path(),
        projection: ProjectionPath::Interp(be.proj().degree()),
        w: Some(be.proj().clone()),
        ds: 1,
        s: s_nodes.clone(),
        t: data.grid_t.clone(),
        // serve-time replay is entirely grid-space (W^T is already
        // folded into the stored tensors), so the grid mask is all-ones
        mask: vec![1.0; pq],
        theta: params[..n_theta].to_vec(),
        log_sigma2: params[n_theta],
        y_mean,
        y_std,
        n_samples: nsamp,
        masked_alpha: grid_alpha.row(0).iter().map(|x| x.to_f64()).collect(),
        vm: vm_all.cast(),
        f_prior: fp_all.cast(),
        posterior: posterior.clone(),
    });

    Ok(LkgpFit {
        posterior,
        theta: params[..n_theta].to_vec(),
        log_sigma2: params[n_theta],
        loss_trace,
        train_secs,
        predict_secs,
        cg_iters_total,
        mvm_total,
        kernel_bytes: be.kernel_bytes(),
        profile: prof,
        model,
        diagnostics,
    })
}

/// Pathwise samples are drawn and accumulated in chunks of this many
/// rows. Shared by training and serve-time reconstruction
/// (`crate::serve`) so the per-cell moment accumulation order — and
/// therefore every posterior bit — is identical in both paths.
pub(crate) const PATHWISE_CHUNK: usize = 16;

/// Accumulate pathwise first/second moments per grid cell:
/// `mean_acc[c] += sum_r f(r, c)` and `var_acc[c] += sum_r f(r, c)^2`
/// with `f = f_prior + kv` widened to f64. The per-cell reduction over
/// sample rows runs in a fixed ascending order and in f64 (in both
/// precisions), so the result is bit-identical for any thread count and
/// for any caller that presents the same row chunks in the same order.
pub(crate) fn accumulate_pathwise_moments<T: Scalar>(
    f_prior: &Matrix<T>,
    kv: &Matrix<T>,
    mean_acc: &mut [f64],
    var_acc: &mut [f64],
) {
    let b = f_prior.rows;
    debug_assert_eq!(kv.rows, b);
    debug_assert_eq!(f_prior.cols, kv.cols);
    let block = 1024usize;
    let accum = |ci: usize, mseg: &mut [f64], vseg: &mut [f64]| {
        let base = ci * block;
        for (off, (ma, va)) in mseg.iter_mut().zip(vseg.iter_mut()).enumerate() {
            let c = base + off;
            let mut msum = 0.0;
            let mut vsum = 0.0;
            for r in 0..b {
                let f = f_prior[(r, c)].to_f64() + kv[(r, c)].to_f64();
                msum += f;
                vsum += f * f;
            }
            *ma += msum;
            *va += vsum;
        }
    };
    crate::par::par_zip_mut("lkgp.var_accum", mean_acc, var_acc, block, accum);
}

/// Convert accumulated pathwise moments + the exact standardized mean
/// into the raw-scale [`Posterior`]: mean from the exact alpha solve,
/// variance from the sample moments plus observation noise. Pure
/// sequential f64 arithmetic — bit-identical wherever the inputs are.
pub(crate) fn finalize_posterior(
    mean_std: &[f64],
    mean_acc: &[f64],
    var_acc: &[f64],
    nsamp: usize,
    sigma2: f64,
    y_mean: f64,
    y_std: f64,
) -> Posterior {
    let pq = mean_std.len();
    let mut mean = vec![0.0; pq];
    let mut var = vec![0.0; pq];
    for c in 0..pq {
        let m_samp = mean_acc[c] / nsamp as f64;
        let v_samp =
            (var_acc[c] / nsamp as f64 - m_samp * m_samp).max(1e-10) * nsamp as f64
                / (nsamp - 1) as f64;
        mean[c] = mean_std[c] * y_std + y_mean;
        var[c] = (v_samp + sigma2) * y_std * y_std;
    }
    Posterior { mean, var }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::well_specified;
    use crate::kernels::ProductGridKernel;

    fn quick_cfg() -> LkgpConfig {
        LkgpConfig {
            train_iters: 15,
            n_samples: 16,
            cg_max_iters: 200,
            cg_tol: 1e-3,
            probes: 4,
            ..LkgpConfig::default()
        }
    }

    #[test]
    fn recovers_well_specified_signal() {
        let mut kernel = ProductGridKernel::new(2, "rbf", 8);
        let mut theta = vec![0.0; kernel.n_theta()];
        theta[2] = 0.5; // outputscale e^0.5
        kernel.set_theta(&theta);
        let data = well_specified(24, 8, 2, &kernel, 0.01, 0.25, 42);
        let fit = Lkgp::fit(&data, quick_cfg()).unwrap();
        let (test_rmse, test_nll) = fit.posterior.test_metrics(&data);
        // data std ~ 1; exact GP interpolation should do much better
        let (_, y_std) = data.target_stats();
        assert!(test_rmse < 0.8 * y_std, "rmse {test_rmse} vs std {y_std}");
        assert!(test_nll < 1.5, "nll {test_nll}");
        // loss trace is populated and finite (the fit term alone is not
        // monotone — NLL trades it against the logdet — so no ordering
        // assertion here)
        assert_eq!(fit.loss_trace.len(), 16);
        assert!(fit.loss_trace.iter().all(|x| x.is_finite()));
        // exact-GP train fit must beat test fit
        let (train_rmse, _) = fit.posterior.train_metrics(&data);
        assert!(train_rmse < test_rmse, "{train_rmse} !< {test_rmse}");
    }

    #[test]
    fn dense_baseline_matches_kron_posterior() {
        // The paper's Fig-3 claim: identical predictions, different cost.
        let kernel = ProductGridKernel::new(2, "rbf", 6);
        let data = well_specified(16, 6, 2, &kernel, 0.05, 0.3, 7);
        let cfg_kron = LkgpConfig { seed: 5, ..quick_cfg() };
        let cfg_dense = LkgpConfig {
            seed: 5,
            backend: Backend::Rust(MvmMode::DenseMaterialized),
            ..quick_cfg()
        };
        let fit_k = Lkgp::fit(&data, cfg_kron).unwrap();
        let fit_d = Lkgp::fit(&data, cfg_dense).unwrap();
        // same seed, same probes, same solver: posteriors agree to CG tol
        let scale = fit_k
            .posterior
            .mean
            .iter()
            .map(|x| x.abs())
            .fold(0.0, f64::max)
            .max(1e-6);
        for i in 0..fit_k.posterior.mean.len() {
            assert!(
                (fit_k.posterior.mean[i] - fit_d.posterior.mean[i]).abs() < 0.05 * scale,
                "mean mismatch at {i}: {} vs {}",
                fit_k.posterior.mean[i],
                fit_d.posterior.mean[i]
            );
        }
        assert!(fit_k.kernel_bytes < fit_d.kernel_bytes);
    }

    #[test]
    fn f32_precision_matches_f64_posterior() {
        // The mixed-precision contract: an f32 fit with the same seed
        // reproduces the f64 posterior to well under the CG tolerance,
        // and its test RMSE lands within ~1% (the Fig-3 check runs at
        // scale in bench_precision.rs).
        let kernel = ProductGridKernel::new(2, "rbf", 8);
        let data = well_specified(20, 8, 2, &kernel, 0.01, 0.25, 13);
        // gentle Adam steps keep the two trajectories glued so this
        // compares numerics, not optimizer bifurcation
        let cfg64 = LkgpConfig { seed: 5, train_iters: 10, lr: 0.02, ..quick_cfg() };
        let cfg32 = LkgpConfig { precision: Precision::F32, ..cfg64.clone() };
        let fit64 = Lkgp::fit(&data, cfg64).unwrap();
        let fit32 = Lkgp::fit(&data, cfg32).unwrap();
        let scale = fit64
            .posterior
            .mean
            .iter()
            .map(|x| x.abs())
            .fold(0.0, f64::max)
            .max(1e-6);
        for i in 0..fit64.posterior.mean.len() {
            assert!(
                (fit64.posterior.mean[i] - fit32.posterior.mean[i]).abs()
                    < 0.05 * scale + 0.02,
                "mean mismatch at {i}: {} vs {}",
                fit64.posterior.mean[i],
                fit32.posterior.mean[i]
            );
            assert!(fit32.posterior.var[i].is_finite() && fit32.posterior.var[i] > 0.0);
        }
        let (r64, _) = fit64.posterior.test_metrics(&data);
        let (r32, _) = fit32.posterior.test_metrics(&data);
        assert!(
            (r64 - r32).abs() <= 0.02 * r64.max(1e-9),
            "f32 test rmse {r32} vs f64 {r64}"
        );
        // the f32 factored kernel is half the size
        assert_eq!(fit32.kernel_bytes * 2, fit64.kernel_bytes);
    }

    #[test]
    fn toeplitz_time_op_matches_dense_posterior() {
        // Same seed, same probe/sample streams: routing the K_TT half
        // through the FFT path must land on the same posterior as the
        // dense GEMM to within the solve tolerance (same shape of bound
        // as the f32-vs-f64 and eig-vs-cg contracts).
        use super::super::diagnostics::TimeOpPath;
        let kernel = ProductGridKernel::new(2, "rbf", 8);
        let data = well_specified(16, 8, 2, &kernel, 0.05, 0.3, 17);
        let cfg_d = LkgpConfig { seed: 5, train_iters: 8, lr: 0.02, ..quick_cfg() };
        let cfg_t = LkgpConfig { time_op: TimeOpChoice::Toeplitz, ..cfg_d.clone() };
        let fit_d = Lkgp::fit(&data, cfg_d).unwrap();
        let fit_t = Lkgp::fit(&data, cfg_t).unwrap();
        assert_eq!(fit_d.diagnostics.time_op, TimeOpPath::Dense);
        assert_eq!(fit_t.diagnostics.time_op, TimeOpPath::Toeplitz);
        let scale = fit_d
            .posterior
            .mean
            .iter()
            .map(|x| x.abs())
            .fold(0.0, f64::max)
            .max(1e-6);
        for i in 0..fit_d.posterior.mean.len() {
            assert!(
                (fit_d.posterior.mean[i] - fit_t.posterior.mean[i]).abs()
                    < 0.05 * scale + 0.02,
                "mean mismatch at {i}: {} vs {}",
                fit_d.posterior.mean[i],
                fit_t.posterior.mean[i]
            );
            assert!(fit_t.posterior.var[i].is_finite() && fit_t.posterior.var[i] > 0.0);
        }
    }

    #[test]
    fn pivoted_preconditioner_reduces_cg_iterations() {
        let kernel = ProductGridKernel::new(2, "rbf", 8);
        let data = well_specified(20, 8, 2, &kernel, 0.005, 0.2, 3);
        let base = LkgpConfig { train_iters: 3, n_samples: 4, ..quick_cfg() };
        let plain = Lkgp::fit(&data, LkgpConfig { precond_rank: 0, ..base.clone() }).unwrap();
        let pre =
            Lkgp::fit(&data, LkgpConfig { precond_rank: 30, ..base }).unwrap();
        assert!(
            pre.cg_iters_total <= plain.cg_iters_total,
            "pivchol {} !<= jacobi {}",
            pre.cg_iters_total,
            plain.cg_iters_total
        );
    }

    #[test]
    fn full_grid_auto_runs_zero_cg_iterations() {
        // Acceptance gate: a fully-observed grid under the default Auto
        // solver must never enter CG — every solve is a direct spectral
        // solve with true residuals at roundoff level.
        let kernel = ProductGridKernel::new(2, "rbf", 8);
        let data = well_specified(20, 8, 2, &kernel, 0.01, 0.0, 21);
        let fit = Lkgp::fit(&data, quick_cfg()).unwrap();
        assert_eq!(fit.diagnostics.solver_path, SolverPath::Eig);
        assert!(fit.diagnostics.eig_solves > 0, "{:?}", fit.diagnostics);
        assert_eq!(fit.diagnostics.cg_solves, 0);
        assert_eq!(fit.diagnostics.cg_iters_total, 0);
        assert_eq!(fit.cg_iters_total, 0);
        assert_eq!(fit.mvm_total, 0);
        // exact solves: residuals far inside the CG tolerance
        assert!(
            fit.diagnostics.worst_rel_residual < 1e-8,
            "worst rel residual {}",
            fit.diagnostics.worst_rel_residual
        );
        assert_eq!(fit.diagnostics.nonconverged_solves, 0);
        assert!(fit.posterior.var.iter().all(|&v| v.is_finite() && v > 0.0));
    }

    #[test]
    fn eig_and_cg_posteriors_agree_on_full_grid() {
        // Same seed, same probe/sample streams: forcing CG on a full
        // grid must land on the same posterior as the spectral path to
        // within the solve tolerance (same shape of bound as the
        // f32-vs-f64 contract above).
        let kernel = ProductGridKernel::new(2, "rbf", 6);
        let data = well_specified(16, 6, 2, &kernel, 0.05, 0.0, 29);
        let cfg_cg = LkgpConfig {
            seed: 5,
            train_iters: 10,
            lr: 0.02,
            solver: Solver::Cg,
            ..quick_cfg()
        };
        let cfg_eig = LkgpConfig { solver: Solver::Auto, ..cfg_cg.clone() };
        let fit_cg = Lkgp::fit(&data, cfg_cg).unwrap();
        let fit_eig = Lkgp::fit(&data, cfg_eig).unwrap();
        assert_eq!(fit_cg.diagnostics.solver_path, SolverPath::Cg);
        assert!(fit_cg.diagnostics.eig_solves == 0 && fit_cg.cg_iters_total > 0);
        assert_eq!(fit_eig.diagnostics.solver_path, SolverPath::Eig);
        let scale = fit_cg
            .posterior
            .mean
            .iter()
            .map(|x| x.abs())
            .fold(0.0, f64::max)
            .max(1e-6);
        for i in 0..fit_cg.posterior.mean.len() {
            assert!(
                (fit_cg.posterior.mean[i] - fit_eig.posterior.mean[i]).abs()
                    < 0.05 * scale + 0.02,
                "mean mismatch at {i}: {} vs {}",
                fit_cg.posterior.mean[i],
                fit_eig.posterior.mean[i]
            );
            assert!(fit_eig.posterior.var[i].is_finite() && fit_eig.posterior.var[i] > 0.0);
        }
    }

    #[test]
    fn zero_noise_jacobi_falls_back_to_identity() {
        // try_jacobi regression: sigma2 = 0 zeroes the system diagonal
        // at every unobserved cell, so the Jacobi constructor must fail
        // typed and the fit must walk to the identity preconditioner
        // instead of dividing by zero.
        let kernel = ProductGridKernel::new(2, "rbf", 6);
        let data = well_specified(12, 6, 2, &kernel, 0.01, 0.3, 33);
        let cfg = LkgpConfig {
            train_iters: 0,
            n_samples: 2,
            init_log_sigma2: f64::NEG_INFINITY,
            ..quick_cfg()
        };
        let fit = Lkgp::fit(&data, cfg).unwrap();
        assert!(
            fit.diagnostics
                .precond_fallbacks
                .iter()
                .any(|f| f.from == PrecondLevel::Jacobi && f.to == PrecondLevel::Identity),
            "{:?}",
            fit.diagnostics.precond_fallbacks
        );
        assert!(fit.posterior.mean.iter().all(|m| m.is_finite()));
    }

    #[test]
    fn variance_higher_at_missing_cells() {
        let kernel = ProductGridKernel::new(2, "rbf", 8);
        let data = well_specified(20, 8, 2, &kernel, 0.01, 0.3, 11);
        let fit = Lkgp::fit(&data, quick_cfg()).unwrap();
        let var_obs: f64 = data
            .observed_indices()
            .iter()
            .map(|&i| fit.posterior.var[i])
            .sum::<f64>()
            / data.n_observed() as f64;
        let var_miss: f64 = data
            .missing_indices()
            .iter()
            .map(|&i| fit.posterior.var[i])
            .sum::<f64>()
            / data.missing_indices().len() as f64;
        assert!(
            var_miss > var_obs,
            "missing var {var_miss} !> observed var {var_obs}"
        );
    }
}
