//! Register-tiled, packed, multithreaded GEMM for row-major matrices.
//!
//! The innermost compute layer of the crate. Both public entry points
//! (`matmul_acc` for C += A @ B and `matmul_nt` for C = A @ B^T) run the
//! same three-level schedule:
//!
//! 1. **Pack** B once per call into panel-major strips (`pack_b`):
//!    for each KC-deep k-panel, NR-wide column strips laid out so the
//!    microkernel reads one contiguous NR-vector per k step.
//! 2. **Block** C into row blocks (MC rows, shrunk for short C so the
//!    pool still fans out) — the parallel work unit, distributed over
//!    the `crate::par` pool. Each block packs its own A rows into
//!    MR-lane panels (`gemm_block`).
//! 3. **Microkernel**: an MR x NR register tile (4x4 for f64, 4x8 for
//!    f32) of explicit FMA lanes over the packed panels — AVX2+FMA
//!    `_mm256_fmadd_pd/ps` when the CPU has them (runtime-detected,
//!    stable Rust), otherwise a portable mul+add tile with the same
//!    loop structure ([`Scalar::gemm_microkernel`]).
//!
//! **Bit-invariance contract.** Every C cell is produced by a fixed
//! reduction order: ascending k within a panel (one FMA chain per tile
//! cell), panels accumulated in ascending k0, and block/strip/tile
//! boundaries depend only on the matrix shape and the [`Tiling`]
//! constants — never on the thread count. Each block is written by
//! exactly one worker, so results are bit-identical for any
//! `LKGP_THREADS` in both precisions (asserted end-to-end by
//! rust/tests/par_invariance.rs). The FMA and portable kernels round
//! differently (fused vs two-step), so bits are fixed per *machine*,
//! not across CPU families — same contract as libm already imposes on
//! the golden posterior.
//!
//! Ragged edges are handled by zero-padding the packed panels in the
//! M/N directions only: padding adds discarded output lanes, never
//! extra terms to a valid cell's reduction chain, so edge tiles are
//! bit-identical to what a full tile would produce for those cells.
//!
//! The pre-microkernel scalar kernels survive in two roles: products
//! below the `SMALL_GEMM_FLOPS` threshold dispatch to them outright (packing and
//! panel allocations would rival the multiply itself — a shape-only
//! decision, so bit-invariance is unaffected), and [`matmul_nt_ref`]
//! is the baseline the `bench-smoke` CI job measures the tile against
//! (BENCH_par.json `gemm_microkernel` acceptance fields).

use super::matrix::{Matrix, Scalar};
use crate::par;

/// Cache block sizes: C rows per parallel block, packed k-panel depth.
const MC: usize = 64;
const KC: usize = 256;

/// Below this many FLOPs a GEMM runs sequentially. Re-tuned for the
/// persistent pool: a region dispatch costs ~a microsecond (a condvar
/// wake at worst) where the old scoped spawn/join cost tens, so the
/// fan-out break-even dropped 4x from the PR-1 value of 2.5e5.
/// Sequential and parallel paths walk the same blocks in the same
/// order, so this is purely a scheduling decision.
const PAR_MIN_FLOPS: f64 = 6.4e4;

/// Below this many FLOPs the packing overhead (B re-pack + panel/tile
/// allocations per call) can rival the multiply itself, so tiny
/// products take the allocation-free scalar kernels instead — e.g. the
/// per-column `kernel_col` Grams in pivoted Cholesky and the q x q
/// half of a small Kron MVM row. The dispatch depends only on the
/// shape, so thread-count bit-invariance is unaffected.
const SMALL_GEMM_FLOPS: f64 = 2.0e4;

/// GEMM blocking parameters for one scalar type.
///
/// `mr`/`nr` are the register microtile dimensions (per-scalar: the NR
/// axis is one SIMD vector — f64x4 or f32x8 on AVX2); `mc`/`kc` are the
/// cache blocks shared by both precisions. All four shape the packed
/// layouts, so they are compile-time constants surfaced through
/// [`Scalar`]; this struct is the runtime view the drivers and benches
/// work with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tiling {
    /// Microtile rows: A lanes broadcast against each B vector.
    pub mr: usize,
    /// Microtile cols: the SIMD width of one packed B row vector.
    pub nr: usize,
    /// C rows per cache block — the parallel work unit.
    pub mc: usize,
    /// Depth of one packed k-panel.
    pub kc: usize,
}

impl Tiling {
    /// The tiling the GEMM drivers use for scalar type `T`.
    pub fn of<T: Scalar>() -> Tiling {
        Tiling { mr: T::MR, nr: T::NR, mc: MC, kc: KC }
    }
}

/// C = A @ B.
pub fn matmul<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>) -> Matrix<T> {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_acc(a, b, &mut c);
    c
}

/// C += A @ B (C must be a.rows x b.cols). MC-row blocks of C are
/// distributed across the worker pool.
pub fn matmul_acc<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>, c: &mut Matrix<T>) {
    assert_eq!(a.cols, b.rows, "inner dims {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    if c.data.is_empty() {
        return;
    }
    if gemm_flops(a.rows, a.cols, b.cols) < SMALL_GEMM_FLOPS {
        matmul_acc_small(a, b, c);
        return;
    }
    gemm_driver(a, b, false, c);
}

/// Allocation-free scalar kernel for tiny C += A @ B (the pre-tiling
/// i-k-j axpy form, sequential).
fn matmul_acc_small<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>, c: &mut Matrix<T>) {
    let n = b.cols;
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == T::ZERO {
                continue;
            }
            let brow = &b.data[kk * n..(kk + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * *bv;
            }
        }
    }
}

/// C = A @ B^T without materializing the transpose: the packing step
/// reads B row-wise (contiguous) and emits the same panel layout the
/// normal orientation uses, so both products share one microkernel.
/// Used by kernel Gram construction and the V @ K_TT^T half of the
/// Kron MVM.
pub fn matmul_nt<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>) -> Matrix<T> {
    assert_eq!(a.cols, b.cols, "inner dims for A B^T");
    if gemm_flops(a.rows, a.cols, b.rows) < SMALL_GEMM_FLOPS {
        // tiny product: the pack-free dot-product kernel wins
        return matmul_nt_ref(a, b);
    }
    let mut c = Matrix::zeros(a.rows, b.rows);
    if c.data.is_empty() {
        return c;
    }
    gemm_driver(a, b, true, &mut c);
    c
}

/// Shared driver behind `matmul_acc` / `matmul_nt`: pack B, then walk
/// MC-row blocks of C — in parallel when the product is big enough.
/// Block boundaries depend only on the shape and `Tiling::mc`, and the
/// sequential path walks the identical blocks in the identical order,
/// so the output bits never depend on the thread count.
fn gemm_driver<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>, b_transposed: bool, c: &mut Matrix<T>) {
    let tl = Tiling::of::<T>();
    let ndim = c.cols;
    let bpack = pack_b(b, b_transposed, &tl);
    // Row-block granularity: MC rows per block, shrunk (to a multiple
    // of MR, aiming for >= 8 blocks) when C is short so that
    // short-and-wide products — e.g. a CG probe batch against a large
    // dense Gram, rows << MC — still fan out across the pool. The rule
    // is a function of the shape alone, and each C cell's reduction
    // chain is independent of how rows are grouped into blocks/strips,
    // so the choice cannot affect output bits.
    let per = (c.rows + 7) / 8;
    let block_rows = ((per.clamp(tl.mr, tl.mc) + tl.mr - 1) / tl.mr) * tl.mr;
    let block_elems = block_rows * ndim;
    if gemm_flops(c.rows, a.cols, ndim) < PAR_MIN_FLOPS {
        for (ib, cblock) in c.data.chunks_mut(block_elems).enumerate() {
            gemm_block(a, &bpack, ib * block_rows, cblock, ndim, &tl);
        }
        return;
    }
    // Stealing schedule: row blocks are near-uniform except the ragged
    // last block (short rows, short last k-panel), and with short-C
    // shrinking the block count need not divide the worker count — the
    // shared-cursor assignment keeps every worker busy to the end.
    // Chunk content is a pure function of the block index, so the
    // schedule cannot affect output bits.
    let bp = &bpack;
    par::par_chunks_mut_steal("gemm.row_blocks", &mut c.data, block_elems, |ib, cblock| {
        gemm_block(a, bp, ib * block_rows, cblock, ndim, &tl);
    });
}

/// Pack the logical B' (kdim x ndim, where B' = B or B^T) into
/// panel-major strips: for each KC-deep k-panel (ascending k0), for
/// each NR-wide column strip (ascending j0), a contiguous `kcp * nr`
/// run with `packed[kk * nr + jj] = B'[k0 + kk][j0 + jj]`, zero-padded
/// in j past `ndim`. The microkernel then loads one contiguous
/// NR-vector per k step regardless of the original orientation.
fn pack_b<T: Scalar>(b: &Matrix<T>, b_transposed: bool, tl: &Tiling) -> Vec<T> {
    let (kdim, ndim) = if b_transposed { (b.cols, b.rows) } else { (b.rows, b.cols) };
    let nr = tl.nr;
    let nstrips = (ndim + nr - 1) / nr;
    let mut out = vec![T::ZERO; kdim * nstrips * nr];
    let mut off = 0usize;
    let mut k0 = 0usize;
    while k0 < kdim {
        let kcp = tl.kc.min(kdim - k0);
        for js in 0..nstrips {
            let j0 = js * nr;
            let jn = nr.min(ndim - j0);
            if b_transposed {
                // B'[k][j] = b[j][k]: read b rows contiguously, write
                // one strided lane per source row
                for jj in 0..jn {
                    let src = &b.data[(j0 + jj) * b.cols + k0..(j0 + jj) * b.cols + k0 + kcp];
                    for (kk, &v) in src.iter().enumerate() {
                        out[off + kk * nr + jj] = v;
                    }
                }
            } else {
                for kk in 0..kcp {
                    let src = &b.data[(k0 + kk) * b.cols + j0..(k0 + kk) * b.cols + j0 + jn];
                    out[off + kk * nr..off + kk * nr + jn].copy_from_slice(src);
                }
            }
            off += kcp * nr;
        }
        k0 += kcp;
    }
    out
}

/// One MC-row block of the tiled GEMM: C[i0.., :] += A[i0.., :] @ B'.
/// Packs the block's A rows into MR-lane panels (zero-padded past the
/// block edge — padding only adds discarded lanes, never terms), then
/// sweeps the microtile grid over the shared packed B. The work done
/// for a block is a pure function of (shape, i0), so distributing
/// blocks over workers cannot change any output bit.
fn gemm_block<T: Scalar>(
    a: &Matrix<T>,
    bpack: &[T],
    i0: usize,
    cblock: &mut [T],
    ndim: usize,
    tl: &Tiling,
) {
    let kdim = a.cols;
    if kdim == 0 {
        return;
    }
    let (mr, nr) = (tl.mr, tl.nr);
    let rows = cblock.len() / ndim;
    let astrips = (rows + mr - 1) / mr;
    let nstrips = (ndim + nr - 1) / nr;
    let padded_n = nstrips * nr;
    // A panel buffer, reused across k-panels with a *constant* per-strip
    // stride (sized for the deepest panel): valid lanes are overwritten
    // every panel at the same positions, so the zero-pad lanes (rows
    // past the block edge) stay zero from this allocation even when the
    // last panel is shorter than KC.
    let astride = mr * tl.kc.min(kdim);
    let mut apanel = vec![T::ZERO; astrips * astride];
    let mut acc = vec![T::ZERO; mr * nr];
    let mut k0 = 0usize;
    while k0 < kdim {
        let kcp = tl.kc.min(kdim - k0);
        // pack A[i0.., k0..k0+kcp] into MR-lane strips:
        // apanel[strip][kk * mr + lane] = A[i0 + strip*mr + lane][k0 + kk]
        for s in 0..astrips {
            let base = s * astride;
            let ilo = s * mr;
            let ihi = rows.min(ilo + mr);
            for i in ilo..ihi {
                let lane = i - ilo;
                let arow = &a.data[(i0 + i) * kdim + k0..(i0 + i) * kdim + k0 + kcp];
                for (kk, &v) in arow.iter().enumerate() {
                    apanel[base + kk * mr + lane] = v;
                }
            }
        }
        // microtile grid: B strip (<= KC*NR elements) stays L1-hot
        // across all A strips of the block
        for js in 0..nstrips {
            let boff = k0 * padded_n + js * kcp * nr;
            let bpan = &bpack[boff..boff + kcp * nr];
            let j0 = js * nr;
            let jn = nr.min(ndim - j0);
            for s in 0..astrips {
                let apan = &apanel[s * astride..s * astride + kcp * mr];
                T::gemm_microkernel(kcp, apan, bpan, &mut acc);
                let ilo = s * mr;
                let ihi = rows.min(ilo + mr);
                for i in ilo..ihi {
                    let crow = &mut cblock[i * ndim + j0..i * ndim + j0 + jn];
                    let trow = &acc[(i - ilo) * nr..(i - ilo) * nr + jn];
                    for (cv, tv) in crow.iter_mut().zip(trow) {
                        *cv += *tv;
                    }
                }
            }
        }
        k0 += kcp;
    }
}

// ---------------------------------------------------------------------
// Microkernels
// ---------------------------------------------------------------------

/// Portable MR x NR microtile: same packed layout and ascending-k
/// reduction order as the FMA kernels, plain mul+add lanes (LLVM
/// vectorizes the NR-wide inner loop for the baseline target).
/// `mul_add` is deliberately NOT used here: without the `fma` target
/// feature it lowers to the correctly-rounded libm call, which is far
/// slower than a mul+add pair.
fn micro_portable<T: Scalar, const MR: usize, const NR: usize>(
    kc: usize,
    ap: &[T],
    bp: &[T],
    acc: &mut [T],
) {
    let mut tile = [[T::ZERO; NR]; MR];
    for k in 0..kc {
        let av = &ap[k * MR..k * MR + MR];
        let bv = &bp[k * NR..k * NR + NR];
        for (trow, ai) in tile.iter_mut().zip(av.iter()) {
            for (t, bj) in trow.iter_mut().zip(bv.iter()) {
                *t += *ai * *bj;
            }
        }
    }
    for (row, trow) in tile.iter().enumerate() {
        acc[row * NR..row * NR + NR].copy_from_slice(trow);
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2+FMA register tiles (stable `std::arch`, runtime-dispatched).
    //! Each accumulator register holds one microtile row; per k step a
    //! single NR-wide B vector is loaded and each broadcast A lane is
    //! fused into its row — one `vfmadd` chain per tile cell, ascending
    //! k, matching the portable kernel's reduction order exactly (up to
    //! the fused rounding).

    use std::arch::x86_64::*;

    /// 4x4 f64 microtile over packed panels (`ap`: kc x 4, `bp`: kc x 4).
    ///
    /// # Safety
    /// Caller must have verified `avx2` and `fma` CPU support and that
    /// `ap.len() >= kc * 4`, `bp.len() >= kc * 4`, `acc.len() >= 16`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn kernel_f64_4x4(kc: usize, ap: &[f64], bp: &[f64], acc: &mut [f64]) {
        let mut c0 = _mm256_setzero_pd();
        let mut c1 = _mm256_setzero_pd();
        let mut c2 = _mm256_setzero_pd();
        let mut c3 = _mm256_setzero_pd();
        let mut a = ap.as_ptr();
        let mut b = bp.as_ptr();
        for _ in 0..kc {
            let bv = _mm256_loadu_pd(b);
            c0 = _mm256_fmadd_pd(_mm256_set1_pd(*a), bv, c0);
            c1 = _mm256_fmadd_pd(_mm256_set1_pd(*a.add(1)), bv, c1);
            c2 = _mm256_fmadd_pd(_mm256_set1_pd(*a.add(2)), bv, c2);
            c3 = _mm256_fmadd_pd(_mm256_set1_pd(*a.add(3)), bv, c3);
            a = a.add(4);
            b = b.add(4);
        }
        _mm256_storeu_pd(acc.as_mut_ptr(), c0);
        _mm256_storeu_pd(acc.as_mut_ptr().add(4), c1);
        _mm256_storeu_pd(acc.as_mut_ptr().add(8), c2);
        _mm256_storeu_pd(acc.as_mut_ptr().add(12), c3);
    }

    /// 4x8 f32 microtile over packed panels (`ap`: kc x 4, `bp`: kc x 8).
    ///
    /// # Safety
    /// Caller must have verified `avx2` and `fma` CPU support and that
    /// `ap.len() >= kc * 4`, `bp.len() >= kc * 8`, `acc.len() >= 32`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn kernel_f32_4x8(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [f32]) {
        let mut c0 = _mm256_setzero_ps();
        let mut c1 = _mm256_setzero_ps();
        let mut c2 = _mm256_setzero_ps();
        let mut c3 = _mm256_setzero_ps();
        let mut a = ap.as_ptr();
        let mut b = bp.as_ptr();
        for _ in 0..kc {
            let bv = _mm256_loadu_ps(b);
            c0 = _mm256_fmadd_ps(_mm256_set1_ps(*a), bv, c0);
            c1 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(1)), bv, c1);
            c2 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(2)), bv, c2);
            c3 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(3)), bv, c3);
            a = a.add(4);
            b = b.add(8);
        }
        _mm256_storeu_ps(acc.as_mut_ptr(), c0);
        _mm256_storeu_ps(acc.as_mut_ptr().add(8), c1);
        _mm256_storeu_ps(acc.as_mut_ptr().add(16), c2);
        _mm256_storeu_ps(acc.as_mut_ptr().add(24), c3);
    }
}

/// Cached runtime check for the AVX2+FMA kernels. Constant per process,
/// so the dispatch can never differ between pool workers.
#[cfg(target_arch = "x86_64")]
fn fma_available() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    // 0 = unknown, 1 = available, 2 = unavailable
    static STATE: AtomicU8 = AtomicU8::new(0);
    match STATE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let ok = std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma");
            STATE.store(if ok { 1 } else { 2 }, Ordering::Relaxed);
            ok
        }
    }
}

/// f64 4x4 microkernel entry point (see [`Scalar::gemm_microkernel`]).
pub(crate) fn microkernel_f64(kc: usize, ap: &[f64], bp: &[f64], acc: &mut [f64]) {
    assert!(ap.len() >= kc * 4 && bp.len() >= kc * 4 && acc.len() >= 16);
    #[cfg(target_arch = "x86_64")]
    if fma_available() {
        // SAFETY: CPU support verified at runtime; lengths checked above
        // cover every lane the kernel touches.
        unsafe { x86::kernel_f64_4x4(kc, ap, bp, acc) };
        return;
    }
    micro_portable::<f64, 4, 4>(kc, ap, bp, acc);
}

/// f32 4x8 microkernel entry point (see [`Scalar::gemm_microkernel`]).
pub(crate) fn microkernel_f32(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [f32]) {
    assert!(ap.len() >= kc * 4 && bp.len() >= kc * 8 && acc.len() >= 32);
    #[cfg(target_arch = "x86_64")]
    if fma_available() {
        // SAFETY: CPU support verified at runtime; lengths checked above
        // cover every lane the kernel touches.
        unsafe { x86::kernel_f32_4x8(kc, ap, bp, acc) };
        return;
    }
    micro_portable::<f32, 4, 8>(kc, ap, bp, acc);
}

// ---------------------------------------------------------------------
// Scalar reference baseline
// ---------------------------------------------------------------------

/// Pre-microkernel scalar kernel for C = A @ B^T — the PR-1 1x4
/// dot-product form, sequential. Kept (not dead code) as the baseline
/// the `bench-smoke` CI job measures the register tile against
/// (`gemm_microkernel.*` acceptance fields in BENCH_par.json) and as an
/// independent oracle in the microkernel property tests.
pub fn matmul_nt_ref<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>) -> Matrix<T> {
    assert_eq!(a.cols, b.cols, "inner dims for A B^T");
    let (m, n) = (a.rows, b.rows);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        let mut j = 0;
        while j + 4 <= n {
            let (b0, b1, b2, b3) = (b.row(j), b.row(j + 1), b.row(j + 2), b.row(j + 3));
            let (mut s0, mut s1, mut s2, mut s3) = (T::ZERO, T::ZERO, T::ZERO, T::ZERO);
            for (idx, x) in arow.iter().enumerate() {
                s0 += *x * b0[idx];
                s1 += *x * b1[idx];
                s2 += *x * b2[idx];
                s3 += *x * b3[idx];
            }
            crow[j] = s0;
            crow[j + 1] = s1;
            crow[j + 2] = s2;
            crow[j + 3] = s3;
            j += 4;
        }
        while j < n {
            let brow = b.row(j);
            let mut acc = T::ZERO;
            for (x, y) in arow.iter().zip(brow) {
                acc += *x * *y;
            }
            crow[j] = acc;
            j += 1;
        }
    }
    c
}

/// FLOP count of an (m x k) @ (k x n) product, for throughput reports.
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::with_threads;
    use crate::util::testing::{assert_close, prop_check};

    fn naive<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>) -> Matrix<T> {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = T::ZERO;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn prop_matches_naive() {
        prop_check("gemm-vs-naive", 17, 25, |g| {
            let (m, k, n) = (g.size(1, 40), g.size(1, 40), g.size(1, 40));
            let a = Matrix::from_vec(m, k, g.vec_normal(m * k));
            let b = Matrix::from_vec(k, n, g.vec_normal(k * n));
            assert_close(&a.matmul(&b).data, &naive(&a, &b).data, 1e-10)
        });
    }

    #[test]
    fn prop_nt_matches_transpose() {
        prop_check("gemm-nt", 19, 20, |g| {
            let (m, k, n) = (g.size(1, 30), g.size(1, 30), g.size(1, 30));
            let a = Matrix::from_vec(m, k, g.vec_normal(m * k));
            let b = Matrix::from_vec(n, k, g.vec_normal(n * k));
            assert_close(&matmul_nt(&a, &b).data, &a.matmul(&b.transpose()).data, 1e-10)
        });
    }

    #[test]
    fn prop_nt_matches_scalar_ref() {
        // tiled vs the pre-microkernel 1x4 kernel — independent oracle
        prop_check("gemm-nt-vs-ref", 23, 15, |g| {
            let (m, k, n) = (g.size(1, 30), g.size(1, 30), g.size(1, 30));
            let a = Matrix::from_vec(m, k, g.vec_normal(m * k));
            let b = Matrix::from_vec(n, k, g.vec_normal(n * k));
            assert_close(&matmul_nt(&a, &b).data, &matmul_nt_ref(&a, &b).data, 1e-10)
        });
    }

    /// Exhaustive ragged-shape sweep against the naive triple loop.
    /// Data is small-integer-valued, so every partial sum (|s| <= a few
    /// hundred) is exactly representable in f32 and f64 and FMA rounding
    /// is exact — every path must match naive *bit for bit*, which pins
    /// the remainder/edge-tile logic precisely. The tiled driver is
    /// invoked directly (these shapes are below the small-product
    /// dispatch threshold), and the public entry points are swept too
    /// so the scalar dispatch stays covered.
    fn ragged_sweep_exact<T: Scalar>() {
        for m in 1..=9usize {
            for k in 0..=9usize {
                for n in 1..=9usize {
                    let a = Matrix::<T>::from_fn(m, k, |i, j| {
                        T::from_f64(((i * 7 + j * 3) % 5) as f64 - 2.0)
                    });
                    let b = Matrix::<T>::from_fn(k, n, |i, j| {
                        T::from_f64(((i + j * 11) % 7) as f64 - 3.0)
                    });
                    let bt = b.transpose();
                    let want = naive(&a, &b);
                    // public entry points (scalar small-product path here)
                    assert!(
                        a.matmul(&b).data == want.data,
                        "{} matmul {m}x{k}x{n} != naive",
                        T::NAME
                    );
                    assert!(
                        matmul_nt(&a, &bt).data == want.data,
                        "{} matmul_nt {m}x{k}x{n} != naive",
                        T::NAME
                    );
                    // tiled driver directly — the microkernel edge cases
                    let mut ct = Matrix::<T>::zeros(m, n);
                    gemm_driver(&a, &b, false, &mut ct);
                    assert!(
                        ct.data == want.data,
                        "{} tiled normal {m}x{k}x{n} != naive",
                        T::NAME
                    );
                    let mut cnt = Matrix::<T>::zeros(m, n);
                    gemm_driver(&a, &bt, true, &mut cnt);
                    assert!(
                        cnt.data == want.data,
                        "{} tiled nt {m}x{k}x{n} != naive",
                        T::NAME
                    );
                    // tiled accumulate into a non-zero C
                    let mut cacc =
                        Matrix::<T>::from_fn(m, n, |i, j| T::from_f64((i + 2 * j) as f64));
                    gemm_driver(&a, &b, false, &mut cacc);
                    for i in 0..m {
                        for j in 0..n {
                            let w = want[(i, j)] + T::from_f64((i + 2 * j) as f64);
                            assert!(
                                cacc[(i, j)] == w,
                                "{} tiled acc {m}x{k}x{n} at ({i},{j})",
                                T::NAME
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn ragged_shapes_exact_f64() {
        ragged_sweep_exact::<f64>();
    }

    #[test]
    fn ragged_shapes_exact_f32() {
        // n up to 9 covers the f32 NR=8 strip plus a 1-wide remainder
        ragged_sweep_exact::<f32>();
    }

    /// Tiled output is bit-identical for any thread count, including
    /// shapes with remainder tiles in every direction.
    fn tiled_thread_invariance<T: Scalar>(bits: impl Fn(&[T]) -> Vec<u64>) {
        let cases = [(130usize, 70usize, 65usize), (67, 17, 9), (5, 3, 2)];
        for &(m, k, n) in &cases {
            let a = Matrix::<T>::from_fn(m, k, |i, j| {
                T::from_f64(((i * 13 + j * 5) % 11) as f64 * 0.37 - 1.5)
            });
            let b = Matrix::<T>::from_fn(n, k, |i, j| {
                T::from_f64(((i * 3 + j * 7) % 13) as f64 * 0.21 - 1.1)
            });
            let bk = b.transpose(); // k x n for matmul
            let want = with_threads(1, || (a.matmul(&bk), matmul_nt(&a, &b)));
            for t in [2usize, 3, 8] {
                let got = with_threads(t, || (a.matmul(&bk), matmul_nt(&a, &b)));
                assert_eq!(
                    bits(&want.0.data),
                    bits(&got.0.data),
                    "{} matmul {m}x{k}x{n} differs at t={t}",
                    T::NAME
                );
                assert_eq!(
                    bits(&want.1.data),
                    bits(&got.1.data),
                    "{} matmul_nt {m}x{k}x{n} differs at t={t}",
                    T::NAME
                );
            }
        }
    }

    #[test]
    fn tiled_bit_identical_across_threads_f64() {
        tiled_thread_invariance::<f64>(|v| v.iter().map(|x| x.to_bits()).collect());
    }

    #[test]
    fn tiled_bit_identical_across_threads_f32() {
        tiled_thread_invariance::<f32>(|v| v.iter().map(|x| x.to_bits() as u64).collect());
    }

    #[test]
    fn blocked_handles_sizes_spanning_blocks() {
        // sizes straddling MC/KC boundaries; (70, 300, 10) pins the
        // A-panel reuse across a short last k-panel with a ragged
        // (padded) row strip in the tail block
        for &(m, k, n) in
            &[(1, 1, 1), (64, 256, 64), (65, 257, 3), (130, 300, 70), (70, 300, 10)]
        {
            let a = Matrix::from_fn(m, k, |i, j| ((i * 7 + j * 3) % 5) as f64 - 2.0);
            let b = Matrix::from_fn(k, n, |i, j| ((i + j * 11) % 7) as f64 - 3.0);
            let got = a.matmul(&b);
            let want = naive(&a, &b);
            assert_close(&got.data, &want.data, 1e-9).unwrap();
        }
    }

    #[test]
    fn acc_accumulates() {
        let a = Matrix::from_fn(3, 3, |i, j| (i + j) as f64);
        let b = Matrix::eye(3);
        let mut c = Matrix::eye(3);
        matmul_acc(&a, &b, &mut c);
        for i in 0..3 {
            for j in 0..3 {
                let want = (i + j) as f64 + if i == j { 1.0 } else { 0.0 };
                assert!((c[(i, j)] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn empty_dims_are_safe() {
        let a = Matrix::<f64>::zeros(0, 5);
        let b = Matrix::<f64>::zeros(5, 3);
        assert_eq!(a.matmul(&b).data.len(), 0);
        let a = Matrix::<f64>::zeros(4, 0);
        let b = Matrix::<f64>::zeros(3, 0);
        let c = matmul_nt(&a, &b); // inner dim 0
        assert_eq!(c.rows, 4);
        assert!(c.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn tiling_matches_scalar_consts() {
        let t64 = Tiling::of::<f64>();
        assert_eq!((t64.mr, t64.nr), (4, 4));
        let t32 = Tiling::of::<f32>();
        assert_eq!((t32.mr, t32.nr), (4, 8));
    }

    #[test]
    fn f32_path_works() {
        let a = Matrix::<f32>::from_fn(20, 30, |i, j| (i as f32 - j as f32) * 0.1);
        let b = Matrix::<f32>::from_fn(30, 10, |i, j| (i as f32 + j as f32) * 0.05);
        let got = a.matmul(&b);
        let want = naive(&a, &b);
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-3);
        }
    }
}
