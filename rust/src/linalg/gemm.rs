//! Blocked, multithreaded GEMM for row-major matrices.
//!
//! Cache-blocked i-k-j kernels whose innermost loops are contiguous
//! fused multiply-adds over the output row (LLVM auto-vectorizes them),
//! parallelized over disjoint output row blocks via `crate::par`. Block
//! boundaries depend only on the matrix shape and `MC` — never on the
//! thread count — and each block is written by exactly one worker with
//! a fixed k-order, so results are bit-identical for any
//! `LKGP_THREADS`. This is the dense-baseline hot path the Fig-2/Fig-3
//! comparisons run on, so it gets its own module + perf tests.

use super::matrix::{Matrix, Scalar};
use crate::par;

/// Cache block sizes (rows of A, inner depth).
const MC: usize = 64;
const KC: usize = 256;

/// Below this many FLOPs a GEMM runs sequentially: thread spawn/join
/// costs tens of microseconds, which only pays off once the product is
/// a few hundred thousand FLOPs. Sequential and parallel paths are
/// bit-identical, so this is purely a scheduling decision.
const PAR_MIN_FLOPS: f64 = 2.5e5;

/// C = A @ B.
pub fn matmul<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>) -> Matrix<T> {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_acc(a, b, &mut c);
    c
}

/// C += A @ B (C must be a.rows x b.cols). MC-row blocks of C are
/// distributed across the worker pool.
pub fn matmul_acc<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>, c: &mut Matrix<T>) {
    assert_eq!(a.cols, b.rows, "inner dims {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let n = b.cols;
    if c.data.is_empty() {
        return;
    }
    if gemm_flops(a.rows, a.cols, n) < PAR_MIN_FLOPS {
        for (ib, cblock) in c.data.chunks_mut(MC * n).enumerate() {
            matmul_block_acc(a, b, ib * MC, cblock);
        }
        return;
    }
    par::par_chunks_mut(&mut c.data, MC * n, |ib, cblock| {
        matmul_block_acc(a, b, ib * MC, cblock);
    });
}

/// One MC-row block of `matmul_acc`: C[i0.., :] += A[i0.., :] @ B, with
/// 2x register blocking over A rows — each B row loaded from cache
/// feeds two output rows (perf pass: +20-30% on the K_SS @ T1 half of
/// the Kron MVM).
fn matmul_block_acc<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>, i0: usize, cblock: &mut [T]) {
    let (k, n) = (a.cols, b.cols);
    let rows = cblock.len() / n;
    let i1 = i0 + rows;
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        let mut i = i0;
        while i + 1 < i1 {
            let li = i - i0;
            let (c_lo, c_hi) = cblock.split_at_mut((li + 1) * n);
            let crow0 = &mut c_lo[li * n..];
            let crow1 = &mut c_hi[..n];
            let arow0 = &a.data[i * k..(i + 1) * k];
            let arow1 = &a.data[(i + 1) * k..(i + 2) * k];
            for kk in k0..k1 {
                let (a0, a1) = (arow0[kk], arow1[kk]);
                if a0 == T::ZERO && a1 == T::ZERO {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                for ((c0, c1), bv) in
                    crow0.iter_mut().zip(crow1.iter_mut()).zip(brow)
                {
                    *c0 += a0 * *bv;
                    *c1 += a1 * *bv;
                }
            }
            i += 2;
        }
        while i < i1 {
            let li = i - i0;
            let arow = &a.data[i * k..(i + 1) * k];
            let crow = &mut cblock[li * n..(li + 1) * n];
            for kk in k0..k1 {
                let aik = arow[kk];
                if aik == T::ZERO {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                // contiguous axpy over the output row — vectorizes
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += aik * *bv;
                }
            }
            i += 1;
        }
    }
}

/// C = A @ B^T without materializing the transpose (dot-product form,
/// both operand rows contiguous), register-blocked 1x4 over B rows and
/// parallelized over output rows. Used by kernel Gram construction and
/// the V @ K_TT^T half of the Kron MVM.
pub fn matmul_nt<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>) -> Matrix<T> {
    assert_eq!(a.cols, b.cols, "inner dims for A B^T");
    let (m, n) = (a.rows, b.rows);
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    if gemm_flops(m, a.cols, n) < PAR_MIN_FLOPS {
        for (i, crow) in c.data.chunks_mut(n).enumerate() {
            matmul_nt_row(a, b, i, crow);
        }
        return c;
    }
    par::par_chunks_mut(&mut c.data, n, |i, crow| {
        matmul_nt_row(a, b, i, crow);
    });
    c
}

/// One output row of `matmul_nt`: four dot products march down the A
/// row together, so each A element loaded from registers feeds four
/// outputs. Per-output accumulation runs in fixed ascending k-order, so
/// the result matches the scalar dot product bit-for-bit.
fn matmul_nt_row<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>, i: usize, crow: &mut [T]) {
    let arow = a.row(i);
    let n = b.rows;
    let mut j = 0;
    while j + 4 <= n {
        let (b0, b1, b2, b3) = (b.row(j), b.row(j + 1), b.row(j + 2), b.row(j + 3));
        let (mut s0, mut s1, mut s2, mut s3) = (T::ZERO, T::ZERO, T::ZERO, T::ZERO);
        for (idx, x) in arow.iter().enumerate() {
            s0 += *x * b0[idx];
            s1 += *x * b1[idx];
            s2 += *x * b2[idx];
            s3 += *x * b3[idx];
        }
        crow[j] = s0;
        crow[j + 1] = s1;
        crow[j + 2] = s2;
        crow[j + 3] = s3;
        j += 4;
    }
    while j < n {
        let brow = b.row(j);
        let mut acc = T::ZERO;
        for (x, y) in arow.iter().zip(brow) {
            acc += *x * *y;
        }
        crow[j] = acc;
        j += 1;
    }
}

/// FLOP count of an (m x k) @ (k x n) product, for throughput reports.
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{assert_close, prop_check};

    fn naive<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>) -> Matrix<T> {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = T::ZERO;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn prop_matches_naive() {
        prop_check("gemm-vs-naive", 17, 25, |g| {
            let (m, k, n) = (g.size(1, 40), g.size(1, 40), g.size(1, 40));
            let a = Matrix::from_vec(m, k, g.vec_normal(m * k));
            let b = Matrix::from_vec(k, n, g.vec_normal(k * n));
            assert_close(&a.matmul(&b).data, &naive(&a, &b).data, 1e-10)
        });
    }

    #[test]
    fn prop_nt_matches_transpose() {
        prop_check("gemm-nt", 19, 20, |g| {
            let (m, k, n) = (g.size(1, 30), g.size(1, 30), g.size(1, 30));
            let a = Matrix::from_vec(m, k, g.vec_normal(m * k));
            let b = Matrix::from_vec(n, k, g.vec_normal(n * k));
            assert_close(&matmul_nt(&a, &b).data, &a.matmul(&b.transpose()).data, 1e-10)
        });
    }

    #[test]
    fn blocked_handles_sizes_spanning_blocks() {
        // sizes straddling MC/KC boundaries
        for &(m, k, n) in &[(1, 1, 1), (64, 256, 64), (65, 257, 3), (130, 300, 70)] {
            let a = Matrix::from_fn(m, k, |i, j| ((i * 7 + j * 3) % 5) as f64 - 2.0);
            let b = Matrix::from_fn(k, n, |i, j| ((i + j * 11) % 7) as f64 - 3.0);
            let got = a.matmul(&b);
            let want = naive(&a, &b);
            assert_close(&got.data, &want.data, 1e-9).unwrap();
        }
    }

    #[test]
    fn acc_accumulates() {
        let a = Matrix::from_fn(3, 3, |i, j| (i + j) as f64);
        let b = Matrix::eye(3);
        let mut c = Matrix::eye(3);
        matmul_acc(&a, &b, &mut c);
        for i in 0..3 {
            for j in 0..3 {
                let want = (i + j) as f64 + if i == j { 1.0 } else { 0.0 };
                assert!((c[(i, j)] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn f32_path_works() {
        let a = Matrix::<f32>::from_fn(20, 30, |i, j| (i as f32 - j as f32) * 0.1);
        let b = Matrix::<f32>::from_fn(30, 10, |i, j| (i as f32 + j as f32) * 0.05);
        let got = a.matmul(&b);
        let want = naive(&a, &b);
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-3);
        }
    }
}
