//! Row-major dense matrix generic over f32/f64.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Floating-point scalar abstraction (f32 | f64). `Send + Sync` so
/// matrices over any scalar can cross the `crate::par` worker pool.
pub trait Scalar:
    Copy
    + PartialOrd
    + Send
    + Sync
    + fmt::Debug
    + fmt::Display
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::AddAssign
    + std::ops::SubAssign
    + std::ops::MulAssign
    + 'static
{
    /// Additive identity.
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;
    /// `"f32"` / `"f64"` — for diagnostics, bench labels, and the
    /// precision-aware test tolerances in `util::testing`.
    const NAME: &'static str;
    /// GEMM microtile rows: A lanes broadcast per k step (see
    /// `linalg::gemm::Tiling`).
    const MR: usize;
    /// GEMM microtile cols — one SIMD vector of packed B (f64x4 /
    /// f32x8 on AVX2).
    const NR: usize;
    /// Register-tiled GEMM microkernel for this scalar: overwrite `acc`
    /// (`MR * NR`, row-major) with the product of packed panels `ap`
    /// (`kc x MR`, lane-major) and `bp` (`kc x NR`), accumulating each
    /// cell in fixed ascending-k order. Packing layout and dispatch
    /// (AVX2+FMA vs portable) live in `linalg::gemm`.
    fn gemm_microkernel(kc: usize, ap: &[Self], bp: &[Self], acc: &mut [Self]);
    /// Square root.
    fn sqrt(self) -> Self;
    /// Absolute value.
    fn abs(self) -> Self;
    /// Natural logarithm.
    fn ln(self) -> Self;
    /// Natural exponential.
    fn exp(self) -> Self;
    /// Round an f64 into this precision (the narrowing point for f32).
    fn from_f64(x: f64) -> Self;
    /// Widen to f64 (exact for both precisions).
    fn to_f64(self) -> f64;
}

macro_rules! impl_scalar {
    ($t:ty, $nr:expr, $kern:path) => {
        impl Scalar for $t {
            const ZERO: Self = 0.0;
            const ONE: Self = 1.0;
            const NAME: &'static str = stringify!($t);
            const MR: usize = 4;
            const NR: usize = $nr;
            #[inline]
            fn gemm_microkernel(kc: usize, ap: &[Self], bp: &[Self], acc: &mut [Self]) {
                $kern(kc, ap, bp, acc)
            }
            #[inline]
            fn sqrt(self) -> Self {
                self.sqrt()
            }
            #[inline]
            fn abs(self) -> Self {
                self.abs()
            }
            #[inline]
            fn ln(self) -> Self {
                self.ln()
            }
            #[inline]
            fn exp(self) -> Self {
                self.exp()
            }
            #[inline]
            fn from_f64(x: f64) -> Self {
                x as $t
            }
            #[inline]
            fn to_f64(self) -> f64 {
                self as f64
            }
        }
    };
}

impl_scalar!(f32, 8, crate::linalg::gemm::microkernel_f32);
impl_scalar!(f64, 4, crate::linalg::gemm::microkernel_f64);

/// Dense row-major matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix<T: Scalar = f64> {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major storage, `data[i * cols + j]`.
    pub data: Vec<T>,
}

impl<T: Scalar> Matrix<T> {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![T::ZERO; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = T::ONE;
        }
        m
    }

    /// Wrap a row-major buffer (asserts the length).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Matrix { rows, cols, data }
    }

    /// Fill from `f(i, j)` in row-major order.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> T) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[T] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [T] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column `j`, copied out.
    pub fn col(&self, j: usize) -> Vec<T> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix<T> {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Matrix product via the blocked GEMM (see gemm.rs).
    pub fn matmul(&self, other: &Matrix<T>) -> Matrix<T> {
        super::gemm::matmul(self, other)
    }

    /// self @ v for a vector v.
    pub fn matvec(&self, v: &[T]) -> Vec<T> {
        assert_eq!(v.len(), self.cols);
        let mut out = vec![T::ZERO; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = T::ZERO;
            for (a, b) in row.iter().zip(v) {
                acc += *a * *b;
            }
            out[i] = acc;
        }
        out
    }

    /// self^T @ v.
    pub fn matvec_t(&self, v: &[T]) -> Vec<T> {
        assert_eq!(v.len(), self.rows);
        let mut out = vec![T::ZERO; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            let vi = v[i];
            for (o, a) in out.iter_mut().zip(row) {
                *o += *a * vi;
            }
        }
        out
    }

    /// Multiply every element by `s` in place.
    pub fn scale(&mut self, s: T) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// Elementwise `self += other` (asserts matching shapes).
    pub fn add_assign(&mut self, other: &Matrix<T>) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += *b;
        }
    }

    /// Add s to the diagonal (jitter / noise).
    pub fn add_diag(&mut self, s: T) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += s;
        }
    }

    /// Main diagonal, copied out.
    pub fn diag(&self) -> Vec<T> {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).collect()
    }

    /// Sum of the main diagonal.
    pub fn trace(&self) -> T {
        let mut t = T::ZERO;
        for i in 0..self.rows.min(self.cols) {
            t += self[(i, i)];
        }
        t
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> T {
        let mut s = T::ZERO;
        for x in &self.data {
            s += *x * *x;
        }
        s.sqrt()
    }

    /// Convert precision (f64 <-> f32 boundaries).
    pub fn cast<U: Scalar>(&self) -> Matrix<U> {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| U::from_f64(x.to_f64())).collect(),
        }
    }

    /// Extract the submatrix with the given row/col indices.
    pub fn submatrix(&self, rows: &[usize], cols: &[usize]) -> Matrix<T> {
        Matrix::from_fn(rows.len(), cols.len(), |i, j| self[(rows[i], cols[j])])
    }

    /// Largest absolute element, widened to f64.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().map(|x| x.abs().to_f64()).fold(0.0, f64::max)
    }
}

impl<T: Scalar> Index<(usize, usize)> for Matrix<T> {
    type Output = T;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &T {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl<T: Scalar> IndexMut<(usize, usize)> for Matrix<T> {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut T {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl<T: Scalar> fmt::Debug for Matrix<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>10.4} ", self[(i, j)].to_f64())?;
            }
            writeln!(f, "{}", if self.cols > 8 { "..." } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

// ---- vector helpers used across the crate ----

/// Dot product of two equal-length slices (fixed ascending order).
pub fn dot<T: Scalar>(a: &[T], b: &[T]) -> T {
    debug_assert_eq!(a.len(), b.len());
    let mut s = T::ZERO;
    for (x, y) in a.iter().zip(b) {
        s += *x * *y;
    }
    s
}

/// `y += alpha * x` elementwise.
pub fn axpy<T: Scalar>(alpha: T, x: &[T], y: &mut [T]) {
    debug_assert_eq!(x.len(), y.len());
    for (xi, yi) in x.iter().zip(y.iter_mut()) {
        *yi += alpha * *xi;
    }
}

/// Euclidean norm.
pub fn norm2<T: Scalar>(x: &[T]) -> T {
    dot(x, x).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_transpose() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m[(1, 2)], 6.0);
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t[(2, 1)], 6.0);
    }

    #[test]
    fn matvec_matches_matmul() {
        let m = Matrix::from_fn(4, 3, |i, j| (i * 3 + j) as f64);
        let v = vec![1.0, -1.0, 2.0];
        let got = m.matvec(&v);
        let vm = Matrix::from_vec(3, 1, v.clone());
        let want = m.matmul(&vm);
        for i in 0..4 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_t_is_transpose_matvec() {
        let m = Matrix::from_fn(4, 3, |i, j| ((i + 1) * (j + 2)) as f64);
        let v = vec![1.0, 0.5, -2.0, 3.0];
        let got = m.matvec_t(&v);
        let want = m.transpose().matvec(&v);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn eye_is_identity_under_matmul() {
        let m = Matrix::from_fn(5, 5, |i, j| (i as f64 - j as f64) * 0.3);
        let prod = m.matmul(&Matrix::eye(5));
        assert!((&prod.data[..])
            .iter()
            .zip(&m.data)
            .all(|(a, b)| (a - b).abs() < 1e-12));
    }

    #[test]
    fn cast_roundtrip() {
        let m = Matrix::<f64>::from_fn(3, 3, |i, j| (i + j) as f64 * 0.5);
        let m32: Matrix<f32> = m.cast();
        let back: Matrix<f64> = m32.cast();
        assert!(m.data.iter().zip(&back.data).all(|(a, b)| (a - b).abs() < 1e-6));
    }

    #[test]
    fn submatrix_picks_entries() {
        let m = Matrix::from_fn(4, 4, |i, j| (i * 10 + j) as f64);
        let s = m.submatrix(&[1, 3], &[0, 2]);
        assert_eq!(s.data, vec![10.0, 12.0, 30.0, 32.0]);
    }

    #[test]
    fn trace_and_diag() {
        let m = Matrix::from_fn(3, 3, |i, j| if i == j { (i + 1) as f64 } else { 9.0 });
        assert_eq!(m.trace(), 6.0);
        assert_eq!(m.diag(), vec![1.0, 2.0, 3.0]);
    }
}
