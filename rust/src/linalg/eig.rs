//! Symmetric eigendecomposition: Householder tridiagonalization plus
//! implicit-shift QL iteration (the classic EISPACK `tred2`/`tql2`
//! pair), all in f64.
//!
//! This is the spectral substrate behind the exact Kronecker solver
//! (`solvers::eig`) and the latent-grid preconditioner
//! (`Preconditioner::KronEig`): per-factor decompositions of `K_SS` and
//! `K_TT` diagonalize the full `K_SS (x) K_TT + sigma2 I` system at
//! `O(p^3 + q^3)` cost instead of `O((pq)^3)`.
//!
//! Determinism: the factorization is a fixed, sequential sweep — no
//! parallel regions, no pivot choices that depend on thread count — so
//! every consumer inherits the crate-wide `LKGP_THREADS` bit-invariance
//! contract for free (see rust/tests/par_invariance.rs).

use crate::linalg::Matrix;

/// Typed failure of [`sym_eig`].
#[derive(Clone, Debug, PartialEq)]
pub enum EigError {
    /// The input matrix holds a NaN/Inf entry (nothing to decompose).
    NonFiniteEntry {
        /// Row of the offending entry.
        row: usize,
        /// Column of the offending entry.
        col: usize,
        /// The non-finite value found there.
        value: f64,
    },
    /// The QL iteration failed to isolate an eigenvalue within the
    /// sweep budget (50 implicit-shift iterations per eigenvalue).
    NoConvergence {
        /// Index of the eigenvalue that did not converge.
        index: usize,
    },
}

impl std::fmt::Display for EigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EigError::NonFiniteEntry { row, col, value } => {
                write!(f, "non-finite matrix entry ({row}, {col}) = {value}")
            }
            EigError::NoConvergence { index } => {
                write!(f, "QL iteration did not converge for eigenvalue {index}")
            }
        }
    }
}

impl std::error::Error for EigError {}

/// Eigendecomposition `A = Q diag(values) Q^T` of a symmetric matrix.
#[derive(Clone, Debug)]
pub struct SymEig {
    /// Eigenvalues in ascending order.
    pub values: Vec<f64>,
    /// Orthonormal eigenvectors; column `j` pairs with `values[j]`.
    pub vectors: Matrix<f64>,
}

/// Full eigendecomposition of a symmetric matrix (the strictly lower
/// triangle is read as the mirror of the upper one).
///
/// Returns eigenvalues in ascending order with matching eigenvector
/// columns. Fails typed on non-finite input or (pathologically) on a
/// QL sweep that exceeds its iteration budget.
pub fn sym_eig(a: &Matrix<f64>) -> Result<SymEig, EigError> {
    assert_eq!(a.rows, a.cols, "sym_eig needs a square matrix");
    let n = a.rows;
    for i in 0..n {
        for j in 0..n {
            let v = a[(i, j)];
            if !v.is_finite() {
                return Err(EigError::NonFiniteEntry { row: i, col: j, value: v });
            }
        }
    }
    if n == 0 {
        return Ok(SymEig { values: Vec::new(), vectors: Matrix::zeros(0, 0) });
    }
    let mut v = a.data.clone();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];
    tred2(n, &mut v, &mut d, &mut e);
    if let Err(index) = tql2(n, &mut d, &mut e, &mut v) {
        return Err(EigError::NoConvergence { index });
    }
    sort_ascending(n, &mut d, &mut v);
    Ok(SymEig { values: d, vectors: Matrix { rows: n, cols: n, data: v } })
}

/// Householder reduction of a symmetric matrix to tridiagonal form
/// (EISPACK `tred2`). On exit `d` holds the diagonal, `e[1..]` the
/// subdiagonal, and `v` the accumulated orthogonal transformation.
#[allow(clippy::needless_range_loop)]
fn tred2(n: usize, v: &mut [f64], d: &mut [f64], e: &mut [f64]) {
    for j in 0..n {
        d[j] = v[(n - 1) * n + j];
    }
    for i in (1..n).rev() {
        // scale to avoid under/overflow in the reflector norm
        let mut scale = 0.0;
        let mut h = 0.0;
        for k in 0..i {
            scale += d[k].abs();
        }
        if scale == 0.0 {
            e[i] = d[i - 1];
            for j in 0..i {
                d[j] = v[(i - 1) * n + j];
                v[i * n + j] = 0.0;
                v[j * n + i] = 0.0;
            }
        } else {
            // generate the Householder vector
            for k in 0..i {
                d[k] /= scale;
                h += d[k] * d[k];
            }
            let f = d[i - 1];
            let mut g = h.sqrt();
            if f > 0.0 {
                g = -g;
            }
            e[i] = scale * g;
            h -= f * g;
            d[i - 1] = f - g;
            for j in 0..i {
                e[j] = 0.0;
            }
            // apply the similarity transformation to remaining columns
            for j in 0..i {
                let f = d[j];
                v[j * n + i] = f;
                let mut g = e[j] + v[j * n + j] * f;
                for k in j + 1..i {
                    g += v[k * n + j] * d[k];
                    e[k] += v[k * n + j] * f;
                }
                e[j] = g;
            }
            let mut f = 0.0;
            for j in 0..i {
                e[j] /= h;
                f += e[j] * d[j];
            }
            let hh = f / (h + h);
            for j in 0..i {
                e[j] -= hh * d[j];
            }
            for j in 0..i {
                let f = d[j];
                let g = e[j];
                for k in j..i {
                    v[k * n + j] -= f * e[k] + g * d[k];
                }
                d[j] = v[(i - 1) * n + j];
                v[i * n + j] = 0.0;
            }
        }
        d[i] = h;
    }
    // accumulate the transformations
    for i in 0..n - 1 {
        v[(n - 1) * n + i] = v[i * n + i];
        v[i * n + i] = 1.0;
        let h = d[i + 1];
        if h != 0.0 {
            for k in 0..=i {
                d[k] = v[k * n + i + 1] / h;
            }
            for j in 0..=i {
                let mut g = 0.0;
                for k in 0..=i {
                    g += v[k * n + i + 1] * v[k * n + j];
                }
                for k in 0..=i {
                    v[k * n + j] -= g * d[k];
                }
            }
        }
        for k in 0..=i {
            v[k * n + i + 1] = 0.0;
        }
    }
    for j in 0..n {
        d[j] = v[(n - 1) * n + j];
        v[(n - 1) * n + j] = 0.0;
    }
    v[(n - 1) * n + n - 1] = 1.0;
    e[0] = 0.0;
}

/// Implicit-shift QL iteration on a symmetric tridiagonal matrix
/// (EISPACK `tql2`), accumulating eigenvectors into `v`. `Err(l)`
/// reports the eigenvalue index whose sweep exceeded 50 iterations.
#[allow(clippy::needless_range_loop)]
fn tql2(n: usize, d: &mut [f64], e: &mut [f64], v: &mut [f64]) -> Result<(), usize> {
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;

    let mut f = 0.0;
    let mut tst1 = 0.0f64;
    let eps = 2.0f64.powi(-52);
    for l in 0..n {
        // find a negligible subdiagonal element
        tst1 = tst1.max(d[l].abs() + e[l].abs());
        let mut m = l;
        while m < n {
            if e[m].abs() <= eps * tst1 {
                break;
            }
            m += 1;
        }
        // if m == l, d[l] is already an eigenvalue; otherwise iterate
        if m > l && m < n {
            let mut iter = 0usize;
            loop {
                iter += 1;
                if iter > 50 {
                    return Err(l);
                }
                // implicit shift from the leading 2x2
                let g = d[l];
                let mut p = (d[l + 1] - g) / (2.0 * e[l]);
                let mut r = p.hypot(1.0);
                if p < 0.0 {
                    r = -r;
                }
                d[l] = e[l] / (p + r);
                d[l + 1] = e[l] * (p + r);
                let dl1 = d[l + 1];
                let mut h = g - d[l];
                for i in l + 2..n {
                    d[i] -= h;
                }
                f += h;

                // implicit QL sweep from m down to l
                p = d[m];
                let mut c = 1.0;
                let mut c2 = c;
                let mut c3 = c;
                let el1 = e[l + 1];
                let mut s = 0.0;
                let mut s2 = 0.0;
                for i in (l..m).rev() {
                    c3 = c2;
                    c2 = c;
                    s2 = s;
                    let g = c * e[i];
                    h = c * p;
                    r = p.hypot(e[i]);
                    e[i + 1] = s * r;
                    s = e[i] / r;
                    c = p / r;
                    p = c * d[i] - s * g;
                    d[i + 1] = h + s * (c * g + s * d[i]);
                    // accumulate the rotation into the eigenvectors
                    for k in 0..n {
                        let h = v[k * n + i + 1];
                        v[k * n + i + 1] = s * v[k * n + i] + c * h;
                        v[k * n + i] = c * v[k * n + i] - s * h;
                    }
                }
                p = -s * s2 * c3 * el1 * e[l] / dl1;
                e[l] = s * p;
                d[l] = c * p;
                if e[l].abs() <= eps * tst1 {
                    break;
                }
            }
        }
        d[l] += f;
        e[l] = 0.0;
    }
    Ok(())
}

/// Deterministic ascending selection sort of eigenpairs (stable with
/// respect to ties, independent of any thread count).
fn sort_ascending(n: usize, d: &mut [f64], v: &mut [f64]) {
    for i in 0..n.saturating_sub(1) {
        let mut k = i;
        let mut p = d[i];
        for j in i + 1..n {
            if d[j] < p {
                k = j;
                p = d[j];
            }
        }
        if k != i {
            d[k] = d[i];
            d[i] = p;
            for j in 0..n {
                v.swap(j * n + i, j * n + k);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{assert_close, prop_check};

    #[test]
    fn prop_reconstructs_spd_matrices() {
        prop_check("eig-reconstruction", 811, 20, |g| {
            let n = g.size(1, 12);
            let a = Matrix::from_vec(n, n, g.spd(n));
            let eig = sym_eig(&a).map_err(|e| e.to_string())?;
            // Q Lambda Q^T == A
            let mut recon = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0;
                    for k in 0..n {
                        acc += eig.vectors[(i, k)] * eig.values[k] * eig.vectors[(j, k)];
                    }
                    recon[(i, j)] = acc;
                }
            }
            assert_close(&recon.data, &a.data, 1e-8)?;
            // Q^T Q == I
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0;
                    for k in 0..n {
                        acc += eig.vectors[(k, i)] * eig.vectors[(k, j)];
                    }
                    let want = if i == j { 1.0 } else { 0.0 };
                    if (acc - want).abs() > 1e-10 {
                        return Err(format!("Q^T Q at ({i},{j}) = {acc}"));
                    }
                }
            }
            // ascending, and positive for SPD input
            for k in 0..n {
                if k + 1 < n && eig.values[k] > eig.values[k + 1] {
                    return Err(format!("eigenvalues not ascending at {k}"));
                }
                if eig.values[k] <= 0.0 {
                    return Err(format!("SPD eigenvalue {k} = {}", eig.values[k]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn diagonal_matrix_has_its_diagonal_as_spectrum() {
        let a = Matrix::from_fn(3, 3, |i, j| if i == j { [3.0, 1.0, 2.0][i] } else { 0.0 });
        let eig = sym_eig(&a).expect("eig");
        assert_close(&eig.values, &[1.0, 2.0, 3.0], 1e-12).expect("values");
    }

    #[test]
    fn non_finite_input_is_a_typed_error() {
        let mut a = Matrix::zeros(2, 2);
        a[(0, 1)] = f64::NAN;
        match sym_eig(&a) {
            Err(EigError::NonFiniteEntry { row: 0, col: 1, .. }) => {}
            other => panic!("expected NonFiniteEntry, got {other:?}"),
        }
    }

    #[test]
    fn empty_and_single_entry_matrices() {
        let e0 = sym_eig(&Matrix::zeros(0, 0)).expect("0x0");
        assert!(e0.values.is_empty());
        let a = Matrix::from_vec(1, 1, vec![4.5]);
        let e1 = sym_eig(&a).expect("1x1");
        assert_eq!(e1.values, vec![4.5]);
        assert_eq!(e1.vectors[(0, 0)].abs(), 1.0);
    }
}
