//! Dense linear algebra substrate (no BLAS/LAPACK in the offline set).
//!
//! Everything the GP stack needs: a generic row-major matrix over
//! f32/f64, a blocked GEMM, Cholesky factorization + triangular solves,
//! and the rank-revealing pivoted Cholesky used both by the CG
//! preconditioner (paper Appendix C: "pivoted Cholesky preconditioner of
//! rank 100") and by CaGP's low-rank actions.

pub mod chol;
pub mod gemm;
pub mod matrix;

pub use chol::{cholesky, pivoted_cholesky, solve_lower, solve_lower_t, Cholesky};
pub use matrix::{Matrix, Scalar};
