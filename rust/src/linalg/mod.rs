//! Dense linear algebra substrate (no BLAS/LAPACK in the offline set).
//!
//! Everything the GP stack needs: a generic row-major matrix over
//! f32/f64, a blocked GEMM, Cholesky factorization + triangular solves,
//! the rank-revealing pivoted Cholesky used both by the CG
//! preconditioner (paper Appendix C: "pivoted Cholesky preconditioner of
//! rank 100") and by CaGP's low-rank actions, and a symmetric
//! eigensolver (`eig`) backing the exact per-factor Kronecker solver.

pub mod chol;
pub mod eig;
pub mod gemm;
pub mod matrix;

pub use chol::{cholesky, pivoted_cholesky, solve_lower, solve_lower_t, Cholesky};
pub use eig::{sym_eig, EigError, SymEig};
pub use matrix::{Matrix, Scalar};
