//! Cholesky factorization, triangular solves, and pivoted Cholesky.
//!
//! The pivoted (rank-revealing, greedily truncated) Cholesky implements
//! the paper's CG preconditioner (Appendix C: "pivoted Cholesky
//! preconditioner of rank 100") and also backs CaGP's low-rank actions.

use super::matrix::{Matrix, Scalar};

/// Lower-triangular Cholesky factor of an SPD matrix.
pub struct Cholesky<T: Scalar> {
    /// The lower-triangular factor L with A = L L^T.
    pub l: Matrix<T>,
}

/// Factor A = L L^T. Returns None if A is not positive definite
/// (after exhausting a small relative jitter escalation).
pub fn cholesky<T: Scalar>(a: &Matrix<T>) -> Option<Cholesky<T>> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mean_diag = a.trace().to_f64() / n.max(1) as f64;
    let mut jitter = 0.0f64;
    'attempt: for attempt in 0..6 {
        if attempt > 0 {
            jitter = if jitter == 0.0 { 1e-10 * mean_diag.max(1e-30) } else { jitter * 100.0 };
        }
        let mut l = a.clone();
        for i in 0..n {
            l[(i, i)] += T::from_f64(jitter);
        }
        for j in 0..n {
            // update column j using columns < j
            for k in 0..j {
                let ljk = l[(j, k)];
                if ljk == T::ZERO {
                    continue;
                }
                for i in j..n {
                    let v = l[(i, k)];
                    l[(i, j)] -= v * ljk;
                }
            }
            let d = l[(j, j)];
            if d.to_f64() <= 0.0 || !d.to_f64().is_finite() {
                continue 'attempt;
            }
            let inv = T::ONE / d.sqrt();
            for i in j..n {
                l[(i, j)] *= inv;
            }
        }
        // zero the strict upper triangle
        for i in 0..n {
            for j in i + 1..n {
                l[(i, j)] = T::ZERO;
            }
        }
        return Some(Cholesky { l });
    }
    None
}

impl<T: Scalar> Cholesky<T> {
    /// Solve A x = b via forward + backward substitution.
    pub fn solve(&self, b: &[T]) -> Vec<T> {
        let mut y = solve_lower(&self.l, b);
        solve_lower_t_inplace(&self.l, &mut y);
        y
    }

    /// Solve A X = B for matrix RHS.
    pub fn solve_mat(&self, b: &Matrix<T>) -> Matrix<T> {
        let mut out = Matrix::zeros(b.rows, b.cols);
        for j in 0..b.cols {
            let col: Vec<T> = (0..b.rows).map(|i| b[(i, j)]).collect();
            let x = self.solve(&col);
            for i in 0..b.rows {
                out[(i, j)] = x[i];
            }
        }
        out
    }

    /// log |A| = 2 sum log diag(L).
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows).map(|i| 2.0 * self.l[(i, i)].to_f64().ln()).sum()
    }

    /// L @ v (e.g. correlated sampling).
    pub fn l_apply(&self, v: &[T]) -> Vec<T> {
        let n = self.l.rows;
        let mut out = vec![T::ZERO; n];
        for i in 0..n {
            let row = &self.l.data[i * n..i * n + i + 1];
            let mut acc = T::ZERO;
            for (a, b) in row.iter().zip(v) {
                acc += *a * *b;
            }
            out[i] = acc;
        }
        out
    }
}

/// Solve L y = b (L lower-triangular).
pub fn solve_lower<T: Scalar>(l: &Matrix<T>, b: &[T]) -> Vec<T> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = b.to_vec();
    for i in 0..n {
        let mut acc = y[i];
        let row = &l.data[i * n..i * n + i];
        for (a, yj) in row.iter().zip(&y[..i]) {
            acc -= *a * *yj;
        }
        y[i] = acc / l[(i, i)];
    }
    y
}

/// Solve L^T x = b (L lower-triangular).
pub fn solve_lower_t<T: Scalar>(l: &Matrix<T>, b: &[T]) -> Vec<T> {
    let mut x = b.to_vec();
    solve_lower_t_inplace(l, &mut x);
    x
}

fn solve_lower_t_inplace<T: Scalar>(l: &Matrix<T>, x: &mut [T]) {
    let n = l.rows;
    assert_eq!(x.len(), n);
    for i in (0..n).rev() {
        let xi = x[i] / l[(i, i)];
        x[i] = xi;
        // subtract xi * L[i, :i] from x[:i]  (column i of L^T)
        for j in 0..i {
            x[j] -= l[(i, j)] * xi;
        }
    }
}

/// Greedy pivoted Cholesky: returns (L, pivots) with L of shape n x rank
/// such that P A P^T ~= L L^T (in original index order: A ~= L L^T after
/// row permutation is *already applied*, i.e. rows of L correspond to
/// original indices). Stops at `rank` columns or when the largest
/// remaining diagonal falls below `tol * max_diag`.
pub fn pivoted_cholesky<T: Scalar>(a: &Matrix<T>, rank: usize, tol: f64) -> (Matrix<T>, Vec<usize>) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let rank = rank.min(n);
    let mut d: Vec<f64> = (0..n).map(|i| a[(i, i)].to_f64()).collect();
    let max0 = d.iter().cloned().fold(0.0, f64::max).max(1e-300);
    let mut l = Matrix::<T>::zeros(n, rank);
    let mut pivots = Vec::with_capacity(rank);
    let mut used = vec![false; n];
    for k in 0..rank {
        // pick the largest remaining diagonal
        let picked = d
            .iter()
            .enumerate()
            .filter(|(i, _)| !used[*i])
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal));
        // k < rank <= n, and each pass marks exactly one index used, so
        // at least one unused diagonal always remains
        let Some((piv, &dmax)) = picked else { break };
        if dmax < tol * max0 || dmax <= 0.0 {
            let mut ltrim = Matrix::zeros(n, k);
            for i in 0..n {
                for j in 0..k {
                    ltrim[(i, j)] = l[(i, j)];
                }
            }
            return (ltrim, pivots);
        }
        used[piv] = true;
        pivots.push(piv);
        let s = dmax.sqrt();
        l[(piv, k)] = T::from_f64(s);
        for i in 0..n {
            if used[i] && i != piv {
                continue;
            }
            if i == piv {
                continue;
            }
            // L[i,k] = (A[i,piv] - sum_j L[i,j] L[piv,j]) / s
            let mut acc = a[(i, piv)].to_f64();
            for j in 0..k {
                acc -= l[(i, j)].to_f64() * l[(piv, j)].to_f64();
            }
            let v = acc / s;
            l[(i, k)] = T::from_f64(v);
            d[i] -= v * v;
        }
        d[piv] = 0.0;
    }
    (l, pivots)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{assert_close, prop_check};

    #[test]
    fn prop_cholesky_recomposes() {
        prop_check("chol-recompose", 23, 20, |g| {
            let n = g.size(1, 25);
            let a = Matrix::from_vec(n, n, g.spd(n));
            let ch = cholesky(&a).ok_or("not spd")?;
            let back = ch.l.matmul(&ch.l.transpose());
            assert_close(&back.data, &a.data, 1e-8)
        });
    }

    #[test]
    fn prop_solve_inverts() {
        prop_check("chol-solve", 29, 20, |g| {
            let n = g.size(1, 25);
            let a = Matrix::from_vec(n, n, g.spd(n));
            let b = g.vec_normal(n);
            let ch = cholesky(&a).ok_or("not spd")?;
            let x = ch.solve(&b);
            let back = a.matvec(&x);
            assert_close(&back, &b, 1e-7)
        });
    }

    #[test]
    fn logdet_matches_2x2() {
        let a = Matrix::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]);
        let ch = cholesky(&a).unwrap();
        let det: f64 = 4.0 * 3.0 - 1.0;
        assert!((ch.logdet() - det.ln()).abs() < 1e-10);
    }

    #[test]
    fn triangular_solves_match() {
        prop_check("tri-solves", 31, 15, |g| {
            let n = g.size(1, 20);
            let a = Matrix::from_vec(n, n, g.spd(n));
            let ch = cholesky(&a).ok_or("not spd")?;
            let b = g.vec_normal(n);
            let y = solve_lower(&ch.l, &b);
            assert_close(&ch.l.matvec(&y), &b, 1e-8)?;
            let x = solve_lower_t(&ch.l, &b);
            assert_close(&ch.l.transpose().matvec(&x), &b, 1e-8)
        });
    }

    #[test]
    fn pivoted_full_rank_recovers_matrix() {
        prop_check("piv-chol-full", 37, 15, |g| {
            let n = g.size(1, 15);
            let a = Matrix::from_vec(n, n, g.spd(n));
            let (l, piv) = pivoted_cholesky(&a, n, 1e-12);
            if piv.len() != n {
                return Err(format!("rank {} < {}", piv.len(), n));
            }
            let back = l.matmul(&l.transpose());
            assert_close(&back.data, &a.data, 1e-6)
        });
    }

    #[test]
    fn pivoted_low_rank_error_decays() {
        // A smooth RBF-like Gram matrix has fast-decaying spectrum: the
        // rank-k pivoted Cholesky error must decrease with k.
        let n = 40;
        let a = Matrix::from_fn(n, n, |i, j| {
            let d = (i as f64 - j as f64) / 5.0;
            (-0.5 * d * d).exp()
        });
        let mut prev = f64::INFINITY;
        for rank in [2, 5, 10, 20] {
            let (l, _) = pivoted_cholesky(&a, rank, 0.0);
            let mut diff = a.clone();
            let ll = l.matmul(&l.transpose());
            for (d, v) in diff.data.iter_mut().zip(&ll.data) {
                *d -= *v;
            }
            let err = diff.frob_norm();
            assert!(err < prev + 1e-9, "rank {rank}: {err} !< {prev}");
            prev = err;
        }
        assert!(prev < 1e-3, "rank-20 error {prev}");
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig -1, 3
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn f32_cholesky_works() {
        let a64 = Matrix::<f64>::from_fn(10, 10, |i, j| {
            let d = (i as f64 - j as f64) / 3.0;
            (-0.5 * d * d).exp() + if i == j { 0.1 } else { 0.0 }
        });
        let a: Matrix<f32> = a64.cast();
        let ch = cholesky(&a).unwrap();
        let back = ch.l.matmul(&ch.l.transpose());
        for (g, w) in back.data.iter().zip(&a.data) {
            assert!((g - w).abs() < 1e-4);
        }
    }
}
