//! Datasets: the partial-grid regression problems of the paper.
//!
//! Every experiment starts from a *fully gridded* ground truth plus a
//! missing mask; missing cells are withheld from training and used as
//! test targets (exactly the paper's protocol, Sec. 4). The real
//! datasets (SARCOS, LCBench, Nordic climate) are unavailable offline,
//! so faithful simulators generate workloads with the same structure
//! (see DESIGN.md §Substitutions).

pub mod climate;
pub mod grid;
pub mod lcbench;
pub mod offgrid;
pub mod sarcos;
pub mod synthetic;

pub use grid::GridDataset;
pub use offgrid::OffGridDataset;
