//! Simulated Nordic climate data: spatiotemporal temperature and
//! precipitation fields on a (lat, lon) x days grid.
//!
//! The Nordic Gridded Climate Dataset is unavailable offline; this
//! simulator reproduces the structure Fig. 5 exhibits (DESIGN.md
//! §Substitutions): every location carries a seasonal periodic trend,
//! fields are spatially locally correlated, temperature is smooth while
//! precipitation is noisy/intermittent (log-normal-like transform).
//! Smooth GP-like fields are drawn with random Fourier features in
//! O(p q M) — no large Cholesky needed at generation time.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

use super::grid::GridDataset;

/// Which Table-2 variant to generate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ClimateVariant {
    /// Smooth seasonal temperature fields.
    Temperature,
    /// Noisy, intermittent precipitation fields.
    Precipitation,
}

/// Simulator configuration for the climate workloads.
pub struct ClimateSim {
    /// number of spatial stations
    pub p: usize,
    /// number of days
    pub q: usize,
    /// Which field to generate.
    pub variant: ClimateVariant,
    /// Fraction of grid cells withheld as test targets.
    pub missing_ratio: f64,
    /// Generation seed.
    pub seed: u64,
    /// random Fourier features for the latent field
    pub n_features: usize,
}

impl ClimateSim {
    /// Simulator with the default feature count.
    pub fn new(
        p: usize,
        q: usize,
        variant: ClimateVariant,
        missing_ratio: f64,
        seed: u64,
    ) -> Self {
        ClimateSim { p, q, variant, missing_ratio, seed, n_features: 96 }
    }

    /// Generate the temperature variant in one call.
    pub fn default_temperature(p: usize, q: usize, missing_ratio: f64, seed: u64) -> GridDataset {
        Self::new(p, q, ClimateVariant::Temperature, missing_ratio, seed).generate()
    }

    /// Generate the precipitation variant in one call.
    pub fn default_precipitation(p: usize, q: usize, missing_ratio: f64, seed: u64) -> GridDataset {
        Self::new(p, q, ClimateVariant::Precipitation, missing_ratio, seed).generate()
    }

    /// Generate the dataset (deterministic per configuration).
    pub fn generate(&self) -> GridDataset {
        let mut rng = Rng::new(self.seed ^ 0xC11A7E);
        // station locations in a Nordic-like box (lat 55..71, lon 4..31),
        // standardized for the kernel
        let mut s_raw = Matrix::zeros(self.p, 2);
        for i in 0..self.p {
            s_raw[(i, 0)] = rng.uniform_in(55.0, 71.0);
            s_raw[(i, 1)] = rng.uniform_in(4.0, 31.0);
        }
        // latent smooth spatial fields via random Fourier features:
        // phi_m(s) = cos(w_m . s + b_m), field(s) = sum_m a_m phi_m(s)
        let m = self.n_features;
        let ls_space = 3.0; // degrees
        let mut w = vec![0.0; 2 * m];
        let mut b = vec![0.0; m];
        for v in w.iter_mut() {
            *v = rng.normal() / ls_space;
        }
        for v in b.iter_mut() {
            *v = rng.uniform_in(0.0, std::f64::consts::TAU);
        }
        let feats = |i: usize, w: &[f64], b: &[f64]| -> Vec<f64> {
            (0..m)
                .map(|mm| {
                    (w[2 * mm] * s_raw[(i, 0)] + w[2 * mm + 1] * s_raw[(i, 1)] + b[mm]).cos()
                        * (2.0 / m as f64).sqrt()
                })
                .collect()
        };
        // temporal basis: seasonal harmonics + slow trend + AR-ish wiggle
        let year = 365.25;
        let n_temporal = 6;
        // per feature: random temporal mixture
        let mut t_coef = vec![0.0; m * n_temporal];
        for v in t_coef.iter_mut() {
            *v = rng.normal();
        }
        let temporal_basis = |day: f64| -> [f64; 6] {
            let ph = std::f64::consts::TAU * day / year;
            [
                1.0,
                ph.sin(),
                ph.cos(),
                (2.0 * ph).sin(),
                (day / self.q as f64) * 2.0 - 1.0,
                (std::f64::consts::TAU * day / 7.3).sin(), // synoptic-scale wiggle
            ]
        };

        // station-level static offsets (altitude/coastal effects)
        let offset_coef: Vec<f64> = (0..m).map(|_| rng.normal() * 2.0).collect();

        let mut y = vec![0.0; self.p * self.q];
        let (amp_seasonal, base, noise) = match self.variant {
            ClimateVariant::Temperature => (10.0, 4.0, 0.8),
            ClimateVariant::Precipitation => (0.8, 0.2, 0.45),
        };
        for i in 0..self.p {
            let phi = feats(i, &w, &b);
            let lat_effect = -0.6 * (s_raw[(i, 0)] - 63.0); // colder north
            let static_off: f64 =
                phi.iter().zip(&offset_coef).map(|(a, c)| a * c).sum::<f64>() + lat_effect;
            for k in 0..self.q {
                let day = k as f64;
                let tb = temporal_basis(day);
                // spatiotemporal interaction field
                let mut field = 0.0;
                for mm in 0..m {
                    let mut g = 0.0;
                    for (bi, tv) in tb.iter().enumerate() {
                        g += t_coef[mm * n_temporal + bi] * tv;
                    }
                    field += phi[mm] * g;
                }
                let seasonal = amp_seasonal * (std::f64::consts::TAU * (day - 15.0) / year).cos();
                let v = match self.variant {
                    ClimateVariant::Temperature => {
                        base - seasonal + static_off + 1.5 * field + noise * rng.normal()
                    }
                    ClimateVariant::Precipitation => {
                        // log-normal-ish: intermittent, non-negative, noisy
                        let latent =
                            base + 0.3 * seasonal + 0.25 * static_off + 0.8 * field;
                        let wet = latent + noise * rng.normal();
                        (wet.exp() - 1.0).max(0.0)
                    }
                };
                y[i * self.q + k] = v;
            }
        }
        let mut s = s_raw;
        super::sarcos::standardize_columns(&mut s);
        let mut ds = GridDataset {
            s,
            t: (0..self.q).map(|k| k as f64).collect(),
            y_grid: y,
            mask: vec![true; self.p * self.q],
            time_family: "rbf_periodic".into(),
            name: format!(
                "climate-sim-{:?}(p={},q={},miss={})",
                self.variant, self.p, self.q, self.missing_ratio
            ),
            };
        ds.mask_uniform(self.missing_ratio, self.seed);
        ds.validate();
        ds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn temperature_has_seasonal_cycle() {
        let ds = ClimateSim::default_temperature(30, 730, 0.0, 0);
        // winter (day ~15) colder than summer (day ~198) on average
        let q = ds.q();
        let avg_day = |day: usize| -> f64 {
            (0..ds.p()).map(|i| ds.y_grid[i * q + day]).sum::<f64>() / ds.p() as f64
        };
        assert!(avg_day(15) < avg_day(198), "no seasonal cycle");
        // second year repeats roughly
        assert!((avg_day(15) - avg_day(380)).abs() < 6.0);
    }

    #[test]
    fn spatial_correlation_decays_with_distance() {
        let ds = ClimateSim::default_temperature(60, 200, 0.0, 1);
        let q = ds.q();
        let series = |i: usize| -> Vec<f64> { (0..q).map(|k| ds.y_grid[i * q + k]).collect() };
        let corr = |a: &[f64], b: &[f64]| -> f64 {
            let n = a.len() as f64;
            let (ma, mb) = (a.iter().sum::<f64>() / n, b.iter().sum::<f64>() / n);
            let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum();
            let va: f64 = a.iter().map(|x| (x - ma) * (x - ma)).sum();
            let vb: f64 = b.iter().map(|y| (y - mb) * (y - mb)).sum();
            cov / (va * vb).sqrt().max(1e-12)
        };
        let dist = |i: usize, j: usize| -> f64 {
            let dx = ds.s[(i, 0)] - ds.s[(j, 0)];
            let dy = ds.s[(i, 1)] - ds.s[(j, 1)];
            (dx * dx + dy * dy).sqrt()
        };
        // average correlation among nearest vs farthest pairs
        let mut near = vec![];
        let mut far = vec![];
        for i in 0..20 {
            for j in (i + 1)..20 {
                let c = corr(&series(i), &series(j));
                if dist(i, j) < 0.5 {
                    near.push(c);
                } else if dist(i, j) > 2.0 {
                    far.push(c);
                }
            }
        }
        if !near.is_empty() && !far.is_empty() {
            let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
            assert!(
                mean(&near) > mean(&far) - 0.05,
                "near {} vs far {}",
                mean(&near),
                mean(&far)
            );
        }
    }

    #[test]
    fn precipitation_nonnegative_and_noisier() {
        let t = ClimateSim::default_temperature(20, 100, 0.0, 2);
        let p = ClimateSim::default_precipitation(20, 100, 0.0, 2);
        assert!(p.y_grid.iter().all(|&v| v >= 0.0));
        // relative variability of precip day-to-day differences is larger
        let rough = |ds: &GridDataset| -> f64 {
            let q = ds.q();
            let mut num = 0.0;
            let mut den = 0.0;
            for i in 0..ds.p() {
                for k in 1..q {
                    let d = ds.y_grid[i * q + k] - ds.y_grid[i * q + k - 1];
                    num += d * d;
                    den += ds.y_grid[i * q + k] * ds.y_grid[i * q + k];
                }
            }
            (num / den.max(1e-12)).sqrt()
        };
        assert!(rough(&p) > rough(&t), "precip not rougher");
    }

    #[test]
    fn missing_ratio_honored() {
        let ds = ClimateSim::default_temperature(40, 50, 0.35, 3);
        assert!((ds.missing_ratio() - 0.35).abs() < 0.01);
        assert_eq!(ds.time_family, "rbf_periodic");
    }
}
