//! Simulated SARCOS: inverse dynamics of a 7-DOF anthropomorphic arm.
//!
//! The real SARCOS dataset is unavailable offline; this module builds the
//! closest synthetic equivalent (DESIGN.md §Substitutions): a recursive
//! Newton–Euler (RNE) inverse-dynamics model of a randomized 7-joint
//! revolute serial chain. Inputs are 21-dimensional (7 positions, 7
//! velocities, 7 accelerations), outputs are the 7 joint torques — the
//! same smooth nonlinear multi-output regression the paper's Fig. 3
//! experiment regresses with k_S = SE(R^21), k_T = full-rank ICM over
//! the 7 torque tasks.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

use super::grid::GridDataset;

const DOF: usize = 7;

type Vec3 = [f64; 3];

fn cross(a: Vec3, b: Vec3) -> Vec3 {
    [
        a[1] * b[2] - a[2] * b[1],
        a[2] * b[0] - a[0] * b[2],
        a[0] * b[1] - a[1] * b[0],
    ]
}

fn add(a: Vec3, b: Vec3) -> Vec3 {
    [a[0] + b[0], a[1] + b[1], a[2] + b[2]]
}

fn scale(a: Vec3, s: f64) -> Vec3 {
    [a[0] * s, a[1] * s, a[2] * s]
}

fn dot3(a: Vec3, b: Vec3) -> f64 {
    a[0] * b[0] + a[1] * b[1] + a[2] * b[2]
}

/// 3x3 rotation applied to a vector (row-major).
fn rot(r: &[f64; 9], v: Vec3) -> Vec3 {
    [
        r[0] * v[0] + r[1] * v[1] + r[2] * v[2],
        r[3] * v[0] + r[4] * v[1] + r[5] * v[2],
        r[6] * v[0] + r[7] * v[1] + r[8] * v[2],
    ]
}

fn rot_t(r: &[f64; 9], v: Vec3) -> Vec3 {
    [
        r[0] * v[0] + r[3] * v[1] + r[6] * v[2],
        r[1] * v[0] + r[4] * v[1] + r[7] * v[2],
        r[2] * v[0] + r[5] * v[1] + r[8] * v[2],
    ]
}

/// Randomized anthropomorphic-scale arm (modified DH convention).
#[derive(Clone, Debug)]
pub struct ArmModel {
    /// link lengths a_i (m)
    pub a: [f64; DOF],
    /// link twists alpha_i (rad)
    pub alpha: [f64; DOF],
    /// link offsets d_i (m)
    pub d: [f64; DOF],
    /// link masses (kg)
    pub mass: [f64; DOF],
    /// center of mass in link frame
    pub com: [Vec3; DOF],
    /// diagonal link inertias (kg m^2)
    pub inertia: [Vec3; DOF],
    /// viscous friction coefficients
    pub friction: [f64; DOF],
}

impl ArmModel {
    /// Randomized but anthropomorphic-scale parameters.
    pub fn random(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x5A2C05);
        let mut a = [0.0; DOF];
        let mut alpha = [0.0; DOF];
        let mut d = [0.0; DOF];
        let mut mass = [0.0; DOF];
        let mut com = [[0.0; 3]; DOF];
        let mut inertia = [[0.0; 3]; DOF];
        let mut friction = [0.0; DOF];
        for i in 0..DOF {
            a[i] = rng.uniform_in(0.05, 0.40);
            alpha[i] = [-std::f64::consts::FRAC_PI_2, 0.0, std::f64::consts::FRAC_PI_2]
                [rng.below(3)];
            d[i] = rng.uniform_in(0.0, 0.25);
            mass[i] = rng.uniform_in(1.0, 8.0) * (1.0 - 0.08 * i as f64);
            com[i] = [
                rng.uniform_in(-0.1, 0.1),
                rng.uniform_in(-0.1, 0.1),
                rng.uniform_in(0.0, 0.2),
            ];
            inertia[i] = [
                rng.uniform_in(0.01, 0.3),
                rng.uniform_in(0.01, 0.3),
                rng.uniform_in(0.01, 0.3),
            ];
            friction[i] = rng.uniform_in(0.05, 0.5);
        }
        ArmModel { a, alpha, d, mass, com, inertia, friction }
    }

    /// Rotation from frame i to frame i-1 for joint angle q_i
    /// (modified DH).
    fn joint_rot(&self, i: usize, q: f64) -> [f64; 9] {
        let (cq, sq) = (q.cos(), q.sin());
        let (ca, sa) = (self.alpha[i].cos(), self.alpha[i].sin());
        // R = Rx(alpha_{i-1}) * Rz(q_i) (modified DH), transposed below
        [
            cq, -sq, 0.0, //
            sq * ca, cq * ca, -sa, //
            sq * sa, cq * sa, ca,
        ]
    }

    /// Recursive Newton–Euler inverse dynamics:
    /// torque = RNE(q, qd, qdd) including gravity and viscous friction.
    pub fn inverse_dynamics(&self, q: &[f64], qd: &[f64], qdd: &[f64]) -> [f64; DOF] {
        assert!(q.len() == DOF && qd.len() == DOF && qdd.len() == DOF);
        let z: Vec3 = [0.0, 0.0, 1.0];
        // forward recursion
        let mut w = [[0.0f64; 3]; DOF]; // angular velocity
        let mut wd = [[0.0f64; 3]; DOF]; // angular acceleration
        let mut vd = [[0.0f64; 3]; DOF]; // linear acceleration of frame origin
        let mut rots = [[0.0f64; 9]; DOF];
        let gravity: Vec3 = [0.0, 0.0, 9.81]; // -g expressed as base accel
        let mut w_prev: Vec3 = [0.0; 3];
        let mut wd_prev: Vec3 = [0.0; 3];
        let mut vd_prev: Vec3 = gravity;
        for i in 0..DOF {
            let r = self.joint_rot(i, q[i]);
            rots[i] = r;
            let p: Vec3 = [self.a[i], -self.d[i] * self.alpha[i].sin(), self.d[i] * self.alpha[i].cos()];
            let w_in = rot_t(&r, w_prev);
            let wi = add(w_in, scale(z, qd[i]));
            let wdi = add(
                add(rot_t(&r, wd_prev), scale(z, qdd[i])),
                cross(w_in, scale(z, qd[i])),
            );
            let vdi = {
                let term = add(rot_t(&r, vd_prev), cross(wd_prev, p).map(|_| 0.0));
                // linear acceleration: R^T (vd_prev + wd_prev x p + w_prev x (w_prev x p))
                let inner = add(
                    vd_prev,
                    add(cross(wd_prev, p), cross(w_prev, cross(w_prev, p))),
                );
                let _ = term;
                rot_t(&r, inner)
            };
            w[i] = wi;
            wd[i] = wdi;
            vd[i] = vdi;
            w_prev = wi;
            wd_prev = wdi;
            vd_prev = vdi;
        }
        // backward recursion
        let mut f_next: Vec3 = [0.0; 3];
        let mut n_next: Vec3 = [0.0; 3];
        let mut torque = [0.0f64; DOF];
        for i in (0..DOF).rev() {
            let c = self.com[i];
            // acceleration of COM
            let vc = add(vd[i], add(cross(wd[i], c), cross(w[i], cross(w[i], c))));
            let ff = scale(vc, self.mass[i]); // F = m a_c
            let iw: Vec3 = [
                self.inertia[i][0] * w[i][0],
                self.inertia[i][1] * w[i][1],
                self.inertia[i][2] * w[i][2],
            ];
            let iwd: Vec3 = [
                self.inertia[i][0] * wd[i][0],
                self.inertia[i][1] * wd[i][1],
                self.inertia[i][2] * wd[i][2],
            ];
            let nn = add(iwd, cross(w[i], iw)); // N = I wd + w x (I w)
            // propagate from link i+1
            let (f_prop, n_prop) = if i + 1 < DOF {
                let r_next = rots[i + 1];
                let p_next: Vec3 = [
                    self.a[i + 1],
                    -self.d[i + 1] * self.alpha[i + 1].sin(),
                    self.d[i + 1] * self.alpha[i + 1].cos(),
                ];
                let fp = rot(&r_next, f_next);
                let np = add(rot(&r_next, n_next), cross(p_next, fp));
                (fp, np)
            } else {
                ([0.0; 3], [0.0; 3])
            };
            let fi = add(ff, f_prop);
            let ni = add(add(nn, n_prop), cross(c, ff));
            torque[i] = ni[2] + self.friction[i] * qd[i] + dot3([0.0, 0.0, 0.0], fi);
            f_next = fi;
            n_next = ni;
        }
        torque
    }
}

/// Simulated-SARCOS generator: p joint states x 7 torque tasks.
pub struct SarcosSim {
    /// Number of joint states (spatial points).
    pub p: usize,
    /// Fraction of torque readings withheld as test targets.
    pub missing_ratio: f64,
    /// Generation seed.
    pub seed: u64,
    /// output observation noise (fraction of per-task std)
    pub noise_frac: f64,
}

impl SarcosSim {
    /// Simulator with the default noise fraction.
    pub fn new(p: usize, missing_ratio: f64, seed: u64) -> Self {
        SarcosSim { p, missing_ratio, seed, noise_frac: 0.05 }
    }

    /// Generate the dataset: inputs are standardized 21-d joint states
    /// sampled along smooth sum-of-sinusoid trajectories (as in real
    /// robot excitation runs), targets are RNE torques per task.
    pub fn generate(&self) -> GridDataset {
        let arm = ArmModel::random(self.seed);
        let mut rng = Rng::new(self.seed ^ 0x54C05);
        // smooth excitation trajectories: q_j(t) = sum_h A_h sin(w_h t + phi_h)
        let nh = 4;
        let mut amp = vec![0.0; DOF * nh];
        let mut freq = vec![0.0; DOF * nh];
        let mut phase = vec![0.0; DOF * nh];
        for v in amp.iter_mut() {
            *v = rng.uniform_in(0.2, 0.8);
        }
        for v in freq.iter_mut() {
            *v = rng.uniform_in(0.3, 2.5);
        }
        for v in phase.iter_mut() {
            *v = rng.uniform_in(0.0, std::f64::consts::TAU);
        }
        let mut s = Matrix::zeros(self.p, 3 * DOF);
        let mut y = vec![0.0; self.p * DOF];
        for i in 0..self.p {
            let t = i as f64 * 0.01 + rng.uniform_in(0.0, 0.005);
            let mut q = [0.0; DOF];
            let mut qd = [0.0; DOF];
            let mut qdd = [0.0; DOF];
            for j in 0..DOF {
                for h in 0..nh {
                    let (a, w0, ph) = (amp[j * nh + h], freq[j * nh + h], phase[j * nh + h]);
                    q[j] += a * (w0 * t + ph).sin();
                    qd[j] += a * w0 * (w0 * t + ph).cos();
                    qdd[j] -= a * w0 * w0 * (w0 * t + ph).sin();
                }
            }
            let row = s.row_mut(i);
            for j in 0..DOF {
                row[j] = q[j];
                row[DOF + j] = qd[j];
                row[2 * DOF + j] = qdd[j];
            }
            let tau = arm.inverse_dynamics(&q, &qd, &qdd);
            for k in 0..DOF {
                y[i * DOF + k] = tau[k];
            }
        }
        // standardize inputs per dimension
        standardize_columns(&mut s);
        // additive noise per task, scaled to task std
        for k in 0..DOF {
            let col: Vec<f64> = (0..self.p).map(|i| y[i * DOF + k]).collect();
            let mean = col.iter().sum::<f64>() / self.p as f64;
            let std = (col.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
                / self.p as f64)
                .sqrt()
                .max(1e-9);
            for i in 0..self.p {
                y[i * DOF + k] += self.noise_frac * std * rng.normal();
            }
        }
        let mut ds = GridDataset {
            s,
            t: (0..DOF).map(|k| k as f64).collect(),
            y_grid: y,
            mask: vec![true; self.p * DOF],
            time_family: "icm".into(),
            name: format!("sarcos-sim(p={},miss={})", self.p, self.missing_ratio),
        };
        ds.mask_uniform(self.missing_ratio, self.seed);
        ds.validate();
        ds
    }
}

/// Standardize matrix columns to zero mean, unit variance.
pub fn standardize_columns(m: &mut Matrix<f64>) {
    for j in 0..m.cols {
        let col = m.col(j);
        let mean = col.iter().sum::<f64>() / m.rows.max(1) as f64;
        let var = col.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / m.rows.max(1) as f64;
        let std = var.sqrt().max(1e-12);
        for i in 0..m.rows {
            m[(i, j)] = (m[(i, j)] - mean) / std;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn torques_deterministic_and_finite() {
        let arm = ArmModel::random(1);
        let q = [0.1, -0.4, 0.2, 0.8, -0.2, 0.3, 0.0];
        let qd = [0.5; DOF];
        let qdd = [0.1; DOF];
        let t1 = arm.inverse_dynamics(&q, &qd, &qdd);
        let t2 = arm.inverse_dynamics(&q, &qd, &qdd);
        assert_eq!(t1, t2);
        assert!(t1.iter().all(|x| x.is_finite()));
        assert!(t1.iter().any(|x| x.abs() > 1e-6), "all-zero torques");
    }

    #[test]
    fn gravity_load_depends_on_configuration() {
        let arm = ArmModel::random(2);
        let zero = [0.0; DOF];
        let t_a = arm.inverse_dynamics(&[0.0; DOF], &zero, &zero);
        let t_b = arm.inverse_dynamics(&[1.0, -0.7, 0.3, 0.9, -1.1, 0.5, 0.2], &zero, &zero);
        let diff: f64 = t_a.iter().zip(&t_b).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-3, "static torques insensitive to pose: {diff}");
    }

    #[test]
    fn friction_is_linear_in_velocity() {
        // tau(qd) - tau(-qd) = 2 * friction * qd at zero accel, same pose,
        // up to velocity-product (Coriolis) terms that are even in qd on
        // the friction axis... verify friction contributes.
        let mut arm = ArmModel::random(3);
        let q = [0.3; DOF];
        let qd = [1.0; DOF];
        let zero = [0.0; DOF];
        let t_f = arm.inverse_dynamics(&q, &qd, &zero);
        arm.friction = [0.0; DOF];
        let t_nf = arm.inverse_dynamics(&q, &qd, &zero);
        for k in 0..DOF {
            assert!((t_f[k] - t_nf[k]).abs() > 1e-6, "joint {k} friction missing");
        }
    }

    #[test]
    fn dataset_shape_and_mask() {
        let ds = SarcosSim::new(64, 0.3, 0).generate();
        assert_eq!(ds.p(), 64);
        assert_eq!(ds.q(), 7);
        assert!((ds.missing_ratio() - 0.3).abs() < 0.01);
        assert_eq!(ds.time_family, "icm");
        // inputs standardized
        for j in 0..ds.s.cols {
            let col = ds.s.col(j);
            let mean = col.iter().sum::<f64>() / col.len() as f64;
            assert!(mean.abs() < 1e-9);
        }
    }

    #[test]
    fn torque_tasks_are_correlated_but_distinct() {
        let ds = SarcosSim::new(256, 0.0, 5).generate();
        // tasks share dynamics -> nontrivial correlation between adjacent
        // joints, but not identical
        let col = |k: usize| -> Vec<f64> { (0..256).map(|i| ds.y_grid[i * 7 + k]).collect() };
        let (a, b) = (col(1), col(2));
        let corr = {
            let ma = a.iter().sum::<f64>() / 256.0;
            let mb = b.iter().sum::<f64>() / 256.0;
            let cov: f64 = a.iter().zip(&b).map(|(x, y)| (x - ma) * (y - mb)).sum();
            let va: f64 = a.iter().map(|x| (x - ma) * (x - ma)).sum();
            let vb: f64 = b.iter().map(|y| (y - mb) * (y - mb)).sum();
            cov / (va * vb).sqrt().max(1e-12)
        };
        assert!(corr.abs() < 0.999, "tasks identical");
        assert!(corr.is_finite());
    }
}
