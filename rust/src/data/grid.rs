//! Partial-grid dataset container.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// A regression problem on a p x q grid with missing values.
///
/// Layout matches the kron module: grid index `j*q + k` = (s_j, t_k).
/// `y_grid` holds the *full* ground truth (simulators know it), `mask`
/// marks which cells are observed during training; the complement is
/// the test set.
#[derive(Clone, Debug)]
pub struct GridDataset {
    /// Spatial inputs, p x d_s (standardized).
    pub s: Matrix<f64>,
    /// Time/task coordinates, length q.
    pub t: Vec<f64>,
    /// Full-grid targets (raw scale), length p*q.
    pub y_grid: Vec<f64>,
    /// Observed mask, length p*q.
    pub mask: Vec<bool>,
    /// Time-kernel family this dataset is modeled with.
    pub time_family: String,
    /// Dataset name for reports.
    pub name: String,
}

impl GridDataset {
    /// Number of spatial points p.
    pub fn p(&self) -> usize {
        self.s.rows
    }

    /// Number of time steps / tasks q.
    pub fn q(&self) -> usize {
        self.t.len()
    }

    /// Grid size p*q.
    pub fn grid_len(&self) -> usize {
        self.p() * self.q()
    }

    /// Number of observed (training) cells.
    pub fn n_observed(&self) -> usize {
        self.mask.iter().filter(|&&m| m).count()
    }

    /// Fraction of cells withheld (the test set).
    pub fn missing_ratio(&self) -> f64 {
        1.0 - self.n_observed() as f64 / self.grid_len() as f64
    }

    /// Mean/std of the *observed* targets (training statistics only —
    /// no test leakage).
    pub fn target_stats(&self) -> (f64, f64) {
        let obs: Vec<f64> = self
            .y_grid
            .iter()
            .zip(&self.mask)
            .filter(|(_, &m)| m)
            .map(|(y, _)| *y)
            .collect();
        let n = obs.len().max(1) as f64;
        let mean = obs.iter().sum::<f64>() / n;
        let var = obs.iter().map(|y| (y - mean) * (y - mean)).sum::<f64>() / n;
        (mean, var.sqrt().max(1e-12))
    }

    /// Standardized targets padded with zeros at missing cells — the RHS
    /// vector the LKGP solver consumes.
    pub fn y_std_padded(&self) -> Vec<f64> {
        let (mean, std) = self.target_stats();
        self.y_grid
            .iter()
            .zip(&self.mask)
            .map(|(y, &m)| if m { (y - mean) / std } else { 0.0 })
            .collect()
    }

    /// Mask as f64 (1 observed / 0 missing).
    pub fn mask_f64(&self) -> Vec<f64> {
        self.mask.iter().map(|&m| if m { 1.0 } else { 0.0 }).collect()
    }

    /// Indices of observed cells.
    pub fn observed_indices(&self) -> Vec<usize> {
        (0..self.grid_len()).filter(|&i| self.mask[i]).collect()
    }

    /// Indices of missing (test) cells.
    pub fn missing_indices(&self) -> Vec<usize> {
        (0..self.grid_len()).filter(|&i| !self.mask[i]).collect()
    }

    /// Observed cells as (spatial index, time index) pairs.
    pub fn observed_coords(&self) -> Vec<(usize, usize)> {
        let q = self.q();
        self.observed_indices().iter().map(|&i| (i / q, i % q)).collect()
    }

    /// Raw-scale test targets at missing cells.
    pub fn test_targets(&self) -> Vec<f64> {
        self.missing_indices().iter().map(|&i| self.y_grid[i]).collect()
    }

    /// Raw-scale train targets at observed cells.
    pub fn train_targets(&self) -> Vec<f64> {
        self.observed_indices().iter().map(|&i| self.y_grid[i]).collect()
    }

    /// Apply uniform-at-random missingness (paper's SARCOS/climate
    /// protocol), preserving at least one observation.
    pub fn mask_uniform(&mut self, missing_ratio: f64, seed: u64) {
        let mut rng = Rng::new(seed ^ 0xC0FFEE);
        let n = self.grid_len();
        let n_missing = ((n as f64) * missing_ratio).round() as usize;
        let n_missing = n_missing.min(n - 1);
        self.mask = vec![true; n];
        for idx in rng.choose(n, n_missing) {
            self.mask[idx] = false;
        }
    }

    /// Right-censor rows: for each spatial row not in `full_rows`, keep a
    /// uniformly random prefix of time steps (the LCBench early-stopping
    /// pattern, paper Sec. 4 "Learning Curve Prediction").
    pub fn mask_censor_rows(&mut self, full_fraction: f64, min_prefix: usize, seed: u64) {
        let mut rng = Rng::new(seed ^ 0xCE2508);
        let (p, q) = (self.p(), self.q());
        let n_full = ((p as f64) * full_fraction).round() as usize;
        let full_rows: Vec<usize> = rng.choose(p, n_full.max(1));
        let is_full = {
            let mut v = vec![false; p];
            for &r in &full_rows {
                v[r] = true;
            }
            v
        };
        self.mask = vec![true; p * q];
        for j in 0..p {
            if is_full[j] {
                continue;
            }
            let stop = min_prefix + rng.below(q - min_prefix);
            for k in stop..q {
                self.mask[j * q + k] = false;
            }
        }
    }

    /// Sanity-check the invariants experiments rely on.
    pub fn validate(&self) {
        assert_eq!(self.y_grid.len(), self.grid_len());
        assert_eq!(self.mask.len(), self.grid_len());
        assert!(self.n_observed() > 0, "no observed cells");
        assert!(self.y_grid.iter().all(|y| y.is_finite()), "non-finite target");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(p: usize, q: usize) -> GridDataset {
        GridDataset {
            s: Matrix::from_fn(p, 2, |i, j| (i + j) as f64),
            t: (0..q).map(|k| k as f64).collect(),
            y_grid: (0..p * q).map(|i| i as f64).collect(),
            mask: vec![true; p * q],
            time_family: "rbf".into(),
            name: "toy".into(),
        }
    }

    #[test]
    fn uniform_mask_hits_requested_ratio() {
        let mut d = toy(20, 10);
        d.mask_uniform(0.3, 7);
        assert_eq!(d.grid_len() - d.n_observed(), 60);
        assert!((d.missing_ratio() - 0.3).abs() < 1e-9);
        d.validate();
    }

    #[test]
    fn censor_mask_is_prefix_structured() {
        let mut d = toy(30, 8);
        d.mask_censor_rows(0.1, 2, 3);
        for j in 0..30 {
            let row = &d.mask[j * 8..(j + 1) * 8];
            // once missing, stays missing (prefix observation)
            let mut seen_missing = false;
            let mut prefix_len = 0;
            for &m in row {
                if m {
                    assert!(!seen_missing, "non-prefix mask in row {j}");
                    prefix_len += 1;
                } else {
                    seen_missing = true;
                }
            }
            assert!(prefix_len >= 2, "prefix too short in row {j}");
        }
        d.validate();
    }

    #[test]
    fn standardization_uses_observed_only() {
        let mut d = toy(4, 4);
        // make missing cells wild — must not affect stats
        d.mask = (0..16).map(|i| i % 2 == 0).collect();
        for (i, y) in d.y_grid.iter_mut().enumerate() {
            if i % 2 == 1 {
                *y = 1e9;
            }
        }
        let (mean, std) = d.target_stats();
        let obs: Vec<f64> = (0..16).step_by(2).map(|i| i as f64).collect();
        let want_mean = obs.iter().sum::<f64>() / 8.0;
        assert!((mean - want_mean).abs() < 1e-9);
        assert!(std < 10.0);
        let ypad = d.y_std_padded();
        for i in (1..16).step_by(2) {
            assert_eq!(ypad[i], 0.0);
        }
    }

    #[test]
    fn train_test_partition() {
        let mut d = toy(5, 4);
        d.mask_uniform(0.25, 1);
        assert_eq!(d.train_targets().len() + d.test_targets().len(), 20);
        let obs = d.observed_coords();
        assert_eq!(obs.len(), d.n_observed());
        for (j, k) in obs {
            assert!(d.mask[j * 4 + k]);
        }
    }
}
