//! Synthetic product-grid datasets.
//!
//! * `fig2_dataset` — the ten-dimensional synthetic data of Figure 2
//!   (balanced factorization p = q = sqrt(n), 5 spatial + 5 time dims).
//! * `kron_gp_draw` — exact GP draws from a product kernel on a grid via
//!   Kronecker Cholesky factors, used by correctness tests (the model is
//!   well-specified there, so exact inference must recover hyperparams).

use crate::kernels::ProductGridKernel;
use crate::kron::KronOp;
use crate::linalg::{cholesky, Matrix};
use crate::util::rng::Rng;

use super::grid::GridDataset;

/// Random inputs for the Fig-2 scaling study: p x ds spatial inputs and
/// q x dt "time" inputs, all standard normal (matching the paper's
/// ten-dimensional synthetic setup with ds = dt = 5).
pub struct SyntheticInputs {
    /// Spatial inputs (p x 5, standard normal).
    pub s: Matrix<f64>,
    /// Multi-dimensional "time" inputs (q x 5, standard normal).
    pub t_multi: Matrix<f64>,
}

/// Draw the Fig-2 input set for a (p, q) factorization.
pub fn fig2_inputs(p: usize, q: usize, seed: u64) -> SyntheticInputs {
    let mut rng = Rng::new(seed ^ 0xF162);
    SyntheticInputs {
        s: Matrix::from_vec(p, 5, rng.normals(p * 5)),
        t_multi: Matrix::from_vec(q, 5, rng.normals(q * 5)),
    }
}

/// Draw y ~ N(0, K_SS (x) K_TT + sigma2 I) on the full grid using the
/// factored Cholesky (L_S (x) L_T) z — O(p^3 + q^3 + pq(p+q)).
pub fn kron_gp_draw(
    kss: &Matrix<f64>,
    ktt: &Matrix<f64>,
    sigma2: f64,
    rng: &mut Rng,
) -> Vec<f64> {
    let (p, q) = (kss.rows, ktt.rows);
    let mut kss_j = kss.clone();
    kss_j.add_diag(1e-8 * kss.trace() / p as f64);
    let mut ktt_j = ktt.clone();
    ktt_j.add_diag(1e-8 * ktt.trace() / q as f64);
    let ls = cholesky(&kss_j).expect("K_SS not PD").l;
    let lt = cholesky(&ktt_j).expect("K_TT not PD").l;
    let z = Matrix::from_vec(1, p * q, rng.normals(p * q));
    let f = KronOp::new(ls, lt).apply_batch(&z);
    f.row(0).iter().map(|v| v + sigma2.sqrt() * rng.normal()).collect()
}

/// A well-specified GridDataset drawn from the model class itself:
/// ideal for solver/exactness tests and ablations.
pub fn well_specified(
    p: usize,
    q: usize,
    ds: usize,
    kernel: &ProductGridKernel,
    sigma2: f64,
    missing_ratio: f64,
    seed: u64,
) -> GridDataset {
    let mut rng = Rng::new(seed ^ 0x3E11);
    let s = Matrix::from_vec(p, ds, rng.normals(p * ds));
    let t: Vec<f64> = (0..q).map(|k| k as f64 / (q.max(2) - 1) as f64).collect();
    let kss = kernel.gram_s(&s);
    let ktt = kernel.gram_t(&t);
    let y = kron_gp_draw(&kss, &ktt, sigma2, &mut rng);
    let mut dsr = GridDataset {
        s,
        t,
        y_grid: y,
        mask: vec![true; p * q],
        time_family: kernel.time.family().to_string(),
        name: format!("synthetic(p={p},q={q})"),
    };
    dsr.mask_uniform(missing_ratio, seed);
    dsr.validate();
    dsr
}

/// An off-grid regression workload for SKI training: `n_train` +
/// `n_test` points scattered uniformly inside the unit square, targets
/// from a smooth two-frequency surface plus observation noise of
/// variance `sigma2`, referenced to a `p x q` linspace inducing grid on
/// `[0, 1]^2`.
///
/// The target surface is deterministic (no kernel draw), so a dense
/// exact GP and a SKI fit on the same sample disagree only through
/// their respective approximations — exactly the comparison
/// `bench_ski` gates.
pub fn off_grid(
    n_train: usize,
    n_test: usize,
    p: usize,
    q: usize,
    sigma2: f64,
    seed: u64,
) -> super::offgrid::OffGridDataset {
    let mut rng = Rng::new(seed ^ 0x0FF6);
    let surface = |xs: f64, xt: f64| {
        (3.0 * xs).sin() * (2.0 * xt).cos() + 0.5 * (7.0 * xs * xt).sin()
    };
    let noise = sigma2.sqrt();
    let mut draw = |n: usize| {
        let mut xs = Vec::with_capacity(n);
        let mut xt = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let a = rng.uniform();
            let b = rng.uniform();
            xs.push(a);
            xt.push(b);
            y.push(surface(a, b) + noise * rng.normal());
        }
        (xs, xt, y)
    };
    let (xs, xt, y) = draw(n_train);
    let (test_xs, test_xt, test_y) = draw(n_test);
    let linspace = |m: usize| -> Vec<f64> {
        (0..m).map(|k| k as f64 / (m.max(2) - 1) as f64).collect()
    };
    super::offgrid::OffGridDataset {
        xs,
        xt,
        y,
        test_xs,
        test_xt,
        test_y,
        grid_s: linspace(p),
        grid_t: linspace(q),
        time_family: "rbf".to_string(),
        name: format!("offgrid(n={n_train},p={p},q={q})"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draw_covariance_statistically_correct() {
        // empirical variance of grid values ~ diag(K (x) K) + sigma2
        let mut rng = Rng::new(0);
        let kernel = ProductGridKernel::new(2, "rbf", 4);
        let s = Matrix::from_vec(3, 2, rng.normals(6));
        let t = vec![0.0, 0.5, 1.0, 1.5];
        let kss = kernel.gram_s(&s);
        let ktt = kernel.gram_t(&t);
        let nsamp = 3000;
        let mut acc = vec![0.0; 12];
        for _ in 0..nsamp {
            let y = kron_gp_draw(&kss, &ktt, 0.1, &mut rng);
            for (a, v) in acc.iter_mut().zip(&y) {
                *a += v * v;
            }
        }
        for (idx, a) in acc.iter().enumerate() {
            let want = kss[(idx / 4, idx / 4)] * ktt[(idx % 4, idx % 4)] + 0.1;
            let got = a / nsamp as f64;
            assert!((got - want).abs() < 0.15 * want + 0.05, "idx {idx}: {got} vs {want}");
        }
    }

    #[test]
    fn well_specified_shapes() {
        let kernel = ProductGridKernel::new(3, "rbf", 6);
        let ds = well_specified(10, 6, 3, &kernel, 0.05, 0.2, 1);
        assert_eq!(ds.p(), 10);
        assert_eq!(ds.q(), 6);
        assert!((ds.missing_ratio() - 0.2).abs() < 0.02);
    }

    #[test]
    fn fig2_inputs_are_ten_dimensional() {
        let si = fig2_inputs(32, 32, 0);
        assert_eq!(si.s.cols + si.t_multi.cols, 10);
    }

    #[test]
    fn off_grid_points_live_inside_the_inducing_box() {
        let od = off_grid(200, 50, 16, 12, 0.01, 9);
        od.validate().unwrap();
        assert_eq!(od.n(), 200);
        assert_eq!(od.test_y.len(), 50);
        assert_eq!((od.p(), od.q()), (16, 12));
        let (s_lo, s_hi) = (od.grid_s[0], *od.grid_s.last().unwrap());
        let (t_lo, t_hi) = (od.grid_t[0], *od.grid_t.last().unwrap());
        for i in 0..od.n() {
            assert!(od.xs[i] >= s_lo && od.xs[i] <= s_hi);
            assert!(od.xt[i] >= t_lo && od.xt[i] <= t_hi);
        }
        // deterministic in the seed
        let od2 = off_grid(200, 50, 16, 12, 0.01, 9);
        assert_eq!(od.y[0].to_bits(), od2.y[0].to_bits());
    }
}
