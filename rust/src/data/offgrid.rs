//! Off-grid regression datasets for SKI (sparse kernel interpolation)
//! training: n scattered `(x_s, x_t)` points projected onto a latent
//! spatial x time inducing grid by a
//! [`SparseProjection`](crate::kron::interp::SparseProjection).
//!
//! Unlike [`GridDataset`](crate::data::GridDataset), where every target
//! sits exactly on a (partially observed) grid cell, an
//! [`OffGridDataset`] places targets anywhere inside the grid's bounding
//! box. The fit path (`Lkgp::fit_offgrid`) builds the interpolation
//! projection `W` from the point coordinates and trains against the
//! data-space system `W (K_SS (x) K_TT) W^T + sigma2 I`.

use anyhow::{bail, Result};

use crate::linalg::Matrix;

use super::GridDataset;

/// An off-grid training set plus an optional held-out test split, both
/// referenced to the same latent inducing grid.
///
/// The spatial axis is one-dimensional (`ds = 1`): interpolation
/// stencils need a sorted coordinate axis per dimension, and the latent
/// grid is the tensor product `grid_s x grid_t`.
#[derive(Clone, Debug)]
pub struct OffGridDataset {
    /// Spatial coordinate of each training point, length n.
    pub xs: Vec<f64>,
    /// Time coordinate of each training point, length n.
    pub xt: Vec<f64>,
    /// Raw (unstandardized) target of each training point, length n.
    pub y: Vec<f64>,
    /// Spatial coordinates of held-out test points (may be empty).
    pub test_xs: Vec<f64>,
    /// Time coordinates of held-out test points.
    pub test_xt: Vec<f64>,
    /// Raw targets of held-out test points.
    pub test_y: Vec<f64>,
    /// Sorted (strictly increasing) spatial inducing nodes, length p.
    pub grid_s: Vec<f64>,
    /// Sorted (strictly increasing) time inducing nodes, length q.
    pub grid_t: Vec<f64>,
    /// Time-kernel family (`"rbf"` | `"rbf_periodic"` | `"icm"`).
    pub time_family: String,
    /// Dataset name (reports only).
    pub name: String,
}

impl OffGridDataset {
    /// Number of training points n.
    pub fn n(&self) -> usize {
        self.y.len()
    }

    /// Number of spatial inducing nodes p.
    pub fn p(&self) -> usize {
        self.grid_s.len()
    }

    /// Number of time inducing nodes q.
    pub fn q(&self) -> usize {
        self.grid_t.len()
    }

    /// Latent grid size p*q.
    pub fn grid_len(&self) -> usize {
        self.p() * self.q()
    }

    /// Spatial inducing nodes as the p x 1 matrix the kernel layer
    /// consumes.
    pub fn s_matrix(&self) -> Matrix<f64> {
        Matrix::from_vec(self.p(), 1, self.grid_s.clone())
    }

    /// Mean and std of the training targets — the same population
    /// formula (and the same summation order) as
    /// [`GridDataset::target_stats`], so a grid-coincident conversion
    /// standardizes bit-identically to the mask path.
    pub fn target_stats(&self) -> (f64, f64) {
        let n = self.y.len().max(1) as f64;
        let mean = self.y.iter().sum::<f64>() / n;
        let var = self.y.iter().map(|y| (y - mean) * (y - mean)).sum::<f64>() / n;
        (mean, var.sqrt().max(1e-12))
    }

    /// Standardized training targets — the RHS vector the SKI solver
    /// consumes (no padding: every point is observed).
    pub fn y_std(&self) -> Vec<f64> {
        let (mean, std) = self.target_stats();
        self.y.iter().map(|y| (y - mean) / std).collect()
    }

    /// Check internal shape consistency.
    pub fn validate(&self) -> Result<()> {
        let n = self.n();
        if self.xs.len() != n || self.xt.len() != n {
            bail!("coordinate lengths {}/{} != target length {n}", self.xs.len(), self.xt.len());
        }
        if self.test_xs.len() != self.test_y.len() || self.test_xt.len() != self.test_y.len() {
            bail!(
                "test coordinate lengths {}/{} != test target length {}",
                self.test_xs.len(),
                self.test_xt.len(),
                self.test_y.len()
            );
        }
        if self.grid_s.is_empty() || self.grid_t.is_empty() {
            bail!("empty inducing grid ({} x {})", self.grid_s.len(), self.grid_t.len());
        }
        for g in [&self.grid_s, &self.grid_t] {
            if g.windows(2).any(|w| !(w[0] < w[1])) {
                bail!("inducing grid is not strictly increasing");
            }
        }
        Ok(())
    }

    /// Convert a (partially observed) grid dataset into its off-grid
    /// equivalent: one point per observed cell, placed exactly at the
    /// cell's node coordinates, in grid order `j*q + k`. Requires a
    /// one-dimensional, strictly increasing spatial axis (`ds == 1`).
    ///
    /// Because every point coincides with a grid node, the linear
    /// interpolation projection built from this dataset is exactly the
    /// 0/1 observation mask — the degenerate case the differential
    /// tests pin against the mask path.
    pub fn from_grid(g: &GridDataset) -> Result<Self> {
        if g.s.cols != 1 {
            bail!(
                "interp projection needs a 1-D spatial axis (ds == 1), got ds = {}",
                g.s.cols
            );
        }
        let grid_s: Vec<f64> = (0..g.p()).map(|j| g.s[(j, 0)]).collect();
        if grid_s.windows(2).any(|w| !(w[0] < w[1])) {
            bail!("spatial axis must be strictly increasing for interp projection");
        }
        let q = g.q();
        let mut xs = Vec::new();
        let mut xt = Vec::new();
        let mut y = Vec::new();
        for j in 0..g.p() {
            for k in 0..q {
                let idx = j * q + k;
                if g.mask[idx] {
                    xs.push(grid_s[j]);
                    xt.push(g.t[k]);
                    y.push(g.y_grid[idx]);
                }
            }
        }
        if y.is_empty() {
            bail!("grid dataset has no observed cells");
        }
        Ok(OffGridDataset {
            xs,
            xt,
            y,
            test_xs: Vec::new(),
            test_xt: Vec::new(),
            test_y: Vec::new(),
            grid_s,
            grid_t: g.t.clone(),
            time_family: g.time_family.clone(),
            name: g.name.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::well_specified;
    use crate::kernels::ProductGridKernel;

    fn grid_1d(seed: u64, missing: f64) -> GridDataset {
        let kernel = ProductGridKernel::new(1, "rbf", 6);
        let mut g = well_specified(8, 6, 1, &kernel, 0.01, missing, seed);
        // well_specified draws s ~ N(0,1); sort it into a valid axis
        let mut col: Vec<f64> = (0..g.p()).map(|j| g.s[(j, 0)]).collect();
        col.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (j, v) in col.iter().enumerate() {
            g.s[(j, 0)] = *v;
        }
        g
    }

    #[test]
    fn from_grid_orders_points_like_the_grid() {
        let g = grid_1d(11, 0.25);
        let od = OffGridDataset::from_grid(&g).unwrap();
        od.validate().unwrap();
        assert_eq!(od.n(), g.n_observed());
        assert_eq!(od.p(), g.p());
        assert_eq!(od.q(), g.q());
        let obs = g.observed_indices();
        for (i, &idx) in obs.iter().enumerate() {
            let (j, k) = (idx / g.q(), idx % g.q());
            assert_eq!(od.xs[i].to_bits(), g.s[(j, 0)].to_bits());
            assert_eq!(od.xt[i].to_bits(), g.t[k].to_bits());
            assert_eq!(od.y[i].to_bits(), g.y_grid[idx].to_bits());
        }
    }

    #[test]
    fn target_stats_match_grid_bitwise() {
        let g = grid_1d(7, 0.3);
        let od = OffGridDataset::from_grid(&g).unwrap();
        let (gm, gs) = g.target_stats();
        let (om, os) = od.target_stats();
        assert_eq!(gm.to_bits(), om.to_bits());
        assert_eq!(gs.to_bits(), os.to_bits());
        // standardized targets: the off-grid vector is the observed
        // subsequence of the padded grid vector, bit for bit
        let yg = g.y_std_padded();
        let yo = od.y_std();
        for (i, &idx) in g.observed_indices().iter().enumerate() {
            assert_eq!(yo[i].to_bits(), yg[idx].to_bits());
        }
    }

    #[test]
    fn from_grid_rejects_multidim_and_unsorted() {
        let kernel = ProductGridKernel::new(2, "rbf", 4);
        let g2 = well_specified(6, 4, 2, &kernel, 0.01, 0.2, 3);
        assert!(OffGridDataset::from_grid(&g2).is_err());
        let mut g1 = grid_1d(5, 0.2);
        g1.s[(0, 0)] = g1.s[(1, 0)]; // duplicate node
        assert!(OffGridDataset::from_grid(&g1).is_err());
    }
}
