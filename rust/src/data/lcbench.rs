//! Simulated LCBench: learning-curve prediction workloads.
//!
//! The real LCBench (Zimmer et al. 2021) contains 35 datasets x 2000
//! neural-network learning curves x 52 epochs, where each curve's shape
//! depends on 7 hyperparameters. This simulator reproduces that
//! structure (DESIGN.md §Substitutions): curves follow a saturating
//! power-law/exponential family whose parameters are smooth (random
//! quadratic) functions of the hyperparameter vector, plus
//! heteroskedastic noise and a small fraction of divergent "outlier"
//! curves (the paper's Fig. 4 third row). Missingness is right-censoring
//! at a uniform random epoch — the early-stopping pattern.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

use super::grid::GridDataset;

const N_HYPER: usize = 7;

/// One synthetic "LCBench dataset" family.
pub struct LcBenchSim {
    /// number of hyperparameter configurations (curves)
    pub p: usize,
    /// number of epochs per curve
    pub q: usize,
    /// fraction of curves observed in full during training
    pub full_fraction: f64,
    /// fraction of divergent outlier curves
    pub outlier_fraction: f64,
    /// Generation seed.
    pub seed: u64,
}

impl LcBenchSim {
    /// Simulator with default censoring/outlier fractions.
    pub fn new(p: usize, q: usize, seed: u64) -> Self {
        LcBenchSim { p, q, full_fraction: 0.1, outlier_fraction: 0.02, seed }
    }

    /// The 7 paper names of the hyperparameters (for docs/reports).
    pub fn hyper_names() -> [&'static str; N_HYPER] {
        ["batch_size", "learning_rate", "momentum", "weight_decay", "num_layers",
         "max_units", "dropout"]
    }

    /// Generate the dataset (deterministic per configuration).
    pub fn generate(&self) -> GridDataset {
        let mut rng = Rng::new(self.seed ^ 0x1CBE7C);
        // dataset-level difficulty parameters
        let base_floor = rng.uniform_in(5.0, 30.0); // best reachable error %
        let base_start = rng.uniform_in(60.0, 95.0); // error at epoch 0
        let noise_scale = rng.uniform_in(0.3, 1.2);

        // random quadratic maps: hyperparams -> curve parameters.
        // w1: linear terms, w2: pairwise interactions (low-rank).
        let mut lin = |scale: f64| -> Vec<f64> {
            (0..N_HYPER).map(|_| scale * rng.normal()).collect()
        };
        let w_floor = lin(0.8);
        let w_rate = lin(0.5);
        let w_start = lin(0.4);
        let u: Vec<f64> = (0..N_HYPER).map(|_| rng.normal() * 0.4).collect();

        let mut s = Matrix::zeros(self.p, N_HYPER);
        let mut y = vec![0.0; self.p * self.q];
        for i in 0..self.p {
            // hyperparameters in [-1, 1] (log-scaled raw ranges)
            let h: Vec<f64> = (0..N_HYPER).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            s.row_mut(i).copy_from_slice(&h);
            let dotw = |w: &[f64]| -> f64 { w.iter().zip(&h).map(|(a, b)| a * b).sum() };
            let inter: f64 = {
                let t = u.iter().zip(&h).map(|(a, b)| a * b).sum::<f64>();
                t * t
            };
            // curve parameters, all smooth in h
            let floor = base_floor * (1.0 + 0.5 * (dotw(&w_floor) + inter).tanh());
            let start = base_start * (1.0 + 0.2 * dotw(&w_start).tanh());
            let rate = 0.12 * (1.0 + 0.9 * dotw(&w_rate).tanh()); // per-epoch decay
            let is_outlier = rng.uniform() < self.outlier_fraction;
            let diverge_at = if is_outlier { rng.uniform_in(0.2, 0.7) * self.q as f64 } else { f64::INFINITY };
            let het = noise_scale * rng.uniform_in(0.5, 1.5);
            for k in 0..self.q {
                let t = k as f64;
                let mut v = floor + (start - floor) * (-rate * t).exp();
                if t > diverge_at {
                    // divergence: error climbs back up after some epoch
                    v += (t - diverge_at) * rng.uniform_in(0.8, 1.6);
                }
                // heteroskedastic noise, larger early in training
                let sigma = het * (0.3 + (-0.05 * t).exp());
                v += sigma * rng.normal();
                y[i * self.q + k] = v.clamp(0.0, 120.0);
            }
        }
        let mut ds = GridDataset {
            s,
            t: (0..self.q).map(|k| k as f64 / (self.q - 1).max(1) as f64).collect(),
            y_grid: y,
            mask: vec![true; self.p * self.q],
            time_family: "rbf".into(),
            name: format!("lcbench-sim-{}", self.seed),
        };
        ds.mask_censor_rows(self.full_fraction, 2, self.seed);
        ds.validate();
        ds
    }
}

/// The 7 named dataset families reported in Table 1 (every fifth of the
/// paper's 35), regenerated as seeded simulator instances.
pub fn table1_datasets(p: usize, q: usize) -> Vec<(&'static str, LcBenchSim)> {
    ["APSFailure", "MiniBooNE", "blood", "covertype", "higgs", "kr-vs-kp", "segment"]
        .into_iter()
        .enumerate()
        .map(|(i, name)| (name, LcBenchSim::new(p, q, 1000 + i as u64)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curves_decrease_on_average() {
        let ds = LcBenchSim::new(100, 52, 0).generate();
        let q = ds.q();
        let mut early = 0.0;
        let mut late = 0.0;
        for i in 0..ds.p() {
            early += ds.y_grid[i * q];
            late += ds.y_grid[i * q + q - 1];
        }
        assert!(late < early, "curves should improve: early {early} late {late}");
    }

    #[test]
    fn censoring_structure() {
        let ds = LcBenchSim::new(200, 52, 1).generate();
        // ~10% rows full
        let q = ds.q();
        let full_rows = (0..ds.p())
            .filter(|&i| (0..q).all(|k| ds.mask[i * q + k]))
            .count();
        assert!((15..=25).contains(&full_rows), "{full_rows} full rows");
        // all test points are at curve tails
        for i in 0..ds.p() {
            let mut missing_started = false;
            for k in 0..q {
                if !ds.mask[i * q + k] {
                    missing_started = true;
                } else {
                    assert!(!missing_started);
                }
            }
        }
    }

    #[test]
    fn distinct_seeds_distinct_datasets() {
        let a = LcBenchSim::new(50, 20, 1).generate();
        let b = LcBenchSim::new(50, 20, 2).generate();
        let diff: f64 = a.y_grid.iter().zip(&b.y_grid).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1.0);
    }

    #[test]
    fn outliers_exist_with_high_fraction() {
        let mut sim = LcBenchSim::new(200, 40, 3);
        sim.outlier_fraction = 0.5;
        let ds = sim.generate();
        let q = ds.q();
        // an outlier curve ends higher than its own minimum by a margin
        let n_outlier = (0..ds.p())
            .filter(|&i| {
                let row = &ds.y_grid[i * q..(i + 1) * q];
                let min = row.iter().cloned().fold(f64::INFINITY, f64::min);
                row[q - 1] > min + 10.0
            })
            .count();
        assert!(n_outlier > 20, "only {n_outlier} outliers");
    }

    #[test]
    fn table1_families_are_seven() {
        let fams = table1_datasets(10, 8);
        assert_eq!(fams.len(), 7);
        assert_eq!(fams[0].0, "APSFailure");
    }
}
