//! Persisted model state — the train-once / serve-many boundary.
//!
//! The paper's pathwise conditioning (Sec. 3.3) concentrates all of the
//! expensive work of LKGP inference in the *fit*: once the representer
//! weights `alpha` and the pathwise sample coefficients are known,
//! every prediction is a cheap Kronecker MVM. [`TrainedModel`] captures
//! exactly that state — kernel hyperparameters, grid/mask metadata, the
//! masked representer weights, and the pathwise sample state — so a
//! model fitted in one process can be checkpointed to disk
//! ([`TrainedModel::save`]) and served from another
//! ([`crate::serve::ServeEngine`]) with **bit-identical** f64
//! predictions.
//!
//! The on-disk format (module [`io`]) is a versioned, endian-stable
//! binary layout documented in `docs/formats.md`: an 8-byte magic, a
//! fixed header, length-prefixed strings, named f64/f32 tensor blobs,
//! and a trailing FNV-1a checksum. Corrupted, truncated, or
//! wrong-version files are rejected with a typed
//! [`io::CheckpointError`], never a panic.
//!
//! Capture is opt-in: set
//! [`LkgpConfig::capture_pathwise`](crate::gp::lkgp::LkgpConfig::capture_pathwise)
//! and the fit returns the model in
//! [`LkgpFit::model`](crate::gp::lkgp::LkgpFit::model):
//!
//! ```no_run
//! use lkgp::gp::lkgp::{Lkgp, LkgpConfig};
//! use lkgp::model::TrainedModel;
//!
//! # fn main() -> anyhow::Result<()> {
//! # let data: lkgp::data::GridDataset = unimplemented!();
//! let cfg = LkgpConfig { capture_pathwise: true, ..LkgpConfig::default() };
//! let fit = Lkgp::fit(&data, cfg)?;
//! fit.model.expect("capture was on").save("model.ckpt")?;
//! let reloaded = TrainedModel::load("model.ckpt")?;
//! assert_eq!(reloaded.posterior.mean, fit.posterior.mean);
//! # Ok(())
//! # }
//! ```

pub mod io;

use crate::gp::backend::Precision;
use crate::gp::diagnostics::{ProjectionPath, TimeOpPath};
use crate::gp::Posterior;
use crate::kernels::ProductGridKernel;
use crate::kron::interp::SparseProjection;
use crate::linalg::Matrix;

/// Everything needed to reproduce (and serve) the predictions of a
/// fitted LKGP without re-running training.
///
/// All tensors are held widened to f64 in memory; [`precision`]
/// records the compute precision of the fit, and the checkpoint codec
/// stores the iterative-state tensors (`masked_alpha`, `vm`,
/// `f_prior`) in that native precision — the f64 <-> f32 round trip is
/// exact for values that originated in f32, so narrowing on write
/// loses nothing.
///
/// [`precision`]: TrainedModel::precision
#[derive(Clone, Debug)]
pub struct TrainedModel {
    /// Dataset name the model was fitted on (reports only).
    pub name: String,
    /// Time-kernel family (`"rbf"` | `"rbf_periodic"` | `"icm"`).
    pub time_family: String,
    /// Compute precision of the fit's iterative hot path; serve-time
    /// reconstruction replays MVMs in the same precision.
    pub precision: Precision,
    /// Time-factor engine the fit's MVMs used; serve-time
    /// reconstruction replays through the same engine so a Toeplitz-
    /// trained checkpoint reproduces its posterior bit for bit.
    pub time_op: TimeOpPath,
    /// Projection the fit trained through ([`ProjectionPath::Mask`] for
    /// every pre-v3 checkpoint). Serve-time replay is grid-space either
    /// way — `W^T` is already folded into `masked_alpha` / `vm` — so
    /// this is provenance plus the key that gates the `w` record.
    pub projection: ProjectionPath,
    /// The interpolation projection of an SKI fit (`None` on mask
    /// fits), persisted in checkpoint format v3 so a reloaded model can
    /// project new off-grid query points.
    pub w: Option<SparseProjection>,
    /// Spatial input dimension d_s.
    pub ds: usize,
    /// Spatial training inputs, p x d_s (standardized).
    pub s: Matrix<f64>,
    /// Time/task grid coordinates, length q.
    pub t: Vec<f64>,
    /// Observation mask over the p*q grid (1 observed / 0 missing).
    pub mask: Vec<f64>,
    /// Fitted kernel hyperparameters (flat layout, see `kernels`).
    pub theta: Vec<f64>,
    /// Fitted log observation-noise variance.
    pub log_sigma2: f64,
    /// Mean of the observed training targets (standardization state).
    pub y_mean: f64,
    /// Std of the observed training targets (standardization state).
    pub y_std: f64,
    /// Number of pathwise-conditioning samples the fit drew.
    pub n_samples: usize,
    /// Masked representer weights `M alpha`, length p*q: the predictive
    /// mean is `(K_SS (x) K_TT) M alpha` — one MVM.
    pub masked_alpha: Vec<f64>,
    /// Masked pathwise sample coefficients, `n_samples x (p q)`: row r
    /// is `M v_r` with `v_r = (P K P^T + s2 I)^{-1} (y - f_r - eps_r)`.
    pub vm: Matrix<f64>,
    /// Prior function samples on the grid, `n_samples x (p q)`: row r
    /// is `f_r = (L_S (x) L_T) z_r`.
    pub f_prior: Matrix<f64>,
    /// The posterior the fit produced, stored for integrity checks:
    /// serve-time reconstruction must reproduce it bit for bit (f64
    /// fits on the rust backend).
    pub posterior: Posterior,
}

impl TrainedModel {
    /// Number of spatial points p.
    pub fn p(&self) -> usize {
        self.s.rows
    }

    /// Number of time steps / tasks q.
    pub fn q(&self) -> usize {
        self.t.len()
    }

    /// Grid size p*q.
    pub fn grid_len(&self) -> usize {
        self.p() * self.q()
    }

    /// Validate internal shape consistency (used after deserialization).
    pub fn validate(&self) -> Result<(), io::CheckpointError> {
        let pq = self.grid_len();
        let check = |ok: bool, what: &'static str, detail: String| {
            if ok {
                Ok(())
            } else {
                Err(io::CheckpointError::BadField { what, detail })
            }
        };
        check(
            self.s.cols == self.ds,
            "s",
            format!("spatial matrix is {}x{}, expected ds {}", self.s.rows, self.s.cols, self.ds),
        )?;
        check(self.mask.len() == pq, "mask", format!("len {} != p*q {pq}", self.mask.len()))?;
        check(
            self.masked_alpha.len() == pq,
            "masked_alpha",
            format!("len {} != p*q {pq}", self.masked_alpha.len()),
        )?;
        check(
            self.vm.rows == self.n_samples && self.vm.cols == pq,
            "vm",
            format!("{}x{} != {}x{pq}", self.vm.rows, self.vm.cols, self.n_samples),
        )?;
        check(
            self.f_prior.rows == self.n_samples && self.f_prior.cols == pq,
            "f_prior",
            format!("{}x{} != {}x{pq}", self.f_prior.rows, self.f_prior.cols, self.n_samples),
        )?;
        check(
            self.posterior.mean.len() == pq && self.posterior.var.len() == pq,
            "posterior",
            format!(
                "mean/var lens {}/{} != p*q {pq}",
                self.posterior.mean.len(), self.posterior.var.len()
            ),
        )?;
        check(self.y_std > 0.0, "y_std", format!("{} must be positive", self.y_std))?;
        check(self.n_samples >= 2, "n_samples", format!("{} < 2", self.n_samples))?;
        check(
            matches!(self.time_family.as_str(), "rbf" | "rbf_periodic" | "icm"),
            "time_family",
            format!("unknown family {:?}", self.time_family),
        )?;
        let kernel = ProductGridKernel::new(self.ds, &self.time_family, self.q());
        let expect_theta = kernel.n_theta();
        check(
            self.theta.len() == expect_theta,
            "theta",
            format!("len {} != {expect_theta} for this kernel", self.theta.len()),
        )?;
        match (&self.projection, &self.w) {
            (ProjectionPath::Mask, None) => {}
            (ProjectionPath::Mask, Some(_)) => {
                return Err(io::CheckpointError::BadField {
                    what: "w",
                    detail: "mask-projection model carries a W record".into(),
                });
            }
            (ProjectionPath::Interp(_), None) => {
                return Err(io::CheckpointError::BadField {
                    what: "w",
                    detail: "interp-projection model is missing its W record".into(),
                });
            }
            (ProjectionPath::Interp(d), Some(w)) => {
                check(
                    w.degree() == *d,
                    "w",
                    format!("W degree {} != projection {}", w.degree(), d),
                )?;
                check(
                    w.grid_p() == self.p() && w.grid_q() == self.q(),
                    "w",
                    format!(
                        "W grid {}x{} != model grid {}x{}",
                        w.grid_p(),
                        w.grid_q(),
                        self.p(),
                        self.q()
                    ),
                )?;
            }
        }
        Ok(())
    }
}
