//! Versioned, endian-stable binary checkpoint codec for
//! [`TrainedModel`].
//!
//! Layout (byte-exact specification in `docs/formats.md`):
//!
//! ```text
//! [0..8)    magic  b"LKGPCKPT"
//! [8..12)   format version, u32 LE (currently 3; version 2 still reads)
//! [12..16)  precision u8 (0 = f64, 1 = f32), time-op u8 (0 = dense,
//!           1 = toeplitz; new in version 2), projection u8 (0 = mask,
//!           1 = interp-linear, 2 = interp-cubic; new in version 3),
//!           1 reserved zero byte
//! [16..48)  p, q, ds, n_samples       — 4 x u64 LE
//! [48..72)  log_sigma2, y_mean, y_std — 3 x f64 LE
//! ...       time_family, name         — 2 x (u32 LE length + UTF-8)
//! ...       theta                     — u32 LE count + count x f64 LE
//! ...       tensor count u32 LE, then per tensor:
//!             name (u32 LE length + UTF-8), dtype u8 (0 = f64, 1 = f32),
//!             rows u64 LE, cols u64 LE, rows*cols scalars LE
//! [len-8..) FNV-1a 64 checksum of every preceding byte, u64 LE
//! ```
//!
//! A mask checkpoint carries exactly 8 tensors; an interp (SKI)
//! checkpoint carries 11 — the sparse projection `W` travels as three
//! extra f64 tensors `w_indptr` (1 x (n+1)), `w_cols` (1 x nnz), and
//! `w_weights` (1 x nnz), with indices stored as exact f64 integers
//! (lossless below 2^53, far beyond any realistic nnz).
//!
//! Every multi-byte value is little-endian regardless of host
//! byte order, so checkpoints move between machines. The iterative
//! state tensors (`masked_alpha`, `vm`, `f_prior`) are stored in the
//! fit's native compute precision — f32 checkpoints are half the size
//! and the narrow/widen round trip is exact because the values
//! originated as f32. Structural metadata and the fitted posterior are
//! always f64.
//!
//! Decoding is total: corrupted, truncated, or wrong-version input is
//! rejected with a typed [`CheckpointError`] (downcastable from the
//! `anyhow` chain returned by [`TrainedModel::load`]), never a panic.

use std::fmt;
use std::path::Path;

use anyhow::{Context, Result};

use crate::gp::backend::Precision;
use crate::gp::diagnostics::{ProjectionPath, TimeOpPath};
use crate::gp::Posterior;
use crate::kron::interp::{InterpDegree, SparseProjection};
use crate::linalg::Matrix;
use crate::util::convert;

use super::TrainedModel;

/// First 8 bytes of every checkpoint.
pub const MAGIC: [u8; 8] = *b"LKGPCKPT";

/// Current checkpoint format version. Version 2 assigned the second
/// header flag byte (offset 13) to the time-op tag; version 3 assigned
/// the third (offset 14) to the projection tag and added the `W`
/// tensor records of SKI fits. Version-2 files (always mask-projection)
/// still load; version-1 files are rejected with
/// [`CheckpointError::UnsupportedVersion`].
pub const VERSION: u32 = 3;

/// Oldest checkpoint format version this build still reads.
pub const MIN_VERSION: u32 = 2;

/// FNV-1a 64-bit hash — the checkpoint's trailing checksum function.
/// Exposed so external tooling (and the format tests) can produce and
/// verify the integrity trailer documented in `docs/formats.md`.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Typed decode failure for checkpoint bytes. Every malformed input
/// maps to one of these variants — decoding never panics.
#[derive(Debug, PartialEq, Eq)]
pub enum CheckpointError {
    /// The first 8 bytes are not [`MAGIC`] — not a checkpoint file.
    BadMagic {
        /// The bytes actually found at offset 0.
        found: [u8; 8],
    },
    /// The format version is not one this build can read.
    UnsupportedVersion {
        /// Version recorded in the file.
        found: u32,
        /// Version this build supports ([`VERSION`]).
        supported: u32,
    },
    /// The input ended before a field could be read in full.
    Truncated {
        /// What was being read when the input ran out.
        what: &'static str,
        /// Bytes the field needed.
        needed: usize,
        /// Bytes actually available.
        available: usize,
    },
    /// The trailing FNV-1a checksum does not match the content.
    ChecksumMismatch {
        /// Checksum stored in the trailer.
        stored: u64,
        /// Checksum computed over the content.
        computed: u64,
    },
    /// A structurally valid field carries an invalid value
    /// (bad UTF-8, unknown dtype, shape mismatch, ...).
    BadField {
        /// Field name.
        what: &'static str,
        /// Human-readable description of the problem.
        detail: String,
    },
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::BadMagic { found } => {
                write!(f, "not an LKGP checkpoint (magic {found:?}, expected {MAGIC:?})")
            }
            CheckpointError::UnsupportedVersion { found, supported } => {
                write!(f, "unsupported checkpoint version {found} (this build reads {supported})")
            }
            CheckpointError::Truncated { what, needed, available } => {
                write!(f, "truncated checkpoint: {what} needs {needed} bytes, {available} left")
            }
            CheckpointError::ChecksumMismatch { stored, computed } => write!(
                f,
                "checkpoint checksum mismatch: trailer {stored:#018x}, content {computed:#018x}"
            ),
            CheckpointError::BadField { what, detail } => {
                write!(f, "invalid checkpoint field {what:?}: {detail}")
            }
        }
    }
}

impl std::error::Error for CheckpointError {}

// ---------------------------------------------------------------------
// encoding
// ---------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Tensor dtype tags (the `dtype` byte of a tensor record).
const DTYPE_F64: u8 = 0;
const DTYPE_F32: u8 = 1;

/// Time-op tags (header byte at offset 13, format version >= 2).
const TIME_OP_DENSE: u8 = 0;
const TIME_OP_TOEPLITZ: u8 = 1;

/// Projection tags (header byte at offset 14, format version >= 3;
/// reserved zero — i.e. mask — in version 2).
const PROJ_MASK: u8 = 0;
const PROJ_INTERP_LINEAR: u8 = 1;
const PROJ_INTERP_CUBIC: u8 = 2;

fn put_tensor(out: &mut Vec<u8>, name: &str, rows: usize, cols: usize, data: &[f64], dtype: u8) {
    // a real assert (not debug): a shape-desynced record would produce a
    // checksum-valid but unreadable file, failing far from the cause
    assert_eq!(data.len(), rows * cols, "tensor {name:?} shape mismatch");
    put_str(out, name);
    out.push(dtype);
    put_u64(out, rows as u64);
    put_u64(out, cols as u64);
    match dtype {
        DTYPE_F32 => {
            for &x in data {
                out.extend_from_slice(&convert::f32_of(x).to_le_bytes());
            }
        }
        _ => {
            for &x in data {
                put_f64(out, x);
            }
        }
    }
}

// ---------------------------------------------------------------------
// decoding
// ---------------------------------------------------------------------

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

/// Fixed-size copy of a slice whose length was already checked by the
/// caller (`take(N)` / manual bounds check). Centralizes the
/// `try_into` so the decoding paths stay free of unwraps.
fn arr<const N: usize>(s: &[u8]) -> [u8; N] {
    let mut out = [0u8; N];
    out.copy_from_slice(&s[..N]);
    out
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], CheckpointError> {
        if n > self.b.len() - self.i {
            return Err(CheckpointError::Truncated {
                what,
                needed: n,
                available: self.b.len() - self.i,
            });
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, CheckpointError> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes(arr(s)))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, CheckpointError> {
        let s = self.take(8, what)?;
        Ok(u64::from_le_bytes(arr(s)))
    }

    fn f64(&mut self, what: &'static str) -> Result<f64, CheckpointError> {
        let s = self.take(8, what)?;
        Ok(f64::from_le_bytes(arr(s)))
    }

    fn string(&mut self, what: &'static str) -> Result<String, CheckpointError> {
        let n = self.u32(what)? as usize;
        let s = self.take(n, what)?;
        String::from_utf8(s.to_vec()).map_err(|e| CheckpointError::BadField {
            what,
            detail: format!("invalid UTF-8: {e}"),
        })
    }

    fn byte_len(n: usize, width: usize, what: &'static str) -> Result<usize, CheckpointError> {
        n.checked_mul(width).ok_or_else(|| CheckpointError::BadField {
            what,
            detail: format!("element count {n} overflows"),
        })
    }

    fn f64_vec(&mut self, n: usize, what: &'static str) -> Result<Vec<f64>, CheckpointError> {
        let bytes = self.take(Self::byte_len(n, 8, what)?, what)?;
        Ok(bytes.chunks_exact(8).map(|c| f64::from_le_bytes(arr(c))).collect())
    }

    fn f32_vec_widened(
        &mut self,
        n: usize,
        what: &'static str,
    ) -> Result<Vec<f64>, CheckpointError> {
        let bytes = self.take(Self::byte_len(n, 4, what)?, what)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(arr(c)) as f64)
            .collect())
    }
}

/// One decoded tensor record (data widened to f64).
struct Tensor {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
    dtype: u8,
}

fn expect_shape(
    t: Tensor,
    rows: usize,
    cols: usize,
    what: &'static str,
) -> Result<Tensor, CheckpointError> {
    if t.rows != rows || t.cols != cols {
        return Err(CheckpointError::BadField {
            what,
            detail: format!("shape {}x{} != expected {rows}x{cols}", t.rows, t.cols),
        });
    }
    Ok(t)
}

/// Decode f64-encoded indices back to `usize`, rejecting anything that
/// is not an exact non-negative integer below 2^53.
fn as_indices(xs: &[f64], what: &'static str) -> Result<Vec<usize>, CheckpointError> {
    const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
    xs.iter()
        .map(|&x| {
            if x.is_finite() && x >= 0.0 && x <= MAX_EXACT && x.fract() == 0.0 {
                Ok(x as usize)
            } else {
                Err(CheckpointError::BadField {
                    what,
                    detail: format!("{x} is not a valid index"),
                })
            }
        })
        .collect()
}

fn read_tensor(cur: &mut Cursor<'_>) -> Result<(String, Tensor), CheckpointError> {
    let name = cur.string("tensor name")?;
    let dtype = cur.take(1, "tensor dtype")?[0];
    let rows = cur.u64("tensor rows")? as usize;
    let cols = cur.u64("tensor cols")? as usize;
    let n = rows.checked_mul(cols).ok_or_else(|| CheckpointError::BadField {
        what: "tensor shape",
        detail: format!("{name}: {rows} x {cols} overflows"),
    })?;
    let data = match dtype {
        DTYPE_F64 => cur.f64_vec(n, "tensor data")?,
        DTYPE_F32 => cur.f32_vec_widened(n, "tensor data")?,
        other => {
            return Err(CheckpointError::BadField {
                what: "tensor dtype",
                detail: format!("{name}: unknown dtype tag {other}"),
            })
        }
    };
    Ok((name, Tensor { rows, cols, data, dtype }))
}

impl TrainedModel {
    /// Serialize to the versioned binary checkpoint format (including
    /// the trailing checksum). The inverse of [`TrainedModel::from_bytes`].
    /// Panics if the model's tensor shapes are internally inconsistent;
    /// [`TrainedModel::save`] validates first and returns a typed error
    /// instead.
    pub fn to_bytes(&self) -> Vec<u8> {
        let state_dtype = match self.precision {
            Precision::F64 => DTYPE_F64,
            Precision::F32 => DTYPE_F32,
        };
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        put_u32(&mut out, VERSION);
        out.push(state_dtype);
        out.push(match self.time_op {
            TimeOpPath::Dense => TIME_OP_DENSE,
            TimeOpPath::Toeplitz => TIME_OP_TOEPLITZ,
        });
        out.push(match self.projection {
            ProjectionPath::Mask => PROJ_MASK,
            ProjectionPath::Interp(InterpDegree::Linear) => PROJ_INTERP_LINEAR,
            ProjectionPath::Interp(InterpDegree::Cubic) => PROJ_INTERP_CUBIC,
        });
        out.push(0u8);
        put_u64(&mut out, self.p() as u64);
        put_u64(&mut out, self.q() as u64);
        put_u64(&mut out, self.ds as u64);
        put_u64(&mut out, self.n_samples as u64);
        put_f64(&mut out, self.log_sigma2);
        put_f64(&mut out, self.y_mean);
        put_f64(&mut out, self.y_std);
        put_str(&mut out, &self.time_family);
        put_str(&mut out, &self.name);
        put_u32(&mut out, self.theta.len() as u32);
        for &x in &self.theta {
            put_f64(&mut out, x);
        }
        let pq = self.grid_len();
        let n_tensors = 8 + if self.w.is_some() { 3 } else { 0 };
        put_u32(&mut out, n_tensors); // tensor count
        put_tensor(&mut out, "s", self.p(), self.ds, &self.s.data, DTYPE_F64);
        put_tensor(&mut out, "t", 1, self.q(), &self.t, DTYPE_F64);
        put_tensor(&mut out, "mask", 1, pq, &self.mask, DTYPE_F64);
        put_tensor(&mut out, "masked_alpha", 1, pq, &self.masked_alpha, state_dtype);
        put_tensor(&mut out, "vm", self.n_samples, pq, &self.vm.data, state_dtype);
        put_tensor(&mut out, "f_prior", self.n_samples, pq, &self.f_prior.data, state_dtype);
        put_tensor(&mut out, "post_mean", 1, pq, &self.posterior.mean, DTYPE_F64);
        put_tensor(&mut out, "post_var", 1, pq, &self.posterior.var, DTYPE_F64);
        if let Some(w) = &self.w {
            // indices as exact f64 integers: lossless below 2^53
            let indptr: Vec<f64> = w.indptr().iter().map(|&i| i as f64).collect();
            let cols: Vec<f64> = w.cols().iter().map(|&c| c as f64).collect();
            put_tensor(&mut out, "w_indptr", 1, indptr.len(), &indptr, DTYPE_F64);
            put_tensor(&mut out, "w_cols", 1, cols.len(), &cols, DTYPE_F64);
            put_tensor(&mut out, "w_weights", 1, w.nnz(), w.row_weights(), DTYPE_F64);
        }
        let sum = fnv64(&out);
        put_u64(&mut out, sum);
        out
    }

    /// Decode a checkpoint from bytes, verifying magic, version, and
    /// checksum, and validating every shape. All failure modes return a
    /// typed [`CheckpointError`].
    pub fn from_bytes(bytes: &[u8]) -> Result<TrainedModel, CheckpointError> {
        // smallest conceivable checkpoint: magic + version + trailer
        if bytes.len() < MAGIC.len() + 4 + 8 {
            return Err(CheckpointError::Truncated {
                what: "file header",
                needed: MAGIC.len() + 4 + 8,
                available: bytes.len(),
            });
        }
        if bytes[..8] != MAGIC {
            let mut found = [0u8; 8];
            found.copy_from_slice(&bytes[..8]);
            return Err(CheckpointError::BadMagic { found });
        }
        let version = u32::from_le_bytes(arr(&bytes[8..12]));
        if !(MIN_VERSION..=VERSION).contains(&version) {
            return Err(CheckpointError::UnsupportedVersion { found: version, supported: VERSION });
        }
        let body = &bytes[..bytes.len() - 8];
        let stored = u64::from_le_bytes(arr(&bytes[bytes.len() - 8..]));
        let computed = fnv64(body);
        if stored != computed {
            return Err(CheckpointError::ChecksumMismatch { stored, computed });
        }

        let mut cur = Cursor { b: body, i: 12 };
        let flags = cur.take(4, "precision")?;
        let precision = match flags[0] {
            DTYPE_F64 => Precision::F64,
            DTYPE_F32 => Precision::F32,
            other => {
                return Err(CheckpointError::BadField {
                    what: "precision",
                    detail: format!("unknown precision tag {other}"),
                })
            }
        };
        let time_op = match flags[1] {
            TIME_OP_DENSE => TimeOpPath::Dense,
            TIME_OP_TOEPLITZ => TimeOpPath::Toeplitz,
            other => {
                return Err(CheckpointError::BadField {
                    what: "time_op",
                    detail: format!("unknown time-op tag {other}"),
                })
            }
        };
        // the projection byte is reserved zero in version 2, so the
        // (version, tag) pair decodes uniformly: any nonzero tag in a
        // v2 file is malformed, as is an unknown tag in a v3 file
        let projection = match (version, flags[2]) {
            (_, PROJ_MASK) => ProjectionPath::Mask,
            (3, PROJ_INTERP_LINEAR) => ProjectionPath::Interp(InterpDegree::Linear),
            (3, PROJ_INTERP_CUBIC) => ProjectionPath::Interp(InterpDegree::Cubic),
            (_, other) => {
                return Err(CheckpointError::BadField {
                    what: "projection",
                    detail: format!("unknown projection tag {other} (version {version})"),
                })
            }
        };
        let p = cur.u64("p")? as usize;
        let q = cur.u64("q")? as usize;
        let ds = cur.u64("ds")? as usize;
        let n_samples = cur.u64("n_samples")? as usize;
        let log_sigma2 = cur.f64("log_sigma2")?;
        let y_mean = cur.f64("y_mean")?;
        let y_std = cur.f64("y_std")?;
        let time_family = cur.string("time_family")?;
        let name = cur.string("name")?;
        let n_theta = cur.u32("theta count")? as usize;
        let theta = cur.f64_vec(n_theta, "theta")?;

        let n_tensors = cur.u32("tensor count")? as usize;
        // the projection tag pins the exact tensor count (8 for mask,
        // 11 for interp); checking before allocating keeps a crafted
        // count from forcing a huge reservation
        let expect_tensors = match projection {
            ProjectionPath::Mask => 8,
            ProjectionPath::Interp(_) => 11,
        };
        if n_tensors != expect_tensors {
            return Err(CheckpointError::BadField {
                what: "tensor count",
                detail: format!(
                    "{n_tensors} != {expect_tensors} (version {version}, {projection} projection)"
                ),
            });
        }
        let mut tensors: Vec<(String, Tensor)> = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            tensors.push(read_tensor(&mut cur)?);
        }
        if cur.i != body.len() {
            return Err(CheckpointError::BadField {
                what: "trailer",
                detail: format!("{} unparsed bytes before checksum", body.len() - cur.i),
            });
        }
        let mut take = |want: &'static str| -> Result<Tensor, CheckpointError> {
            let pos = tensors.iter().position(|(n, _)| n == want).ok_or_else(|| {
                CheckpointError::BadField {
                    what: "tensor directory",
                    detail: format!("missing tensor {want:?}"),
                }
            })?;
            Ok(tensors.remove(pos).1)
        };
        let pq = p.checked_mul(q).ok_or_else(|| CheckpointError::BadField {
            what: "header",
            detail: format!("p * q overflows ({p} x {q})"),
        })?;
        let s = expect_shape(take("s")?, p, ds, "s")?;
        let t = expect_shape(take("t")?, 1, q, "t")?;
        let mask = expect_shape(take("mask")?, 1, pq, "mask")?;
        let masked_alpha = expect_shape(take("masked_alpha")?, 1, pq, "masked_alpha")?;
        let vm = expect_shape(take("vm")?, n_samples, pq, "vm")?;
        let f_prior = expect_shape(take("f_prior")?, n_samples, pq, "f_prior")?;
        let post_mean = expect_shape(take("post_mean")?, 1, pq, "post_mean")?;
        let post_var = expect_shape(take("post_var")?, 1, pq, "post_var")?;
        let w = match projection {
            ProjectionPath::Mask => None,
            ProjectionPath::Interp(degree) => {
                let wi = take("w_indptr")?;
                let wc = take("w_cols")?;
                let ww = take("w_weights")?;
                for (t, label) in [(&wi, "w_indptr"), (&wc, "w_cols"), (&ww, "w_weights")] {
                    if t.dtype != DTYPE_F64 {
                        return Err(CheckpointError::BadField {
                            what: "w",
                            detail: format!("{label} must be f64, got dtype tag {}", t.dtype),
                        });
                    }
                    if t.rows != 1 {
                        return Err(CheckpointError::BadField {
                            what: "w",
                            detail: format!("{label} must be a row vector, got {} rows", t.rows),
                        });
                    }
                }
                if wi.cols < 2 {
                    return Err(CheckpointError::BadField {
                        what: "w",
                        detail: format!("w_indptr has {} entries, need at least 2", wi.cols),
                    });
                }
                let indptr = as_indices(&wi.data, "w_indptr")?;
                let cols = as_indices(&wc.data, "w_cols")?;
                let n = indptr.len() - 1;
                // from_parts re-validates every CSR invariant (monotone
                // indptr, per-row support bounds, in-grid columns,
                // finite weights) so a shape-lying record cannot build
                let proj =
                    SparseProjection::from_parts(n, p, q, degree, indptr, cols, ww.data)
                        .map_err(|detail| CheckpointError::BadField { what: "w", detail })?;
                Some(proj)
            }
        };
        if let Some((extra, _)) = tensors.first() {
            return Err(CheckpointError::BadField {
                what: "tensor directory",
                detail: format!("unknown tensor {extra:?} (version {VERSION} reader)"),
            });
        }
        let state_dtype = match precision {
            Precision::F64 => DTYPE_F64,
            Precision::F32 => DTYPE_F32,
        };
        let state_tensors = [(&masked_alpha, "masked_alpha"), (&vm, "vm"), (&f_prior, "f_prior")];
        for (tensor, label) in state_tensors {
            if tensor.dtype != state_dtype {
                return Err(CheckpointError::BadField {
                    what: "tensor dtype",
                    detail: format!(
                        "{label} stored as dtype {} but header precision implies {}",
                        tensor.dtype, state_dtype
                    ),
                });
            }
        }

        let model = TrainedModel {
            name,
            time_family,
            precision,
            time_op,
            projection,
            w,
            ds,
            s: Matrix::from_vec(p, ds, s.data),
            t: t.data,
            mask: mask.data,
            theta,
            log_sigma2,
            y_mean,
            y_std,
            n_samples,
            masked_alpha: masked_alpha.data,
            vm: Matrix::from_vec(n_samples, pq, vm.data),
            f_prior: Matrix::from_vec(n_samples, pq, f_prior.data),
            posterior: Posterior { mean: post_mean.data, var: post_var.data },
        };
        model.validate()?;
        Ok(model)
    }

    /// Write the checkpoint to `path`, returning the byte count. The
    /// model is validated first, so an internally inconsistent one
    /// fails with a typed [`CheckpointError`] instead of writing a
    /// broken file. The write is crash-safe: bytes land in a sibling
    /// temp file that is renamed over `path` only once complete, so an
    /// interrupted save never destroys a previous good checkpoint.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<u64> {
        let path = path.as_ref();
        self.validate().map_err(anyhow::Error::new)?;
        let mut bytes = self.to_bytes();
        // fault injection: simulate a torn (half-written) file or a
        // storage bit flip between encode and write — the reader must
        // reject both with a typed error (see rust/tests/faults.rs)
        match crate::util::failpoint::check("ckpt_write") {
            Some(crate::util::failpoint::FaultAction::Torn) => bytes.truncate(bytes.len() / 2),
            Some(crate::util::failpoint::FaultAction::BitFlip) => {
                let mid = bytes.len() / 2;
                bytes[mid] ^= 0x01;
            }
            _ => {}
        }
        let mut tmp_name = path.as_os_str().to_owned();
        tmp_name.push(format!(".tmp.{}", std::process::id()));
        let tmp = std::path::PathBuf::from(tmp_name);
        std::fs::write(&tmp, &bytes)
            .with_context(|| format!("writing checkpoint temp file {}", tmp.display()))?;
        if let Err(e) = std::fs::rename(&tmp, path) {
            std::fs::remove_file(&tmp).ok();
            return Err(anyhow::Error::new(e))
                .with_context(|| format!("renaming checkpoint into place at {}", path.display()));
        }
        Ok(bytes.len() as u64)
    }

    /// Read a checkpoint from `path`. Format failures carry a typed
    /// [`CheckpointError`] in the error chain (use
    /// `err.downcast_ref::<CheckpointError>()` to inspect them).
    pub fn load(path: impl AsRef<Path>) -> Result<TrainedModel> {
        let path = path.as_ref();
        let mut bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        // fault injection: simulate a short read or in-transit bit flip
        match crate::util::failpoint::check("ckpt_read") {
            Some(crate::util::failpoint::FaultAction::Short) => bytes.truncate(bytes.len() / 2),
            Some(crate::util::failpoint::FaultAction::BitFlip) if !bytes.is_empty() => {
                let mid = bytes.len() / 2;
                bytes[mid] ^= 0x01;
            }
            _ => {}
        }
        TrainedModel::from_bytes(&bytes)
            .map_err(anyhow::Error::new)
            .with_context(|| format!("decoding checkpoint {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny but fully consistent model for codec tests.
    pub(crate) fn dummy_model(precision: Precision) -> TrainedModel {
        let (p, q, ds, n) = (3usize, 2usize, 2usize, 2usize);
        let pq = p * q;
        let narrowed = |xs: Vec<f64>| -> Vec<f64> {
            match precision {
                Precision::F64 => xs,
                Precision::F32 => xs.iter().map(|&x| convert::f32_of(x) as f64).collect(),
            }
        };
        TrainedModel {
            name: "dummy".into(),
            time_family: "rbf".into(),
            precision,
            time_op: TimeOpPath::Dense,
            projection: ProjectionPath::Mask,
            w: None,
            ds,
            s: Matrix::from_vec(p, ds, (0..p * ds).map(|i| i as f64 * 0.25).collect()),
            t: (0..q).map(|k| k as f64).collect(),
            mask: (0..pq).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect(),
            theta: vec![0.1, -0.2, 0.3, 0.05],
            log_sigma2: -1.5,
            y_mean: 0.7,
            y_std: 1.3,
            n_samples: n,
            masked_alpha: narrowed((0..pq).map(|i| (i as f64).sin()).collect()),
            vm: Matrix::from_vec(n, pq, narrowed((0..n * pq).map(|i| (i as f64).cos()).collect())),
            f_prior: Matrix::from_vec(
                n,
                pq,
                narrowed((0..n * pq).map(|i| 0.01 * i as f64).collect()),
            ),
            posterior: Posterior {
                mean: (0..pq).map(|i| i as f64 * 0.5).collect(),
                var: (0..pq).map(|i| 1.0 + i as f64 * 0.1).collect(),
            },
        }
    }

    /// A fully consistent interp-projection (SKI) model: 1-D node axis
    /// of length p, W built from off-grid points, grid-space state.
    pub(crate) fn dummy_interp_model(degree: InterpDegree) -> TrainedModel {
        let mut m = dummy_model(Precision::F64);
        let p = m.p();
        // interp needs ds == 1 with the nodes as the spatial axis
        m.ds = 1;
        m.s = Matrix::from_vec(p, 1, (0..p).map(|j| j as f64).collect());
        let kernel = crate::kernels::ProductGridKernel::new(1, &m.time_family, m.q());
        m.theta.truncate(kernel.n_theta());
        let xs = vec![0.25, 1.5, 1.75, 0.0];
        let xt = vec![0.5, 0.25, 1.0, 0.75];
        let w = SparseProjection::build(&xs, &xt, &m.s.data, &m.t, degree).unwrap();
        m.projection = ProjectionPath::Interp(degree);
        m.w = Some(w);
        m.validate().unwrap();
        m
    }

    fn assert_models_bit_equal(a: &TrainedModel, b: &TrainedModel) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.time_family, b.time_family);
        assert_eq!(a.precision, b.precision);
        assert_eq!(a.time_op, b.time_op);
        assert_eq!(a.projection, b.projection);
        assert_eq!(a.w, b.w);
        assert_eq!((a.p(), a.q(), a.ds, a.n_samples), (b.p(), b.q(), b.ds, b.n_samples));
        let bits = |xs: &[f64]| -> Vec<u64> { xs.iter().map(|x| x.to_bits()).collect() };
        assert_eq!(bits(&a.s.data), bits(&b.s.data));
        assert_eq!(bits(&a.t), bits(&b.t));
        assert_eq!(bits(&a.mask), bits(&b.mask));
        assert_eq!(bits(&a.theta), bits(&b.theta));
        assert_eq!(a.log_sigma2.to_bits(), b.log_sigma2.to_bits());
        assert_eq!(a.y_mean.to_bits(), b.y_mean.to_bits());
        assert_eq!(a.y_std.to_bits(), b.y_std.to_bits());
        assert_eq!(bits(&a.masked_alpha), bits(&b.masked_alpha));
        assert_eq!(bits(&a.vm.data), bits(&b.vm.data));
        assert_eq!(bits(&a.f_prior.data), bits(&b.f_prior.data));
        assert_eq!(bits(&a.posterior.mean), bits(&b.posterior.mean));
        assert_eq!(bits(&a.posterior.var), bits(&b.posterior.var));
    }

    #[test]
    fn roundtrip_f64_is_bit_exact() {
        let m = dummy_model(Precision::F64);
        let bytes = m.to_bytes();
        let back = TrainedModel::from_bytes(&bytes).unwrap();
        assert_models_bit_equal(&m, &back);
    }

    #[test]
    fn roundtrip_f32_is_bit_exact_and_smaller() {
        // values already representable in f32, so narrow-on-write /
        // widen-on-read is lossless — and the state tensors shrink
        let m32 = dummy_model(Precision::F32);
        let m64 = dummy_model(Precision::F64);
        let bytes = m32.to_bytes();
        assert!(bytes.len() < m64.to_bytes().len());
        let back = TrainedModel::from_bytes(&bytes).unwrap();
        assert_models_bit_equal(&m32, &back);
    }

    #[test]
    fn interp_w_record_roundtrips_bitwise() {
        for degree in [InterpDegree::Linear, InterpDegree::Cubic] {
            let m = dummy_interp_model(degree);
            let bytes = m.to_bytes();
            let tag = match degree {
                InterpDegree::Linear => PROJ_INTERP_LINEAR,
                InterpDegree::Cubic => PROJ_INTERP_CUBIC,
            };
            assert_eq!(bytes[14], tag, "projection tag lives at offset 14");
            let back = TrainedModel::from_bytes(&bytes).unwrap();
            assert_models_bit_equal(&m, &back);
        }
    }

    #[test]
    fn version_2_mask_files_still_load() {
        // a v2 file is a v3 mask file with the older version stamp (the
        // projection byte was reserved zero); rewriting the version and
        // re-stamping the checksum reproduces one byte for byte
        let m = dummy_model(Precision::F64);
        let mut bytes = m.to_bytes();
        bytes[8..12].copy_from_slice(&2u32.to_le_bytes());
        let n = bytes.len();
        let sum = fnv64(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let back = TrainedModel::from_bytes(&bytes).unwrap();
        assert_models_bit_equal(&m, &back);
        assert_eq!(back.projection, ProjectionPath::Mask);
        assert!(back.w.is_none());
    }

    #[test]
    fn unknown_projection_tag_is_typed() {
        // tag 9 is undefined in any version; tag 1 is defined only in v3
        for (version, tag) in [(3u32, 9u8), (2u32, 1u8)] {
            let mut bytes = dummy_model(Precision::F64).to_bytes();
            bytes[8..12].copy_from_slice(&version.to_le_bytes());
            bytes[14] = tag;
            let n = bytes.len();
            let sum = fnv64(&bytes[..n - 8]);
            bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
            match TrainedModel::from_bytes(&bytes) {
                Err(CheckpointError::BadField { what: "projection", detail }) => {
                    assert!(detail.contains(&tag.to_string()), "{detail}");
                }
                other => panic!("expected BadField for projection, got {other:?}"),
            }
        }
    }

    #[test]
    fn shape_lying_w_records_are_rejected() {
        // a non-integer column index must fail the typed index decode
        let m = dummy_interp_model(InterpDegree::Linear);
        let mut bad = m.clone();
        let w = bad.w.as_ref().unwrap();
        let (n, p, q) = (w.n(), w.grid_p(), w.grid_q());
        let mut indptr = w.indptr().to_vec();
        let cols = w.cols().to_vec();
        let weights = w.row_weights().to_vec();
        // lie about the row structure: last row claims more support
        // than the stencil allows
        *indptr.last_mut().unwrap() += 64;
        assert!(SparseProjection::from_parts(
            n,
            p,
            q,
            InterpDegree::Linear,
            indptr,
            cols,
            weights
        )
        .is_err());
        // and through the codec: corrupt the stored w_cols bytes into a
        // non-integer and re-stamp the checksum — typed BadField, not a
        // panic
        let bytes = m.to_bytes();
        let needle = (m.w.as_ref().unwrap().cols()[0] as f64).to_le_bytes();
        // find the w_cols record by its name marker, then its payload
        let marker = b"w_cols";
        let pos = bytes
            .windows(marker.len())
            .position(|wnd| wnd == marker)
            .expect("w_cols record present");
        let payload = pos + marker.len() + 1 + 16; // dtype + rows + cols
        assert_eq!(&bytes[payload..payload + 8], &needle);
        let mut bad_bytes = bytes.clone();
        bad_bytes[payload..payload + 8].copy_from_slice(&0.5f64.to_le_bytes());
        let nb = bad_bytes.len();
        let sum = fnv64(&bad_bytes[..nb - 8]);
        bad_bytes[nb - 8..].copy_from_slice(&sum.to_le_bytes());
        match TrainedModel::from_bytes(&bad_bytes) {
            Err(CheckpointError::BadField { what: "w_cols", detail }) => {
                assert!(detail.contains("0.5"), "{detail}");
            }
            other => panic!("expected BadField for w_cols, got {other:?}"),
        }
        // finally: drop the w tensors but keep the interp tag — the
        // tensor count check rejects before any allocation
        let mut bad2 = m.clone();
        bad2.w = None;
        assert!(matches!(
            bad2.validate(),
            Err(CheckpointError::BadField { what: "w", .. })
        ));
    }

    #[test]
    fn toeplitz_time_op_roundtrips() {
        let mut m = dummy_model(Precision::F64);
        m.time_op = TimeOpPath::Toeplitz;
        let bytes = m.to_bytes();
        assert_eq!(bytes[13], TIME_OP_TOEPLITZ, "time-op tag lives at offset 13");
        let back = TrainedModel::from_bytes(&bytes).unwrap();
        assert_models_bit_equal(&m, &back);
    }

    #[test]
    fn unknown_time_op_tag_is_typed() {
        let mut bytes = dummy_model(Precision::F64).to_bytes();
        bytes[13] = 7;
        let n = bytes.len();
        let sum = fnv64(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
        match TrainedModel::from_bytes(&bytes) {
            Err(CheckpointError::BadField { what: "time_op", detail }) => {
                assert!(detail.contains('7'), "{detail}");
            }
            other => panic!("expected BadField for time_op, got {other:?}"),
        }
    }

    #[test]
    fn bad_magic_is_typed() {
        let mut bytes = dummy_model(Precision::F64).to_bytes();
        bytes[0] = b'X';
        match TrainedModel::from_bytes(&bytes) {
            Err(CheckpointError::BadMagic { found }) => assert_eq!(found[0], b'X'),
            other => panic!("expected BadMagic, got {other:?}"),
        }
    }

    #[test]
    fn wrong_version_is_typed() {
        let mut bytes = dummy_model(Precision::F64).to_bytes();
        bytes[8] = 99;
        // version is checked before the checksum so an old reader gives
        // the actionable error even for a well-formed newer file
        let n = bytes.len();
        let sum = fnv64(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
        match TrainedModel::from_bytes(&bytes) {
            Err(CheckpointError::UnsupportedVersion { found: 99, supported: VERSION }) => {}
            other => panic!("expected UnsupportedVersion, got {other:?}"),
        }
    }

    #[test]
    fn flipped_byte_fails_checksum() {
        let mut bytes = dummy_model(Precision::F64).to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        match TrainedModel::from_bytes(&bytes) {
            Err(CheckpointError::ChecksumMismatch { .. }) => {}
            other => panic!("expected ChecksumMismatch, got {other:?}"),
        }
    }

    #[test]
    fn truncation_is_typed() {
        let bytes = dummy_model(Precision::F64).to_bytes();
        // below the minimum header size: reported as Truncated directly
        match TrainedModel::from_bytes(&bytes[..10]) {
            Err(CheckpointError::Truncated { what: "file header", .. }) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
        // mid-body truncation with a re-stamped checksum: the cursor
        // runs out while reading a field
        let cut = bytes.len() - 200;
        let mut short = bytes[..cut].to_vec();
        let sum = fnv64(&short);
        short.extend_from_slice(&sum.to_le_bytes());
        match TrainedModel::from_bytes(&short) {
            Err(CheckpointError::Truncated { .. }) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
    }

    #[test]
    fn shape_lies_are_rejected() {
        let mut m = dummy_model(Precision::F64);
        m.mask.pop();
        assert!(matches!(m.validate(), Err(CheckpointError::BadField { what: "mask", .. })));
        // save() validates before serializing: typed error, no file
        let path =
            std::env::temp_dir().join(format!("lkgp_io_badsave_{}.ckpt", std::process::id()));
        let err = m.save(&path).unwrap_err();
        assert!(err.downcast_ref::<CheckpointError>().is_some(), "{err:#}");
        assert!(!path.exists());
    }
}
