//! Persistent worker pool: long-lived parked workers behind the region
//! scheduler in [`super::region`].
//!
//! Workers are spawned lazily the first time a region needs them, grow
//! on demand up to the widest region ever requested, park on a condvar
//! when idle (after a short spin window, so back-to-back regions — the
//! CG iteration pattern — skip the futex round-trip entirely), and are
//! joined by [`shutdown`]. This replaces the PR-1 scoped-spawn design,
//! whose per-region `std::thread::scope` spawn/join cost tens of
//! microseconds and forced large sequential-fallback thresholds.
//!
//! A region is published as a [`Job`] with `helpers` claim slots; each
//! slot grants exactly one execution of the region body with a distinct
//! worker id in `1..=helpers`. The submitting thread always executes
//! slot 0 itself and, once its own share is done, *self-serves* any
//! slots no pool worker has picked up yet. Progress therefore never
//! depends on pool threads being awake, idle, or even existing — a
//! region racing [`shutdown`] simply degrades to sequential execution
//! instead of deadlocking, and concurrent regions from independent
//! threads (the `cargo test` harness) drain through the same queue.
//!
//! Memory safety: `Job::task` borrows a closure on the submitting
//! thread's stack with its lifetime erased. [`submit_and_run`] only
//! returns once `done == helpers`, i.e. after every claim's execution
//! has finished, so the borrow outlives every dereference; jobs left in
//! the queue after that are claim-exhausted and are discarded by the
//! next worker that sees them without touching `task`.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Spin iterations a worker burns watching for a new job before parking
/// on the condvar. Keeps back-to-back region dispatch in the
/// sub-microsecond range without pinning a CPU when the pool is idle.
const IDLE_SPIN: usize = 2_000;

/// Lock that survives poisoning: the pool mutexes only guard counters
/// and queue links that stay consistent across a caught task panic, and
/// pool bookkeeping must keep working after one (regions surface panics
/// as structured errors instead of poisoning the scheduler).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One published parallel region.
pub(crate) struct Job {
    /// Region body, invoked as `task(worker_id)`. Borrowed from the
    /// submitting thread's stack — see the module docs for the lifetime
    /// argument behind the `'static` erasure.
    task: &'static (dyn Fn(usize) + Sync),
    /// Number of claim slots (worker ids `1..=helpers`); the submitting
    /// thread runs id 0 without a claim.
    helpers: usize,
    /// Claims handed out so far. Monotone and may overshoot `helpers`:
    /// executors that draw a slot `> helpers` simply back off.
    claims: AtomicUsize,
    /// Executed claims; the submitting thread blocks until this reaches
    /// `helpers`.
    done: Mutex<usize>,
    done_cv: Condvar,
}

impl Job {
    /// Draw the next claim slot, or `None` when all are taken.
    fn claim(&self) -> Option<usize> {
        let c = self.claims.fetch_add(1, Ordering::Relaxed);
        (c < self.helpers).then_some(c + 1)
    }

    fn exhausted(&self) -> bool {
        self.claims.load(Ordering::Relaxed) >= self.helpers
    }

    /// Run claim slot `wid` and mark it done. The region body already
    /// catches per-chunk panics; this outer net guarantees a missed
    /// unwind can never leave `done` short of `helpers`, which would
    /// deadlock the submitting thread.
    fn run_claim(&self, wid: usize) {
        let _ = catch_unwind(AssertUnwindSafe(|| (self.task)(wid)));
        let mut d = lock(&self.done);
        *d += 1;
        if *d == self.helpers {
            self.done_cv.notify_all();
        }
    }
}

struct Queue {
    jobs: VecDeque<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    work_cv: Condvar,
    /// Bumped on every publish (and on shutdown); the worker spin
    /// window watches it so freshly idle workers catch the next region
    /// without a condvar wait.
    seq: AtomicU64,
}

pub(crate) struct Pool {
    shared: Arc<Shared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

static POOL: Mutex<Option<Arc<Pool>>> = Mutex::new(None);
static WORKERS_SPAWNED: AtomicU64 = AtomicU64::new(0);
static WORKERS_LIVE: AtomicUsize = AtomicUsize::new(0);

/// Total pool worker threads ever spawned (across shutdown/re-init).
pub(crate) fn workers_spawned() -> u64 {
    WORKERS_SPAWNED.load(Ordering::Relaxed)
}

/// Pool worker threads currently alive (parked or running).
pub(crate) fn workers_live() -> usize {
    WORKERS_LIVE.load(Ordering::Relaxed)
}

fn pool() -> Arc<Pool> {
    let mut g = lock(&POOL);
    g.get_or_insert_with(|| {
        Arc::new(Pool {
            shared: Arc::new(Shared {
                queue: Mutex::new(Queue { jobs: VecDeque::new(), shutdown: false }),
                work_cv: Condvar::new(),
                seq: AtomicU64::new(0),
            }),
            handles: Mutex::new(Vec::new()),
        })
    })
    .clone()
}

impl Pool {
    /// Grow the pool to at least `want` workers. Spawn failure is not
    /// fatal: `submit_and_run` self-serves whatever workers cannot take.
    fn ensure_workers(&self, want: usize) {
        let mut h = lock(&self.handles);
        while h.len() < want {
            let shared = self.shared.clone();
            let name = format!("lkgp-par-{}", h.len() + 1);
            match std::thread::Builder::new().name(name).spawn(move || worker_loop(shared)) {
                Ok(handle) => {
                    WORKERS_SPAWNED.fetch_add(1, Ordering::Relaxed);
                    h.push(handle);
                }
                Err(_) => break,
            }
        }
    }
}

/// Pop the next claim from the queue front, discarding jobs whose
/// claims were already exhausted (e.g. fully self-served by their
/// submitter before any worker woke up).
fn next_claim(q: &mut Queue) -> Option<(Arc<Job>, usize)> {
    while let Some(front) = q.jobs.front() {
        if let Some(wid) = front.claim() {
            let job = front.clone();
            if job.exhausted() {
                q.jobs.pop_front();
            }
            return Some((job, wid));
        }
        q.jobs.pop_front();
    }
    None
}

fn worker_loop(shared: Arc<Shared>) {
    // nested regions issued from inside a task collapse to inline runs
    super::mark_pool_worker();
    WORKERS_LIVE.fetch_add(1, Ordering::Relaxed);
    let mut q = lock(&shared.queue);
    loop {
        // drain claimable work before honoring shutdown, so a shutdown
        // never strands a published region mid-flight
        if let Some((job, wid)) = next_claim(&mut q) {
            drop(q);
            job.run_claim(wid);
            q = lock(&shared.queue);
            continue;
        }
        if q.shutdown {
            break;
        }
        let seen = shared.seq.load(Ordering::Acquire);
        drop(q);
        let mut woke = false;
        for _ in 0..IDLE_SPIN {
            if shared.seq.load(Ordering::Acquire) != seen {
                woke = true;
                break;
            }
            std::hint::spin_loop();
        }
        q = lock(&shared.queue);
        if !woke && q.jobs.is_empty() && !q.shutdown {
            q = shared.work_cv.wait(q).unwrap_or_else(|e| e.into_inner());
        }
    }
    drop(q);
    WORKERS_LIVE.fetch_sub(1, Ordering::Relaxed);
}

/// Publish a region with `helpers` claim slots and run it to
/// completion: the calling thread executes slot 0, pool workers (and,
/// for any slot still unclaimed once the caller is free, the caller
/// itself) execute slots `1..=helpers`. Returns only after every slot
/// has finished executing.
pub(crate) fn submit_and_run(helpers: usize, body: &(dyn Fn(usize) + Sync)) {
    if helpers == 0 {
        body(0);
        return;
    }
    let pool = pool();
    pool.ensure_workers(helpers);
    // SAFETY: pure lifetime erasure on a fat reference. The job only
    // dereferences `task` between a successful claim and the matching
    // `done` increment, and this function blocks below until
    // `done == helpers` — after which no dereference can happen — so
    // the borrow outlives every use.
    let task = unsafe {
        std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(body)
    };
    let job = Arc::new(Job {
        task,
        helpers,
        claims: AtomicUsize::new(0),
        done: Mutex::new(0),
        done_cv: Condvar::new(),
    });
    {
        let mut q = lock(&pool.shared.queue);
        q.jobs.push_back(job.clone());
    }
    pool.shared.seq.fetch_add(1, Ordering::Release);
    // wake at most `helpers` workers, not the whole herd: a lost
    // notify_one is harmless because parking workers re-check the queue
    // under the lock first, and spinners watch `seq`
    for _ in 0..helpers {
        pool.shared.work_cv.notify_one();
    }
    // slot 0: the submitting thread is always a region worker. The
    // region body never unwinds by contract (per-chunk catch_unwind in
    // region.rs), but a catch here makes the memory-safety argument
    // unconditional: the done-wait below always runs before this frame
    // — which `task` borrows from — can be popped.
    let unwind = catch_unwind(AssertUnwindSafe(|| body(0)));
    // self-serve whatever no pool worker has claimed yet: completion
    // never depends on worker availability, so dispatch cannot deadlock
    while let Some(wid) = job.claim() {
        job.run_claim(wid);
    }
    let mut d = lock(&job.done);
    while *d < job.helpers {
        d = job.done_cv.wait(d).unwrap_or_else(|e| e.into_inner());
    }
    drop(d);
    if let Err(p) = unwind {
        std::panic::resume_unwind(p);
    }
}

/// Join every pool worker and reset the global pool to its
/// lazily-initialized state; the next region transparently restarts
/// it. In-flight regions finish first: workers drain the queue before
/// honoring the flag, and submitters self-serve any slots workers no
/// longer pick up, so shutdown can never deadlock a region. When
/// called from inside a region task on a pool worker, joining would
/// self-deadlock — the handles are detached instead and the workers
/// exit on their own after draining.
pub(crate) fn shutdown() {
    let pool = lock(&POOL).take();
    let Some(pool) = pool else { return };
    {
        let mut q = lock(&pool.shared.queue);
        q.shutdown = true;
    }
    pool.shared.seq.fetch_add(1, Ordering::Release);
    pool.shared.work_cv.notify_all();
    let handles = std::mem::take(&mut *lock(&pool.handles));
    if super::in_pool_worker() {
        return; // dropping the handles detaches the exiting workers
    }
    for h in handles {
        let _ = h.join();
    }
}
