//! Dependency-free data-parallel compute subsystem.
//!
//! A scoped worker pool (`std::thread::scope`) behind a global
//! [`Parallelism`] configuration: the thread count comes from the
//! `LKGP_THREADS` environment variable (read once, at first use),
//! defaulting to the number of available cores; [`set_threads`]
//! overrides it process-wide and [`with_threads`] overrides it for one
//! scope on the calling thread.
//!
//! Every helper splits work over *disjoint* output chunks whose
//! boundaries depend only on the problem shape (never on the thread
//! count), and each chunk is written by exactly one worker with a fixed
//! sequential reduction order. Parallel results are therefore
//! **bit-identical for any thread count** — the invariant the whole
//! inference hot path relies on, asserted end-to-end by
//! `rust/tests/par_invariance.rs`.
//!
//! Nested parallel regions collapse: work spawned from inside a pool
//! worker runs inline on that worker. This prevents oversubscription
//! (e.g. a batched Kron MVM parallelized over batch rows calling the
//! parallel GEMM per row) while letting single-row calls still fan out
//! at the inner level.
//!
//! The heaviest client is the register-tiled GEMM (`linalg::gemm`),
//! which dispatches MC-row blocks of C through [`par_chunks_mut`]; the
//! kernel Gram distance/exp post-pass and the dense-baseline Gram
//! assembly ride the same pool via [`par_chunks_mut_cheap`].

use std::cell::Cell;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide thread-count override (0 = derive from the environment
/// on first use).
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread override installed by [`with_threads`] (0 = unset).
    static TL_THREADS: Cell<usize> = Cell::new(0);
    /// True while the current thread is executing inside a pool worker.
    static IN_POOL: Cell<bool> = Cell::new(false);
}

/// Snapshot of the effective parallelism configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    /// Worker threads a new parallel region may use.
    pub threads: usize,
}

impl Parallelism {
    /// Resolve the currently effective configuration: a [`with_threads`]
    /// scope wins over [`set_threads`], which wins over `LKGP_THREADS`,
    /// which wins over the detected core count.
    pub fn current() -> Self {
        Parallelism { threads: num_threads() }
    }
}

fn detected_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn env_threads() -> usize {
    match std::env::var("LKGP_THREADS") {
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => detected_cores(),
        },
        Err(_) => detected_cores(),
    }
}

/// Effective worker count for new parallel regions on this thread.
pub fn num_threads() -> usize {
    let tl = TL_THREADS.with(|c| c.get());
    if tl != 0 {
        return tl;
    }
    let g = GLOBAL_THREADS.load(Ordering::Relaxed);
    if g != 0 {
        return g;
    }
    let n = env_threads();
    GLOBAL_THREADS.store(n, Ordering::Relaxed);
    n
}

/// Set the process-wide thread count (overrides `LKGP_THREADS`).
pub fn set_threads(n: usize) {
    GLOBAL_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Run `f` with the calling thread's parallelism pinned to `n` —
/// a scoped override used by benches and the invariance tests. The
/// previous value is restored even if `f` panics.
pub fn with_threads<T>(n: usize, f: impl FnOnce() -> T) -> T {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0;
            TL_THREADS.with(|c| c.set(prev));
        }
    }
    let prev = TL_THREADS.with(|c| {
        let p = c.get();
        c.set(n.max(1));
        p
    });
    let _restore = Restore(prev);
    f()
}

/// RAII marker: the current thread is a pool worker, so nested parallel
/// regions must run inline.
struct PoolGuard {
    prev: bool,
}

impl PoolGuard {
    fn enter() -> Self {
        let prev = IN_POOL.with(|c| {
            let p = c.get();
            c.set(true);
            p
        });
        PoolGuard { prev }
    }
}

impl Drop for PoolGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_POOL.with(|c| c.set(prev));
    }
}

/// Worker count for a region with `work_items` independent items:
/// 1 inside an existing pool worker (no nesting), otherwise
/// `min(num_threads(), work_items)`.
fn pool_width(work_items: usize) -> usize {
    if work_items <= 1 || IN_POOL.with(|c| c.get()) {
        1
    } else {
        num_threads().min(work_items)
    }
}

/// Run `f(worker)` on `nt` workers; worker 0 runs on the calling thread.
fn run_pool<F: Fn(usize) + Sync>(nt: usize, f: F) {
    if nt <= 1 {
        f(0);
        return;
    }
    std::thread::scope(|s| {
        for w in 1..nt {
            let fr = &f;
            s.spawn(move || {
                let _in_pool = PoolGuard::enter();
                fr(w);
            });
        }
        let _in_pool = PoolGuard::enter();
        f(0);
    });
}

/// Split `0..n` into one contiguous range per worker and run `f` on each
/// range in parallel. The range boundaries depend on the thread count,
/// so `f` must compute each index independently (no cross-index
/// accumulation) for results to stay thread-count invariant.
pub fn par_rows<F>(n: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    let nt = pool_width(n);
    if nt <= 1 {
        if n > 0 {
            f(0..n);
        }
        return;
    }
    let per = (n + nt - 1) / nt;
    run_pool(nt, |w| {
        let lo = w * per;
        let hi = ((w + 1) * per).min(n);
        if lo < hi {
            f(lo..hi);
        }
    });
}

/// Below this many total elements, a cheap elementwise sweep is not
/// worth spawning for: thread spawn/join costs tens of microseconds
/// while the sweep costs nanoseconds per element. Only used by
/// [`par_chunks_mut_cheap`]; heavy per-element work (dot products, RNG
/// draws, GEMM blocks) should use [`par_chunks_mut`] directly.
pub const CHEAP_SWEEP_MIN: usize = 1 << 14;

/// Split `data` into contiguous segments of `per` whole chunks each,
/// tagged with the index of their first chunk. Shared by
/// [`par_chunks_mut`] / [`par_zip_mut`] so the chunk->segment mapping
/// cannot diverge between them.
fn split_segments<T>(data: &mut [T], chunk_len: usize, per: usize) -> Vec<(usize, &mut [T])> {
    let seg_elems = per * chunk_len;
    let mut segments = Vec::new();
    let mut rest = data;
    let mut chunk0 = 0usize;
    while !rest.is_empty() {
        let take = seg_elems.min(rest.len());
        let (seg, tail) = std::mem::take(&mut rest).split_at_mut(take);
        segments.push((chunk0, seg));
        rest = tail;
        chunk0 += per;
    }
    segments
}

/// Process disjoint `chunk_len`-sized chunks of `data` in parallel:
/// `f(chunk_index, chunk)`. Chunk boundaries depend only on `chunk_len`
/// (the tail chunk may be short) and each chunk is written by exactly
/// one worker, so output bits never depend on the thread count.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    assert!(chunk_len > 0, "chunk_len must be positive");
    let n_chunks = (data.len() + chunk_len - 1) / chunk_len;
    let nt = pool_width(n_chunks);
    if nt <= 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    // contiguous blocks of whole chunks per worker
    let per = (n_chunks + nt - 1) / nt;
    let segments = split_segments(data, chunk_len, per);
    std::thread::scope(|s| {
        let fr = &f;
        let mut iter = segments.into_iter();
        let head = iter.next();
        for (c0, seg) in iter {
            s.spawn(move || {
                let _in_pool = PoolGuard::enter();
                for (i, chunk) in seg.chunks_mut(chunk_len).enumerate() {
                    fr(c0 + i, chunk);
                }
            });
        }
        if let Some((c0, seg)) = head {
            let _in_pool = PoolGuard::enter();
            for (i, chunk) in seg.chunks_mut(chunk_len).enumerate() {
                fr(c0 + i, chunk);
            }
        }
    });
}

/// Like [`par_chunks_mut`] but stays sequential below
/// [`CHEAP_SWEEP_MIN`] total elements — for cheap elementwise sweeps
/// (mask multiplies, diagonal fills) where thread spawn/join would
/// dominate the work. The sequential and parallel paths are bit-exact
/// identical, so this is purely a scheduling decision.
pub fn par_chunks_mut_cheap<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.len() < CHEAP_SWEEP_MIN {
        if data.is_empty() {
            return;
        }
        assert!(chunk_len > 0, "chunk_len must be positive");
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    par_chunks_mut(data, chunk_len, f);
}

/// Like [`par_chunks_mut`] over two equal-length slices split at the
/// same chunk boundaries: `f(chunk_index, a_chunk, b_chunk)`.
pub fn par_zip_mut<A, B, F>(a: &mut [A], b: &mut [B], chunk_len: usize, f: F)
where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert_eq!(a.len(), b.len(), "par_zip_mut slices must have equal length");
    if a.is_empty() {
        return;
    }
    assert!(chunk_len > 0, "chunk_len must be positive");
    let n_chunks = (a.len() + chunk_len - 1) / chunk_len;
    let nt = pool_width(n_chunks);
    if nt <= 1 {
        for (i, (ca, cb)) in a.chunks_mut(chunk_len).zip(b.chunks_mut(chunk_len)).enumerate() {
            f(i, ca, cb);
        }
        return;
    }
    let per = (n_chunks + nt - 1) / nt;
    let seg_a = split_segments(a, chunk_len, per);
    let seg_b = split_segments(b, chunk_len, per);
    let segments: Vec<(usize, &mut [A], &mut [B])> = seg_a
        .into_iter()
        .zip(seg_b)
        .map(|((c0, sa), (_, sb))| (c0, sa, sb))
        .collect();
    std::thread::scope(|s| {
        let fr = &f;
        let mut iter = segments.into_iter();
        let head = iter.next();
        for (c0, sa, sb) in iter {
            s.spawn(move || {
                let _in_pool = PoolGuard::enter();
                for (i, (ca, cb)) in
                    sa.chunks_mut(chunk_len).zip(sb.chunks_mut(chunk_len)).enumerate()
                {
                    fr(c0 + i, ca, cb);
                }
            });
        }
        if let Some((c0, sa, sb)) = head {
            let _in_pool = PoolGuard::enter();
            for (i, (ca, cb)) in
                sa.chunks_mut(chunk_len).zip(sb.chunks_mut(chunk_len)).enumerate()
            {
                fr(c0 + i, ca, cb);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn with_threads_scopes_override() {
        let outside = num_threads();
        with_threads(3, || assert_eq!(num_threads(), 3));
        assert_eq!(num_threads(), outside);
    }

    #[test]
    fn parallelism_reports_current() {
        with_threads(5, || assert_eq!(Parallelism::current().threads, 5));
    }

    #[test]
    fn par_rows_covers_all_indices_once() {
        for &t in &[1usize, 2, 5] {
            with_threads(t, || {
                let n = 103;
                let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                par_rows(n, |range| {
                    for i in range {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
            });
        }
    }

    #[test]
    fn par_chunks_mut_indices_and_values() {
        for &t in &[1usize, 2, 8] {
            with_threads(t, || {
                let mut data = vec![0usize; 25];
                par_chunks_mut(&mut data, 4, |ci, chunk| {
                    for (off, x) in chunk.iter_mut().enumerate() {
                        *x = ci * 4 + off;
                    }
                });
                let want: Vec<usize> = (0..25).collect();
                assert_eq!(data, want);
            });
        }
    }

    #[test]
    fn par_chunks_mut_handles_empty_and_tail() {
        let mut empty: Vec<u8> = vec![];
        par_chunks_mut(&mut empty, 4, |_, _| panic!("no chunks expected"));
        with_threads(4, || {
            let mut data = vec![0u8; 5]; // 2 chunks, short tail
            par_chunks_mut(&mut data, 3, |ci, chunk| {
                for x in chunk.iter_mut() {
                    *x = ci as u8 + 1;
                }
            });
            assert_eq!(data, vec![1, 1, 1, 2, 2]);
        });
    }

    #[test]
    fn cheap_variant_matches_parallel_below_and_above_threshold() {
        for &len in &[100usize, CHEAP_SWEEP_MIN + 5] {
            with_threads(4, || {
                let mut a = vec![0usize; len];
                let mut b = vec![0usize; len];
                par_chunks_mut_cheap(&mut a, 7, |ci, chunk| {
                    for (off, x) in chunk.iter_mut().enumerate() {
                        *x = ci * 7 + off;
                    }
                });
                par_chunks_mut(&mut b, 7, |ci, chunk| {
                    for (off, x) in chunk.iter_mut().enumerate() {
                        *x = ci * 7 + off;
                    }
                });
                assert_eq!(a, b);
            });
        }
    }

    #[test]
    fn par_zip_mut_splits_consistently() {
        for &t in &[1usize, 4] {
            with_threads(t, || {
                let mut a = vec![0u32; 17];
                let mut b = vec![0u32; 17];
                par_zip_mut(&mut a, &mut b, 3, |ci, ca, cb| {
                    for (off, (x, y)) in ca.iter_mut().zip(cb.iter_mut()).enumerate() {
                        *x = (ci * 3 + off) as u32;
                        *y = *x * 2;
                    }
                });
                for i in 0..17 {
                    assert_eq!(a[i], i as u32);
                    assert_eq!(b[i], 2 * i as u32);
                }
            });
        }
    }

    #[test]
    fn nested_regions_run_inline() {
        with_threads(4, || {
            par_rows(4, |range| {
                for _ in range {
                    // inside a worker the nested width must collapse to 1
                    assert_eq!(super::pool_width(128), 1);
                }
            });
            // back outside the pool, width is restored
            assert_eq!(super::pool_width(128), 4);
        });
    }
}
