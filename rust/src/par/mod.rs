//! Dependency-free data-parallel compute subsystem.
//!
//! A **persistent worker pool** (`pool`) behind a deterministic
//! region scheduler (`region`): long-lived workers are spawned lazily
//! on first use (`LKGP_THREADS`-sized, default = available cores), park
//! on a condvar when idle, and are reused by every subsequent parallel
//! region — dispatching a region costs ~a condvar wake instead of the
//! tens of microseconds of `std::thread::scope` spawn/join the PR-1
//! design paid. [`set_threads`] overrides the width process-wide,
//! [`with_threads`] per scope on the calling thread, [`shutdown_pool`]
//! joins the workers (the next region restarts them transparently).
//!
//! Every helper splits work over *disjoint* output chunks whose
//! boundaries depend only on the problem shape (never on the thread
//! count), and each chunk is executed by exactly one worker with a
//! fixed sequential reduction order. Parallel results are therefore
//! **bit-identical for any thread count** — the invariant the whole
//! inference hot path relies on, asserted end-to-end by
//! `rust/tests/par_invariance.rs`. This holds under both chunk
//! schedules: [`Schedule::Block`] assigns contiguous chunk runs per
//! worker, [`Schedule::Steal`] lets workers pull chunk indices from a
//! shared cursor (for ragged workloads — pivoted-Cholesky columns,
//! short last GEMM panels) — writer *identity* varies, chunk content
//! never does.
//!
//! Nested parallel regions collapse: work spawned from inside a pool
//! worker runs inline on that worker. This prevents oversubscription
//! (e.g. a batched Kron MVM parallelized over batch rows calling the
//! parallel GEMM per row) while letting single-row calls still fan out
//! at the inner level.
//!
//! A panic inside any task is caught per chunk, cancels the region's
//! remaining chunks, and is rethrown on the submitting thread as a
//! structured [`RegionPanic`] (region name + chunk index). The pool is
//! never poisoned and never deadlocks: subsequent regions run normally.
//!
//! The heaviest client is the register-tiled GEMM (`linalg::gemm`),
//! which dispatches MC-row blocks of C through [`par_chunks_mut_steal`];
//! the kernel Gram distance/exp post-pass and the dense-baseline Gram
//! assembly ride the same pool via [`par_chunks_mut_cheap`].

mod pool;
mod region;

pub use region::{RegionPanic, Schedule};

use std::cell::Cell;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide thread-count override (0 = derive from the environment
/// on first use).
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread override installed by [`with_threads`] (0 = unset).
    static TL_THREADS: Cell<usize> = Cell::new(0);
    /// True while the current thread is executing inside a pool worker.
    static IN_POOL: Cell<bool> = Cell::new(false);
}

/// Snapshot of the effective parallelism configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    /// Worker threads a new parallel region may use.
    pub threads: usize,
}

impl Parallelism {
    /// Resolve the currently effective configuration: a [`with_threads`]
    /// scope wins over [`set_threads`], which wins over `LKGP_THREADS`,
    /// which wins over the detected core count.
    pub fn current() -> Self {
        Parallelism { threads: num_threads() }
    }
}

fn detected_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn env_threads() -> usize {
    match std::env::var("LKGP_THREADS") {
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => detected_cores(),
        },
        Err(_) => detected_cores(),
    }
}

/// Effective worker count for new parallel regions on this thread.
pub fn num_threads() -> usize {
    let tl = TL_THREADS.with(|c| c.get());
    if tl != 0 {
        return tl;
    }
    let g = GLOBAL_THREADS.load(Ordering::Relaxed);
    if g != 0 {
        return g;
    }
    let n = env_threads();
    GLOBAL_THREADS.store(n, Ordering::Relaxed);
    n
}

/// Set the process-wide thread count (overrides `LKGP_THREADS`). The
/// persistent pool grows on demand; shrinking the count simply leaves
/// the extra workers parked.
pub fn set_threads(n: usize) {
    GLOBAL_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Run `f` with the calling thread's parallelism pinned to `n` —
/// a scoped override used by benches and the invariance tests. The
/// previous value is restored even if `f` panics.
pub fn with_threads<T>(n: usize, f: impl FnOnce() -> T) -> T {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0;
            TL_THREADS.with(|c| c.set(prev));
        }
    }
    let prev = TL_THREADS.with(|c| {
        let p = c.get();
        c.set(n.max(1));
        p
    });
    let _restore = Restore(prev);
    f()
}

/// Run `f`, converting a [`RegionPanic`] escaping from any parallel
/// region inside it into a typed `Err` instead of unwinding further.
///
/// This is the boundary where the resilience layer turns a worker-task
/// panic (caught per chunk and rethrown on the submitting thread by the
/// region scheduler) into an error value that survives `anyhow` chains.
/// Panics that are *not* region panics are re-raised unchanged — only
/// structured pool faults are captured.
pub fn catch_region<T>(f: impl FnOnce() -> T) -> Result<T, RegionPanic> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(v) => Ok(v),
        Err(payload) => match payload.downcast::<RegionPanic>() {
            Ok(rp) => Err(*rp),
            Err(other) => std::panic::resume_unwind(other),
        },
    }
}

/// Join all persistent pool workers and reset the pool; the next
/// parallel region lazily restarts it. Safe to call at any time —
/// regions racing a shutdown complete by running their chunks on the
/// submitting thread — but intended for tests and orderly teardown.
pub fn shutdown_pool() {
    pool::shutdown();
}

/// Cumulative scheduler/pool counters (process-wide, monotone).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Parallel regions executed (including inline-collapsed ones).
    pub regions: u64,
    /// Regions that actually fanned out over the pool.
    pub fanned_regions: u64,
    /// Chunks executed under [`Schedule::Steal`].
    pub steal_chunks: u64,
    /// Steal-mode chunks executed by a worker other than the chunk's
    /// block-mode "home" worker — the work-stealing/balancing signal.
    pub stolen_chunks: u64,
    /// Pool worker threads ever spawned (across shutdown/re-init).
    pub workers_spawned: u64,
    /// Pool worker threads currently alive.
    pub workers_live: usize,
}

/// Snapshot of the cumulative [`PoolStats`] counters.
pub fn pool_stats() -> PoolStats {
    PoolStats {
        regions: region::REGIONS.load(Ordering::Relaxed),
        fanned_regions: region::FANNED_REGIONS.load(Ordering::Relaxed),
        steal_chunks: region::STEAL_CHUNKS.load(Ordering::Relaxed),
        stolen_chunks: region::STOLEN_CHUNKS.load(Ordering::Relaxed),
        workers_spawned: pool::workers_spawned(),
        workers_live: pool::workers_live(),
    }
}

/// RAII marker: the current thread is executing a region task, so
/// nested parallel regions must run inline.
pub(crate) struct PoolGuard {
    prev: bool,
}

impl PoolGuard {
    pub(crate) fn enter() -> Self {
        let prev = IN_POOL.with(|c| {
            let p = c.get();
            c.set(true);
            p
        });
        PoolGuard { prev }
    }
}

impl Drop for PoolGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_POOL.with(|c| c.set(prev));
    }
}

/// Mark the current thread as a permanent pool worker (regions issued
/// from it always collapse inline).
pub(crate) fn mark_pool_worker() {
    IN_POOL.with(|c| c.set(true));
}

/// True while the current thread is a pool worker or executing a
/// region task (nested regions collapse; a pool shutdown from here
/// must not try to join the current thread).
pub(crate) fn in_pool_worker() -> bool {
    IN_POOL.with(|c| c.get())
}

/// Worker count for a region with `work_items` independent items:
/// 1 inside an existing pool worker (no nesting), otherwise
/// `min(num_threads(), work_items)`.
pub(crate) fn effective_width(work_items: usize) -> usize {
    if work_items <= 1 || in_pool_worker() {
        1
    } else {
        num_threads().min(work_items)
    }
}

/// Split `0..n` into one contiguous range per worker and run `f` on each
/// range in parallel. The range boundaries depend on the thread count,
/// so `f` must compute each index independently (no cross-index
/// accumulation) for results to stay thread-count invariant. `name`
/// tags the region in panic reports.
pub fn par_rows<F>(name: &'static str, n: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    if n == 0 {
        return;
    }
    let nt = effective_width(n);
    let per = (n + nt - 1) / nt;
    region::run_chunked(name, nt, Schedule::Block, &|w| {
        let lo = w * per;
        let hi = n.min(lo + per);
        if lo < hi {
            f(lo..hi);
        }
    });
}

/// Default sequential-fallback threshold (total elements) for
/// [`par_chunks_mut_cheap`]: below this, a cheap elementwise sweep is
/// not worth a region dispatch. The persistent pool dispatches in ~a
/// microsecond where the old scoped-spawn design paid tens, so this
/// dropped 8x from [`CHEAP_SWEEP_MIN_SPAWN`] (the PR-1 value, kept as
/// the documented `LKGP_CHEAP_SWEEP_MIN` fallback for platforms where
/// pool wakeups are slow). Heavy per-element work (dot products, RNG
/// draws, GEMM blocks) should use [`par_chunks_mut`] directly.
pub const CHEAP_SWEEP_MIN: usize = 1 << 11;

/// The scoped-spawn-era threshold (PR 1-3): the value to restore via
/// `LKGP_CHEAP_SWEEP_MIN=16384` if persistent-pool dispatch ever
/// regresses to spawn/join cost on some platform.
pub const CHEAP_SWEEP_MIN_SPAWN: usize = 1 << 14;

/// Cached effective cheap-sweep threshold: `LKGP_CHEAP_SWEEP_MIN` (read
/// once) or [`CHEAP_SWEEP_MIN`]. Purely a scheduling decision — the
/// sequential and parallel paths are bit-identical.
pub fn cheap_sweep_min() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let v = CACHED.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let n = std::env::var("LKGP_CHEAP_SWEEP_MIN")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(CHEAP_SWEEP_MIN);
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Pointer wrapper that lets region tasks carve disjoint chunks out of
/// one `&mut [T]` from different workers.
struct SendPtr<T>(*mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

fn chunks_impl<T, F>(name: &'static str, schedule: Schedule, data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    chunks_run(name, Some(schedule), data, chunk_len, f);
}

/// Shared body of the chunked helpers: `schedule` of `None` forces the
/// sequential path (the cheap-sweep fallback), keeping the exact panic
/// surface of the pooled paths either way.
fn chunks_run<T, F>(
    name: &'static str,
    schedule: Option<Schedule>,
    data: &mut [T],
    chunk_len: usize,
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    assert!(chunk_len > 0, "chunk_len must be positive");
    let len = data.len();
    let n_chunks = (len + chunk_len - 1) / chunk_len;
    let base = SendPtr(data.as_mut_ptr());
    let task = move |c: usize| {
        let lo = c * chunk_len;
        let hi = len.min(lo + chunk_len);
        // SAFETY: the scheduler executes each chunk index at most once,
        // so these ranges are disjoint across concurrent tasks; `data`
        // outlives the region because the region entry points block
        // until every chunk has finished.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), hi - lo) };
        f(c, chunk);
    };
    match schedule {
        Some(s) => region::run_chunked(name, n_chunks, s, &task),
        None => region::run_sequential(name, n_chunks, &task),
    }
}

fn zip_impl<A, B, F>(
    name: &'static str,
    schedule: Schedule,
    a: &mut [A],
    b: &mut [B],
    chunk_len: usize,
    f: F,
) where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert_eq!(a.len(), b.len(), "par_zip_mut slices must have equal length");
    if a.is_empty() {
        return;
    }
    assert!(chunk_len > 0, "chunk_len must be positive");
    let len = a.len();
    let n_chunks = (len + chunk_len - 1) / chunk_len;
    let base_a = SendPtr(a.as_mut_ptr());
    let base_b = SendPtr(b.as_mut_ptr());
    region::run_chunked(name, n_chunks, schedule, &move |c| {
        let lo = c * chunk_len;
        let hi = len.min(lo + chunk_len);
        // SAFETY: as in `chunks_impl` — disjoint chunk ranges, each
        // executed at most once, both borrows outlive the region.
        let ca = unsafe { std::slice::from_raw_parts_mut(base_a.0.add(lo), hi - lo) };
        let cb = unsafe { std::slice::from_raw_parts_mut(base_b.0.add(lo), hi - lo) };
        f(c, ca, cb);
    });
}

/// Process disjoint `chunk_len`-sized chunks of `data` in parallel:
/// `f(chunk_index, chunk)`. Chunk boundaries depend only on `chunk_len`
/// (the tail chunk may be short) and each chunk is written by exactly
/// one worker, so output bits never depend on the thread count.
/// Contiguous block assignment ([`Schedule::Block`]).
pub fn par_chunks_mut<T, F>(name: &'static str, data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    chunks_impl(name, Schedule::Block, data, chunk_len, f);
}

/// [`par_chunks_mut`] under the work-stealing schedule
/// ([`Schedule::Steal`]) — for ragged chunks whose cost varies. Output
/// bits are identical to the block schedule at any thread count.
pub fn par_chunks_mut_steal<T, F>(name: &'static str, data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    chunks_impl(name, Schedule::Steal, data, chunk_len, f);
}

/// Like [`par_chunks_mut`] but stays sequential below
/// [`cheap_sweep_min`] total elements — for cheap elementwise sweeps
/// (mask multiplies, diagonal fills) where even a pool dispatch would
/// dominate the work. The sequential and parallel paths are bit-exact
/// identical (and share the [`RegionPanic`] surface), so this is
/// purely a scheduling decision.
pub fn par_chunks_mut_cheap<T, F>(name: &'static str, data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.len() < cheap_sweep_min() {
        chunks_run(name, None, data, chunk_len, f);
        return;
    }
    par_chunks_mut(name, data, chunk_len, f);
}

/// Like [`par_chunks_mut`] over two equal-length slices split at the
/// same chunk boundaries: `f(chunk_index, a_chunk, b_chunk)`.
pub fn par_zip_mut<A, B, F>(name: &'static str, a: &mut [A], b: &mut [B], chunk_len: usize, f: F)
where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    zip_impl(name, Schedule::Block, a, b, chunk_len, f);
}

/// [`par_zip_mut`] under the work-stealing schedule — the ragged
/// pivoted-Cholesky row sweep runs here. Bit-identical to the block
/// schedule at any thread count.
pub fn par_zip_mut_steal<A, B, F>(
    name: &'static str,
    a: &mut [A],
    b: &mut [B],
    chunk_len: usize,
    f: F,
) where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    zip_impl(name, Schedule::Steal, a, b, chunk_len, f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn with_threads_scopes_override() {
        let outside = num_threads();
        with_threads(3, || assert_eq!(num_threads(), 3));
        assert_eq!(num_threads(), outside);
    }

    #[test]
    fn parallelism_reports_current() {
        with_threads(5, || assert_eq!(Parallelism::current().threads, 5));
    }

    #[test]
    fn par_rows_covers_all_indices_once() {
        for &t in &[1usize, 2, 5] {
            with_threads(t, || {
                let n = 103;
                let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                par_rows("test.rows", n, |range| {
                    for i in range {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
            });
        }
    }

    #[test]
    fn par_chunks_mut_indices_and_values_both_schedules() {
        for &t in &[1usize, 2, 8] {
            for sched in [Schedule::Block, Schedule::Steal] {
                with_threads(t, || {
                    let mut data = vec![0usize; 25];
                    chunks_impl("test.chunks", sched, &mut data, 4, |ci, chunk| {
                        for (off, x) in chunk.iter_mut().enumerate() {
                            *x = ci * 4 + off;
                        }
                    });
                    let want: Vec<usize> = (0..25).collect();
                    assert_eq!(data, want, "schedule {sched:?} t={t}");
                });
            }
        }
    }

    #[test]
    fn par_chunks_mut_handles_empty_and_tail() {
        let mut empty: Vec<u8> = vec![];
        par_chunks_mut("test.empty", &mut empty, 4, |_, _| panic!("no chunks expected"));
        with_threads(4, || {
            let mut data = vec![0u8; 5]; // 2 chunks, short tail
            par_chunks_mut("test.tail", &mut data, 3, |ci, chunk| {
                for x in chunk.iter_mut() {
                    *x = ci as u8 + 1;
                }
            });
            assert_eq!(data, vec![1, 1, 1, 2, 2]);
        });
    }

    #[test]
    fn cheap_variant_matches_parallel_below_and_above_threshold() {
        for &len in &[100usize, cheap_sweep_min() + 5] {
            with_threads(4, || {
                let mut a = vec![0usize; len];
                let mut b = vec![0usize; len];
                par_chunks_mut_cheap("test.cheap", &mut a, 7, |ci, chunk| {
                    for (off, x) in chunk.iter_mut().enumerate() {
                        *x = ci * 7 + off;
                    }
                });
                par_chunks_mut("test.full", &mut b, 7, |ci, chunk| {
                    for (off, x) in chunk.iter_mut().enumerate() {
                        *x = ci * 7 + off;
                    }
                });
                assert_eq!(a, b);
            });
        }
    }

    #[test]
    fn par_zip_mut_splits_consistently_both_schedules() {
        for &t in &[1usize, 4] {
            for sched in [Schedule::Block, Schedule::Steal] {
                with_threads(t, || {
                    let mut a = vec![0u32; 17];
                    let mut b = vec![0u32; 17];
                    zip_impl("test.zip", sched, &mut a, &mut b, 3, |ci, ca, cb| {
                        for (off, (x, y)) in ca.iter_mut().zip(cb.iter_mut()).enumerate() {
                            *x = (ci * 3 + off) as u32;
                            *y = *x * 2;
                        }
                    });
                    for i in 0..17 {
                        assert_eq!(a[i], i as u32);
                        assert_eq!(b[i], 2 * i as u32);
                    }
                });
            }
        }
    }

    #[test]
    fn steal_bits_match_block_bits() {
        // float content with a fixed per-chunk reduction order must be
        // bit-identical under both schedules at any width
        let run = |sched: Schedule, t: usize| -> Vec<u64> {
            with_threads(t, || {
                let mut data = vec![0.0f64; 4096];
                chunks_impl("test.bits", sched, &mut data, 37, |ci, chunk| {
                    for (off, x) in chunk.iter_mut().enumerate() {
                        let mut acc = 0.0f64;
                        for k in 0..(ci % 13) + 1 {
                            acc += ((ci * 37 + off + k) as f64).sin() * 0.1;
                        }
                        *x = acc;
                    }
                });
                data.iter().map(|x| x.to_bits()).collect()
            })
        };
        let want = run(Schedule::Block, 1);
        for t in [2usize, 4, 8] {
            assert_eq!(want, run(Schedule::Block, t), "block t={t}");
            assert_eq!(want, run(Schedule::Steal, t), "steal t={t}");
        }
    }

    #[test]
    fn nested_regions_run_inline() {
        with_threads(4, || {
            par_rows("test.outer", 4, |range| {
                for _ in range {
                    // inside a worker the nested width must collapse to 1
                    assert_eq!(super::effective_width(128), 1);
                }
            });
            // back outside the pool, width is restored
            assert_eq!(super::effective_width(128), 4);
        });
    }

    #[test]
    fn nested_region_calls_complete_and_cover() {
        // a region body that itself issues regions (the Kron-MVM-
        // calls-GEMM pattern): inner calls collapse inline, every
        // element still written exactly once, no deadlock
        with_threads(4, || {
            let mut data = vec![0usize; 64 * 16];
            par_chunks_mut("test.nested_outer", &mut data, 16, |ci, chunk| {
                par_chunks_mut("test.nested_inner", chunk, 4, |cj, sub| {
                    for (off, x) in sub.iter_mut().enumerate() {
                        *x = ci * 16 + cj * 4 + off;
                    }
                });
            });
            let want: Vec<usize> = (0..64 * 16).collect();
            assert_eq!(data, want);
        });
    }

    #[test]
    fn panic_is_structured_and_pool_survives() {
        for sched in [Schedule::Block, Schedule::Steal] {
            for &t in &[1usize, 4] {
                let err = with_threads(t, || {
                    catch_unwind(AssertUnwindSafe(|| {
                        let mut data = vec![0u8; 40];
                        chunks_impl("test.boom", sched, &mut data, 4, |ci, _chunk| {
                            if ci == 3 {
                                panic!("task exploded");
                            }
                        });
                    }))
                    .expect_err("region must rethrow the task panic")
                });
                let rp = err.downcast::<RegionPanic>().expect("payload must be RegionPanic");
                assert_eq!(rp.region, "test.boom");
                assert_eq!(rp.chunk, 3);
                assert!(rp.payload.contains("task exploded"), "payload: {}", rp.payload);
                assert!(format!("{rp}").contains("'test.boom'"));
                // the pool is not poisoned: the next region works
                with_threads(t, || {
                    let mut data = vec![0usize; 100];
                    par_chunks_mut("test.after_boom", &mut data, 7, |ci, chunk| {
                        for (off, x) in chunk.iter_mut().enumerate() {
                            *x = ci * 7 + off;
                        }
                    });
                    let want: Vec<usize> = (0..100).collect();
                    assert_eq!(data, want);
                });
            }
        }
    }

    #[test]
    fn cheap_sequential_panic_is_structured_too() {
        // the below-threshold fallback must surface the same RegionPanic
        // as the pooled paths, so the payload a caller catches never
        // depends on the (env-tunable) threshold
        let err = catch_unwind(AssertUnwindSafe(|| {
            let mut data = vec![0u8; 40]; // well below cheap_sweep_min
            par_chunks_mut_cheap("test.cheap_boom", &mut data, 4, |ci, _chunk| {
                if ci == 2 {
                    panic!("cheap task exploded");
                }
            });
        }))
        .expect_err("cheap fallback must rethrow as RegionPanic");
        let rp = err.downcast::<RegionPanic>().expect("payload must be RegionPanic");
        assert_eq!(rp.region, "test.cheap_boom");
        assert_eq!(rp.chunk, 2);
    }

    #[test]
    fn shutdown_and_reinit_roundtrip() {
        for round in 0..3 {
            shutdown_pool();
            with_threads(3, || {
                let mut data = vec![0usize; 256];
                par_chunks_mut_steal("test.reinit", &mut data, 8, |ci, chunk| {
                    for (off, x) in chunk.iter_mut().enumerate() {
                        *x = ci * 8 + off;
                    }
                });
                let want: Vec<usize> = (0..256).collect();
                assert_eq!(data, want, "round {round}");
            });
        }
    }

    #[test]
    fn oversubscribed_width_completes() {
        // far more workers than cores: regions must still cover every
        // chunk exactly once and terminate promptly
        with_threads(4 * detected_cores().max(2), || {
            let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
            par_rows("test.oversub", 1000, |range| {
                for i in range {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        });
    }

    #[test]
    fn pool_stats_accumulate() {
        let before = pool_stats();
        with_threads(4, || {
            let mut data = vec![0u64; 512];
            par_chunks_mut_steal("test.stats", &mut data, 8, |ci, chunk| {
                for x in chunk.iter_mut() {
                    *x = ci as u64;
                }
            });
        });
        let after = pool_stats();
        assert!(after.regions > before.regions);
        assert!(after.steal_chunks >= before.steal_chunks + 64);
        assert!(after.stolen_chunks >= before.stolen_chunks);
    }
}
