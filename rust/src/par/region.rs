//! Deterministic region scheduler on top of the persistent pool.
//!
//! A *region* is one blocking parallel construct: `n_chunks` disjoint
//! work items, each identified by its chunk index, executed exactly
//! once while the submitting thread waits. Two chunk-assignment
//! policies are offered (see [`Schedule`]); both preserve the crate's
//! determinism contract — every chunk's *content* is a pure function of
//! its index, each chunk is executed by exactly one worker, and chunk
//! boundaries never depend on the thread count — so output bits are
//! identical for any `LKGP_THREADS` under either policy.
//!
//! Panics inside a task are caught per chunk ([`catch_unwind`]),
//! sibling chunks are cancelled at the next chunk boundary, and the
//! first panic is rethrown on the submitting thread as a structured
//! [`RegionPanic`] carrying the region name and chunk index. The pool
//! itself is never poisoned: subsequent regions run normally.

use std::any::Any;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use super::pool;

/// Chunk-assignment policy for one parallel region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// Contiguous runs of chunks per worker (`ceil(n_chunks / width)`
    /// each, in index order). Zero coordination after dispatch and the
    /// best cache locality — the default for uniform workloads like
    /// GEMM row blocks and batched MVM rows.
    Block,
    /// Dynamic self-scheduling: every worker repeatedly takes the
    /// lowest unclaimed chunk index from a shared cursor. Chunks whose
    /// cost varies (pivoted-Cholesky row sweeps that thin out as pivots
    /// are consumed, short last GEMM panels, lazy kernel rows) no
    /// longer gate the region on the unluckiest worker. Legal whenever
    /// chunk content is a pure function of the chunk index — writer
    /// *identity* varies run to run, but each chunk is still written
    /// exactly once, so output bits are unaffected.
    Steal,
}

/// Structured panic payload rethrown on the submitting thread when a
/// task inside a parallel region panics. Catch with
/// `std::panic::catch_unwind` and downcast to recover the fields.
#[derive(Debug)]
pub struct RegionPanic {
    /// Name of the region whose task panicked.
    pub region: &'static str,
    /// Chunk index the panicking task was executing.
    pub chunk: usize,
    /// Stringified payload of the original panic (best effort).
    pub payload: String,
}

impl fmt::Display for RegionPanic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "parallel region '{}' panicked in chunk {}: {}",
            self.region, self.chunk, self.payload
        )
    }
}

impl std::error::Error for RegionPanic {}

// Cumulative scheduler counters, surfaced through `super::pool_stats`.
pub(super) static REGIONS: AtomicU64 = AtomicU64::new(0);
pub(super) static FANNED_REGIONS: AtomicU64 = AtomicU64::new(0);
pub(super) static STEAL_CHUNKS: AtomicU64 = AtomicU64::new(0);
pub(super) static STOLEN_CHUNKS: AtomicU64 = AtomicU64::new(0);

fn payload_string(p: Box<dyn Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Shared per-region state: the steal cursor, the cancellation flag,
/// and the first caught panic.
struct RegionState {
    name: &'static str,
    /// Chunks per worker under [`Schedule::Block`]; also defines the
    /// "home" worker of a chunk for the steal-ratio bookkeeping.
    per: usize,
    next: AtomicUsize,
    poisoned: AtomicBool,
    panic_slot: Mutex<Option<(usize, String)>>,
}

impl RegionState {
    fn run_one(&self, c: usize, task: &(dyn Fn(usize) + Sync)) {
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| task(c))) {
            self.poisoned.store(true, Ordering::Relaxed);
            let msg = payload_string(p);
            let mut slot = self.panic_slot.lock().unwrap_or_else(|e| e.into_inner());
            if slot.is_none() {
                *slot = Some((c, msg));
            }
        }
    }

    /// Rethrow the first caught panic (if any) as a [`RegionPanic`].
    fn rethrow(&self) {
        let got = self.panic_slot.lock().unwrap_or_else(|e| e.into_inner()).take();
        if let Some((chunk, payload)) = got {
            std::panic::panic_any(RegionPanic { region: self.name, chunk, payload });
        }
    }
}

/// Run `task(c)` for every chunk in `0..n_chunks` sequentially on the
/// calling thread, with the exact panic surface of the pooled paths
/// (first panic cancels the rest and rethrows as [`RegionPanic`]).
/// Used for regions that collapse inline and for the cheap-sweep
/// sequential fallback, so the payload a caller catches never depends
/// on which path a threshold picked.
/// Consult the `par_region` failpoint at region dispatch. When it fires
/// with the `panic` action, chunk 0 of this region panics — exercising
/// the per-chunk catch / cancel / rethrow machinery end to end. The
/// check happens once per region (not per chunk), so a bare
/// `par_region:panic` spec is deterministic at any thread count; `@N`
/// indexing is only meaningful where regions dispatch from one thread.
fn region_fault() -> bool {
    matches!(
        crate::util::failpoint::check("par_region"),
        Some(crate::util::failpoint::FaultAction::Panic)
    )
}

pub(crate) fn run_sequential(name: &'static str, n_chunks: usize, task: &(dyn Fn(usize) + Sync)) {
    if n_chunks == 0 {
        return;
    }
    let inject = region_fault();
    let wrapped = move |c: usize| {
        if inject && c == 0 {
            panic!("injected fault at failpoint par_region");
        }
        task(c)
    };
    run_sequential_inner(name, n_chunks, &wrapped)
}

fn run_sequential_inner(name: &'static str, n_chunks: usize, task: &(dyn Fn(usize) + Sync)) {
    REGIONS.fetch_add(1, Ordering::Relaxed);
    let state = RegionState {
        name,
        per: n_chunks,
        next: AtomicUsize::new(0),
        poisoned: AtomicBool::new(false),
        panic_slot: Mutex::new(None),
    };
    for c in 0..n_chunks {
        if state.poisoned.load(Ordering::Relaxed) {
            break;
        }
        state.run_one(c, task);
    }
    state.rethrow();
}

/// Execute `task(chunk)` exactly once for every chunk in `0..n_chunks`,
/// fanned out over the persistent pool under `schedule`, blocking until
/// the region completes. Width is `min(num_threads(), n_chunks)`, or 1
/// inside an existing pool worker (nested regions collapse).
pub(crate) fn run_chunked(
    name: &'static str,
    n_chunks: usize,
    schedule: Schedule,
    task: &(dyn Fn(usize) + Sync),
) {
    if n_chunks == 0 {
        return;
    }
    let inject = region_fault();
    let wrapped = move |c: usize| {
        if inject && c == 0 {
            panic!("injected fault at failpoint par_region");
        }
        task(c)
    };
    let task: &(dyn Fn(usize) + Sync) = &wrapped;
    let nt = super::effective_width(n_chunks);
    if nt <= 1 {
        run_sequential_inner(name, n_chunks, task);
        return;
    }
    REGIONS.fetch_add(1, Ordering::Relaxed);
    FANNED_REGIONS.fetch_add(1, Ordering::Relaxed);
    let state = RegionState {
        name,
        per: (n_chunks + nt - 1) / nt,
        next: AtomicUsize::new(0),
        poisoned: AtomicBool::new(false),
        panic_slot: Mutex::new(None),
    };
    let st = &state;
    let body = |wid: usize| {
        let _inline = super::PoolGuard::enter();
        match schedule {
            Schedule::Block => {
                let lo = wid * st.per;
                let hi = n_chunks.min(lo + st.per);
                for c in lo..hi {
                    if st.poisoned.load(Ordering::Relaxed) {
                        break;
                    }
                    st.run_one(c, task);
                }
            }
            Schedule::Steal => loop {
                let c = st.next.fetch_add(1, Ordering::Relaxed);
                if c >= n_chunks || st.poisoned.load(Ordering::Relaxed) {
                    break;
                }
                STEAL_CHUNKS.fetch_add(1, Ordering::Relaxed);
                if c / st.per != wid {
                    STOLEN_CHUNKS.fetch_add(1, Ordering::Relaxed);
                }
                st.run_one(c, task);
            },
        }
    };
    pool::submit_and_run(nt - 1, &body);
    state.rethrow();
}
