//! Latent Kronecker structure — the paper's core contribution (Sec. 3).
//!
//! A grid vector v of length p*q uses the row-major layout
//! `v[j*q + k] = value at (s_j, t_k)` (shared with the AOT artifacts),
//! under which `(K_SS (x) K_TT) v = vec(K_SS @ unvec(v) @ K_TT^T)`.
//! The projection P of the paper is a {0,1} mask multiply; the masked
//! system operator is `M (K_SS (x) K_TT) M + sigma2 I`, which restricted
//! to the observed subspace equals `P K P^T + sigma2 I` exactly.

pub mod breakeven;
pub mod interp;
pub mod lazy;
pub mod multi;
pub mod toeplitz;

use crate::linalg::gemm::{matmul_acc, matmul_nt};
use crate::linalg::{Matrix, Scalar};

/// Engine for the `K_TT` half of a Kronecker MVM. `Dense` is the
/// bit-exact seed path (blocked GEMM); `Toeplitz` applies the time
/// factor in O(q log q) via circulant embedding when the grid is
/// uniform and the time kernel stationary (`LkgpConfig::time_op`).
#[derive(Clone, Debug)]
pub enum TimeOp {
    /// Dense q x q GEMM against the materialized `K_TT`.
    Dense,
    /// Planned-FFT Toeplitz MVM (see [`toeplitz::ToeplitzOp`]).
    Toeplitz(toeplitz::ToeplitzOp),
}

/// Kronecker product operator K_SS (x) K_TT held in factored form.
#[derive(Clone, Debug)]
pub struct KronOp<T: Scalar = f64> {
    /// Spatial Gram factor K_SS (p x p).
    pub kss: Matrix<T>,
    /// Time/task Gram factor K_TT (q x q). Always materialized — the
    /// diagonal/column accessors and the dense baselines read it even
    /// when MVMs route through a Toeplitz fast path.
    pub ktt: Matrix<T>,
    /// How `apply_batch` applies the `K_TT` half (default: `Dense`).
    pub time_op: TimeOp,
}

impl<T: Scalar> KronOp<T> {
    /// Factored operator from square Gram factors (asserts shapes).
    /// MVMs use the dense `K_TT` path; see [`KronOp::with_toeplitz`].
    pub fn new(kss: Matrix<T>, ktt: Matrix<T>) -> Self {
        assert_eq!(kss.rows, kss.cols);
        assert_eq!(ktt.rows, ktt.cols);
        KronOp { kss, ktt, time_op: TimeOp::Dense }
    }

    /// Route the `K_TT` half of every MVM through the given Toeplitz
    /// operator (must represent the same q x q matrix as `ktt`).
    pub fn with_toeplitz(mut self, op: toeplitz::ToeplitzOp) -> Self {
        assert_eq!(op.q, self.q(), "Toeplitz factor must match K_TT dimension");
        self.time_op = TimeOp::Toeplitz(op);
        self
    }

    /// Number of spatial points p.
    pub fn p(&self) -> usize {
        self.kss.rows
    }

    /// Number of time steps / tasks q.
    pub fn q(&self) -> usize {
        self.ktt.rows
    }

    /// Grid dimension p*q.
    pub fn dim(&self) -> usize {
        self.p() * self.q()
    }

    /// Apply to a batch of grid vectors (rows of `v`, each length p*q):
    /// out[b] = vec(K_SS @ unvec(v[b]) @ K_TT^T).
    /// Cost O(b (p^2 q + p q^2)) — the headline complexity reduction.
    ///
    /// Parallel schedule: batch rows are embarrassingly parallel, so
    /// they are distributed across the `crate::par` worker pool (one
    /// output row per task, contiguous row groups per worker). For a
    /// single-row batch the fan-out happens *inside* the two blocked
    /// GEMMs instead — nested regions collapse, so exactly one level
    /// ever spawns. Either way each output element is produced by one
    /// worker with a fixed reduction order, so the result is
    /// bit-identical for any `LKGP_THREADS` (see
    /// rust/tests/par_invariance.rs). The per-row two-GEMM form keeps
    /// both halves on blocked kernels with zero reshuffling.
    pub fn apply_batch(&self, v: &Matrix<T>) -> Matrix<T> {
        match &self.time_op {
            TimeOp::Dense => self.apply_batch_dense(v),
            TimeOp::Toeplitz(top) => self.apply_batch_toeplitz(top, v),
        }
    }

    /// Dense-path MVM (the seed implementation, byte-for-byte).
    fn apply_batch_dense(&self, v: &Matrix<T>) -> Matrix<T> {
        let (p, q) = (self.p(), self.q());
        assert_eq!(v.cols, p * q, "grid vector length");
        let mut out = Matrix::zeros(v.rows, p * q);
        crate::par::par_chunks_mut("kron.apply_batch", &mut out.data, p * q, |b, orow| {
            let vb = Matrix { rows: p, cols: q, data: v.row(b).to_vec() };
            // T1 = V @ K_TT^T  (p x q), tiled nt kernel, no transpose
            let t1 = matmul_nt(&vb, &self.ktt);
            // out_b = K_SS @ T1 (p x q)
            let mut ob = Matrix { rows: p, cols: q, data: vec![T::ZERO; p * q] };
            matmul_acc(&self.kss, &t1, &mut ob);
            orow.copy_from_slice(&ob.data);
        });
        out
    }

    /// Toeplitz-path MVM: the `K_TT` half becomes b*p independent
    /// O(q log q) FFT MVMs (one column per task, stolen across the
    /// pool — ragged lengths don't stall a static split), then the
    /// `K_SS` half reuses the same blocked GEMM as the dense path.
    /// Each output element is produced by exactly one worker from a
    /// fixed-order planned transform, so the result is bit-identical
    /// at any `LKGP_THREADS` and any batch grouping.
    fn apply_batch_toeplitz(&self, top: &toeplitz::ToeplitzOp, v: &Matrix<T>) -> Matrix<T> {
        let (p, q) = (self.p(), self.q());
        assert_eq!(v.cols, p * q, "grid vector length");
        let mut out = Matrix::zeros(v.rows, p * q);
        if v.rows == 0 || p == 0 || q == 0 {
            return out;
        }
        // T1[b*p + i] = K_TT @ v[b][i*q..], via circulant embedding
        let mut t1 = Matrix::zeros(v.rows * p, q);
        crate::par::par_chunks_mut_steal("kron.toeplitz_tt", &mut t1.data, q, |ri, row| {
            let (b, i) = (ri / p, ri % p);
            top.matvec_into(&v.row(b)[i * q..(i + 1) * q], row);
        });
        // out_b = K_SS @ T1_b (p x q)
        crate::par::par_chunks_mut("kron.toeplitz_ss", &mut out.data, p * q, |b, orow| {
            let t1b =
                Matrix { rows: p, cols: q, data: t1.data[b * p * q..(b + 1) * p * q].to_vec() };
            let mut ob = Matrix { rows: p, cols: q, data: vec![T::ZERO; p * q] };
            matmul_acc(&self.kss, &t1b, &mut ob);
            orow.copy_from_slice(&ob.data);
        });
        out
    }

    /// Materialize the full Kronecker product (tests / tiny sizes only).
    pub fn dense(&self) -> Matrix<T> {
        let (p, q) = (self.p(), self.q());
        Matrix::from_fn(p * q, p * q, |a, b| {
            self.kss[(a / q, b / q)] * self.ktt[(a % q, b % q)]
        })
    }
}

/// The LKGP system operator `M (K_SS (x) K_TT) M + D` with the
/// projection represented lazily by a mask (paper Fig. 1 / Sec. 3).
/// D is `sigma2 I` by default; `with_noise_vec` / `with_task_noise`
/// generalize to heteroskedastic noise (per-cell / per-task variances —
/// the paper's Sec. 5 future-work item).
#[derive(Clone, Debug)]
pub struct MaskedKronSystem<T: Scalar = f64> {
    /// The latent Kronecker product in factored form.
    pub op: KronOp<T>,
    /// Observation mask over the p*q grid (1 observed / 0 missing).
    pub mask: Vec<T>,
    /// Homoskedastic observation-noise variance.
    pub sigma2: T,
    /// optional per-cell noise variances (overrides sigma2 where set)
    pub noise: Option<Vec<T>>,
}

impl<T: Scalar> MaskedKronSystem<T> {
    /// System operator from a factored Kron product, a mask, and noise.
    pub fn new(op: KronOp<T>, mask: Vec<T>, sigma2: T) -> Self {
        assert_eq!(mask.len(), op.dim());
        MaskedKronSystem { op, mask, sigma2, noise: None }
    }

    /// Heteroskedastic variant: per-grid-cell noise variances.
    pub fn with_noise_vec(mut self, noise: Vec<T>) -> Self {
        assert_eq!(noise.len(), self.op.dim());
        self.noise = Some(noise);
        self
    }

    /// Heteroskedastic variant keyed by task: noise[k] applies to every
    /// cell (s_j, t_k) — e.g. one variance per SARCOS torque channel.
    pub fn with_task_noise(self, task_noise: &[T]) -> Self {
        let (p, q) = (self.op.p(), self.op.q());
        assert_eq!(task_noise.len(), q);
        let mut noise = Vec::with_capacity(p * q);
        for _ in 0..p {
            noise.extend_from_slice(task_noise);
        }
        self.with_noise_vec(noise)
    }

    #[inline]
    fn noise_at(&self, idx: usize) -> T {
        match &self.noise {
            Some(n) => n[idx],
            None => self.sigma2,
        }
    }

    /// Grid dimension p*q.
    pub fn dim(&self) -> usize {
        self.op.dim()
    }

    /// System MVM `M (K (x) K) M v + D v`, batched over rows of `v`.
    /// The mask/noise sweeps are parallelized over batch rows (disjoint
    /// row writes); the Kronecker apply parallelizes internally.
    pub fn apply_batch(&self, v: &Matrix<T>) -> Matrix<T> {
        let cols = v.cols;
        let mut masked = v.clone();
        crate::par::par_chunks_mut_cheap("kron.mask_in", &mut masked.data, cols.max(1), |_, row| {
            for (x, m) in row.iter_mut().zip(&self.mask) {
                *x *= *m;
            }
        });
        let mut kv = self.op.apply_batch(&masked);
        crate::par::par_chunks_mut_cheap("kron.mask_noise", &mut kv.data, cols.max(1), |b, row| {
            let vrow = v.row(b);
            for (idx, ((x, m), v0)) in
                row.iter_mut().zip(&self.mask).zip(vrow).enumerate()
            {
                *x = *x * *m + self.noise_at(idx) * *v0;
            }
        });
        kv
    }

    /// Diagonal of the system matrix (for Jacobi preconditioning):
    /// diag = mask * diag(K_SS) (x) diag(K_TT) + sigma2.
    /// Parallelized over the p spatial blocks (q entries each).
    pub fn diag(&self) -> Vec<T> {
        let (p, q) = (self.op.p(), self.op.q());
        let mut d = vec![T::ZERO; p * q];
        crate::par::par_chunks_mut_cheap("kron.diag", &mut d, q.max(1), |j, seg| {
            let ds = self.op.kss[(j, j)];
            for (k, out) in seg.iter_mut().enumerate() {
                let idx = j * q + k;
                *out = self.mask[idx] * ds * self.op.ktt[(k, k)] + self.noise_at(idx);
            }
        });
        d
    }

    /// One column of the *observed-space padded* kernel matrix
    /// M (K (x) K) M (no noise), for lazy pivoted Cholesky.
    /// Parallelized over the p spatial blocks (q entries each).
    pub fn kernel_col(&self, idx: usize) -> Vec<T> {
        let (p, q) = (self.op.p(), self.op.q());
        let (j0, k0) = (idx / q, idx % q);
        let mcol = self.mask[idx];
        let mut col = vec![T::ZERO; p * q];
        crate::par::par_chunks_mut_cheap("kron.kernel_col", &mut col, q.max(1), |j, seg| {
            let ks = self.op.kss[(j, j0)];
            for (k, out) in seg.iter_mut().enumerate() {
                let v = ks * self.op.ktt[(k, k0)];
                *out = v * self.mask[j * q + k] * mcol;
            }
        });
        col
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{assert_close, prop_check};

    #[test]
    fn prop_kron_apply_matches_dense() {
        prop_check("kron-apply-vs-dense", 53, 20, |g| {
            let (p, q, b) = (g.size(1, 10), g.size(1, 10), g.size(1, 3));
            let op = KronOp::new(
                Matrix::from_vec(p, p, g.spd(p)),
                Matrix::from_vec(q, q, g.spd(q)),
            );
            let v = Matrix::from_vec(b, p * q, g.vec_normal(b * p * q));
            let got = op.apply_batch(&v);
            let dense = op.dense();
            let mut want = Matrix::zeros(b, p * q);
            for bi in 0..b {
                let r = dense.matvec(v.row(bi));
                want.row_mut(bi).copy_from_slice(&r);
            }
            assert_close(&got.data, &want.data, 1e-8)
        });
    }

    #[test]
    fn prop_masked_system_matches_dense_projection() {
        prop_check("masked-kron-vs-dense", 59, 20, |g| {
            let (p, q) = (g.size(1, 8), g.size(1, 8));
            let op = KronOp::new(
                Matrix::from_vec(p, p, g.spd(p)),
                Matrix::from_vec(q, q, g.spd(q)),
            );
            let missing = g.f64_in(0.0, 0.8);
            let mask = g.mask(p * q, missing);
            let sigma2 = g.f64_in(0.01, 1.0);
            let sys = MaskedKronSystem::new(op.clone(), mask.clone(), sigma2);
            let v = Matrix::from_vec(2, p * q, g.vec_normal(2 * p * q));
            let got = sys.apply_batch(&v);
            // dense: diag(m) K diag(m) + sigma2 I
            let dense = op.dense();
            let n = p * q;
            let mut want = Matrix::zeros(2, n);
            for bi in 0..2 {
                for i in 0..n {
                    let mut acc = 0.0;
                    for j in 0..n {
                        acc += mask[i] * dense[(i, j)] * mask[j] * v[(bi, j)];
                    }
                    want[(bi, i)] = acc + sigma2 * v[(bi, i)];
                }
            }
            assert_close(&got.data, &want.data, 1e-8)
        });
    }

    #[test]
    fn prop_diag_and_col_consistent() {
        prop_check("kron-diag-col", 61, 15, |g| {
            let (p, q) = (g.size(1, 7), g.size(1, 7));
            let sys = MaskedKronSystem::new(
                KronOp::new(
                    Matrix::from_vec(p, p, g.spd(p)),
                    Matrix::from_vec(q, q, g.spd(q)),
                ),
                g.mask(p * q, 0.3),
                0.17,
            );
            let d = sys.diag();
            for idx in 0..p * q {
                let col = sys.kernel_col(idx);
                // diag = kernel diag + sigma2
                let want = col[idx] + 0.17;
                if (d[idx] - want).abs() > 1e-9 {
                    return Err(format!("idx {idx}: diag {} vs col {}", d[idx], want));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn masked_apply_keeps_observed_subspace() {
        let mut g = crate::util::testing::Gen { rng: crate::util::rng::Rng::new(5) };
        let (p, q) = (6, 5);
        let op = KronOp::new(
            Matrix::from_vec(p, p, g.spd(p)),
            Matrix::from_vec(q, q, g.spd(q)),
        );
        let mask = g.mask(p * q, 0.4);
        let sys = MaskedKronSystem::new(op, mask.clone(), 0.1);
        let mut v = Matrix::from_vec(1, p * q, g.vec_normal(p * q));
        for (x, m) in v.row_mut(0).iter_mut().zip(&mask) {
            *x *= *m;
        }
        let out = sys.apply_batch(&v);
        for (i, m) in mask.iter().enumerate() {
            if *m == 0.0 {
                assert!(out[(0, i)].abs() < 1e-12, "leaked into missing coord {i}");
            }
        }
    }
}

#[cfg(test)]
mod hetero_tests {
    use super::*;
    use crate::util::testing::{assert_close, prop_check};

    #[test]
    fn prop_per_cell_noise_matches_dense() {
        prop_check("hetero-noise", 241, 12, |g| {
            let (p, q) = (g.size(1, 6), g.size(1, 6));
            let op = KronOp::new(
                Matrix::from_vec(p, p, g.spd(p)),
                Matrix::from_vec(q, q, g.spd(q)),
            );
            let mask = g.mask(p * q, 0.3);
            let noise: Vec<f64> = (0..p * q).map(|_| g.f64_in(0.05, 2.0)).collect();
            let sys = MaskedKronSystem::new(op.clone(), mask.clone(), 0.0)
                .with_noise_vec(noise.clone());
            let v = Matrix::from_vec(1, p * q, g.vec_normal(p * q));
            let got = sys.apply_batch(&v);
            let dense = op.dense();
            let n = p * q;
            let mut want = vec![0.0; n];
            for i in 0..n {
                let mut acc = 0.0;
                for j in 0..n {
                    acc += mask[i] * dense[(i, j)] * mask[j] * v[(0, j)];
                }
                want[i] = acc + noise[i] * v[(0, i)];
            }
            assert_close(got.row(0), &want, 1e-8)?;
            // diag consistency
            let d = sys.diag();
            for i in 0..n {
                let col = sys.kernel_col(i);
                if (d[i] - (col[i] + noise[i])).abs() > 1e-9 {
                    return Err(format!("diag mismatch at {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn task_noise_broadcasts_over_rows() {
        let mut g = crate::util::testing::Gen { rng: crate::util::rng::Rng::new(6) };
        let (p, q) = (4, 3);
        let sys = MaskedKronSystem::new(
            KronOp::new(Matrix::from_vec(p, p, g.spd(p)), Matrix::from_vec(q, q, g.spd(q))),
            vec![1.0; p * q],
            0.0,
        )
        .with_task_noise(&[0.1, 0.2, 0.3]);
        let noise = sys.noise.as_ref().unwrap();
        for j in 0..p {
            assert_eq!(noise[j * q], 0.1);
            assert_eq!(noise[j * q + 1], 0.2);
            assert_eq!(noise[j * q + 2], 0.3);
        }
        // heteroskedastic CG still solves the system
        let rhs = Matrix::from_vec(1, p * q, g.vec_normal(p * q));
        use crate::solvers::cg::{solve_cg, BatchedOp, CgOptions};
        use crate::solvers::precond::Preconditioner;
        struct Op<'a>(&'a MaskedKronSystem<f64>);
        impl<'a> BatchedOp<f64> for Op<'a> {
            fn dim(&self) -> usize {
                self.0.dim()
            }
            fn apply_batch(&mut self, v: &Matrix<f64>) -> Matrix<f64> {
                self.0.apply_batch(v)
            }
        }
        let (x, stats) = solve_cg(
            &mut Op(&sys),
            &rhs,
            &Preconditioner::jacobi(&sys.diag()),
            &CgOptions { max_iters: 500, tol: 1e-8, ..CgOptions::default() },
        );
        assert!(stats.converged);
        let back = sys.apply_batch(&x);
        for (a, b) in back.row(0).iter().zip(rhs.row(0)) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
