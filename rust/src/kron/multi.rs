//! Multi-factor latent Kronecker structure — the paper's "multi-product
//! generalizations" future-work item (Sec. 5).
//!
//! Generalizes the two-factor algebra to `K_1 (x) K_2 (x) ... (x) K_d`
//! with missing values, via sequential mode products: for a grid tensor
//! v of shape (n_1, ..., n_d),
//!
//!   (K_1 (x) ... (x) K_d) vec(V) = vec(V x_1 K_1 x_2 K_2 ... x_d K_d)
//!
//! where `x_j` is the mode-j product. Cost O(N * sum_j n_j) for
//! N = prod n_j — the d-factor version of O(p^2 q + p q^2). The masked
//! system operator (projection + noise) works exactly as in the
//! two-factor case.

use crate::linalg::{Matrix, Scalar};

/// Kronecker product of d square factors, held in factored form.
#[derive(Clone, Debug)]
pub struct MultiKronOp<T: Scalar = f64> {
    /// The square Gram factors K_1, ..., K_d.
    pub factors: Vec<Matrix<T>>,
}

impl<T: Scalar> MultiKronOp<T> {
    /// Factored operator from square factors (asserts shapes, requires
    /// at least one factor).
    pub fn new(factors: Vec<Matrix<T>>) -> Self {
        assert!(!factors.is_empty());
        for f in &factors {
            assert_eq!(f.rows, f.cols, "factors must be square");
        }
        MultiKronOp { factors }
    }

    /// Per-factor dimensions (n_1, ..., n_d).
    pub fn dims(&self) -> Vec<usize> {
        self.factors.iter().map(|f| f.rows).collect()
    }

    /// Total grid dimension N = prod n_j.
    pub fn dim(&self) -> usize {
        self.factors.iter().map(|f| f.rows).product()
    }

    /// Apply to one grid vector (row-major layout: the last factor's
    /// index varies fastest, matching the 2-factor `v[j*q + k]`).
    pub fn apply(&self, v: &[T]) -> Vec<T> {
        let n = self.dim();
        assert_eq!(v.len(), n);
        let mut cur = v.to_vec();
        // mode-j product for each factor in turn. Maintain the value as
        // a (left, n_j, right) tensor, contracting n_j with K_j.
        let dims = self.dims();
        for (j, k) in self.factors.iter().enumerate() {
            let nj = dims[j];
            let left: usize = dims[..j].iter().product();
            let right: usize = dims[j + 1..].iter().product();
            let mut next = vec![T::ZERO; n];
            // cur[(l, a, r)] at index (l*nj + a)*right + r
            for l in 0..left {
                for a_out in 0..nj {
                    let krow = k.row(a_out);
                    let out_base = (l * nj + a_out) * right;
                    for (a_in, &kv) in krow.iter().enumerate() {
                        if kv == T::ZERO {
                            continue;
                        }
                        let in_base = (l * nj + a_in) * right;
                        let (src, dst) =
                            (&cur[in_base..in_base + right], &mut next[out_base..out_base + right]);
                        for (d, s) in dst.iter_mut().zip(src) {
                            *d += kv * *s;
                        }
                    }
                }
            }
            cur = next;
        }
        cur
    }

    /// Materialize the full Kronecker product (tests / tiny dims only).
    pub fn dense(&self) -> Matrix<T> {
        let n = self.dim();
        let dims = self.dims();
        let index = |mut flat: usize| -> Vec<usize> {
            let mut idx = vec![0; dims.len()];
            for j in (0..dims.len()).rev() {
                idx[j] = flat % dims[j];
                flat /= dims[j];
            }
            idx
        };
        Matrix::from_fn(n, n, |r, c| {
            let (ri, ci) = (index(r), index(c));
            let mut prod = T::ONE;
            for (j, f) in self.factors.iter().enumerate() {
                prod *= f[(ri[j], ci[j])];
            }
            prod
        })
    }
}

/// Masked multi-factor system: M (K_1 (x) ... (x) K_d) M + sigma2 I.
pub struct MultiMaskedSystem<T: Scalar = f64> {
    /// The latent multi-factor Kronecker product.
    pub op: MultiKronOp<T>,
    /// Observation mask over the full grid.
    pub mask: Vec<T>,
    /// Observation-noise variance.
    pub sigma2: T,
}

impl<T: Scalar> MultiMaskedSystem<T> {
    /// Masked system from a factored operator (asserts the mask length).
    pub fn new(op: MultiKronOp<T>, mask: Vec<T>, sigma2: T) -> Self {
        assert_eq!(mask.len(), op.dim());
        MultiMaskedSystem { op, mask, sigma2 }
    }

    /// System MVM `M (K_1 (x) ... (x) K_d) M v + sigma2 v`.
    pub fn apply(&self, v: &[T]) -> Vec<T> {
        let masked: Vec<T> = v.iter().zip(&self.mask).map(|(x, m)| *x * *m).collect();
        let mut kv = self.op.apply(&masked);
        for ((o, m), v0) in kv.iter_mut().zip(&self.mask).zip(v) {
            *o = *o * *m + self.sigma2 * *v0;
        }
        kv
    }
}

/// FLOPs of one d-factor Kron MVM (generalizes kron_mvm_flops).
pub fn multi_kron_flops(dims: &[usize]) -> f64 {
    let n: f64 = dims.iter().map(|&d| d as f64).product();
    2.0 * n * dims.iter().map(|&d| d as f64).sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kron::KronOp;
    use crate::util::testing::{assert_close, prop_check};

    #[test]
    fn prop_matches_dense_three_factors() {
        prop_check("multikron-vs-dense", 201, 15, |g| {
            let dims = [g.size(1, 5), g.size(1, 5), g.size(1, 5)];
            let factors: Vec<Matrix<f64>> =
                dims.iter().map(|&d| Matrix::from_vec(d, d, g.spd(d))).collect();
            let op = MultiKronOp::new(factors);
            let v = g.vec_normal(op.dim());
            let got = op.apply(&v);
            let want = op.dense().matvec(&v);
            assert_close(&got, &want, 1e-8)
        });
    }

    #[test]
    fn two_factor_case_matches_kronop() {
        prop_check("multikron-2f", 203, 15, |g| {
            let (p, q) = (g.size(1, 8), g.size(1, 8));
            let a = Matrix::from_vec(p, p, g.spd(p));
            let b = Matrix::from_vec(q, q, g.spd(q));
            let multi = MultiKronOp::new(vec![a.clone(), b.clone()]);
            let two = KronOp::new(a, b);
            let v = Matrix::from_vec(1, p * q, g.vec_normal(p * q));
            let got = multi.apply(v.row(0));
            let want = two.apply_batch(&v);
            assert_close(&got, want.row(0), 1e-9)
        });
    }

    #[test]
    fn prop_masked_system_matches_dense() {
        prop_check("multikron-masked", 207, 10, |g| {
            let dims = [g.size(1, 4), g.size(1, 4), g.size(1, 4)];
            let factors: Vec<Matrix<f64>> =
                dims.iter().map(|&d| Matrix::from_vec(d, d, g.spd(d))).collect();
            let op = MultiKronOp::new(factors);
            let n = op.dim();
            let mask = g.mask(n, 0.4);
            let sys = MultiMaskedSystem::new(op.clone(), mask.clone(), 0.3);
            let v = g.vec_normal(n);
            let got = sys.apply(&v);
            let dense = op.dense();
            let mut want = vec![0.0; n];
            for i in 0..n {
                let mut acc = 0.0;
                for j in 0..n {
                    acc += mask[i] * dense[(i, j)] * mask[j] * v[j];
                }
                want[i] = acc + 0.3 * v[i];
            }
            assert_close(&got, &want, 1e-8)
        });
    }

    #[test]
    fn single_factor_is_plain_matvec() {
        let mut g = crate::util::testing::Gen { rng: crate::util::rng::Rng::new(1) };
        let a = Matrix::from_vec(6, 6, g.spd(6));
        let op = MultiKronOp::new(vec![a.clone()]);
        let v = g.vec_normal(6);
        assert_close(&op.apply(&v), &a.matvec(&v), 1e-10).unwrap();
    }

    #[test]
    fn flops_model_generalizes() {
        // d=2 must agree with the paper's O(p^2 q + p q^2)
        assert_eq!(
            multi_kron_flops(&[30, 7]),
            crate::kron::breakeven::kron_mvm_flops(30, 7)
        );
        assert!(multi_kron_flops(&[8, 8, 8]) < 2.0 * 512.0 * 512.0);
    }
}
