//! Sparse kernel interpolation (SKI) projection onto the latent grid.
//!
//! The paper's projection `P` is a 0/1 mask, which restricts training
//! data to (partial) grid cells. This module generalizes `P` to a
//! sparse interpolation matrix `W` (n x p*q) in the KISS-GP lineage:
//! each off-grid input `(x_s, x_t)` is written as a convex/convolution
//! combination of nearby inducing-grid nodes, and the observed-space
//! system operator becomes `W (K_SS (x) K_TT) W^T + sigma2 I`.
//!
//! Determinism contract: `W` construction is sequential and depends
//! only on the inputs; [`SparseProjection::interp_apply`] /
//! [`SparseProjection::interp_apply_t`] compute every output element by
//! an independent gather in fixed ascending order, chunked at a fixed
//! block size under `Schedule::Steal` with one writer per chunk — so
//! results are bit-identical at any `LKGP_THREADS` setting.
//!
//! Degenerate-case guarantee (exercised by the differential test in
//! `rust/tests/numerics.rs`): when an input coincides bitwise with a
//! grid node, its linear stencil collapses to a single weight of
//! exactly `1.0`, and `W` acts as the 0/1 mask — `1.0 * x == x` in IEEE
//! arithmetic, so the whole SKI fit reproduces the mask fit bit for
//! bit on grid-coincident data.

use crate::linalg::{Matrix, Scalar};

use super::KronOp;

/// Fixed chunk length (in output elements) for the SpMM sweeps. The
/// chunk grid depends only on this constant and the output shape —
/// never on thread count — which is what keeps steal-scheduled runs
/// bit-identical (each element is an independent gather).
const SPMM_CHUNK: usize = 256;

/// Interpolation stencil family for [`SparseProjection`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum InterpDegree {
    /// Two-point-per-axis linear interpolation (tensor stencil <= 4).
    /// Rows sum to exactly 1.0; grid-coincident inputs collapse to a
    /// single weight of exactly 1.0 (the mask-degenerate case).
    #[default]
    Linear,
    /// Four-point-per-axis cubic convolution (Keys, a = -1/2; tensor
    /// stencil <= 16). Rows are normalized to sum to 1.0 up to a few
    /// ulp; exact for cubics on uniform interior grids.
    Cubic,
}

impl InterpDegree {
    /// Stencil width along one axis (2 linear, 4 cubic).
    pub fn stencil_1d(self) -> usize {
        match self {
            InterpDegree::Linear => 2,
            InterpDegree::Cubic => 4,
        }
    }

    /// Maximum row support of the 2-D tensor-product stencil.
    pub fn stencil_2d(self) -> usize {
        self.stencil_1d() * self.stencil_1d()
    }
}

impl std::fmt::Display for InterpDegree {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InterpDegree::Linear => write!(f, "linear"),
            InterpDegree::Cubic => write!(f, "cubic"),
        }
    }
}

/// Deterministic CSR sparse interpolation matrix `W` (n rows over an
/// n-point dataset, `p*q` columns over the spatial x time inducing
/// grid, row-major grid layout `j*q + k`).
///
/// Invariants (validated on every construction path):
/// * `indptr` is monotone with `indptr[0] == 0`, `indptr[n] == nnz`;
/// * every row has between 1 and [`InterpDegree::stencil_2d`] entries,
///   with strictly ascending in-range column indices;
/// * rows sum to 1.0 — exactly for `Linear` (the final weight is
///   computed as `1.0 - partial_sum`), to a few ulp for `Cubic`;
/// * a prebuilt transpose (CSC with ascending row order per column)
///   makes [`SparseProjection::interp_apply_t`] an *exact* transpose:
///   both directions gather in the same fixed order.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseProjection {
    n: usize,
    grid_p: usize,
    grid_q: usize,
    degree: InterpDegree,
    indptr: Vec<usize>,
    cols: Vec<usize>,
    weights: Vec<f64>,
    // transpose (CSC), rebuilt deterministically from the CSR arrays
    t_indptr: Vec<usize>,
    t_rows: Vec<usize>,
    t_weights: Vec<f64>,
}

/// One-axis stencil: ascending (node, weight) pairs, merged and
/// boundary-clamped; exact node hits collapse to a single 1.0 weight.
fn stencil_1d(x: f64, grid: &[f64], degree: InterpDegree) -> Vec<(usize, f64)> {
    let len = grid.len();
    if len == 1 {
        return vec![(0, 1.0)];
    }
    // cell search: largest j with grid[j] <= x, clamped into [0, len-2]
    let j = match grid.partition_point(|&g| g <= x) {
        0 => 0,
        k => (k - 1).min(len - 2),
    };
    let step = grid[j + 1] - grid[j];
    // boundary clamp: inputs outside the grid project onto the edge cell
    let frac = ((x - grid[j]) / step).clamp(0.0, 1.0);
    if frac == 0.0 {
        return vec![(j, 1.0)];
    }
    if frac == 1.0 {
        return vec![(j + 1, 1.0)];
    }
    match degree {
        InterpDegree::Linear => vec![(j, 1.0 - frac), (j + 1, frac)],
        InterpDegree::Cubic => {
            // Keys cubic convolution weights (a = -1/2) at t = frac for
            // nodes j-1 .. j+2; indices clamp to the grid and clamped
            // duplicates merge by weight accumulation (sum preserved).
            let t = frac;
            let w = [
                ((-0.5 * t + 1.0) * t - 0.5) * t,
                (1.5 * t - 2.5) * t * t + 1.0,
                ((-1.5 * t + 2.0) * t + 0.5) * t,
                (0.5 * t - 0.5) * t * t,
            ];
            let mut merged: Vec<(usize, f64)> = Vec::with_capacity(4);
            for (off, &wk) in w.iter().enumerate() {
                let idx = (j + off).saturating_sub(1).min(len - 1);
                match merged.last_mut() {
                    Some((last, acc)) if *last == idx => *acc += wk,
                    _ => merged.push((idx, wk)),
                }
            }
            merged.retain(|&(_, wk)| wk != 0.0);
            merged
        }
    }
}

impl SparseProjection {
    /// Build `W` for data points `(xs[i], xt[i])` over the inducing
    /// grid `grid_s x grid_t` (both strictly increasing). Construction
    /// is sequential and deterministic; rows are normalized to sum to
    /// 1.0 (see [`SparseProjection`] invariants) and stencils never
    /// index outside the grid (boundary clamping).
    pub fn build(
        xs: &[f64],
        xt: &[f64],
        grid_s: &[f64],
        grid_t: &[f64],
        degree: InterpDegree,
    ) -> Result<Self, String> {
        if xs.len() != xt.len() {
            return Err(format!(
                "coordinate length mismatch: {} spatial vs {} time",
                xs.len(),
                xt.len()
            ));
        }
        if xs.is_empty() {
            return Err("no data points to interpolate".into());
        }
        for (name, grid) in [("spatial", grid_s), ("time", grid_t)] {
            if grid.is_empty() {
                return Err(format!("{name} inducing grid is empty"));
            }
            if grid.iter().any(|g| !g.is_finite()) {
                return Err(format!("{name} inducing grid has non-finite nodes"));
            }
            if grid.windows(2).any(|w| w[0] >= w[1]) {
                return Err(format!("{name} inducing grid is not strictly increasing"));
            }
        }
        if xs.iter().chain(xt).any(|x| !x.is_finite()) {
            return Err("non-finite data coordinate".into());
        }
        let n = xs.len();
        let q = grid_t.len();
        let mut indptr = Vec::with_capacity(n + 1);
        indptr.push(0usize);
        let mut cols = Vec::with_capacity(n * degree.stencil_2d());
        let mut weights = Vec::with_capacity(n * degree.stencil_2d());
        for i in 0..n {
            let sa = stencil_1d(xs[i], grid_s, degree);
            let sb = stencil_1d(xt[i], grid_t, degree);
            let start = weights.len();
            for &(ja, wa) in &sa {
                for &(kb, wb) in &sb {
                    cols.push(ja * q + kb);
                    weights.push(wa * wb);
                }
            }
            let row = &mut weights[start..];
            match degree {
                InterpDegree::Linear => {
                    // exact unit row sum: the last weight is the
                    // remainder 1.0 - (ascending partial sum), so the
                    // same ascending fold recovers exactly 1.0
                    if row.len() > 1 {
                        let partial: f64 = row[..row.len() - 1].iter().sum();
                        let last = row.len() - 1;
                        row[last] = 1.0 - partial;
                    }
                }
                InterpDegree::Cubic => {
                    // normalize: the analytic sum is 1, the float sum a
                    // few ulp off; division pins it to 1.0 +- O(ulp)
                    let sum: f64 = row.iter().sum();
                    if row.len() > 1 {
                        for w in row.iter_mut() {
                            *w /= sum;
                        }
                    }
                }
            }
            indptr.push(weights.len());
        }
        Self::from_parts(n, grid_s.len(), q, degree, indptr, cols, weights)
    }

    /// Reassemble a projection from raw CSR arrays (the checkpoint load
    /// path). Validates every invariant listed on [`SparseProjection`]
    /// and rebuilds the transpose deterministically; returns a
    /// description of the first violation on malformed input.
    pub fn from_parts(
        n: usize,
        grid_p: usize,
        grid_q: usize,
        degree: InterpDegree,
        indptr: Vec<usize>,
        cols: Vec<usize>,
        weights: Vec<f64>,
    ) -> Result<Self, String> {
        let m = grid_p * grid_q;
        if n == 0 || m == 0 {
            return Err("empty projection (zero rows or grid cells)".into());
        }
        if indptr.len() != n + 1 {
            return Err(format!("indptr length {} != n+1 = {}", indptr.len(), n + 1));
        }
        if indptr[0] != 0 {
            return Err(format!("indptr[0] = {} != 0", indptr[0]));
        }
        if *indptr.last().unwrap() != cols.len() || cols.len() != weights.len() {
            return Err(format!(
                "nnz mismatch: indptr ends at {}, {} cols, {} weights",
                indptr.last().unwrap(),
                cols.len(),
                weights.len()
            ));
        }
        for i in 0..n {
            let (lo, hi) = (indptr[i], indptr[i + 1]);
            if hi < lo {
                return Err(format!("indptr not monotone at row {i}"));
            }
            let width = hi - lo;
            if width == 0 || width > degree.stencil_2d() {
                return Err(format!(
                    "row {i} support {width} outside 1..={} for {degree} stencil",
                    degree.stencil_2d()
                ));
            }
            for e in lo..hi {
                if cols[e] >= m {
                    return Err(format!("row {i} column {} >= grid size {m}", cols[e]));
                }
                if e > lo && cols[e] <= cols[e - 1] {
                    return Err(format!("row {i} columns not strictly ascending"));
                }
                if !weights[e].is_finite() {
                    return Err(format!("row {i} has non-finite weight"));
                }
            }
        }
        // deterministic CSC transpose: counting sort over columns; rows
        // ascend within each column because CSR rows are visited in order
        let nnz = cols.len();
        let mut t_indptr = vec![0usize; m + 1];
        for &c in &cols {
            t_indptr[c + 1] += 1;
        }
        for c in 0..m {
            t_indptr[c + 1] += t_indptr[c];
        }
        let mut cursor = t_indptr.clone();
        let mut t_rows = vec![0usize; nnz];
        let mut t_weights = vec![0.0f64; nnz];
        for i in 0..n {
            for e in indptr[i]..indptr[i + 1] {
                let slot = cursor[cols[e]];
                t_rows[slot] = i;
                t_weights[slot] = weights[e];
                cursor[cols[e]] += 1;
            }
        }
        Ok(SparseProjection {
            n,
            grid_p,
            grid_q,
            degree,
            indptr,
            cols,
            weights,
            t_indptr,
            t_rows,
            t_weights,
        })
    }

    /// Number of data rows n.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Spatial grid size p.
    pub fn grid_p(&self) -> usize {
        self.grid_p
    }

    /// Time grid size q.
    pub fn grid_q(&self) -> usize {
        self.grid_q
    }

    /// Grid dimension p*q (the column count of `W`).
    pub fn grid_dim(&self) -> usize {
        self.grid_p * self.grid_q
    }

    /// Stencil family this projection was built with.
    pub fn degree(&self) -> InterpDegree {
        self.degree
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// CSR row pointer array (length n+1) — checkpoint serialization.
    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }

    /// CSR column indices (length nnz) — checkpoint serialization.
    pub fn cols(&self) -> &[usize] {
        &self.cols
    }

    /// CSR weights (length nnz) — checkpoint serialization.
    pub fn row_weights(&self) -> &[f64] {
        &self.weights
    }

    /// Entries of row `i` as parallel (columns, weights) slices.
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.cols[lo..hi], &self.weights[lo..hi])
    }

    /// `W v^T` per batch row: `(b x p*q) -> (b x n)`. Each output
    /// element is one CSR-row gather in fixed ascending entry order;
    /// the flattened output is chunked at a fixed block size under
    /// `Schedule::Steal` (one writer per chunk), so the result is
    /// bit-identical at any thread count.
    pub fn interp_apply<T: Scalar>(&self, v: &Matrix<T>) -> Matrix<T> {
        assert_eq!(v.cols, self.grid_dim(), "interp_apply: grid width mismatch");
        let n = self.n;
        let mut out = Matrix::zeros(v.rows, n);
        crate::par::par_chunks_mut_steal("interp.apply", &mut out.data, SPMM_CHUNK, |ci, chunk| {
            let base = ci * SPMM_CHUNK;
            for (off, o) in chunk.iter_mut().enumerate() {
                let e = base + off;
                let (b, i) = (e / n, e % n);
                let vrow = v.row(b);
                let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
                let mut acc = T::from_f64(self.weights[lo]) * vrow[self.cols[lo]];
                for k in lo + 1..hi {
                    acc += T::from_f64(self.weights[k]) * vrow[self.cols[k]];
                }
                *o = acc;
            }
        });
        out
    }

    /// `W^T v^T` per batch row: `(b x n) -> (b x p*q)`. Gathers through
    /// the prebuilt CSC transpose in ascending data-row order — the
    /// exact transpose of [`SparseProjection::interp_apply`] — with the
    /// same fixed-chunk steal schedule and determinism guarantee. Grid
    /// cells no stencil touches come back exactly `+0.0`.
    pub fn interp_apply_t<T: Scalar>(&self, v: &Matrix<T>) -> Matrix<T> {
        assert_eq!(v.cols, self.n, "interp_apply_t: data width mismatch");
        let m = self.grid_dim();
        let mut out = Matrix::zeros(v.rows, m);
        crate::par::par_chunks_mut_steal(
            "interp.apply_t",
            &mut out.data,
            SPMM_CHUNK,
            |ci, chunk| {
                let base = ci * SPMM_CHUNK;
                for (off, o) in chunk.iter_mut().enumerate() {
                    let e = base + off;
                    let (b, c) = (e / m, e % m);
                    let vrow = v.row(b);
                    let (lo, hi) = (self.t_indptr[c], self.t_indptr[c + 1]);
                    if lo == hi {
                        *o = T::ZERO;
                        continue;
                    }
                    let mut acc = T::from_f64(self.t_weights[lo]) * vrow[self.t_rows[lo]];
                    for k in lo + 1..hi {
                        acc += T::from_f64(self.t_weights[k]) * vrow[self.t_rows[k]];
                    }
                    *o = acc;
                }
            },
        );
        out
    }

    /// `W^T v` for a single f64 vector (length n) — the gradient
    /// projection path. Same gather order as
    /// [`SparseProjection::interp_apply_t`], sequential.
    pub fn project_vec_f64(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.n, "project_vec_f64: length mismatch");
        let m = self.grid_dim();
        let mut out = vec![0.0f64; m];
        for (c, o) in out.iter_mut().enumerate() {
            let (lo, hi) = (self.t_indptr[c], self.t_indptr[c + 1]);
            if lo == hi {
                continue;
            }
            let mut acc = self.t_weights[lo] * v[self.t_rows[lo]];
            for k in lo + 1..hi {
                acc += self.t_weights[k] * v[self.t_rows[k]];
            }
            *o = acc;
        }
        out
    }
}

/// SKI system operator `W (K_SS (x) K_TT) W^T + sigma2 I` over the
/// n-point data space, sharing the CG/`SystemOp` plumbing with
/// [`super::MaskedKronSystem`] (same `apply_batch`/`diag`/`kernel_col`
/// surface, so the preconditioner fallback chain and solver resilience
/// policies apply unchanged).
#[derive(Clone, Debug)]
pub struct InterpKronSystem<T: Scalar = f64> {
    /// The latent Kronecker product in factored form (p*q grid space).
    pub op: KronOp<T>,
    /// The sparse interpolation projection (n x p*q).
    pub proj: SparseProjection,
    /// Homoskedastic observation-noise variance.
    pub sigma2: T,
}

impl<T: Scalar> InterpKronSystem<T> {
    /// System operator from a factored Kron product and a projection
    /// (asserts the grid dimensions agree).
    pub fn new(op: KronOp<T>, proj: SparseProjection, sigma2: T) -> Self {
        assert_eq!(proj.grid_dim(), op.dim(), "projection/grid dimension mismatch");
        InterpKronSystem { op, proj, sigma2 }
    }

    /// Data-space dimension n (the system is n x n).
    pub fn dim(&self) -> usize {
        self.proj.n()
    }

    /// System MVM `W (K (x) K) W^T v + sigma2 v`, batched over rows of
    /// `v` (each row length n). Mirrors the masked system's arithmetic:
    /// on a grid-coincident linear projection every gather is a single
    /// `1.0 * x` multiply, so the result is bit-equal to
    /// [`super::MaskedKronSystem::apply_batch`] on a full grid.
    pub fn apply_batch(&self, v: &Matrix<T>) -> Matrix<T> {
        assert_eq!(v.cols, self.dim(), "system width mismatch");
        let u = self.proj.interp_apply_t(v);
        let ku = self.op.apply_batch(&u);
        let gathered = self.proj.interp_apply(&ku);
        let n = self.dim();
        let mut out = gathered;
        crate::par::par_chunks_mut_cheap("interp.noise", &mut out.data, n.max(1), |b, row| {
            let vrow = v.row(b);
            for (x, v0) in row.iter_mut().zip(vrow) {
                *x = *x + self.sigma2 * *v0;
            }
        });
        out
    }

    /// Diagonal of the system matrix (for Jacobi preconditioning):
    /// `diag_i = w_i^T (K_SS (x) K_TT)[rows] w_i + sigma2`, computed
    /// exactly from the <= stencil^2 x stencil^2 local quadratic form.
    pub fn diag(&self) -> Vec<T> {
        let q = self.op.q();
        let n = self.dim();
        let mut d = vec![T::ZERO; n];
        crate::par::par_chunks_mut_steal("interp.diag", &mut d, SPMM_CHUNK, |ci, seg| {
            let base = ci * SPMM_CHUNK;
            for (off, out) in seg.iter_mut().enumerate() {
                let i = base + off;
                let (cols, ws) = self.proj.row(i);
                let mut acc: Option<T> = None;
                for (a, &ca) in cols.iter().enumerate() {
                    let (ja, ka) = (ca / q, ca % q);
                    for (b, &cb) in cols.iter().enumerate() {
                        let (jb, kb) = (cb / q, cb % q);
                        let wp = T::from_f64(ws[a]) * T::from_f64(ws[b]);
                        let term = wp * self.op.kss[(ja, jb)] * self.op.ktt[(ka, kb)];
                        acc = Some(match acc {
                            None => term,
                            Some(s) => s + term,
                        });
                    }
                }
                *out = acc.expect("row support is never empty") + self.sigma2;
            }
        });
        d
    }

    /// One column of the data-space kernel matrix `W (K (x) K) W^T`
    /// (no noise), for lazy pivoted Cholesky.
    pub fn kernel_col(&self, idx: usize) -> Vec<T> {
        let q = self.op.q();
        let n = self.dim();
        let (bcols, bws) = self.proj.row(idx);
        let mut col = vec![T::ZERO; n];
        crate::par::par_chunks_mut_steal("interp.kernel_col", &mut col, SPMM_CHUNK, |ci, seg| {
            let base = ci * SPMM_CHUNK;
            for (off, out) in seg.iter_mut().enumerate() {
                let i = base + off;
                let (acols, aws) = self.proj.row(i);
                let mut acc: Option<T> = None;
                for (a, &ca) in acols.iter().enumerate() {
                    let (ja, ka) = (ca / q, ca % q);
                    for (b, &cb) in bcols.iter().enumerate() {
                        let (jb, kb) = (cb / q, cb % q);
                        let v = self.op.kss[(ja, jb)] * self.op.ktt[(ka, kb)];
                        let term = v * T::from_f64(aws[a]) * T::from_f64(bws[b]);
                        acc = Some(match acc {
                            None => term,
                            Some(s) => s + term,
                        });
                    }
                }
                *out = acc.expect("row support is never empty");
            }
        });
        col
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{assert_close, prop_check};

    /// A strictly increasing grid with `len` nodes on roughly [0, 1].
    fn linspace(len: usize) -> Vec<f64> {
        (0..len).map(|k| k as f64 / (len.max(2) - 1) as f64).collect()
    }

    fn random_projection(g: &mut crate::util::testing::Gen, degree: InterpDegree) -> SparseProjection {
        let (p, q, n) = (g.size(2, 9), g.size(2, 9), g.size(1, 40));
        let (gs, gt) = (linspace(p), linspace(q));
        let xs: Vec<f64> = (0..n).map(|_| g.f64_in(-0.3, 1.3)).collect();
        let xt: Vec<f64> = (0..n).map(|_| g.f64_in(-0.3, 1.3)).collect();
        SparseProjection::build(&xs, &xt, &gs, &gt, degree).unwrap()
    }

    #[test]
    fn prop_linear_rows_sum_exactly_one() {
        prop_check("interp-linear-row-sum", 11, 60, |g| {
            let w = random_projection(g, InterpDegree::Linear);
            for i in 0..w.n() {
                let (_, ws) = w.row(i);
                let sum: f64 = ws.iter().sum();
                if sum != 1.0 {
                    return Err(format!("row {i} sums to {sum:?}, not exactly 1.0"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_cubic_rows_sum_to_one_within_1e12() {
        prop_check("interp-cubic-row-sum", 12, 60, |g| {
            let w = random_projection(g, InterpDegree::Cubic);
            for i in 0..w.n() {
                let (_, ws) = w.row(i);
                let sum: f64 = ws.iter().sum();
                if (sum - 1.0).abs() > 1e-12 {
                    return Err(format!("row {i} sums to {sum}, off by {}", sum - 1.0));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_support_and_bounds() {
        prop_check("interp-support-bounds", 13, 60, |g| {
            for degree in [InterpDegree::Linear, InterpDegree::Cubic] {
                let w = random_projection(g, degree);
                let m = w.grid_dim();
                for i in 0..w.n() {
                    let (cols, _) = w.row(i);
                    if cols.is_empty() || cols.len() > degree.stencil_2d() {
                        return Err(format!(
                            "row {i} support {} outside 1..={}",
                            cols.len(),
                            degree.stencil_2d()
                        ));
                    }
                    // boundary clamping: even for inputs drawn outside
                    // the grid every index stays in range and ascending
                    for win in cols.windows(2) {
                        if win[0] >= win[1] {
                            return Err(format!("row {i} columns not ascending"));
                        }
                    }
                    if *cols.last().unwrap() >= m {
                        return Err(format!("row {i} indexes past the grid"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_apply_t_is_exact_transpose() {
        // <Wx, y> == <x, W^T y> in f64 *bits* on integer-exact data:
        // small-integer weights/values keep every product and sum exact,
        // so any ordering discrepancy would show up as a bit mismatch.
        prop_check("interp-transpose-exact", 14, 40, |g| {
            for degree in [InterpDegree::Linear, InterpDegree::Cubic] {
                let w = random_projection(g, degree);
                let (n, m) = (w.n(), w.grid_dim());
                // integer-exact replacement weights: reuse the sparsity
                // pattern, substitute small integers via from_parts
                let iw: Vec<f64> =
                    (0..w.nnz()).map(|_| (g.size(0, 8) as f64) - 4.0).collect();
                let w = SparseProjection::from_parts(
                    n,
                    w.grid_p(),
                    w.grid_q(),
                    degree,
                    w.indptr().to_vec(),
                    w.cols().to_vec(),
                    iw,
                )
                .unwrap();
                let x = Matrix::from_vec(
                    1,
                    m,
                    (0..m).map(|_| (g.size(0, 16) as f64) - 8.0).collect(),
                );
                let y = Matrix::from_vec(
                    1,
                    n,
                    (0..n).map(|_| (g.size(0, 16) as f64) - 8.0).collect(),
                );
                let wx = w.interp_apply(&x);
                let wty = w.interp_apply_t(&y);
                let lhs: f64 = wx.row(0).iter().zip(y.row(0)).map(|(a, b)| a * b).sum();
                let rhs: f64 = x.row(0).iter().zip(wty.row(0)).map(|(a, b)| a * b).sum();
                if lhs.to_bits() != rhs.to_bits() {
                    return Err(format!("<Wx,y> = {lhs:?} != <x,W^T y> = {rhs:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_f32_agrees_with_f64() {
        prop_check("interp-f32-vs-f64", 15, 30, |g| {
            for degree in [InterpDegree::Linear, InterpDegree::Cubic] {
                let w = random_projection(g, degree);
                let (n, m, b) = (w.n(), w.grid_dim(), g.size(1, 3));
                let v64 = Matrix::from_vec(b, m, g.vec_normal(b * m));
                let v32: Matrix<f32> = v64.cast();
                let got64 = w.interp_apply(&v64);
                let got32 = w.interp_apply(&v32);
                let tol = crate::util::testing::prec_tol::<f32>(1e-12, 2e-5);
                assert_close(
                    &got32.data.iter().map(|x| *x as f64).collect::<Vec<_>>(),
                    &got64.data,
                    tol,
                )?;
                let u64m = Matrix::from_vec(b, n, g.vec_normal(b * n));
                let u32m: Matrix<f32> = u64m.cast();
                assert_close(
                    &w.interp_apply_t(&u32m).data.iter().map(|x| *x as f64).collect::<Vec<_>>(),
                    &w.interp_apply_t(&u64m).data,
                    tol,
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn grid_coincident_linear_rows_are_unit_masks() {
        // the degenerate case the differential test relies on: inputs
        // bitwise on grid nodes collapse to a single exact 1.0 weight
        let (gs, gt) = (linspace(5), linspace(7));
        let mut xs = Vec::new();
        let mut xt = Vec::new();
        for &a in &gs {
            for &b in &gt {
                xs.push(a);
                xt.push(b);
            }
        }
        let w = SparseProjection::build(&xs, &xt, &gs, &gt, InterpDegree::Linear).unwrap();
        assert_eq!(w.nnz(), w.n());
        for i in 0..w.n() {
            let (cols, ws) = w.row(i);
            assert_eq!(cols, &[i], "row {i} must hit exactly its own node");
            assert_eq!(ws[0].to_bits(), 1.0f64.to_bits());
        }
    }

    #[test]
    fn cubic_reproduces_cubics_on_interior() {
        // Keys interpolation with a = -1/2 is exact for quadratics on
        // uniform grids; check interior points against x^2 - 0.5 x
        let gs = linspace(12);
        let gt = linspace(12);
        let f = |a: f64, b: f64| a * a - 0.5 * b + 0.25 * a * b;
        let mut grid_vals = Vec::new();
        for &a in &gs {
            for &b in &gt {
                grid_vals.push(f(a, b));
            }
        }
        let xs = vec![0.31, 0.47, 0.55, 0.68];
        let xt = vec![0.42, 0.29, 0.61, 0.53];
        let w = SparseProjection::build(&xs, &xt, &gs, &gt, InterpDegree::Cubic).unwrap();
        let v = Matrix::from_vec(1, gs.len() * gt.len(), grid_vals);
        let got = w.interp_apply(&v);
        let want: Vec<f64> = xs.iter().zip(&xt).map(|(&a, &b)| f(a, b)).collect();
        assert_close(got.row(0), &want, 1e-10).unwrap();
    }

    #[test]
    fn from_parts_rejects_malformed_inputs() {
        let bad = |r: Result<SparseProjection, String>, needle: &str| {
            let err = r.expect_err("must reject");
            assert!(err.contains(needle), "error {err:?} missing {needle:?}");
        };
        // non-monotone indptr
        bad(
            SparseProjection::from_parts(
                2,
                2,
                2,
                InterpDegree::Linear,
                vec![0, 2, 1],
                vec![0, 1],
                vec![0.5, 0.5],
            ),
            "nnz mismatch",
        );
        // column past the grid
        bad(
            SparseProjection::from_parts(
                1,
                2,
                2,
                InterpDegree::Linear,
                vec![0, 1],
                vec![4],
                vec![1.0],
            ),
            ">= grid size",
        );
        // support wider than the stencil
        bad(
            SparseProjection::from_parts(
                1,
                3,
                3,
                InterpDegree::Linear,
                vec![0, 5],
                vec![0, 1, 2, 3, 4],
                vec![0.2; 5],
            ),
            "support",
        );
        // unsorted columns
        bad(
            SparseProjection::from_parts(
                1,
                2,
                2,
                InterpDegree::Linear,
                vec![0, 2],
                vec![1, 0],
                vec![0.5, 0.5],
            ),
            "ascending",
        );
        // non-finite weight
        bad(
            SparseProjection::from_parts(
                1,
                2,
                2,
                InterpDegree::Linear,
                vec![0, 1],
                vec![0],
                vec![f64::NAN],
            ),
            "non-finite",
        );
    }

    #[test]
    fn build_rejects_unsorted_grid() {
        let err = SparseProjection::build(
            &[0.5],
            &[0.5],
            &[0.0, 1.0, 0.5],
            &[0.0, 1.0],
            InterpDegree::Linear,
        )
        .expect_err("must reject");
        assert!(err.contains("strictly increasing"), "{err}");
    }

    #[test]
    fn interp_system_matches_dense_reference() {
        prop_check("interp-system-vs-dense", 16, 15, |g| {
            let (p, q) = (g.size(2, 6), g.size(2, 6));
            let op = KronOp::new(
                Matrix::from_vec(p, p, g.spd(p)),
                Matrix::from_vec(q, q, g.spd(q)),
            );
            let n = g.size(1, 12);
            let (gs, gt) = (linspace(p), linspace(q));
            let xs: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 1.0)).collect();
            let xt: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 1.0)).collect();
            let w =
                SparseProjection::build(&xs, &xt, &gs, &gt, InterpDegree::Cubic).unwrap();
            let sys = InterpKronSystem::new(op.clone(), w.clone(), 0.3);
            // dense reference: A = W K W^T + sigma2 I
            let kdense = op.dense();
            let m = p * q;
            let mut wk = Matrix::zeros(n, m); // W K
            for i in 0..n {
                let (cols, ws) = w.row(i);
                for jm in 0..m {
                    let mut s = 0.0;
                    for (e, &c) in cols.iter().enumerate() {
                        s += ws[e] * kdense[(c, jm)];
                    }
                    wk[(i, jm)] = s;
                }
            }
            let mut a = Matrix::zeros(n, n); // W K W^T + sigma2 I
            for i in 0..n {
                for j in 0..n {
                    let (cols, ws) = w.row(j);
                    let mut s = 0.0;
                    for (e, &c) in cols.iter().enumerate() {
                        s += wk[(i, c)] * ws[e];
                    }
                    a[(i, j)] = s + if i == j { 0.3 } else { 0.0 };
                }
            }
            let v = Matrix::from_vec(1, n, g.vec_normal(n));
            let got = sys.apply_batch(&v);
            let want = a.matvec(v.row(0));
            assert_close(got.row(0), &want, 1e-8)?;
            // diag agrees with the dense diagonal
            let dg: Vec<f64> = sys.diag().iter().map(|x| x.to_f64()).collect();
            let dwant: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
            assert_close(&dg, &dwant, 1e-8)?;
            // kernel_col agrees with the noise-free column
            let idx = g.size(0, n - 1);
            let cg: Vec<f64> = sys.kernel_col(idx).iter().map(|x| x.to_f64()).collect();
            let cwant: Vec<f64> = (0..n)
                .map(|i| a[(i, idx)] - if i == idx { 0.3 } else { 0.0 })
                .collect();
            assert_close(&cg, &cwant, 1e-8)
        });
    }
}
