//! Toeplitz acceleration for the temporal factor (paper Sec. 2, last
//! paragraph): if the time grid is uniform and k_T stationary, K_TT is
//! Toeplitz and its MVM runs in O(q log q) via circulant embedding +
//! FFT, making LKGP quasi-linear in the number of time steps.
//!
//! Includes a self-contained radix-2 complex FFT (no external crates in
//! the offline set) and a `ToeplitzOp` that embeds the q x q Toeplitz
//! matrix into a 2m-point circulant (m = next power of two >= q).

use crate::linalg::Matrix;

/// In-place iterative radix-2 Cooley–Tukey FFT on interleaved
/// (re, im) pairs. `inverse` applies the conjugate transform WITHOUT
/// the 1/n scaling (caller scales).
pub fn fft_inplace(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    assert_eq!(im.len(), n);
    assert!(n.is_power_of_two(), "fft length must be a power of two");
    // bit reversal
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (ur, ui) = (re[i + k], im[i + k]);
                let (vr, vi) = (
                    re[i + k + len / 2] * cr - im[i + k + len / 2] * ci,
                    re[i + k + len / 2] * ci + im[i + k + len / 2] * cr,
                );
                re[i + k] = ur + vr;
                im[i + k] = ui + vi;
                re[i + k + len / 2] = ur - vr;
                im[i + k + len / 2] = ui - vi;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Symmetric Toeplitz operator defined by its first column, applied via
/// circulant embedding: O(q log q) per MVM after an O(q log q) setup.
pub struct ToeplitzOp {
    /// Toeplitz dimension q (the time-grid length).
    pub q: usize,
    m: usize,
    /// FFT of the embedded circulant's first column
    eig_re: Vec<f64>,
    eig_im: Vec<f64>,
}

impl ToeplitzOp {
    /// `col` is the first column [k(0), k(1), ..., k(q-1)] of the
    /// symmetric Toeplitz matrix.
    pub fn new(col: &[f64]) -> Self {
        let q = col.len();
        let m = (2 * q).next_power_of_two();
        // circulant first column: [c0, c1, .., c_{q-1}, 0.., c_{q-1}, .., c1]
        let mut cre = vec![0.0; m];
        let mut cim = vec![0.0; m];
        cre[..q].copy_from_slice(col);
        for lag in 1..q {
            cre[m - lag] = col[lag];
        }
        fft_inplace(&mut cre, &mut cim, false);
        ToeplitzOp { q, m, eig_re: cre, eig_im: cim }
    }

    /// Build from a stationary kernel on a uniform grid with spacing dt.
    pub fn from_kernel(q: usize, dt: f64, k: impl Fn(f64) -> f64) -> Self {
        let col: Vec<f64> = (0..q).map(|lag| k(lag as f64 * dt)).collect();
        Self::new(&col)
    }

    /// y = T v in O(q log q).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.q);
        let mut re = vec![0.0; self.m];
        let mut im = vec![0.0; self.m];
        re[..self.q].copy_from_slice(v);
        fft_inplace(&mut re, &mut im, false);
        for i in 0..self.m {
            let (ar, ai) = (re[i], im[i]);
            re[i] = ar * self.eig_re[i] - ai * self.eig_im[i];
            im[i] = ar * self.eig_im[i] + ai * self.eig_re[i];
        }
        fft_inplace(&mut re, &mut im, true);
        let scale = 1.0 / self.m as f64;
        re[..self.q].iter().map(|x| x * scale).collect()
    }

    /// Dense materialization (tests).
    pub fn dense(&self, col: &[f64]) -> Matrix<f64> {
        Matrix::from_fn(self.q, self.q, |i, j| col[i.abs_diff(j)])
    }
}

/// Latent-Kronecker MVM with a Toeplitz time factor:
/// out[b] = vec(K_SS @ unvec(v[b]) @ T^T) where T is Toeplitz-symmetric.
/// Cost O(b (p^2 q + p q log q)) instead of O(b (p^2 q + p q^2)).
pub struct KronToeplitzOp {
    /// Spatial Gram factor K_SS (dense, p x p).
    pub kss: Matrix<f64>,
    /// Toeplitz time factor applied via FFT.
    pub ktt: ToeplitzOp,
}

impl KronToeplitzOp {
    /// Apply to a batch of grid vectors (rows of `v`, length p*q each).
    pub fn apply_batch(&self, v: &Matrix<f64>) -> Matrix<f64> {
        let (p, q) = (self.kss.rows, self.ktt.q);
        assert_eq!(v.cols, p * q);
        let mut out = Matrix::zeros(v.rows, p * q);
        for b in 0..v.rows {
            // right half: each of the p rows through the FFT MVM
            let mut t1 = Matrix::zeros(p, q);
            for i in 0..p {
                let row = &v.row(b)[i * q..(i + 1) * q];
                t1.row_mut(i).copy_from_slice(&self.ktt.matvec(row));
            }
            // left half: K_SS @ T1 (blocked GEMM)
            let mut ob = Matrix::zeros(p, q);
            crate::linalg::gemm::matmul_acc(&self.kss, &t1, &mut ob);
            out.row_mut(b).copy_from_slice(&ob.data);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testing::{assert_close, prop_check, Gen};

    #[test]
    fn fft_roundtrip() {
        prop_check("fft-roundtrip", 231, 15, |g| {
            let n = 1 << g.size(1, 9);
            let re0 = g.vec_normal(n);
            let im0 = g.vec_normal(n);
            let (mut re, mut im) = (re0.clone(), im0.clone());
            fft_inplace(&mut re, &mut im, false);
            fft_inplace(&mut re, &mut im, true);
            let scale = 1.0 / n as f64;
            for v in re.iter_mut().chain(im.iter_mut()) {
                *v *= scale;
            }
            assert_close(&re, &re0, 1e-9)?;
            assert_close(&im, &im0, 1e-9)
        });
    }

    #[test]
    fn fft_matches_dft_definition() {
        let mut rng = Rng::new(4);
        let n = 16;
        let re0 = rng.normals(n);
        let (mut re, mut im) = (re0.clone(), vec![0.0; n]);
        fft_inplace(&mut re, &mut im, false);
        for k in 0..n {
            let (mut sr, mut si) = (0.0, 0.0);
            for (t, x) in re0.iter().enumerate() {
                let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                sr += x * ang.cos();
                si += x * ang.sin();
            }
            assert!((re[k] - sr).abs() < 1e-9 && (im[k] - si).abs() < 1e-9, "bin {k}");
        }
    }

    #[test]
    fn prop_toeplitz_matvec_matches_dense() {
        prop_check("toeplitz-vs-dense", 233, 15, |g| {
            let q = g.size(1, 50);
            // SE-like decaying first column keeps things well-scaled
            let col: Vec<f64> =
                (0..q).map(|lag| (-0.5 * (lag as f64 / 3.0).powi(2)).exp()).collect();
            let op = ToeplitzOp::new(&col);
            let v = g.vec_normal(q);
            let got = op.matvec(&v);
            let want = op.dense(&col).matvec(&v);
            assert_close(&got, &want, 1e-9)
        });
    }

    #[test]
    fn kron_toeplitz_matches_kronop() {
        let mut g = Gen { rng: Rng::new(9) };
        let (p, q) = (6, 12);
        let kernel = crate::kernels::RbfArd::new(2);
        let s = Matrix::from_vec(p, 2, g.vec_normal(p * 2));
        let kss = kernel.gram(&s, &s);
        let col: Vec<f64> =
            (0..q).map(|lag| (-0.5 * (lag as f64 / 2.0).powi(2)).exp()).collect();
        let ktt_dense = Matrix::from_fn(q, q, |i, j| col[i.abs_diff(j)]);
        let fast = KronToeplitzOp { kss: kss.clone(), ktt: ToeplitzOp::new(&col) };
        let slow = crate::kron::KronOp::new(kss, ktt_dense);
        let v = Matrix::from_vec(2, p * q, g.vec_normal(2 * p * q));
        let a = fast.apply_batch(&v);
        let b = slow.apply_batch(&v);
        assert_close(&a.data, &b.data, 1e-8).unwrap();
    }

    #[test]
    fn quasi_linear_scaling() {
        // FLOP count sanity: FFT path beats dense q^2 once q is large
        let q = 1024;
        let col: Vec<f64> = (0..q).map(|lag| (-(lag as f64) / 40.0).exp()).collect();
        let op = ToeplitzOp::new(&col);
        let mut rng = Rng::new(1);
        let v = rng.normals(q);
        let t0 = std::time::Instant::now();
        for _ in 0..50 {
            std::hint::black_box(op.matvec(&v));
        }
        let fast = t0.elapsed();
        let dense = op.dense(&col);
        let t0 = std::time::Instant::now();
        for _ in 0..50 {
            std::hint::black_box(dense.matvec(&v));
        }
        let slow = t0.elapsed();
        assert!(fast < slow, "fft {fast:?} !< dense {slow:?}");
    }
}
