//! Toeplitz acceleration for the temporal factor (paper Sec. 2, last
//! paragraph): if the time grid is uniform and k_T stationary, K_TT is
//! Toeplitz and its MVM runs in O(q log q) via circulant embedding +
//! FFT, making LKGP quasi-linear in the number of time steps.
//!
//! This is the production time-factor engine behind
//! [`TimeOp::Toeplitz`](crate::kron::TimeOp): `KronOp::apply_batch`
//! routes the `K_TT` half of every Kronecker MVM through
//! [`ToeplitzOp::matvec_into`] when the fit selected the Toeplitz path
//! (`LkgpConfig::time_op` / `--time-op` / `LKGP_TIME_OP`).
//!
//! The FFT is a *planned* transform: [`FftPlan`] precomputes the
//! bit-reversal swap list and per-stage twiddle tables once per length
//! (cached process-wide in [`plan`]), and every transform replays the
//! same fixed butterfly order. Combined with per-worker scratch buffers
//! that are fully overwritten per column, the batched MVM is
//! bit-identical at any `LKGP_THREADS` and any batch grouping — the
//! same determinism contract as the dense GEMM path.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, Mutex};

use crate::linalg::{Matrix, Scalar};

/// A planned radix-2 Cooley–Tukey FFT of one fixed power-of-two length:
/// the bit-reversal permutation (as a swap list) and the per-stage
/// twiddle factors are computed once and replayed on every transform in
/// a fixed butterfly order, so outputs are bit-identical regardless of
/// who runs the transform. Obtain shared plans via [`plan`].
pub struct FftPlan {
    n: usize,
    /// bit-reversal swaps (i < j), in ascending-i order
    swaps: Vec<(u32, u32)>,
    /// forward twiddles, stage-major: the stage with half-length `h`
    /// owns entries `[h-1, 2h-1)` (offsets telescope: 1+2+..+h/2 = h-1)
    tw_re: Vec<f64>,
    tw_im: Vec<f64>,
}

impl FftPlan {
    /// Build the plan for an `n`-point transform (`n` a power of two).
    fn new(n: usize) -> Self {
        assert!(n.is_power_of_two(), "fft length must be a power of two");
        let mut swaps = Vec::new();
        let mut j = 0usize;
        for i in 1..n {
            let mut bit = n >> 1;
            while j & bit != 0 {
                j ^= bit;
                bit >>= 1;
            }
            j |= bit;
            if i < j {
                swaps.push((i as u32, j as u32));
            }
        }
        let mut tw_re = vec![0.0; n.saturating_sub(1)];
        let mut tw_im = vec![0.0; n.saturating_sub(1)];
        let mut h = 1usize;
        while h < n {
            // forward twiddle w^k = exp(-i pi k / h) for the stage whose
            // butterflies span 2h points
            let (rs, is) = (&mut tw_re[h - 1..2 * h - 1], &mut tw_im[h - 1..2 * h - 1]);
            for (k, (r, im)) in rs.iter_mut().zip(is.iter_mut()).enumerate() {
                let ang = -std::f64::consts::PI * k as f64 / h as f64;
                *r = ang.cos();
                *im = ang.sin();
            }
            h <<= 1;
        }
        FftPlan { n, swaps, tw_re, tw_im }
    }

    /// Transform length n.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Run the transform in place on split (re, im) buffers of length
    /// `n`. `inverse` applies the conjugate transform WITHOUT the 1/n
    /// scaling (caller scales). The butterfly order is fixed by the
    /// plan, so equal inputs produce bit-equal outputs.
    pub fn run(&self, re: &mut [f64], im: &mut [f64], inverse: bool) {
        let n = self.n;
        assert_eq!(re.len(), n, "re length");
        assert_eq!(im.len(), n, "im length");
        for &(i, j) in &self.swaps {
            re.swap(i as usize, j as usize);
            im.swap(i as usize, j as usize);
        }
        let mut h = 1usize; // stage half-length; butterflies span 2h
        while h < n {
            let base = h - 1;
            let mut i = 0;
            while i < n {
                for k in 0..h {
                    let wr = self.tw_re[base + k];
                    let wi =
                        if inverse { -self.tw_im[base + k] } else { self.tw_im[base + k] };
                    let (ur, ui) = (re[i + k], im[i + k]);
                    let (xr, xi) = (re[i + k + h], im[i + k + h]);
                    let vr = xr * wr - xi * wi;
                    let vi = xr * wi + xi * wr;
                    re[i + k] = ur + vr;
                    im[i + k] = ui + vi;
                    re[i + k + h] = ur - vr;
                    im[i + k + h] = ui - vi;
                }
                i += 2 * h;
            }
            h <<= 1;
        }
    }
}

/// Process-wide plan cache, keyed by transform length. Plans are
/// immutable once built, so every `ToeplitzOp` of the same embedding
/// length shares one table set instead of recomputing twiddles.
static PLANS: Mutex<BTreeMap<usize, Arc<FftPlan>>> = Mutex::new(BTreeMap::new());

/// Fetch (or build and cache) the shared plan for an `n`-point FFT.
pub fn plan(n: usize) -> Arc<FftPlan> {
    // a poisoned lock only means another thread panicked after the map
    // was left in a consistent state (inserts are atomic), so recover
    let mut cache = PLANS.lock().unwrap_or_else(|e| e.into_inner());
    cache.entry(n).or_insert_with(|| Arc::new(FftPlan::new(n))).clone()
}

thread_local! {
    /// Per-worker (re, im) embedding scratch, reused across columns and
    /// MVMs. Every use fully overwrites the buffers (resize-after-clear
    /// zero-fills), so results never depend on scratch history.
    static SCRATCH: RefCell<(Vec<f64>, Vec<f64>)> = RefCell::new((Vec::new(), Vec::new()));
}

/// In-place radix-2 FFT on split (re, im) buffers, using the shared
/// plan for `re.len()`. `inverse` applies the conjugate transform
/// WITHOUT the 1/n scaling (caller scales).
pub fn fft_inplace(re: &mut [f64], im: &mut [f64], inverse: bool) {
    assert_eq!(im.len(), re.len());
    plan(re.len()).run(re, im, inverse);
}

/// Symmetric Toeplitz operator defined by its first column, applied via
/// circulant embedding: O(q log q) per MVM after an O(m log m) setup,
/// where `m` is the minimal power of two >= 2q-1 (see [`embed_len`]).
///
/// [`embed_len`]: ToeplitzOp::embed_len
#[derive(Clone)]
pub struct ToeplitzOp {
    /// Toeplitz dimension q (the time-grid length).
    pub q: usize,
    m: usize,
    /// FFT of the embedded circulant's first column
    eig_re: Vec<f64>,
    eig_im: Vec<f64>,
    plan: Arc<FftPlan>,
}

impl fmt::Debug for ToeplitzOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ToeplitzOp {{ q: {}, m: {} }}", self.q, self.m)
    }
}

impl ToeplitzOp {
    /// `col` is the first column [k(0), k(1), ..., k(q-1)] of the
    /// symmetric Toeplitz matrix (q >= 1).
    pub fn new(col: &[f64]) -> Self {
        let q = col.len();
        assert!(q >= 1, "Toeplitz operator needs at least one lag");
        // minimal circulant embedding: the first column
        // [c0, .., c_{q-1}, 0.., c_{q-1}, .., c1] needs m >= 2q-1
        // entries, and q=1 degenerates to the 1-point identity FFT
        let m = (2 * q - 1).next_power_of_two();
        let plan = plan(m);
        let mut cre = vec![0.0; m];
        let mut cim = vec![0.0; m];
        cre[..q].copy_from_slice(col);
        for lag in 1..q {
            cre[m - lag] = col[lag];
        }
        plan.run(&mut cre, &mut cim, false);
        ToeplitzOp { q, m, eig_re: cre, eig_im: cim, plan }
    }

    /// Build from a stationary kernel on a uniform grid with spacing dt.
    pub fn from_kernel(q: usize, dt: f64, k: impl Fn(f64) -> f64) -> Self {
        let col: Vec<f64> = (0..q).map(|lag| k(lag as f64 * dt)).collect();
        Self::new(&col)
    }

    /// Circulant embedding length m: the smallest power of two >= 2q-1.
    pub fn embed_len(&self) -> usize {
        self.m
    }

    /// y = T v in O(q log q), writing into `out` (both length q). The
    /// transform runs in f64 regardless of `T` — same policy as the
    /// f64-internal Cholesky in prior sampling — with one rounding at
    /// the output boundary. Embedding buffers come from per-worker
    /// thread-local scratch, amortized across the whole batch.
    pub fn matvec_into<T: Scalar>(&self, v: &[T], out: &mut [T]) {
        assert_eq!(v.len(), self.q);
        assert_eq!(out.len(), self.q);
        SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            let (re, im) = &mut *scratch;
            re.clear();
            re.resize(self.m, 0.0);
            im.clear();
            im.resize(self.m, 0.0);
            for (r, x) in re[..self.q].iter_mut().zip(v) {
                *r = x.to_f64();
            }
            self.plan.run(re, im, false);
            for ((ar, ai), (er, ei)) in re
                .iter_mut()
                .zip(im.iter_mut())
                .zip(self.eig_re.iter().zip(&self.eig_im))
            {
                let (r0, i0) = (*ar, *ai);
                *ar = r0 * er - i0 * ei;
                *ai = r0 * ei + i0 * er;
            }
            self.plan.run(re, im, true);
            let scale = 1.0 / self.m as f64;
            for (o, r) in out.iter_mut().zip(&re[..self.q]) {
                *o = T::from_f64(*r * scale);
            }
        });
    }

    /// y = T v in O(q log q) (allocating convenience wrapper).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.q];
        self.matvec_into(v, &mut out);
        out
    }

    /// Dense materialization (tests).
    pub fn dense(&self, col: &[f64]) -> Matrix<f64> {
        Matrix::from_fn(self.q, self.q, |i, j| col[i.abs_diff(j)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kron::{KronOp, MaskedKronSystem, TimeOp};
    use crate::par::with_threads;
    use crate::util::rng::Rng;
    use crate::util::testing::{assert_close, assert_close_prec, prop_check, Gen};

    #[test]
    fn fft_roundtrip() {
        prop_check("fft-roundtrip", 231, 15, |g| {
            let n = 1 << g.size(0, 9);
            let re0 = g.vec_normal(n);
            let im0 = g.vec_normal(n);
            let (mut re, mut im) = (re0.clone(), im0.clone());
            fft_inplace(&mut re, &mut im, false);
            fft_inplace(&mut re, &mut im, true);
            let scale = 1.0 / n as f64;
            for v in re.iter_mut().chain(im.iter_mut()) {
                *v *= scale;
            }
            assert_close(&re, &re0, 1e-9)?;
            assert_close(&im, &im0, 1e-9)
        });
    }

    #[test]
    fn fft_matches_dft_definition_lengths_1_through_64() {
        // every power-of-two length in 1..=64 against the O(n^2) DFT
        let mut rng = Rng::new(4);
        for n in [1usize, 2, 4, 8, 16, 32, 64] {
            let re0 = rng.normals(n);
            let im0 = rng.normals(n);
            let (mut re, mut im) = (re0.clone(), im0.clone());
            fft_inplace(&mut re, &mut im, false);
            for k in 0..n {
                let (mut sr, mut si) = (0.0, 0.0);
                for t in 0..n {
                    let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                    let (c, s) = (ang.cos(), ang.sin());
                    sr += re0[t] * c - im0[t] * s;
                    si += re0[t] * s + im0[t] * c;
                }
                assert!(
                    (re[k] - sr).abs() < 1e-9 && (im[k] - si).abs() < 1e-9,
                    "n={n} bin {k}: got ({}, {}), want ({sr}, {si})",
                    re[k],
                    im[k]
                );
            }
        }
    }

    #[test]
    fn plans_are_shared_per_length() {
        let a = plan(64);
        let b = plan(64);
        assert!(Arc::ptr_eq(&a, &b), "same-length plans must share tables");
        assert_eq!(a.n(), 64);
    }

    #[test]
    fn embed_len_is_minimal() {
        // regression for the 2q -> 2q-1 embedding fix: the circulant
        // length is the smallest power of two that fits both wings,
        // and q=1 degenerates to a 1-point transform
        for (q, want_m) in
            [(1usize, 1usize), (2, 4), (3, 8), (4, 8), (5, 16), (8, 16), (9, 32), (16, 32), (17, 64), (64, 128)]
        {
            let col: Vec<f64> = (0..q).map(|lag| (-(lag as f64) / 3.0).exp()).collect();
            let op = ToeplitzOp::new(&col);
            assert_eq!(op.embed_len(), want_m, "q={q}");
            assert!(op.embed_len() >= 2 * q - 1, "q={q}: wings must not overlap");
        }
    }

    #[test]
    fn q_equals_one_matvec_is_scalar_multiply() {
        let op = ToeplitzOp::new(&[2.5]);
        assert_eq!(op.embed_len(), 1);
        let y = op.matvec(&[3.0]);
        assert!((y[0] - 7.5).abs() < 1e-12);
    }

    #[test]
    fn prop_toeplitz_matvec_matches_dense() {
        prop_check("toeplitz-vs-dense", 233, 15, |g| {
            let q = g.size(1, 64);
            // SE-like decaying first column keeps things well-scaled
            let col: Vec<f64> =
                (0..q).map(|lag| (-0.5 * (lag as f64 / 3.0).powi(2)).exp()).collect();
            let op = ToeplitzOp::new(&col);
            let v = g.vec_normal(q);
            let got = op.matvec(&v);
            let want = op.dense(&col).matvec(&v);
            assert_close(&got, &want, 1e-9)
        });
    }

    #[test]
    fn toeplitz_matvec_matches_dense_every_length_to_64() {
        // exhaustive q sweep so every embedding-length transition
        // (power-of-two crossings included) gets at least one case
        let mut rng = Rng::new(11);
        for q in 1..=64usize {
            let col: Vec<f64> = (0..q).map(|lag| (-(lag as f64) / 5.0).exp()).collect();
            let op = ToeplitzOp::new(&col);
            let v = rng.normals(q);
            let got = op.matvec(&v);
            let want = op.dense(&col).matvec(&v);
            assert_close(&got, &want, 1e-9).unwrap_or_else(|e| panic!("q={q}: {e}"));
        }
    }

    /// Build matched dense/Toeplitz KronOps over the same factors.
    fn kron_pair<T: Scalar>(g: &mut Gen, p: usize, q: usize) -> (KronOp<T>, KronOp<T>) {
        let kernel = crate::kernels::RbfArd::new(2);
        let s = Matrix::from_vec(p, 2, g.vec_normal(p * 2));
        let kss = kernel.gram(&s, &s);
        let col: Vec<f64> =
            (0..q).map(|lag| (-0.5 * (lag as f64 / 2.0).powi(2)).exp()).collect();
        let ktt = Matrix::from_fn(q, q, |i, j| col[i.abs_diff(j)]);
        let dense = KronOp::new(kss.cast::<T>(), ktt.cast::<T>());
        let fast = dense.clone().with_toeplitz(ToeplitzOp::new(&col));
        (dense, fast)
    }

    #[test]
    fn kron_toeplitz_matches_dense_full_and_masked_f64() {
        let mut g = Gen { rng: Rng::new(9) };
        let (p, q) = (6, 12);
        let (dense, fast) = kron_pair::<f64>(&mut g, p, q);
        let v = Matrix::from_vec(3, p * q, g.vec_normal(3 * p * q));
        assert_close(&fast.apply_batch(&v).data, &dense.apply_batch(&v).data, 1e-9)
            .expect("full-grid KronOp agreement");
        let mask = g.mask(p * q, 0.35);
        let sys_d = MaskedKronSystem::new(dense, mask.clone(), 0.21);
        let sys_t = MaskedKronSystem::new(fast, mask, 0.21);
        assert_close(&sys_t.apply_batch(&v).data, &sys_d.apply_batch(&v).data, 1e-9)
            .expect("masked-system agreement");
    }

    #[test]
    fn kron_toeplitz_matches_dense_full_and_masked_f32() {
        let mut g = Gen { rng: Rng::new(10) };
        let (p, q) = (5, 9);
        let (dense, fast) = kron_pair::<f32>(&mut g, p, q);
        let v: Matrix<f32> = Matrix::from_vec(2, p * q, g.vec_normal(2 * p * q)).cast();
        let want: Vec<f64> = dense.apply_batch(&v).data.iter().map(|x| x.to_f64()).collect();
        assert_close_prec::<f32>(&fast.apply_batch(&v).data, &want, 1e-9, 2e-4)
            .expect("full-grid f32 agreement");
        let mask: Vec<f32> = g.mask(p * q, 0.35).iter().map(|&m| m as f32).collect();
        let sys_d = MaskedKronSystem::new(dense, mask.clone(), 0.21f32);
        let sys_t = MaskedKronSystem::new(fast, mask, 0.21f32);
        let want: Vec<f64> = sys_d.apply_batch(&v).data.iter().map(|x| x.to_f64()).collect();
        assert_close_prec::<f32>(&sys_t.apply_batch(&v).data, &want, 1e-9, 2e-4)
            .expect("masked-system f32 agreement");
    }

    #[test]
    fn toeplitz_apply_bit_identical_across_threads_and_grouping() {
        let mut g = Gen { rng: Rng::new(17) };
        let (p, q) = (7, 11);
        let (_, fast) = kron_pair::<f64>(&mut g, p, q);
        let v = Matrix::from_vec(6, p * q, g.vec_normal(6 * p * q));
        let bits = |m: &Matrix<f64>| -> Vec<u64> { m.data.iter().map(|x| x.to_bits()).collect() };
        let base = with_threads(1, || fast.apply_batch(&v));
        for t in [2usize, 4, 8] {
            let got = with_threads(t, || fast.apply_batch(&v));
            assert_eq!(bits(&base), bits(&got), "toeplitz apply differs at t={t}");
        }
        // batch grouping: applying row-by-row must reproduce the same bits
        for b in 0..v.rows {
            let one = Matrix::from_vec(1, p * q, v.row(b).to_vec());
            let got = with_threads(3, || fast.apply_batch(&one));
            let want: Vec<u64> = base.row(b).iter().map(|x| x.to_bits()).collect();
            let got_bits: Vec<u64> = got.row(0).iter().map(|x| x.to_bits()).collect();
            assert_eq!(want, got_bits, "row {b} differs when applied alone");
        }
    }

    #[test]
    fn time_op_debug_and_default_are_dense() {
        let mut g = Gen { rng: Rng::new(3) };
        let (dense, fast) = kron_pair::<f64>(&mut g, 3, 4);
        assert!(matches!(dense.time_op, TimeOp::Dense));
        assert!(matches!(fast.time_op, TimeOp::Toeplitz(_)));
        // Debug must stay compact (no eigenvalue dump)
        let s = format!("{:?}", fast.time_op);
        assert!(s.contains("q: 4"), "{s}");
    }

    #[test]
    fn quasi_linear_scaling() {
        // FLOP count sanity: FFT path beats dense q^2 once q is large
        let q = 1024;
        let col: Vec<f64> = (0..q).map(|lag| (-(lag as f64) / 40.0).exp()).collect();
        let op = ToeplitzOp::new(&col);
        let mut rng = Rng::new(1);
        let v = rng.normals(q);
        let t0 = std::time::Instant::now();
        for _ in 0..50 {
            std::hint::black_box(op.matvec(&v));
        }
        let fast = t0.elapsed();
        let dense = op.dense(&col);
        let t0 = std::time::Instant::now();
        for _ in 0..50 {
            std::hint::black_box(dense.matvec(&v));
        }
        let slow = t0.elapsed();
        assert!(fast < slow, "fft {fast:?} !< dense {slow:?}");
    }
}
