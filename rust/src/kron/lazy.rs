//! Lazy (on-demand) kernel evaluation MVM for the dense baseline.
//!
//! When the n x n kernel matrix does not fit in memory, iterative
//! methods must rematerialize kernel values during every MVM — this is
//! the regime Figure 2 highlights where "kernel evaluation time
//! dominates matrix multiplication time". This operator evaluates Gram
//! blocks on the fly with O(block) storage, trading FLOPs for memory.

use crate::linalg::{Matrix, Scalar};

/// Row-block lazily evaluated symmetric operator: entries come from an
/// entry oracle `f(i, j)`; only `block_rows x n` values are live at once.
pub struct LazyGramOp<F> {
    /// System dimension n.
    pub n: usize,
    /// Rows materialized per block (memory = `block_rows * n` f64s).
    pub block_rows: usize,
    /// Entry oracle returning K_ij.
    pub entry: F,
    /// Noise variance added on the diagonal.
    pub sigma2: f64,
}

impl<F: Fn(usize, usize) -> f64 + Sync> LazyGramOp<F> {
    /// Lazy operator over an entry oracle (`block_rows` clamped to >= 1).
    pub fn new(n: usize, block_rows: usize, entry: F, sigma2: f64) -> Self {
        LazyGramOp { n, block_rows: block_rows.max(1), entry, sigma2 }
    }

    /// (K + sigma2 I) V^T for batched RHS rows of `v`, materializing only
    /// one row block of K at a time. Also returns the number of kernel
    /// evaluations performed (the Fig-2 bookkeeping).
    ///
    /// Both halves of each block step run on the `crate::par` pool with
    /// disjoint writes: kernel rows of the block are materialized in
    /// parallel (this is the dominant cost in the out-of-memory Fig-2
    /// regime), then each batch row's partial MVM over the block is
    /// computed in parallel across batch rows.
    pub fn apply_batch<T: Scalar>(&self, v: &Matrix<T>) -> (Matrix<T>, u64) {
        assert_eq!(v.cols, self.n);
        let n = self.n;
        let mut out = Matrix::<T>::zeros(v.rows, n);
        let mut evals = 0u64;
        let mut block = vec![0.0f64; self.block_rows * n];
        for i0 in (0..n).step_by(self.block_rows) {
            let i1 = (i0 + self.block_rows).min(n);
            let rows = i1 - i0;
            // materialize rows [i0, i1), one kernel row per task — the
            // stealing schedule absorbs entry oracles whose cost varies
            // across rows (each row is still written by exactly one
            // worker, so bits are schedule-independent)
            let live = &mut block[..rows * n];
            crate::par::par_chunks_mut_steal("lazy_gram.rows", live, n, |r, brow| {
                let i = i0 + r;
                for (j, x) in brow.iter_mut().enumerate() {
                    *x = (self.entry)(i, j);
                }
            });
            evals += (rows * n) as u64;
            // partial MVM: each batch row owns its output row
            let block_ref = &block;
            crate::par::par_chunks_mut("lazy_gram.mvm", &mut out.data, n, |b, orow| {
                let vrow = v.row(b);
                for i in i0..i1 {
                    let krow = &block_ref[(i - i0) * n..(i - i0 + 1) * n];
                    let mut acc = 0.0f64;
                    for (kij, vj) in krow.iter().zip(vrow) {
                        acc += *kij * vj.to_f64();
                    }
                    orow[i] = T::from_f64(acc + self.sigma2 * vrow[i].to_f64());
                }
            });
        }
        (out, evals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{assert_close, prop_check};

    #[test]
    fn prop_lazy_matches_materialized() {
        prop_check("lazy-vs-dense", 73, 15, |g| {
            let n = g.size(1, 30);
            let a = g.spd(n);
            let a2 = a.clone();
            let op = LazyGramOp::new(n, g.size(1, 7), move |i, j| a2[i * n + j], 0.25);
            let v = Matrix::from_vec(2, n, g.vec_normal(2 * n));
            let (got, evals) = op.apply_batch(&v);
            let am = Matrix::from_vec(n, n, a);
            let mut want = Matrix::zeros(2, n);
            for b in 0..2 {
                let mut r = am.matvec(v.row(b));
                for (ri, vi) in r.iter_mut().zip(v.row(b)) {
                    *ri += 0.25 * vi;
                }
                want.row_mut(b).copy_from_slice(&r);
            }
            if evals != (n * n) as u64 {
                return Err(format!("evals {evals} != n^2"));
            }
            assert_close(&got.data, &want.data, 1e-9)
        });
    }

    #[test]
    fn eval_count_is_per_mvm() {
        let n = 16;
        let op = LazyGramOp::new(n, 4, |i, j| if i == j { 2.0 } else { 0.0 }, 0.0);
        let v = Matrix::<f64>::from_vec(1, n, vec![1.0; n]);
        let (out, evals) = op.apply_batch(&v);
        assert_eq!(evals, 256);
        assert!(out.data.iter().all(|&x| (x - 2.0).abs() < 1e-12));
    }
}
