//! Proposition 3.1: asymptotic break-even missing ratios, plus the
//! analytic FLOP/byte cost models used by the Fig-2/Fig-3 analyses.
//!
//! With missing ratio gamma = 1 - n/(p q):
//!   time break-even  gamma*_time = 1 - sqrt(1/p + 1/q)
//!   memory break-even gamma*_mem = 1 - sqrt(1/p^2 + 1/q^2)
//! Below the break-even (fewer missing values) latent Kronecker wins;
//! above it, the dense representation of the n x n observed matrix is
//! asymptotically cheaper.

/// gamma*_time = 1 - sqrt(1/p + 1/q).
pub fn gamma_time(p: usize, q: usize) -> f64 {
    1.0 - (1.0 / p as f64 + 1.0 / q as f64).sqrt()
}

/// gamma*_mem = 1 - sqrt(1/p^2 + 1/q^2).
pub fn gamma_mem(p: usize, q: usize) -> f64 {
    let (p, q) = (p as f64, q as f64);
    1.0 - (1.0 / (p * p) + 1.0 / (q * q)).sqrt()
}

/// Observed count n for a missing ratio gamma on a p x q grid.
pub fn observed_count(p: usize, q: usize, gamma: f64) -> usize {
    (((1.0 - gamma) * (p * q) as f64).round() as usize).clamp(1, p * q)
}

/// FLOPs of one dense MVM on the n x n observed kernel matrix.
pub fn dense_mvm_flops(n: usize) -> f64 {
    2.0 * (n as f64) * (n as f64)
}

/// FLOPs of one latent-Kronecker MVM on the p x q grid.
pub fn kron_mvm_flops(p: usize, q: usize) -> f64 {
    2.0 * ((p * p * q) as f64 + (p * q * q) as f64)
}

/// Kernel-evaluation counts (the Fig-2 "kernel time" axis).
pub fn dense_kernel_evals(n: usize) -> f64 {
    (n as f64) * (n as f64)
}

/// Kernel evaluations to build the factored p x p and q x q Grams.
pub fn kron_kernel_evals(p: usize, q: usize) -> f64 {
    (p * p) as f64 + (q * q) as f64
}

/// Predicted speedup of latent-Kron MVM over dense MVM at ratio gamma.
pub fn predicted_mvm_speedup(p: usize, q: usize, gamma: f64) -> f64 {
    let n = observed_count(p, q, gamma);
    dense_mvm_flops(n) / kron_mvm_flops(p, q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::prop_check;

    #[test]
    fn matches_paper_algebra() {
        // Appendix A: (1-gamma)^2 = 1/p + 1/q at the time break-even.
        prop_check("prop31-time", 67, 50, |g| {
            let (p, q) = (g.size(2, 5000), g.size(2, 5000));
            let gamma = gamma_time(p, q);
            let lhs = (1.0 - gamma) * (1.0 - gamma);
            let rhs = 1.0 / p as f64 + 1.0 / q as f64;
            if (lhs - rhs).abs() > 1e-12 {
                return Err(format!("{lhs} != {rhs}"));
            }
            Ok(())
        });
    }

    #[test]
    fn breakeven_flops_cross_at_gamma_star() {
        // At gamma*_time, dense and kron MVM FLOPs must be (nearly) equal,
        // below it kron is cheaper, above it dense is cheaper.
        for &(p, q) in &[(5000, 7), (2000, 52), (384, 96), (100, 100)] {
            let gstar = gamma_time(p, q);
            if gstar <= 0.0 {
                continue;
            }
            let at = predicted_mvm_speedup(p, q, gstar);
            assert!((at - 1.0).abs() < 0.05, "p={p} q={q}: speedup at g*={at}");
            assert!(predicted_mvm_speedup(p, q, (gstar - 0.2).max(0.0)) > 1.0);
            assert!(predicted_mvm_speedup(p, q, (gstar + 0.2).min(0.99)) < 1.0);
        }
    }

    #[test]
    fn mem_breakeven_higher_than_time() {
        // sqrt(1/p^2+1/q^2) <= sqrt(1/p+1/q) for p,q >= 1, so the memory
        // break-even tolerates more missing data than the time one.
        prop_check("prop31-order", 71, 50, |g| {
            let (p, q) = (g.size(2, 3000), g.size(2, 3000));
            if gamma_mem(p, q) < gamma_time(p, q) - 1e-12 {
                return Err("mem breakeven below time".into());
            }
            Ok(())
        });
    }

    #[test]
    fn paper_scale_values() {
        // SARCOS scale (p=5000, q=7): time break-even ~ 62%.
        assert!((gamma_time(5000, 7) - 0.6216).abs() < 0.001);
        // memory break-even essentially 1 - 1/q for q << p
        assert!((gamma_mem(5000, 7) - (1.0 - (1.0f64 / 25e6 + 1.0 / 49.0).sqrt())).abs() < 1e-9);
    }

    #[test]
    fn observed_count_bounds() {
        assert_eq!(observed_count(10, 10, 0.0), 100);
        assert_eq!(observed_count(10, 10, 1.0), 1);
        assert_eq!(observed_count(10, 10, 0.25), 75);
    }
}
