//! Adam (Kingma & Ba 2015) — used for marginal-likelihood hyperparameter
//! optimization (paper Appendix C: "Adam with a learning rate of 0.1")
//! and for the variational baselines' ELBO training.

/// Adam optimizer state over a flat parameter vector.
#[derive(Clone, Debug)]
pub struct Adam {
    /// Learning rate.
    pub lr: f64,
    /// First-moment decay.
    pub beta1: f64,
    /// Second-moment decay.
    pub beta2: f64,
    /// Denominator stabilizer.
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
    skipped_nonfinite: u64,
}

impl Adam {
    /// Fresh optimizer state with standard (0.9, 0.999) decays.
    pub fn new(n_params: usize, lr: f64) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; n_params],
            v: vec![0.0; n_params],
            t: 0,
            skipped_nonfinite: 0,
        }
    }

    /// Parameter-vector length this state was built for.
    pub fn dim(&self) -> usize {
        self.m.len()
    }

    /// Gradient entries that were NaN/Inf and therefore treated as zero
    /// across all steps so far. A nonzero count means the loss surface
    /// produced garbage gradients — the parameter search silently
    /// ignored them, so surface this (see `gp::diagnostics`).
    pub fn skipped_nonfinite(&self) -> u64 {
        self.skipped_nonfinite
    }

    /// One descent step: params -= lr * mhat / (sqrt(vhat) + eps).
    /// `grad` is the gradient of the loss being *minimized*.
    pub fn step(&mut self, params: &mut [f64], grad: &[f64]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grad.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = if grad[i].is_finite() {
                grad[i]
            } else {
                self.skipped_nonfinite += 1;
                0.0
            };
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = sum (x - c)^2
        let c = [3.0, -1.5, 0.25];
        let mut x = vec![0.0; 3];
        let mut opt = Adam::new(3, 0.1);
        for _ in 0..500 {
            let grad: Vec<f64> = x.iter().zip(&c).map(|(xi, ci)| 2.0 * (xi - ci)).collect();
            opt.step(&mut x, &grad);
        }
        for (xi, ci) in x.iter().zip(&c) {
            assert!((xi - ci).abs() < 1e-2, "{xi} vs {ci}");
        }
    }

    #[test]
    fn ignores_nan_gradients() {
        let mut x = vec![1.0];
        let mut opt = Adam::new(1, 0.1);
        opt.step(&mut x, &[f64::NAN]);
        assert!(x[0].is_finite());
        assert_eq!(opt.skipped_nonfinite(), 1);
        opt.step(&mut x, &[0.5]);
        assert_eq!(opt.skipped_nonfinite(), 1, "finite grads are not counted");
        opt.step(&mut x, &[f64::INFINITY]);
        assert_eq!(opt.skipped_nonfinite(), 2);
    }

    #[test]
    fn rosenbrock_descends() {
        let mut x = vec![-1.0, 1.0];
        let mut opt = Adam::new(2, 0.02);
        let f = |x: &[f64]| (1.0 - x[0]).powi(2) + 100.0 * (x[1] - x[0] * x[0]).powi(2);
        let f0 = f(&x);
        for _ in 0..2000 {
            let g = vec![
                -2.0 * (1.0 - x[0]) - 400.0 * x[0] * (x[1] - x[0] * x[0]),
                200.0 * (x[1] - x[0] * x[0]),
            ];
            opt.step(&mut x, &g);
        }
        assert!(f(&x) < 0.1 * f0, "f={} from {}", f(&x), f0);
    }
}
