//! Optimizers for hyperparameter / variational training loops.

pub mod adam;

pub use adam::Adam;
