//! Shared machinery for the baseline models: flattened feature views of
//! grid datasets, heuristic hyperparameter initialization, and a
//! finite-difference Adam loop for low-dimensional hyper optimization.

use crate::data::GridDataset;
use crate::kernels::RbfArd;
use crate::linalg::Matrix;
use crate::optim::Adam;
use crate::util::rng::Rng;

/// Observed data flattened to (X, y): x_i = [s_j.., t_k], y standardized.
pub struct FlatData {
    /// Observed feature rows `[s.., t]` (n x (d_s + 1)).
    pub x: Matrix<f64>,
    /// Standardized observed targets.
    pub y: Vec<f64>,
    /// all grid cells as feature rows (prediction targets)
    pub x_grid: Matrix<f64>,
    /// Mean of the observed targets (standardization state).
    pub y_mean: f64,
    /// Std of the observed targets (standardization state).
    pub y_std: f64,
}

/// Flatten a grid dataset into the baseline feature view.
pub fn flatten(data: &GridDataset) -> FlatData {
    let (p, q) = (data.p(), data.q());
    let d = data.s.cols + 1;
    let (y_mean, y_std) = data.target_stats();
    // time coordinates standardized to match spatial scaling
    let t_mean = data.t.iter().sum::<f64>() / q as f64;
    let t_var =
        data.t.iter().map(|v| (v - t_mean) * (v - t_mean)).sum::<f64>() / q as f64;
    let t_std = t_var.sqrt().max(1e-9);
    let mut x_grid = Matrix::zeros(p * q, d);
    for j in 0..p {
        for k in 0..q {
            let row = x_grid.row_mut(j * q + k);
            row[..d - 1].copy_from_slice(data.s.row(j));
            row[d - 1] = (data.t[k] - t_mean) / t_std;
        }
    }
    let obs = data.observed_indices();
    let mut x = Matrix::zeros(obs.len(), d);
    let mut y = Vec::with_capacity(obs.len());
    for (r, &i) in obs.iter().enumerate() {
        x.row_mut(r).copy_from_slice(x_grid.row(i));
        y.push((data.y_grid[i] - y_mean) / y_std);
    }
    FlatData { x, y, x_grid, y_mean, y_std }
}

/// Heuristic initialization: unit lengthscales on standardized features,
/// unit outputscale (standardized targets), 10% noise.
pub fn init_hypers(d: usize) -> Vec<f64> {
    // [log_ls (shared per-dim via ARD), log_os, log_sigma2]
    let mut h = vec![0.0; d + 1];
    h.push((0.1f64).ln());
    h
}

/// Build the RBF kernel from a hyper vector [log_ls.., log_os].
pub fn kernel_from(h: &[f64], d: usize) -> RbfArd {
    let mut k = RbfArd::new(d);
    k.set_params(&h[..d + 1]);
    k
}

/// Finite-difference Adam on a scalar loss. Central differences; the
/// loss should be deterministic in `params` (fix RNG seeds inside).
pub fn fd_adam(
    params: &mut Vec<f64>,
    iters: usize,
    lr: f64,
    eps: f64,
    mut loss: impl FnMut(&[f64]) -> f64,
) -> Vec<f64> {
    let mut adam = Adam::new(params.len(), lr);
    let mut trace = Vec::with_capacity(iters);
    for _ in 0..iters {
        let mut grad = vec![0.0; params.len()];
        for i in 0..params.len() {
            let mut pp = params.clone();
            pp[i] += eps;
            let lp = loss(&pp);
            pp[i] -= 2.0 * eps;
            let lm = loss(&pp);
            grad[i] = (lp - lm) / (2.0 * eps);
        }
        adam.step(params, &grad);
        trace.push(loss(params));
    }
    trace
}

/// Random subset of rows as initial inducing inputs.
pub fn random_rows(x: &Matrix<f64>, m: usize, rng: &mut Rng) -> Matrix<f64> {
    let m = m.min(x.rows);
    let idx = rng.choose(x.rows, m);
    let mut z = Matrix::zeros(m, x.cols);
    for (r, &i) in idx.iter().enumerate() {
        z.row_mut(r).copy_from_slice(x.row(i));
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::well_specified;
    use crate::kernels::ProductGridKernel;

    #[test]
    fn flatten_shapes_and_standardization() {
        let kernel = ProductGridKernel::new(2, "rbf", 5);
        let data = well_specified(8, 5, 2, &kernel, 0.1, 0.25, 0);
        let fd = flatten(&data);
        assert_eq!(fd.x.cols, 3);
        assert_eq!(fd.x.rows, data.n_observed());
        assert_eq!(fd.x_grid.rows, 40);
        let mean: f64 = fd.y.iter().sum::<f64>() / fd.y.len() as f64;
        assert!(mean.abs() < 1e-9);
    }

    #[test]
    fn fd_adam_minimizes() {
        let mut p = vec![2.0, -3.0];
        fd_adam(&mut p, 300, 0.1, 1e-5, |p| {
            (p[0] - 0.5).powi(2) + (p[1] + 1.0).powi(2)
        });
        assert!((p[0] - 0.5).abs() < 0.05 && (p[1] + 1.0).abs() < 0.05, "{p:?}");
    }
}
