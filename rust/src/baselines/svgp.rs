//! SVGP / SGPR baseline (Titsias 2009; Hensman et al. 2013).
//!
//! With a Gaussian likelihood the optimal variational distribution of
//! SVGP coincides with the Titsias collapsed solution, so we train by
//! maximizing the collapsed ELBO
//!
//!   ELBO = log N(y | 0, Q_nn + s2 I) - 1/(2 s2) tr(K_nn - Q_nn)
//!
//! (Q_nn = K_nm K_mm^{-1} K_mn) over [log_ls.., log_os, log_s2] and
//! recover q(u) in closed form. Cost O(n m^2) per ELBO evaluation via
//! the Woodbury/QR-free formulation below.

use anyhow::{Context, Result};

use crate::data::GridDataset;
use crate::gp::Posterior;
use crate::linalg::chol::{cholesky, solve_lower};
use crate::linalg::Matrix;
use crate::util::rng::Rng;

use super::common::{fd_adam, flatten, init_hypers, kernel_from, random_rows};
use super::{BaselineFit, BaselineModel};

/// SVGP (collapsed-ELBO) baseline configuration.
pub struct Svgp {
    /// number of inducing points
    pub m: usize,
    /// finite-difference Adam iterations on the collapsed ELBO
    pub train_iters: usize,
    /// Adam learning rate.
    pub lr: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Svgp {
    /// Baseline with the default learning rate.
    pub fn new(m: usize, train_iters: usize, seed: u64) -> Self {
        Svgp { m, train_iters, lr: 0.1, seed }
    }
}

/// Collapsed negative ELBO and the posterior-over-u statistics.
/// Returns (neg_elbo, a_vec, b_chol, kmm_chol) where the predictive is
///   mean(x) = k_m(x)^T a
///   var(x)  = k(x,x) - k_m^T Kmm^-1 k_m + k_m^T B^-1 k_m   (+ s2)
/// with B = Kmm + s2^-1 Kmn Knm (Titsias).
struct SgprState {
    a: Vec<f64>,
    kmm_chol: crate::linalg::chol::Cholesky<f64>,
    b_chol: crate::linalg::chol::Cholesky<f64>,
}

fn sgpr(
    x: &Matrix<f64>,
    y: &[f64],
    z: &Matrix<f64>,
    hypers: &[f64],
) -> Result<(f64, SgprState)> {
    let d = x.cols;
    let n = x.rows;
    let m = z.rows;
    let kernel = kernel_from(hypers, d);
    let s2 = hypers[d + 1].exp();
    let kmm = {
        let mut k = kernel.gram(z, z);
        k.add_diag(1e-6 * k.trace() / m as f64);
        k
    };
    let knm = kernel.gram(x, z); // n x m
    let kmm_chol = cholesky(&kmm).context("Kmm chol")?;
    // B = Kmm + s2^-1 Kmn Knm
    let mut b = kmm.clone();
    for i in 0..m {
        for j in 0..m {
            let mut acc = 0.0;
            for r in 0..n {
                acc += knm[(r, i)] * knm[(r, j)];
            }
            b[(i, j)] += acc / s2;
        }
    }
    let b_chol = cholesky(&b).context("B chol")?;
    // a = s2^-1 B^-1 Kmn y  (predictive-mean weights)
    let kmn_y: Vec<f64> = (0..m)
        .map(|i| (0..n).map(|r| knm[(r, i)] * y[r]).sum::<f64>() / s2)
        .collect();
    let a = b_chol.solve(&kmn_y);
    // collapsed ELBO:
    // log N(y|0, Qnn + s2 I) = -1/2 [ n log(2 pi) + log|Qnn + s2 I|
    //    + y^T (Qnn + s2 I)^-1 y ]
    // log|Qnn+s2I| = log|B| - log|Kmm| + n log s2
    // y^T(.)^-1 y = s2^-1 (y^T y - s2^-1 y^T Knm B^-1 Kmn y)
    //             = s2^-1 y^T y - y^T Knm a / s2
    let yty: f64 = y.iter().map(|v| v * v).sum();
    let ykna: f64 = {
        let mut acc = 0.0;
        for r in 0..n {
            let mut dotv = 0.0;
            for i in 0..m {
                dotv += knm[(r, i)] * a[i];
            }
            acc += y[r] * dotv;
        }
        acc
    };
    let quad = yty / s2 - ykna / s2;
    let logdet = b_chol.logdet() - kmm_chol.logdet() + n as f64 * s2.ln();
    let ll = -0.5 * (n as f64 * (2.0 * std::f64::consts::PI).ln() + logdet + quad);
    // trace correction: -1/(2 s2) tr(Knn - Qnn)
    // tr Knn = n * os ; tr Qnn = sum_r k_m(r)^T Kmm^-1 k_m(r)
    let os = hypers[d].exp();
    let mut tr_q = 0.0;
    for r in 0..n {
        let km: Vec<f64> = (0..m).map(|i| knm[(r, i)]).collect();
        let v = solve_lower(&kmm_chol.l, &km);
        tr_q += v.iter().map(|x| x * x).sum::<f64>();
    }
    let elbo = ll - (n as f64 * os - tr_q).max(0.0) / (2.0 * s2);
    Ok((-elbo, SgprState { a, kmm_chol, b_chol }))
}

impl BaselineModel for Svgp {
    fn name(&self) -> &'static str {
        "SVGP"
    }

    fn fit_predict(&mut self, data: &GridDataset) -> Result<BaselineFit> {
        let t0 = std::time::Instant::now();
        let fd = flatten(data);
        let d = fd.x.cols;
        let mut rng = Rng::new(self.seed ^ 0x5497);
        let z = random_rows(&fd.x, self.m, &mut rng);
        let mut hypers = init_hypers(d);
        // hyperparameter training on the collapsed ELBO
        fd_adam(&mut hypers, self.train_iters, self.lr, 1e-4, |h| {
            sgpr(&fd.x, &fd.y, &z, h).map(|(nelbo, _)| nelbo).unwrap_or(1e12)
        });
        let (_, state) = sgpr(&fd.x, &fd.y, &z, &hypers)?;
        let kernel = kernel_from(&hypers, d);
        let s2 = hypers[d + 1].exp();
        let os = hypers[d].exp();

        // predict over the full grid
        let kgm = kernel.gram(&fd.x_grid, &z); // (pq) x m
        let pq = fd.x_grid.rows;
        let mut mean = vec![0.0; pq];
        let mut var = vec![0.0; pq];
        for r in 0..pq {
            let km: Vec<f64> = (0..z.rows).map(|i| kgm[(r, i)]).collect();
            let mu: f64 = km.iter().zip(&state.a).map(|(k, a)| k * a).sum();
            let v_kmm = solve_lower(&state.kmm_chol.l, &km);
            let v_b = solve_lower(&state.b_chol.l, &km);
            let q_contrib: f64 = v_kmm.iter().map(|x| x * x).sum();
            let b_contrib: f64 = v_b.iter().map(|x| x * x).sum();
            let v = (os - q_contrib + b_contrib).max(1e-10) + s2;
            mean[r] = mu * fd.y_std + fd.y_mean;
            var[r] = v * fd.y_std * fd.y_std;
        }
        Ok(BaselineFit {
            posterior: Posterior { mean, var },
            train_secs: t0.elapsed().as_secs_f64(),
            hypers,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::well_specified;
    use crate::kernels::ProductGridKernel;

    #[test]
    fn fits_well_specified_data() {
        let kernel = ProductGridKernel::new(2, "rbf", 6);
        let data = well_specified(20, 6, 2, &kernel, 0.05, 0.3, 2);
        let mut model = Svgp::new(24, 10, 0);
        let fit = model.fit_predict(&data).unwrap();
        let (rmse, nll) = fit.posterior.test_metrics(&data);
        let (_, y_std) = data.target_stats();
        assert!(rmse < y_std, "rmse {rmse} vs std {y_std}");
        assert!(nll < 2.5, "nll {nll}");
    }

    #[test]
    fn more_inducing_points_no_worse_elbo() {
        let kernel = ProductGridKernel::new(2, "rbf", 5);
        let data = well_specified(16, 5, 2, &kernel, 0.1, 0.2, 4);
        let fd = flatten(&data);
        let mut rng = Rng::new(1);
        let h = init_hypers(fd.x.cols);
        let z_small = random_rows(&fd.x, 8, &mut rng);
        // superset: small z plus extra rows
        let mut rng2 = Rng::new(1);
        let z_big = random_rows(&fd.x, 32, &mut rng2);
        let (ne_small, _) = sgpr(&fd.x, &fd.y, &z_small, &h).unwrap();
        let (ne_big, _) = sgpr(&fd.x, &fd.y, &z_big, &h).unwrap();
        // more inducing capacity -> ELBO at least close (allow slack for
        // random placement)
        assert!(ne_big < ne_small + 5.0, "{ne_big} vs {ne_small}");
    }

    #[test]
    fn full_inducing_set_recovers_exact_gp_mean() {
        // m = n inducing at training points makes SGPR exact.
        let kernel = ProductGridKernel::new(1, "rbf", 4);
        let data = well_specified(6, 4, 1, &kernel, 0.05, 0.2, 8);
        let fd = flatten(&data);
        let h = init_hypers(fd.x.cols);
        let (_, state) = sgpr(&fd.x, &fd.y, &fd.x, &h).unwrap();
        // exact GP mean at training points
        let kern = kernel_from(&h, fd.x.cols);
        let s2 = h[fd.x.cols + 1].exp();
        let mut knn = kern.gram(&fd.x, &fd.x);
        knn.add_diag(s2);
        let chol = cholesky(&knn).unwrap();
        let alpha = chol.solve(&fd.y);
        let kxx = kern.gram(&fd.x, &fd.x);
        for r in 0..fd.x.rows {
            let exact: f64 = (0..fd.x.rows).map(|j| kxx[(r, j)] * alpha[j]).sum();
            let sparse: f64 =
                (0..fd.x.rows).map(|j| kxx[(r, j)] * state.a[j]).sum();
            assert!((exact - sparse).abs() < 1e-5, "row {r}: {exact} vs {sparse}");
        }
    }
}
