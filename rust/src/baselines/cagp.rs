//! CaGP baseline (Wenger et al. 2024): computation-aware GP.
//!
//! Inference is projected onto m "actions" s_1..s_m (columns of S):
//!
//!   mean(x) = k(x, X) S (S^T Khat S)^{-1} S^T y
//!   var(x)  = k(x,x) - k(x,X) S (S^T Khat S)^{-1} S^T k(X,x) + s2
//!
//! with Khat = K_nn + s2 I. Because the downdate uses the *projected*
//! inverse, var is provably >= the exact GP posterior variance — the
//! extra is the method's "computational uncertainty", which is what
//! keeps CaGP calibrated at small m (the paper's Table 1/2 rows).
//! Actions here are conjugate-gradient directions of Khat v = y
//! (the CaGP-CG policy), which concentrate computation on the data fit.

use anyhow::{Context, Result};

use crate::data::GridDataset;
use crate::gp::Posterior;
use crate::linalg::chol::cholesky;
use crate::linalg::Matrix;

use super::common::{fd_adam, flatten, init_hypers, kernel_from};
use super::{BaselineFit, BaselineModel};

/// Computation-aware GP (Wenger et al. 2024) baseline configuration.
pub struct CaGp {
    /// number of actions (projection dimension)
    pub m: usize,
    /// Hyperparameter-training iterations.
    pub train_iters: usize,
    /// Adam learning rate.
    pub lr: f64,
    /// RNG seed.
    pub seed: u64,
}

impl CaGp {
    /// Baseline with the default learning rate.
    pub fn new(m: usize, train_iters: usize, seed: u64) -> Self {
        CaGp { m, train_iters, lr: 0.1, seed }
    }
}

struct CagpState {
    /// actions, n x m
    s: Matrix<f64>,
    /// chol of S^T Khat S
    proj_chol: crate::linalg::chol::Cholesky<f64>,
    /// S (S^T Khat S)^{-1} S^T y, length n (representer weights)
    weights: Vec<f64>,
}

/// CG-direction actions + projected solves for fixed hypers.
/// Returns (projected-NLL surrogate, state).
fn cagp_solve(x: &Matrix<f64>, y: &[f64], m: usize, hypers: &[f64]) -> Result<(f64, CagpState)> {
    let d = x.cols;
    let n = x.rows;
    let m = m.min(n);
    let kernel = kernel_from(hypers, d);
    let s2 = hypers[d + 1].exp();
    let mut khat = kernel.gram(x, x);
    khat.add_diag(s2);
    // CG directions on Khat v = y
    let mut s_cols: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut r = y.to_vec();
    let mut p = r.clone();
    let mut v = vec![0.0; n];
    let mut rr: f64 = r.iter().map(|a| a * a).sum();
    for _ in 0..m {
        if rr.sqrt() < 1e-12 {
            break;
        }
        s_cols.push(p.clone());
        let ap = khat.matvec(&p);
        let pap: f64 = p.iter().zip(&ap).map(|(a, b)| a * b).sum();
        if pap.abs() < 1e-300 {
            break;
        }
        let alpha = rr / pap;
        for i in 0..n {
            v[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rr_new: f64 = r.iter().map(|a| a * a).sum();
        let beta = rr_new / rr;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rr = rr_new;
    }
    let m_eff = s_cols.len().max(1);
    let mut s = Matrix::zeros(n, m_eff);
    for (j, col) in s_cols.iter().enumerate() {
        for i in 0..n {
            s[(i, j)] = col[i];
        }
    }
    if s_cols.is_empty() {
        s = Matrix::from_fn(n, 1, |i, _| if i == 0 { 1.0 } else { 0.0 });
    }
    // projected system
    let ks = khat.matmul(&s); // n x m
    let mut proj = Matrix::zeros(s.cols, s.cols);
    for a in 0..s.cols {
        for b in 0..s.cols {
            let mut acc = 0.0;
            for i in 0..n {
                acc += s[(i, a)] * ks[(i, b)];
            }
            proj[(a, b)] = acc;
        }
    }
    // symmetrize tiny asymmetries
    for a in 0..proj.rows {
        for b in 0..a {
            let avg = 0.5 * (proj[(a, b)] + proj[(b, a)]);
            proj[(a, b)] = avg;
            proj[(b, a)] = avg;
        }
    }
    let proj_chol = cholesky(&proj).context("projected system chol")?;
    let sty: Vec<f64> = (0..s.cols)
        .map(|a| (0..n).map(|i| s[(i, a)] * y[i]).sum())
        .collect();
    let gamma = proj_chol.solve(&sty);
    let weights: Vec<f64> =
        (0..n).map(|i| (0..s.cols).map(|a| s[(i, a)] * gamma[a]).sum()).collect();
    // projected-evidence surrogate (Wenger et al.'s projected NLL):
    // 1/2 y^T weights + 1/2 log|S^T Khat S| - 1/2 log|S^T S|  + const
    let yw: f64 = y.iter().zip(&weights).map(|(a, b)| a * b).sum();
    let mut sts = Matrix::zeros(s.cols, s.cols);
    for a in 0..s.cols {
        for b in 0..s.cols {
            let mut acc = 0.0;
            for i in 0..n {
                acc += s[(i, a)] * s[(i, b)];
            }
            sts[(a, b)] = acc;
        }
    }
    let sts_logdet = cholesky(&sts).map(|c| c.logdet()).unwrap_or(0.0);
    let nll = 0.5 * yw + 0.5 * (proj_chol.logdet() - sts_logdet);
    Ok((nll, CagpState { s, proj_chol, weights }))
}

impl BaselineModel for CaGp {
    fn name(&self) -> &'static str {
        "CaGP"
    }

    fn fit_predict(&mut self, data: &GridDataset) -> Result<BaselineFit> {
        let t0 = std::time::Instant::now();
        let fd = flatten(data);
        let d = fd.x.cols;
        let mut hypers = init_hypers(d);
        fd_adam(&mut hypers, self.train_iters, self.lr, 1e-4, |h| {
            cagp_solve(&fd.x, &fd.y, self.m, h).map(|(nll, _)| nll).unwrap_or(1e12)
        });
        let (_, state) = cagp_solve(&fd.x, &fd.y, self.m, &hypers)?;
        let kernel = kernel_from(&hypers, d);
        let s2 = hypers[d + 1].exp();
        let os = hypers[d].exp();

        let kgx = kernel.gram(&fd.x_grid, &fd.x); // pq x n
        let pq = fd.x_grid.rows;
        let mut mean = vec![0.0; pq];
        let mut var = vec![0.0; pq];
        for r in 0..pq {
            let krow = kgx.row(r);
            let mu: f64 = krow.iter().zip(&state.weights).map(|(a, b)| a * b).sum();
            // downdate: k S (S^T Khat S)^-1 S^T k
            let sk: Vec<f64> = (0..state.s.cols)
                .map(|a| (0..fd.x.rows).map(|i| state.s[(i, a)] * krow[i]).sum())
                .collect();
            let w = crate::linalg::chol::solve_lower(&state.proj_chol.l, &sk);
            let red: f64 = w.iter().map(|x| x * x).sum();
            let v = (os - red).max(1e-10) + s2;
            mean[r] = mu * fd.y_std + fd.y_mean;
            var[r] = v * fd.y_std * fd.y_std;
        }
        Ok(BaselineFit {
            posterior: Posterior { mean, var },
            train_secs: t0.elapsed().as_secs_f64(),
            hypers,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::well_specified;
    use crate::kernels::ProductGridKernel;

    #[test]
    fn fits_well_specified_data() {
        let kernel = ProductGridKernel::new(2, "rbf", 6);
        let data = well_specified(18, 6, 2, &kernel, 0.05, 0.3, 5);
        let mut model = CaGp::new(24, 8, 0);
        let fit = model.fit_predict(&data).unwrap();
        let (rmse, nll) = fit.posterior.test_metrics(&data);
        let (_, y_std) = data.target_stats();
        assert!(rmse < y_std, "rmse {rmse} vs {y_std}");
        assert!(nll < 2.5, "nll {nll}");
    }

    #[test]
    fn variance_at_least_exact_gp() {
        // CaGP's guarantee: projected posterior variance >= exact GP's.
        let kernel = ProductGridKernel::new(1, "rbf", 4);
        let data = well_specified(8, 4, 1, &kernel, 0.05, 0.25, 9);
        let fd = flatten(&data);
        let h = init_hypers(fd.x.cols);
        let (_, state) = cagp_solve(&fd.x, &fd.y, 4, &h).unwrap();
        let kern = kernel_from(&h, fd.x.cols);
        let s2 = h[fd.x.cols + 1].exp();
        let os = h[fd.x.cols].exp();
        let mut khat = kern.gram(&fd.x, &fd.x);
        khat.add_diag(s2);
        let chol = cholesky(&khat).unwrap();
        for r in (0..fd.x_grid.rows).step_by(3) {
            let kx: Vec<f64> = (0..fd.x.rows)
                .map(|i| kern.eval(fd.x.row(i), fd.x_grid.row(r)))
                .collect();
            // exact downdate
            let sol = chol.solve(&kx);
            let exact_red: f64 = kx.iter().zip(&sol).map(|(a, b)| a * b).sum();
            // projected downdate
            let sk: Vec<f64> = (0..state.s.cols)
                .map(|a| (0..fd.x.rows).map(|i| state.s[(i, a)] * kx[i]).sum())
                .collect();
            let w = crate::linalg::chol::solve_lower(&state.proj_chol.l, &sk);
            let proj_red: f64 = w.iter().map(|x| x * x).sum();
            assert!(
                proj_red <= exact_red + 1e-6,
                "cell {r}: projected reduction {proj_red} > exact {exact_red}"
            );
            assert!(os - proj_red >= -1e-9);
        }
    }

    #[test]
    fn full_actions_recover_exact_mean() {
        // m = n CG directions solve the system exactly.
        let kernel = ProductGridKernel::new(1, "rbf", 3);
        let data = well_specified(6, 3, 1, &kernel, 0.1, 0.2, 12);
        let fd = flatten(&data);
        let h = init_hypers(fd.x.cols);
        let (_, state) = cagp_solve(&fd.x, &fd.y, fd.x.rows, &h).unwrap();
        let kern = kernel_from(&h, fd.x.cols);
        let s2 = h[fd.x.cols + 1].exp();
        let mut khat = kern.gram(&fd.x, &fd.x);
        khat.add_diag(s2);
        let chol = cholesky(&khat).unwrap();
        let alpha = chol.solve(&fd.y);
        for (w, a) in state.weights.iter().zip(&alpha) {
            assert!((w - a).abs() < 1e-4, "{w} vs {a}");
        }
    }
}
