//! VNNGP baseline (Wu et al. 2022): variational nearest-neighbour GP.
//!
//! Inducing points sit at every training input; the variational prior
//! retains only K-nearest-neighbour correlations. At prediction time the
//! posterior at x conditions on the K nearest training points only —
//! a local-GP conditional. This reproduces VNNGP's signature behaviour
//! in the paper's tables: excellent *train* fit, but overconfident and
//! weaker *test* predictions once targets are far from their neighbours
//! (Table 1: best train RMSE, worst test NLL).
//!
//! Hyperparameters are trained by maximizing the sum of local
//! leave-one-out log predictive densities over a subsample — the
//! mini-batched flavour of VNNGP's decomposed ELBO.

use anyhow::{Context, Result};

use crate::data::GridDataset;
use crate::gp::Posterior;
use crate::linalg::chol::cholesky;
use crate::linalg::Matrix;
use crate::util::rng::Rng;

use super::common::{fd_adam, flatten, init_hypers, kernel_from};
use super::nn::knn;
use super::{BaselineFit, BaselineModel};

/// VNNGP (nearest-neighbour variational GP) baseline configuration.
pub struct Vnngp {
    /// nearest neighbours retained
    pub k: usize,
    /// Hyperparameter-training iterations.
    pub train_iters: usize,
    /// subsample size for hyper training
    pub batch: usize,
    /// Adam learning rate.
    pub lr: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Vnngp {
    /// Baseline with the default batch size and learning rate.
    pub fn new(k: usize, train_iters: usize, seed: u64) -> Self {
        Vnngp { k, train_iters, batch: 64, lr: 0.1, seed }
    }
}

/// Local GP conditional of y(query) on (xs[nbrs], y[nbrs]).
fn local_conditional(
    x: &Matrix<f64>,
    y: &[f64],
    nbrs: &[usize],
    query: &[f64],
    hypers: &[f64],
) -> Result<(f64, f64)> {
    let d = x.cols;
    let kernel = kernel_from(hypers, d);
    let s2 = hypers[d + 1].exp();
    let os = hypers[d].exp();
    let k = nbrs.len();
    let mut knn_m = Matrix::zeros(k, k);
    for (a, &i) in nbrs.iter().enumerate() {
        for (b, &j) in nbrs.iter().enumerate() {
            knn_m[(a, b)] = kernel.eval(x.row(i), x.row(j));
        }
        knn_m[(a, a)] += s2;
    }
    let chol = cholesky(&knn_m).context("local chol")?;
    let yn: Vec<f64> = nbrs.iter().map(|&i| y[i]).collect();
    let alpha = chol.solve(&yn);
    let kq: Vec<f64> = nbrs.iter().map(|&i| kernel.eval(x.row(i), query)).collect();
    let mu: f64 = kq.iter().zip(&alpha).map(|(a, b)| a * b).sum();
    let v = {
        let w = chol.solve(&kq);
        let red: f64 = kq.iter().zip(&w).map(|(a, b)| a * b).sum();
        (os - red).max(1e-10) + s2
    };
    Ok((mu, v))
}

impl BaselineModel for Vnngp {
    fn name(&self) -> &'static str {
        "VNNGP"
    }

    fn fit_predict(&mut self, data: &GridDataset) -> Result<BaselineFit> {
        let t0 = std::time::Instant::now();
        let fd = flatten(data);
        let d = fd.x.cols;
        let mut rng = Rng::new(self.seed ^ 0x4997);
        let mut hypers = init_hypers(d);

        // precompute neighbour lists for a training subsample (fixed
        // across hyper iterations: neighbours are hyper-independent
        // under an isotropic metric)
        let batch = self.batch.min(fd.x.rows);
        let sub = rng.choose(fd.x.rows, batch);
        let nbr_lists: Vec<(usize, Vec<usize>)> = sub
            .iter()
            .map(|&i| (i, knn(&fd.x, fd.x.row(i), self.k, Some(i))))
            .collect();
        fd_adam(&mut hypers, self.train_iters, self.lr, 1e-4, |h| {
            let mut nll = 0.0;
            for (i, nbrs) in &nbr_lists {
                match local_conditional(&fd.x, &fd.y, nbrs, fd.x.row(*i), h) {
                    Ok((mu, v)) => {
                        let r = fd.y[*i] - mu;
                        nll += 0.5 * (v.ln() + r * r / v);
                    }
                    Err(_) => nll += 1e6,
                }
            }
            nll / batch as f64
        });

        // predict every grid cell from its K nearest training points
        let pq = fd.x_grid.rows;
        let mut mean = vec![0.0; pq];
        let mut var = vec![0.0; pq];
        let obs_set: Vec<usize> = data.observed_indices();
        for r in 0..pq {
            // exclude self if this grid cell is a training point
            let self_row = obs_set.iter().position(|&i| i == r);
            let nbrs = knn(&fd.x, fd.x_grid.row(r), self.k, self_row);
            let (mu, v) =
                local_conditional(&fd.x, &fd.y, &nbrs, fd.x_grid.row(r), &hypers)?;
            mean[r] = mu * fd.y_std + fd.y_mean;
            var[r] = v * fd.y_std * fd.y_std;
        }
        Ok(BaselineFit {
            posterior: Posterior { mean, var },
            train_secs: t0.elapsed().as_secs_f64(),
            hypers,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::well_specified;
    use crate::kernels::ProductGridKernel;

    #[test]
    fn fits_and_interpolates() {
        let kernel = ProductGridKernel::new(2, "rbf", 6);
        let data = well_specified(18, 6, 2, &kernel, 0.05, 0.3, 3);
        let mut model = Vnngp::new(12, 8, 0);
        let fit = model.fit_predict(&data).unwrap();
        let (train_rmse, _) = fit.posterior.train_metrics(&data);
        let (test_rmse, _) = fit.posterior.test_metrics(&data);
        let (_, y_std) = data.target_stats();
        assert!(train_rmse < 0.7 * y_std, "train {train_rmse} vs {y_std}");
        assert!(test_rmse < 1.3 * y_std, "test {test_rmse}");
        assert!(train_rmse <= test_rmse + 0.1 * y_std);
    }

    #[test]
    fn local_conditional_exact_for_full_neighborhood() {
        // k = n makes VNNGP's local conditional the exact GP posterior
        let kernel = ProductGridKernel::new(1, "rbf", 4);
        let data = well_specified(5, 4, 1, &kernel, 0.05, 0.25, 6);
        let fd = flatten(&data);
        let h = init_hypers(fd.x.cols);
        let nbrs: Vec<usize> = (0..fd.x.rows).collect();
        let q = fd.x_grid.row(0).to_vec();
        let (mu, _) = local_conditional(&fd.x, &fd.y, &nbrs, &q, &h).unwrap();
        // exact GP
        let kern = kernel_from(&h, fd.x.cols);
        let s2 = h[fd.x.cols + 1].exp();
        let mut knn_m = kern.gram(&fd.x, &fd.x);
        knn_m.add_diag(s2);
        let chol = cholesky(&knn_m).unwrap();
        let alpha = chol.solve(&fd.y);
        let want: f64 =
            (0..fd.x.rows).map(|j| kern.eval(fd.x.row(j), &q) * alpha[j]).sum();
        assert!((mu - want).abs() < 1e-8, "{mu} vs {want}");
    }
}
