//! k-nearest-neighbour search for VNNGP.
//!
//! Brute-force partial-selection kNN (n is moderate at this testbed's
//! scale; a KD-tree gains little above d ~ 8, and SARCOS has d = 22).

use crate::linalg::Matrix;

/// Squared Euclidean distance between rows.
#[inline]
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Indices of the k nearest rows of `xs` to `query` (excluding any index
/// in `exclude`), ascending by distance.
pub fn knn(xs: &Matrix<f64>, query: &[f64], k: usize, exclude: Option<usize>) -> Vec<usize> {
    let k = k.min(xs.rows);
    // (dist, idx) max-heap of size k via simple insertion (k is small)
    let mut best: Vec<(f64, usize)> = Vec::with_capacity(k + 1);
    for i in 0..xs.rows {
        if exclude == Some(i) {
            continue;
        }
        let d = sqdist(xs.row(i), query);
        if best.len() < k {
            best.push((d, i));
            best.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        } else if d < best[k - 1].0 {
            best[k - 1] = (d, i);
            let mut j = k - 1;
            while j > 0 && best[j].0 < best[j - 1].0 {
                best.swap(j, j - 1);
                j -= 1;
            }
        }
    }
    best.into_iter().map(|(_, i)| i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn finds_true_neighbors() {
        let mut rng = Rng::new(0);
        let xs = Matrix::from_vec(50, 3, rng.normals(150));
        let q = vec![0.1, -0.2, 0.3];
        let got = knn(&xs, &q, 5, None);
        // brute-force reference via full sort
        let mut all: Vec<(f64, usize)> =
            (0..50).map(|i| (sqdist(xs.row(i), &q), i)).collect();
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let want: Vec<usize> = all[..5].iter().map(|&(_, i)| i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn exclude_self() {
        let xs = Matrix::from_vec(3, 1, vec![0.0, 1.0, 2.0]);
        let got = knn(&xs, &[0.0], 2, Some(0));
        assert_eq!(got, vec![1, 2]);
    }

    #[test]
    fn k_larger_than_n_clamps() {
        let xs = Matrix::from_vec(3, 1, vec![0.0, 1.0, 2.0]);
        assert_eq!(knn(&xs, &[5.0], 10, None).len(), 3);
    }
}
