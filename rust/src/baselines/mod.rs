//! Sparse / variational / computation-aware GP baselines.
//!
//! The comparison set of the paper's Tables 1–2: SVGP (Hensman et al.
//! 2013), VNNGP (Wu et al. 2022), and CaGP (Wenger et al. 2024),
//! implemented in pure rust over the same datasets and metrics.
//!
//! Implementation notes (scaled to this testbed, see DESIGN.md):
//! * Baselines model observations as points x = [s, t] in R^{d_s+1}
//!   with an isotropic-per-dim SE kernel — the product-kernel structure
//!   is the *LKGP* contribution; baselines are generic GP approximations.
//! * With a Gaussian likelihood the optimum of SVGP's uncollapsed ELBO
//!   is the Titsias collapsed solution; we train hyperparameters by
//!   maximizing the collapsed ELBO directly (finite-difference Adam) and
//!   recover q(u) in closed form. This is mathematically equivalent to
//!   converged SVGP and avoids hand-deriving dozens of gradient terms.
//! * VNNGP keeps inducing points at all training inputs and retains only
//!   K-nearest-neighbor correlations — predictions are local-GP
//!   conditionals, reproducing VNNGP's characteristic overconfidence
//!   away from data.
//! * CaGP projects inference onto m "actions" (CG directions on the
//!   training system), with the guaranteed variance inflation
//!   (computational uncertainty) of the original method.

pub mod cagp;
pub mod common;
pub mod nn;
pub mod svgp;
pub mod vnngp;

pub use cagp::CaGp;
pub use svgp::Svgp;
pub use vnngp::Vnngp;

use crate::data::GridDataset;
use crate::gp::Posterior;

/// Uniform interface so experiment runners can iterate over models.
pub trait BaselineModel {
    /// Model name for tables/reports.
    fn name(&self) -> &'static str;
    /// Fit on the observed cells and predict the full grid.
    fn fit_predict(&mut self, data: &GridDataset) -> crate::Result<BaselineFit>;
}

/// Result of one baseline fit.
pub struct BaselineFit {
    /// Full-grid predictive posterior (raw target scale).
    pub posterior: Posterior,
    /// Wall-clock seconds of fitting + prediction.
    pub train_secs: f64,
    /// Fitted hyperparameters (model-specific layout).
    pub hypers: Vec<f64>,
}
