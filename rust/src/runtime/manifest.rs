//! artifacts/manifest.json loader — the ABI between the AOT compile path
//! (python/compile/aot.py) and the rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::util::json::Json;

/// One declared input tensor of an artifact.
#[derive(Clone, Debug)]
pub struct ArtifactInput {
    /// Input name (diagnostics only).
    pub name: String,
    /// Static shape the artifact was compiled for.
    pub shape: Vec<usize>,
}

impl ArtifactInput {
    /// Element count of the input (at least 1, scalars included).
    pub fn len(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One compiled artifact: its HLO file and declared inputs.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Path of the `.hlo.txt` file.
    pub file: PathBuf,
    /// Declared input tensors, in call order.
    pub inputs: Vec<ArtifactInput>,
}

/// One artifact configuration (a fixed problem shape).
#[derive(Clone, Debug)]
pub struct ConfigMeta {
    /// Configuration name.
    pub name: String,
    /// Spatial points the artifacts were compiled for.
    pub p: usize,
    /// Time steps the artifacts were compiled for.
    pub q: usize,
    /// Spatial input dimension.
    pub ds: usize,
    /// Time-kernel family.
    pub kernel_t: String,
    /// Static batch size of the batched artifacts.
    pub batch: usize,
    /// Static Hutchinson probe count.
    pub probes: usize,
    /// Hyperparameter-vector length.
    pub n_theta: usize,
    /// Artifacts by operation name (`kron_mvm`, `kernels`, ...).
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

/// Parsed artifacts/manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Directory the manifest (and artifact files) live in.
    pub dir: PathBuf,
    /// Configurations by name.
    pub configs: BTreeMap<String, ConfigMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        if root.at(&["version"]).as_usize() != Some(1) {
            bail!("unsupported manifest version");
        }
        let mut configs = BTreeMap::new();
        let Some(cfgs) = root.get("configs").and_then(|c| c.as_obj()) else {
            bail!("manifest missing configs");
        };
        for (cname, c) in cfgs {
            let geti = |k: &str| -> anyhow::Result<usize> {
                c.get(k).and_then(|v| v.as_usize()).context(format!("config {cname}: {k}"))
            };
            let mut artifacts = BTreeMap::new();
            let arts = c.get("artifacts").and_then(|a| a.as_obj()).unwrap_or(&[]);
            for (aname, a) in arts {
                let file = a
                    .get("file")
                    .and_then(|f| f.as_str())
                    .context("artifact file")?
                    .to_string();
                let mut inputs = Vec::new();
                for inp in a.get("inputs").and_then(|i| i.as_arr()).unwrap_or(&[]) {
                    inputs.push(ArtifactInput {
                        name: inp.get("name").and_then(|n| n.as_str()).unwrap_or("").into(),
                        shape: inp
                            .get("shape")
                            .and_then(|s| s.as_arr())
                            .map(|s| s.iter().filter_map(|d| d.as_usize()).collect())
                            .unwrap_or_default(),
                    });
                }
                artifacts
                    .insert(aname.clone(), ArtifactMeta { file: dir.join(file), inputs });
            }
            configs.insert(
                cname.clone(),
                ConfigMeta {
                    name: cname.clone(),
                    p: geti("p")?,
                    q: geti("q")?,
                    ds: geti("ds")?,
                    kernel_t: c
                        .get("kernel_t")
                        .and_then(|v| v.as_str())
                        .unwrap_or("rbf")
                        .into(),
                    batch: geti("batch")?,
                    probes: geti("probes")?,
                    n_theta: geti("n_theta")?,
                    artifacts,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), configs })
    }

    /// Look up a configuration by name (error lists the known names).
    pub fn config(&self, name: &str) -> anyhow::Result<&ConfigMeta> {
        self.configs
            .get(name)
            .with_context(|| format!("config {name:?} not in manifest ({:?})",
                self.configs.keys().collect::<Vec<_>>()))
    }

    /// Default artifact directory: $LKGP_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("LKGP_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
            // walk up from cwd to find artifacts/manifest.json (tests run
            // from target subdirs)
            let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            for _ in 0..4 {
                let cand = cur.join("artifacts");
                if cand.join("manifest.json").exists() {
                    return cand;
                }
                if !cur.pop() {
                    break;
                }
            }
            PathBuf::from("artifacts")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_generated_manifest_if_present() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        let tiny = man.config("tiny").unwrap();
        assert_eq!(tiny.p * tiny.q, 128);
        let mvm = &tiny.artifacts["kron_mvm"];
        assert_eq!(mvm.inputs.len(), 5);
        assert_eq!(mvm.inputs[4].shape, vec![tiny.batch, tiny.p * tiny.q]);
        assert!(mvm.file.exists());
    }

    #[test]
    fn missing_config_is_error() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        assert!(man.config("nope").is_err());
    }
}
