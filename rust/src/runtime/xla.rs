//! Minimal stand-in for the `xla` PJRT bindings.
//!
//! The offline build environment does not vendor the real `xla` crate,
//! so this module provides exactly the API surface `runtime::Runtime`
//! uses; every entry point that would touch PJRT reports an
//! unavailable-runtime error at call time instead. All artifact-gated
//! tests, benches and examples self-skip when `artifacts/manifest.json`
//! is absent, so in practice the stub only has to satisfy the type
//! checker — and when artifacts *are* present but the real bindings are
//! not, callers get a clean `Result::Err` rather than a panic. To use
//! real PJRT, replace `mod xla;` in `runtime/mod.rs` with the external
//! dependency of the same name (the signatures match).

use std::fmt;

/// Error type for all stubbed PJRT operations.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla unavailable: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError(format!(
        "{what}: PJRT bindings are not built into this binary (offline build)"
    )))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable("PjRtClient::compile")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, XlaError> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct Literal;

impl Literal {
    pub fn scalar(_x: f32) -> Self {
        Literal
    }

    pub fn vec1(_xs: &[f32]) -> Self {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Ok(Literal)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable("Literal::to_vec")
    }
}
