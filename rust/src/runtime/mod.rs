//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on
//! the CPU PJRT client (`xla` crate). This is the only place the process
//! touches XLA; Python never runs here.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO text ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Artifacts were lowered with
//! return_tuple=True, so every execution returns one tuple literal that
//! is decomposed into the artifact's outputs.

pub mod manifest;
/// PJRT bindings: an in-tree stub with the real crate's signatures (the
/// offline build has no `xla` dependency; see xla.rs to swap in the
/// real bindings).
mod xla;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

pub use manifest::{ArtifactMeta, ConfigMeta, Manifest};

/// Handle to a compiled artifact set + the PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    /// The parsed artifact manifest.
    pub manifest: Manifest,
    cache: HashMap<(String, String), xla::PjRtLoadedExecutable>,
    /// wall time spent inside PJRT execute (for the perf pass)
    pub exec_secs: f64,
    /// Number of artifact executions performed.
    pub exec_calls: u64,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifact directory.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: HashMap::new(), exec_secs: 0.0, exec_calls: 0 })
    }

    /// Load from the default artifact location.
    pub fn load_default() -> Result<Runtime> {
        Self::load(&Manifest::default_dir())
    }

    /// PJRT platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact executable.
    fn executable(&mut self, config: &str, artifact: &str) -> Result<&xla::PjRtLoadedExecutable> {
        let key = (config.to_string(), artifact.to_string());
        if !self.cache.contains_key(&key) {
            let cfg = self.manifest.config(config)?;
            let meta = cfg
                .artifacts
                .get(artifact)
                .with_context(|| format!("artifact {artifact:?} in config {config:?}"))?;
            let proto = xla::HloModuleProto::from_text_file(
                meta.file.to_str().context("artifact path utf8")?,
            )
            .with_context(|| format!("parsing HLO text {:?}", meta.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {config}/{artifact}"))?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(&self.cache[&key])
    }

    /// Pre-compile all artifacts of a config (so timing loops exclude
    /// compilation).
    pub fn warmup(&mut self, config: &str) -> Result<()> {
        let names: Vec<String> =
            self.manifest.config(config)?.artifacts.keys().cloned().collect();
        for a in names {
            self.executable(config, &a)?;
        }
        Ok(())
    }

    /// Execute `config/artifact` with f32 tensor inputs, checking shapes
    /// against the manifest ABI. Returns the decomposed output tuple as
    /// f32 vectors.
    pub fn exec_f32(
        &mut self,
        config: &str,
        artifact: &str,
        inputs: &[TensorF32],
    ) -> Result<Vec<Vec<f32>>> {
        // ABI check
        {
            let cfg = self.manifest.config(config)?;
            let meta = cfg.artifacts.get(artifact).context("artifact")?;
            if meta.inputs.len() != inputs.len() {
                bail!(
                    "{config}/{artifact}: expected {} inputs, got {}",
                    meta.inputs.len(),
                    inputs.len()
                );
            }
            for (spec, got) in meta.inputs.iter().zip(inputs) {
                if spec.len() != got.data.len() {
                    bail!(
                        "{config}/{artifact}: input {:?} expects {:?} ({} elems), got {}",
                        spec.name,
                        spec.shape,
                        spec.len(),
                        got.data.len()
                    );
                }
            }
        }
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let t0 = std::time::Instant::now();
        let exe = self.executable(config, artifact)?;
        let result = exe.execute::<xla::Literal>(&lits).context("execute")?;
        let tuple = result[0][0].to_literal_sync()?;
        self.exec_secs += t0.elapsed().as_secs_f64();
        self.exec_calls += 1;
        let parts = tuple.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for lit in parts {
            out.push(lit.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// A shaped f32 tensor crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub struct TensorF32 {
    /// Tensor shape (empty = scalar).
    pub shape: Vec<usize>,
    /// Row-major element data.
    pub data: Vec<f32>,
}

impl TensorF32 {
    /// Shaped tensor (asserts the element count).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len().max(1));
        TensorF32 { shape, data }
    }

    /// Rank-0 scalar tensor.
    pub fn scalar(x: f32) -> Self {
        TensorF32 { shape: vec![], data: vec![x] }
    }

    /// Rank-1 tensor over the data.
    pub fn vec1(data: Vec<f32>) -> Self {
        TensorF32 { shape: vec![data.len()], data }
    }

    /// Narrow f64 host data through the crate's single rounding point
    /// (`util::convert`) — the same conversion the mixed-precision
    /// compute path uses.
    pub fn from_f64(shape: Vec<usize>, data: &[f64]) -> Self {
        TensorF32::new(shape, crate::util::convert::f32_vec(data))
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        if self.shape.is_empty() {
            return Ok(xla::Literal::scalar(self.data[0]));
        }
        let flat = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(flat.reshape(&dims)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime_or_skip() -> Option<Runtime> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::load(&dir).unwrap())
    }

    #[test]
    fn executes_kernels_artifact() {
        let Some(mut rt) = runtime_or_skip() else { return };
        let cfg = rt.manifest.config("tiny").unwrap().clone();
        let (p, q, ds, nt) = (cfg.p, cfg.q, cfg.ds, cfg.n_theta);
        let s =
            TensorF32::new(vec![p, ds], (0..p * ds).map(|i| (i as f32 * 0.1).sin()).collect());
        let t = TensorF32::new(vec![q, 1], (0..q).map(|i| i as f32 / q as f32).collect());
        let theta = TensorF32::vec1(vec![0.0; nt]);
        let out = rt.exec_f32("tiny", "kernels", &[s, t, theta]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), p * p);
        assert_eq!(out[1].len(), q * q);
        // K_SS diagonal = outputscale exp(0) = 1
        for i in 0..p {
            assert!((out[0][i * p + i] - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn shape_mismatch_is_error() {
        let Some(mut rt) = runtime_or_skip() else { return };
        let bad = TensorF32::vec1(vec![0.0; 3]);
        assert!(rt.exec_f32("tiny", "kernels", &[bad.clone(), bad.clone(), bad]).is_err());
    }
}
