//! Long-lived TCP prediction daemon with cross-request batching.
//!
//! [`ServeDaemon`] holds one or more resident [`ServeEngine`]s (one per
//! checkpoint, keyed by model id) behind a dependency-free TCP
//! endpoint speaking the length-prefixed binary protocol of
//! [`crate::util::wire`] (spec: `docs/formats.md`). The serving model:
//!
//! * **Accept loop** (one thread): accepts connections and spawns one
//!   reader thread per connection. An armed `serve_accept` failpoint
//!   rejects the connection with a typed error frame — the daemon
//!   itself keeps serving.
//! * **Connection threads**: read frames, decode requests, answer pings
//!   immediately, and hand predict requests to the batcher. Every
//!   malformed, truncated, or mid-read-disconnected frame becomes a
//!   typed [`Response::Error`] (and, for framing-level corruption where
//!   the byte stream can no longer be trusted, a closed connection) —
//!   never a daemon crash.
//! * **Batcher** (one thread, when the admission window is nonzero):
//!   collects predict requests from *all* connections for up to
//!   `window_ms` (closing early at `max_batch`), coalesces them into a
//!   single [`ServeEngine::predict_batch`] sweep per model, and
//!   demultiplexes the responses back per connection with one coalesced
//!   socket write each. This lifts `predict_batch`'s within-call
//!   coalescing to *cross-request* coalescing: many tiny concurrent
//!   queries ride one steal-scheduled sweep.
//!
//! **Determinism contract.** `predict_batch` guarantees that batch
//! grouping never changes output bits, so the daemon inherits it: the
//! bytes a client reads back for a given cell list are identical
//! whether its request was answered alone (window 0), coalesced with
//! a hundred strangers, or computed offline by `lkgp predict` — at any
//! `LKGP_THREADS`. The serve CI job asserts exactly this across the
//! wire.

use std::collections::{BTreeMap, HashMap};
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::gp::diagnostics::{ServeCounters, ServeReport};
use crate::serve::{BatchRequest, BatchResponse, ServeEngine};
use crate::util::failpoint;
use crate::util::wire::{
    decode_response, encode_response, read_frame, write_frame, Request, Response, WireError,
    MAX_FRAME_BYTES,
};

/// Tuning knobs of a [`ServeDaemon`]. `Default::default()` does not
/// read the environment; the CLI maps `--window` / `LKGP_SERVE_WINDOW`
/// onto `window_ms`.
#[derive(Clone, Debug)]
pub struct DaemonOptions {
    /// Cross-request admission window in milliseconds: how long the
    /// batcher collects predict requests before sweeping. `0` disables
    /// cross-request batching — every request dispatches on its own
    /// (the serial baseline `bench_serve` compares against).
    pub window_ms: u64,
    /// Close the window early once this many requests are queued.
    pub max_batch: usize,
    /// Per-frame payload bound handed to [`read_frame`]; a length
    /// prefix above this is rejected before allocating.
    pub max_frame_bytes: usize,
}

impl Default for DaemonOptions {
    fn default() -> Self {
        DaemonOptions {
            window_ms: crate::gp::lkgp::LkgpConfig::default().serve_batch_window_ms,
            max_batch: 1024,
            max_frame_bytes: MAX_FRAME_BYTES,
        }
    }
}

/// One queued predict request awaiting the batcher's sweep.
struct Pending {
    req_id: u64,
    /// Resolved model id (guaranteed present in `Shared::engines`).
    model: String,
    cells: Vec<usize>,
    conn: Arc<ConnWriter>,
    t0: Instant,
}

/// The write half of a connection, shared between its reader thread and
/// the batcher. Responses for one connection serialize on this lock.
struct ConnWriter {
    stream: Mutex<TcpStream>,
}

impl ConnWriter {
    /// Encode + frame + write one response (best effort: a vanished
    /// client is the client's problem, not the daemon's).
    fn respond(&self, resp: &Response) -> Result<(), WireError> {
        let payload = encode_response(resp);
        let mut s = self.stream.lock().unwrap_or_else(|e| e.into_inner());
        write_frame(&mut *s, &payload)
    }

    fn shutdown_socket(&self) {
        let s = self.stream.lock().unwrap_or_else(|e| e.into_inner());
        let _ = s.shutdown(std::net::Shutdown::Both);
    }
}

/// State shared by the accept loop, connection threads, and batcher.
struct Shared {
    engines: BTreeMap<String, Arc<ServeEngine>>,
    /// Pre-rendered model listing answering pings.
    info_line: String,
    queue: Mutex<Vec<Pending>>,
    cv: Condvar,
    shutdown: AtomicBool,
    counters: Arc<ServeCounters>,
    /// Live connection writers, so a daemon shutdown can unblock reader
    /// threads parked inside `read_frame`.
    conns: Mutex<Vec<Weak<ConnWriter>>>,
    window_ms: u64,
    max_batch: usize,
    max_frame_bytes: usize,
    addr: SocketAddr,
}

impl Shared {
    fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    /// Resolve a request's model id to an engine. An empty id is
    /// shorthand for "the only model" and errors when several are
    /// loaded.
    fn resolve(&self, model: &str) -> Result<(String, Arc<ServeEngine>), String> {
        if model.is_empty() {
            if self.engines.len() == 1 {
                let (id, e) = self
                    .engines
                    .iter()
                    .next()
                    .map(|(k, v)| (k.clone(), Arc::clone(v)))
                    .unwrap_or_else(|| unreachable!("len checked above"));
                return Ok((id, e));
            }
            return Err(format!(
                "request names no model but {} are loaded (available: {})",
                self.engines.len(),
                self.model_ids()
            ));
        }
        match self.engines.get(model) {
            Some(e) => Ok((model.to_string(), Arc::clone(e))),
            None => Err(format!("unknown model {model:?} (available: {})", self.model_ids())),
        }
    }

    fn model_ids(&self) -> String {
        self.engines.keys().cloned().collect::<Vec<_>>().join(", ")
    }
}

/// Wake the accept loop out of its blocking `accept` by connecting to
/// ourselves; the loop re-checks the shutdown flag on every iteration.
fn wake_accept(shared: &Shared) {
    let _ = TcpStream::connect(shared.addr);
}

/// A running serve daemon. Dropping the handle shuts the daemon down;
/// [`ServeDaemon::wait`] blocks until a client sends a shutdown
/// request (the CLI `lkgp serve` path).
pub struct ServeDaemon {
    shared: Arc<Shared>,
    accept_handle: Option<JoinHandle<()>>,
    batcher_handle: Option<JoinHandle<()>>,
}

impl ServeDaemon {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving the given engines. Model ids must be unique and
    /// non-empty; at least one engine is required.
    pub fn start(
        addr: &str,
        engines: Vec<(String, ServeEngine)>,
        opts: DaemonOptions,
    ) -> Result<ServeDaemon> {
        if engines.is_empty() {
            bail!("serve daemon needs at least one checkpoint");
        }
        let mut map = BTreeMap::new();
        for (id, engine) in engines {
            if id.is_empty() {
                bail!("empty model id (checkpoint file stems name the models)");
            }
            if map.insert(id.clone(), Arc::new(engine)).is_some() {
                bail!("duplicate model id {id:?}: checkpoint file stems must be unique");
            }
        }
        let info_line = map
            .iter()
            .map(|(id, e)| format!("{id} ({} x {})", e.model().p(), e.model().q()))
            .collect::<Vec<_>>()
            .join(", ");
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr().context("resolving bound address")?;
        let shared = Arc::new(Shared {
            engines: map,
            info_line,
            queue: Mutex::new(Vec::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            counters: Arc::new(ServeCounters::default()),
            conns: Mutex::new(Vec::new()),
            window_ms: opts.window_ms,
            max_batch: opts.max_batch.max(1),
            max_frame_bytes: opts.max_frame_bytes,
            addr: local,
        });
        let batcher_handle = if opts.window_ms > 0 {
            let s = Arc::clone(&shared);
            Some(
                std::thread::Builder::new()
                    .name("lkgp-serve-batcher".into())
                    .spawn(move || batcher_loop(&s))
                    .context("spawning batcher thread")?,
            )
        } else {
            None
        };
        let s = Arc::clone(&shared);
        let accept_handle = std::thread::Builder::new()
            .name("lkgp-serve-accept".into())
            .spawn(move || accept_loop(&s, &listener))
            .context("spawning accept thread")?;
        Ok(ServeDaemon { shared, accept_handle: Some(accept_handle), batcher_handle })
    }

    /// The address the daemon is actually listening on (resolves the
    /// ephemeral port of a `:0` bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Live serve counters (shared with the serving threads).
    pub fn counters(&self) -> Arc<ServeCounters> {
        Arc::clone(&self.shared.counters)
    }

    /// Block until a client's shutdown request stops the daemon, then
    /// return the final counter report. This is the CLI's foreground
    /// path; tests usually use [`ServeDaemon::shutdown`] instead.
    pub fn wait(mut self) -> ServeReport {
        self.join();
        self.shared.counters.report()
    }

    /// Stop the daemon from this side: unblock the accept loop, flush
    /// the batcher, unblock parked connection readers, join the service
    /// threads, and return the final counter report. Idempotent.
    pub fn shutdown(&mut self) -> ServeReport {
        self.shared.shutdown.store(true, Ordering::Release);
        wake_accept(&self.shared);
        self.join();
        self.shared.counters.report()
    }

    fn join(&mut self) {
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cv.notify_all();
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join();
        }
        let conns = self.shared.conns.lock().unwrap_or_else(|e| e.into_inner());
        for weak in conns.iter() {
            if let Some(conn) = weak.upgrade() {
                conn.shutdown_socket();
            }
        }
    }
}

impl Drop for ServeDaemon {
    fn drop(&mut self) {
        if self.accept_handle.is_some() || self.batcher_handle.is_some() {
            let _ = self.shutdown();
        }
    }
}

fn accept_loop(shared: &Arc<Shared>, listener: &TcpListener) {
    use std::sync::atomic::Ordering::Relaxed;
    for stream in listener.incoming() {
        if shared.is_shutdown() {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue, // transient accept error; keep serving
        };
        if let Some(action) = failpoint::check("serve_accept") {
            // reject this one connection with a typed error frame; the
            // daemon itself stays up
            shared.counters.errors.fetch_add(1, Relaxed);
            let conn = ConnWriter { stream: Mutex::new(stream) };
            let _ = conn.respond(&Response::Error {
                id: 0,
                message: format!("injected fault at failpoint serve_accept ({action:?})"),
            });
            continue;
        }
        let _ = stream.set_nodelay(true);
        shared.counters.connections.fetch_add(1, Relaxed);
        let writer = match stream.try_clone() {
            Ok(w) => Arc::new(ConnWriter { stream: Mutex::new(w) }),
            Err(_) => continue, // cannot even clone the fd; drop it
        };
        {
            let mut conns = shared.conns.lock().unwrap_or_else(|e| e.into_inner());
            conns.retain(|w| w.strong_count() > 0);
            conns.push(Arc::downgrade(&writer));
        }
        let s = Arc::clone(shared);
        let spawned = std::thread::Builder::new()
            .name("lkgp-serve-conn".into())
            .spawn(move || handle_conn(&s, stream, writer));
        if spawned.is_err() {
            // thread exhaustion: drop the connection, keep accepting
            continue;
        }
    }
}

/// Read-decode-respond loop of one connection. Returns (closing the
/// connection) on clean EOF, framing-level corruption, or shutdown;
/// payload-level decode errors answer with a typed error and keep the
/// connection open.
fn handle_conn(shared: &Arc<Shared>, mut stream: TcpStream, conn: Arc<ConnWriter>) {
    use std::sync::atomic::Ordering::Relaxed;
    loop {
        let payload = match read_frame(&mut stream, shared.max_frame_bytes) {
            Ok(Some(p)) => p,
            Ok(None) => return, // client closed cleanly between frames
            Err(e) => {
                // the byte stream can no longer be trusted: answer with
                // a typed error, then drop the connection
                shared.counters.errors.fetch_add(1, Relaxed);
                let _ = conn.respond(&Response::Error { id: 0, message: e.to_string() });
                return;
            }
        };
        let req = match crate::util::wire::decode_request(&payload) {
            Ok(r) => r,
            Err(e) => {
                // frame boundary was intact, so the stream stays usable
                shared.counters.errors.fetch_add(1, Relaxed);
                let _ = conn.respond(&Response::Error { id: 0, message: e.to_string() });
                continue;
            }
        };
        shared.counters.requests.fetch_add(1, Relaxed);
        match req {
            Request::Ping { id } => {
                let _ = conn
                    .respond(&Response::Info { id, info: format!("models: {}", shared.info_line) });
            }
            Request::Shutdown { id } => {
                let _ = conn.respond(&Response::ShutdownAck { id });
                shared.shutdown.store(true, Ordering::Release);
                shared.cv.notify_all();
                wake_accept(shared);
                return;
            }
            Request::Predict { id, model, cells } => {
                shared.counters.predict_requests.fetch_add(1, Relaxed);
                let t0 = Instant::now();
                let (model, engine) = match shared.resolve(&model) {
                    Ok(pair) => pair,
                    Err(msg) => {
                        shared.counters.errors.fetch_add(1, Relaxed);
                        let _ = conn.respond(&Response::Error { id, message: msg });
                        continue;
                    }
                };
                // validate cells here so one bad request can never fail
                // a whole coalesced sweep
                let pq = engine.model().grid_len();
                if let Some(&bad) = cells.iter().find(|&&c| c >= pq) {
                    shared.counters.errors.fetch_add(1, Relaxed);
                    let _ = conn.respond(&Response::Error {
                        id,
                        message: format!(
                            "cell index {bad} out of range (model {model:?} has {pq} cells)"
                        ),
                    });
                    continue;
                }
                if shared.window_ms == 0 {
                    // serial dispatch: answer inline, one request per sweep
                    answer_inline(shared, &conn, &engine, id, cells, t0);
                } else {
                    if shared.is_shutdown() {
                        let _ = conn.respond(&Response::Error {
                            id,
                            message: "daemon is shutting down".to_string(),
                        });
                        return;
                    }
                    let mut q = shared.queue.lock().unwrap_or_else(|e| e.into_inner());
                    q.push(Pending { req_id: id, model, cells, conn: Arc::clone(&conn), t0 });
                    drop(q);
                    shared.cv.notify_all();
                }
            }
        }
    }
}

/// Window-0 path: one `predict_batch` sweep per request.
fn answer_inline(
    shared: &Shared,
    conn: &ConnWriter,
    engine: &ServeEngine,
    id: u64,
    cells: Vec<usize>,
    t0: Instant,
) {
    use std::sync::atomic::Ordering::Relaxed;
    let n_cells = cells.len() as u64;
    let resp = match engine.predict_batch(&[BatchRequest { cells }]) {
        Ok(mut rs) => match rs.pop() {
            Some(BatchResponse { mean, var }) => Response::Predict { id, mean, var },
            None => {
                shared.counters.errors.fetch_add(1, Relaxed);
                Response::Error { id, message: "empty predict_batch result".to_string() }
            }
        },
        Err(e) => {
            shared.counters.errors.fetch_add(1, Relaxed);
            Response::Error { id, message: format!("predict failed: {e:#}") }
        }
    };
    shared.counters.record_batch(1, n_cells);
    let _ = conn.respond(&resp);
    shared.counters.record_latency_us(t0.elapsed().as_micros() as u64);
}

/// Cross-request batcher: wait for the first pending request, hold the
/// admission window open, then sweep everything that arrived.
fn batcher_loop(shared: &Arc<Shared>) {
    loop {
        // park until there is work (or we are told to stop and the
        // queue is drained)
        let first_t0 = {
            let mut q = shared.queue.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if let Some(p) = q.first() {
                    break p.t0;
                }
                if shared.is_shutdown() {
                    return;
                }
                let (guard, _) = shared
                    .cv
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap_or_else(|e| e.into_inner());
                q = guard;
            }
        };
        // admission window: collect more requests until the deadline,
        // the early-close threshold, or shutdown
        let deadline = first_t0 + Duration::from_millis(shared.window_ms);
        let pendings = {
            let mut q = shared.queue.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if q.len() >= shared.max_batch || shared.is_shutdown() {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = shared
                    .cv
                    .wait_timeout(q, deadline - now)
                    .unwrap_or_else(|e| e.into_inner());
                q = guard;
            }
            std::mem::take(&mut *q)
        };
        if !pendings.is_empty() {
            sweep(shared, pendings);
        }
    }
}

/// One coalesced sweep: group pendings by model (arrival order
/// preserved within each model), run one `predict_batch` per model,
/// demultiplex, and write each connection's responses with a single
/// coalesced socket write.
fn sweep(shared: &Shared, pendings: Vec<Pending>) {
    use std::sync::atomic::Ordering::Relaxed;
    let n = pendings.len();
    let total_cells: u64 = pendings.iter().map(|p| p.cells.len() as u64).sum();
    let mut groups: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    for (i, p) in pendings.iter().enumerate() {
        groups.entry(p.model.as_str()).or_default().push(i);
    }
    let mut responses: Vec<Option<Response>> = (0..n).map(|_| None).collect();
    for (model, idxs) in &groups {
        let Some(engine) = shared.engines.get(*model) else {
            continue; // unreachable: resolved before enqueue
        };
        let reqs: Vec<BatchRequest> =
            idxs.iter().map(|&i| BatchRequest { cells: pendings[i].cells.clone() }).collect();
        match engine.predict_batch(&reqs) {
            Ok(rs) => {
                for (&i, r) in idxs.iter().zip(rs) {
                    responses[i] =
                        Some(Response::Predict { id: pendings[i].req_id, mean: r.mean, var: r.var });
                }
            }
            Err(e) => {
                for &i in idxs.iter() {
                    shared.counters.errors.fetch_add(1, Relaxed);
                    responses[i] = Some(Response::Error {
                        id: pendings[i].req_id,
                        message: format!("predict failed: {e:#}"),
                    });
                }
            }
        }
    }
    shared.counters.record_batch(n as u64, total_cells);
    // demultiplex: one write buffer per connection, frames in arrival
    // order, flushed with a single write_all per connection
    let mut bufs: Vec<(Arc<ConnWriter>, Vec<u8>)> = Vec::new();
    let mut by_conn: HashMap<usize, usize> = HashMap::new();
    for (i, p) in pendings.iter().enumerate() {
        let Some(resp) = &responses[i] else { continue };
        let key = Arc::as_ptr(&p.conn) as usize;
        let bi = *by_conn.entry(key).or_insert_with(|| {
            bufs.push((Arc::clone(&p.conn), Vec::new()));
            bufs.len() - 1
        });
        let payload = encode_response(resp);
        let _ = write_frame(&mut bufs[bi].1, &payload); // Vec write is infallible
    }
    for (conn, bytes) in &bufs {
        let mut s = conn.stream.lock().unwrap_or_else(|e| e.into_inner());
        let _ = s.write_all(bytes); // a vanished client cannot fail the sweep
    }
    let now = Instant::now();
    for p in &pendings {
        shared.counters.record_latency_us(now.duration_since(p.t0).as_micros() as u64);
    }
}

// ---------------------------------------------------------------------
// client
// ---------------------------------------------------------------------

/// Minimal blocking client for the serve protocol — what
/// `lkgp predict --addr` and the serve tests/benches use. Requests can
/// be pipelined: issue many [`ServeClient::send`]s, then collect the
/// responses (matching on [`Response::id`]) with
/// [`ServeClient::recv`].
pub struct ServeClient {
    stream: TcpStream,
    max_frame_bytes: usize,
    next_id: u64,
}

impl ServeClient {
    /// Connect to a running daemon.
    pub fn connect(addr: &str) -> Result<ServeClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        let _ = stream.set_nodelay(true);
        Ok(ServeClient { stream, max_frame_bytes: MAX_FRAME_BYTES, next_id: 1 })
    }

    /// Allocate the next request id on this connection.
    pub fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Send one request without waiting for its response (pipelining).
    pub fn send(&mut self, req: &Request) -> Result<()> {
        let payload = crate::util::wire::encode_request(req);
        write_frame(&mut self.stream, &payload).context("sending request frame")?;
        Ok(())
    }

    /// Receive the next response frame.
    pub fn recv(&mut self) -> Result<Response> {
        let payload = read_frame(&mut self.stream, self.max_frame_bytes)
            .context("reading response frame")?
            .ok_or_else(|| anyhow::anyhow!("server closed the connection"))?;
        decode_response(&payload).context("decoding response frame").map_err(Into::into)
    }

    /// Round-trip one request.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        self.send(req)?;
        self.recv()
    }

    /// Predict `cells` of `model` (empty string = the only loaded
    /// model), turning a served [`Response::Error`] into a typed
    /// client-side error.
    pub fn predict(&mut self, model: &str, cells: &[usize]) -> Result<BatchResponse> {
        let id = self.fresh_id();
        let resp = self.call(&Request::Predict {
            id,
            model: model.to_string(),
            cells: cells.to_vec(),
        })?;
        match resp {
            Response::Predict { id: rid, mean, var } if rid == id => {
                Ok(BatchResponse { mean, var })
            }
            Response::Error { message, .. } => bail!("server error: {message}"),
            other => bail!("unexpected response {other:?} to predict request {id}"),
        }
    }

    /// Ping the daemon, returning its model listing.
    pub fn ping(&mut self) -> Result<String> {
        let id = self.fresh_id();
        match self.call(&Request::Ping { id })? {
            Response::Info { id: rid, info } if rid == id => Ok(info),
            Response::Error { message, .. } => bail!("server error: {message}"),
            other => bail!("unexpected response {other:?} to ping {id}"),
        }
    }

    /// Ask the daemon to shut down; returns once the ack arrives.
    pub fn shutdown_server(&mut self) -> Result<()> {
        let id = self.fresh_id();
        match self.call(&Request::Shutdown { id })? {
            Response::ShutdownAck { id: rid } if rid == id => Ok(()),
            Response::Error { message, .. } => bail!("server error: {message}"),
            other => bail!("unexpected response {other:?} to shutdown {id}"),
        }
    }

    /// The underlying stream (tests use this to write malformed bytes).
    pub fn stream_mut(&mut self) -> &mut TcpStream {
        &mut self.stream
    }
}
