//! Batched prediction service over checkpointed models — the light
//! half of the train-once / serve-many split.
//!
//! [`ServeEngine`] loads a [`TrainedModel`] (from memory or from a
//! `model::io` checkpoint file) and serves predictions without ever
//! touching the training path:
//!
//! 1. **Reconstruction** (once, at engine construction): the Gram
//!    factors are rebuilt from the checkpointed hyperparameters and the
//!    full-grid posterior is recomputed from the pathwise state with
//!    cheap Kronecker MVMs — exactly the paper's "predictions are MVMs"
//!    claim (Sec. 3.3). The reconstruction replays the *same* code path
//!    and chunk order as the fit (`gp::lkgp`), so for models fitted on
//!    the rust backend it reproduces the fit's posterior
//!    **bit-for-bit**, in both precisions, at any thread count —
//!    asserted by [`ServeEngine::verify`]. Queries themselves are
//!    served from the checkpoint's stored posterior, so served numbers
//!    always equal the fit's output even for PJRT-trained checkpoints
//!    where the rust replay only approximates the on-device f32 fit.
//! 2. **Batched queries**: [`ServeEngine::predict_batch`] accepts many
//!    independent query batches (ragged sizes welcome), coalesces them
//!    into one flat, uniformly blocked work buffer, and fans the blocks
//!    out over the `crate::par` worker pool under `Schedule::Steal` —
//!    batch boundaries never affect a single output bit, so the
//!    response is identical no matter how callers group their queries.
//! 3. **New spatial points**: [`ServeEngine::predict_new_points`]
//!    serves predictive means for spatial inputs that were never in the
//!    training grid. The expensive half-product
//!    `unvec(M alpha) K_TT^T` is computed once per engine and reused by
//!    every query batch, so a batch of m new points costs two GEMMs
//!    (`m x p` cross-Gram, `m x p @ p x q` contraction) — the
//!    Gram-factor amortization that makes high query throughput cheap.
//! 4. **Network serving**: [`daemon::ServeDaemon`] (`lkgp serve`) keeps
//!    engines resident behind a TCP endpoint and lifts the within-call
//!    coalescing of `predict_batch` to *cross-request* batching: an
//!    admission window collects predict requests from many concurrent
//!    connections into one steal-scheduled sweep, bit-identical to
//!    answering each request alone. Protocol spec in `docs/formats.md`,
//!    lifecycle and determinism contract in `docs/serve.md`.
//!
//! ```no_run
//! use lkgp::serve::{BatchRequest, ServeEngine};
//!
//! # fn main() -> anyhow::Result<()> {
//! let engine = ServeEngine::open("model.ckpt")?;
//! assert!(engine.verify().bit_identical);
//! let responses = engine.predict_batch(&[
//!     BatchRequest { cells: vec![0, 1, 2] },
//!     BatchRequest { cells: vec![41] },
//! ])?;
//! println!("mean at cell 41: {}", responses[1].mean[0]);
//! # Ok(())
//! # }
//! ```

pub mod daemon;

use anyhow::{bail, Context, Result};

use crate::gp::backend::{KronBackend, MvmMode, Precision, RustKronBackend};
use crate::gp::diagnostics::{FitDiagnostics, SolverPath, TimeOpChoice, TimeOpPath};
use crate::gp::lkgp::{accumulate_pathwise_moments, finalize_posterior, PATHWISE_CHUNK};
use crate::gp::Posterior;
use crate::kernels::ProductGridKernel;
use crate::linalg::gemm::matmul_nt;
use crate::linalg::{Matrix, Scalar};
use crate::model::TrainedModel;

/// One independent batch of grid-cell queries. Cell indices use the
/// grid layout `j*q + k` = (spatial point j, time step k) shared with
/// `crate::kron`.
#[derive(Clone, Debug)]
pub struct BatchRequest {
    /// Grid cells to predict (any order, duplicates allowed).
    pub cells: Vec<usize>,
}

/// Predictions for one [`BatchRequest`], aligned with its `cells`.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchResponse {
    /// Predictive means in raw target scale.
    pub mean: Vec<f64>,
    /// Predictive variances (including observation noise).
    pub var: Vec<f64>,
}

/// Outcome of comparing the reconstructed posterior against the one
/// stored in the checkpoint (see [`ServeEngine::verify`]).
#[derive(Clone, Copy, Debug)]
pub struct VerifyReport {
    /// True when every mean and variance bit matches the stored
    /// posterior — the expected state for rust-backend checkpoints.
    pub bit_identical: bool,
    /// Largest absolute mean deviation.
    pub max_mean_diff: f64,
    /// Largest absolute variance deviation.
    pub max_var_diff: f64,
}

/// Block length (in queries) of the coalesced prediction sweep: small
/// enough that ragged batch mixes spread across workers, large enough
/// that a block amortizes its dispatch. Purely a scheduling constant —
/// output bits never depend on it.
const SERVE_BLOCK: usize = 256;

/// Bounded retries for a failed backend MVM during posterior
/// reconstruction, mirroring the fit path's transient-fault tolerance
/// (`LkgpConfig::mvm_retries`). Retries are pure re-executions of a
/// deterministic computation, so a retry that succeeds produces the
/// same bits a first-try success would have.
const SERVE_MVM_RETRIES: usize = 2;

/// A loaded model plus everything reconstructed from it, ready to
/// answer queries. Construction does all the heavy work; queries are
/// cheap and `&self` (share one engine across threads freely).
///
/// Queries are answered from the checkpoint's stored posterior — the
/// fit's exact output, authoritative by construction. The Kronecker-MVM
/// reconstruction is the *integrity replay*: for rust-backend
/// checkpoints it must reproduce the stored posterior bit for bit
/// ([`ServeEngine::verify`]), and for PJRT-trained checkpoints it
/// quantifies the rust-vs-artifact deviation without ever leaking it
/// into served predictions.
pub struct ServeEngine {
    model: TrainedModel,
    /// Posterior recomputed from the pathwise state via Kronecker MVMs.
    reconstructed: Posterior,
    /// `unvec(M alpha) @ K_TT^T` (p x q): the reusable half of the
    /// predictive-mean product for new-point queries.
    half_alpha: Matrix<f64>,
    /// Product kernel at the checkpointed hyperparameters (cross-Gram
    /// evaluation for new-point queries).
    kernel: ProductGridKernel,
    reconstruct_secs: f64,
    /// Resilience counters accumulated while building the engine
    /// (backend MVM retries during reconstruction, MVM totals).
    diagnostics: FitDiagnostics,
}

impl ServeEngine {
    /// Load a checkpoint file and build the engine (reconstructing the
    /// posterior — the one-time serving cost).
    pub fn open(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::from_model(TrainedModel::load(path)?)
    }

    /// Build the engine from an in-memory model (e.g. straight from
    /// `LkgpFit::model`), reconstructing the posterior.
    pub fn from_model(model: TrainedModel) -> Result<Self> {
        model.validate().map_err(anyhow::Error::new)?;
        let t0 = std::time::Instant::now();
        let mut diagnostics = FitDiagnostics::default();
        // reconstruction replays captured pathwise state through MVMs
        // only — no linear solves of any kind run at serve time
        diagnostics.solver_path = SolverPath::Replay;
        let reconstructed = crate::par::catch_region(|| match model.precision {
            Precision::F64 => reconstruct::<f64>(&model, &mut diagnostics),
            Precision::F32 => reconstruct::<f32>(&model, &mut diagnostics),
        })
        .map_err(|rp| {
            anyhow::Error::new(rp).context("parallel region fault during posterior reconstruction")
        })??;
        let mut kernel = ProductGridKernel::new(model.ds, &model.time_family, model.q());
        kernel.set_theta(&model.theta);
        let ktt = kernel.gram_t(&model.t);
        let a = Matrix::from_vec(model.p(), model.q(), model.masked_alpha.clone());
        let half_alpha = matmul_nt(&a, &ktt);
        let reconstruct_secs = t0.elapsed().as_secs_f64();
        Ok(ServeEngine { model, reconstructed, half_alpha, kernel, reconstruct_secs, diagnostics })
    }

    /// The underlying model state.
    pub fn model(&self) -> &TrainedModel {
        &self.model
    }

    /// The full-grid posterior queries are served from (raw target
    /// scale): the checkpoint's stored fit posterior, which the MVM
    /// reconstruction must reproduce bit for bit on rust-backend
    /// checkpoints (see [`ServeEngine::verify`]).
    pub fn posterior(&self) -> &Posterior {
        &self.model.posterior
    }

    /// The posterior recomputed from the pathwise state via Kronecker
    /// MVMs — the integrity replay compared by [`ServeEngine::verify`].
    pub fn reconstructed(&self) -> &Posterior {
        &self.reconstructed
    }

    /// Wall-clock seconds the posterior reconstruction took.
    pub fn reconstruct_secs(&self) -> f64 {
        self.reconstruct_secs
    }

    /// Resilience counters from engine construction: total backend MVMs
    /// issued during the reconstruction replay and how many had to be
    /// retried after transient failures. All zeros on a clean build.
    pub fn diagnostics(&self) -> &FitDiagnostics {
        &self.diagnostics
    }

    /// Compare the reconstructed posterior against the one stored in
    /// the checkpoint. Rust-backend checkpoints must report
    /// `bit_identical`; PJRT-trained checkpoints report the (small)
    /// rust-vs-artifact deviation instead.
    pub fn verify(&self) -> VerifyReport {
        let stored = &self.model.posterior;
        let recon = &self.reconstructed;
        let mut bit_identical = true;
        let mut max_mean_diff = 0.0f64;
        let mut max_var_diff = 0.0f64;
        for c in 0..stored.mean.len() {
            if stored.mean[c].to_bits() != recon.mean[c].to_bits()
                || stored.var[c].to_bits() != recon.var[c].to_bits()
            {
                bit_identical = false;
            }
            max_mean_diff = max_mean_diff.max((stored.mean[c] - recon.mean[c]).abs());
            max_var_diff = max_var_diff.max((stored.var[c] - recon.var[c]).abs());
        }
        VerifyReport { bit_identical, max_mean_diff, max_var_diff }
    }

    /// Serve many independent query batches at once.
    ///
    /// All batches are coalesced into one flat work buffer, swept in
    /// uniform fixed-size blocks over the `crate::par` pool
    /// under the work-stealing schedule (ragged batch mixes balance
    /// across workers), and scattered back per batch. Output bits are
    /// independent of the thread count *and* of how queries were
    /// grouped into batches. Out-of-range cells are rejected up front.
    pub fn predict_batch(&self, batches: &[BatchRequest]) -> Result<Vec<BatchResponse>> {
        let pq = self.model.grid_len();
        let total: usize = batches.iter().map(|b| b.cells.len()).sum();
        let mut flat: Vec<usize> = Vec::with_capacity(total);
        for (bi, b) in batches.iter().enumerate() {
            for &c in &b.cells {
                if c >= pq {
                    bail!("batch {bi}: cell index {c} out of range (grid has {pq} cells)");
                }
                flat.push(c);
            }
        }
        let mut mean_out = vec![0.0f64; total];
        let mut var_out = vec![0.0f64; total];
        let (mean, var) = (&self.model.posterior.mean, &self.model.posterior.var);
        let cells = &flat;
        if total < crate::par::cheap_sweep_min() {
            // small coalesced sweeps: a pool dispatch would dominate the
            // gather itself; the sequential path writes identical bits
            for (i, &cell) in flat.iter().enumerate() {
                mean_out[i] = mean[cell];
                var_out[i] = var[cell];
            }
        } else {
            crate::par::catch_region(|| {
                crate::par::par_zip_mut_steal(
                    "serve.predict_batch",
                    &mut mean_out,
                    &mut var_out,
                    SERVE_BLOCK,
                    |ci, ms, vs| {
                        let base = ci * SERVE_BLOCK;
                        for (off, (m, v)) in ms.iter_mut().zip(vs.iter_mut()).enumerate() {
                            let cell = cells[base + off];
                            *m = mean[cell];
                            *v = var[cell];
                        }
                    },
                )
            })
            .map_err(|rp| {
                anyhow::Error::new(rp).context("parallel region fault during batched prediction")
            })?;
        }
        let mut out = Vec::with_capacity(batches.len());
        let mut at = 0;
        for b in batches {
            let n = b.cells.len();
            out.push(BatchResponse {
                mean: mean_out[at..at + n].to_vec(),
                var: var_out[at..at + n].to_vec(),
            });
            at += n;
        }
        Ok(out)
    }

    /// Convenience wrapper: one batch of cells.
    pub fn predict_cells(&self, cells: &[usize]) -> Result<BatchResponse> {
        let mut res = self.predict_batch(&[BatchRequest { cells: cells.to_vec() }])?;
        res.pop().ok_or_else(|| anyhow::anyhow!("predict_batch returned no response for one batch"))
    }

    /// Predictive means for spatial inputs that were never part of the
    /// training grid: rows of `s_star` are new points in the same
    /// standardized coordinate space as the training inputs, and the
    /// returned `m x q` matrix holds the raw-scale mean across the full
    /// time grid for each.
    ///
    /// This is the amortized-GEMM serving path: the engine-resident
    /// half-product `unvec(M alpha) K_TT^T` is reused by every call, so
    /// each batch costs one `m x p` cross-Gram and one
    /// `m x p @ p x q` GEMM. Pathwise variances are not available
    /// off-grid (prior function samples exist only on the grid), so
    /// this returns means only; use grid queries for calibrated
    /// uncertainty.
    pub fn predict_new_points(&self, s_star: &Matrix<f64>) -> Result<Matrix<f64>> {
        if s_star.cols != self.model.ds {
            bail!("query points have {} columns, model expects ds={}", s_star.cols, self.model.ds);
        }
        let k_star = self.kernel.spatial.gram(s_star, &self.model.s);
        let mut g = k_star.matmul(&self.half_alpha);
        for x in &mut g.data {
            *x = *x * self.model.y_std + self.model.y_mean;
        }
        Ok(g)
    }
}

/// Recompute the full-grid posterior from the checkpointed pathwise
/// state, in the fit's compute precision `T`.
///
/// Replays the fit's prediction phase exactly: the same backend type,
/// the same Gram construction from the same hyperparameter bits, the
/// same `kron_apply` entry point, the same [`PATHWISE_CHUNK`]-row
/// sample chunks in the same order, and the same f64 moment
/// accumulation — which is what makes the result bit-identical to the
/// in-memory fit rather than merely close.
fn reconstruct<T: Scalar>(m: &TrainedModel, diags: &mut FitDiagnostics) -> Result<Posterior> {
    let q = m.q();
    let pq = m.grid_len();
    // replay through the same time-factor engine the fit used: a
    // Toeplitz-trained checkpoint must reproduce its FFT-path bits, and
    // a dense-trained one must never silently upgrade to the FFT path
    let time_choice = match m.time_op {
        TimeOpPath::Dense => TimeOpChoice::Dense,
        TimeOpPath::Toeplitz => TimeOpChoice::Toeplitz,
    };
    let mut be = RustKronBackend::<T>::new(m.ds, &m.time_family, q, 1)
        .with_mode(MvmMode::Kron)
        .with_time_op(time_choice);
    be.set_data(&m.s, &m.t, &m.mask).context("installing checkpointed data")?;
    be.set_hypers(&m.theta, m.log_sigma2).context("rebuilding Gram factors")?;
    diags.time_op = be.time_op_path();
    // The replay is identical for mask- and interp-trained models: an
    // SKI checkpoint stores grid-space state (`W^T` already folded into
    // masked_alpha / vm, grid mask all-ones), so only the provenance
    // tag differs.
    diags.projection = m.projection;
    let to_t = |row: &[f64]| -> Vec<T> { row.iter().map(|&x| T::from_f64(x)).collect() };

    let ma = Matrix::from_vec(1, pq, to_t(&m.masked_alpha));
    let mean_std_t = serve_mvm(&be, &ma, diags).context("predictive-mean MVM")?;
    let mean_std: Vec<f64> = mean_std_t.row(0).iter().map(|x| x.to_f64()).collect();

    let mut mean_acc = vec![0.0f64; pq];
    let mut var_acc = vec![0.0f64; pq];
    let nsamp = m.n_samples;
    let mut done = 0;
    while done < nsamp {
        let b = PATHWISE_CHUNK.min(nsamp - done);
        let mut vm_chunk = Matrix::<T>::zeros(b, pq);
        let mut f_chunk = Matrix::<T>::zeros(b, pq);
        for r in 0..b {
            vm_chunk.row_mut(r).copy_from_slice(&to_t(m.vm.row(done + r)));
            f_chunk.row_mut(r).copy_from_slice(&to_t(m.f_prior.row(done + r)));
        }
        let kv = serve_mvm(&be, &vm_chunk, diags).context("pathwise MVM")?;
        accumulate_pathwise_moments(&f_chunk, &kv, &mut mean_acc, &mut var_acc);
        done += b;
    }
    Ok(finalize_posterior(
        &mean_std,
        &mean_acc,
        &var_acc,
        nsamp,
        m.log_sigma2.exp(),
        m.y_mean,
        m.y_std,
    ))
}

/// One reconstruction MVM with bounded retry: transient backend errors
/// (including faults injected at the `serve_mvm` failpoint) are retried
/// up to [`SERVE_MVM_RETRIES`] times before surfacing as a typed error.
/// Each attempt is a pure re-execution, so a successful retry yields
/// the same bits as a clean first attempt; `diags` records how many
/// MVMs ran and how many were retried.
fn serve_mvm<T: Scalar>(
    be: &RustKronBackend<T>,
    rhs: &Matrix<T>,
    diags: &mut FitDiagnostics,
) -> Result<Matrix<T>> {
    use crate::util::failpoint::{self, FaultAction, InjectedFault};
    let mut attempt = 0usize;
    loop {
        diags.mvm_total += 1;
        let res = match failpoint::check("serve_mvm") {
            Some(FaultAction::Error) => Err(anyhow::Error::new(InjectedFault {
                site: "serve_mvm".into(),
                action: FaultAction::Error,
            })),
            _ => be.kron_apply(rhs),
        };
        match res {
            Ok(out) => return Ok(out),
            Err(_) if attempt < SERVE_MVM_RETRIES => {
                attempt += 1;
                diags.backend_retries += 1;
            }
            Err(e) => {
                return Err(e.context(format!("backend MVM failed after {attempt} retries")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::well_specified;
    use crate::gp::lkgp::{Lkgp, LkgpConfig};
    use crate::kernels::ProductGridKernel as Pgk;

    fn fitted(seed: u64) -> crate::gp::lkgp::LkgpFit {
        let kernel = Pgk::new(2, "rbf", 6);
        let data = well_specified(12, 6, 2, &kernel, 0.02, 0.3, seed);
        let cfg = LkgpConfig {
            train_iters: 5,
            n_samples: 8,
            probes: 4,
            cg_tol: 1e-3,
            cg_max_iters: 200,
            seed,
            capture_pathwise: true,
            ..LkgpConfig::default()
        };
        Lkgp::fit(&data, cfg).unwrap()
    }

    #[test]
    fn reconstruction_matches_fit_bit_for_bit() {
        let fit = fitted(3);
        let engine = ServeEngine::from_model(fit.model.clone().unwrap()).unwrap();
        let rep = engine.verify();
        assert!(
            rep.bit_identical,
            "reconstructed posterior deviates: mean {} var {}",
            rep.max_mean_diff,
            rep.max_var_diff
        );
        let recon = engine.reconstructed();
        for c in 0..fit.posterior.mean.len() {
            assert_eq!(fit.posterior.mean[c].to_bits(), recon.mean[c].to_bits());
            assert_eq!(fit.posterior.var[c].to_bits(), recon.var[c].to_bits());
        }
    }

    #[test]
    fn eig_trained_checkpoint_roundtrips_bit_for_bit() {
        // A model trained on the fully-observed spectral path (zero CG
        // iterations) must checkpoint and replay exactly like a
        // CG-trained one: the serve replay is pure MVMs either way, and
        // it records the mvm-replay path in its diagnostics.
        let kernel = Pgk::new(2, "rbf", 6);
        let data = well_specified(12, 6, 2, &kernel, 0.02, 0.0, 19);
        let cfg = LkgpConfig {
            train_iters: 5,
            n_samples: 8,
            probes: 4,
            cg_tol: 1e-3,
            cg_max_iters: 200,
            seed: 19,
            capture_pathwise: true,
            ..LkgpConfig::default()
        };
        let fit = Lkgp::fit(&data, cfg).unwrap();
        assert_eq!(fit.diagnostics.solver_path, SolverPath::Eig);
        assert_eq!(fit.cg_iters_total, 0);
        let engine = ServeEngine::from_model(fit.model.clone().unwrap()).unwrap();
        assert_eq!(engine.diagnostics().solver_path, SolverPath::Replay);
        let rep = engine.verify();
        assert!(
            rep.bit_identical,
            "eig-trained replay deviates: mean {} var {}",
            rep.max_mean_diff,
            rep.max_var_diff
        );
    }

    #[test]
    fn toeplitz_trained_checkpoint_replays_bit_for_bit() {
        // A model fitted through the FFT/Toeplitz time factor must
        // carry that tag through the on-disk codec and replay through
        // the same engine: same path recorded in the serve diagnostics,
        // same posterior bits as the fit.
        let kernel = Pgk::new(2, "rbf", 6);
        let data = well_specified(12, 6, 2, &kernel, 0.02, 0.3, 23);
        let cfg = LkgpConfig {
            train_iters: 5,
            n_samples: 8,
            probes: 4,
            cg_tol: 1e-3,
            cg_max_iters: 200,
            seed: 23,
            capture_pathwise: true,
            time_op: TimeOpChoice::Toeplitz,
            ..LkgpConfig::default()
        };
        let fit = Lkgp::fit(&data, cfg).unwrap();
        assert_eq!(fit.diagnostics.time_op, TimeOpPath::Toeplitz);
        let model = fit.model.clone().unwrap();
        assert_eq!(model.time_op, TimeOpPath::Toeplitz);
        let path =
            std::env::temp_dir().join(format!("lkgp_serve_toep_{}.ckpt", std::process::id()));
        model.save(&path).unwrap();
        let loaded = TrainedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.time_op, TimeOpPath::Toeplitz);
        let engine = ServeEngine::from_model(loaded).unwrap();
        assert_eq!(engine.diagnostics().time_op, TimeOpPath::Toeplitz);
        let rep = engine.verify();
        assert!(
            rep.bit_identical,
            "toeplitz-trained replay deviates: mean {} var {}",
            rep.max_mean_diff,
            rep.max_var_diff
        );
    }

    #[test]
    fn ski_trained_checkpoint_replays_bit_for_bit() {
        // An interp-projection fit stores grid-space pathwise state plus
        // its W record; the serve replay must reproduce the fit's
        // posterior bit for bit and surface the projection provenance.
        use crate::data::synthetic::off_grid;
        use crate::gp::diagnostics::{ProjectionChoice, ProjectionPath, Solver};
        use crate::kron::interp::InterpDegree;
        let data = off_grid(90, 0, 8, 6, 0.02, 31);
        let cfg = LkgpConfig {
            train_iters: 4,
            n_samples: 8,
            probes: 4,
            cg_tol: 1e-3,
            cg_max_iters: 200,
            seed: 31,
            capture_pathwise: true,
            solver: Solver::Cg,
            projection: ProjectionChoice::Interp(InterpDegree::Linear),
            ..LkgpConfig::default()
        };
        let fit = Lkgp::fit_offgrid(&data, cfg).unwrap();
        assert_eq!(fit.diagnostics.projection, ProjectionPath::Interp(InterpDegree::Linear));
        let model = fit.model.clone().unwrap();
        assert!(model.w.is_some());
        let path =
            std::env::temp_dir().join(format!("lkgp_serve_ski_{}.ckpt", std::process::id()));
        model.save(&path).unwrap();
        let loaded = TrainedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.projection, ProjectionPath::Interp(InterpDegree::Linear));
        let engine = ServeEngine::from_model(loaded).unwrap();
        assert_eq!(
            engine.diagnostics().projection,
            ProjectionPath::Interp(InterpDegree::Linear)
        );
        let rep = engine.verify();
        assert!(
            rep.bit_identical,
            "ski-trained replay deviates: mean {} var {}",
            rep.max_mean_diff,
            rep.max_var_diff
        );
    }

    #[test]
    fn batch_grouping_does_not_change_answers() {
        let fit = fitted(5);
        let engine = ServeEngine::from_model(fit.model.unwrap()).unwrap();
        let pq = engine.model().grid_len();
        let all: Vec<usize> = (0..pq).collect();
        let one = engine.predict_cells(&all).unwrap();
        // same cells split into ragged batches
        let batches: Vec<BatchRequest> = vec![
            BatchRequest { cells: all[..5].to_vec() },
            BatchRequest { cells: all[5..6].to_vec() },
            BatchRequest { cells: all[6..].to_vec() },
        ];
        let many = engine.predict_batch(&batches).unwrap();
        let glued_mean: Vec<f64> = many.iter().flat_map(|r| r.mean.iter().copied()).collect();
        let glued_var: Vec<f64> = many.iter().flat_map(|r| r.var.iter().copied()).collect();
        assert_eq!(
            one.mean.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            glued_mean.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            one.var.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            glued_var.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn out_of_range_cell_is_rejected() {
        let fit = fitted(7);
        let engine = ServeEngine::from_model(fit.model.unwrap()).unwrap();
        let pq = engine.model().grid_len();
        let err = engine.predict_cells(&[0, pq]).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    }

    #[test]
    fn new_point_means_agree_with_grid_means_at_training_points() {
        let fit = fitted(11);
        let engine = ServeEngine::from_model(fit.model.unwrap()).unwrap();
        let m = engine.model();
        let (q, pq) = (m.q(), m.grid_len());
        // query the training inputs themselves as "new" points
        let s_star = m.s.clone();
        let got = engine.predict_new_points(&s_star).unwrap();
        let grid = engine.predict_cells(&(0..pq).collect::<Vec<_>>()).unwrap();
        let scale = grid.mean.iter().map(|x| x.abs()).fold(1.0, f64::max);
        for j in 0..m.p() {
            for k in 0..q {
                let want = grid.mean[j * q + k];
                let have = got[(j, k)];
                assert!(
                    (want - have).abs() < 1e-7 * scale,
                    "cell ({j},{k}): grid {want} vs new-point {have}"
                );
            }
        }
    }
}
