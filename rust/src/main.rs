//! lkgp — Latent Kronecker GP coordinator CLI.
//!
//! Subcommands:
//!
//! ```text
//! info                         artifact manifest + platform report
//! train  --data <set> ...      fit one model on one dataset, report
//! save   --data <set> ...      fit, then checkpoint the pathwise
//!                              state to --out (train-once half)
//! predict --checkpoint <path>  load a checkpoint and serve
//!                              predictions (serve-many half)
//! predict --addr host:port     query a running `lkgp serve` daemon
//!                              over the wire protocol instead
//! serve  --checkpoint <path>.. long-lived prediction daemon with
//!                              cross-request batching (docs/serve.md)
//! experiment <id> [--scale ..] regenerate a paper table/figure
//!                              (fig2 | fig3 | fig4 | fig5 | table1 |
//!                               table2 | all)
//! ```
//!
//! Python never runs here: the binary consumes artifacts/ produced once
//! by `make artifacts`.

use lkgp::coordinator::{experiments, ExperimentScale};
use lkgp::data::climate::ClimateSim;
use lkgp::data::lcbench::LcBenchSim;
use lkgp::data::sarcos::SarcosSim;
use lkgp::data::synthetic::{off_grid, well_specified};
use lkgp::data::{GridDataset, OffGridDataset};
use lkgp::gp::backend::{MvmMode, Precision};
use lkgp::gp::diagnostics::{OnNonConverged, ProjectionChoice, Solver, TimeOpChoice};
use lkgp::gp::lkgp::{Backend, Lkgp, LkgpConfig, LkgpFit};
use lkgp::kernels::ProductGridKernel;
use lkgp::kron::interp::{InterpDegree, SparseProjection};
use lkgp::linalg::Matrix;
use lkgp::runtime::{Manifest, Runtime};
use lkgp::serve::daemon::{DaemonOptions, ServeClient, ServeDaemon};
use lkgp::serve::ServeEngine;
use lkgp::util::cli::Args;
use lkgp::util::json::Json;

const USAGE: &str = "usage: lkgp <info|train|save|predict|serve|experiment> [flags]
  lkgp info
  lkgp train --data <climate|climate-precip|lcbench|sarcos|synthetic|offgrid>
             [--p N] [--q N] [--missing R] [--seed S]
             [--backend rust|<artifact-config>] [--dense] [--f32]
             [--iters N] [--on-nonconverged warn|error]
             [--solver auto|cg|eig] [--time-op auto|dense|toeplitz]
             [--projection mask|interp|interp-cubic]
             [--n N]   (offgrid only: scattered training points)
  lkgp save  [same flags as train] [--out <path>=lkgp_model.ckpt]
  lkgp predict --checkpoint <path> [--cells i,j,k] [--json <path>]
  lkgp predict --addr host:port [--model id] --cells i,j,k
             [--json <path>] | --ping | --shutdown
  lkgp serve --checkpoint <path> [--checkpoint <path> ...]
             [--addr host:port=127.0.0.1:7474] [--window MS]
             [--max-batch N]
  lkgp experiment <fig2|fig3|fig4|fig5|table1|table2|ablations|all>
             [--scale quick|paper] [--seeds N] [--ratios a,b,..]
             [--backend rust|<artifact-config>]";

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("info") => cmd_info(),
        Some("train") => cmd_train(&args),
        Some("save") => cmd_save(&args),
        Some("predict") => cmd_predict(&args),
        Some("serve") => cmd_serve(&args),
        Some("experiment") => cmd_experiment(&args),
        _ => {
            eprintln!("{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn cmd_info() -> i32 {
    println!("lkgp — Latent Kronecker Gaussian Processes (ICML 2025 reproduction)");
    match Runtime::load_default() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifact configs:");
            for (name, cfg) in &rt.manifest.configs {
                println!(
                    "  {name:>8}: p={:<5} q={:<4} ds={:<3} kernel_t={:<13} batch={} probes={} n_theta={}",
                    cfg.p, cfg.q, cfg.ds, cfg.kernel_t, cfg.batch, cfg.probes, cfg.n_theta
                );
            }
            0
        }
        Err(e) => {
            println!("artifacts unavailable: {e:#}");
            println!("(run `make artifacts`; dir searched: {:?})", Manifest::default_dir());
            1
        }
    }
}

fn load_dataset(args: &Args) -> GridDataset {
    let missing = args.f64("missing", 0.3);
    let seed = args.u64("seed", 0);
    match args.str("data", "synthetic").as_str() {
        "climate" => ClimateSim::default_temperature(
            args.usize("p", 96),
            args.usize("q", 64),
            missing,
            seed,
        ),
        "climate-precip" => ClimateSim::default_precipitation(
            args.usize("p", 96),
            args.usize("q", 64),
            missing,
            seed,
        ),
        "lcbench" => {
            let mut sim = LcBenchSim::new(args.usize("p", 128), args.usize("q", 52), seed);
            sim.full_fraction = 0.1;
            sim.generate()
        }
        "sarcos" => SarcosSim::new(args.usize("p", 256), missing, seed).generate(),
        _ => {
            let kernel = ProductGridKernel::new(2, "rbf", args.usize("q", 16));
            well_specified(
                args.usize("p", 64),
                args.usize("q", 16),
                2,
                &kernel,
                0.05,
                missing,
                seed,
            )
        }
    }
}

/// Build the fit configuration shared by `train` and `save` from the
/// common flag set.
fn build_train_config(args: &Args, capture_pathwise: bool) -> Result<LkgpConfig, String> {
    let backend = match args.str("backend", "rust").as_str() {
        "rust" => {
            if args.bool("dense") {
                Backend::Rust(MvmMode::DenseMaterialized)
            } else {
                Backend::Rust(MvmMode::Kron)
            }
        }
        cfg => Backend::Pjrt { config: cfg.to_string() },
    };
    let precision = if args.bool("f32") {
        if matches!(backend, Backend::Pjrt { .. }) {
            eprintln!(
                "note: --f32 has no effect on the PJRT backend \
                 (artifacts already execute in f32 on-device)"
            );
        }
        Precision::F32
    } else {
        Precision::F64
    };
    // flag > env > default: an explicit --on-nonconverged beats
    // LKGP_ON_NONCONVERGED, which beats the Warn default
    let on_nonconverged = match args.str_opt("on-nonconverged") {
        None => OnNonConverged::from_env(),
        Some(s) => OnNonConverged::parse(&s).map_err(|e| format!("--on-nonconverged: {e}"))?,
    };
    // same precedence for the solver engine: --solver beats LKGP_SOLVER,
    // which beats the Auto default
    let solver = match args.str_opt("solver") {
        None => Solver::from_env(),
        Some(s) => Solver::parse(&s).map_err(|e| format!("--solver: {e}"))?,
    };
    // and for the time-factor engine: --time-op beats LKGP_TIME_OP,
    // which beats the dense default
    let time_op = match args.str_opt("time-op") {
        None => TimeOpChoice::from_env(),
        Some(s) => TimeOpChoice::parse(&s).map_err(|e| format!("--time-op: {e}"))?,
    };
    // and for the training projection: --projection beats
    // LKGP_PROJECTION, which beats the mask default
    let projection = match args.str_opt("projection") {
        None => ProjectionChoice::from_env(),
        Some(s) => ProjectionChoice::parse(&s).map_err(|e| format!("--projection: {e}"))?,
    };
    Ok(LkgpConfig {
        train_iters: args.usize("iters", 20),
        n_samples: args.usize("samples", 32),
        precond_rank: args.usize("precond-rank", 0),
        seed: args.u64("seed", 0),
        backend,
        precision,
        capture_pathwise,
        on_nonconverged,
        solver,
        time_op,
        projection,
        ..LkgpConfig::default()
    })
}

/// Build the off-grid synthetic workload for `--data offgrid`:
/// `--n` scattered training points (plus n/4 held-out test points) on a
/// `--p x --q` linspace inducing grid.
fn load_offgrid(args: &Args) -> OffGridDataset {
    let n = args.usize("n", 512);
    off_grid(
        n,
        n.div_ceil(4),
        args.usize("p", 32),
        args.usize("q", 32),
        args.f64("noise", 0.02),
        args.u64("seed", 0),
    )
}

/// RMSE of the grid posterior mean interpolated to scattered query
/// points: `W_query mean` with a fresh stencil built on the same
/// inducing grid the model was trained against.
fn offgrid_rmse(
    mean_grid: &[f64],
    od: &OffGridDataset,
    degree: InterpDegree,
    xs: &[f64],
    xt: &[f64],
    y: &[f64],
) -> Result<f64, String> {
    let w = SparseProjection::build(xs, xt, &od.grid_s, &od.grid_t, degree)?;
    let m = Matrix::from_vec(1, mean_grid.len(), mean_grid.to_vec());
    let pred = w.interp_apply(&m);
    let mut sq = 0.0;
    for (i, &yi) in y.iter().enumerate() {
        let d = pred[(0, i)] - yi;
        sq += d * d;
    }
    Ok((sq / y.len().max(1) as f64).sqrt())
}

fn print_offgrid_dataset(od: &OffGridDataset) {
    println!(
        "dataset {}: n={} (+{} test) on a {} x {} inducing grid",
        od.name,
        od.n(),
        od.test_y.len(),
        od.p(),
        od.q()
    );
}

/// Shared `train`/`save` path for `--data offgrid`: fit through the SKI
/// projection and report train/test RMSE at the scattered points.
fn fit_offgrid_cli(args: &Args, capture_pathwise: bool) -> Result<(OffGridDataset, LkgpFit), i32> {
    let od = load_offgrid(args);
    let cfg = match build_train_config(args, capture_pathwise) {
        Ok(cfg) => cfg,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            return Err(2);
        }
    };
    let ProjectionChoice::Interp(degree) = cfg.projection else {
        eprintln!(
            "--data offgrid needs an interpolation projection: \
             pass --projection interp (or interp-cubic)\n{USAGE}"
        );
        return Err(2);
    };
    if let Err(e) = args.finish() {
        eprintln!("{e}\n{USAGE}");
        return Err(2);
    }
    print_offgrid_dataset(&od);
    let fit = match Lkgp::fit_offgrid(&od, cfg) {
        Ok(fit) => fit,
        Err(e) => {
            eprintln!("fit failed: {e:#}");
            return Err(1);
        }
    };
    let report = |tag: &str, xs: &[f64], xt: &[f64], y: &[f64]| {
        if y.is_empty() {
            return;
        }
        match offgrid_rmse(&fit.posterior.mean, &od, degree, xs, xt, y) {
            Ok(rmse) => println!("{tag}: rmse {rmse:.4} ({} points)", y.len()),
            Err(e) => eprintln!("{tag}: rmse unavailable ({e})"),
        }
    };
    report("train", &od.xs, &od.xt, &od.y);
    report("test ", &od.test_xs, &od.test_xt, &od.test_y);
    Ok((od, fit))
}

fn print_dataset(data: &GridDataset) {
    println!(
        "dataset {}: p={} q={} observed {} / {} (missing {:.1}%)",
        data.name,
        data.p(),
        data.q(),
        data.n_observed(),
        data.grid_len(),
        100.0 * data.missing_ratio()
    );
}

fn cmd_train(args: &Args) -> i32 {
    if args.str("data", "synthetic") == "offgrid" {
        return match fit_offgrid_cli(args, false) {
            Ok((_, fit)) => {
                println!("loss trace (0.5 y^T alpha): {:?}", round3(&fit.loss_trace));
                println!(
                    "time: train {:.2}s predict {:.2}s | CG iters {} | kernel bytes {}",
                    fit.train_secs, fit.predict_secs, fit.cg_iters_total, fit.kernel_bytes
                );
                println!("\ndiagnostics:\n{}", fit.diagnostics.render());
                println!("\nprofile:\n{}", fit.profile.render());
                0
            }
            Err(code) => code,
        };
    }
    let data = load_dataset(args);
    let cfg = match build_train_config(args, false) {
        Ok(cfg) => cfg,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            return 2;
        }
    };
    if let Err(e) = args.finish() {
        eprintln!("{e}\n{USAGE}");
        return 2;
    }
    print_dataset(&data);
    match Lkgp::fit(&data, cfg) {
        Ok(fit) => {
            let (train_rmse, train_nll) = fit.posterior.train_metrics(&data);
            let (test_rmse, test_nll) = fit.posterior.test_metrics(&data);
            println!("loss trace (0.5 y^T alpha): {:?}", round3(&fit.loss_trace));
            println!("train: rmse {train_rmse:.4}  nll {train_nll:.4}");
            println!("test : rmse {test_rmse:.4}  nll {test_nll:.4}");
            println!(
                "time: train {:.2}s predict {:.2}s | CG iters {} | kernel bytes {}",
                fit.train_secs, fit.predict_secs, fit.cg_iters_total, fit.kernel_bytes
            );
            println!("\ndiagnostics:\n{}", fit.diagnostics.render());
            println!("\nprofile:\n{}", fit.profile.render());
            0
        }
        Err(e) => {
            eprintln!("fit failed: {e:#}");
            1
        }
    }
}

fn round3(xs: &[f64]) -> Vec<f64> {
    xs.iter().map(|x| (x * 1000.0).round() / 1000.0).collect()
}

/// `lkgp save`: fit with pathwise capture on, then write the versioned
/// binary checkpoint — the train-once half of train-once/serve-many.
fn cmd_save(args: &Args) -> i32 {
    if args.str("data", "synthetic") == "offgrid" {
        let out = args.str("out", "lkgp_model.ckpt");
        let (_, fit) = match fit_offgrid_cli(args, true) {
            Ok(v) => v,
            Err(code) => return code,
        };
        let Some(model) = fit.model else {
            eprintln!("fit returned no pathwise state despite capture_pathwise; cannot checkpoint");
            return 1;
        };
        return match model.save(&out) {
            Ok(bytes) => {
                println!(
                    "checkpoint: {out} ({:.1} KiB, {} pathwise samples, {} projection)",
                    bytes as f64 / 1024.0,
                    model.n_samples,
                    model.projection
                );
                println!("serve it with: lkgp predict --checkpoint {out}");
                0
            }
            Err(e) => {
                eprintln!("save failed: {e:#}");
                1
            }
        };
    }
    let data = load_dataset(args);
    let cfg = match build_train_config(args, true) {
        Ok(cfg) => cfg,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            return 2;
        }
    };
    let out = args.str("out", "lkgp_model.ckpt");
    if let Err(e) = args.finish() {
        eprintln!("{e}\n{USAGE}");
        return 2;
    }
    print_dataset(&data);
    let fit = match Lkgp::fit(&data, cfg) {
        Ok(fit) => fit,
        Err(e) => {
            eprintln!("fit failed: {e:#}");
            return 1;
        }
    };
    let Some(model) = fit.model else {
        eprintln!("fit returned no pathwise state despite capture_pathwise; cannot checkpoint");
        return 1;
    };
    match model.save(&out) {
        Ok(bytes) => {
            let (test_rmse, test_nll) = fit.posterior.test_metrics(&data);
            println!("fit : test rmse {test_rmse:.4} nll {test_nll:.4}");
            println!(
                "time: train {:.2}s predict {:.2}s | CG iters {}",
                fit.train_secs, fit.predict_secs, fit.cg_iters_total
            );
            if !fit.diagnostics.healthy() {
                println!("diagnostics:\n{}", fit.diagnostics.render());
            }
            println!(
                "checkpoint: {out} ({:.1} KiB, {} pathwise samples, {})",
                bytes as f64 / 1024.0,
                model.n_samples,
                match model.precision {
                    Precision::F64 => "f64",
                    Precision::F32 => "f32 state tensors",
                }
            );
            println!("serve it with: lkgp predict --checkpoint {out}");
            0
        }
        Err(e) => {
            eprintln!("save failed: {e:#}");
            1
        }
    }
}

/// `lkgp predict`: load a checkpoint, reconstruct the posterior with
/// cheap MVMs, verify it against the stored posterior, and serve the
/// requested cells — the serve-many half. With `--addr` the same
/// subcommand becomes a client of a running `lkgp serve` daemon
/// instead, emitting byte-identical `--json` cell/mean/var arrays.
fn cmd_predict(args: &Args) -> i32 {
    if let Some(addr) = args.str_opt("addr") {
        return cmd_predict_remote(args, &addr);
    }
    let Some(path) = args.str_opt("checkpoint") else {
        eprintln!("--checkpoint <path> (or --addr host:port) is required\n{USAGE}");
        return 2;
    };
    // strict parsing: a typo in --cells must not silently degrade into
    // a full-grid query
    let cells: Vec<usize> = match args.usize_list("cells") {
        Ok(None) => Vec::new(),
        Ok(Some(v)) if v.is_empty() => {
            eprintln!("--cells was given but contains no cell indices\n{USAGE}");
            return 2;
        }
        Ok(Some(v)) => v,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            return 2;
        }
    };
    let json_out = args.str_opt("json");
    if let Err(e) = args.finish() {
        eprintln!("{e}\n{USAGE}");
        return 2;
    }
    let engine = match ServeEngine::open(&path) {
        Ok(engine) => engine,
        Err(e) => {
            eprintln!("cannot serve {path}: {e:#}");
            return 1;
        }
    };
    let m = engine.model();
    println!(
        "checkpoint {path}: model {:?} ({} x {} grid, ds={}, {} samples, {:?}, time kernel {})",
        m.name, m.p(), m.q(), m.ds, m.n_samples, m.precision, m.time_family
    );
    println!("posterior reconstructed in {:.3}s (cheap MVMs only)", engine.reconstruct_secs());
    let diag = engine.diagnostics();
    if diag.backend_retries > 0 {
        println!(
            "resilience: {} of {} reconstruction MVMs recovered by retry",
            diag.backend_retries, diag.mvm_total
        );
    }
    let rep = engine.verify();
    if rep.bit_identical {
        println!("integrity: reconstruction is bit-identical to the stored posterior");
    } else {
        println!(
            "integrity: reconstruction deviates from stored posterior \
             (max |d mean| {:.3e}, max |d var| {:.3e}; expected for PJRT-trained checkpoints)",
            rep.max_mean_diff, rep.max_var_diff
        );
    }
    let query: Vec<usize> = if cells.is_empty() {
        (0..m.grid_len()).collect()
    } else {
        cells.clone()
    };
    let t0 = std::time::Instant::now();
    let res = match engine.predict_cells(&query) {
        Ok(res) => res,
        Err(e) => {
            eprintln!("predict failed: {e:#}");
            return 1;
        }
    };
    let predict_secs = t0.elapsed().as_secs_f64();
    if cells.is_empty() {
        let n = res.mean.len() as f64;
        let mean_avg = res.mean.iter().sum::<f64>() / n;
        let var_avg = res.var.iter().sum::<f64>() / n;
        println!(
            "full grid ({} cells) served in {:.3}s: mean avg {mean_avg:.4}, var avg {var_avg:.4}",
            res.mean.len(), predict_secs
        );
    } else {
        println!("{} cells served in {:.6}s:", query.len(), predict_secs);
        println!("{:>8} {:>5} {:>5} {:>12} {:>12}", "cell", "j", "k", "mean", "var");
        for (i, &c) in query.iter().enumerate() {
            println!(
                "{c:>8} {:>5} {:>5} {:>12.5} {:>12.5}",
                c / m.q(), c % m.q(), res.mean[i], res.var[i]
            );
        }
    }
    if let Some(json_path) = json_out {
        let doc = Json::obj(vec![
            ("checkpoint", Json::Str(path.clone())),
            ("model", Json::Str(m.name.clone())),
            ("p", Json::Num(m.p() as f64)),
            ("q", Json::Num(m.q() as f64)),
            ("bit_identical", Json::Bool(rep.bit_identical)),
            ("cells", Json::arr_usize(&query)),
            ("mean", Json::arr_f64(&res.mean)),
            ("var", Json::arr_f64(&res.var)),
        ]);
        if let Err(e) = std::fs::write(&json_path, format!("{doc}\n")) {
            eprintln!("cannot write {json_path}: {e}");
            return 1;
        }
        println!("predictions written to {json_path}");
    }
    0
}

/// `lkgp predict --addr`: client mode against a running daemon. The
/// served numbers are bit-identical to offline `lkgp predict` on the
/// same checkpoint, so the `--json` cells/mean/var arrays compare
/// byte-for-byte (the serve-smoke CI job asserts exactly that).
fn cmd_predict_remote(args: &Args, addr: &str) -> i32 {
    let model = args.str("model", "");
    let ping = args.bool("ping");
    let shutdown = args.bool("shutdown");
    let cells = match args.usize_list("cells") {
        Ok(v) => v.unwrap_or_default(),
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            return 2;
        }
    };
    let json_out = args.str_opt("json");
    if let Err(e) = args.finish() {
        eprintln!("{e}\n{USAGE}");
        return 2;
    }
    let mut client = match ServeClient::connect(addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("cannot reach {addr}: {e:#}");
            return 1;
        }
    };
    if ping {
        return match client.ping() {
            Ok(info) => {
                println!("{addr}: {info}");
                0
            }
            Err(e) => {
                eprintln!("ping failed: {e:#}");
                1
            }
        };
    }
    if shutdown {
        return match client.shutdown_server() {
            Ok(()) => {
                println!("{addr}: shutdown acknowledged");
                0
            }
            Err(e) => {
                eprintln!("shutdown failed: {e:#}");
                1
            }
        };
    }
    if cells.is_empty() {
        eprintln!("--cells i,j,k is required in --addr mode (or use --ping / --shutdown)\n{USAGE}");
        return 2;
    }
    let t0 = std::time::Instant::now();
    let res = match client.predict(&model, &cells) {
        Ok(res) => res,
        Err(e) => {
            eprintln!("predict failed: {e:#}");
            return 1;
        }
    };
    let secs = t0.elapsed().as_secs_f64();
    println!("{} cells served by {addr} in {:.6}s:", cells.len(), secs);
    println!("{:>8} {:>12} {:>12}", "cell", "mean", "var");
    for (i, &c) in cells.iter().enumerate() {
        println!("{c:>8} {:>12.5} {:>12.5}", res.mean[i], res.var[i]);
    }
    if let Some(json_path) = json_out {
        let doc = Json::obj(vec![
            ("addr", Json::Str(addr.to_string())),
            ("model", Json::Str(model)),
            ("cells", Json::arr_usize(&cells)),
            ("mean", Json::arr_f64(&res.mean)),
            ("var", Json::arr_f64(&res.var)),
        ]);
        if let Err(e) = std::fs::write(&json_path, format!("{doc}\n")) {
            eprintln!("cannot write {json_path}: {e}");
            return 1;
        }
        println!("predictions written to {json_path}");
    }
    0
}

/// `lkgp serve`: load every `--checkpoint` into a resident engine and
/// run the cross-request-batching daemon until a client sends a
/// shutdown request. Window precedence: `--window` beats
/// `LKGP_SERVE_WINDOW` beats the `LkgpConfig` default.
fn cmd_serve(args: &Args) -> i32 {
    let paths = args.str_all("checkpoint");
    if paths.is_empty() {
        eprintln!("at least one --checkpoint <path> is required\n{USAGE}");
        return 2;
    }
    let addr = args.str("addr", "127.0.0.1:7474");
    let window_ms = match args.str_opt("window") {
        Some(v) => match v.parse::<u64>() {
            Ok(ms) => ms,
            Err(_) => {
                eprintln!("--window: {v:?} is not a millisecond count\n{USAGE}");
                return 2;
            }
        },
        None => match std::env::var("LKGP_SERVE_WINDOW") {
            Ok(v) if !v.trim().is_empty() => match v.trim().parse::<u64>() {
                Ok(ms) => ms,
                Err(_) => {
                    eprintln!("warning: ignoring invalid LKGP_SERVE_WINDOW {v:?}");
                    LkgpConfig::default().serve_batch_window_ms
                }
            },
            _ => LkgpConfig::default().serve_batch_window_ms,
        },
    };
    let max_batch = args.usize("max-batch", 1024);
    if let Err(e) = args.finish() {
        eprintln!("{e}\n{USAGE}");
        return 2;
    }
    let mut engines = Vec::new();
    for path in &paths {
        // the file stem names the model in request frames
        let id = std::path::Path::new(path)
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        let engine = match ServeEngine::open(path) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("cannot serve {path}: {e:#}");
                return 1;
            }
        };
        let m = engine.model();
        let rep = engine.verify();
        println!(
            "loaded {id:?} from {path}: {} x {} grid, {} samples, reconstructed in {:.3}s ({})",
            m.p(),
            m.q(),
            m.n_samples,
            engine.reconstruct_secs(),
            if rep.bit_identical { "bit-identical" } else { "deviates from stored posterior" }
        );
        engines.push((id, engine));
    }
    let opts = DaemonOptions { window_ms, max_batch, ..DaemonOptions::default() };
    let daemon = match ServeDaemon::start(&addr, engines, opts) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("cannot start daemon: {e:#}");
            return 1;
        }
    };
    let local = daemon.local_addr();
    println!(
        "serving {} model(s) on {local} (admission window {window_ms} ms, max batch {max_batch})",
        paths.len()
    );
    println!("query:    lkgp predict --addr {local} --cells 0,1,2");
    println!("shutdown: lkgp predict --addr {local} --shutdown");
    let report = daemon.wait();
    println!("{}", report.render());
    0
}

fn cmd_experiment(args: &Args) -> i32 {
    let which = args
        .positional()
        .first()
        .cloned()
        .or_else(|| args.str_opt("name"))
        .unwrap_or_else(|| "all".to_string());
    let scale = ExperimentScale::from_args(args);
    let t0 = std::time::Instant::now();
    match which.as_str() {
        "fig2" => experiments::fig2::run(&scale),
        "fig3" => experiments::fig3::run(&scale),
        "fig4" => experiments::fig4::run(&scale),
        "fig5" => experiments::fig5::run(&scale),
        "table1" => experiments::table1::run(&scale),
        "table2" => experiments::table2::run(&scale),
        "ablations" => experiments::ablations::run(&scale),
        "all" => {
            experiments::fig2::run(&scale);
            experiments::fig3::run(&scale);
            experiments::fig4::run(&scale);
            experiments::fig5::run(&scale);
            experiments::table1::run(&scale);
            experiments::table2::run(&scale);
        }
        other => {
            eprintln!("unknown experiment {other:?}\n{USAGE}");
            return 2;
        }
    }
    println!("[experiment {which} done in {:.1}s]", t0.elapsed().as_secs_f64());
    0
}
