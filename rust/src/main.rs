//! lkgp — Latent Kronecker GP coordinator CLI.
//!
//! Subcommands:
//!   info                         artifact manifest + platform report
//!   train  --data <set> ...      fit one model on one dataset, report
//!   experiment <id> [--scale ..] regenerate a paper table/figure
//!                                (fig2 | fig3 | fig4 | fig5 | table1 |
//!                                 table2 | all)
//!
//! Python never runs here: the binary consumes artifacts/ produced once
//! by `make artifacts`.

use lkgp::coordinator::{experiments, ExperimentScale};
use lkgp::data::climate::ClimateSim;
use lkgp::data::lcbench::LcBenchSim;
use lkgp::data::sarcos::SarcosSim;
use lkgp::data::synthetic::well_specified;
use lkgp::data::GridDataset;
use lkgp::gp::backend::MvmMode;
use lkgp::gp::lkgp::{Backend, Lkgp, LkgpConfig};
use lkgp::kernels::ProductGridKernel;
use lkgp::runtime::{Manifest, Runtime};
use lkgp::util::cli::Args;

const USAGE: &str = "usage: lkgp <info|train|experiment> [flags]
  lkgp info
  lkgp train --data <climate|climate-precip|lcbench|sarcos|synthetic>
             [--p N] [--q N] [--missing R] [--seed S]
             [--backend rust|<artifact-config>] [--dense] [--f32]
             [--iters N]
  lkgp experiment <fig2|fig3|fig4|fig5|table1|table2|ablations|all>
             [--scale quick|paper] [--seeds N] [--ratios a,b,..]
             [--backend rust|<artifact-config>]";

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("info") => cmd_info(),
        Some("train") => cmd_train(&args),
        Some("experiment") => cmd_experiment(&args),
        _ => {
            eprintln!("{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn cmd_info() -> i32 {
    println!("lkgp — Latent Kronecker Gaussian Processes (ICML 2025 reproduction)");
    match Runtime::load_default() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifact configs:");
            for (name, cfg) in &rt.manifest.configs {
                println!(
                    "  {name:>8}: p={:<5} q={:<4} ds={:<3} kernel_t={:<13} batch={} probes={} n_theta={}",
                    cfg.p, cfg.q, cfg.ds, cfg.kernel_t, cfg.batch, cfg.probes, cfg.n_theta
                );
            }
            0
        }
        Err(e) => {
            println!("artifacts unavailable: {e:#}");
            println!("(run `make artifacts`; dir searched: {:?})", Manifest::default_dir());
            1
        }
    }
}

fn load_dataset(args: &Args) -> GridDataset {
    let missing = args.f64("missing", 0.3);
    let seed = args.u64("seed", 0);
    match args.str("data", "synthetic").as_str() {
        "climate" => ClimateSim::default_temperature(
            args.usize("p", 96),
            args.usize("q", 64),
            missing,
            seed,
        ),
        "climate-precip" => ClimateSim::default_precipitation(
            args.usize("p", 96),
            args.usize("q", 64),
            missing,
            seed,
        ),
        "lcbench" => {
            let mut sim = LcBenchSim::new(args.usize("p", 128), args.usize("q", 52), seed);
            sim.full_fraction = 0.1;
            sim.generate()
        }
        "sarcos" => SarcosSim::new(args.usize("p", 256), missing, seed).generate(),
        _ => {
            let kernel = ProductGridKernel::new(2, "rbf", args.usize("q", 16));
            well_specified(
                args.usize("p", 64),
                args.usize("q", 16),
                2,
                &kernel,
                0.05,
                missing,
                seed,
            )
        }
    }
}

fn cmd_train(args: &Args) -> i32 {
    let data = load_dataset(args);
    let backend = match args.str("backend", "rust").as_str() {
        "rust" => {
            if args.bool("dense") {
                Backend::Rust(MvmMode::DenseMaterialized)
            } else {
                Backend::Rust(MvmMode::Kron)
            }
        }
        cfg => Backend::Pjrt { config: cfg.to_string() },
    };
    let precision = if args.bool("f32") {
        if matches!(backend, Backend::Pjrt { .. }) {
            eprintln!(
                "note: --f32 has no effect on the PJRT backend \
                 (artifacts already execute in f32 on-device)"
            );
        }
        lkgp::gp::backend::Precision::F32
    } else {
        lkgp::gp::backend::Precision::F64
    };
    let cfg = LkgpConfig {
        train_iters: args.usize("iters", 20),
        n_samples: args.usize("samples", 32),
        precond_rank: args.usize("precond-rank", 0),
        seed: args.u64("seed", 0),
        backend,
        precision,
        ..LkgpConfig::default()
    };
    if let Err(e) = args.finish() {
        eprintln!("{e}\n{USAGE}");
        return 2;
    }
    println!(
        "dataset {}: p={} q={} observed {} / {} (missing {:.1}%)",
        data.name,
        data.p(),
        data.q(),
        data.n_observed(),
        data.grid_len(),
        100.0 * data.missing_ratio()
    );
    match Lkgp::fit(&data, cfg) {
        Ok(fit) => {
            let (train_rmse, train_nll) = fit.posterior.train_metrics(&data);
            let (test_rmse, test_nll) = fit.posterior.test_metrics(&data);
            println!("loss trace (0.5 y^T alpha): {:?}", round3(&fit.loss_trace));
            println!("train: rmse {train_rmse:.4}  nll {train_nll:.4}");
            println!("test : rmse {test_rmse:.4}  nll {test_nll:.4}");
            println!(
                "time: train {:.2}s predict {:.2}s | CG iters {} | kernel bytes {}",
                fit.train_secs, fit.predict_secs, fit.cg_iters_total, fit.kernel_bytes
            );
            println!("\nprofile:\n{}", fit.profile.render());
            0
        }
        Err(e) => {
            eprintln!("fit failed: {e:#}");
            1
        }
    }
}

fn round3(xs: &[f64]) -> Vec<f64> {
    xs.iter().map(|x| (x * 1000.0).round() / 1000.0).collect()
}

fn cmd_experiment(args: &Args) -> i32 {
    let which = args
        .positional()
        .first()
        .cloned()
        .or_else(|| args.str_opt("name"))
        .unwrap_or_else(|| "all".to_string());
    let scale = ExperimentScale::from_args(args);
    let t0 = std::time::Instant::now();
    match which.as_str() {
        "fig2" => experiments::fig2::run(&scale),
        "fig3" => experiments::fig3::run(&scale),
        "fig4" => experiments::fig4::run(&scale),
        "fig5" => experiments::fig5::run(&scale),
        "table1" => experiments::table1::run(&scale),
        "table2" => experiments::table2::run(&scale),
        "ablations" => experiments::ablations::run(&scale),
        "all" => {
            experiments::fig2::run(&scale);
            experiments::fig3::run(&scale);
            experiments::fig4::run(&scale);
            experiments::fig5::run(&scale);
            experiments::table1::run(&scale);
            experiments::table2::run(&scale);
        }
        other => {
            eprintln!("unknown experiment {other:?}\n{USAGE}");
            return 2;
        }
    }
    println!("[experiment {which} done in {:.1}s]", t0.elapsed().as_secs_f64());
    0
}
