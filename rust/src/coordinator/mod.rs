//! Experiment coordinator: regenerates every table and figure of the
//! paper (see DESIGN.md §6 for the experiment index).
//!
//! Each runner is a pure function over an `ExperimentScale` (sizes,
//! seeds, ratios) that prints markdown tables and writes them under
//! `results/`. The CLI (`lkgp experiment <id>`) dispatches here.

pub mod config;
pub mod experiments;
pub mod report;

pub use config::ExperimentScale;
