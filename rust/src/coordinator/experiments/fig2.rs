//! Figure 2: computational resources of kernel evaluation and MVM on
//! ten-dimensional synthetic data, dense vs latent Kronecker, as the
//! dataset size n grows (balanced factorization p = q = sqrt(n)).
//!
//! Reproduced series: kernel evaluation time, MVM time, and kernel
//! memory, for both representations, plus the analytic models from
//! kron::breakeven. The paper's qualitative claims checked here:
//! * dense memory escalates as n^2 while latent-Kron stays ~flat;
//! * dense kernel-eval time dominates its MVM time asymptotically;
//! * with latent Kronecker, MVM dominates kernel evaluation.

use crate::coordinator::report;
use crate::coordinator::ExperimentScale;
use crate::data::synthetic::fig2_inputs;
use crate::kernels::RbfArd;
use crate::kron::{breakeven, KronOp};
use crate::linalg::Matrix;
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::util::timer::Stopwatch;

/// Regenerate the Figure-2 cost-scaling study.
pub fn run(scale: &ExperimentScale) {
    println!("== Figure 2: kernel-eval / MVM scaling (dense vs latent Kronecker) ==\n");
    let mut table = Table::new(
        "Fig 2 — resource usage vs dataset size (10-d synthetic, p=q=sqrt(n))",
        &[
            "n", "p=q", "dense kernel s", "kron kernel s", "dense MVM s", "kron MVM s",
            "dense MiB", "kron MiB", "pred. MVM speedup",
        ],
    );
    let kernel = RbfArd::new(5); // factor kernels (5 spatial + 5 time dims)
    let kernel10 = RbfArd::new(10); // dense product kernel over all 10 dims
    for &n in &scale.fig2_sizes {
        let p = (n as f64).sqrt().round() as usize;
        let (p, q) = (p.max(2), p.max(2));
        let n = p * q;
        let inputs = fig2_inputs(p, q, 7);
        let mut rng = Rng::new(n as u64);

        // latent Kronecker: evaluate the two factor Grams
        let sw = Stopwatch::start();
        let kss = kernel.gram(&inputs.s, &inputs.s);
        let ktt = kernel.gram(&inputs.t_multi, &inputs.t_multi);
        let kron_kernel_s = sw.secs();
        let op = KronOp::new(kss, ktt);
        let v = Matrix::from_vec(1, n, rng.normals(n));
        let sw = Stopwatch::start();
        let _ = op.apply_batch(&v);
        let kron_mvm_s = sw.secs();

        // dense: full n x n Gram over concatenated 10-d inputs
        let (dense_kernel_s, dense_mvm_s) = if n <= scale.fig2_dense_cap {
            let mut x = Matrix::zeros(n, 10);
            for j in 0..p {
                for k in 0..q {
                    let row = x.row_mut(j * q + k);
                    row[..5].copy_from_slice(inputs.s.row(j));
                    row[5..].copy_from_slice(inputs.t_multi.row(k));
                }
            }
            let sw = Stopwatch::start();
            let kd = kernel10.gram(&x, &x);
            let dk = sw.secs();
            let sw = Stopwatch::start();
            let _ = kd.matvec(v.row(0));
            (dk, sw.secs())
        } else {
            (f64::NAN, f64::NAN)
        };

        let dense_mib = crate::util::mem::dense_kernel_bytes(n) as f64 / (1 << 20) as f64;
        let kron_mib = crate::util::mem::kron_kernel_bytes(p, q) as f64 / (1 << 20) as f64;
        let fmt = |x: f64| {
            if x.is_nan() {
                "OOM/skipped".to_string()
            } else {
                format!("{x:.4}")
            }
        };
        table.row(vec![
            n.to_string(),
            p.to_string(),
            fmt(dense_kernel_s),
            fmt(kron_kernel_s),
            fmt(dense_mvm_s),
            fmt(kron_mvm_s),
            format!("{dense_mib:.2}"),
            format!("{kron_mib:.4}"),
            format!("{:.1}x", breakeven::predicted_mvm_speedup(p, q, 0.0)),
        ]);
    }
    report::emit(&table, "fig2_scaling");

    // the two qualitative claims, checked on the largest dense size
    let claim = "\nClaims checked (largest dense size): with latent Kronecker the \
                 kernel-eval time stays negligible relative to MVM; dense memory \
                 grows ~n^2 while Kron memory grows ~n.\n";
    report::note("fig2_scaling", claim);
    println!("{claim}");
}
