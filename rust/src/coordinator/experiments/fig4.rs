//! Figure 4: qualitative learning-curve extrapolation — predictive mean
//! and ±2σ bands of all four models on representative partially
//! observed curves (including an outlier), dumped as CSV series and a
//! terminal ASCII sketch.

use crate::coordinator::experiments::models::run_all_models;
use crate::coordinator::{report, ExperimentScale};
use crate::data::lcbench::LcBenchSim;
use crate::data::GridDataset;
use crate::util::table::Table;

/// pick curve rows: most-censored, median, and the most outlier-like
fn pick_rows(data: &GridDataset) -> Vec<usize> {
    let (p, q) = (data.p(), data.q());
    let prefix_len = |j: usize| (0..q).take_while(|&k| data.mask[j * q + k]).count();
    let censored: Vec<usize> = (0..p).filter(|&j| prefix_len(j) < q).collect();
    if censored.is_empty() {
        return vec![0, p / 2, p - 1];
    }
    // outlier score: final value minus curve minimum
    let outlier_score = |j: usize| {
        let row = &data.y_grid[j * q..(j + 1) * q];
        row[q - 1] - row.iter().cloned().fold(f64::INFINITY, f64::min)
    };
    let mut by_prefix = censored.clone();
    by_prefix.sort_by_key(|&j| prefix_len(j));
    let shortest = by_prefix[0];
    let median = by_prefix[by_prefix.len() / 2];
    let outlier = *censored
        .iter()
        .max_by(|&&a, &&b| {
            outlier_score(a).partial_cmp(&outlier_score(b)).unwrap_or(std::cmp::Ordering::Equal)
        })
        .expect("censored is non-empty: guarded by the caller above");
    vec![shortest, median, outlier]
}

/// Regenerate the Figure-4 learning-curve panels.
pub fn run(scale: &ExperimentScale) {
    println!("== Figure 4: qualitative learning-curve extrapolation ==\n");
    let sim = LcBenchSim::new(scale.table1_p, scale.table1_q, 1003); // "Fashion"-like family
    let data = sim.generate();
    let (_, posteriors) = run_all_models(&data, scale, 0).expect("models");
    let rows = pick_rows(&data);
    let q = data.q();

    let mut table = Table::new(
        "Fig 4 — per-epoch predictive mean / 2-sigma per model (3 curves)",
        &["curve", "epoch", "observed", "truth", "LKGP mu", "LKGP 2s", "SVGP mu",
          "SVGP 2s", "VNNGP mu", "VNNGP 2s", "CaGP mu", "CaGP 2s"],
    );
    for (ci, &j) in rows.iter().enumerate() {
        for k in 0..q {
            let idx = j * q + k;
            let mut row = vec![
                format!("curve{ci}(row {j})"),
                k.to_string(),
                if data.mask[idx] { "yes".into() } else { "no".into() },
                format!("{:.2}", data.y_grid[idx]),
            ];
            for (_, post) in &posteriors {
                row.push(format!("{:.2}", post.mean[idx]));
                row.push(format!("{:.2}", 2.0 * post.var[idx].sqrt()));
            }
            table.row(row);
        }
    }
    report::emit(&table, "fig4_curves");

    // terminal sketch of the outlier curve under LKGP
    let j = rows[2];
    let lkgp = &posteriors[0].1;
    println!("ASCII sketch — outlier curve {j} (x = truth, o = LKGP mean, | = ±2σ):");
    let vals: Vec<f64> = (0..q).map(|k| data.y_grid[j * q + k]).collect();
    let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min) - 5.0;
    let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max) + 5.0;
    let cols = 60usize;
    let scale_to = |v: f64| (((v - lo) / (hi - lo)).clamp(0.0, 1.0) * (cols - 1) as f64) as usize;
    for k in (0..q).step_by((q / 16).max(1)) {
        let idx = j * q + k;
        let mut line = vec![b' '; cols];
        let s = lkgp.var[idx].sqrt();
        let (l, r) = (scale_to(lkgp.mean[idx] - 2.0 * s), scale_to(lkgp.mean[idx] + 2.0 * s));
        for c in l..=r {
            line[c] = b'-';
        }
        line[scale_to(lkgp.mean[idx])] = b'o';
        line[scale_to(data.y_grid[idx])] = b'x';
        let tag = if data.mask[idx] { "obs " } else { "MISS" };
        println!("e{k:>3} {tag} |{}|", String::from_utf8_lossy(&line));
    }
    println!();

    // quantitative fig-4 claim: LKGP's predictive σ must grow into the
    // missing region (sensible uncertainty growth)
    let lkgp_sigma_growth: f64 = rows
        .iter()
        .map(|&j| {
            let pre = (0..q).find(|&k| !data.mask[j * q + k]).unwrap_or(q - 1);
            let s_obs = lkgp.var[j * q + pre.saturating_sub(1)].sqrt();
            let s_end = lkgp.var[j * q + q - 1].sqrt();
            s_end / s_obs.max(1e-9)
        })
        .sum::<f64>()
        / rows.len() as f64;
    let note = format!(
        "\nLKGP mean sigma growth into the missing tail: {lkgp_sigma_growth:.2}x \
         (paper: uncertainty grows smoothly into the extrapolated region).\n"
    );
    report::note("fig4_curves", &note);
    println!("{note}");
}
