//! Table 2: climate (temperature + precipitation) with missing ratios
//! 10%–50% — LKGP vs SVGP / VNNGP / CaGP, RMSE + NLL + time.

use crate::coordinator::experiments::models::{aggregate, run_all_models};
use crate::coordinator::{report, ExperimentScale};
use crate::data::climate::{ClimateSim, ClimateVariant};
use crate::util::table::Table;

/// Regenerate Table 2 (climate datasets).
pub fn run(scale: &ExperimentScale) {
    println!(
        "== Table 2: sim-climate (p={}, q={}) with missing ratios {:?} ==\n",
        scale.table2_p, scale.table2_q, scale.table2_ratios
    );
    for variant in [ClimateVariant::Temperature, ClimateVariant::Precipitation] {
        let vname = match variant {
            ClimateVariant::Temperature => "temperature",
            ClimateVariant::Precipitation => "precipitation",
        };
        let mut table = Table::new(
            &format!("Table 2 — {vname} (sim-Nordic, p={}, q={})", scale.table2_p, scale.table2_q),
            &["missing", "Model", "Train RMSE", "Test RMSE", "Train NLL", "Test NLL", "Time (s)"],
        );
        for &ratio in &scale.table2_ratios {
            let mut per_seed = Vec::new();
            for seed in 0..scale.table2_seeds {
                let data = ClimateSim::new(
                    scale.table2_p,
                    scale.table2_q,
                    variant,
                    ratio,
                    100 + seed,
                )
                .generate();
                let (res, _) = run_all_models(&data, scale, seed).expect("models");
                per_seed.push(res);
            }
            for (mi, (name, cells, _)) in aggregate(&per_seed).iter().enumerate() {
                table.row(vec![
                    if mi == 0 { format!("{:.0}%", ratio * 100.0) } else { String::new() },
                    name.clone(),
                    cells[0].clone(),
                    cells[1].clone(),
                    cells[2].clone(),
                    cells[3].clone(),
                    cells[4].clone(),
                ]);
            }
            println!("  {vname} missing {:.0}%... done", ratio * 100.0);
        }
        report::emit(&table, &format!("table2_climate_{vname}"));
    }
    println!(
        "\nPaper claims to compare against: LKGP best test RMSE + NLL at every \
         ratio on both variants, while also fastest.\n"
    );
}
