//! Ablations over the design choices DESIGN.md calls out:
//!
//! A1 solver family     — CG vs alternating projections vs SGD on the
//!                        same LKGP system (paper Sec. 2 cites all three)
//! A2 preconditioner    — none / Jacobi / pivoted Cholesky rank sweep
//! A3 Hutchinson probes — gradient error vs probe count
//! A4 Toeplitz factor   — O(q^2) vs O(q log q) temporal MVM crossover
//! A5 multi-factor Kron — 3-factor grid MVM vs materialized dense

use crate::coordinator::{report, ExperimentScale};
use crate::gp::grad::{mll_surrogate_grads, standard_pairs};
use crate::kernels::ProductGridKernel;
use crate::kron::multi::{multi_kron_flops, MultiKronOp};
use crate::kron::toeplitz::ToeplitzOp;
use crate::kron::{KronOp, MaskedKronSystem};
use crate::linalg::{cholesky, Matrix};
use crate::solvers::altproj::{solve_altproj, AltProjOptions};
use crate::solvers::cg::{solve_cg, BatchedOp, CgOptions};
use crate::solvers::precond::Preconditioner;
use crate::solvers::sgd::{solve_sgd, SgdOptions};
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::util::timer::Stopwatch;

struct Op<'a>(&'a MaskedKronSystem<f64>);

impl<'a> BatchedOp<f64> for Op<'a> {
    fn dim(&self) -> usize {
        self.0.dim()
    }
    fn apply_batch(&mut self, v: &Matrix<f64>) -> Matrix<f64> {
        self.0.apply_batch(v)
    }
}

fn test_system(p: usize, q: usize, s2: f64, seed: u64) -> (MaskedKronSystem<f64>, Matrix<f64>) {
    let mut rng = Rng::new(seed);
    let kernel = ProductGridKernel::new(3, "rbf", q);
    let s = Matrix::from_vec(p, 3, rng.normals(p * 3));
    let t: Vec<f64> = (0..q).map(|k| k as f64 / (q - 1) as f64).collect();
    let mask: Vec<f64> =
        (0..p * q).map(|_| if rng.uniform() < 0.3 { 0.0 } else { 1.0 }).collect();
    let sys = MaskedKronSystem::new(
        KronOp::new(kernel.gram_s(&s), kernel.gram_t(&t)),
        mask.clone(),
        s2,
    );
    let mut rhs = Matrix::from_vec(3, p * q, rng.normals(3 * p * q));
    for r in 0..3 {
        for (x, m) in rhs.row_mut(r).iter_mut().zip(&mask) {
            *x *= *m;
        }
    }
    (sys, rhs)
}

/// Run the ablation sweeps at the given scale.
pub fn run(_scale: &ExperimentScale) {
    println!("== Ablations over design choices ==\n");

    // ---- A1: solver family ----
    let (sys, rhs) = test_system(128, 24, 0.05, 1);
    let mut t = Table::new(
        "A1 — iterative solver family on the LKGP system (p=128, q=24, tol 1e-2)",
        &["solver", "iters/sweeps", "MVMs", "secs", "converged"],
    );
    {
        let sw = Stopwatch::start();
        let (_, s) = solve_cg(
            &mut Op(&sys),
            &rhs,
            &Preconditioner::jacobi(&sys.diag()),
            &CgOptions::default(),
        );
        t.row(vec![
            "CG (jacobi)".into(),
            s.iters.to_string(),
            s.mvm_count.to_string(),
            format!("{:.3}", sw.secs()),
            s.converged.to_string(),
        ]);
        let sw = Stopwatch::start();
        let sysr = &sys;
        let (_, s) = solve_altproj(
            &mut Op(&sys),
            |i, j| {
                let col = sysr.kernel_col(j);
                col[i] + if i == j { sysr.sigma2 } else { 0.0 }
            },
            &rhs,
            &AltProjOptions::default(),
        );
        t.row(vec![
            "Alternating projections".into(),
            s.iters.to_string(),
            s.mvm_count.to_string(),
            format!("{:.3}", sw.secs()),
            s.converged.to_string(),
        ]);
        let sw = Stopwatch::start();
        let (_, s) = solve_sgd(&mut Op(&sys), &rhs, &SgdOptions::default());
        t.row(vec![
            "SGD (heavy ball)".into(),
            s.iters.to_string(),
            s.mvm_count.to_string(),
            format!("{:.3}", sw.secs()),
            s.converged.to_string(),
        ]);
    }
    report::emit(&t, "ablation_solvers");

    // ---- A2: preconditioner rank sweep ----
    let (sys, rhs) = test_system(128, 24, 0.01, 2);
    let mut t = Table::new(
        "A2 — preconditioner vs CG iterations (sigma2 = 0.01)",
        &["preconditioner", "iters", "secs"],
    );
    for (name, pre) in [
        ("none".to_string(), Preconditioner::Identity),
        ("jacobi".to_string(), Preconditioner::jacobi(&sys.diag())),
    ]
    .into_iter()
    .chain([10usize, 25, 50, 100].into_iter().map(|rank| {
        (
            format!("pivchol-{rank}"),
            Preconditioner::pivoted_from_columns(
                sys.diag().iter().map(|d| d - sys.sigma2).collect(),
                |j| sys.kernel_col(j),
                rank,
                sys.sigma2,
            ),
        )
    })) {
        let sw = Stopwatch::start();
        let (_, s) = solve_cg(&mut Op(&sys), &rhs, &pre, &CgOptions::default());
        t.row(vec![name, s.iters.to_string(), format!("{:.3}", sw.secs())]);
    }
    report::emit(&t, "ablation_precond");

    // ---- A3: Hutchinson probes vs gradient error ----
    let mut t = Table::new(
        "A3 — MLL gradient error vs probe count (vs 256-probe reference)",
        &["probes", "rel. gradient error"],
    );
    {
        let mut rng = Rng::new(5);
        let (p, q) = (24, 8);
        let kernel = ProductGridKernel::new(2, "rbf", q);
        let s = Matrix::from_vec(p, 2, rng.normals(p * 2));
        let tgrid: Vec<f64> = (0..q).map(|k| k as f64 / (q - 1) as f64).collect();
        let mask: Vec<f64> =
            (0..p * q).map(|_| if rng.uniform() < 0.3 { 0.0 } else { 1.0 }).collect();
        let kss = kernel.gram_s(&s);
        let ktt = kernel.gram_t(&tgrid);
        let s2 = 0.1;
        // dense solves for exact alpha and probe solves
        let sys = MaskedKronSystem::new(KronOp::new(kss.clone(), ktt.clone()), mask.clone(), s2);
        let dense = {
            let mut d = sys.op.dense();
            for i in 0..d.rows {
                for j in 0..d.cols {
                    d[(i, j)] *= mask[i] * mask[j];
                }
                d[(i, i)] += s2;
            }
            d
        };
        let chol = cholesky(&dense).expect("dense chol");
        let y: Vec<f64> =
            rng.normals(p * q).iter().zip(&mask).map(|(v, m)| v * m).collect();
        let alpha: Vec<f64> =
            chol.solve(&y).iter().zip(&mask).map(|(v, m)| v * m).collect();
        let grad_for = |k: usize, rng: &mut Rng| -> Vec<f64> {
            let mut w = Matrix::zeros(k, p * q);
            let mut z = Matrix::zeros(k, p * q);
            for i in 0..k {
                let zi: Vec<f64> = rng
                    .rademacher_f32(p * q)
                    .iter()
                    .zip(&mask)
                    .map(|(r, m)| *r as f64 * m)
                    .collect();
                let wi: Vec<f64> =
                    chol.solve(&zi).iter().zip(&mask).map(|(v, m)| v * m).collect();
                w.row_mut(i).copy_from_slice(&wi);
                z.row_mut(i).copy_from_slice(&zi);
            }
            let pairs = standard_pairs(&alpha, &w, &z);
            mll_surrogate_grads(&kernel, &s, &tgrid, &kss, &ktt, s2.ln(), &pairs)
        };
        let reference = grad_for(256, &mut rng);
        let norm: f64 = reference.iter().map(|g| g * g).sum::<f64>().sqrt();
        for k in [1usize, 2, 4, 8, 16, 32] {
            let g = grad_for(k, &mut rng);
            let err: f64 = g
                .iter()
                .zip(&reference)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt()
                / norm.max(1e-12);
            t.row(vec![k.to_string(), format!("{err:.4}")]);
        }
    }
    report::emit(&t, "ablation_probes");

    // ---- A4: Toeplitz temporal factor ----
    let mut t = Table::new(
        "A4 — temporal MVM: dense O(q^2) vs Toeplitz-FFT O(q log q)",
        &["q", "dense ms", "toeplitz ms", "speedup"],
    );
    {
        let mut rng = Rng::new(7);
        let p = 64;
        let kernel = ProductGridKernel::new(2, "rbf", 4);
        let s = Matrix::from_vec(p, 2, rng.normals(p * 2));
        let kss = kernel.gram_s(&s);
        for q in [64usize, 256, 1024] {
            let col: Vec<f64> =
                (0..q).map(|lag| (-0.5 * (lag as f64 / 8.0).powi(2)).exp()).collect();
            let ktt = Matrix::from_fn(q, q, |i, j| col[i.abs_diff(j)]);
            let dense_op = KronOp::new(kss.clone(), ktt.clone());
            // the production fast path: same KronOp, FFT time factor
            let fast_op = KronOp::new(kss.clone(), ktt).with_toeplitz(ToeplitzOp::new(&col));
            let v = Matrix::from_vec(1, p * q, rng.normals(p * q));
            let reps = 5;
            let sw = Stopwatch::start();
            for _ in 0..reps {
                std::hint::black_box(dense_op.apply_batch(&v));
            }
            let td = sw.secs() / reps as f64;
            let sw = Stopwatch::start();
            for _ in 0..reps {
                std::hint::black_box(fast_op.apply_batch(&v));
            }
            let tf = sw.secs() / reps as f64;
            t.row(vec![
                q.to_string(),
                format!("{:.2}", td * 1e3),
                format!("{:.2}", tf * 1e3),
                format!("{:.2}x", td / tf),
            ]);
        }
    }
    report::emit(&t, "ablation_toeplitz");

    // ---- A5: multi-factor Kron ----
    let mut t = Table::new(
        "A5 — 3-factor latent Kronecker MVM (future-work generalization)",
        &["dims", "N", "kron ms", "dense ms", "flops ratio"],
    );
    {
        let mut rng = Rng::new(9);
        for dims in [[8usize, 8, 8], [16, 8, 8], [16, 16, 8]] {
            let factors: Vec<Matrix<f64>> = dims
                .iter()
                .map(|&d| {
                    let a = Matrix::from_vec(d, 2, rng.normals(d * 2));
                    crate::kernels::RbfArd::new(2).gram(&a, &a)
                })
                .collect();
            let op = MultiKronOp::new(factors);
            let n = op.dim();
            let v = rng.normals(n);
            let reps = 10;
            let sw = Stopwatch::start();
            for _ in 0..reps {
                std::hint::black_box(op.apply(&v));
            }
            let tk = sw.secs() / reps as f64;
            let dense = op.dense();
            let sw = Stopwatch::start();
            for _ in 0..reps {
                std::hint::black_box(dense.matvec(&v));
            }
            let td = sw.secs() / reps as f64;
            t.row(vec![
                format!("{dims:?}"),
                n.to_string(),
                format!("{:.3}", tk * 1e3),
                format!("{:.3}", td * 1e3),
                format!(
                    "{:.1}x",
                    2.0 * (n as f64) * (n as f64) / multi_kron_flops(&dims)
                ),
            ]);
        }
    }
    report::emit(&t, "ablation_multikron");
}
