//! One module per paper table/figure. Each exposes `run(&ExperimentScale)`.

pub mod ablations;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod models;
pub mod table1;
pub mod table2;
