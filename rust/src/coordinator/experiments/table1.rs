//! Table 1 (and Tables 3–7): learning-curve prediction on the LCBench
//! families — LKGP vs SVGP / VNNGP / CaGP, train/test RMSE + NLL +
//! total time + average ranks.

use crate::coordinator::experiments::models::{aggregate, run_all_models};
use crate::coordinator::{report, ExperimentScale};
use crate::data::lcbench::table1_datasets;
use crate::util::stats::{mean, ranks};
use crate::util::table::Table;

/// Regenerate Table 1 (learning-curve prediction).
pub fn run(scale: &ExperimentScale) {
    println!(
        "== Table 1: learning-curve prediction (sim-LCBench, p={}, q={}) ==\n",
        scale.table1_p, scale.table1_q
    );
    let metric_names = ["Train RMSE", "Test RMSE", "Train NLL", "Test NLL", "Time (s)"];
    let datasets = table1_datasets(scale.table1_p, scale.table1_q);
    let ds_names: Vec<&str> = datasets.iter().map(|(n, _)| *n).collect();

    // results[metric][model][dataset] = mean value; cells pretty strings
    let n_models = 4;
    let mut cell: Vec<Vec<Vec<String>>> =
        vec![vec![vec![String::new(); ds_names.len()]; n_models]; 5];
    let mut val: Vec<Vec<Vec<f64>>> = vec![vec![vec![0.0; ds_names.len()]; n_models]; 5];
    let mut model_names: Vec<String> = vec![];

    for (di, (name, sim)) in datasets.iter().enumerate() {
        println!("dataset {name} ...");
        let mut per_seed = Vec::new();
        for seed in 0..scale.table1_seeds {
            let mut sim2 =
                crate::data::lcbench::LcBenchSim::new(sim.p, sim.q, sim.seed + 131 * seed);
            sim2.full_fraction = sim.full_fraction;
            let data = sim2.generate();
            let (res, _) = run_all_models(&data, scale, seed).expect("models");
            per_seed.push(res);
        }
        let agg = aggregate(&per_seed);
        model_names = agg.iter().map(|(n, _, _)| n.clone()).collect();
        for (mi, (_, cells, vals)) in agg.iter().enumerate() {
            for metric in 0..5 {
                cell[metric][mi][di] = cells[metric].clone();
                val[metric][mi][di] = vals[metric];
            }
        }
    }

    // assemble the paper-style table: metric blocks x models x datasets
    let mut header: Vec<&str> = vec!["Metric", "Model"];
    header.extend(ds_names.iter());
    header.push("Avg Rank");
    let mut table = Table::new(
        "Table 1 — learning-curve prediction across sim-LCBench families",
        &header,
    );
    for (metric, mname) in metric_names.iter().enumerate() {
        // ranks per dataset (lower = better for all five metrics)
        let mut rank_acc = vec![0.0; n_models];
        for di in 0..ds_names.len() {
            let scores: Vec<f64> = (0..n_models).map(|mi| val[metric][mi][di]).collect();
            for (mi, r) in ranks(&scores).into_iter().enumerate() {
                rank_acc[mi] += r;
            }
        }
        for mi in 0..n_models {
            let mut row = vec![
                if mi == 0 { mname.to_string() } else { String::new() },
                model_names[mi].clone(),
            ];
            row.extend(cell[metric][mi].iter().cloned());
            row.push(format!("{:.2}", rank_acc[mi] / ds_names.len() as f64));
            table.row(row);
        }
    }
    report::emit(&table, "table1_lcbench");

    // headline checks from the paper
    let lkgp_i = model_names.iter().position(|m| m == "LKGP").unwrap_or(0);
    let avg = |metric: usize, mi: usize| -> f64 { mean(&val[metric][mi]) };
    let mut notes = String::from("\nHeadline comparisons (paper Table 1):\n");
    notes += &format!(
        "- LKGP mean test NLL {:.3} vs best baseline {:.3} (paper: LKGP best)\n",
        avg(3, lkgp_i),
        (0..n_models)
            .filter(|&m| m != lkgp_i)
            .map(|m| avg(3, m))
            .fold(f64::INFINITY, f64::min)
    );
    notes += &format!(
        "- LKGP mean train RMSE {:.3} vs best baseline {:.3} (paper: LKGP best)\n",
        avg(0, lkgp_i),
        (0..n_models)
            .filter(|&m| m != lkgp_i)
            .map(|m| avg(0, m))
            .fold(f64::INFINITY, f64::min)
    );
    notes += &format!(
        "- LKGP mean time {:.2}s vs baselines {:?}s (paper: LKGP fastest)\n",
        avg(4, lkgp_i),
        (0..n_models)
            .filter(|&m| m != lkgp_i)
            .map(|m| (model_names[m].clone(), (avg(4, m) * 100.0).round() / 100.0))
            .collect::<Vec<_>>()
    );
    report::note("table1_lcbench", &notes);
    println!("{notes}");
}
