//! Figure 3: inverse dynamics of a 7-DOF arm — LKGP vs the standard
//! dense iterative method across missing ratios, with the Prop. 3.1
//! break-even overlay.
//!
//! Same model, same solver, same hyperparameter trajectory; the only
//! difference is the MVM (latent Kronecker vs materialized dense), so
//! predictive metrics must coincide while time and memory diverge —
//! exactly the paper's claim.

use crate::coordinator::experiments::models::lkgp_config;
use crate::coordinator::{report, ExperimentScale};
use crate::data::sarcos::SarcosSim;
use crate::gp::backend::MvmMode;
use crate::gp::lkgp::{Backend, Lkgp};
use crate::kron::breakeven;
use crate::util::stats::mean;
use crate::util::table::Table;

/// Regenerate the Figure-3 missing-ratio comparison.
pub fn run(scale: &ExperimentScale) {
    let (p, q) = (scale.fig3_p, 7);
    println!("== Figure 3: simulated SARCOS (p={p}, q={q}) — LKGP vs dense iterative ==\n");
    let gstar_time = breakeven::gamma_time(p, q);
    let gstar_mem = breakeven::gamma_mem(p, q);
    println!(
        "Prop 3.1 asymptotic break-even: gamma*_time = {gstar_time:.3}, \
         gamma*_mem = {gstar_mem:.3}\n"
    );

    let mut table = Table::new(
        &format!("Fig 3 — missing-ratio sweep on sim-SARCOS (p={p}, q=7)"),
        &[
            "missing", "n", "LKGP s", "dense s", "LKGP kernel MiB", "dense kernel MiB",
            "LKGP test RMSE", "dense test RMSE", "LKGP test NLL", "dense test NLL",
        ],
    );
    let mut crossover: Option<f64> = None;
    let mut prev_ratio_speed: Option<(f64, f64)> = None;
    for &ratio in &scale.fig3_ratios {
        let mut t_k = vec![];
        let mut t_d = vec![];
        let mut rk = vec![];
        let mut rd = vec![];
        let mut nk = vec![];
        let mut nd = vec![];
        let mut mem_k = 0.0;
        let mut mem_d = 0.0;
        let mut n_obs = 0;
        for seed in 0..scale.fig3_seeds {
            let data = SarcosSim::new(p, ratio, seed).generate();
            n_obs = data.n_observed();
            let mut cfg = lkgp_config(scale, seed);
            cfg.backend = Backend::Rust(MvmMode::Kron);
            let fit = Lkgp::fit(&data, cfg.clone()).expect("lkgp fit");
            let mut cfg_d = cfg.clone();
            cfg_d.backend = Backend::Rust(MvmMode::DenseMaterialized);
            let fit_d = Lkgp::fit(&data, cfg_d).expect("dense fit");
            t_k.push(fit.train_secs + fit.predict_secs);
            t_d.push(fit_d.train_secs + fit_d.predict_secs);
            let (trm, tnl) = fit.posterior.test_metrics(&data);
            let (drm, dnl) = fit_d.posterior.test_metrics(&data);
            rk.push(trm);
            rd.push(drm);
            nk.push(tnl);
            nd.push(dnl);
            mem_k = fit.kernel_bytes as f64 / (1 << 20) as f64;
            mem_d = fit_d.kernel_bytes as f64 / (1 << 20) as f64;
        }
        let (mtk, mtd) = (mean(&t_k), mean(&t_d));
        // empirical time crossover: first ratio where dense gets faster
        if let Some((r0, s0)) = prev_ratio_speed {
            let s1 = mtd / mtk;
            if s0 >= 1.0 && s1 < 1.0 && crossover.is_none() {
                // linear interpolation in speedup
                crossover = Some(r0 + (ratio - r0) * (s0 - 1.0) / (s0 - s1).max(1e-9));
            }
        }
        prev_ratio_speed = Some((ratio, mtd / mtk));
        table.row(vec![
            format!("{ratio:.1}"),
            n_obs.to_string(),
            format!("{mtk:.2}"),
            format!("{mtd:.2}"),
            format!("{mem_k:.3}"),
            format!("{mem_d:.3}"),
            format!("{:.3}", mean(&rk)),
            format!("{:.3}", mean(&rd)),
            format!("{:.3}", mean(&nk)),
            format!("{:.3}", mean(&nd)),
        ]);
    }
    report::emit(&table, "fig3_sarcos");
    let cross_note = match crossover {
        Some(c) => format!(
            "\nEmpirical time break-even ~ {c:.2} vs Prop 3.1 gamma*_time = \
             {gstar_time:.3} (predictions should coincide across the sweep — \
             LKGP is exact).\n"
        ),
        None => format!(
            "\nNo time crossover inside the sweep; Prop 3.1 predicts \
             gamma*_time = {gstar_time:.3}.\n"
        ),
    };
    report::note("fig3_sarcos", &cross_note);
    println!("{cross_note}");
}
