//! The four-model comparison harness shared by Table 1, Table 2 and
//! Fig 4: LKGP (ours) vs SVGP / VNNGP / CaGP on one GridDataset.

use anyhow::Result;

use crate::baselines::{BaselineModel, CaGp, Svgp, Vnngp};
use crate::coordinator::ExperimentScale;
use crate::data::GridDataset;
use crate::gp::lkgp::{Backend, Lkgp, LkgpConfig};
use crate::gp::backend::MvmMode;
use crate::gp::Posterior;

/// One model's metrics on one dataset.
#[derive(Clone, Debug)]
pub struct ModelResult {
    /// Model name.
    pub model: String,
    /// RMSE on observed cells.
    pub train_rmse: f64,
    /// RMSE on withheld cells.
    pub test_rmse: f64,
    /// Mean Gaussian NLL on observed cells.
    pub train_nll: f64,
    /// Mean Gaussian NLL on withheld cells.
    pub test_nll: f64,
    /// Fit + predict wall-clock seconds.
    pub secs: f64,
}

/// The LKGP configuration all table/figure experiments share.
pub fn lkgp_config(scale: &ExperimentScale, seed: u64) -> LkgpConfig {
    let backend = if scale.backend == "rust" {
        Backend::Rust(MvmMode::Kron)
    } else {
        Backend::Pjrt { config: scale.backend.clone() }
    };
    LkgpConfig {
        train_iters: scale.gp_train_iters,
        n_samples: scale.n_samples,
        seed,
        backend,
        ..LkgpConfig::default()
    }
}

fn record(name: &str, post: &Posterior, data: &GridDataset, secs: f64) -> ModelResult {
    let (train_rmse, train_nll) = post.train_metrics(data);
    let (test_rmse, test_nll) = post.test_metrics(data);
    ModelResult {
        model: name.to_string(),
        train_rmse,
        test_rmse,
        train_nll,
        test_nll,
        secs,
    }
}

/// Run all four models on one dataset, returning posteriors for
/// qualitative plots (Fig 4).
pub fn run_all_models(
    data: &GridDataset,
    scale: &ExperimentScale,
    seed: u64,
) -> Result<(Vec<ModelResult>, Vec<(String, Posterior)>)> {
    let mut results = Vec::new();
    let mut posteriors = Vec::new();

    let t0 = std::time::Instant::now();
    let fit = Lkgp::fit(data, lkgp_config(scale, seed))?;
    results.push(record("LKGP", &fit.posterior, data, t0.elapsed().as_secs_f64()));
    posteriors.push(("LKGP".to_string(), fit.posterior));

    let n = data.n_observed();
    let m_inducing = (n / 8).clamp(16, 128);
    let mut svgp = Svgp::new(m_inducing, scale.baseline_train_iters, seed);
    let f = svgp.fit_predict(data)?;
    results.push(record("SVGP", &f.posterior, data, f.train_secs));
    posteriors.push(("SVGP".to_string(), f.posterior));

    let k_nn = 24.min(n.saturating_sub(1)).max(2);
    let mut vnngp = Vnngp::new(k_nn, scale.baseline_train_iters, seed);
    let f = vnngp.fit_predict(data)?;
    results.push(record("VNNGP", &f.posterior, data, f.train_secs));
    posteriors.push(("VNNGP".to_string(), f.posterior));

    let mut cagp = CaGp::new(m_inducing.min(48), scale.baseline_train_iters, seed);
    let f = cagp.fit_predict(data)?;
    results.push(record("CaGP", &f.posterior, data, f.train_secs));
    posteriors.push(("CaGP".to_string(), f.posterior));

    Ok((results, posteriors))
}

/// Aggregate per-seed results: mean ± sem strings per metric.
pub fn aggregate(per_seed: &[Vec<ModelResult>]) -> Vec<(String, [String; 5], [f64; 5])> {
    use crate::util::stats::{mean, mean_sem_str};
    let models: Vec<String> = per_seed[0].iter().map(|r| r.model.clone()).collect();
    let mut out = Vec::new();
    for (mi, name) in models.iter().enumerate() {
        let pick = |f: fn(&ModelResult) -> f64| -> Vec<f64> {
            per_seed.iter().map(|seed| f(&seed[mi])).collect()
        };
        let tr = pick(|r| r.train_rmse);
        let te = pick(|r| r.test_rmse);
        let trn = pick(|r| r.train_nll);
        let ten = pick(|r| r.test_nll);
        let sec = pick(|r| r.secs);
        out.push((
            name.clone(),
            [
                mean_sem_str(&tr),
                mean_sem_str(&te),
                mean_sem_str(&trn),
                mean_sem_str(&ten),
                format!("{:.2}", mean(&sec)),
            ],
            [mean(&tr), mean(&te), mean(&trn), mean(&ten), mean(&sec)],
        ));
    }
    out
}
