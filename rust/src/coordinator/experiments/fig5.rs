//! Figure 5: illustration of the (simulated) Nordic climate data —
//! summary statistics + example time series demonstrating the seasonal
//! periodic trend (temperature) and the noisy, locally-correlated
//! precipitation field.

use crate::coordinator::{report, ExperimentScale};
use crate::data::climate::{ClimateSim, ClimateVariant};
use crate::util::table::Table;

/// Regenerate the Figure-5 climate comparison.
pub fn run(scale: &ExperimentScale) {
    println!("== Figure 5: climate dataset illustration ==\n");
    let mut table = Table::new(
        "Fig 5 — sim-Nordic dataset summary",
        &["variant", "p", "q", "mean", "std", "min", "max", "lag-1 autocorr", "seasonal amp"],
    );
    for variant in [ClimateVariant::Temperature, ClimateVariant::Precipitation] {
        let vname = match variant {
            ClimateVariant::Temperature => "temperature",
            ClimateVariant::Precipitation => "precipitation",
        };
        let data =
            ClimateSim::new(scale.table2_p, scale.table2_q.max(365), variant, 0.0, 42).generate();
        let (p, q) = (data.p(), data.q());
        let n = (p * q) as f64;
        let mean = data.y_grid.iter().sum::<f64>() / n;
        let var = data.y_grid.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
        let (lo, hi) = data
            .y_grid
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &v| (l.min(v), h.max(v)));
        // lag-1 autocorrelation averaged over stations
        let mut ac = 0.0;
        for j in 0..p {
            let row = &data.y_grid[j * q..(j + 1) * q];
            let rm = row.iter().sum::<f64>() / q as f64;
            let mut num = 0.0;
            let mut den = 0.0;
            for k in 1..q {
                num += (row[k] - rm) * (row[k - 1] - rm);
            }
            for v in row {
                den += (v - rm) * (v - rm);
            }
            ac += num / den.max(1e-12);
        }
        ac /= p as f64;
        // seasonal amplitude: winter-vs-summer mean gap over first year
        let day_mean = |d: usize| -> f64 {
            (0..p).map(|j| data.y_grid[j * q + d]).sum::<f64>() / p as f64
        };
        let seas = if q >= 365 { (day_mean(198) - day_mean(15)).abs() } else { f64::NAN };
        table.row(vec![
            vname.into(),
            p.to_string(),
            q.to_string(),
            format!("{mean:.2}"),
            format!("{:.2}", var.sqrt()),
            format!("{lo:.2}"),
            format!("{hi:.2}"),
            format!("{ac:.3}"),
            if seas.is_nan() { "-".into() } else { format!("{seas:.2}") },
        ]);

        // dump one station's series as CSV for plotting
        let mut series = Table::new(
            &format!("Fig 5 — example station series ({vname})"),
            &["day", "value"],
        );
        for k in 0..q.min(365) {
            series.row(vec![k.to_string(), format!("{:.3}", data.y_grid[k])]);
        }
        let _ = series.save(&report::results_dir(), &format!("fig5_series_{vname}"));
    }
    report::emit(&table, "fig5_summary");
}
