//! Experiment scaling knobs.
//!
//! The paper ran on A100s (p=5000, q=1000, 2000 GPU-hours); this testbed
//! is one CPU core. `quick` keeps every experiment under ~a minute,
//! `paper` is the scaled-shape default used for EXPERIMENTS.md, `full`
//! stretches as far as is sane on one core. The *shape* of every claim
//! (who wins, break-even location) is scale-invariant — see DESIGN.md.

use crate::util::cli::Args;

/// Per-experiment problem sizes and iteration budgets.
#[derive(Clone, Debug)]
pub struct ExperimentScale {
    /// Fig 2: grid sizes n = p*q with p = q = sqrt(n)
    pub fig2_sizes: Vec<usize>,
    /// Fig 2: largest n for which the dense path is materialized
    pub fig2_dense_cap: usize,
    /// Fig 3: spatial points (q = 7 tasks fixed by the problem)
    pub fig3_p: usize,
    /// Fig 3: missing-ratio sweep.
    pub fig3_ratios: Vec<f64>,
    /// Fig 3: seeds per configuration.
    pub fig3_seeds: u64,
    /// Table 1 / Fig 4: learning curves per dataset, epochs
    pub table1_p: usize,
    /// Table 1 / Fig 4: epochs per curve.
    pub table1_q: usize,
    /// Table 1: seeds per configuration.
    pub table1_seeds: u64,
    /// Table 2: stations x days
    pub table2_p: usize,
    /// Table 2: days.
    pub table2_q: usize,
    /// Table 2: missing-ratio sweep.
    pub table2_ratios: Vec<f64>,
    /// Table 2: seeds per configuration.
    pub table2_seeds: u64,
    /// model-fit iteration budgets
    pub gp_train_iters: usize,
    /// Training-iteration budget of the variational baselines.
    pub baseline_train_iters: usize,
    /// Pathwise samples per fit.
    pub n_samples: usize,
    /// LKGP backend: "rust" or a PJRT artifact config name
    pub backend: String,
}

impl ExperimentScale {
    /// Sub-minute sizes for local iteration and CI.
    pub fn quick() -> Self {
        ExperimentScale {
            fig2_sizes: vec![64, 256, 1024, 4096, 16384],
            fig2_dense_cap: 4096,
            fig3_p: 128,
            fig3_ratios: vec![0.1, 0.3, 0.5, 0.7, 0.9],
            fig3_seeds: 2,
            table1_p: 64,
            table1_q: 52,
            table1_seeds: 2,
            table2_p: 64,
            table2_q: 48,
            table2_ratios: vec![0.1, 0.3, 0.5],
            table2_seeds: 1,
            gp_train_iters: 10,
            baseline_train_iters: 5,
            n_samples: 16,
            backend: "rust".into(),
        }
    }

    /// The scaled-shape defaults behind EXPERIMENTS.md.
    pub fn paper() -> Self {
        ExperimentScale {
            fig2_sizes: vec![256, 1024, 4096, 16384, 65536, 262144],
            fig2_dense_cap: 16384,
            fig3_p: 512,
            fig3_ratios: vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9],
            fig3_seeds: 3,
            table1_p: 256,
            table1_q: 52,
            table1_seeds: 3,
            table2_p: 160,
            table2_q: 64,
            table2_ratios: vec![0.1, 0.2, 0.3, 0.4, 0.5],
            table2_seeds: 2,
            gp_train_iters: 20,
            baseline_train_iters: 8,
            n_samples: 32,
            backend: "rust".into(),
        }
    }

    /// Parse from CLI flags: --scale quick|paper plus per-knob overrides.
    pub fn from_args(args: &Args) -> Self {
        let mut s = match args.str("scale", "quick").as_str() {
            "paper" => Self::paper(),
            _ => Self::quick(),
        };
        s.fig3_p = args.usize("fig3-p", s.fig3_p);
        s.fig3_seeds = args.u64("seeds", s.fig3_seeds);
        s.table1_seeds = args.u64("seeds", s.table1_seeds);
        s.table2_seeds = args.u64("seeds", s.table2_seeds).max(1);
        s.fig3_ratios = args.f64_list("ratios", &s.fig3_ratios);
        s.table2_ratios = args.f64_list("ratios", &s.table2_ratios);
        s.gp_train_iters = args.usize("train-iters", s.gp_train_iters);
        s.baseline_train_iters = args.usize("baseline-iters", s.baseline_train_iters);
        s.n_samples = args.usize("samples", s.n_samples);
        s.backend = args.str("backend", &s.backend);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_is_smaller_than_paper() {
        let (q, p) = (ExperimentScale::quick(), ExperimentScale::paper());
        assert!(q.fig3_p < p.fig3_p);
        assert!(q.table1_p < p.table1_p);
        assert!(q.fig2_sizes.last() < p.fig2_sizes.last());
    }

    #[test]
    fn args_override() {
        let args = Args::parse(
            "x --scale paper --fig3-p 99 --ratios 0.5 --seeds 1"
                .split_whitespace()
                .map(String::from),
        );
        let s = ExperimentScale::from_args(&args);
        assert_eq!(s.fig3_p, 99);
        assert_eq!(s.fig3_ratios, vec![0.5]);
        assert_eq!(s.fig3_seeds, 1);
    }
}
