//! Result persistence: every experiment prints its tables and saves
//! markdown + CSV under results/, so EXPERIMENTS.md can reference them.

use std::path::PathBuf;

use crate::util::table::Table;

/// Directory experiment outputs are written to (`LKGP_RESULTS` or the
/// repo-root `results/`).
pub fn results_dir() -> PathBuf {
    let dir = std::env::var("LKGP_RESULTS").map(PathBuf::from).unwrap_or_else(|_| {
        // anchor at the repo root if we can find it
        let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        for _ in 0..4 {
            if cur.join("Cargo.toml").exists() {
                return cur.join("results");
            }
            if !cur.pop() {
                break;
            }
        }
        PathBuf::from("results")
    });
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Print and persist a table.
pub fn emit(table: &Table, stem: &str) {
    println!("{}", table.markdown());
    if let Err(e) = table.save(&results_dir(), stem) {
        eprintln!("warning: could not save {stem}: {e}");
    } else {
        println!("[saved results/{stem}.md + .csv]\n");
    }
}

/// Append a free-form markdown note next to the tables.
pub fn note(stem: &str, text: &str) {
    let path = results_dir().join(format!("{stem}.md"));
    let mut body = std::fs::read_to_string(&path).unwrap_or_default();
    body.push_str(text);
    let _ = std::fs::write(&path, body);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_dir_exists_after_call() {
        let d = results_dir();
        assert!(d.exists());
    }
}
