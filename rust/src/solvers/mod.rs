//! Iterative linear-system solvers (the paper's inference engine).
//!
//! The LKGP posterior, probe solves, and pathwise-conditioning samples
//! are all solutions of `(P K P^T + sigma2 I) x = b` computed by batched
//! preconditioned conjugate gradients against a matrix-free operator
//! (rust Kron backend or the PJRT kron_mvm artifact). On fully-observed
//! grids the `eig` module short-circuits CG entirely with an exact
//! per-factor spectral solve; under light masking the same
//! decomposition serves as the latent-grid `KronEig` preconditioner.

pub mod altproj;
pub mod cg;
pub mod eig;
pub mod precond;
pub mod sgd;

pub use cg::{solve_cg, BatchedOp, CgOptions, CgStats, SolveDiag, SolveError, SolveOutcome};
pub use eig::{EigSolveError, EigSolver};
pub use precond::{PrecondError, Preconditioner};
