//! Alternating-projections linear solver (Wu et al. 2024, cited in
//! paper Sec. 2 as one of the iterative-GP solver families).
//!
//! Solves (K + sigma2 I) x = b by cycling over coordinate blocks B and
//! applying the exact block update
//!
//!   x_B <- x_B + (K_BB + sigma2 I)^{-1} r_B,
//!
//! which is a projection of the residual onto the block subspace in the
//! K-norm. Converges linearly for SPD systems; each sweep costs
//! O(n b^2 + n^2) via cached block Cholesky factors (amortized across
//! sweeps) plus one full MVM for the residual refresh.

use crate::linalg::chol::{cholesky, Cholesky};
use crate::linalg::{Matrix, Scalar};

use super::cg::{BatchedOp, CgStats};

/// Stopping criteria and block size for [`solve_altproj`].
pub struct AltProjOptions {
    /// Coordinate-block size b.
    pub block_size: usize,
    /// Maximum full sweeps over all blocks.
    pub max_sweeps: usize,
    /// Relative residual tolerance.
    pub tol: f64,
}

impl Default for AltProjOptions {
    fn default() -> Self {
        AltProjOptions { block_size: 64, max_sweeps: 60, tol: 1e-2 }
    }
}

/// Solve A X = B (rows of `b` are independent RHS) with alternating
/// projections. `entry(i, j)` must return A_ij (including the noise on
/// the diagonal). The operator `op` provides the full MVM used for
/// residual refreshes.
pub fn solve_altproj<T: Scalar>(
    op: &mut impl BatchedOp<T>,
    entry: impl Fn(usize, usize) -> f64,
    b: &Matrix<T>,
    opts: &AltProjOptions,
) -> (Matrix<T>, CgStats) {
    let n = op.dim();
    assert_eq!(b.cols, n);
    let nsys = b.rows;
    let bs = opts.block_size.min(n).max(1);
    let nblocks = n.div_ceil(bs);

    // cache block Cholesky factors once (hyperparameters are fixed
    // during a solve)
    let mut block_chols: Vec<(usize, usize, Cholesky<f64>)> = Vec::with_capacity(nblocks);
    for blk in 0..nblocks {
        let lo = blk * bs;
        let hi = ((blk + 1) * bs).min(n);
        let m = Matrix::<f64>::from_fn(hi - lo, hi - lo, |a, c| entry(lo + a, lo + c));
        let ch = cholesky(&m).expect("block not PD");
        block_chols.push((lo, hi, ch));
    }

    let mut x = Matrix::<T>::zeros(nsys, n);
    let mut r = b.clone(); // residual b - A x (x = 0)
    let b_norms: Vec<f64> = (0..nsys)
        .map(|s| {
            b.row(s).iter().map(|v| v.to_f64() * v.to_f64()).sum::<f64>().sqrt().max(1e-300)
        })
        .collect();
    let mut stats = CgStats::default();

    for sweep in 0..opts.max_sweeps {
        for (lo, hi, ch) in &block_chols {
            for s in 0..nsys {
                let rb: Vec<f64> =
                    r.row(s)[*lo..*hi].iter().map(|v| v.to_f64()).collect();
                let dx = ch.solve(&rb);
                for (i, d) in dx.iter().enumerate() {
                    let xi = &mut x.row_mut(s)[lo + i];
                    *xi += T::from_f64(*d);
                }
            }
            // cheap local residual update is possible, but the exact
            // refresh below keeps the implementation simple and robust.
        }
        // refresh residual exactly: r = b - A x
        let ax = op.apply_batch(&x);
        stats.mvm_count += 1;
        let mut worst = 0.0f64;
        for s in 0..nsys {
            let rrow = r.row_mut(s);
            let mut acc = 0.0;
            for ((ri, bi), axi) in rrow.iter_mut().zip(b.row(s)).zip(ax.row(s)) {
                *ri = *bi - *axi;
                acc += ri.to_f64() * ri.to_f64();
            }
            worst = worst.max(acc.sqrt() / b_norms[s]);
        }
        stats.iters = sweep + 1;
        stats.rel_residuals = vec![worst];
        if worst < opts.tol {
            stats.converged = true;
            return (x, stats);
        }
    }
    (x, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::cg::DenseOp;
    use crate::util::testing::{assert_close, prop_check};

    #[test]
    fn prop_solves_spd_systems() {
        prop_check("altproj-solves", 211, 10, |g| {
            let n = g.size(2, 40);
            let mut a = Matrix::from_vec(n, n, g.spd(n));
            a.add_diag(0.5);
            let b = Matrix::from_vec(2, n, g.vec_normal(2 * n));
            let a2 = a.clone();
            let (x, stats) = solve_altproj(
                &mut DenseOp(&a),
                |i, j| a2[(i, j)],
                &b,
                &AltProjOptions { block_size: 7, max_sweeps: 500, tol: 1e-8 },
            );
            if !stats.converged {
                return Err(format!("not converged: {:?}", stats.rel_residuals));
            }
            for s in 0..2 {
                assert_close(&a.matvec(x.row(s)), b.row(s), 1e-5)?;
            }
            Ok(())
        });
    }

    #[test]
    fn single_block_converges_in_one_sweep() {
        let mut g = crate::util::testing::Gen { rng: crate::util::rng::Rng::new(2) };
        let n = 12;
        let a = Matrix::from_vec(n, n, g.spd(n));
        let b = Matrix::from_vec(1, n, g.vec_normal(n));
        let a2 = a.clone();
        let (x, stats) = solve_altproj(
            &mut DenseOp(&a),
            |i, j| a2[(i, j)],
            &b,
            &AltProjOptions { block_size: n, max_sweeps: 3, tol: 1e-10 },
        );
        assert!(stats.converged && stats.iters == 1, "{stats:?}");
        assert_close(&a.matvec(x.row(0)), b.row(0), 1e-7).unwrap();
    }
}
