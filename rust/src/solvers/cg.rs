//! Batched preconditioned conjugate gradients.
//!
//! Solves A X = B for several right-hand sides at once, where A is any
//! symmetric positive-definite operator exposed through `BatchedOp`
//! (rows of the batch matrix are independent systems, so the MVM cost
//! is amortized across RHS — exactly how the paper batches y together
//! with pathwise/probe vectors). Per-system convergence is tracked by
//! relative residual norm (paper: tolerance 0.01).

use crate::linalg::{Matrix, Scalar};

use super::precond::Preconditioner;

/// A symmetric positive definite operator applied to a batch of row
/// vectors: `out[b] = A v[b]`.
pub trait BatchedOp<T: Scalar> {
    /// Dimension n of the operator (rows of `v` have n columns).
    fn dim(&self) -> usize;
    /// Apply the operator to every row of `v`: `out[b] = A v[b]`.
    fn apply_batch(&mut self, v: &Matrix<T>) -> Matrix<T>;
    /// Operators whose applies can fail mid-solve (e.g. a PJRT backend,
    /// see `gp::backend::SystemOp`) report it here so the solver stops
    /// iterating instead of spinning on degenerate products; the caller
    /// is responsible for surfacing the underlying error after the
    /// solve returns.
    fn failed(&self) -> bool {
        false
    }
}

impl<T: Scalar, O: BatchedOp<T> + ?Sized> BatchedOp<T> for &mut O {
    fn dim(&self) -> usize {
        (**self).dim()
    }
    fn apply_batch(&mut self, v: &Matrix<T>) -> Matrix<T> {
        (**self).apply_batch(v)
    }
    fn failed(&self) -> bool {
        (**self).failed()
    }
}

/// Dense matrix as a BatchedOp (baselines, tests).
pub struct DenseOp<'a, T: Scalar>(
    /// The (symmetric) system matrix.
    pub &'a Matrix<T>,
);

impl<'a, T: Scalar> BatchedOp<T> for DenseOp<'a, T> {
    fn dim(&self) -> usize {
        self.0.rows
    }
    fn apply_batch(&mut self, v: &Matrix<T>) -> Matrix<T> {
        // out rows = v rows; out[b] = A v[b] = (v @ A^T) rows; A symmetric
        crate::linalg::gemm::matmul_nt(v, self.0)
    }
}

/// Stopping criteria for [`solve_cg`].
#[derive(Clone, Debug)]
pub struct CgOptions {
    /// Iteration cap per solve.
    pub max_iters: usize,
    /// relative residual norm tolerance ||r|| / ||b||.
    pub tol: f64,
}

impl Default for CgOptions {
    fn default() -> Self {
        CgOptions { max_iters: 500, tol: 1e-2 }
    }
}

/// Convergence report of one [`solve_cg`] call.
#[derive(Clone, Debug, Default)]
pub struct CgStats {
    /// Iterations executed.
    pub iters: usize,
    /// Batched operator applications performed.
    pub mvm_count: usize,
    /// final relative residuals per system
    pub rel_residuals: Vec<f64>,
    /// True when every system met the tolerance.
    pub converged: bool,
}

/// Solve A X = B with batched PCG. Returns (X, stats); X rows align
/// with B rows. Iteration stops when every system's relative residual
/// is below tol (or max_iters).
pub fn solve_cg<T: Scalar>(
    op: &mut impl BatchedOp<T>,
    b: &Matrix<T>,
    precond: &Preconditioner<T>,
    opts: &CgOptions,
) -> (Matrix<T>, CgStats) {
    let n = op.dim();
    assert_eq!(b.cols, n, "rhs dim");
    let nsys = b.rows;
    let mut x = Matrix::<T>::zeros(nsys, n);
    let mut r = b.clone(); // r = b - A*0
    let mut z = precond.apply_batch(&r);
    let mut p = z.clone();

    let dot_rows = |a: &Matrix<T>, c: &Matrix<T>| -> Vec<f64> {
        (0..a.rows)
            .map(|i| {
                let mut s = 0.0f64;
                for (x, y) in a.row(i).iter().zip(c.row(i)) {
                    s += x.to_f64() * y.to_f64();
                }
                s
            })
            .collect()
    };

    let b_norms: Vec<f64> = dot_rows(b, b).iter().map(|s| s.sqrt().max(1e-300)).collect();
    let mut rz = dot_rows(&r, &z);
    let mut stats = CgStats::default();
    let mut active = vec![true; nsys];

    for iter in 0..opts.max_iters {
        // convergence check
        let rr = dot_rows(&r, &r);
        let rel: Vec<f64> = rr.iter().zip(&b_norms).map(|(s, bn)| s.sqrt() / bn).collect();
        for (a, rel) in active.iter_mut().zip(&rel) {
            *a = *rel > opts.tol;
        }
        stats.rel_residuals = rel;
        if active.iter().all(|a| !a) {
            stats.converged = true;
            stats.iters = iter;
            return (x, stats);
        }

        let ap = op.apply_batch(&p);
        stats.mvm_count += 1;
        if op.failed() {
            break; // operator failure: stop, caller surfaces the error
        }
        let pap = dot_rows(&p, &ap);
        for sys in 0..nsys {
            if !active[sys] || pap[sys].abs() < 1e-300 {
                continue;
            }
            let alpha = T::from_f64(rz[sys] / pap[sys]);
            let (xr, pr) = (x.row_mut(sys), p.row(sys));
            for (xi, pi) in xr.iter_mut().zip(pr) {
                *xi += alpha * *pi;
            }
            let (rrow, aprow) = (r.row_mut(sys), ap.row(sys));
            for (ri, api) in rrow.iter_mut().zip(aprow) {
                *ri -= alpha * *api;
            }
        }
        z = precond.apply_batch(&r);
        let rz_new = dot_rows(&r, &z);
        for sys in 0..nsys {
            if !active[sys] {
                continue;
            }
            let beta = if rz[sys].abs() < 1e-300 { 0.0 } else { rz_new[sys] / rz[sys] };
            let betat = T::from_f64(beta);
            let (prow, zrow) = (p.row_mut(sys), z.row(sys));
            for (pi, zi) in prow.iter_mut().zip(zrow) {
                *pi = *zi + betat * *pi;
            }
        }
        rz = rz_new;
        stats.iters = iter + 1;
    }
    // final residual report
    let rr = dot_rows(&r, &r);
    stats.rel_residuals = rr.iter().zip(&b_norms).map(|(s, bn)| s.sqrt() / bn).collect();
    stats.converged = stats.rel_residuals.iter().all(|&r| r <= opts.tol);
    (x, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::precond::Preconditioner;
    use crate::util::testing::{assert_close, prop_check};

    #[test]
    fn failed_operator_stops_after_one_mvm() {
        struct FailingOp;
        impl BatchedOp<f64> for FailingOp {
            fn dim(&self) -> usize {
                8
            }
            fn apply_batch(&mut self, v: &Matrix<f64>) -> Matrix<f64> {
                Matrix::zeros(v.rows, v.cols)
            }
            fn failed(&self) -> bool {
                true
            }
        }
        let b = Matrix::from_vec(1, 8, vec![1.0; 8]);
        let (x, stats) =
            solve_cg(&mut FailingOp, &b, &Preconditioner::Identity, &CgOptions::default());
        assert!(!stats.converged);
        assert_eq!(stats.mvm_count, 1);
        assert!(x.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn prop_cg_solves_spd_systems() {
        prop_check("cg-solves", 83, 15, |g| {
            let n = g.size(1, 30);
            let a = Matrix::from_vec(n, n, g.spd(n));
            let b = Matrix::from_vec(3, n, g.vec_normal(3 * n));
            let mut op = DenseOp(&a);
            let (x, stats) = solve_cg(
                &mut op,
                &b,
                &Preconditioner::Identity,
                &CgOptions { max_iters: 10 * n, tol: 1e-10 },
            );
            if !stats.converged {
                return Err(format!("not converged: {:?}", stats.rel_residuals));
            }
            for sys in 0..3 {
                let back = a.matvec(x.row(sys));
                assert_close(&back, b.row(sys), 1e-6)?;
            }
            Ok(())
        });
    }

    #[test]
    fn jacobi_preconditioner_reduces_iterations() {
        // strongly diagonal-dominant, badly scaled system
        let n = 60;
        let a = Matrix::from_fn(n, n, |i, j| {
            if i == j {
                10.0 * (1.0 + i as f64)
            } else {
                1.0 / (1.0 + (i as f64 - j as f64).abs())
            }
        });
        let b = Matrix::from_vec(1, n, vec![1.0; n]);
        let opts = CgOptions { max_iters: 200, tol: 1e-8 };
        let (_, s_plain) = solve_cg(&mut DenseOp(&a), &b, &Preconditioner::Identity, &opts);
        let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
        let pre = Preconditioner::jacobi(&diag);
        let (_, s_pre) = solve_cg(&mut DenseOp(&a), &b, &pre, &opts);
        assert!(s_pre.converged && s_plain.converged);
        assert!(
            s_pre.iters < s_plain.iters,
            "jacobi {} !< plain {}",
            s_pre.iters,
            s_plain.iters
        );
    }

    #[test]
    fn per_system_convergence_tracked() {
        let n = 20;
        let a = Matrix::from_fn(n, n, |i, j| if i == j { 2.0 } else { 0.0 });
        let mut b = Matrix::zeros(2, n);
        b.row_mut(0).copy_from_slice(&vec![1.0; n]);
        // second system has zero rhs -> converged immediately
        let (x, stats) = solve_cg(
            &mut DenseOp(&a),
            &b,
            &Preconditioner::Identity,
            &CgOptions::default(),
        );
        assert!(stats.converged);
        assert!(x.row(0).iter().all(|&v| (v - 0.5).abs() < 1e-6));
        assert!(x.row(1).iter().all(|&v| v.abs() < 1e-12));
    }

    #[test]
    fn f32_path_converges() {
        let mut g = crate::util::testing::Gen { rng: crate::util::rng::Rng::new(9) };
        let n = 25;
        let a64 = Matrix::from_vec(n, n, g.spd(n));
        let a: Matrix<f32> = a64.cast();
        let b = Matrix::<f32>::from_vec(1, n, g.vec_normal_f32(n));
        let (x, stats) = solve_cg(
            &mut DenseOp(&a),
            &b,
            &Preconditioner::Identity,
            &CgOptions { max_iters: 200, tol: 1e-4 },
        );
        assert!(stats.converged, "{:?}", stats.rel_residuals);
        let back = a.matvec(x.row(0));
        for (g, w) in back.iter().zip(b.row(0)) {
            assert!((g - w).abs() < 1e-2);
        }
    }
}
