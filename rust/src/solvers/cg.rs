//! Batched preconditioned conjugate gradients.
//!
//! Solves A X = B for several right-hand sides at once, where A is any
//! symmetric positive-definite operator exposed through `BatchedOp`
//! (rows of the batch matrix are independent systems, so the MVM cost
//! is amortized across RHS — exactly how the paper batches y together
//! with pathwise/probe vectors). Per-system convergence is tracked by
//! relative residual norm (paper: tolerance 0.01).
//!
//! The solver is defensive: per-system NaN/Inf breakdown detection, a
//! stagnation watchdog (no residual progress across a window triggers a
//! residual-recomputation restart, then a typed stop), and an
//! indefinite-preconditioner check on z'r. All detection reads f64
//! reductions the solver already computes, so a healthy solve produces
//! bit-identical iterates with the checks in place.

use crate::linalg::{Matrix, Scalar};
use crate::util::failpoint::{self, FaultAction};

use super::precond::Preconditioner;

/// A symmetric positive definite operator applied to a batch of row
/// vectors: `out[b] = A v[b]`.
pub trait BatchedOp<T: Scalar> {
    /// Dimension n of the operator (rows of `v` have n columns).
    fn dim(&self) -> usize;
    /// Apply the operator to every row of `v`: `out[b] = A v[b]`.
    fn apply_batch(&mut self, v: &Matrix<T>) -> Matrix<T>;
    /// Operators whose applies can fail mid-solve (e.g. a PJRT backend,
    /// see `gp::backend::SystemOp`) report it here so the solver stops
    /// iterating instead of spinning on degenerate products; the caller
    /// is responsible for surfacing the underlying error after the
    /// solve returns.
    fn failed(&self) -> bool {
        false
    }
}

impl<T: Scalar, O: BatchedOp<T> + ?Sized> BatchedOp<T> for &mut O {
    fn dim(&self) -> usize {
        (**self).dim()
    }
    fn apply_batch(&mut self, v: &Matrix<T>) -> Matrix<T> {
        (**self).apply_batch(v)
    }
    fn failed(&self) -> bool {
        (**self).failed()
    }
}

/// Dense matrix as a BatchedOp (baselines, tests).
pub struct DenseOp<'a, T: Scalar>(
    /// The (symmetric) system matrix.
    pub &'a Matrix<T>,
);

impl<'a, T: Scalar> BatchedOp<T> for DenseOp<'a, T> {
    fn dim(&self) -> usize {
        self.0.rows
    }
    fn apply_batch(&mut self, v: &Matrix<T>) -> Matrix<T> {
        // out rows = v rows; out[b] = A v[b] = (v @ A^T) rows; A symmetric
        crate::linalg::gemm::matmul_nt(v, self.0)
    }
}

/// Stopping criteria for [`solve_cg`].
#[derive(Clone, Debug)]
pub struct CgOptions {
    /// Iteration cap per solve.
    pub max_iters: usize,
    /// relative residual norm tolerance ||r|| / ||b||.
    pub tol: f64,
    /// Stagnation window, tracked **per system**: when a system goes
    /// this many consecutive iterations without improving its relative
    /// residual by at least 0.1%, the solver restarts (recomputed
    /// residual) and, once that system's restarts are exhausted,
    /// retires it with [`SolveOutcome::Stagnated`] while the rest of
    /// the batch keeps iterating. 0 disables the watchdog.
    pub stall_window: usize,
    /// Residual-recomputation restarts allowed **per system** before a
    /// stagnated system gives up. One system's stall history never
    /// burns a sibling's budget.
    pub max_restarts: usize,
}

impl Default for CgOptions {
    fn default() -> Self {
        CgOptions { max_iters: 500, tol: 1e-2, stall_window: 50, max_restarts: 1 }
    }
}

/// Why a system (or the whole solve) stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolveOutcome {
    /// Relative residual met the tolerance.
    Converged,
    /// Iteration cap reached before the tolerance.
    MaxIters,
    /// Residual plateaued across the stall window with restarts
    /// exhausted.
    Stagnated,
    /// Residual became NaN/Inf (or the preconditioner was indefinite).
    Breakdown,
    /// The batched operator reported a failure mid-solve.
    OperatorFailed,
}

/// Per-system diagnostic of one [`solve_cg`] call.
#[derive(Clone, Copy, Debug)]
pub struct SolveDiag {
    /// How this system ended.
    pub outcome: SolveOutcome,
    /// Final relative residual of this system.
    pub rel_residual: f64,
}

/// Typed hard failures detected inside [`solve_cg`].
///
/// Recorded in [`CgStats::error`] (the solver still returns its best
/// iterate) so callers can apply recovery policy; the error type
/// survives `anyhow` chains for downcasting.
#[derive(Clone, Debug)]
pub enum SolveError {
    /// A residual became non-finite.
    Breakdown {
        /// System whose residual broke down first.
        system: usize,
        /// Iteration at which the breakdown was detected.
        iter: usize,
    },
    /// The preconditioner produced z'r < 0 beyond roundoff — it is not
    /// positive definite, so CG's invariants are void.
    IndefinitePreconditioner {
        /// System with the negative inner product.
        system: usize,
        /// Iteration at which it was detected.
        iter: usize,
        /// The offending z'r value.
        rz: f64,
    },
    /// The solve finished without reaching the tolerance (reported by
    /// policy layers; `solve_cg` itself records this via
    /// `converged == false`).
    NotConverged {
        /// System with the largest final relative residual.
        worst_system: usize,
        /// That system's relative residual.
        rel_residual: f64,
        /// Iterations executed.
        iters: usize,
    },
}

impl std::fmt::Display for SolveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolveError::Breakdown { system, iter } => write!(
                f,
                "CG breakdown: non-finite residual in system {system} at iteration {iter}"
            ),
            SolveError::IndefinitePreconditioner { system, iter, rz } => write!(
                f,
                "preconditioner is not positive definite: z'r = {rz:.3e} \
                 for system {system} at iteration {iter}"
            ),
            SolveError::NotConverged { worst_system, rel_residual, iters } => write!(
                f,
                "CG did not converge: system {worst_system} at relative residual \
                 {rel_residual:.3e} after {iters} iterations"
            ),
        }
    }
}

impl std::error::Error for SolveError {}

/// Convergence report of one [`solve_cg`] call.
#[derive(Clone, Debug, Default)]
pub struct CgStats {
    /// Iterations executed.
    pub iters: usize,
    /// Batched operator applications performed.
    pub mvm_count: usize,
    /// final relative residuals per system
    pub rel_residuals: Vec<f64>,
    /// True when every system met the tolerance.
    pub converged: bool,
    /// Per-system outcome and final residual.
    pub diags: Vec<SolveDiag>,
    /// Stagnation restarts taken during the solve, summed over the
    /// batch (each system draws on its own `max_restarts` budget).
    pub restarts: usize,
    /// Hard failure detected mid-solve (breakdown / indefinite
    /// preconditioner); `None` for clean, merely-unconverged, or
    /// operator-failed solves (the operator owns its own error).
    pub error: Option<SolveError>,
}

fn diags_from(rel: &[f64], tol: f64, fallback: SolveOutcome) -> Vec<SolveDiag> {
    rel.iter()
        .map(|&r| SolveDiag {
            outcome: if !r.is_finite() {
                SolveOutcome::Breakdown
            } else if r <= tol {
                SolveOutcome::Converged
            } else {
                fallback
            },
            rel_residual: r,
        })
        .collect()
}

/// z'r must be finite and >= 0 for an SPD preconditioner. Returns the
/// first active system where it is negative beyond roundoff (scaled by
/// ||r||^2) — or non-finite while the residual itself is still finite,
/// which means the preconditioner apply poisoned z (a broken residual
/// is the breakdown detector's case, not this one).
fn indefinite_system<T: Scalar>(rz: &[f64], active: &[bool], r: &Matrix<T>) -> Option<usize> {
    for sys in 0..rz.len() {
        if !active[sys] || (rz[sys].is_finite() && rz[sys] >= 0.0) {
            continue;
        }
        let mut rr = 0.0f64;
        for v in r.row(sys) {
            let f = v.to_f64();
            rr += f * f;
        }
        if !rz[sys].is_finite() {
            if rr.is_finite() {
                return Some(sys);
            }
            continue;
        }
        if rz[sys].abs() > 1e-12 * rr.max(1e-300) {
            return Some(sys);
        }
    }
    None
}

/// Solve A X = B with batched PCG. Returns (X, stats); X rows align
/// with B rows. Iteration stops when every system's relative residual
/// is below tol (or max_iters). Hard failures (NaN residual, indefinite
/// preconditioner) abort early with `stats.error` set; the operator
/// signalling `failed()` stops the solve with the partial iterate and
/// leaves error reporting to the operator's owner.
pub fn solve_cg<T: Scalar>(
    op: &mut impl BatchedOp<T>,
    b: &Matrix<T>,
    precond: &Preconditioner<T>,
    opts: &CgOptions,
) -> (Matrix<T>, CgStats) {
    let n = op.dim();
    assert_eq!(b.cols, n, "rhs dim");
    let nsys = b.rows;
    let mut x = Matrix::<T>::zeros(nsys, n);
    let mut r = b.clone(); // r = b - A*0
    let mut z = precond.apply_batch(&r);
    let mut p = z.clone();

    let dot_rows = |a: &Matrix<T>, c: &Matrix<T>| -> Vec<f64> {
        (0..a.rows)
            .map(|i| {
                let mut s = 0.0f64;
                for (x, y) in a.row(i).iter().zip(c.row(i)) {
                    s += x.to_f64() * y.to_f64();
                }
                s
            })
            .collect()
    };

    let b_norms: Vec<f64> = dot_rows(b, b).iter().map(|s| s.sqrt().max(1e-300)).collect();
    let mut rz = dot_rows(&r, &z);
    let mut stats = CgStats::default();
    let mut active = vec![true; nsys];
    // stagnation watchdog state, all tracked per system: one system's
    // stall streak must never consume a sibling's restart budget
    let mut best_rel = vec![f64::INFINITY; nsys];
    let mut stall = vec![0usize; nsys];
    let mut restarts_used = vec![0usize; nsys];
    let mut stagnated = vec![false; nsys];
    let mut tail_outcome = SolveOutcome::MaxIters;

    if let Some(sys) = indefinite_system(&rz, &active, &r) {
        let rel: Vec<f64> =
            dot_rows(&r, &r).iter().zip(&b_norms).map(|(s, bn)| s.sqrt() / bn).collect();
        stats.error =
            Some(SolveError::IndefinitePreconditioner { system: sys, iter: 0, rz: rz[sys] });
        stats.diags = diags_from(&rel, opts.tol, SolveOutcome::Breakdown);
        stats.rel_residuals = rel;
        return (x, stats);
    }

    for iter in 0..opts.max_iters {
        if matches!(failpoint::check("cg_iter"), Some(FaultAction::Nan)) {
            r[(0, 0)] = T::from_f64(f64::NAN);
        }
        // convergence check
        let rr = dot_rows(&r, &r);
        let rel: Vec<f64> = rr.iter().zip(&b_norms).map(|(s, bn)| s.sqrt() / bn).collect();
        // breakdown detection: a non-finite residual would otherwise
        // read as "converged" (NaN > tol is false) and poison x forever
        if let Some(sys) = rel.iter().position(|v| !v.is_finite()) {
            stats.error = Some(SolveError::Breakdown { system: sys, iter });
            stats.diags = diags_from(&rel, opts.tol, SolveOutcome::Breakdown);
            stats.rel_residuals = rel;
            stats.iters = iter;
            return (x, stats);
        }
        for (sys, a) in active.iter_mut().enumerate() {
            *a = rel[sys] > opts.tol && !stagnated[sys];
        }
        if active.iter().all(|a| !a) {
            if stagnated.iter().any(|&s| s) {
                // retired systems keep their last residual; fall through
                // to the final report so they read Stagnated, not
                // Converged
                break;
            }
            stats.converged = true;
            stats.iters = iter;
            stats.diags = diags_from(&rel, opts.tol, SolveOutcome::Converged);
            stats.rel_residuals = rel;
            return (x, stats);
        }
        // stagnation watchdog: a system makes progress when it improves
        // its own best-seen residual by at least 0.1%; stall streaks
        // are per system
        for sys in 0..nsys {
            if active[sys] {
                if rel[sys] < 0.999 * best_rel[sys] {
                    stall[sys] = 0;
                } else {
                    stall[sys] += 1;
                }
            }
            if rel[sys] < best_rel[sys] {
                best_rel[sys] = rel[sys];
            }
        }
        stats.rel_residuals = rel;
        // a system whose streak hit the window restarts against its own
        // budget; once that budget is exhausted it retires as Stagnated
        // while the rest of the batch keeps iterating
        let mut restart_now = false;
        if opts.stall_window > 0 {
            for sys in 0..nsys {
                if !active[sys] || stall[sys] < opts.stall_window {
                    continue;
                }
                if restarts_used[sys] < opts.max_restarts {
                    restarts_used[sys] += 1;
                    restart_now = true;
                    break;
                }
                stagnated[sys] = true;
                active[sys] = false;
            }
        }
        if restart_now {
            // restart: recompute r = b - A x from scratch to shed
            // accumulated rounding drift, then rebuild the Krylov
            // direction state (shared across the batch, so every
            // system's stall streak starts over)
            let ax = op.apply_batch(&x);
            stats.mvm_count += 1;
            if op.failed() {
                tail_outcome = SolveOutcome::OperatorFailed;
                break;
            }
            for sys in 0..nsys {
                let (rrow, brow, axrow) = (r.row_mut(sys), b.row(sys), ax.row(sys));
                for ((ri, bi), ai) in rrow.iter_mut().zip(brow).zip(axrow) {
                    *ri = *bi - *ai;
                }
            }
            z = precond.apply_batch(&r);
            p = z.clone();
            rz = dot_rows(&r, &z);
            if let Some(sys) = indefinite_system(&rz, &active, &r) {
                stats.error = Some(SolveError::IndefinitePreconditioner {
                    system: sys,
                    iter,
                    rz: rz[sys],
                });
                stats.diags =
                    diags_from(&stats.rel_residuals, opts.tol, SolveOutcome::Breakdown);
                stats.iters = iter;
                return (x, stats);
            }
            stats.restarts += 1;
            for s in stall.iter_mut() {
                *s = 0;
            }
            stats.iters = iter;
            continue;
        }
        if active.iter().all(|a| !a) {
            // every remaining system just retired stagnated
            break;
        }

        let ap = op.apply_batch(&p);
        stats.mvm_count += 1;
        if op.failed() {
            tail_outcome = SolveOutcome::OperatorFailed;
            break; // operator failure: stop, caller surfaces the error
        }
        let pap = dot_rows(&p, &ap);
        for sys in 0..nsys {
            if !active[sys] || pap[sys].abs() < 1e-300 {
                continue;
            }
            let alpha = T::from_f64(rz[sys] / pap[sys]);
            let (xr, pr) = (x.row_mut(sys), p.row(sys));
            for (xi, pi) in xr.iter_mut().zip(pr) {
                *xi += alpha * *pi;
            }
            let (rrow, aprow) = (r.row_mut(sys), ap.row(sys));
            for (ri, api) in rrow.iter_mut().zip(aprow) {
                *ri -= alpha * *api;
            }
        }
        z = precond.apply_batch(&r);
        let rz_new = dot_rows(&r, &z);
        if let Some(sys) = indefinite_system(&rz_new, &active, &r) {
            stats.error = Some(SolveError::IndefinitePreconditioner {
                system: sys,
                iter,
                rz: rz_new[sys],
            });
            stats.diags = diags_from(&stats.rel_residuals, opts.tol, SolveOutcome::Breakdown);
            stats.iters = iter;
            return (x, stats);
        }
        for sys in 0..nsys {
            if !active[sys] {
                continue;
            }
            let beta = if rz[sys].abs() < 1e-300 { 0.0 } else { rz_new[sys] / rz[sys] };
            let betat = T::from_f64(beta);
            let (prow, zrow) = (p.row_mut(sys), z.row(sys));
            for (pi, zi) in prow.iter_mut().zip(zrow) {
                *pi = *zi + betat * *pi;
            }
        }
        rz = rz_new;
        stats.iters = iter + 1;
    }
    // final residual report
    let rr = dot_rows(&r, &r);
    stats.rel_residuals = rr.iter().zip(&b_norms).map(|(s, bn)| s.sqrt() / bn).collect();
    stats.converged = stats.rel_residuals.iter().all(|&r| r <= opts.tol);
    let fallback = if stats.converged { SolveOutcome::Converged } else { tail_outcome };
    stats.diags = diags_from(&stats.rel_residuals, opts.tol, fallback);
    for (sys, diag) in stats.diags.iter_mut().enumerate() {
        if stagnated[sys]
            && !matches!(diag.outcome, SolveOutcome::Converged | SolveOutcome::Breakdown)
        {
            diag.outcome = SolveOutcome::Stagnated;
        }
    }
    (x, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::precond::Preconditioner;
    use crate::util::testing::{assert_close, prop_check};

    #[test]
    fn failed_operator_stops_after_one_mvm() {
        struct FailingOp;
        impl BatchedOp<f64> for FailingOp {
            fn dim(&self) -> usize {
                8
            }
            fn apply_batch(&mut self, v: &Matrix<f64>) -> Matrix<f64> {
                Matrix::zeros(v.rows, v.cols)
            }
            fn failed(&self) -> bool {
                true
            }
        }
        let b = Matrix::from_vec(1, 8, vec![1.0; 8]);
        let (x, stats) =
            solve_cg(&mut FailingOp, &b, &Preconditioner::Identity, &CgOptions::default());
        assert!(!stats.converged);
        assert_eq!(stats.mvm_count, 1);
        assert!(x.data.iter().all(|&v| v == 0.0));
        assert!(stats.diags.iter().all(|d| d.outcome == SolveOutcome::OperatorFailed));
    }

    #[test]
    fn prop_cg_solves_spd_systems() {
        prop_check("cg-solves", 83, 15, |g| {
            let n = g.size(1, 30);
            let a = Matrix::from_vec(n, n, g.spd(n));
            let b = Matrix::from_vec(3, n, g.vec_normal(3 * n));
            let mut op = DenseOp(&a);
            let (x, stats) = solve_cg(
                &mut op,
                &b,
                &Preconditioner::Identity,
                &CgOptions { max_iters: 10 * n, tol: 1e-10, ..CgOptions::default() },
            );
            if !stats.converged {
                return Err(format!("not converged: {:?}", stats.rel_residuals));
            }
            if stats.error.is_some() {
                return Err(format!("unexpected solve error: {:?}", stats.error));
            }
            for sys in 0..3 {
                let back = a.matvec(x.row(sys));
                assert_close(&back, b.row(sys), 1e-6)?;
            }
            Ok(())
        });
    }

    #[test]
    fn jacobi_preconditioner_reduces_iterations() {
        // strongly diagonal-dominant, badly scaled system
        let n = 60;
        let a = Matrix::from_fn(n, n, |i, j| {
            if i == j {
                10.0 * (1.0 + i as f64)
            } else {
                1.0 / (1.0 + (i as f64 - j as f64).abs())
            }
        });
        let b = Matrix::from_vec(1, n, vec![1.0; n]);
        let opts = CgOptions { max_iters: 200, tol: 1e-8, ..CgOptions::default() };
        let (_, s_plain) = solve_cg(&mut DenseOp(&a), &b, &Preconditioner::Identity, &opts);
        let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
        let pre = Preconditioner::jacobi(&diag);
        let (_, s_pre) = solve_cg(&mut DenseOp(&a), &b, &pre, &opts);
        assert!(s_pre.converged && s_plain.converged);
        assert!(
            s_pre.iters < s_plain.iters,
            "jacobi {} !< plain {}",
            s_pre.iters,
            s_plain.iters
        );
    }

    #[test]
    fn per_system_convergence_tracked() {
        let n = 20;
        let a = Matrix::from_fn(n, n, |i, j| if i == j { 2.0 } else { 0.0 });
        let mut b = Matrix::zeros(2, n);
        b.row_mut(0).copy_from_slice(&vec![1.0; n]);
        // second system has zero rhs -> converged immediately
        let (x, stats) = solve_cg(
            &mut DenseOp(&a),
            &b,
            &Preconditioner::Identity,
            &CgOptions::default(),
        );
        assert!(stats.converged);
        assert!(x.row(0).iter().all(|&v| (v - 0.5).abs() < 1e-6));
        assert!(x.row(1).iter().all(|&v| v.abs() < 1e-12));
        assert_eq!(stats.diags.len(), 2);
        assert!(stats.diags.iter().all(|d| d.outcome == SolveOutcome::Converged));
    }

    #[test]
    fn f32_path_converges() {
        let mut g = crate::util::testing::Gen { rng: crate::util::rng::Rng::new(9) };
        let n = 25;
        let a64 = Matrix::from_vec(n, n, g.spd(n));
        let a: Matrix<f32> = a64.cast();
        let b = Matrix::<f32>::from_vec(1, n, g.vec_normal_f32(n));
        let (x, stats) = solve_cg(
            &mut DenseOp(&a),
            &b,
            &Preconditioner::Identity,
            &CgOptions { max_iters: 200, tol: 1e-4, ..CgOptions::default() },
        );
        assert!(stats.converged, "{:?}", stats.rel_residuals);
        let back = a.matvec(x.row(0));
        for (g, w) in back.iter().zip(b.row(0)) {
            assert!((g - w).abs() < 1e-2);
        }
    }

    #[test]
    fn stagnation_restarts_then_stops_typed() {
        // an operator that maps everything to zero makes no progress:
        // pap = 0 skips every update, so the residual plateaus forever
        struct ZeroOp(usize);
        impl BatchedOp<f64> for ZeroOp {
            fn dim(&self) -> usize {
                self.0
            }
            fn apply_batch(&mut self, v: &Matrix<f64>) -> Matrix<f64> {
                Matrix::zeros(v.rows, v.cols)
            }
        }
        let n = 8;
        let b = Matrix::from_vec(1, n, vec![1.0; n]);
        let opts = CgOptions { max_iters: 200, tol: 1e-8, stall_window: 5, max_restarts: 1 };
        let (x, stats) = solve_cg(&mut ZeroOp(n), &b, &Preconditioner::Identity, &opts);
        assert!(!stats.converged);
        assert_eq!(stats.restarts, 1, "one restart before giving up");
        assert!(stats.iters < 200, "watchdog must fire well before max_iters");
        assert!(stats.diags.iter().all(|d| d.outcome == SolveOutcome::Stagnated));
        assert!(x.data.iter().all(|&v| v == 0.0));
        assert!(stats.error.is_none(), "stagnation is policy, not a hard error");
    }

    #[test]
    fn stalled_system_does_not_burn_siblings_budget() {
        // row 0 sees a zero operator (stalls forever); row 1 sees the
        // identity (converges in one iteration). The stalling system
        // must retire as Stagnated without dragging the converged one
        // down with it.
        struct SplitOp(usize);
        impl BatchedOp<f64> for SplitOp {
            fn dim(&self) -> usize {
                self.0
            }
            fn apply_batch(&mut self, v: &Matrix<f64>) -> Matrix<f64> {
                let mut out = v.clone();
                for x in out.row_mut(0).iter_mut() {
                    *x = 0.0;
                }
                out
            }
        }
        let n = 8;
        let mut b = Matrix::zeros(2, n);
        b.row_mut(0).copy_from_slice(&vec![1.0; n]);
        b.row_mut(1).copy_from_slice(&vec![2.0; n]);
        let opts = CgOptions { max_iters: 200, tol: 1e-8, stall_window: 5, max_restarts: 1 };
        let (x, stats) = solve_cg(&mut SplitOp(n), &b, &Preconditioner::Identity, &opts);
        assert!(!stats.converged);
        assert_eq!(stats.diags[0].outcome, SolveOutcome::Stagnated, "{:?}", stats.diags);
        assert_eq!(stats.diags[1].outcome, SolveOutcome::Converged, "{:?}", stats.diags);
        assert_eq!(stats.restarts, 1, "only the stalling system restarts");
        assert!(x.row(1).iter().all(|&v| (v - 2.0).abs() < 1e-9));
        assert!(stats.error.is_none(), "stagnation is policy, not a hard error");
    }

    #[test]
    fn restart_budget_is_per_system() {
        // both systems stall: each must draw on its own restart budget
        // (the old batch-global counter allowed a single restart total,
        // so system 0's stall history starved system 1)
        struct ZeroOp(usize);
        impl BatchedOp<f64> for ZeroOp {
            fn dim(&self) -> usize {
                self.0
            }
            fn apply_batch(&mut self, v: &Matrix<f64>) -> Matrix<f64> {
                Matrix::zeros(v.rows, v.cols)
            }
        }
        let n = 6;
        let mut b = Matrix::zeros(2, n);
        b.row_mut(0).copy_from_slice(&vec![1.0; n]);
        b.row_mut(1).copy_from_slice(&vec![3.0; n]);
        let opts = CgOptions { max_iters: 200, tol: 1e-8, stall_window: 5, max_restarts: 1 };
        let (_, stats) = solve_cg(&mut ZeroOp(n), &b, &Preconditioner::Identity, &opts);
        assert!(!stats.converged);
        assert_eq!(stats.restarts, 2, "one restart per stalling system");
        assert!(stats.diags.iter().all(|d| d.outcome == SolveOutcome::Stagnated));
        assert!(stats.error.is_none());
    }

    #[test]
    fn poisoned_preconditioner_apply_reads_indefinite() {
        // a preconditioner that emits NaN on a finite residual must be
        // flagged as indefinite (so the downgrade path can re-solve),
        // not misread as convergence or a residual breakdown
        let n = 5;
        let a = Matrix::from_fn(n, n, |i, j| if i == j { 2.0 } else { 0.0 });
        let b = Matrix::from_vec(1, n, vec![1.0; n]);
        let pre = Preconditioner::Jacobi { inv_diag: vec![f64::NAN; n] };
        let (_, stats) = solve_cg(&mut DenseOp(&a), &b, &pre, &CgOptions::default());
        assert!(!stats.converged);
        assert!(
            matches!(stats.error, Some(SolveError::IndefinitePreconditioner { system: 0, .. })),
            "{:?}",
            stats.error
        );
    }

    #[test]
    fn indefinite_preconditioner_detected() {
        let n = 10;
        let a = Matrix::from_fn(n, n, |i, j| if i == j { 2.0 } else { 0.0 });
        let b = Matrix::from_vec(1, n, vec![1.0; n]);
        // a negative "inverse diagonal" is not SPD: z'r = -||r||^2 < 0
        let pre = Preconditioner::Jacobi { inv_diag: vec![-1.0; n] };
        let (_, stats) = solve_cg(&mut DenseOp(&a), &b, &pre, &CgOptions::default());
        assert!(!stats.converged);
        match stats.error {
            Some(SolveError::IndefinitePreconditioner { system: 0, rz, .. }) => {
                assert!(rz < 0.0, "rz {rz}");
            }
            ref other => panic!("expected IndefinitePreconditioner, got {other:?}"),
        }
    }

    #[test]
    fn nan_residual_is_a_typed_breakdown() {
        // operator that injects a NaN into its output: alpha goes NaN,
        // poisoning r, and the solver must stop with a typed error
        // instead of reporting instant convergence (NaN > tol == false)
        struct NanOp(usize);
        impl BatchedOp<f64> for NanOp {
            fn dim(&self) -> usize {
                self.0
            }
            fn apply_batch(&mut self, v: &Matrix<f64>) -> Matrix<f64> {
                let mut out = v.clone();
                out[(0, 0)] = f64::NAN;
                out
            }
        }
        let n = 6;
        let b = Matrix::from_vec(1, n, vec![1.0; n]);
        let (_, stats) =
            solve_cg(&mut NanOp(n), &b, &Preconditioner::Identity, &CgOptions::default());
        assert!(!stats.converged);
        assert!(
            matches!(stats.error, Some(SolveError::Breakdown { system: 0, .. })),
            "{:?}",
            stats.error
        );
        assert!(stats.iters <= 2, "breakdown must be caught immediately");
        assert_eq!(stats.diags[0].outcome, SolveOutcome::Breakdown);
    }
}
